"""Paper Table 1 (RULER-style accuracy vs sparsity): synthetic retrieval —
attention-output relative error and top-k recall at 5/10/20/50x sparsity
for SOCKET vs Quest, hard LSH, HashAttention and the oracle."""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import (attention_output_error,
                               heavy_hitter_workload)
from repro.baselines import hard_lsh, hash_attn, quest
from repro.core import hashing, socket


def run(n: int = 4096, d: int = 128, n_queries: int = 16):
    rng = jax.random.PRNGKey(3)
    queries, keys, values, targets = heavy_hitter_workload(
        rng, n, d, n_queries)
    scale = 1.0 / np.sqrt(d)
    true = np.asarray(queries @ keys.T)                   # (Q, N)

    # build all indexes once
    scfg = socket.SocketConfig(num_planes=10, num_tables=60, tau=0.4)
    w = hashing.make_hash_params(jax.random.fold_in(rng, 1), d, 10, 60)
    packed = hashing.pack_signs(hashing.hash_keys_signs(w, keys))

    h1 = hard_lsh.HardLSHConfig(num_planes=2, num_tables=300)
    st_h = hard_lsh.build(h1, jax.random.fold_in(rng, 2), keys, values)
    qcfg = quest.QuestConfig(page_size=16)
    st_q = quest.build(qcfg, jax.random.fold_in(rng, 3), keys, values)
    hacfg = hash_attn.HashAttnConfig(num_bits=128)
    st_ha = hash_attn.build(hacfg, jax.random.fold_in(rng, 4), keys,
                            values)

    def scores(method, q):
        if method == "socket":
            return np.asarray(socket.soft_scores_factorized(
                scfg, packed, socket.soft_hash_query(w, q)))
        if method == "hard_lsh":
            return np.asarray(hard_lsh.score(st_h, h1, q))
        if method == "quest":
            return np.asarray(quest.token_scores(st_q, qcfg, q, n))
        if method == "hash_attn":
            return np.asarray(hash_attn.score(st_ha, hacfg, q))
        if method == "oracle":
            return np.asarray(keys @ q)
        raise ValueError(method)

    rows = []
    for sparsity in (5, 10, 20, 50):
        k = max(16, n // sparsity)
        for method in ("oracle", "socket", "quest", "hard_lsh",
                       "hash_attn"):
            recalls, errs = [], []
            for qi in range(n_queries):
                q = queries[qi]
                s = scores(method, q)
                sel = np.argsort(-s)[:k]
                true_top = set(np.argsort(-true[qi])[:k].tolist())
                recalls.append(len(set(sel.tolist()) & true_top) / k)
                errs.append(attention_output_error(
                    q, keys, values, jnp.asarray(sel), scale))
            rows.append((f"tab1_{method}_spr{sparsity}x", {
                "recall": float(np.mean(recalls)),
                "attn_rel_err": float(np.mean(errs))}))
    return rows


def main():
    for name, m in run():
        print(f"{name},recall={m['recall']:.3f},"
              f"attn_rel_err={m['attn_rel_err']:.4f}")


if __name__ == "__main__":
    main()
