"""Kernel micro-benchmarks: the Pallas socket_score / flash_decode /
flash_prefill wall-times (interpret mode on CPU — structural check that the
wrappers dispatch; the §Roofline analytic model carries the TPU numbers)
plus the XLA scoring path they replace."""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import time_fn
from repro.core import hashing, socket


def run():
    rng = jax.random.PRNGKey(0)
    rows = []
    d, n, bh, g = 128, 8192, 4, 4
    kk, kq, kw, kv = jax.random.split(rng, 4)
    w = hashing.make_hash_params(kw, d, 10, 60)
    keys = jax.random.normal(kk, (bh, n, d))
    q = jax.random.normal(kq, (bh, g, d))
    bits = hashing.pack_signs(hashing.hash_keys_signs(w, keys))
    u = socket.soft_hash_query(w, q)
    vnorm = jax.random.uniform(kv, (bh, n)) + 0.5

    cfg = socket.SocketConfig(num_planes=10, num_tables=60, tau=0.4)
    xla_fn = jax.jit(lambda b, uu: jax.vmap(
        lambda bb, uu2: socket.soft_scores_factorized(cfg, bb, uu2))(
            b, uu))
    t_xla = time_fn(xla_fn, bits, u[:, 0], iters=10)
    rows.append(("kernel_score_xla_path", {"us": t_xla}))

    # the Pallas kernel in interpret mode is orders slower on CPU (python
    # grid loop) — time one small shape only as a smoke measurement
    from repro.kernels.socket_score import socket_score
    small_bits = bits[:1, :1024]
    small_u = u[:1]
    t_pallas = time_fn(
        lambda b, uu: socket_score(b, uu, None, num_tables=60,
                                   num_planes=10, tau=0.4),
        small_bits, small_u, iters=3, warmup=1)
    rows.append(("kernel_score_pallas_interpret_1k", {"us": t_pallas}))

    from repro.kernels.flash_decode import flash_decode
    kk2 = jax.random.normal(rng, (bh, 1024, d))
    vv2 = jax.random.normal(rng, (bh, 1024, d))
    mask = jnp.ones((bh, 1024), bool)
    t_fd = time_fn(
        lambda a, b, c, m: flash_decode(a, b, c, m, scale=0.1,
                                        block_k=512),
        q, kk2, vv2, mask, iters=3, warmup=1)
    rows.append(("kernel_flash_decode_interpret_1k", {"us": t_fd}))
    return rows


def main():
    for name, m in run():
        print(f"{name},us={m['us']:.0f}")


if __name__ == "__main__":
    main()
