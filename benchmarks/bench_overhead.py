"""Paper Table 2: retrieval memory + scoring-time overhead — SOCKET
(P=10, L=60) vs hard LSH at increasing L.  Memory is the exact cache
footprint (bits/token); time is the measured jitted scoring wall-time on
this host plus the analytic TPU v5e HBM-traffic model (the quantity the
CUDA kernel optimizes)."""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import time_fn
from repro.core import hashing, socket
from repro.baselines import hard_lsh
from repro.roofline.analysis import HW


def run(n: int = 32768, d: int = 128):
    rng = jax.random.PRNGKey(0)
    kk, kq = jax.random.split(rng)
    keys = jax.random.normal(kk, (n, d))
    q = jax.random.normal(kq, (d,))
    rows = []

    def tpu_score_time(bits_per_token):
        bytes_moved = n * (bits_per_token / 8 + 2)      # bits + bf16 vnorm
        return bytes_moved / HW["hbm_bw"] * 1e6         # µs

    # SOCKET (10, 60)
    cfg = socket.SocketConfig(num_planes=10, num_tables=60, tau=0.4)
    w = hashing.make_hash_params(rng, d, 10, 60)
    packed = hashing.pack_signs(hashing.hash_keys_signs(w, keys))
    u = socket.soft_hash_query(w, q)
    f = jax.jit(lambda b, uu: socket.soft_scores_factorized(cfg, b, uu))
    us = time_fn(f, packed, u)
    stored_bits = packed.shape[-1] * 32
    rows.append(("tab2_socket_p10_l60", {
        "bits_per_token": stored_bits,
        "mem_gb_32k_8bh": stored_bits / 8 * n * 8 / 2**30,
        "cpu_us": us,
        "tpu_model_us": tpu_score_time(stored_bits)}))

    # hard LSH at growing budgets
    for l in (60, 300, 400, 500):
        p = 10 if l == 60 else 2
        hcfg = hard_lsh.HardLSHConfig(num_planes=p, num_tables=l)
        st = hard_lsh.build(hcfg, jax.random.fold_in(rng, l), keys, keys)
        fh = jax.jit(lambda qq: hard_lsh.score(st, hcfg, qq))
        us_h = time_fn(fh, q)
        stored = st.packed.shape[-1] * 32
        rows.append((f"tab2_hardlsh_p{p}_l{l}", {
            "bits_per_token": stored,
            "mem_gb_32k_8bh": stored / 8 * n * 8 / 2**30,
            "cpu_us": us_h,
            "tpu_model_us": tpu_score_time(stored)}))

    # dense reference: reading full bf16 keys
    rows.append(("tab2_dense_keys_read", {
        "bits_per_token": d * 16,
        "mem_gb_32k_8bh": d * 2 * n * 8 / 2**30,
        "cpu_us": float("nan"),
        "tpu_model_us": tpu_score_time(d * 16)}))
    return rows


def main():
    for name, m in run():
        print(f"{name},bits/tok={m['bits_per_token']},"
              f"cpu_us={m['cpu_us']:.0f},tpu_model_us={m['tpu_model_us']:.1f}")


if __name__ == "__main__":
    main()
