"""Paper fig. 3 (b-c): decode throughput vs context length, SOCKET vs
dense attention.

Two measurements per context length:
* measured: jitted single-layer decode-attention wall-time on this host
  (CPU — direction is meaningful, magnitude is not);
* modelled: TPU v5e HBM-traffic time for the same step (the regime the
  paper's H200/A100 numbers probe — decode is bandwidth-bound), from
  which the projected SOCKET speedup over dense is derived.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import time_fn
from repro.baselines import oracle
from repro.core import hashing, socket
from repro.roofline.analysis import HW


def _tpu_decode_us(n, d, kvh, mode, cfg):
    """Bytes moved per decode step per KV head group (bf16 K/V)."""
    if mode == "dense":
        byt = n * d * 2 * 2 * kvh                      # read all K and V
    else:
        w = hashing.num_words(cfg.num_tables, cfg.num_planes)
        k = socket.topk_budget(cfg, n)
        byt = kvh * (n * (w * 4 + 2)                   # bits + vnorm
                     + k * d * 2 * 2)                  # gathered K/V
    return byt / HW["hbm_bw"] * 1e6


def run(d: int = 128, kvh: int = 8, g: int = 4):
    rng = jax.random.PRNGKey(0)
    cfg = socket.SocketConfig(num_planes=10, num_tables=60, tau=0.4,
                              sparsity=33.0, sink_tokens=128,
                              window_tokens=128, min_k=128,
                              score_chunk=16384)
    rows = []
    for n in (8192, 32768, 65536, 131072):
        kk, kv, kq, kw = jax.random.split(jax.random.fold_in(rng, n), 4)
        keys = jax.random.normal(kk, (1, kvh, n, d), jnp.bfloat16)
        vals = jax.random.normal(kv, (1, kvh, n, d), jnp.bfloat16)
        q = jax.random.normal(kq, (1, kvh, g, 1, d), jnp.bfloat16)
        w = hashing.make_hash_params(kw, d, 10, 60)
        side = socket.precompute_key_hashes(cfg, w, keys, vals)

        dense_fn = jax.jit(lambda qq, kk2, vv: oracle.dense_attention(
            qq, kk2, vv, scale=1 / np.sqrt(d), length=n))
        t_dense = time_fn(dense_fn, q, keys, vals, iters=8)

        sock_fn = jax.jit(lambda qq, kk2, vv, b, vn: socket.socket_attend(
            cfg, w, qq, kk2, vv, socket.SocketCache(b, vn), length=n,
            scale=1 / np.sqrt(d)))
        t_sock = time_fn(sock_fn, q, keys, vals, side.bits, side.vnorm,
                         iters=8)

        m_dense = _tpu_decode_us(n, d, kvh, "dense", cfg)
        m_sock = _tpu_decode_us(n, d, kvh, "socket", cfg)
        rows.append((f"fig3_ctx{n}", {
            "cpu_dense_us": t_dense, "cpu_socket_us": t_sock,
            "cpu_speedup": t_dense / t_sock,
            "tpu_model_dense_us": m_dense, "tpu_model_socket_us": m_sock,
            "tpu_model_speedup": m_dense / m_sock}))
    return rows


def main():
    for name, m in run():
        print(f"{name},cpu_speedup={m['cpu_speedup']:.2f},"
              f"tpu_model_speedup={m['tpu_model_speedup']:.2f},"
              f"cpu_dense_us={m['cpu_dense_us']:.0f},"
              f"cpu_socket_us={m['cpu_socket_us']:.0f}")


if __name__ == "__main__":
    main()
