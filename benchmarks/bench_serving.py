"""Serving benchmark: continuous-batching engine vs static lockstep batch.

The paper's fig. 3 throughput story at *serving* granularity: Poisson
arrivals, mixed prompt lengths, paged KV + SOCKET bit-cache.  Reports
decode throughput, TTFT and p50/p99 per-token latency per backend, plus
the static-batch baseline for the same token volume, plus the per-step
gathered-bytes accounting (full contiguous views vs the paged top-k
gather vs the fused paged kernel's zero-materialization pass) that the
DecodeBackend/KVView redesign exists to win.

The ``*_fused`` pseudo-backends (``socket_fused``, ``hard_lsh_fused``,
``quest_fused``) set the corresponding ``cfg.*.use_paged_kernel``: the
whole score → select → attend pipeline runs as one Pallas pass over the
block table, so their ``gathered_kb_per_step`` reports ≈ 0 vs the
unfused paged paths' O(top_k) rows (and the dense path's full views) —
asserted here, so a routing regression fails the bench.

Hybrid rows (``hybrid_gemma3`` / ``hybrid_jamba``) serve the
heterogeneous per-layer cache-plan configs — 5:1 local:global and
attn:mamba — where window layers report *bounded* gathered bytes
(``window_kb_per_step``) and mamba layers ~0.  The
``hybrid_gemma3_ringfused`` row additionally sets
``cfg.use_ring_kernel`` so the local layers stream their circular page
lists through the Pallas ring pass — ``window_kb_per_step`` asserted 0.

Head-of-line rows (``serve_longprompt_chunked`` /
``serve_longprompt_unchunked``) replay the same workload — one
max-length prompt arriving while short requests decode — through the
chunked token-budget mixed step and the legacy whole-prompt prefill;
``stall_ms_max`` (longest gap between consecutive tokens of any one
request) is the head-of-line-blocking number chunked prefill exists to
bound, ``iter_ms_p99`` the per-iteration tail.

Prefix-cache rows (``serve_prefix_chatbot`` / ``serve_prefix_rag``)
serve a shared-prefix workload — multi-turn chat sessions / shared RAG
template — cache-on vs cache-off on identical prompts and arrivals:
hit rate, cached/prompt token ratio, CoW copies, and the TTFT and
throughput deltas radix-tree page reuse buys; greedy generations are
asserted identical both ways (sharing must be token-exact).

Quantized-pool rows (``socket_fused_{bf16,int8,fp8}``) serve the fused
socket path with int8/fp8 K/V pages (per-row absmax scales, in-kernel
dequant) on one workload per storage mode: pool bytes/token at the
served plan, the production-geometry max-resident-requests-at-a-fixed-
pool-byte-budget capacity math (int8 asserted ≥ 1.8x bf16), throughput,
and the selection-quality probe vs the bf16 row (socket selection reads
the full-precision bits/vnorm leaves, so the probe's selection-side
stats and the greedy generations are asserted bit-identical; recall —
measured against a dense reference that reads the quantized cache — is
reported as a tightly-bounded delta).  A second sweep serves int8 pages
through dense, quest and hard_lsh so every backend's quantized
write/dequant-read path runs end to end; the fused rows re-assert zero
gathered pool bytes.

    PYTHONPATH=src python -m benchmarks.bench_serving --smoke [--json F]
"""

from __future__ import annotations

import argparse

HYBRID_ARCHS = {"hybrid_gemma3": "gemma3-27b", "hybrid_jamba":
                "jamba-v0.1-52b"}


def _cfg_for(backend: str, smoke: bool, arch: str = "stablelm-12b"):
    from repro.configs import get_config
    from repro.launch.serve import apply_backend_arg

    cfg = get_config(arch)
    if smoke:
        cfg = cfg.smoke()
    return apply_backend_arg(cfg, backend)


def _footprint_metrics(cfg):
    """Per-step gathered-bytes accounting, per layer kind."""
    from repro.serving.paged import gather_footprint

    fp = gather_footprint(cfg)
    return {
        "gathered_kb_full_view": fp["full_view_bytes_per_step"] / 1024,
        "gathered_kb_per_step": fp["paged_bytes_per_step"] / 1024,
        "window_kb_per_step": fp["window_bytes_per_step"] / 1024,
        "state_kb_per_step": fp["state_bytes_per_step"] / 1024,
        "selected_kv_rows": fp["selected_rows"],
        "fused_paged_kernel": fp["fused_paged_kernel"],
        "fused_ring_kernel": fp["fused_ring_kernel"],
    }


def _serve_row(m, num_requests, cfg):
    return {
        "tput_tok_s": float(m.throughput_tok_s),
        "ttft_ms_mean": float(m.ttft_s_mean * 1e3),
        "tok_ms_p50": float(m.token_latency_s_p50 * 1e3),
        "tok_ms_p99": float(m.token_latency_s_p99 * 1e3),
        "stall_ms_max": float(m.intertoken_stall_s_max * 1e3),
        "iter_ms_p99": float(m.decode_iter_s_p99 * 1e3),
        "preemptions": m.preemptions,
        "decode_iters": m.decode_iters,
        "prefill_chunks": m.prefill_chunks,
        "requests": num_requests,
        **_footprint_metrics(cfg),
    }


def run(smoke: bool = True, num_requests: int = 8, max_new: int = 8,
        backends=("socket", "socket_fused", "dense", "hard_lsh_fused",
                  "quest_fused"),
        hybrids=tuple(HYBRID_ARCHS)):
    """Benchmark-harness entry point (see benchmarks/run.py).

    Defaults are the --smoke operating point: tiny model, 8 requests,
    finishes in well under a minute on one CPU core.
    """
    from repro.launch.serve import run_continuous, run_serve, \
        serving_ceiling

    rows = []
    for backend in backends:
        cfg = _cfg_for(backend, smoke)
        sv = cfg.serving
        ceiling = serving_ceiling(cfg)
        top = ceiling - max_new
        if top < 1:
            raise ValueError(
                f"max_new={max_new} leaves no prompt room under the "
                f"serving context ceiling ({ceiling})")
        lens = sorted({max(1, top // 4), max(1, top // 2), top})

        # warmup=True: exclude jit compiles from the timed region, like
        # the static baseline's explicit warm-up — else TTFT/p99 compare
        # compile time against steady-state decode.
        # The plain-socket row also samples the selection-quality probe
        # (recall vs dense top-k, budget utilization) — the bench JSON
        # then carries a per-run "is selection still sane" pulse.
        obs = None
        if backend == "socket":
            from repro.serving.obs import Observability
            obs = Observability(probe_every=4)
        reqs, m, _ = run_continuous(cfg, num_requests, rate_rps=50.0,
                                    prompt_lens=lens,
                                    max_new_tokens=max_new,
                                    seed=0, warmup=True, obs=obs)
        assert all(r.state == "finished" for r in reqs)
        # memory-traffic accounting: bytes a decode step would move by
        # materializing full contiguous cache views vs what the paged
        # backend actually gathers (metadata + top-k K/V rows; ~0 when
        # the fused paged kernel consumes the pool in place)
        row = _serve_row(m, num_requests, cfg)
        if obs is not None:
            row["probe"] = obs.probe_summary()
        if backend.endswith("_fused"):
            # the point of the fused kernels: zero gathered pool bytes
            assert row["fused_paged_kernel"], (
                f"{backend}: fused_paged() did not claim the kernel path")
            assert row["gathered_kb_per_step"] == 0, (
                f"{backend}: fused paged path gathered "
                f"{row['gathered_kb_per_step']} KiB/step, expected 0")
        rows.append((f"serve_continuous_{backend}", row))

        # static lockstep baseline: same #sequences at the mean length
        # (the fused kernels only exist on the paged path — their static
        # runs would duplicate the unfused backends')
        if backend.endswith("_fused"):
            continue
        mean_len = int(sum(lens) / len(lens))
        _, prefill_s, decode_s = run_serve(
            cfg, batch=min(num_requests, sv.max_batch),
            prompt_len=mean_len, decode_steps=max_new)
        b = min(num_requests, sv.max_batch)
        rows.append((f"serve_static_{backend}", {
            "tput_tok_s": b * max_new / decode_s if decode_s > 0
            else float("nan"),
            "prefill_ms": float(prefill_s * 1e3),
            "decode_ms": float(decode_s * 1e3),
            "batch": b,
        }))

    # heterogeneous cache-plan rows: gemma3's 5:1 local:global and
    # jamba's attn:mamba patterns on the continuous engine (window
    # layers ring-paged, mamba layers per-slot state, global layers
    # socket-paged); fewer requests — they are deeper stacks.
    hybrid_rows = [(name, HYBRID_ARCHS[name], False) for name in hybrids]
    if "hybrid_gemma3" in hybrids:
        # the same 5:1 local:global stack with the Pallas ring pass on
        # its local layers: bounded window gathers drop to 0 outright
        hybrid_rows.append(
            ("hybrid_gemma3_ringfused", HYBRID_ARCHS["hybrid_gemma3"],
             True))
    for name, arch, ring_fused in hybrid_rows:
        cfg = _cfg_for("socket", smoke, arch=arch)
        if ring_fused:
            cfg = cfg.replace(use_ring_kernel=True)
        ceiling = serving_ceiling(cfg)
        top = ceiling - max_new
        if top < 1:
            raise ValueError(
                f"max_new={max_new} leaves no prompt room under the "
                f"{name} serving context ceiling ({ceiling})")
        lens = sorted({max(1, top // 2), top})
        n = min(4, num_requests)
        reqs, m, _ = run_continuous(cfg, n, rate_rps=50.0,
                                    prompt_lens=lens,
                                    max_new_tokens=max_new, seed=0,
                                    warmup=True)
        assert all(r.state == "finished" for r in reqs)
        row = _serve_row(m, n, cfg)
        if ring_fused:
            assert row["window_kb_per_step"] == 0, (
                f"{name}: ring-fused local layers gathered "
                f"{row['window_kb_per_step']} KiB/step of window view, "
                "expected 0")
        rows.append((f"serve_continuous_{name}", row))

    # head-of-line rate sweep: one maximal prompt lands while short
    # requests stream tokens; the legacy engine stalls every decode for
    # the whole prompt's prefill, the mixed step for one chunk.  Same
    # workload both rows (the long prompt is capped at the legacy
    # bucket ceiling so the unchunked engine can serve it at all —
    # beyond-bucket prompts are chunked-only, pinned in tests).
    base = _cfg_for("socket", smoke)
    legacy_ceiling = min(max(base.serving.prefill_buckets),
                         base.serving.max_context)
    long_len = legacy_ceiling - max_new
    lens = [8, long_len, 8, 8, 8, 8]
    arrivals = [0.0, 0.02, 0.04, 0.06, 0.08, 0.10]
    for tag, chunk in (("chunked", base.serving.prefill_chunk or
                        base.serving.block_size * 2),
                       ("unchunked", 0)):
        cfg = base.replace(serving=base.serving.replace(
            prefill_chunk=chunk))
        reqs, m, _ = run_continuous(cfg, len(lens), rate_rps=50.0,
                                    prompt_lens=lens,
                                    max_new_tokens=max_new,
                                    seed=0, warmup=True,
                                    arrivals=arrivals)
        assert all(r.state == "finished" for r in reqs)
        rows.append((f"serve_longprompt_{tag}",
                     _serve_row(m, len(lens), cfg)))

    # prefix-cache rows: one shared-prefix workload (identical prompts
    # AND arrival schedule) served cache-on then cache-off — the
    # TTFT/throughput delta is pure prefix reuse, and greedy decoding
    # must produce identical tokens both ways (sharing + CoW are
    # token-exact or they are wrong).  Two generators: multi-turn chat
    # sessions (each turn's prompt extends the session history) and
    # RAG-style shared template + unique suffix.
    from repro.serving.prefix_cache.workloads import (chatbot_prompts,
                                                      rag_prompts)
    base = _cfg_for("socket", smoke)
    ceiling = serving_ceiling(base)
    top = ceiling - max_new
    prefix_workloads = (
        ("serve_prefix_chatbot",
         chatbot_prompts(num_requests, sessions=2, max_prompt_len=top,
                         vocab_size=base.vocab_size, seed=0)),
        ("serve_prefix_rag",
         rag_prompts(num_requests, prompt_len=top, overlap=0.6,
                     vocab_size=base.vocab_size, seed=0)),
    )
    arrivals = [0.01 * i for i in range(num_requests)]
    for name, prompts in prefix_workloads:
        row: dict = {"requests": num_requests}
        generations = {}
        for on in (True, False):
            cfg = base.replace(serving=base.serving.replace(
                prefix_cache=on))
            reqs, m, eng = run_continuous(
                cfg, num_requests, rate_rps=50.0, prompt_lens=None,
                max_new_tokens=max_new, seed=0, warmup=True,
                arrivals=arrivals, prompts=prompts)
            assert all(r.state == "finished" for r in reqs)
            generations[on] = [r.generated for r in reqs]
            tag = "cached" if on else "cold"
            row[f"ttft_ms_mean_{tag}"] = float(m.ttft_s_mean * 1e3)
            row[f"tput_tok_s_{tag}"] = float(m.throughput_tok_s)
            row[f"preemptions_{tag}"] = m.preemptions
            if on:
                reg = eng.registry
                hits = reg.value("prefix_cache_hits_total")
                misses = reg.value("prefix_cache_misses_total")
                ptoks = reg.value("prefix_cache_prompt_tokens_total")
                ctoks = reg.value("prefix_cache_cached_tokens_total")
                row.update({
                    "hit_rate": hits / (hits + misses)
                    if hits + misses else 0.0,
                    "cached_tokens": int(ctoks),
                    "prompt_tokens": int(ptoks),
                    "cached_token_frac": ctoks / ptoks if ptoks else 0.0,
                    "cow_copies": int(reg.value(
                        "prefix_cache_cow_total")),
                    "evicted_blocks": int(reg.value(
                        "prefix_cache_evicted_total")),
                })
        assert generations[True] == generations[False], (
            f"{name}: prefix cache changed greedy generations")
        rows.append((name, row))

    # quantized K/V pool rows: the fused socket path serving bf16 vs
    # int8 vs fp8 pages on one identical workload (explicit arrivals,
    # virtual time — batch composition must match across storage modes
    # for the probe comparison to mean anything).  gemma-7b geometry:
    # its head_dim is large enough relative to the bits/vnorm metadata
    # that int8 pages clear the 1.8x residency bar at production shapes
    # (stablelm's W=20 packed-bits overhead dilutes it to ~1.76x).
    from repro.launch.serve import apply_kv_dtype
    from repro.serving.obs import Observability
    from repro.serving.paged import pool_block_bytes

    quant_arch = "gemma-7b"
    base = _cfg_for("socket_fused", smoke, arch=quant_arch)
    ceiling = serving_ceiling(base)
    top = ceiling - max_new
    if top < 1:
        raise ValueError(
            f"max_new={max_new} leaves no prompt room under the "
            f"{quant_arch} serving context ceiling ({ceiling})")
    lens = sorted({max(1, top // 2), top})
    arrivals = [0.01 * i for i in range(num_requests)]
    # fixed pool byte budget for the residency math: the production
    # (non-smoke) config's pool at bf16 pages.  Capacity is analytic —
    # requests at the full context ceiling, whole blocks — so the bench
    # can serve the smoke model while reporting the capacity story at
    # the geometry that motivates quantized pages.
    full_bf16 = apply_kv_dtype(
        _cfg_for("socket_fused", False, arch=quant_arch), "bf16")
    fsv = full_bf16.serving
    # per-request footprint: the workload's mean context (prompt lens
    # cycle {top/2, top} + generated tokens) at the full geometry, in
    # whole blocks — "how many requests of this workload's average
    # shape are resident at once" is the capacity number a scheduler
    # admits against
    ftop = serving_ceiling(full_bf16) - max_new
    mean_ctx = (max(1, ftop // 2) + ftop) // 2 + max_new
    blocks_per_req = -(-mean_ctx // fsv.block_size)
    pool_budget = fsv.num_blocks * pool_block_bytes(full_bf16)[
        "per_block_id"]
    qrows: dict = {}
    qgens: dict = {}
    for kvd in ("bf16", "int8", "fp8"):
        cfg = apply_kv_dtype(base, kvd)
        obs = Observability(probe_every=4)
        reqs, m, _ = run_continuous(cfg, num_requests, rate_rps=50.0,
                                    prompt_lens=lens,
                                    max_new_tokens=max_new, seed=0,
                                    warmup=True, realtime=False,
                                    arrivals=arrivals, obs=obs)
        assert all(r.state == "finished" for r in reqs)
        qgens[kvd] = [r.generated for r in reqs]
        row = _serve_row(m, num_requests, cfg)
        assert row["fused_paged_kernel"], (
            f"socket_fused_{kvd}: fused_paged() did not claim the "
            "kernel path")
        assert row["gathered_kb_per_step"] == 0, (
            f"socket_fused_{kvd}: fused paged path gathered "
            f"{row['gathered_kb_per_step']} KiB/step, expected 0")
        row["kv_dtype"] = kvd
        row["probe"] = obs.probe_summary()
        sv2 = cfg.serving
        row["pool_bytes_per_token"] = (
            pool_block_bytes(cfg)["per_block_id"] / sv2.block_size)
        full = apply_kv_dtype(
            _cfg_for("socket_fused", False, arch=quant_arch), kvd)
        pbb = pool_block_bytes(full)["per_block_id"]
        row["pool_bytes_per_token_full"] = pbb / fsv.block_size
        row["max_resident_requests_fixed_pool"] = int(
            pool_budget // (blocks_per_req * pbb))
        qrows[kvd] = row
        rows.append((f"serve_continuous_socket_fused_{kvd}", row))
    res_bf16 = qrows["bf16"]["max_resident_requests_fixed_pool"]
    res_int8 = qrows["int8"]["max_resident_requests_fixed_pool"]
    assert res_int8 >= 1.8 * res_bf16, (
        f"int8 pages fit {res_int8} resident requests in the bf16 "
        f"pool's byte budget vs {res_bf16} at bf16 — below the 1.8x "
        "capacity bar quantized pages exist to clear")
    # socket selection never reads the quantized K/V (bits + vnorms
    # stay full precision) — so the probe's selection-side stats and
    # the greedy generations must be bit-identical to the bf16 run.
    # Recall itself is measured against each run's own dense reference,
    # which *does* read the (de)quantized cache, so it may move in the
    # low decimals even with a provably identical selection — reported
    # as a delta and bounded tightly for int8.
    assert qgens["int8"] == qgens["bf16"], (
        "int8 pages changed greedy socket_fused generations")
    for kvd in ("int8", "fp8"):
        p, p0 = qrows[kvd]["probe"], qrows["bf16"]["probe"]
        for stat in ("budget_utilization", "forced_share",
                     "selected_mean", "budget_mean"):
            assert p[stat] == p0[stat], (
                f"{kvd} pages changed probe {stat} ({p[stat]} vs bf16 "
                f"{p0[stat]}) — selection must not read quantized K/V")
        qrows[kvd]["probe_recall_delta_vs_bf16"] = (
            p["recall"] - p0["recall"])
    assert abs(qrows["int8"]["probe_recall_delta_vs_bf16"]) <= 2e-3, (
        "int8 pages moved socket probe recall by "
        f"{qrows['int8']['probe_recall_delta_vs_bf16']} vs bf16 — the "
        "dense reference drift should be in the noise")

    # int8 across the remaining backends: dense (unfused contiguous +
    # O(top_k)=full gathers dequantize on read), quest (page stats from
    # the quantized round-trip) and hard_lsh — every write/read path
    # serves end to end under quantized pages.
    for backend in ("dense", "quest_fused", "hard_lsh_fused"):
        cfg = apply_kv_dtype(_cfg_for(backend, smoke), "int8")
        n = min(4, num_requests)
        btop = serving_ceiling(cfg) - max_new
        reqs, m, _ = run_continuous(cfg, n, rate_rps=50.0,
                                    prompt_lens=[max(1, btop // 2)],
                                    max_new_tokens=max_new, seed=0,
                                    warmup=True, realtime=False,
                                    arrivals=arrivals[:n])
        assert all(r.state == "finished" for r in reqs)
        row = _serve_row(m, n, cfg)
        row["kv_dtype"] = "int8"
        if backend.endswith("_fused"):
            assert row["fused_paged_kernel"] and \
                row["gathered_kb_per_step"] == 0, (
                    f"{backend}+int8: expected the zero-gather fused "
                    "path")
        rows.append((f"serve_continuous_{backend}_int8", row))
    return rows


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--full", dest="smoke", action="store_false")
    ap.add_argument("--num-requests", type=int, default=8)
    ap.add_argument("--max-new-tokens", type=int, default=8)
    ap.add_argument("--json", type=str, default=None,
                    help="also write results to this JSON file (CI artifact)")
    args = ap.parse_args()
    rows = run(smoke=args.smoke, num_requests=args.num_requests,
               max_new=args.max_new_tokens)
    for name, metrics in rows:
        print(name, metrics)
    if args.json:
        # strict JSON: empty-series metrics are NaN (e.g. a static row's
        # throughput with decode_s == 0), and json.dump would write the
        # non-strict `NaN` token — serialize non-finite floats as null
        # instead (CI validates the artifact with
        # `python -m repro.serving.obs.validate --json`).
        from repro.serving.obs.events import strict_dumps
        with open(args.json, "w") as f:
            f.write(strict_dumps({name: metrics for name, metrics in rows},
                                 indent=2, sort_keys=True))


if __name__ == "__main__":
    main()
