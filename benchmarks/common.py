"""Shared benchmark utilities: timing, workload generation, metrics."""

from __future__ import annotations

import time
from typing import Callable, Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import hashing, socket


def time_fn(fn: Callable, *args, iters: int = 20, warmup: int = 3) -> float:
    """Median wall-time (µs) of a jitted callable."""
    for _ in range(warmup):
        out = fn(*args)
    jax.tree_util.tree_map(
        lambda x: x.block_until_ready() if hasattr(x, "block_until_ready")
        else x, out)
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        out = fn(*args)
        jax.tree_util.tree_map(
            lambda x: x.block_until_ready()
            if hasattr(x, "block_until_ready") else x, out)
        times.append(time.perf_counter() - t0)
    return float(np.median(times) * 1e6)


def heavy_hitter_workload(rng, n: int, d: int, n_queries: int = 32,
                          concentration: float = 2.0):
    """Q/K/V with realistic concentrated attention: each query is a noisy
    scaled copy of some key (the long-context retrieval regime the paper
    targets).  Returns (queries (Q,d), keys (N,d), values (N,d), targets)."""
    kk, kv, kq, kt = jax.random.split(rng, 4)
    keys = jax.random.normal(kk, (n, d))
    values = jax.random.normal(kv, (n, d))
    targets = jax.random.randint(kt, (n_queries,), 0, n)
    noise = jax.random.normal(kq, (n_queries, d))
    queries = concentration * keys[targets] + 0.5 * noise
    return queries, keys, values, targets


def ranking_metrics(pred_scores: np.ndarray, true_scores: np.ndarray,
                    k: int) -> Dict[str, float]:
    """Precision@k, Jaccard@k, NDCG@k (paper Appendix A.5)."""
    pred_top = set(np.argsort(-pred_scores)[:k].tolist())
    true_top = set(np.argsort(-true_scores)[:k].tolist())
    precision = len(pred_top & true_top) / k
    jaccard = len(pred_top & true_top) / len(pred_top | true_top)

    # NDCG with graded relevance = rank position in the true top-k
    order = np.argsort(-true_scores)
    rel = np.zeros(len(true_scores))
    for rank, idx in enumerate(order[:k]):
        rel[idx] = k - rank                      # higher = more relevant
    pred_order = np.argsort(-pred_scores)[:k]
    dcg = sum((2.0 ** rel[i] - 1) / np.log2(r + 2)
              for r, i in enumerate(pred_order))
    idcg = sum((2.0 ** rel[i] - 1) / np.log2(r + 2)
               for r, i in enumerate(order[:k]))
    return {"precision": precision, "jaccard": jaccard,
            "ndcg": dcg / max(idcg, 1e-9)}


def socket_scores_for(rng, cfg: socket.SocketConfig, keys, queries):
    """(Q, N) SOCKET scores for a batch of queries."""
    d = keys.shape[-1]
    w = hashing.make_hash_params(rng, d, cfg.num_planes, cfg.num_tables)
    packed = hashing.pack_signs(hashing.hash_keys_signs(w, keys))
    u = socket.soft_hash_query(w, queries)             # (Q, L, P)
    scores = jax.vmap(
        lambda uq: socket.soft_scores_factorized(cfg, packed, uq))(u)
    return scores, w, packed


def attention_output_error(q, keys, values, sel_idx, scale) -> float:
    """Relative L2 error of sparse attention vs dense for one query."""
    logits = keys @ q * scale
    w_full = jax.nn.softmax(logits)
    y_full = w_full @ values
    sub = logits[sel_idx]
    w_sub = jax.nn.softmax(sub)
    y_sub = w_sub @ values[sel_idx]
    return float(jnp.linalg.norm(y_sub - y_full) /
                 jnp.maximum(jnp.linalg.norm(y_full), 1e-9))
