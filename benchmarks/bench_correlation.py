"""Paper Table 3: correlation with the true similarity q.k and estimator
variance, SOCKET vs hard LSH across (P, L) settings at matched budgets.

Variance is measured as Var over hash draws of the *normalized* score of a
fixed key (the paper's estimator-variance column): SOCKET's graded
evidence concentrates orders of magnitude faster than binary collisions.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import heavy_hitter_workload
from repro.core import hashing, socket


def _socket_corr_var(rng, keys, q, p, l, tau, trials=16):
    cfg = socket.SocketConfig(num_planes=p, num_tables=l, tau=tau)
    true = np.asarray(keys @ q)
    corrs, probe = [], []
    for t in range(trials):
        w = hashing.make_hash_params(jax.random.fold_in(rng, t),
                                     keys.shape[-1], p, l)
        packed = hashing.pack_signs(hashing.hash_keys_signs(w, keys))
        s = np.asarray(socket.soft_scores_factorized(
            cfg, packed, socket.soft_hash_query(w, q)))
        corrs.append(np.corrcoef(true, s)[0, 1])
        probe.append(s[0] / max(s.sum(), 1e-12))    # normalized score
    return float(np.mean(corrs)), float(np.var(probe))


def _hard_corr_var(rng, keys, q, p, l, trials=16):
    true = np.asarray(keys @ q)
    corrs, probe = [], []
    for t in range(trials):
        w = hashing.make_hash_params(jax.random.fold_in(rng, 100 + t),
                                     keys.shape[-1], p, l)
        signs = hashing.hash_keys_signs(w, keys)
        q_signs = hashing.hash_keys_signs(w, q[None])[0]
        counts = np.asarray(jnp.sum(
            jnp.all(signs == q_signs[None], axis=-1), axis=-1),
            dtype=np.float64)
        corrs.append(np.corrcoef(true, counts)[0, 1])
        probe.append(counts[0] / max(counts.sum(), 1e-12))
    return float(np.nanmean(corrs)), float(np.var(probe))


def run(n: int = 2048, d: int = 128):
    rng = jax.random.PRNGKey(7)
    queries, keys, _, _ = heavy_hitter_workload(rng, n, d, 1,
                                                concentration=1.0)
    q = queries[0]
    rows = []
    for (p, l) in ((10, 20), (10, 40), (10, 60)):
        c, v = _socket_corr_var(rng, keys, q, p, l, tau=0.5)
        rows.append((f"tab3_socket_p{p}_l{l}", {"corr": c, "var": v}))
    for (p, l) in ((2, 250), (2, 300), (2, 350)):
        c, v = _hard_corr_var(rng, keys, q, p, l)
        rows.append((f"tab3_hardlsh_p{p}_l{l}", {"corr": c, "var": v}))
    return rows


def main():
    for name, m in run():
        print(f"{name},corr={m['corr']:.3f},var={m['var']:.3e}")


if __name__ == "__main__":
    main()
