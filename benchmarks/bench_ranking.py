"""Paper fig. 2: precision / Jaccard / NDCG vs top-k at a matched
600-bit/token budget — SOCKET (P=10, L=60) vs hard LSH (P=2, L=300) and
(P=10, L=60).  Ground truth = dot-product ranking."""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import heavy_hitter_workload, ranking_metrics
from repro.baselines import hard_lsh
from repro.core import hashing, socket


def run(n: int = 4096, d: int = 128, n_queries: int = 16):
    rng = jax.random.PRNGKey(0)
    queries, keys, values, _ = heavy_hitter_workload(rng, n, d, n_queries)

    scorers = {}
    cfg = socket.SocketConfig(num_planes=10, num_tables=60, tau=0.4)
    w = hashing.make_hash_params(jax.random.fold_in(rng, 1), d, 10, 60)
    packed = hashing.pack_signs(hashing.hash_keys_signs(w, keys))
    scorers["socket_p10_l60"] = lambda q: socket.soft_scores_factorized(
        cfg, packed, socket.soft_hash_query(w, q))

    h1 = hard_lsh.HardLSHConfig(num_planes=2, num_tables=300)
    st1 = hard_lsh.build(h1, jax.random.fold_in(rng, 2), keys, values)
    scorers["hardlsh_p2_l300"] = lambda q: hard_lsh.score(st1, h1, q)

    h2 = hard_lsh.HardLSHConfig(num_planes=10, num_tables=60)
    st2 = hard_lsh.build(h2, jax.random.fold_in(rng, 3), keys, values)
    scorers["hardlsh_p10_l60"] = lambda q: hard_lsh.score(st2, h2, q)

    rows = []
    for k in (32, 64, 128, 256):
        for name, fn in scorers.items():
            ms = []
            for qi in range(n_queries):
                q = queries[qi]
                pred = np.asarray(fn(q))
                true = np.asarray(keys @ q)
                ms.append(ranking_metrics(pred, true, k))
            agg = {key: float(np.mean([m[key] for m in ms]))
                   for key in ms[0]}
            rows.append((f"fig2_{name}_k{k}", agg))
    return rows


def main():
    for name, agg in run():
        print(f"{name},precision={agg['precision']:.3f},"
              f"jaccard={agg['jaccard']:.3f},ndcg={agg['ndcg']:.3f}")


if __name__ == "__main__":
    main()
