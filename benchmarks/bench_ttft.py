"""Paper fig. 3a: time-to-first-token index-build comparison — SOCKET's
data-agnostic random projections vs PQCache's k-means clustering.  The gap
is structural: SOCKET's build is one GEMM + sign + pack; PQ iterates
Lloyd steps over all keys."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import time_fn
from repro.baselines import pqcache
from repro.core import hashing, socket


def run(d: int = 128):
    rng = jax.random.PRNGKey(0)
    rows = []
    for n in (8192, 32768, 131072):
        keys = jax.random.normal(jax.random.fold_in(rng, n), (n, d))

        w = hashing.make_hash_params(rng, d, 10, 60)

        def socket_build(k):
            return hashing.pack_signs(hashing.hash_keys_signs(w, k))

        t_socket = time_fn(jax.jit(socket_build), keys, iters=5, warmup=2)

        pcfg = pqcache.PQConfig(num_subspaces=16, nbits=6, kmeans_iters=8)
        def pq_build(k):
            st = pqcache.build(pcfg, rng, k, k)
            return st.codes
        t_pq = time_fn(pq_build, keys, iters=3, warmup=1)

        rows.append((f"fig3a_n{n}", {
            "socket_build_us": t_socket, "pqcache_build_us": t_pq,
            "ttft_ratio": t_pq / t_socket}))
    return rows


def main():
    for name, m in run():
        print(f"{name},socket_us={m['socket_build_us']:.0f},"
              f"pq_us={m['pqcache_build_us']:.0f},"
              f"ratio={m['ttft_ratio']:.1f}x")


if __name__ == "__main__":
    main()
