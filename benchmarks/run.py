"""Benchmark harness — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows (us_per_call where a timing
is the headline, NaN otherwise; `derived` carries the table's metric).

    PYTHONPATH=src python -m benchmarks.run            # all
    PYTHONPATH=src python -m benchmarks.run --only fig3,tab2
"""

from __future__ import annotations

import argparse
import math
import sys
import time

SUITES = [
    ("fig2_ranking", "benchmarks.bench_ranking"),
    ("tab3_correlation", "benchmarks.bench_correlation"),
    ("tab2_overhead", "benchmarks.bench_overhead"),
    ("tab1_accuracy", "benchmarks.bench_accuracy"),
    ("fig3_throughput", "benchmarks.bench_throughput"),
    ("tab6_ablations", "benchmarks.bench_ablations"),
    ("fig3a_ttft", "benchmarks.bench_ttft"),
    ("kernels", "benchmarks.bench_kernels"),
    ("serving", "benchmarks.bench_serving"),
]


def _fmt(name: str, metrics: dict) -> str:
    us = metrics.get("us", metrics.get("cpu_us",
                                       metrics.get("cpu_socket_us",
                                                   float("nan"))))
    derived = ";".join(f"{k}={v:.4g}" if isinstance(v, float) else
                       f"{k}={v}" for k, v in metrics.items())
    us_s = "nan" if (isinstance(us, float) and math.isnan(us)) else \
        f"{us:.1f}"
    return f"{name},{us_s},{derived}"


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma-separated suite-name substrings")
    args = ap.parse_args()
    wanted = args.only.split(",") if args.only else None

    print("name,us_per_call,derived")
    failures = []
    for suite, module_name in SUITES:
        if wanted and not any(w in suite for w in wanted):
            continue
        t0 = time.time()
        try:
            module = __import__(module_name, fromlist=["run"])
            rows = module.run()
            for name, metrics in rows:
                print(_fmt(name, metrics), flush=True)
            print(f"# {suite}: {len(rows)} rows in "
                  f"{time.time()-t0:.1f}s", flush=True)
        except Exception as e:  # noqa: BLE001 — keep the harness running
            failures.append(suite)
            print(f"# {suite}: FAILED {type(e).__name__}: {e}", flush=True)
    if failures:
        print(f"# FAILURES: {failures}")
        sys.exit(1)


if __name__ == "__main__":
    main()
