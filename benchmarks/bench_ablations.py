"""Paper Table 6 ablations: top-k recall as a function of P, L and tau
(the synthetic analogue of the RULER-32K-Hard sweeps)."""

from __future__ import annotations

import jax
import numpy as np

from benchmarks.common import heavy_hitter_workload
from repro.core import hashing, socket


def _recall(rng, keys, queries, true, p, l, tau, k):
    cfg = socket.SocketConfig(num_planes=p, num_tables=l, tau=tau)
    w = hashing.make_hash_params(rng, keys.shape[-1], p, l)
    packed = hashing.pack_signs(hashing.hash_keys_signs(w, keys))
    rec = []
    for qi in range(queries.shape[0]):
        s = np.asarray(socket.soft_scores_factorized(
            cfg, packed, socket.soft_hash_query(w, queries[qi])))
        got = set(np.argsort(-s)[:k].tolist())
        want = set(np.argsort(-true[qi])[:k].tolist())
        rec.append(len(got & want) / k)
    return float(np.mean(rec))


def run(n: int = 4096, d: int = 128, n_queries: int = 12):
    rng = jax.random.PRNGKey(11)
    queries, keys, _, _ = heavy_hitter_workload(rng, n, d, n_queries)
    true = np.asarray(queries @ keys.T)
    k = n // 20                                       # 20x sparsity
    rows = []
    # (a) vary P at tau=0.4, L=60
    for p in (4, 6, 8, 10):
        r = _recall(jax.random.fold_in(rng, p), keys, queries, true,
                    p, 60, 0.4, k)
        rows.append((f"tab6a_P{p}", {"recall": r}))
    # (b) vary L at tau=0.5, P=10
    for l in (10, 20, 40, 60, 70):
        r = _recall(jax.random.fold_in(rng, 100 + l), keys, queries, true,
                    10, l, 0.5, k)
        rows.append((f"tab6b_L{l}", {"recall": r}))
    # (c) vary tau at P=10, L=60
    for tau in (0.1, 0.3, 0.5, 0.7, 1.0):
        r = _recall(jax.random.fold_in(rng, 999), keys, queries, true,
                    10, 60, tau, k)
        rows.append((f"tab6c_tau{tau}", {"recall": r}))
    return rows


def main():
    for name, m in run():
        print(f"{name},recall={m['recall']:.3f}")


if __name__ == "__main__":
    main()
