"""Data pipeline determinism/resume + checkpointer atomicity/roundtrip."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import Checkpointer
from repro.data import (DataConfig, HostDataLoader, MemmapLMSource,
                        SyntheticLMSource)


def test_synthetic_batches_deterministic():
    cfg = DataConfig(seq_len=64, global_batch=4, vocab_size=1000, seed=7)
    src = SyntheticLMSource(cfg)
    a = src.batch(0, 3, range(4))
    b = src.batch(0, 3, range(4))
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    # labels are next-token shifted
    np.testing.assert_array_equal(a["tokens"][:, 1:], a["labels"][:, :-1])
    # different steps differ
    c = src.batch(0, 4, range(4))
    assert not np.array_equal(a["tokens"], c["tokens"])


def test_copy_spans_planted():
    cfg = DataConfig(seq_len=512, global_batch=1, vocab_size=1000, seed=1,
                     copy_prob=1.0, copy_span=16)
    src = SyntheticLMSource(cfg)
    row = src.row(0, 0, 0)
    span = row[8:24]
    matches = sum(
        np.array_equal(row[i:i + 16], span)
        for i in range(256, 512 - 16))
    assert matches >= 1, "retrieval span not planted"


def test_loader_resume_exact():
    cfg = DataConfig(seq_len=32, global_batch=2, vocab_size=500, seed=3)
    loader = HostDataLoader(cfg)
    batches = [next(loader) for _ in range(5)]
    state = loader.state_dict()
    next_batches = [next(loader) for _ in range(3)]
    loader.close()

    loader2 = HostDataLoader(cfg)
    loader2.load_state_dict(state)
    resumed = [next(loader2) for _ in range(3)]
    loader2.close()
    for a, b in zip(next_batches, resumed):
        np.testing.assert_array_equal(a["tokens"], b["tokens"])


def test_host_sharding_partitions_batch():
    cfg = DataConfig(seq_len=16, global_batch=4, vocab_size=100, seed=5)
    l0 = HostDataLoader(cfg, process_index=0, num_processes=2)
    l1 = HostDataLoader(cfg, process_index=1, num_processes=2)
    b0, b1 = next(l0), next(l1)
    l0.close(); l1.close()
    assert b0["tokens"].shape == (2, 16)
    assert not np.array_equal(b0["tokens"], b1["tokens"])
    full = SyntheticLMSource(cfg).batch(0, 0, range(4))
    np.testing.assert_array_equal(
        np.concatenate([b0["tokens"], b1["tokens"]]), full["tokens"])


def test_memmap_source(tmp_path):
    path = str(tmp_path / "tokens.bin")
    data = np.arange(1000, dtype=np.uint32)
    data.tofile(path)
    cfg = DataConfig(seq_len=64, global_batch=2, vocab_size=2000, seed=0)
    src = MemmapLMSource(cfg, path)
    b = src.batch(0, 0, range(2))
    assert b["tokens"].shape == (2, 64)
    np.testing.assert_array_equal(b["tokens"][:, 1:], b["labels"][:, :-1])
    # epoch permutation changes order deterministically
    b2 = src.batch(1, 0, range(2))
    assert not np.array_equal(b["tokens"], b2["tokens"])
    np.testing.assert_array_equal(src.batch(1, 0, range(2))["tokens"],
                                  b2["tokens"])


# ----------------------------------------------------------- checkpointer

def _tree(seed=0):
    k = jax.random.PRNGKey(seed)
    return {"a": jax.random.normal(k, (8, 8)),
            "nested": {"b": jnp.arange(5), "step": jnp.int32(7)}}


def test_checkpoint_roundtrip(tmp_path):
    ck = Checkpointer(str(tmp_path))
    tree = _tree()
    ck.save(10, tree, extra={"loader": {"epoch": 0, "step": 10, "seed": 0}},
            blocking=True)
    rec = ck.restore()
    assert rec["step"] == 10
    assert rec["extra"]["loader"]["step"] == 10
    jax.tree_util.tree_map(
        lambda a, b: np.testing.assert_array_equal(np.asarray(a),
                                                   np.asarray(b)),
        tree, rec["tree"])


def test_checkpoint_async_and_retention(tmp_path):
    ck = Checkpointer(str(tmp_path), keep=2)
    for s in (1, 2, 3, 4):
        ck.save(s, _tree(s))
    ck.wait()
    assert ck.latest_step() == 4
    assert ck.all_steps() == [3, 4]


def test_checkpoint_atomicity_no_partial_dirs(tmp_path):
    ck = Checkpointer(str(tmp_path))
    ck.save(5, _tree(), blocking=True)
    names = os.listdir(tmp_path)
    assert not any(n.endswith(".tmp") for n in names)
    assert "LATEST" in names


def test_checkpoint_restore_specific_step(tmp_path):
    ck = Checkpointer(str(tmp_path), keep=5)
    for s in (1, 2, 3):
        ck.save(s, {"v": jnp.float32(s)}, blocking=True)
    assert float(ck.restore(step=2)["tree"]["v"]) == 2.0
    assert float(ck.restore()["tree"]["v"]) == 3.0


def test_checkpoint_missing_raises(tmp_path):
    ck = Checkpointer(str(tmp_path))
    with pytest.raises(FileNotFoundError):
        ck.restore()
