"""Component-level model tests: MoE dispatch vs dense reference, Mamba
causality/decode equivalence, attention variants, RoPE."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.configs.base import LayerSpec, ModelConfig
from repro.models import attention as attn
from repro.models import mamba as mb
from repro.models import moe as moe_mod
from repro.models import param as pm
from repro.models.layers import apply_rope


# ------------------------------------------------------------------- MoE

def _moe_cfg(**kw):
    base = dict(name="t", family="moe", d_model=32, d_ff=64, num_experts=4,
                num_experts_per_tok=2, capacity_factor=4.0,
                mlp_activation="swiglu")
    base.update(kw)
    return ModelConfig(**base)


def _moe_reference(cfg, params, x):
    """Dense loop-over-experts oracle (no capacity, exact top-k)."""
    b, t, d = x.shape
    x2 = x.reshape(-1, d)
    logits = x2 @ params["router"]
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_i = jax.lax.top_k(probs, cfg.num_experts_per_tok)
    top_p = top_p / top_p.sum(-1, keepdims=True)
    out = jnp.zeros_like(x2)
    for e in range(cfg.num_experts):
        gate = jax.nn.silu(x2 @ params["w_gate"][e])
        h = gate * (x2 @ params["w_up"][e])
        y_e = h @ params["w_down"][e]
        for slot in range(cfg.num_experts_per_tok):
            w = jnp.where(top_i[:, slot] == e, top_p[:, slot], 0.0)
            out = out + w[:, None] * y_e
    return out.reshape(b, t, d)


def test_moe_matches_dense_reference(rng):
    cfg = _moe_cfg()
    params = pm.unbox(moe_mod.init_moe(cfg, rng))
    x = jax.random.normal(jax.random.fold_in(rng, 1), (2, 16, 32))
    y, aux = moe_mod.apply_moe(cfg, params, x)
    ref = _moe_reference(cfg, params, x)
    np.testing.assert_allclose(np.asarray(y), np.asarray(ref), atol=2e-5)
    assert float(aux["moe_lb_loss"]) > 0


def test_moe_decode_dropless(rng):
    """T=1 must be exactly dropless regardless of capacity_factor."""
    cfg = _moe_cfg(capacity_factor=0.01)
    params = pm.unbox(moe_mod.init_moe(cfg, rng))
    x = jax.random.normal(jax.random.fold_in(rng, 2), (8, 1, 32))
    y, _ = moe_mod.apply_moe(cfg, params, x)
    ref = _moe_reference(cfg, params, x)
    np.testing.assert_allclose(np.asarray(y), np.asarray(ref), atol=2e-5)


def test_moe_capacity_drops_are_first_come_first_served(rng):
    """Stable-sort dispatch: earlier flat tokens keep their slots when
    later tokens are appended (the causality property)."""
    cfg = _moe_cfg(capacity_factor=0.6)
    params = pm.unbox(moe_mod.init_moe(cfg, rng))
    x = jax.random.normal(jax.random.fold_in(rng, 3), (1, 24, 32))
    y_full, _ = moe_mod.apply_moe(cfg, params, x)
    y_short, _ = moe_mod.apply_moe(cfg, params, x[:, :16])
    # capacity differs (N changed) — compare against same-capacity slice:
    # instead check prefix invariance with appended tokens at SAME capacity
    cfg2 = _moe_cfg(capacity_factor=cfg.capacity_factor * 24 / 16)
    y_short2, _ = moe_mod.apply_moe(cfg2, params, x[:, :16])
    np.testing.assert_allclose(np.asarray(y_full[:, :16]),
                               np.asarray(y_short2), atol=2e-5)


# ------------------------------------------------------------------ Mamba

def _mamba_cfg():
    return get_config("mamba2-780m").smoke()


def test_mamba_is_causal(rng):
    cfg = _mamba_cfg()
    params = pm.unbox(mb.init_mamba(cfg, rng))
    x = jax.random.normal(jax.random.fold_in(rng, 1), (2, 40, cfg.d_model))
    y_full = mb.mamba_train(cfg, params, x)
    y_pre = mb.mamba_train(cfg, params, x[:, :24])
    np.testing.assert_allclose(np.asarray(y_full[:, :24]),
                               np.asarray(y_pre), atol=1e-4)


def test_mamba_decode_matches_train(rng):
    cfg = _mamba_cfg()
    params = pm.unbox(mb.init_mamba(cfg, rng))
    x = jax.random.normal(jax.random.fold_in(rng, 2), (2, 33, cfg.d_model))
    y_ref = mb.mamba_train(cfg, params, x)
    y_pre, state = mb.mamba_train(cfg, params, x[:, :32],
                                  return_state=True)
    y_step, _ = mb.mamba_decode(cfg, params, x[:, 32:33], state)
    np.testing.assert_allclose(np.asarray(y_step[:, 0]),
                               np.asarray(y_ref[:, 32]), atol=1e-4)


def test_mamba_chunk_size_invariance(rng):
    cfg = _mamba_cfg()
    params = pm.unbox(mb.init_mamba(cfg, rng))
    x = jax.random.normal(jax.random.fold_in(rng, 3), (1, 64, cfg.d_model))
    y16 = mb.mamba_train(cfg.replace(ssm_chunk=16), params, x)
    y32 = mb.mamba_train(cfg.replace(ssm_chunk=32), params, x)
    np.testing.assert_allclose(np.asarray(y16), np.asarray(y32), atol=1e-4)


# -------------------------------------------------------------- attention

def test_rope_preserves_norm_and_relativity(rng):
    x = jax.random.normal(rng, (1, 8, 2, 16))
    pos = jnp.broadcast_to(jnp.arange(8), (1, 8))
    y = apply_rope(x, pos, 10000.0)
    np.testing.assert_allclose(np.asarray(jnp.linalg.norm(y, axis=-1)),
                               np.asarray(jnp.linalg.norm(x, axis=-1)),
                               rtol=1e-5)
    # relative property: <rope(q,m), rope(k,n)> depends only on m-n
    q = jax.random.normal(jax.random.fold_in(rng, 1), (1, 1, 1, 16))
    k = jax.random.normal(jax.random.fold_in(rng, 2), (1, 1, 1, 16))
    def dot_at(m, n):
        qm = apply_rope(q, jnp.full((1, 1), m), 1e4)
        kn = apply_rope(k, jnp.full((1, 1), n), 1e4)
        return float(jnp.sum(qm * kn))
    assert abs(dot_at(3, 1) - dot_at(10, 8)) < 1e-4


def test_sliding_window_mask(rng):
    cfg = get_config("gemma3-27b").smoke().replace(sliding_window=8)
    params = pm.unbox(attn.init_attention(cfg, rng))
    b, t = 1, 32
    x = jax.random.normal(jax.random.fold_in(rng, 1), (b, t, cfg.d_model))
    pos = jnp.broadcast_to(jnp.arange(t), (b, t))
    y_local = attn.attention_train(cfg, params, x, pos, "local")
    # perturbing a token outside the window must not change the output
    x2 = x.at[:, 0].add(10.0)
    y2 = attn.attention_train(cfg, params, x2, pos, "local")
    np.testing.assert_allclose(np.asarray(y_local[:, 20:]),
                               np.asarray(y2[:, 20:]), atol=1e-4)
    # ...but it does under global attention
    y_g = attn.attention_train(cfg, params, x, pos, "global")
    y_g2 = attn.attention_train(cfg, params, x2, pos, "global")
    assert float(jnp.max(jnp.abs(y_g[:, 20:] - y_g2[:, 20:]))) > 1e-3


def test_local_ring_buffer_decode(rng):
    """Local-layer ring cache must equal masked-window dense attention."""
    cfg = get_config("gemma3-27b").smoke().replace(
        sliding_window=16, attention_backend="dense")
    params = pm.unbox(attn.init_attention(cfg, rng))
    b, t = 1, 40
    x = jax.random.normal(jax.random.fold_in(rng, 5), (b, t, cfg.d_model))
    pos = jnp.broadcast_to(jnp.arange(t), (b, t))
    y_ref = attn.attention_train(cfg, params, x, pos, "local")
    _, cache = attn.attention_prefill(cfg, params, x[:, :32], pos[:, :32],
                                      "local", capacity=64)
    y32, cache = attn.attention_decode(cfg, params, x[:, 32:33], cache,
                                       jnp.int32(32), "local")
    np.testing.assert_allclose(np.asarray(y32[:, 0]),
                               np.asarray(y_ref[:, 32]), atol=2e-4)


def test_head_padding_is_exact(rng):
    """logical_pad_heads zero-pads q heads: same function, padded shapes."""
    cfg = get_config("musicgen-medium").smoke()
    cfg_pad = cfg.replace(logical_pad_heads=True)
    p1 = pm.unbox(attn.init_attention(cfg, rng))
    p2 = pm.unbox(attn.init_attention(cfg_pad, rng))
    assert p2["wq"].shape[1] % 16 == 0
    # padded columns of wq and rows of wo are zero
    h_real = cfg.num_heads
    assert float(jnp.abs(p2["wq"][:, h_real:]).max()) == 0.0
    assert float(jnp.abs(p2["wo"][h_real:]).max()) == 0.0
