"""Roofline machinery: HLO collective parsing + per-device semantics."""

import numpy as np
import pytest

from conftest import run_subprocess_devices
from repro.roofline.analysis import (HW, RooflineTerms,
                                     parse_collective_bytes)

FAKE_HLO = """
HloModule test
  %ag = bf16[128,256]{1,0} all-gather(%x), replica_groups={}
  %ar.1 = f32[1024]{0} all-reduce(%y), to_apply=%add
  %rs = f32[64,64]{1,0} reduce-scatter(%z), dimensions={0}
  %a2a = (s8[32]{0}, s8[32]{0}) all-to-all(%p, %q)
  %cp-start = bf16[16,16]{1,0} collective-permute-start(%w)
  %cp-done = bf16[16,16]{1,0} collective-permute-done(%cp-start)
  %not_a_coll = f32[999]{0} add(%a, %b)
"""


def test_parse_collective_bytes_kinds():
    out = parse_collective_bytes(FAKE_HLO)
    assert out["all-gather"] == 128 * 256 * 2
    assert out["all-reduce"] == 1024 * 4
    assert out["reduce-scatter"] == 64 * 64 * 4
    assert out["all-to-all"] == 64          # two s8[32] tuple elements
    assert out["collective-permute"] == 16 * 16 * 2  # -done not counted
    assert out["total"] == sum(v for k, v in out.items() if k != "total")


def test_roofline_terms_math():
    rt = RooflineTerms(flops_per_device=197e12, hbm_bytes_per_device=819e9,
                       collective_bytes_per_device=50e9, chips=256)
    assert abs(rt.compute_s - 1.0) < 1e-9
    assert abs(rt.memory_s - 1.0) < 1e-9
    assert abs(rt.collective_s - 1.0) < 1e-9
    d = rt.as_dict()
    assert d["dominant"] in ("compute", "memory", "collective")


def test_dominant_selection():
    rt = RooflineTerms(1.0, 1e15, 0.0, chips=1)
    assert rt.dominant == "memory"
    rt = RooflineTerms(1e30, 1.0, 0.0, chips=1)
    assert rt.dominant == "compute"


def test_cost_analysis_is_per_device():
    """The §Roofline formulas assume cost_analysis reports the partitioned
    per-device module; verify against a known matmul."""
    run_subprocess_devices("""
import jax, jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P
mesh = jax.make_mesh((8,), ("d",))
n = 1024
x = jax.ShapeDtypeStruct((n, n), jnp.float32)
f = jax.jit(lambda a: a @ a,
            in_shardings=NamedSharding(mesh, P("d", None)),
            out_shardings=NamedSharding(mesh, P("d", None)))
c = f.lower(x).compile()
ca = c.cost_analysis()
ca = ca[0] if isinstance(ca, list) else ca
flops = float(ca["flops"])
global_flops = 2 * n**3
# per-device should be ~ global/8 (plus small epsilon for collectives)
assert flops < global_flops / 4, (flops, global_flops)
assert flops > global_flops / 16, (flops, global_flops)
print("OK", flops, global_flops / 8)
""")


def test_dryrun_records_exist_and_complete():
    """The committed dry-run records cover every (arch x shape x mesh)."""
    import glob
    import json
    import os
    base = os.path.join(os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))), "experiments", "dryrun")
    if not os.path.isdir(base):
        pytest.skip("dry-run not yet executed in this checkout")
    files = glob.glob(os.path.join(base, "*.json"))
    if len(files) < 80:
        pytest.skip(f"dry-run incomplete ({len(files)}/80 cells)")
    bad = []
    for f in files:
        rec = json.load(open(f))
        if rec.get("status") != "ok":
            bad.append(os.path.basename(f))
    assert not bad, f"failed dry-run cells: {bad}"


def test_input_specs_api():
    """input_specs(arch) returns allocation-free ShapeDtypeStructs for
    every model input of a cell (the dry-run lowering contract)."""
    import jax
    import jax.numpy as jnp
    from repro.launch.specs import input_specs

    s = input_specs("minitron-8b", "train_4k")
    assert set(s) == {"batch"}
    assert s["batch"]["tokens"].shape == (256, 4096)
    assert all(isinstance(x, jax.ShapeDtypeStruct)
               for x in jax.tree_util.tree_leaves(s))

    s2 = input_specs("stablelm-12b", "decode_32k")
    assert set(s2) == {"caches", "inp", "pos"}
    assert s2["inp"].shape == (128, 1)
    leaves = jax.tree_util.tree_leaves(s2["caches"])
    assert any(x.dtype == jnp.uint32 for x in leaves)   # SOCKET bit cache

    s3 = input_specs("mamba2-780m", "long_500k")
    assert s3["inp"].shape == (1, 1)
