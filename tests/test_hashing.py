"""Bit-packing / hashing invariants (property-based)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis_compat import given, settings, st

from repro.core import hashing


@settings(max_examples=25, deadline=None)
@given(l=st.integers(1, 70), p=st.integers(1, 16), n=st.integers(1, 40))
def test_pack_unpack_roundtrip(l, p, n):
    rng = np.random.default_rng(l * 1000 + p * 10 + n)
    signs = jnp.asarray(rng.random((n, l, p)) > 0.5)
    packed = hashing.pack_signs(signs)
    assert packed.dtype == jnp.uint32
    assert packed.shape == (n, hashing.num_words(l, p))
    back = hashing.unpack_signs(packed, l, p)
    assert jnp.all((back > 0) == signs)
    assert set(np.unique(np.asarray(back))) <= {-1.0, 1.0}


@settings(max_examples=20, deadline=None)
@given(p=st.integers(1, 12))
def test_num_words_alignment(p):
    # kernel layout invariant: W*32 is always a multiple of P
    for l in (1, 7, 37, 60):
        w = hashing.num_words(l, p)
        assert w * 32 >= l * p
        assert (w * 32) % p == 0


def test_bucket_ids_match_bits(rng):
    w = hashing.make_hash_params(rng, 16, 6, 4)
    keys = jax.random.normal(jax.random.fold_in(rng, 1), (32, 16))
    signs = hashing.hash_keys_signs(w, keys)
    ids = hashing.signs_to_bucket_ids(signs)
    assert ids.shape == (32, 4)
    assert int(ids.max()) < 64 and int(ids.min()) >= 0
    # bit i of the id is plane i's sign
    for plane in range(6):
        bit = (np.asarray(ids) >> plane) & 1
        assert np.array_equal(bit, np.asarray(signs[..., plane]).astype(int))


def test_hypercube_corners():
    c = hashing.hypercube_corners(4)
    assert c.shape == (16, 4)
    assert len(np.unique(c, axis=0)) == 16
    assert set(np.unique(c)) == {-1.0, 1.0}


def test_collision_prob_matches_angular_kernel(rng):
    """SimHash identity: P[collision on one plane] = 1 - theta/pi."""
    d = 24
    k1, k2 = jax.random.split(rng)
    a = jax.random.normal(k1, (d,))
    b = a + 0.5 * jax.random.normal(k2, (d,))
    cos = float(a @ b / (jnp.linalg.norm(a) * jnp.linalg.norm(b)))
    expected = 1.0 - np.arccos(cos) / np.pi
    w = hashing.make_hash_params(jax.random.fold_in(rng, 7), d, 1, 20000)
    sa = hashing.hash_keys_signs(w, a[None])[0, :, 0]
    sb = hashing.hash_keys_signs(w, b[None])[0, :, 0]
    emp = float(jnp.mean(sa == sb))
    assert abs(emp - expected) < 0.02
