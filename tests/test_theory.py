"""Numerical validation of Section 5 (Theorem 3, Lemmas 4-8)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import hashing, socket, theory


def test_lemma4_correlation_formula(rng):
    """Gamma = C q^T W^T s_hat — closed form vs Monte Carlo."""
    d, p = 48, 8
    kq, kw, kk = jax.random.split(rng, 3)
    q = jax.random.normal(kq, (d,))
    q = q / jnp.linalg.norm(q)
    w, _ = jnp.linalg.qr(jax.random.normal(kw, (d, p)))
    w = w.T                                   # (P, d) orthonormal rows
    s = jnp.tanh(w @ q)                       # the soft per-plane scores
    gamma_formula = float(theory.lemma4_gamma(q, w, s))

    keys = jax.random.normal(kk, (200_000, d))
    x = keys @ q
    y = jnp.sign(keys @ w.T) @ s
    corr = float(jnp.corrcoef(x, y)[0, 1])
    assert abs(corr - gamma_formula) < 0.02


def test_hard_vs_soft_correlation_inequality(rng):
    """Appendix C: Gamma_hard = C ||Wq||_1/sqrt(P) <= C ||Wq||_2 ~ soft."""
    d, p = 32, 10
    for seed in range(5):
        kq, kw = jax.random.split(jax.random.fold_in(rng, seed))
        q = jax.random.normal(kq, (d,))
        q = q / jnp.linalg.norm(q)
        w, _ = jnp.linalg.qr(jax.random.normal(kw, (d, p)))
        w = w.T
        wq = w @ q
        c = np.sqrt(2 / np.pi)
        gamma_hard = c * float(jnp.sum(jnp.abs(wq))) / np.sqrt(p)
        gamma_soft = float(theory.lemma4_gamma(q, w, jnp.tanh(wq)))
        # soft uses tanh ≈ linear in the small-signal regime
        assert gamma_hard <= gamma_soft + 1e-3


def test_eps_tau_limits(rng):
    """Theorem 3 / Appendix B.1: eps_tau -> 0 as tau -> 0 and
    -> 1 - 1/R as tau -> inf; monotone in between."""
    q = jax.random.normal(rng, (32,))
    p = 6
    r = 2 ** p
    # eps_tau decays ~linearly in tau (planes with |u| ≈ 0 contribute
    # 1/2 each); tau = 1e-3 sits well inside the -> 0 regime, where the
    # seed's 0.01 draw landed at ~0.023 and tripped the 0.02 bound
    values = [float(theory.eps_tau_monte_carlo(rng, q, tau, p))
              for tau in (0.001, 0.1, 0.5, 2.0, 50.0)]
    assert values[0] < 0.02
    assert abs(values[-1] - (1 - 1 / r)) < 0.02
    assert all(a <= b + 1e-6 for a, b in zip(values, values[1:]))


def test_finite_l_error_decays_as_sqrt_l(rng):
    """Lemma 6: ||y_{tau,L} - y_tau|| ~ L^{-1/2}."""
    d, n = 24, 96
    kk, kv, kq = jax.random.split(rng, 3)
    keys = jax.random.normal(kk, (n, d))
    values = jax.random.normal(kv, (n, d))
    q = jax.random.normal(kq, (d,))

    def err_at(l, trials=12):
        cfg = socket.SocketConfig(num_planes=4, num_tables=l, tau=0.5)
        # population estimate via a very large L reference
        cfg_ref = socket.SocketConfig(num_planes=4, num_tables=4096,
                                      tau=0.5)
        y_ref, _ = theory.soft_count_attention(
            cfg_ref, jax.random.fold_in(rng, 999), q, keys, values)
        errs = []
        for t in range(trials):
            y, _ = theory.soft_count_attention(
                cfg, jax.random.fold_in(rng, t), q, keys, values)
            errs.append(float(jnp.linalg.norm(y - y_ref)))
        return np.mean(errs)

    e16, e256 = err_at(16), err_at(256)
    ratio = e16 / max(e256, 1e-9)
    # L x16 => error should shrink ~4x; accept [2, 8]
    assert 2.0 < ratio < 8.0, ratio


def test_sampling_estimator_unbiased(rng):
    """Lemma 7 part 1: E[T(q) | tables] = y_{tau,L}."""
    d, n = 16, 64
    kk, kv, kq = jax.random.split(rng, 3)
    keys = jax.random.normal(kk, (n, d))
    values = jax.random.normal(kv, (n, d))
    q = jax.random.normal(kq, (d,))
    cfg = socket.SocketConfig(num_planes=4, num_tables=32, tau=0.5)
    y, a_tilde = theory.soft_count_attention(cfg, rng, q, keys, values)
    estimates = jnp.stack([
        theory.sampling_estimator(jax.random.fold_in(rng, i), a_tilde,
                                  values, m=64)
        for i in range(800)])
    mean_est = jnp.mean(estimates, axis=0)
    rel = float(jnp.linalg.norm(mean_est - y) / jnp.linalg.norm(y))
    # MC standard error at 800 trials is ~0.04 relative; 0.08 = 2 sigma
    assert rel < 0.08, rel


def test_sampling_error_decays_with_m(rng):
    """Theorem 3's M^{-1/2} term."""
    d, n = 16, 64
    kk, kv, kq = jax.random.split(rng, 3)
    keys = jax.random.normal(kk, (n, d))
    values = jax.random.normal(kv, (n, d))
    q = jax.random.normal(kq, (d,))
    cfg = socket.SocketConfig(num_planes=4, num_tables=32, tau=0.5)
    y, a_tilde = theory.soft_count_attention(cfg, rng, q, keys, values)

    def rmse(m, trials=60):
        errs = [float(jnp.linalg.norm(theory.sampling_estimator(
            jax.random.fold_in(rng, 1000 * m + i), a_tilde, values, m) - y))
            for i in range(trials)]
        return np.sqrt(np.mean(np.square(errs)))

    r = rmse(8) / max(rmse(128), 1e-9)
    assert 2.0 < r < 8.0, r  # M x16 => ~4x


def test_correlation_table3_direction(rng):
    """Table 3's qualitative claim: SOCKET's scores correlate better with
    q.k than hard LSH counts at a matched (600-bit) budget."""
    d, n = 64, 2048
    kk, kq = jax.random.split(rng)
    keys = jax.random.normal(kk, (n, d))
    q = jax.random.normal(kq, (d,))
    true_sim = keys @ q

    cfg = socket.SocketConfig(num_planes=10, num_tables=60, tau=0.5)
    w = hashing.make_hash_params(jax.random.fold_in(rng, 1), d, 10, 60)
    signs = hashing.hash_keys_signs(w, keys)
    soft = socket.soft_scores_factorized(cfg, hashing.pack_signs(signs),
                                         socket.soft_hash_query(w, q))

    w2 = hashing.make_hash_params(jax.random.fold_in(rng, 2), d, 2, 300)
    signs2 = hashing.hash_keys_signs(w2, keys)
    q_signs = hashing.hash_keys_signs(w2, q[None])[0]
    hard = jnp.sum(jnp.all(signs2 == q_signs[None], axis=-1),
                   axis=-1).astype(jnp.float32)

    corr_soft = float(jnp.corrcoef(true_sim, soft)[0, 1])
    corr_hard = float(jnp.corrcoef(true_sim, hard)[0, 1])
    assert corr_soft > corr_hard, (corr_soft, corr_hard)
