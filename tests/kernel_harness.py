"""Differential test harness for the Pallas kernels.

Every kernel package under ``src/repro/kernels`` ships an ``ops.py``
wrapper and a pure-jnp ``ref.py`` oracle.  This harness pins each op to
its oracle through one shared mechanism:

* A :class:`KernelOp` declares the op's **parity policy once** —
  ``bitwise`` for exact integer/boolean artifacts (e.g. the paged
  kernel's selected set) or ``allclose`` with per-dtype tolerances for
  float outputs — instead of scattering tolerances across tests.
* A :class:`KernelCase` is one point in the op's dtype ×
  ragged-length × grid-shape sweep; the op's ``build`` function turns
  it into ``(label, kernel_out, oracle_out[, policy_override])``
  comparison tuples (an op may emit several artifacts per case, each
  with its own policy — the fused paged kernel compares its float
  attention output under tolerance *and* its selection bitwise).
* :func:`run_differential` executes one (op, case) pair;
  ``tests/test_kernels.py`` parametrizes a single test function over
  :func:`all_cases` of every registered op.

All kernels run in interpret mode off-TPU (identical semantics, the
same code paths that lower to TPU), so the sweeps are hardware-honest
on the CPU CI runners.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Optional, Sequence, Tuple

import jax.numpy as jnp
import numpy as np

__all__ = ["ParityPolicy", "KernelCase", "KernelOp", "all_cases",
           "run_differential", "BITWISE"]


@dataclasses.dataclass(frozen=True)
class ParityPolicy:
    """How close a kernel output must sit to its oracle.

    ``mode`` is ``"bitwise"`` (``assert_array_equal``) or ``"allclose"``
    (``atol``/``rtol``; ``bf16_atol`` widens the absolute tolerance when
    the case's compute dtype is bfloat16).
    """

    mode: str = "allclose"
    atol: float = 0.0
    rtol: float = 0.0
    bf16_atol: Optional[float] = None

    def for_dtype(self, dtype) -> "ParityPolicy":
        if (self.mode == "allclose" and self.bf16_atol is not None
                and jnp.dtype(dtype) == jnp.bfloat16):
            return dataclasses.replace(self, atol=self.bf16_atol)
        return self

    def assert_match(self, out, ref, label: str) -> None:
        out = np.asarray(out)
        ref = np.asarray(ref)
        if self.mode == "bitwise":
            np.testing.assert_array_equal(out, ref, err_msg=label)
        else:
            np.testing.assert_allclose(out.astype(np.float64),
                                       ref.astype(np.float64),
                                       atol=self.atol, rtol=self.rtol,
                                       err_msg=label)


BITWISE = ParityPolicy(mode="bitwise")


@dataclasses.dataclass(frozen=True)
class KernelCase:
    """One sweep point: a label plus the op-specific case knobs."""

    label: str
    params: Tuple[Tuple[str, object], ...]

    @staticmethod
    def make(label: str, **params) -> "KernelCase":
        return KernelCase(label, tuple(sorted(params.items())))

    @property
    def kwargs(self) -> dict:
        return dict(self.params)


@dataclasses.dataclass(frozen=True)
class KernelOp:
    """One kernel op: its case sweep, build function, and parity policy.

    ``build(case)`` returns an iterable of comparison tuples
    ``(artifact_label, kernel_out, oracle_out)`` or
    ``(artifact_label, kernel_out, oracle_out, policy_override)``.
    """

    name: str
    build: Callable[[KernelCase], Sequence]
    policy: ParityPolicy
    cases: Tuple[KernelCase, ...]


def all_cases(ops: Sequence[KernelOp]):
    """(op, case) pairs + pytest ids for one flat parametrization."""
    pairs = [(op, case) for op in ops for case in op.cases]
    ids = [f"{op.name}-{case.label}" for op, case in pairs]
    return pairs, ids


def run_differential(op: KernelOp, case: KernelCase) -> None:
    """Run one case of one op against its oracle under the op's policy."""
    comparisons = op.build(case)
    assert comparisons, f"{op.name}:{case.label} produced no comparisons"
    dtype = case.kwargs.get("dtype", jnp.float32)
    for cmp in comparisons:
        label, out, ref = cmp[0], cmp[1], cmp[2]
        policy = cmp[3] if len(cmp) > 3 and cmp[3] is not None else op.policy
        policy.for_dtype(dtype).assert_match(
            out, ref, f"{op.name}:{case.label}:{label}")
