"""DecodeBackend registry + KVView contract: contiguous-vs-paged parity
for every registered backend, O(top_k) K/V traffic on the paged SOCKET
path, and the Pallas kernel plumbing."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import attention as attn
from repro.models import backends as bk
from repro.models import param as pm

ALL_BACKENDS = ["socket", "hard_lsh", "quest", "dense"]
NB = 4            # blocks per request in the parity fixtures


def _cfg(backend):
    return get_config("stablelm-12b").smoke().replace(
        attention_backend=backend)


def _setup(backend, seed=0):
    """One attention layer's params + a filled contiguous cache and an
    identical-content paged pool (shuffled physical blocks)."""
    cfg = _cfg(backend)
    be = bk.get_backend(backend)
    rng = np.random.default_rng(seed)
    params = pm.unbox(attn.init_attention(cfg, jax.random.PRNGKey(seed)))
    kv = params["wk"].shape[1]
    b, hd = 2, cfg.head_dim
    bs = cfg.serving.block_size
    capacity = NB * bs

    keys = jnp.asarray(rng.normal(size=(b, kv, capacity, hd)), jnp.float32)
    vals = jnp.asarray(rng.normal(size=(b, kv, capacity, hd)), jnp.float32)
    cache = be.init_cache(cfg, b, kv, capacity, jnp.float32)
    cache = be.prefill_build(cfg, params, cache, keys, vals)

    # paged pool with the same logical content behind shuffled block ids
    num_blocks = 1 + b * NB                      # block 0 = trash
    pool = be.init_cache(cfg, num_blocks, kv, bs, jnp.float32)
    bt = 1 + rng.permutation(b * NB).reshape(b, NB).astype(np.int32)
    pages = {}
    for name, leaf in cache.items():
        rows_pb = pool[name].shape[2]
        p = np.asarray(pool[name]).copy()
        for i in range(b):
            for j in range(NB):
                p[bt[i, j]] = np.asarray(
                    leaf[i, :, j * rows_pb:(j + 1) * rows_pb])
        pages[name] = jnp.asarray(p)

    spec = be.cache_spec(cfg)
    cview = bk.ContiguousView(dict(cache), spec)
    pview = bk.PagedView(pages, spec, jnp.asarray(bt), block_size=bs)
    q = jnp.asarray(rng.normal(size=(b, kv, cfg.gqa_groups, 1, hd)),
                    jnp.float32)
    return cfg, be, params, cview, pview, q


def test_registry_contents():
    assert set(ALL_BACKENDS) <= set(bk.registered_backends())
    for name in ("socket", "hard_lsh", "quest"):
        assert bk.get_backend(name).supports_paged, name
    assert not bk.get_backend("dense").supports_paged
    with pytest.raises(ValueError, match="unknown attention backend"):
        bk.get_backend("flashinfer")


@pytest.mark.parametrize("backend", ALL_BACKENDS)
def test_attend_contiguous_paged_parity(backend):
    """attend through a PagedView must equal the ContiguousView bitwise at
    mixed ragged lengths (same logical content, shuffled physical pages)."""
    cfg, be, params, cview, pview, q = _setup(backend)
    lengths = jnp.asarray([13, 29], jnp.int32)
    out_c = be.attend(cfg, params, q, cview, length=lengths, scale=0.125)
    out_p = be.attend(cfg, params, q, pview, length=lengths, scale=0.125)
    np.testing.assert_array_equal(np.asarray(out_c), np.asarray(out_p))


@pytest.mark.parametrize("backend", ALL_BACKENDS)
def test_append_contiguous_paged_parity(backend):
    """append at ragged per-request positions must leave both views with
    identical logical leaf contents."""
    cfg, be, params, cview, pview, q = _setup(backend, seed=1)
    rng = np.random.default_rng(7)
    b, kv = 2, params["wk"].shape[1]
    kc = jnp.asarray(rng.normal(size=(b, kv, 1, cfg.head_dim)), jnp.float32)
    vc = jnp.asarray(rng.normal(size=(b, kv, 1, cfg.head_dim)), jnp.float32)
    pos = jnp.asarray([13, 29], jnp.int32)
    be.append(cfg, params, cview, kc, vc, pos)
    be.append(cfg, params, pview, kc, vc, pos)
    for name in cview.arrays:
        np.testing.assert_array_equal(
            np.asarray(cview.leaf(name)), np.asarray(pview.leaf(name)),
            err_msg=f"{backend}:{name}")


def test_paged_socket_gathers_only_topk_kv_rows():
    """The paged SOCKET attend must materialize only the small metadata
    leaves; K/V are touched at exactly the static top-k rows."""
    from repro.core import socket as sk

    cfg, be, params, _, pview, q = _setup("socket")
    bk.gather_trace_reset()
    be.attend(cfg, params, q, pview,
              length=jnp.asarray([13, 29], jnp.int32), scale=0.125)
    trace = bk.gather_trace()
    full_leaves = {name for kind, name, _ in trace if kind == "leaf"}
    assert full_leaves <= {"bits", "vnorm"}, trace
    kq = sk.topk_budget(bk.socket_config_of(cfg), pview.n_tokens)
    row_gathers = [t for t in trace if t[0] == "rows"]
    assert {name for _, name, _ in row_gathers} == {"k", "v"}
    for _, name, shape in row_gathers:
        assert shape[-2] == kq, (name, shape, kq)


@pytest.mark.parametrize("selection", ["kvhead", "pooled"])
def test_socket_kernel_plumbing_matches_xla_path(selection):
    """use_score_kernel / use_flash_decode route attend through the Pallas
    kernels (interpret mode off-TPU) with matching results."""
    cfg, be, params, cview, pview, q = _setup("socket")
    cfg = cfg.replace(socket=dataclasses.replace(cfg.socket,
                                                 selection=selection))
    out_ref = be.attend(cfg, params, q, cview,
                        length=jnp.int32(29), scale=0.125)
    cfg_k = cfg.replace(socket=dataclasses.replace(
        cfg.socket, use_score_kernel=True, use_flash_decode=True))
    for view in (cview, pview):
        out_k = be.attend(cfg_k, params, q, view,
                          length=jnp.int32(29), scale=0.125)
        np.testing.assert_allclose(np.asarray(out_k), np.asarray(out_ref),
                                   atol=2e-5)


def test_socket_kernel_scores_int8_bits():
    """The scoring kernel now handles int8 ±1 sign storage (the
    uint32-word assumption was the only blocker): kernel-scored attend
    must match the plain-XLA scoring path on the same int8 cache."""
    cfg, be, params, _, _, q = _setup("socket")
    cfg8 = cfg.replace(socket=dataclasses.replace(
        cfg.socket, bits_storage="int8"))
    be8 = bk.get_backend("socket")
    rng = np.random.default_rng(3)
    kv, hd = params["wk"].shape[1], cfg.head_dim
    keys = jnp.asarray(rng.normal(size=(2, kv, 32, hd)), jnp.float32)
    vals = jnp.asarray(rng.normal(size=(2, kv, 32, hd)), jnp.float32)
    cache = be8.init_cache(cfg8, 2, kv, 32, jnp.float32)
    cache = be8.prefill_build(cfg8, params, cache, keys, vals)
    view = bk.ContiguousView(cache, be8.cache_spec(cfg8))
    outs = {}
    for use_kernel in (False, True):
        ck = cfg8.replace(socket=dataclasses.replace(
            cfg8.socket, use_score_kernel=use_kernel))
        outs[use_kernel] = be8.attend(ck, params, q, view,
                                      length=jnp.int32(16), scale=0.125)
    np.testing.assert_allclose(np.asarray(outs[True]),
                               np.asarray(outs[False]), atol=2e-5)


def test_quest_append_resets_stats_on_reused_page():
    """A decode-growth block may be a reused page still carrying the
    previous owner's min/max (BlockPool never scrubs device memory): the
    first token written into a page must RESET the stats, not merge."""
    cfg, be, params, cview, pview, q = _setup("quest", seed=2)
    ps = cfg.quest.page_size
    bs = cfg.serving.block_size
    # poison every stats page with huge stale bounds
    poison = {"kmin": jnp.full_like(pview.arrays["kmin"], -1e4),
              "kmax": jnp.full_like(pview.arrays["kmax"], 1e4)}
    pview.arrays.update(poison)
    kv, hd = params["wk"].shape[1], cfg.head_dim
    kc = jnp.ones((2, kv, 1, hd), jnp.float32) * 0.5
    pos = jnp.asarray([0, bs], jnp.int32)            # page-opening writes
    be.append(cfg, params, pview, kc, kc, pos)
    for i, p in enumerate([0, bs]):
        row = np.asarray(pview.leaf("kmin"))[i, :, p // ps]
        np.testing.assert_array_equal(row, 0.5)      # reset, not min(-1e4,·)
        row = np.asarray(pview.leaf("kmax"))[i, :, p // ps]
        np.testing.assert_array_equal(row, 0.5)
    # mid-page writes still merge
    be.append(cfg, params, pview, kc * 3, kc * 3, pos + 1)
    np.testing.assert_array_equal(
        np.asarray(pview.leaf("kmax"))[0, :, 0], 1.5)
    np.testing.assert_array_equal(
        np.asarray(pview.leaf("kmin"))[0, :, 0], 0.5)


@pytest.mark.parametrize("selection", ["kvhead", "pooled"])
def test_socket_backend_matches_reference_socket_attend(selection):
    """The backend's attend composition must stay pinned to the reference
    ``core.socket.socket_attend`` oracle (used by the context-parallel
    tests and accuracy benchmarks)."""
    import dataclasses

    from repro.core import socket as sk

    cfg, be, params, cview, _, q = _setup("socket")
    cfg = cfg.replace(socket=dataclasses.replace(cfg.socket,
                                                 selection=selection))
    out_b = be.attend(cfg, params, q, cview, length=jnp.int32(29),
                      scale=0.125)
    out_ref = sk.socket_attend(
        bk.socket_config_of(cfg), params["hash_w"], q, cview.arrays["k"],
        cview.arrays["v"],
        sk.SocketCache(bits=cview.arrays["bits"],
                       vnorm=cview.arrays["vnorm"]),
        length=jnp.int32(29), scale=0.125)
    np.testing.assert_allclose(np.asarray(out_b), np.asarray(out_ref),
                               atol=1e-6)


def test_quest_page_size_must_divide_block_size():
    cfg = _cfg("quest")
    bad = cfg.replace(quest=dataclasses.replace(cfg.quest, page_size=3))
    with pytest.raises(ValueError, match="divide serving block_size"):
        bk.get_backend("quest").cache_spec(bad)


def test_cache_spec_drives_cache_and_axes():
    """init_attention_cache / cache_logical_axes are derived from the
    backend spec — leaf set, page granularity and dtypes must line up."""
    cfg = _cfg("quest")
    cache = attn.init_attention_cache(cfg, batch=2, capacity=32, attn_type="global")
    ps = cfg.quest.page_size
    assert set(cache) == {"k", "v", "kmin", "kmax"}
    assert cache["kmin"].shape[2] == 32 // ps
    assert bool(jnp.all(jnp.isinf(cache["kmin"])))
    axes = attn.cache_logical_axes(cfg, "global")
    assert axes["kmin"] == ("cache_batch", "cache_heads", "cache_seq", None)

    cfg_s = _cfg("socket")
    cache_s = attn.init_attention_cache(cfg_s, batch=2, capacity=32,
                                        attn_type="global")
    assert set(cache_s) == {"k", "v", "bits", "vnorm"}
    assert cache_s["bits"].dtype == jnp.uint32
    assert attn.cache_logical_axes(cfg_s, "global")["vnorm"] == (
        "cache_batch", "cache_heads", "cache_seq")


FUSED_BACKENDS = ["socket", "hard_lsh", "quest"]


def _fused_cfg(cfg, backend):
    """Flip the backend's fused-paged gate (hard_lsh shares SOCKET's)."""
    if backend == "quest":
        return cfg.replace(quest=dataclasses.replace(
            cfg.quest, use_paged_kernel=True))
    return cfg.replace(socket=dataclasses.replace(
        cfg.socket, use_paged_kernel=True))


def _count_pool_gathers(fn, *args, num_blocks):
    """# of XLA gather eqns (recursively) whose operand is a pool leaf."""
    jaxpr = jax.make_jaxpr(fn)(*args)

    def walk(jx):
        hits = 0
        for eqn in jx.eqns:
            if eqn.primitive.name == "gather":
                op = eqn.invars[0].aval
                if op.ndim >= 3 and op.shape[0] == num_blocks:
                    hits += 1
            for sub in jax.core.jaxprs_in_params(eqn.params):
                hits += walk(sub)
        return hits
    return walk(jaxpr.jaxpr)


@pytest.mark.parametrize("backend", FUSED_BACKENDS)
def test_fused_paged_attend_has_zero_pool_gathers(backend):
    """The fused kernels consume the pool in place: the attend jaxpr must
    contain ZERO gather primitives on pool-shaped operands, where the
    unfused paged path needs them for every leaf view / top-k row fetch."""
    cfg, be, params, _, pview, q = _setup(backend)
    num_blocks = pview.arrays["k"].shape[0]
    lengths = jnp.asarray([13, 29], jnp.int32)

    def attend(cfg):
        def fn(q, pages, bt):
            view = bk.PagedView(pages, be.cache_spec(cfg), bt,
                                block_size=cfg.serving.block_size)
            return be.attend(cfg, params, q, view, length=lengths,
                             scale=0.125)
        return fn

    unfused = _count_pool_gathers(attend(cfg), q, pview.arrays,
                                  pview.block_table, num_blocks=num_blocks)
    assert unfused >= 2, "unfused paged path should gather K and V rows"

    fused = _count_pool_gathers(attend(_fused_cfg(cfg, backend)), q,
                                pview.arrays, pview.block_table,
                                num_blocks=num_blocks)
    assert fused == 0, f"fused path launched {fused} pool gathers"


@pytest.mark.parametrize("selection", ["kvhead", "pooled"])
def test_fused_paged_kernel_matches_unfused_paged_path(selection):
    """use_paged_kernel routes PagedView attends through the fused Pallas
    kernel with matching results (ragged and scalar lengths); contiguous
    views keep the existing path bit-for-bit."""
    cfg, be, params, cview, pview, q = _setup("socket")
    cfg = cfg.replace(socket=dataclasses.replace(cfg.socket,
                                                 selection=selection))
    cfg_f = cfg.replace(socket=dataclasses.replace(cfg.socket,
                                                   use_paged_kernel=True))
    for length in (jnp.asarray([13, 29], jnp.int32), jnp.int32(29)):
        out_ref = be.attend(cfg, params, q, pview, length=length,
                            scale=0.125)
        out_f = be.attend(cfg_f, params, q, pview, length=length,
                          scale=0.125)
        np.testing.assert_allclose(np.asarray(out_f), np.asarray(out_ref),
                                   atol=2e-5)
    # the flag must not disturb contiguous callers at all
    out_c = be.attend(cfg, params, q, cview, length=jnp.int32(29),
                      scale=0.125)
    out_cf = be.attend(cfg_f, params, q, cview, length=jnp.int32(29),
                       scale=0.125)
    np.testing.assert_array_equal(np.asarray(out_c), np.asarray(out_cf))


def test_fused_paged_kernel_rejects_unsupported_combos():
    """int8 bit storage, per-q-head selection and non-sublane block sizes
    have no fused path — they must fail fast, not score garbage."""
    cfg, be, params, _, pview, q = _setup("socket")
    lengths = jnp.asarray([13, 29], jnp.int32)
    base_s = dataclasses.replace(cfg.socket, use_paged_kernel=True)

    cfg8 = cfg.replace(socket=dataclasses.replace(base_s,
                                                  bits_storage="int8"))
    with pytest.raises(NotImplementedError, match="int8"):
        be.attend(cfg8, params, q, pview, length=lengths, scale=0.125)

    cfgq = cfg.replace(socket=dataclasses.replace(base_s,
                                                  selection="qhead"))
    with pytest.raises(NotImplementedError, match="per-q-head"):
        be.attend(cfgq, params, q, pview, length=lengths, scale=0.125)

    cfg_bs = cfg.replace(socket=base_s)
    bad_view = bk.PagedView(pview.arrays, be.cache_spec(cfg_bs),
                            pview.block_table, block_size=12)
    with pytest.raises(NotImplementedError, match="block_size"):
        be.attend(cfg_bs, params, q, bad_view, length=lengths, scale=0.125)


@pytest.mark.parametrize("backend", ["hard_lsh", "quest"])
def test_new_fused_backends_match_unfused_paged_path(backend):
    """use_paged_kernel routes hard_lsh / quest PagedView attends through
    their fused Pallas kernels with matching results (ragged and scalar
    lengths); contiguous views keep the existing path bit-for-bit."""
    cfg, be, params, cview, pview, q = _setup(backend)
    cfg_f = _fused_cfg(cfg, backend)
    for length in (jnp.asarray([13, 29], jnp.int32), jnp.int32(29)):
        out_ref = be.attend(cfg, params, q, pview, length=length,
                            scale=0.125)
        out_f = be.attend(cfg_f, params, q, pview, length=length,
                          scale=0.125)
        np.testing.assert_allclose(np.asarray(out_f), np.asarray(out_ref),
                                   atol=2e-5)
    out_c = be.attend(cfg, params, q, cview, length=jnp.int32(29),
                      scale=0.125)
    out_cf = be.attend(cfg_f, params, q, cview, length=jnp.int32(29),
                       scale=0.125)
    np.testing.assert_array_equal(np.asarray(out_c), np.asarray(out_cf))


@pytest.mark.parametrize("backend", FUSED_BACKENDS)
def test_fused_backends_report_zero_paged_bytes(backend):
    """Every fused gate flips its backend's fused_paged() and zeroes the
    per-step paged-pool gather accounting (hard_lsh used to ignore the
    flag — it now fuses through SOCKET's gate)."""
    from repro.serving.paged import gather_footprint

    cfg = _cfg(backend)
    fp = gather_footprint(cfg)
    assert not fp["fused_paged_kernel"]
    assert fp["paged_bytes_per_step"] > 0

    cfg_f = _fused_cfg(cfg, backend)
    assert bk.get_backend(backend).fused_paged(cfg_f)
    fp = gather_footprint(cfg_f)
    assert fp["fused_paged_kernel"]
    assert fp["paged_bytes_per_step"] == 0


def test_config_time_kernel_gate_validation():
    """Every fused-gate combination the Pallas kernels would reject at
    trace time (deep inside a jitted serving step) is rejected by
    ``cfg.validate()`` — and therefore by ``cache_plan()``, the serving
    engine's first config touch — with the offending flag pair named."""
    cfg = _cfg("socket")
    fused_s = dataclasses.replace(cfg.socket, use_paged_kernel=True)

    bad = cfg.replace(socket=dataclasses.replace(fused_s,
                                                 bits_storage="int8"))
    with pytest.raises(ValueError, match="bits_storage"):
        bad.validate()
    with pytest.raises(ValueError, match="use_paged_kernel"):
        bad.cache_plan()
    bad = cfg.replace(socket=dataclasses.replace(fused_s,
                                                 selection="qhead"))
    with pytest.raises(ValueError, match="selection"):
        bad.validate()
    bad = cfg.replace(socket=fused_s, serving=dataclasses.replace(
        cfg.serving, block_size=12))
    with pytest.raises(ValueError, match="block_size"):
        bad.validate()

    qcfg = _cfg("quest")
    fused_q = dataclasses.replace(qcfg.quest, use_paged_kernel=True)
    bad = qcfg.replace(quest=fused_q, serving=dataclasses.replace(
        qcfg.serving, block_size=12))
    with pytest.raises(ValueError, match="block_size"):
        bad.validate()
    bad = qcfg.replace(quest=dataclasses.replace(fused_q, page_size=3))
    with pytest.raises(ValueError, match="page_size"):
        bad.validate()

    bad = cfg.replace(use_ring_kernel=True, serving=dataclasses.replace(
        cfg.serving, block_size=12))
    with pytest.raises(ValueError, match="use_ring_kernel"):
        bad.validate()

    # the eligible smoke gates stay constructible
    cfg.replace(socket=fused_s).validate()
    qcfg.replace(quest=fused_q).validate()
    cfg.replace(use_ring_kernel=True).validate()


def test_ragged_cp_decode_falls_back_to_xla_path():
    """Ragged decode + ``decode_cp_axes`` used to raise a bare
    NotImplementedError mid-serve; it must now warn once (via obs) and
    produce the pjit/XLA result bit-for-bit.  Scalar-length decode keeps
    the shard_map fast path (covered by test_distributed)."""
    import repro.serving.obs as obs
    from repro.distributed import sharding as shd

    cfg, be, params, cview, _, q = _setup("socket")
    lengths = jnp.asarray([13, 29], jnp.int32)
    out_plain = be.attend(cfg, params, q, cview, length=lengths,
                          scale=0.125)

    cfg_cp = cfg.replace(decode_cp_axes=("data",))
    mesh = jax.make_mesh((1,), ("data",))
    obs._WARNED.discard("socket-ragged-cp-fallback")
    with shd.activate_mesh(mesh):
        with pytest.warns(UserWarning, match="ragged decode"):
            out_cp = be.attend(cfg_cp, params, q, cview, length=lengths,
                               scale=0.125)
        # one-shot: the fallback must not spam every decode step
        import warnings
        with warnings.catch_warnings():
            warnings.simplefilter("error")
            out_again = be.attend(cfg_cp, params, q, cview, length=lengths,
                                  scale=0.125)
    np.testing.assert_array_equal(np.asarray(out_plain), np.asarray(out_cp))
    np.testing.assert_array_equal(np.asarray(out_plain),
                                  np.asarray(out_again))
