"""Runtime supervision: training loop, failure injection + recovery,
exact resume, straggler detection."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.data import DataConfig
from repro.optim import AdamWConfig
from repro.optim.schedule import ScheduleConfig
from repro.runtime.fault_tolerance import FailureInjector, RetryPolicy
from repro.runtime.straggler import StragglerDetector
from repro.runtime.train_loop import Trainer, TrainLoopConfig


def _tiny_cfg():
    return get_config("minitron-8b").smoke().replace(
        num_groups=1, attention_backend="dense")


def _mk_trainer(tmp_path, steps=12, injector=None, ckpt_every=4):
    cfg = _tiny_cfg()
    ocfg = AdamWConfig(schedule=ScheduleConfig(peak_lr=1e-3,
                                               warmup_steps=2,
                                               decay_steps=steps))
    loop = TrainLoopConfig(total_steps=steps, checkpoint_every=ckpt_every,
                           log_every=100)
    data = DataConfig(seq_len=32, global_batch=2,
                      vocab_size=cfg.vocab_size, seed=1)
    return Trainer(cfg, ocfg, loop, data, str(tmp_path),
                   injector=injector,
                   mesh_factory=lambda devs: None)


def test_training_reduces_loss(tmp_path):
    trainer = _mk_trainer(tmp_path, steps=25)
    log = trainer.run()
    assert len(log) == 25
    first = np.mean([m["loss"] for m in log[:5]])
    last = np.mean([m["loss"] for m in log[-5:]])
    assert last < first, (first, last)


def test_failure_recovery_resumes_from_checkpoint(tmp_path):
    inj = FailureInjector(schedule={9: RuntimeError("chip fell over")})
    trainer = _mk_trainer(tmp_path, steps=12, injector=inj, ckpt_every=4)
    log = trainer.run()
    assert trainer.rebuild_count == 1
    # failure at step 9 rolls back to the step-8 checkpoint; steps 8..11
    # re-run => the log contains step 8 twice
    steps = [m["step"] for m in log]
    assert steps.count(8) >= 2 or steps.count(9) >= 1
    assert trainer.step == 12


def test_recovered_run_matches_uninterrupted(tmp_path):
    """Determinism through failure: same data stream + restore =>
    the final loss matches an uninterrupted run closely."""
    t1 = _mk_trainer(tmp_path / "a", steps=10)
    log1 = t1.run()
    inj = FailureInjector(schedule={7: RuntimeError("boom")})
    t2 = _mk_trainer(tmp_path / "b", steps=10, injector=inj, ckpt_every=5)
    log2 = t2.run()
    assert abs(log1[-1]["loss"] - log2[-1]["loss"]) < 1e-3


def test_gives_up_after_repeated_failures(tmp_path):
    class AlwaysFail(FailureInjector):
        def maybe_fail(self, step):
            raise RuntimeError("dead")

    inj = AlwaysFail()
    trainer = _mk_trainer(tmp_path, steps=10, injector=inj)
    trainer.retry = RetryPolicy(max_consecutive_failures=2)
    with pytest.raises(RuntimeError, match="giving up"):
        trainer.run()


def test_straggler_detector_flags_outliers():
    det = StragglerDetector(threshold_sigma=3.0, warmup_steps=3,
                            patience=2)
    fired = []
    det.on_straggler = lambda step, lat: fired.append(step)
    for s in range(20):
        det.observe(s, 0.1 + 0.001 * (s % 3))
    assert not det.events
    det.observe(20, 2.5)
    det.observe(21, 2.5)
    assert len(det.events) == 2
    assert fired == [21]
    # healthy steps afterwards don't poison the baseline
    det.observe(22, 0.1)
    assert det.mean_latency < 0.2


def test_retry_policy():
    rp = RetryPolicy(max_consecutive_failures=2)
    assert rp.record_failure()
    assert rp.record_failure()
    assert not rp.record_failure()
    rp.record_success()
    assert rp.record_failure()


def test_grad_accumulation_equivalence(tmp_path):
    """accum=2 over a batch == accum=1 on the same batch (mean of grads)."""
    from repro.models import init_model, param as pm
    from repro.optim import init_adamw
    from repro.runtime.steps import make_train_step

    cfg = _tiny_cfg()
    ocfg = AdamWConfig(schedule=ScheduleConfig(peak_lr=1e-2,
                                               warmup_steps=0,
                                               kind="constant"))
    rng = jax.random.PRNGKey(0)
    params = pm.unbox(init_model(cfg, rng))
    batch = {
        "tokens": jax.random.randint(rng, (4, 32), 0, cfg.vocab_size),
        "labels": jax.random.randint(jax.random.fold_in(rng, 1), (4, 32),
                                     0, cfg.vocab_size),
    }
    p1, _, m1 = make_train_step(cfg, ocfg, accum=1)(
        params, init_adamw(ocfg, params), batch)
    p2, _, m2 = make_train_step(cfg, ocfg, accum=2)(
        params, init_adamw(ocfg, params), batch)
    # losses match exactly; params match to accumulation-order tolerance
    assert abs(float(m1["loss"]) - float(m2["loss"])) < 1e-5
    diffs = jax.tree_util.tree_map(
        lambda a, b: float(jnp.max(jnp.abs(a - b))), p1, p2)
    assert max(jax.tree_util.tree_leaves(diffs)) < 1e-5
