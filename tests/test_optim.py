"""Optimizer tests: AdamW vs a literal numpy reference, masks, clipping,
8-bit states (roundtrip property + convergence equivalence)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis_compat import given, settings, st

from repro.optim import AdamWConfig, adamw_update, init_adamw
from repro.optim import quantized_state as q8
from repro.optim.adamw import is_trainable_path, wants_weight_decay
from repro.optim.schedule import ScheduleConfig, learning_rate


def _np_adamw(w, g_fn, steps, lr, b1=0.9, b2=0.95, eps=1e-8, wd=0.0):
    m = np.zeros_like(w)
    v = np.zeros_like(w)
    for t in range(1, steps + 1):
        g = g_fn(w)
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * g * g
        upd = (m / (1 - b1 ** t)) / (np.sqrt(v / (1 - b2 ** t)) + eps)
        w = w - lr * (upd + wd * w)
    return w


def test_adamw_matches_numpy_reference():
    sched = ScheduleConfig(peak_lr=0.05, warmup_steps=0, kind="constant")
    cfg = AdamWConfig(weight_decay=0.0, grad_clip_norm=1e9, schedule=sched)
    w0 = np.linspace(-2, 2, 16).astype(np.float32)
    g_fn = lambda w: 2 * (w - 0.5)

    params = {"w": jnp.asarray(w0)}
    state = init_adamw(cfg, params)
    for _ in range(25):
        g = {"w": jnp.asarray(g_fn(np.asarray(params["w"])))}
        params, state, _ = adamw_update(cfg, g, state, params)
    ref = _np_adamw(w0, g_fn, 25, 0.05)
    np.testing.assert_allclose(np.asarray(params["w"]), ref, atol=1e-5)


def test_weight_decay_applied_with_mask():
    sched = ScheduleConfig(peak_lr=0.1, warmup_steps=0, kind="constant")
    cfg = AdamWConfig(weight_decay=0.5, grad_clip_norm=1e9, schedule=sched)
    params = {"dense_w": jnp.ones((4, 4)), "norm_scale": jnp.ones((4,))}
    state = init_adamw(cfg, params)
    zeros = jax.tree_util.tree_map(jnp.zeros_like, params)
    new_p, _, _ = adamw_update(cfg, zeros, state, params)
    assert float(jnp.max(new_p["dense_w"])) < 1.0      # decayed
    assert float(jnp.max(jnp.abs(new_p["norm_scale"] - 1.0))) < 1e-6


def test_grad_clipping():
    sched = ScheduleConfig(peak_lr=1.0, warmup_steps=0, kind="constant")
    cfg = AdamWConfig(weight_decay=0.0, grad_clip_norm=1.0, schedule=sched)
    params = {"w": jnp.zeros((4,))}
    state = init_adamw(cfg, params)
    huge = {"w": jnp.full((4,), 1e6)}
    _, _, metrics = adamw_update(cfg, huge, state, params)
    assert float(metrics["grad_norm"]) > 1e6  # reported pre-clip


def test_hash_planes_frozen():
    assert not is_trainable_path("groups/slot_0/attn/hash_w")
    assert is_trainable_path("groups/slot_0/attn/wq")
    sched = ScheduleConfig(peak_lr=0.1, warmup_steps=0, kind="constant")
    cfg = AdamWConfig(schedule=sched)
    params = {"hash_w": jnp.ones((3, 3)), "w": jnp.ones((3,))}
    state = init_adamw(cfg, params)
    g = {"hash_w": jnp.ones((3, 3)), "w": jnp.ones((3,))}
    new_p, _, _ = adamw_update(cfg, g, state, params)
    np.testing.assert_array_equal(np.asarray(new_p["hash_w"]),
                                  np.ones((3, 3)))
    assert float(jnp.max(jnp.abs(new_p["w"] - 1.0))) > 0


def test_schedule_shapes():
    sched = ScheduleConfig(peak_lr=1.0, warmup_steps=10, decay_steps=100,
                           kind="cosine", end_lr_frac=0.1)
    lrs = [float(learning_rate(sched, jnp.int32(s)))
           for s in (0, 5, 10, 55, 100, 200)]
    assert lrs[0] == 0.0
    assert abs(lrs[1] - 0.5) < 1e-6          # mid-warmup
    assert abs(lrs[2] - 1.0) < 1e-6          # peak
    assert lrs[3] < lrs[2]
    assert abs(lrs[4] - 0.1) < 1e-6          # end
    assert abs(lrs[5] - 0.1) < 1e-6          # clamped


@settings(max_examples=20, deadline=None)
@given(n=st.integers(1, 5000), power=st.sampled_from([1, 2, 3, 4, 6]),
       scale=st.floats(1e-6, 1e3))
def test_q8_roundtrip_bounded_error(n, power, scale):
    rng = np.random.default_rng(n)
    x = jnp.asarray(rng.normal(size=(n,)) * scale)
    if power % 2 == 0:
        x = jnp.abs(x)
    qs = q8.quantize(x, power=power)
    back = q8.dequantize(qs, x.shape, power=power)
    # companding: relative error within a block bounded by ~power/127
    tol = (power * 1.2 / 127) * float(jnp.max(jnp.abs(x))) + 1e-9
    assert float(jnp.max(jnp.abs(back - x))) <= tol * 1.5


def test_q8_preserves_leading_shape():
    x = jnp.ones((3, 5, 700))
    qs = q8.quantize(x)
    assert qs["q"].shape[:2] == (3, 5)
    assert qs["scale"].shape[:2] == (3, 5)
    back = q8.dequantize(qs, x.shape)
    assert back.shape == x.shape


def test_q8_adam_converges_like_fp32():
    sched = ScheduleConfig(peak_lr=0.1, warmup_steps=0, kind="constant")
    loss = lambda p: jnp.sum((p["w"] - 1.0) ** 2)
    results = {}
    for bits in (8, 32):
        cfg = AdamWConfig(weight_decay=0.0, state_bits=bits,
                          schedule=sched)
        params = {"w": jax.random.normal(jax.random.PRNGKey(0), (2048,))}
        state = init_adamw(cfg, params)
        for _ in range(100):
            g = jax.grad(loss)(params)
            params, state, _ = adamw_update(cfg, g, state, params)
        results[bits] = float(jnp.max(jnp.abs(params["w"] - 1.0)))
    assert results[8] < max(2 * results[32], 0.05), results
