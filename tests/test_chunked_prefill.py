"""Chunked prefill + the token-budget mixed step: token parity vs the
legacy whole-bucket engine across every backend and hybrid cache plan,
beyond-bucket prompt serving, preemption mid-chunk (greedy AND sampled —
resume must re-chunk bit-exactly), the one-chunk-per-iteration trace
invariant, per-chunk scheduler accounting, and warmup shape narrowing."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.serving import FINISHED, BlockPool, Request, Scheduler


def _smoke(backend="socket"):
    return get_config("stablelm-12b").smoke().replace(
        attention_backend=backend)


def _with_chunk(cfg, chunk, **sv):
    return cfg.replace(serving=cfg.serving.replace(prefill_chunk=chunk,
                                                   **sv))


def _run(cfg, prompts, steps, temperature=0.0, seed=0, engine=None):
    from repro.serving.engine import ContinuousBatchingEngine
    if engine is None:
        engine = ContinuousBatchingEngine(
            cfg, rng=jax.random.PRNGKey(0), temperature=temperature,
            sample_seed=seed)
    reqs = [Request(prompt=list(p), max_new_tokens=steps, arrival=0.0)
            for p in prompts]
    metrics = engine.run(reqs, realtime=False)
    return engine, reqs, metrics


# ----------------------------------------------------- chunked-vs-whole


@pytest.mark.parametrize("backend", ["socket", "dense", "hard_lsh",
                                     "quest"])
def test_chunked_matches_whole_bucket(backend):
    """The mixed token-budget step must reproduce the legacy
    whole-bucket engine token-for-token for every paged backend and the
    dense fallback — prompt lengths deliberately off every chunk/bucket
    boundary."""
    cfg = _smoke(backend)
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab_size, size=n).tolist()
               for n in (9, 24, 17)]
    _, chunked, mc = _run(_with_chunk(cfg, 16), prompts, steps=6)
    _, whole, _ = _run(_with_chunk(cfg, 0), prompts, steps=6)
    assert mc.prefill_chunks >= len(prompts)
    for c, w in zip(chunked, whole):
        assert c.state == FINISHED and c.generated == w.generated, (
            c.generated, w.generated)


@pytest.mark.parametrize("arch,ngroups", [
    ("gemma3-27b", 1), ("jamba-v0.1-52b", 1), ("mamba2-780m", None)])
def test_chunked_matches_whole_bucket_hybrid(arch, ngroups):
    """Heterogeneous cache plans under chunked prefill: gemma3's ring
    layers thread chunks through the circular page list, jamba/mamba2
    carry SSD state across chunks through the per-slot rows — all
    token-exact vs the whole-bucket engine (smoke prefill_chunk ==
    ssm_chunk, so chunk boundaries land on the SSD scan grid)."""
    cfg = get_config(arch).smoke()
    if ngroups is not None:
        cfg = cfg.replace(num_groups=ngroups)
    if arch.startswith("jamba"):
        cfg = cfg.replace(capacity_factor=float(cfg.num_experts))
    rng = np.random.default_rng(1)
    prompts = [rng.integers(0, cfg.vocab_size, size=n).tolist()
               for n in (9, 24)]
    _, chunked, _ = _run(_with_chunk(cfg, 16), prompts, steps=6)
    _, whole, _ = _run(_with_chunk(cfg, 0), prompts, steps=6)
    for c, w in zip(chunked, whole):
        assert c.generated == w.generated, (c.generated, w.generated)


def test_chunked_matches_whole_bucket_sampled():
    """Sampled decoding too: the per-request key stream consumes once at
    the first token (final chunk == whole-bucket prefill) and once per
    decode emission, so chunked and whole-bucket engines draw identical
    temperature/top-p generations."""
    cfg = _smoke("socket")
    rng = np.random.default_rng(9)
    prompts = [rng.integers(0, cfg.vocab_size, size=n).tolist()
               for n in (9, 24, 17)]
    _, chunked, _ = _run(_with_chunk(cfg, 16), prompts, steps=6,
                         temperature=0.8, seed=13)
    _, whole, _ = _run(_with_chunk(cfg, 0), prompts, steps=6,
                       temperature=0.8, seed=13)
    for c, w in zip(chunked, whole):
        assert c.generated == w.generated, (c.generated, w.generated)


def test_chunked_fused_kernel_matches_unfused():
    """cfg.socket.use_paged_kernel composes with chunked prefill: the
    fused decode pass over chunk-written pages yields the same tokens."""
    rng = np.random.default_rng(2)
    prompts = [rng.integers(0, 250, size=n).tolist() for n in (9, 23)]

    def run(fused):
        cfg = _smoke("socket")
        cfg = cfg.replace(socket=dataclasses.replace(
            cfg.socket, use_paged_kernel=fused))
        _, reqs, _ = _run(cfg, prompts, steps=5)
        return [r.generated for r in reqs]

    assert run(True) == run(False)


# ----------------------------------------------- beyond-bucket serving


def test_prompt_beyond_largest_bucket_is_served():
    """Chunked prefill bounds prompts by the block table, not the
    prefill-bucket zoo: a prompt past the largest bucket must serve end
    to end and match the static engine token-for-token, while the legacy
    engine rejects it."""
    from repro.launch.serve import run_serve
    from repro.serving.engine import ContinuousBatchingEngine

    cfg = _smoke("socket")
    cfg = cfg.replace(serving=cfg.serving.replace(prefill_buckets=(24, 32)))
    rng = np.random.default_rng(3)
    long = rng.integers(0, cfg.vocab_size, size=56).tolist()
    short = rng.integers(0, cfg.vocab_size, size=9).tolist()

    _, reqs, m = _run(cfg, [long, short], steps=8)
    assert all(r.state == FINISHED for r in reqs)
    assert m.prefill_chunks == 4 + 1        # ceil(56/16) + ceil(9/16)
    base = _smoke("socket")                 # static path has no buckets
    for r, p in zip(reqs, (long, short)):
        toks, _, _ = run_serve(base, 1, len(p), 7, seed=0,
                               prompt=np.asarray(p)[None])
        assert r.generated == np.asarray(toks)[0].tolist()

    # the legacy engine cannot even exist at this geometry: whole-prompt
    # bucketing requires the largest bucket to cover max_context
    with pytest.raises(AssertionError, match="largest prefill bucket"):
        ContinuousBatchingEngine(_with_chunk(cfg, 0),
                                 rng=jax.random.PRNGKey(0))


def test_one_chunk_per_decode_iteration():
    """The mixed step co-runs at most ONE prefill chunk with the decode
    batch (the bounded-stall contract): every chunk_trace iteration
    index is distinct, and chunks of one request are granted in cursor
    order."""
    cfg = _smoke("socket")
    rng = np.random.default_rng(4)
    prompts = [rng.integers(0, cfg.vocab_size, size=n).tolist()
               for n in (40, 33, 24)]
    engine, reqs, m = _run(cfg, prompts, steps=4)
    trace = engine.chunk_trace
    assert len(trace) == m.prefill_chunks == 3 + 3 + 2  # ceil(n/16) each
    iters = [it for it, _, _, _ in trace]
    assert len(set(iters)) == len(iters), "co-ran chunks in one iteration"
    for rid in {rid for _, rid, _, _ in trace}:
        starts = [s for _, r, s, _ in trace if r == rid]
        assert starts == sorted(starts)


# -------------------------------------------------- preemption resume


def _pressure_cfg(cfg, num_blocks):
    return cfg.replace(serving=cfg.serving.replace(
        num_blocks=num_blocks, max_batch=2))


@pytest.mark.parametrize("temperature", [0.0, 0.8])
@pytest.mark.parametrize("chunk", [16, 0])
def test_chunked_preemption_resume_token_exact(temperature, chunk):
    """Pool pressure with multi-chunk prompts: preempted requests must
    re-chunk from cursor 0 and reproduce the unpressured run exactly —
    greedy AND sampled (the per-request PRNG key is re-installed at
    re-admission and replay re-advances it step for step; ``chunk=0``
    extends the pre-existing preemption parity coverage to sampled
    decoding on the legacy whole-bucket path too)."""
    cfg = _with_chunk(_smoke("socket"), chunk)
    rng = np.random.default_rng(5)
    prompts = [rng.integers(0, cfg.vocab_size, size=24).tolist()
               for _ in range(2)]

    def serve(num_blocks):
        _, reqs, m = _run(_pressure_cfg(cfg, num_blocks), prompts,
                          steps=20, temperature=temperature, seed=7)
        return reqs, m

    hot, mh = serve(9)
    calm, mc = serve(48)
    assert mh.preemptions > 0 and mc.preemptions == 0
    for h, c in zip(hot, calm):
        assert h.state == FINISHED and len(h.generated) == 20
        assert h.generated == c.generated


def test_sampled_stream_is_composition_independent():
    """A request's sampled tokens are a pure function of (seed,
    submission index, token index): serving it alone or alongside
    co-tenants must draw the same tokens — keys live on the request and
    only advance on its own emissions."""
    cfg = _smoke("socket")
    rng = np.random.default_rng(6)
    first = rng.integers(0, cfg.vocab_size, size=12).tolist()
    others = [rng.integers(0, cfg.vocab_size, size=n).tolist()
              for n in (9, 17)]
    _, alone, _ = _run(cfg, [first], steps=6, temperature=0.9, seed=11)
    _, crowd, _ = _run(cfg, [first] + others, steps=6, temperature=0.9,
                       seed=11)
    assert alone[0].generated == crowd[0].generated


# --------------------------------------------------- scheduler units


def _chunked_sched(num_blocks=16, max_batch=2, chunk=16, bs=8):
    return Scheduler(BlockPool(num_blocks), max_batch=max_batch,
                     max_blocks_per_seq=8, block_size=bs,
                     prefill_chunk=chunk)


def test_scheduler_admits_on_first_chunk_blocks():
    """Chunked admission asks for the first chunk only: with 3 free
    blocks a 40-token prompt (5 prompt blocks over its lifetime) admits
    on its 2 chunk-0 blocks + headroom, where whole-prompt admission
    (5 + headroom) must refuse."""
    s = _chunked_sched(num_blocks=8)
    held = s.pool.alloc(4)                  # 3 of 7 usable blocks free
    r = Request(prompt=[1] * 40, max_new_tokens=4, arrival=0.0)
    s.submit(r)
    assert s.try_admit(0.0) is r and len(r.blocks) == 2
    assert r.prefill_pos == 0 and r in s.prefilling and s.has_work
    s.pool.free(held)

    legacy = Scheduler(BlockPool(8), max_batch=2, max_blocks_per_seq=8,
                       block_size=8)
    legacy.pool.alloc(4)
    legacy.submit(Request(prompt=[1] * 40, max_new_tokens=4, arrival=0.0))
    assert legacy.try_admit(0.0) is None


def test_scheduler_grant_chunk_grows_and_finalizes():
    s = _chunked_sched(num_blocks=16)
    r = Request(prompt=[1] * 40, max_new_tokens=4, arrival=0.0)
    s.submit(r)
    s.try_admit(0.0)
    got = []
    while True:
        ch = s.grant_chunk(r)
        got.append((ch.start, ch.tokens, ch.final))
        assert len(r.blocks) == -(-(ch.start + ch.tokens) // 8)
        s.advance_chunk(r, ch)
        if ch.final:
            break
    assert got == [(0, 16, False), (16, 16, False), (32, 8, True)]
    s.activate(r)
    assert r.state == "decode" and not s.prefilling


def test_scheduler_chunk_grant_waits_and_decode_growth_evicts_prefiller():
    """Chunk grants never evict decoders — on pool exhaustion the grant
    is withheld (request stays PREFILL) until blocks free up, which
    prevents the admit/evict ping-pong between a cheap first-chunk
    admission and the decoder it displaced.  Decode *growth* outranks
    the prefiller: ensure_decode_blocks may evict it mid-prefill, which
    resets the chunk cursor so resume re-chunks from zero."""
    s = _chunked_sched(num_blocks=6)        # 5 usable blocks
    d = Request(prompt=[2] * 16, max_new_tokens=8, arrival=0.0)
    s.submit(d)
    s.try_admit(0.0)
    ch = s.grant_chunk(d)                   # single final chunk: 2 blocks
    assert ch.final
    s.advance_chunk(d, ch)
    s.activate(d)
    p = Request(prompt=[3] * 32, max_new_tokens=8, arrival=0.1)
    s.submit(p)
    assert s.try_admit(1.0) is p            # chunk 0: 2 blocks (4 used)
    assert p in s.prefilling
    s.advance_chunk(p, s.grant_chunk(p))
    ch = s.grant_chunk(p)                   # needs 2 more, 1 free -> waits
    assert ch is None
    assert p.state == "prefill" and p.prefill_pos == 16
    assert d.state == "decode" and d.preemptions == 0

    # decoder frees its blocks (finished) -> the withheld grant proceeds
    s.finish(d, now=2.0)
    ch = s.grant_chunk(p)
    assert ch is not None and ch.final and len(p.blocks) == 4

    # decode growth evicting the mid-prefill request resets its cursor
    s.preempt(p)
    assert p.prefill_pos == 0 and p.state == "waiting"
    assert p not in s.prefilling and s.pool.num_used == 0


def test_engine_decode_growth_preempts_prefiller_token_exact(monkeypatch):
    """End-to-end **mid-prefill** preemption: two growing decoders evict
    the in-flight chunked prefill (the 40-token prompt is caught with 2
    of 3 chunks committed — pinned via a preemption spy), the engine
    drops its cursor, and the evicted request still finishes with the
    exact unpressured tokens."""
    cfg = _smoke("socket")
    rng = np.random.default_rng(10)
    prompts = [rng.integers(0, cfg.vocab_size, size=n).tolist()
               for n in (15, 23, 40)]
    seen = []
    orig = Scheduler.preempt

    def spy(self, req, cause="manual"):
        seen.append((req.state, req.prefill_pos))
        orig(self, req, cause=cause)

    monkeypatch.setattr(Scheduler, "preempt", spy)

    def serve(num_blocks):
        _, reqs, m = _run(cfg.replace(serving=cfg.serving.replace(
            num_blocks=num_blocks, max_batch=3)), prompts, steps=16)
        return reqs, m

    hot, mh = serve(13)
    # evicted with a strict subset of its chunks committed
    assert any(s == "prefill" and 0 < c < 40 for s, c in seen), seen
    seen.clear()
    calm, mc = serve(48)
    assert mh.preemptions > 0 and mc.preemptions == 0
    for h, c in zip(hot, calm):
        assert h.state == FINISHED and h.generated == c.generated


# ------------------------------------------------- warmup + metrics


def test_warmup_compiles_only_needed_shapes():
    """Chunked warmup needs exactly the mixed + decode steps (no bucket
    zoo); legacy warmup given the workload warms only the buckets those
    prompts hit."""
    from repro.serving.engine import ContinuousBatchingEngine

    cfg = _smoke("socket")
    eng = ContinuousBatchingEngine(cfg, rng=jax.random.PRNGKey(0))
    eng.warmup()
    assert eng._prefill_fns == {}           # no per-bucket compiles

    legacy = ContinuousBatchingEngine(_with_chunk(cfg, 0),
                                      rng=jax.random.PRNGKey(0))
    reqs = [Request(prompt=[1] * 9, max_new_tokens=2, arrival=0.0),
            Request(prompt=[1] * 30, max_new_tokens=2, arrival=0.0)]
    legacy.warmup(reqs)
    assert sorted(legacy._prefill_fns) == [24, 32]


def test_serve_metrics_report_stall_and_chunks():
    cfg = _smoke("socket")
    rng = np.random.default_rng(8)
    prompts = [rng.integers(0, cfg.vocab_size, size=24).tolist()
               for _ in range(2)]
    _, _, m = _run(cfg, prompts, steps=4)
    assert m.prefill_chunks == 4            # two 24-token prompts, C=16
    assert np.isfinite(m.intertoken_stall_s_max)
    assert np.isfinite(m.decode_iter_s_p99)
    assert m.intertoken_stall_s_max >= 0
    j = m.to_json()
    assert {"prefill_chunks", "intertoken_stall_s_max",
            "decode_iter_s_p99"} <= set(j)


# --------------------------------------------------- mamba chunk carry


def test_mamba_chunk_carry_is_bit_exact():
    """mamba_train(h0, conv0) segment chaining: running a sequence as
    two chunks (boundary on the ssm_chunk grid) must reproduce the
    whole-sequence output and final state bit-for-bit — the carried
    conv tail replaces the zero left-pad exactly."""
    from repro.models import mamba as mb
    from repro.models import param as pm

    cfg = get_config("mamba2-780m").smoke()
    rng = jax.random.PRNGKey(0)
    params = pm.unbox(mb.init_mamba(cfg, rng))
    s = 2 * cfg.ssm_chunk
    x = jax.random.normal(jax.random.fold_in(rng, 1), (2, s, cfg.d_model))

    y_ref, st_ref = mb.mamba_train(cfg, params, x, return_state=True)
    cut = cfg.ssm_chunk
    y1, st1 = mb.mamba_train(cfg, params, x[:, :cut], return_state=True)
    y2, st2 = mb.mamba_train(cfg, params, x[:, cut:], h0=st1["ssm"],
                             conv0=st1["conv"], return_state=True)
    np.testing.assert_array_equal(np.asarray(y_ref[:, :cut]),
                                  np.asarray(y1))
    np.testing.assert_array_equal(np.asarray(y_ref[:, cut:]),
                                  np.asarray(y2))
    np.testing.assert_array_equal(np.asarray(st_ref["ssm"]),
                                  np.asarray(st2["ssm"]))
    np.testing.assert_array_equal(np.asarray(st_ref["conv"]),
                                  np.asarray(st2["conv"]))
