"""Observability layer: event schema, metrics registry, tracer, Perfetto
exporter, scheduler/pool instrumentation, selection-quality probe — plus
the two engine-level contracts the layer must honor: tracing never
perturbs generation (token-bit-exact on vs off) and the disabled path
allocates zero tracing objects."""

import json
import math

import numpy as np
import pytest

from repro.serving import BlockPool, Request, Scheduler
from repro.serving.obs import events as ev
from repro.serving.obs.metrics import Histogram, Registry
from repro.serving.obs.perfetto import chrome_trace
from repro.serving.obs.tracing import Tracer

# ------------------------------------------------------------ strict JSON


def test_sanitize_replaces_nonfinite_floats():
    out = ev.sanitize({"a": float("nan"), "b": [1.5, float("inf")],
                       "c": {"d": -float("inf"), "e": "NaN"}})
    assert out == {"a": None, "b": [1.5, None], "c": {"d": None,
                                                      "e": "NaN"}}


def test_strict_dumps_never_emits_nan_tokens():
    s = ev.strict_dumps({"x": float("nan"), "y": 2.0})
    assert "NaN" not in s
    assert json.loads(s) == {"x": None, "y": 2.0}
    # round-trips through a compliant (strict) parser
    assert ev.strict_loads(s) == {"x": None, "y": 2.0}


def test_strict_loads_rejects_nan_tokens():
    for bad in ('{"x": NaN}', '{"x": Infinity}', '{"x": -Infinity}'):
        with pytest.raises(ValueError):
            ev.strict_loads(bad)


# ----------------------------------------------------------- event schema


def _step_event(**over):
    base = {"ev": "step", "ts": 0.5, "iter": 0, "kind": "decode",
            "occupancy": 2, "chunk_tokens": 0, "step_s": 0.01,
            "pool_free": 40, "pool_used": 7, "pool_high_water": 9,
            "waiting": 0, "prefilling": 0, "running": 2}
    base.update(over)
    return base


def test_validate_event_accepts_conforming_events():
    ev.validate_event(_step_event())
    ev.validate_event({"ev": "trace_start", "ts": 0.0,
                       "schema": ev.SCHEMA_VERSION})      # optionals absent
    ev.validate_event({"ev": "admit", "ts": 0.1, "rid": 3, "slot": 0,
                       "blocks": 2, "resume": False, "wait_s": 0.2})
    # a sanitized non-finite float field is None and still a valid float
    ev.validate_event({"ev": "first_token", "ts": 0.1, "rid": 3,
                       "ttft_s": None})


def test_validate_event_is_strict_both_ways():
    with pytest.raises(ValueError):                       # unknown type
        ev.validate_event({"ev": "nope", "ts": 0.0})
    with pytest.raises(ValueError):                       # missing ts
        ev.validate_event({"ev": "step"})
    with pytest.raises(ValueError):                       # None where str
        ev.validate_event(_step_event(kind=None))
    missing = _step_event()
    del missing["pool_high_water"]
    with pytest.raises(ValueError):
        ev.validate_event(missing)
    with pytest.raises(ValueError):                       # wrong type
        ev.validate_event(_step_event(iter="0"))
    with pytest.raises(ValueError):                       # bool is not int
        ev.validate_event(_step_event(iter=True))
    with pytest.raises(ValueError):                       # unknown field
        ev.validate_event(_step_event(extra=1))


def test_validate_jsonl_requires_version_handshake():
    start = ev.strict_dumps({"ev": "trace_start", "ts": 0.0,
                             "schema": ev.SCHEMA_VERSION})
    step = ev.strict_dumps(_step_event())
    events = ev.validate_jsonl([start, "", step])         # blank lines ok
    assert [e["ev"] for e in events] == ["trace_start", "step"]
    with pytest.raises(ValueError):                       # no handshake
        ev.validate_jsonl([step])
    with pytest.raises(ValueError):                       # empty trace
        ev.validate_jsonl([])
    future = ev.strict_dumps({"ev": "trace_start", "ts": 0.0,
                              "schema": ev.SCHEMA_VERSION + 1})
    with pytest.raises(ValueError):                       # unknown version
        ev.validate_jsonl([future])


def test_tracer_validates_at_emit_time_and_streams_jsonl(tmp_path):
    path = tmp_path / "sub" / "trace.jsonl"               # dir auto-created
    with Tracer(str(path)) as tr:
        tr.ensure_start()
        tr.ensure_start()                                 # idempotent
        run = tr.begin_run(requests=2)
        with pytest.raises(ValueError):                   # rejected AND
            tr.emit("step", iter=0)                       # not recorded
        tr.end_run(run, requests=2, generated=7, wall_s=float("nan"))
    events = ev.validate_jsonl(path.read_text().splitlines())
    assert [e["ev"] for e in events] == ["trace_start", "run_start",
                                         "run_end"]
    assert events == [e for e in events if e is not None]
    assert events[-1]["wall_s"] is None                   # sanitized
    assert events == ev.sanitize(events)                  # in-memory copy
    assert [e["ev"] for e in Tracer(None).events] == []   # memory-only ok


# -------------------------------------------------------------- histogram


def test_histogram_streaming_percentile_error_bound():
    rng = np.random.default_rng(0)
    samples = rng.lognormal(mean=-3.0, sigma=1.2, size=4000)
    h = Histogram(growth=1.05)
    for v in samples:
        h.record(v)
    assert h.count == len(samples)
    assert h.total == pytest.approx(samples.sum())
    assert h.vmin == samples.min() and h.vmax == samples.max()
    for q in (10, 50, 90, 99):
        exact = np.percentile(samples, q)
        est = h.percentile(q)
        # log-bucket midpoint answer: relative error <= growth - 1
        assert abs(est - exact) / exact <= h.growth - 1.0, (q, est, exact)


def test_histogram_exact_views_match_numpy():
    rng = np.random.default_rng(1)
    samples = rng.exponential(0.01, size=257)
    h = Histogram(exact=True)
    for v in samples:
        h.record(float(v))
    for q in (0, 50, 99, 100):
        assert h.percentile_exact(q) == float(np.percentile(samples, q))
    assert h.mean_exact() == float(np.mean(samples))
    assert h.max_exact() == max(float(v) for v in samples)
    with pytest.raises(AssertionError):                   # not retained
        Histogram().percentile_exact(50)


def test_histogram_empty_and_underflow():
    h = Histogram()
    assert math.isnan(h.percentile(50))
    assert h.to_json() == {"count": 0, "sum": 0.0, "min": None,
                           "max": None, "p50": None, "p99": None}
    h.record(0.0)                                         # underflow bucket
    h.record(-1.0)
    h.record(4.0)
    assert h.underflow == 2 and h.count == 3
    assert h.percentile(50) == -1.0                       # min(vmin, 0)
    assert h.percentile(100) == 4.0                       # clamped to vmax
    # strict-JSON-safe snapshot even with negative values recorded
    json.dumps(ev.sanitize(h.to_json()), allow_nan=False)


# --------------------------------------------------------------- registry


def test_registry_families_labels_and_value():
    reg = Registry()
    reg.counter("preempt", cause="lru").inc()
    reg.counter("preempt", cause="lru").inc(2)            # same instrument
    reg.counter("preempt", cause="stall").inc()
    assert reg.counter("preempt", cause="lru").value == 3
    assert reg.value("preempt") == 4                      # sums over labels
    assert reg.value("absent") == 0
    assert reg.get("preempt", cause="lru").value == 3
    assert reg.get("preempt", cause="nope") is None
    reg.gauge("free").set(17)
    assert reg.value("free") == 17
    with pytest.raises(ValueError):                       # kind clash
        reg.gauge("preempt", cause="oom")
    with pytest.raises(ValueError):                       # negative inc
        reg.counter("preempt", cause="lru").inc(-1)


def test_registry_prometheus_text_format():
    reg = Registry()
    reg.counter("serve_tokens_total").inc(5)
    reg.gauge("pool_blocks_free", pool="kv").set(3)
    h = reg.histogram("iter_s")
    for v in (0.001, 0.002, 0.004, 0.008):
        h.record(v)
    text = reg.prometheus_text()
    lines = text.splitlines()
    assert "# TYPE serve_tokens_total counter" in lines
    assert "serve_tokens_total 5" in lines
    assert '# TYPE pool_blocks_free gauge' in lines
    assert 'pool_blocks_free{pool="kv"} 3' in lines
    assert "# TYPE iter_s histogram" in lines
    assert 'iter_s_bucket{le="+Inf"} 4' in lines
    assert "iter_s_count 4" in lines
    assert any(line.startswith("iter_s_sum ") for line in lines)
    # cumulative bucket counts are monotone and end at count
    cums = [int(line.rsplit(" ", 1)[1]) for line in lines
            if line.startswith("iter_s_bucket")]
    assert cums == sorted(cums) and cums[-1] == 4


def test_registry_snapshot_is_strict_json():
    reg = Registry()
    reg.histogram("empty_series")                         # percentiles NaN
    reg.counter("n", kind="a").inc()
    snap = reg.snapshot()
    json.dumps(snap, allow_nan=False)                     # no NaN anywhere
    assert snap["empty_series"]["values"]["_"]["p99"] is None
    assert snap["n"]["values"]['{kind="a"}'] == 1


# --------------------------------------------- pool + scheduler telemetry


def test_block_pool_tracks_high_water():
    pool = BlockPool(num_blocks=8)
    assert pool.stats() == {"free": 7, "used": 0, "high_water": 0,
                            "shared": 0}
    a = pool.alloc(3)
    b = pool.alloc(2)
    pool.free(b)
    assert pool.stats() == {"free": 4, "used": 3, "high_water": 5,
                            "shared": 0}
    pool.free(a)
    assert pool.stats()["high_water"] == 5                # sticky
    assert pool.alloc(99) is None
    assert pool.stats()["high_water"] == 5                # failed alloc: no


def _obs_sched(num_blocks, *, max_batch=2, prefill_chunk=0):
    sched = Scheduler(BlockPool(num_blocks), max_batch=max_batch,
                      max_blocks_per_seq=8, block_size=8,
                      prefill_chunk=prefill_chunk)
    reg, tracer = Registry(), Tracer(None)
    tracer.ensure_start()
    sched.bind_obs(reg, tracer)
    return sched, reg, tracer


def _evs(tracer, kind):
    return [e for e in tracer.events if e["ev"] == kind]


def test_scheduler_emits_admission_wait_and_lifecycle_events():
    sched, reg, tracer = _obs_sched(16)
    sched.submit(Request(prompt=[1] * 8, max_new_tokens=4, arrival=0.5))
    req = sched.try_admit(now=2.5)                        # realtime clock
    assert req is not None
    (admit,) = _evs(tracer, "admit")
    assert admit["rid"] == req.rid and admit["resume"] is False
    assert admit["wait_s"] == pytest.approx(2.0)
    assert reg.histogram("admission_wait_s").count == 1
    assert reg.histogram("admission_wait_s").total == pytest.approx(2.0)
    sched.activate(req)
    sched.finish(req, now=3.0)
    assert reg.value("serve_requests_total") == 1
    (fin,) = _evs(tracer, "finish")
    assert fin["rid"] == req.rid and fin["preemptions"] == 0
    # offline clocks (now=inf) record no wait — it is unmeasurable
    sched.submit(Request(prompt=[1] * 8, max_new_tokens=4, arrival=0.0))
    req2 = sched.try_admit(now=float("inf"))
    assert req2 is not None
    assert reg.histogram("admission_wait_s").count == 1   # unchanged
    assert "wait_s" not in _evs(tracer, "admit")[-1]


def test_scheduler_counts_preemptions_by_cause():
    sched, reg, tracer = _obs_sched(16)
    sched.submit(Request(prompt=[1] * 8, max_new_tokens=4, arrival=0.0))
    req = sched.try_admit(now=0.0)
    sched.activate(req)
    sched.preempt(req)                                    # default cause
    assert reg.counter("serve_preemptions_total", cause="manual").value \
        == 1
    assert reg.value("serve_preemptions_total") == 1
    (pre,) = _evs(tracer, "preempt")
    assert pre["cause"] == "manual" and pre["state"] == "decode"
    assert pre["blocks_freed"] == 1
    assert _evs(tracer, "admit")[-1]["resume"] is False
    req2 = sched.try_admit(now=0.0)                       # resumes
    assert req2 is req
    assert _evs(tracer, "admit")[-1]["resume"] is True


def test_scheduler_counts_withheld_chunk_grants():
    # A decodes holding 1 block; B is mid-prefill needing a 2nd block for
    # its next chunk while the pool is (artificially) drained -> the grant
    # is withheld (counter + event), then proceeds once blocks free up.
    sched, reg, tracer = _obs_sched(5, prefill_chunk=8)
    a = Request(prompt=[1] * 8, max_new_tokens=1, arrival=0.0)
    b = Request(prompt=[2] * 16, max_new_tokens=8, arrival=0.0)
    sched.submit(a)
    sched.submit(b)
    sched.activate(sched.try_admit(now=0.0))              # a decodes
    assert sched.try_admit(now=0.0) is b                  # first chunk fits
    first = sched.grant_chunk(b)
    assert first is not None and not first.final
    sched.advance_chunk(b, first)
    hold = sched.pool.alloc(sched.pool.num_free)          # drain the pool
    assert sched.grant_chunk(b) is None                   # withheld
    assert b.state == "prefill"                           # NOT preempted
    assert reg.value("serve_chunks_withheld_total") == 1
    (wh,) = _evs(tracer, "chunk_withheld")
    assert wh["rid"] == b.rid and wh["free_blocks"] == 0
    sched.pool.free(hold)
    chunk = sched.grant_chunk(b)                          # now proceeds
    assert chunk is not None and chunk.final
    grants = _evs(tracer, "chunk_grant")
    assert [g["start"] for g in grants] == [0, 8]
    assert reg.value("serve_preemptions_total") == 0


# --------------------------------------------------------------- perfetto


def test_chrome_trace_spans_and_counters():
    tr = Tracer(None)
    tr.ensure_start()
    run = tr.begin_run(requests=1)
    tr.emit("submit", rid=0, prompt_tokens=16, max_new_tokens=4,
            arrival=0.0)
    tr.emit("admit", rid=0, slot=0, blocks=2, resume=False)
    tr.emit("compile", fn="mixed", seconds=0.25)
    tr.emit("first_token", rid=0, ttft_s=0.1)
    tr.emit("step", **{k: v for k, v in _step_event().items()
                       if k not in ("ev", "ts")})
    tr.emit("probe", iter=0, layer=1, requests=1, static_k=16,
            recall=0.75, budget_utilization=0.5, forced_share=0.9,
            selected_mean=8.0, budget_mean=16.0)
    tr.emit("finish", rid=0, generated=4, preemptions=0)
    tr.end_run(run, requests=1, generated=4, wall_s=0.5)
    trace = chrome_trace(tr.events)
    out = trace["traceEvents"]
    spans = {e["name"] for e in out if e["ph"] == "X"}
    assert {"queued", "prefill", "decode", "compile mixed"} <= spans
    counters = {e["name"] for e in out if e["ph"] == "C"}
    assert {"pool_blocks", "batch", "probe_recall_l1"} <= counters
    # phases partition the request's lifetime: queued ends where prefill
    # starts, prefill where decode starts
    req_spans = {e["name"]: e for e in out
                 if e["ph"] == "X" and e["pid"] == 1}
    assert req_spans["queued"]["ts"] + req_spans["queued"]["dur"] == \
        pytest.approx(req_spans["prefill"]["ts"])
    assert req_spans["prefill"]["ts"] + req_spans["prefill"]["dur"] == \
        pytest.approx(req_spans["decode"]["ts"])
    json.dumps(trace, allow_nan=False)                    # strict export


# ---------------------------------------------------------- engine-level
#
# One module-scoped workload served twice — traced+probed vs bare — feeds
# the parity, schema, metrics-equivalence and probe tests below without
# recompiling per test.


def _smoke_cfg():
    from repro.configs import get_config
    return get_config("stablelm-12b").smoke().replace(
        attention_backend="socket")


_PLENS = (8, 20, 24)
_MAX_NEW = 6


def _requests(cfg):
    rng = np.random.default_rng(7)
    return [Request(prompt=rng.integers(0, cfg.vocab_size, size=p).tolist(),
                    max_new_tokens=_MAX_NEW, arrival=0.0) for p in _PLENS]


@pytest.fixture(scope="module")
def served(tmp_path_factory):
    import jax
    from repro.serving.engine import ContinuousBatchingEngine
    from repro.serving.obs import Observability

    cfg = _smoke_cfg()
    path = tmp_path_factory.mktemp("obs") / "trace.jsonl"
    obs = Observability(str(path), probe_every=2)
    traced_engine = ContinuousBatchingEngine(cfg, rng=jax.random.PRNGKey(0),
                                             obs=obs)
    traced_reqs = _requests(cfg)
    traced_metrics = traced_engine.run(traced_reqs, realtime=False)
    obs.close()

    bare_engine = ContinuousBatchingEngine(cfg, rng=jax.random.PRNGKey(0))
    bare_reqs = _requests(cfg)
    bare_metrics = bare_engine.run(bare_reqs, realtime=False)
    return {"path": path, "obs": obs,
            "traced": (traced_engine, traced_reqs, traced_metrics),
            "bare": (bare_engine, bare_reqs, bare_metrics)}


def test_engine_trace_file_is_schema_valid(served):
    _, reqs, m = served["traced"]
    with open(served["path"]) as f:
        events = ev.validate_jsonl(f)
    assert events == served["obs"].tracer.events           # file == memory
    head = events[0]
    assert head["ev"] == "trace_start" and head["backend"] == "socket"
    assert head["arch"] == "stablelm-12b" and head["layers_paged"] > 0
    by_kind = {}
    for e in events:
        by_kind.setdefault(e["ev"], []).append(e)
    # every request has a full lifecycle
    for kind in ("submit", "admit", "first_token", "finish"):
        assert sorted(e["rid"] for e in by_kind[kind]) == \
            sorted(r.rid for r in reqs), kind
    # one step record per engine iteration, numbered densely
    assert [e["iter"] for e in by_kind["step"]] == \
        list(range(m.decode_iters))
    assert sum(e["kind"] == "mixed" for e in by_kind["step"]) == \
        m.prefill_chunks
    # chunk grants cover each prompt exactly once, in cursor order
    for r in reqs:
        grants = [e for e in by_kind["chunk_grant"] if e["rid"] == r.rid]
        assert sum(g["tokens"] for g in grants) == len(r.prompt)
        assert grants[-1]["final"] is True
    # unwarmed run: the first mixed/decode/probe dispatches are compiles
    assert {"mixed", "probe"} <= {e["fn"] for e in by_kind["compile"]}
    assert by_kind["run_end"][0]["generated"] == m.total_generated
    assert max(e["pool_high_water"] for e in by_kind["step"]) > 0


def test_tracing_is_token_bit_exact_vs_disabled(served):
    _, traced_reqs, tm = served["traced"]
    _, bare_reqs, bm = served["bare"]
    for t, b in zip(traced_reqs, bare_reqs):
        assert t.generated == b.generated
    assert (tm.total_generated, tm.decode_iters, tm.prefill_chunks) == \
        (bm.total_generated, bm.decode_iters, bm.prefill_chunks)


def test_disabled_path_constructs_no_tracing_objects(monkeypatch):
    """obs=None must never touch Tracer/SelectionProbe/Profiler — the
    hot loop's disabled path allocates zero tracing objects."""
    import jax
    from repro.serving.engine import ContinuousBatchingEngine
    from repro.serving.obs import probe as obs_probe
    from repro.serving.obs import profiling, tracing

    def boom(self, *a, **kw):
        raise AssertionError("tracing object constructed with obs=None")

    monkeypatch.setattr(tracing.Tracer, "__init__", boom)
    monkeypatch.setattr(obs_probe.SelectionProbe, "__init__", boom)
    monkeypatch.setattr(profiling.Profiler, "__init__", boom)
    cfg = _smoke_cfg()
    engine = ContinuousBatchingEngine(cfg, rng=jax.random.PRNGKey(0))
    reqs = _requests(cfg)[:1]
    engine.run(reqs, realtime=False)
    assert reqs[0].state == "finished"
    assert len(reqs[0].generated) == _MAX_NEW


def test_serve_metrics_are_byte_identical_to_direct_aggregation(served):
    """ServeMetrics now derives from the registry's exact histograms; it
    must equal the pre-registry direct aggregation over the per-request
    series, float-for-float."""
    engine, reqs, m = served["traced"]
    ttfts = [r.t_first_token - r.arrival for r in reqs]
    lats = [s for r in reqs for s in r.token_latencies]
    stalls = [b - a for r in reqs
              for a, b in zip(r.token_walls, r.token_walls[1:])]
    assert m.num_requests == len(reqs)
    assert m.total_generated == sum(len(r.generated) for r in reqs)
    assert m.ttft_s_mean == float(np.mean(ttfts))
    assert m.ttft_s_p99 == float(np.percentile(ttfts, 99))
    assert m.token_latency_s_p50 == float(np.percentile(lats, 50))
    assert m.token_latency_s_p99 == float(np.percentile(lats, 99))
    assert m.intertoken_stall_s_max == max(stalls)
    assert m.preemptions == sum(r.preemptions for r in reqs)
    reg = engine.registry
    assert reg.value("serve_tokens_total") == m.total_generated
    assert reg.value("serve_iters_total") == m.decode_iters
    assert reg.counter("serve_iters_total", kind="mixed").value == \
        m.prefill_chunks == reg.value("serve_chunks_total")
    iters = reg.histogram("serve_iter_s", exact=True)
    assert m.decode_iter_s_p99 == \
        float(np.percentile(iters.samples, 99))
    # end-of-run gauges: everything was returned to the pool
    assert reg.get("pool_blocks_used").value == 0
    assert reg.get("pool_blocks_high_water").value == \
        engine.pool.high_water > 0
    json.dumps(m.to_json(), allow_nan=False)
    assert reg.prometheus_text().startswith("# TYPE")


def test_serve_metrics_to_json_nulls_nonfinite():
    from repro.serving.engine import ServeMetrics

    m = ServeMetrics(
        num_requests=0, total_generated=0, wall_s=0.0,
        throughput_tok_s=float("nan"), ttft_s_mean=float("nan"),
        ttft_s_p99=float("nan"), token_latency_s_p50=float("nan"),
        token_latency_s_p99=float("inf"), preemptions=0, decode_iters=0,
        prefill_chunks=0, intertoken_stall_s_max=float("nan"),
        decode_iter_s_p99=float("nan"))
    out = m.to_json()
    assert out["throughput_tok_s"] is None
    assert out["token_latency_s_p99"] is None
    assert out["num_requests"] == 0 and out["wall_s"] == 0.0
    json.dumps(out, allow_nan=False)


def test_engine_probe_rows_sample_every_layer(served):
    engine, reqs, m = served["traced"]
    probe = served["obs"].probe
    assert probe.rows, "probe never fired"
    layers = {r["layer"] for r in probe.rows}
    n_layers = len(engine.cfg.layer_specs)
    assert layers == set(range(n_layers))                 # all socket layers
    iters = sorted({r["iter"] for r in probe.rows})
    assert all(i % probe.every == 0 for i in iters)
    for row in probe.rows:
        assert 0.0 <= row["recall"] <= 1.0
        assert 0.0 < row["budget_utilization"] <= 1.0
        assert 0.0 <= row["forced_share"] <= 1.0
        assert 0 < row["selected_mean"] <= row["budget_mean"] \
            <= row["static_k"]
    # probe events mirror the rows; registry streams recall
    probe_events = [e for e in served["obs"].tracer.events
                    if e["ev"] == "probe"]
    assert len(probe_events) == len(probe.rows)
    reg = engine.registry
    assert reg.histogram("probe_recall").count == len(probe.rows)
    summary = served["obs"].probe_summary()
    assert summary["rows"] == len(probe.rows)
    assert summary["probe_steps"] == len(iters)
    assert summary["recall"] == pytest.approx(
        np.mean([r["recall"] for r in probe.rows]), abs=1e-6)


def test_probe_recall_is_one_when_budget_covers_context():
    """With sparsity=1 the SOCKET budget equals the context length, so
    the selection must contain every valid position — the probe's recall
    against dense top-k is exactly 1 and the budget fully used.  Pins the
    probe's reference math against a case with a known answer."""
    import dataclasses

    import jax
    from repro.serving.engine import ContinuousBatchingEngine
    from repro.serving.obs import Observability

    cfg = _smoke_cfg()
    cfg = cfg.replace(socket=dataclasses.replace(
        cfg.socket, sparsity=1.0, min_k=8))
    obs = Observability(probe_every=1)
    engine = ContinuousBatchingEngine(cfg, rng=jax.random.PRNGKey(0),
                                      obs=obs)
    reqs = _requests(cfg)[:2]
    engine.run(reqs, realtime=False)
    assert obs.probe.rows
    for row in obs.probe.rows:
        assert row["recall"] == 1.0, row
        # budget == context length == realized selection, exactly
        assert row["selected_mean"] == row["budget_mean"], row
        assert row["budget_utilization"] == pytest.approx(
            row["selected_mean"] / row["static_k"], abs=1e-6), row


@pytest.mark.parametrize("backend", ["socket", "hard_lsh", "quest"])
def test_probe_selection_quality_parity_quantized(backend):
    """int8 pool pages must not change what the model *selects* or
    *emits*: socket/hard_lsh score against full-precision bits/vnorms,
    so the greedy generations and every selection-side probe statistic
    (budget_utilization / forced_share / selected_mean / budget_mean)
    are bit-identical to the bf16-pages run; quest recomputes its page
    bounds from the quantized round-trip, so its recall is only
    *bounded* against bf16.  Recall is never asserted exactly equal:
    the probe's dense reference recomputes attention mass from the
    cached (dequantized) K rows, so the reference moves with the
    storage dtype even when the selection does not.  (fp8's 3-bit
    mantissa perturbs attention outputs enough for greedy argmax to
    flip mid-trajectory, so trajectory-level parity is an int8-only
    contract; fp8 selection identity is pinned per-step by the
    kernel-harness BITWISE checks and at serving level by the bench
    quantized rows.)"""
    import jax
    from repro.serving.engine import ContinuousBatchingEngine
    from repro.serving.obs import Observability

    runs = {}
    for kvd in ("bf16", "int8"):
        cfg = _smoke_cfg().replace(attention_backend=backend)
        cfg = cfg.replace(serving=cfg.serving.replace(kv_dtype=kvd))
        obs = Observability(probe_every=2)
        engine = ContinuousBatchingEngine(cfg, rng=jax.random.PRNGKey(0),
                                          obs=obs)
        reqs = _requests(cfg)
        engine.run(reqs, realtime=False)
        assert obs.probe.rows, kvd
        runs[kvd] = {"summary": obs.probe_summary(),
                     "gens": [r.generated for r in reqs]}

    base, quant = runs["bf16"], runs["int8"]
    assert quant["gens"] == base["gens"]
    if backend in ("socket", "hard_lsh"):
        for stat in ("budget_utilization", "forced_share",
                     "selected_mean", "budget_mean"):
            assert quant["summary"][stat] == base["summary"][stat], stat
        tol = 2e-3
    else:
        tol = 2e-2
    assert abs(quant["summary"]["recall"]
               - base["summary"]["recall"]) <= tol
