"""Kernel differential tests, driven by ``kernel_harness``.

Every Pallas op (socket_score, flash_decode, flash_prefill, and the
fused paged_attention kernel) is pinned to its ``ref.py`` oracle through
one parametrized differential test; the bitwise-or-tolerance policy is
declared once per op in the registry below, not per test.  Property
tests (Hypothesis + fixed-seed) pin the fused kernel's *selected set*
exactly to the reference ``value_aware_topk`` semantics.

All tests run the kernels in interpret mode on CPU (identical code
paths lower to TPU) and carry the ``kernels`` marker so CI can split
them from the fast tier-1 job.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis_compat import given, settings, st
from kernel_harness import (BITWISE, KernelCase, KernelOp, ParityPolicy,
                            all_cases, run_differential)

from repro.core import hashing, socket
from repro.kernels.flash_decode import flash_decode, flash_decode_ref
from repro.kernels.flash_prefill import flash_prefill, flash_prefill_ref
from repro.kernels.paged_attention import (paged_hard_lsh_attend,
                                           paged_hard_lsh_attend_ref,
                                           paged_quest_attend,
                                           paged_quest_attend_ref,
                                           paged_ring_attend,
                                           paged_ring_attend_ref,
                                           paged_socket_attend,
                                           paged_socket_attend_ref)
from repro.kernels.socket_score import socket_score, socket_score_ref

pytestmark = pytest.mark.kernels


# --------------------------------------------------------------- builders

def _build_socket_score(case):
    p, l, n, g, bh, d, block_n, weighted = (
        case.kwargs[k] for k in
        ("p", "l", "n", "g", "bh", "d", "block_n", "weighted"))
    bits_fmt = case.kwargs.get("bits_fmt", "packed")
    rng = jax.random.PRNGKey(p * l + n + block_n)
    kk, kq, kw, kv = jax.random.split(rng, 4)
    w = hashing.make_hash_params(kw, d, p, l)
    keys = jax.random.normal(kk, (bh, n, d))
    q = jax.random.normal(kq, (bh, g, d))
    signs = hashing.hash_keys_signs(w, keys)
    if bits_fmt == "int8":
        # bits_storage="int8": ±1 plane bytes (BH, N, L*P) — the kernel
        # skips the unpack and the padding tables entirely
        bits = (signs.astype(jnp.int8) * 2 - 1).reshape(bh, n, l * p)
    else:
        bits = hashing.pack_signs(signs)
    u = socket.soft_hash_query(w, q)
    vnorm = (jax.random.uniform(kv, (bh, n)) + 0.5) if weighted else None
    out = socket_score(bits, u, vnorm, num_tables=l, num_planes=p, tau=0.4,
                       block_n=block_n)
    ref = socket_score_ref(bits, u, vnorm, num_tables=l, num_planes=p,
                           tau=0.4)
    return [("scores", out, ref)]


def _build_flash_decode(case):
    bh, g, k, hd, dtype, block_k = (
        case.kwargs[x] for x in ("bh", "g", "k", "hd", "dtype", "block_k"))
    rng = jax.random.PRNGKey(k + hd + block_k)
    k1, k2, k3, k4 = jax.random.split(rng, 4)
    q = jax.random.normal(k1, (bh, g, hd), dtype)
    kk = jax.random.normal(k2, (bh, k, hd), dtype)
    vv = jax.random.normal(k3, (bh, k, hd), dtype)
    mask = jax.random.bernoulli(k4, 0.7, (bh, k)).at[:, 0].set(True)
    out = flash_decode(q, kk, vv, mask, scale=1 / np.sqrt(hd),
                       block_k=block_k)
    ref = flash_decode_ref(q, kk, vv, mask, scale=1 / np.sqrt(hd))
    return [("attn", out, ref)]


def _build_flash_prefill(case):
    bh, s, hd, window, dtype = (
        case.kwargs[x] for x in ("bh", "s", "hd", "window", "dtype"))
    rng = jax.random.PRNGKey(s + hd + window)
    k1, k2, k3 = jax.random.split(rng, 3)
    q = jax.random.normal(k1, (bh, s, hd), dtype)
    k = jax.random.normal(k2, (bh, s, hd), dtype)
    v = jax.random.normal(k3, (bh, s, hd), dtype)
    out = flash_prefill(q, k, v, scale=1 / np.sqrt(hd), window=window,
                        block_q=128, block_k=128)
    ref = flash_prefill_ref(q, k, v, scale=1 / np.sqrt(hd), window=window)
    return [("attn", out, ref)]


def _paged_fixture(seed, b, kvh, g, gs, nb, bs, hd, p, l, sink, window,
                   lengths, dtype=jnp.float32, dup=False, tau=0.4,
                   kv_dtype=None):
    """Paged-pool inputs with shuffled physical blocks (block 0 = trash).

    ``kv_dtype`` "int8"/"fp8" stores the K/V pages quantized with
    per-row absmax scale pools riding along (passed to kernel and
    oracle as ``k_scale``/``v_scale`` — both dequantize the same
    values, so selection stays bitwise)."""
    from repro.models.backends import kvquant

    rng = np.random.default_rng(seed)
    n, d = nb * bs, 32
    w = hashing.make_hash_params(jax.random.PRNGKey(seed), d, p, l)
    keys = rng.normal(size=(b, kvh, n, d)).astype(np.float32)
    if dup:
        # exact duplicate key content -> exact score ties at selection
        keys[:, :, 1::2] = keys[:, :, 0::2]
    vals = rng.normal(size=(b, kvh, n, d)).astype(np.float32)
    bits = hashing.pack_signs(hashing.hash_keys_signs(w, jnp.asarray(keys)))
    vnorm = jnp.linalg.norm(jnp.asarray(vals), axis=-1).astype(jnp.bfloat16)
    kc = jnp.asarray(rng.normal(size=(b, kvh, n, hd)), dtype)
    vc = jnp.asarray(rng.normal(size=(b, kvh, n, hd)), dtype)
    q = jnp.asarray(rng.normal(size=(b, kvh, g, hd)), jnp.float32)
    u = socket.soft_hash_query(
        w, jnp.asarray(rng.normal(size=(b, kvh, gs, d)), jnp.float32))

    bt = 1 + rng.permutation(b * nb).reshape(b, nb).astype(np.int32)

    def pageify(leaf):
        arr = np.asarray(leaf)
        pool = np.zeros((1 + b * nb, kvh, bs) + arr.shape[3:], arr.dtype)
        for i in range(b):
            for j in range(nb):
                pool[bt[i, j]] = arr[i, :, j * bs:(j + 1) * bs]
        return jnp.asarray(pool)

    scfg = socket.SocketConfig(num_planes=p, num_tables=l, tau=tau,
                               sink_tokens=sink, window_tokens=window,
                               min_k=4, sparsity=4.0)
    kq = socket.topk_budget(scfg, n)
    length = jnp.asarray(lengths, jnp.int32)
    budget = socket.dynamic_topk_budget(scfg, length, kq)
    kw = dict(length=length, budget=budget, num_tables=l, num_planes=p,
              tau=tau, scale=1 / np.sqrt(hd), sink_tokens=sink,
              window_tokens=window)
    if kv_dtype is not None:
        kc, ks = kvquant.quantize(kc, kv_dtype)
        vc, vs = kvquant.quantize(vc, kv_dtype)
        kw.update(k_scale=pageify(ks), v_scale=pageify(vs))
    return (q, pageify(kc), pageify(vc), pageify(bits), pageify(vnorm), u,
            jnp.asarray(bt)), kw, kq


def _build_paged_attention(case):
    args, kw, kq = _paged_fixture(**case.kwargs)
    out, sel = paged_socket_attend(*args, with_selection=True, **kw)
    ref, sel_ref = paged_socket_attend_ref(*args, top_k=kq, **kw)
    return [("attn", out, ref), ("selection", sel, sel_ref, BITWISE)]


def _build_paged_hard_lsh(case):
    """Hard-collision variant: same pool fixture, the query-side soft
    hash replaced by its ±1 plane signs (``tau`` drops out)."""
    args, kw, kq = _paged_fixture(**case.kwargs)
    q, kp, vp, bits, vn, u, bt = args
    u_signs = jnp.where(u >= 0, 1.0, -1.0).astype(jnp.float32)
    kw = {k: v for k, v in kw.items() if k != "tau"}
    out, sel = paged_hard_lsh_attend(q, kp, vp, bits, vn, u_signs, bt,
                                     with_selection=True, **kw)
    ref, sel_ref = paged_hard_lsh_attend_ref(q, kp, vp, bits, vn, u_signs,
                                             bt, top_k=kq, **kw)
    return [("attn", out, ref), ("selection", sel, sel_ref, BITWISE)]


def _quest_fixture(seed, b, kvh, g, nb, bs, hd, ps, sink, window, lengths,
                   sparsity=4.0, min_pages=2, dtype=jnp.float32, dup=False,
                   kv_dtype=None):
    """Paged K/V pool plus per-page kmin/kmax stat pools (ppb = bs / ps
    stat rows per physical block), shuffled block table, ragged lengths.

    ``kv_dtype`` "int8"/"fp8" quantizes the K/V pages (per-row scales
    ride along) and — matching ``quest.stats_from_quantized`` — computes
    the kmin/kmax stats from the quantized *round trip*, so the page
    bounds stay sound for the keys the attend phase dequantizes."""
    from repro.baselines import quest as quest_mod
    from repro.models.backends import kvquant

    rng = np.random.default_rng(seed)
    n = nb * bs
    kc = rng.normal(size=(b, kvh, n, hd)).astype(np.float32)
    if dup:
        # identical page content -> exact page-score ties at selection
        pages = kc.reshape(b, kvh, n // ps, ps, hd)
        pages[:, :, 1::2] = pages[:, :, 0::2]
        kc = pages.reshape(b, kvh, n, hd)
    vc = rng.normal(size=(b, kvh, n, hd)).astype(np.float32)
    q = jnp.asarray(rng.normal(size=(b, kvh, g, hd)), jnp.float32)
    if kv_dtype is not None:
        kq_pages, ks = kvquant.quantize(jnp.asarray(kc), kv_dtype)
        vq_pages, vs = kvquant.quantize(jnp.asarray(vc), kv_dtype)
        stats_src = np.asarray(kvquant.dequantize(kq_pages, ks))
        k_store, v_store = kq_pages, vq_pages
    else:
        stats_src = kc
        k_store = jnp.asarray(kc, dtype)
        v_store = jnp.asarray(vc, dtype)
    # page stats stay f32 even for bf16/quantized K/V (selection is
    # compared bitwise; only the attention math runs in the case dtype)
    kmin = stats_src.reshape(b, kvh, n // ps, ps, hd).min(axis=3)
    kmax = stats_src.reshape(b, kvh, n // ps, ps, hd).max(axis=3)

    bt = 1 + rng.permutation(b * nb).reshape(b, nb).astype(np.int32)

    def pageify(arr, rows):
        arr = np.asarray(arr)
        pool = np.zeros((1 + b * nb, kvh, rows) + arr.shape[3:], arr.dtype)
        for i in range(b):
            for j in range(nb):
                pool[bt[i, j]] = arr[i, :, j * rows:(j + 1) * rows]
        return jnp.asarray(pool)

    qcfg = quest_mod.QuestConfig(page_size=ps, sparsity=sparsity,
                                 sink_tokens=sink, window_tokens=window,
                                 min_pages=min_pages)
    kp = quest_mod.page_budget(qcfg, n // ps, n)
    length = jnp.asarray(lengths, jnp.int32)
    scale = 1 / np.sqrt(hd)
    args = (q, pageify(k_store, bs), pageify(v_store, bs),
            pageify(kmin, bs // ps), pageify(kmax, bs // ps),
            jnp.asarray(bt))
    op_kw = dict(length=length, page_budget=kp, page_size=ps, scale=scale,
                 sink_tokens=sink, window_tokens=window)
    ref_kw = dict(length=length, page_size=ps, sparsity=sparsity,
                  min_pages=min_pages, scale=scale, sink_tokens=sink,
                  window_tokens=window)
    if kv_dtype is not None:
        scales = dict(k_scale=pageify(ks, bs), v_scale=pageify(vs, bs))
        op_kw.update(scales)
        ref_kw.update(scales)
    return args, op_kw, ref_kw


def _build_paged_quest(case):
    args, op_kw, ref_kw = _quest_fixture(**case.kwargs)
    out, sel = paged_quest_attend(*args, with_selection=True, **op_kw)
    ref, sel_ref = paged_quest_attend_ref(*args, **ref_kw)
    return [("attn", out, ref), ("selection", sel, sel_ref, BITWISE)]


def _ring_fixture(seed, b, kvh, g, rb, bs, hd, window, pos, softcap=0.0,
                  dtype=jnp.float32, kv_dtype=None):
    """Circular sliding-window pool: ``rb`` ring blocks per request with
    a shuffled ring slice of the block table and per-request positions
    (both sides read the same pool, so slots outside the window may hold
    arbitrary rows).  ``kv_dtype`` "int8"/"fp8" quantizes the ring pages
    with per-row scale pools alongside."""
    from repro.models.backends import kvquant

    rng = np.random.default_rng(seed)
    pool_k = jnp.asarray(rng.normal(size=(1 + b * rb, kvh, bs, hd)), dtype)
    pool_v = jnp.asarray(rng.normal(size=(1 + b * rb, kvh, bs, hd)), dtype)
    q = jnp.asarray(rng.normal(size=(b, kvh, g, hd)), jnp.float32)
    bt = jnp.asarray(1 + rng.permutation(b * rb).reshape(b, rb), jnp.int32)
    kw = dict(pos=jnp.asarray(pos, jnp.int32), window=window,
              softcap=softcap, scale=1 / np.sqrt(hd))
    if kv_dtype is not None:
        pool_k, ks = kvquant.quantize(pool_k, kv_dtype)
        pool_v, vs = kvquant.quantize(pool_v, kv_dtype)
        kw.update(k_scale=ks, v_scale=vs)
    return (q, pool_k, pool_v, bt), kw


def _build_paged_ring(case):
    args, kw = _ring_fixture(**case.kwargs)
    out = paged_ring_attend(*args, **kw)
    ref = paged_ring_attend_ref(*args, **kw)
    return [("attn", out, ref)]


# --------------------------------------------------- op registry + sweeps

def _c(label, **kw):
    return KernelCase.make(label, **kw)


def _score_case(label, p, l, n, g, bh, d=64, block_n=512, weighted=True):
    return _c(label, p=p, l=l, n=n, g=g, bh=bh, d=d, block_n=block_n,
              weighted=weighted)


def _fd_case(label, bh, g, k, hd, dtype=jnp.float32, block_k=256):
    return _c(label, bh=bh, g=g, k=k, hd=hd, dtype=dtype, block_k=block_k)


def _fp_case(label, bh, s, hd, window, dtype=jnp.float32):
    return _c(label, bh=bh, s=s, hd=hd, window=window, dtype=dtype)


def _pa_case(label, **kw):
    base = dict(seed=0, b=2, kvh=2, g=2, gs=2, nb=4, bs=8, hd=16, p=6,
                l=12, sink=4, window=4, lengths=(13, 29))
    base.update(kw)
    return _c(label, **base)


def _qu_case(label, **kw):
    base = dict(seed=0, b=2, kvh=2, g=2, nb=4, bs=8, hd=16, ps=4,
                sink=4, window=4, lengths=(13, 29))
    base.update(kw)
    return _c(label, **base)


def _ring_case(label, **kw):
    base = dict(seed=0, b=2, kvh=2, g=2, rb=3, bs=8, hd=16, window=10,
                pos=(13, 29), softcap=0.0)
    base.update(kw)
    return _c(label, **base)


KERNEL_OPS = (
    KernelOp(
        name="socket_score",
        build=_build_socket_score,
        policy=ParityPolicy(atol=1e-6, rtol=1e-4),
        cases=(
            _score_case("paper-point", 10, 60, 1024, 4, 2),
            _score_case("longbench", 8, 60, 512, 1, 2),
            _score_case("wide-planes", 16, 40, 2048, 8, 1),
            _score_case("unaligned-tables", 10, 37, 512, 2, 2),
            _score_case("smoke-scale", 6, 12, 256, 2, 3),
            _score_case("block-128", 10, 60, 1024, 2, 1, d=32,
                        block_n=128, weighted=False),
            _score_case("block-256", 10, 60, 1024, 2, 1, d=32,
                        block_n=256, weighted=False),
            _score_case("ragged-n", 10, 60, 384, 2, 1, block_n=512),
            # bits_storage="int8": the kernel streams ±1 plane bytes
            # (no unpack, no padding tables) — same scores as packed
            _c("int8-bits-paper-point", p=10, l=60, n=1024, g=4, bh=2,
               d=64, block_n=512, weighted=True, bits_fmt="int8"),
            _c("int8-bits-unaligned-tables", p=10, l=37, n=512, g=2,
               bh=2, d=64, block_n=512, weighted=True, bits_fmt="int8"),
            _c("int8-bits-block-128", p=6, l=12, n=256, g=2, bh=3,
               d=64, block_n=128, weighted=False, bits_fmt="int8"),
        ),
    ),
    KernelOp(
        name="flash_decode",
        build=_build_flash_decode,
        policy=ParityPolicy(atol=1e-5, bf16_atol=2e-2),
        cases=(
            _fd_case("f32-1024", 4, 4, 1024, 128),
            _fd_case("bf16-512", 2, 1, 512, 64, dtype=jnp.bfloat16),
            _fd_case("f32-768", 3, 8, 768, 128),
            _fd_case("single-short-block", 2, 2, 100, 32),
            _fd_case("bf16-640", 1, 6, 640, 256, dtype=jnp.bfloat16),
            # non-divisible context lengths: ragged tail blocks exercise
            # the pad-and-mask path across *multiple* K blocks
            _fd_case("tail-300@128", 2, 4, 300, 64, block_k=128),
            _fd_case("tail-100@64", 2, 2, 100, 32, block_k=64),
            _fd_case("bf16-tail-129@64", 1, 6, 129, 64,
                     dtype=jnp.bfloat16, block_k=64),
            _fd_case("len-lt-block", 1, 2, 7, 32, block_k=64),
            _fd_case("tail-515@256", 3, 1, 515, 128),
        ),
    ),
    KernelOp(
        name="flash_prefill",
        build=_build_flash_prefill,
        policy=ParityPolicy(atol=1e-5, bf16_atol=3e-2),
        cases=(
            _fp_case("s512", 2, 512, 64, 0),
            _fp_case("s1024", 2, 1024, 128, 0),
            _fp_case("window-128", 2, 512, 64, 128),
            _fp_case("bf16-window", 1, 256, 128, 64, dtype=jnp.bfloat16),
            _fp_case("non-pow2-seq", 1, 384, 32, 0),
        ),
    ),
    KernelOp(
        name="paged_attention",
        build=_build_paged_attention,
        # attention output under tolerance (logical-order vs rank-order
        # accumulation); the selected set is compared BITWISE per case
        policy=ParityPolicy(atol=2e-5, bf16_atol=2e-2),
        cases=(
            _pa_case("ragged"),
            _pa_case("pooled-short-ctx", seed=1, gs=1, nb=3, g=4,
                     lengths=(24, 5)),
            _pa_case("single-seq", seed=2, b=1, g=1, gs=1, nb=2, bs=16,
                     hd=32, p=8, l=10, sink=2, window=2, lengths=(32,)),
            _pa_case("exact-score-ties", seed=3, b=3, lengths=(1, 17, 32),
                     dup=True),
            _pa_case("unaligned-tables", seed=4, p=10, l=37,
                     lengths=(30, 31)),
            _pa_case("bf16-kv", seed=5, dtype=jnp.bfloat16,
                     lengths=(32, 9)),
            _pa_case("budget-floor", seed=6, sink=8, window=8,
                     lengths=(7, 3)),
            # quantized pool pages: per-row scales dequantized in-kernel;
            # selection stays bitwise (scoring never reads K/V)
            _pa_case("int8-ragged", seed=7, kv_dtype="int8"),
            _pa_case("fp8-ragged", seed=7, kv_dtype="fp8"),
            _pa_case("int8-ties-unaligned-tail", seed=8, b=3,
                     lengths=(1, 17, 30), dup=True, kv_dtype="int8"),
            _pa_case("fp8-unaligned-tables", seed=9, p=10, l=37,
                     lengths=(30, 31), kv_dtype="fp8"),
        ),
    ),
    KernelOp(
        name="paged_hard_lsh",
        build=_build_paged_hard_lsh,
        # same policy split as the socket kernel: float attention under
        # tolerance, the hard-collision selected set BITWISE (collision
        # counts are small integers, so zero-count ties are pervasive —
        # every case exercises the stable tie-break)
        policy=ParityPolicy(atol=2e-5, bf16_atol=2e-2),
        cases=(
            _pa_case("ragged"),
            _pa_case("pooled-hash", seed=1, gs=1, nb=3, g=4,
                     lengths=(24, 5)),
            _pa_case("collision-ties", seed=3, b=3, lengths=(1, 17, 32),
                     dup=True),
            _pa_case("unaligned-tables", seed=4, p=10, l=37,
                     lengths=(30, 31)),
            _pa_case("bf16-kv", seed=5, dtype=jnp.bfloat16,
                     lengths=(32, 9)),
            _pa_case("budget-floor", seed=6, sink=8, window=8,
                     lengths=(7, 3)),
            _pa_case("int8-collision-ties", seed=7, b=3,
                     lengths=(1, 17, 30), dup=True, kv_dtype="int8"),
            _pa_case("fp8-ragged", seed=8, kv_dtype="fp8"),
        ),
    ),
    KernelOp(
        name="paged_quest",
        build=_build_paged_quest,
        policy=ParityPolicy(atol=2e-5, bf16_atol=2e-2),
        cases=(
            _qu_case("ragged-ppb2"),
            _qu_case("page-per-block", seed=1, ps=8, lengths=(24, 5)),
            _qu_case("page-score-ties", seed=3, b=3,
                     lengths=(9, 17, 32), dup=True),
            _qu_case("single-seq", seed=2, b=1, g=1, nb=2, bs=16, ps=4,
                     hd=32, sink=2, window=2, lengths=(32,)),
            _qu_case("bf16-kv", seed=5, dtype=jnp.bfloat16,
                     lengths=(32, 9)),
            _qu_case("budget-floor", seed=6, sink=8, window=8,
                     lengths=(7, 3)),
            # quantized pages + stats from the quantized round trip
            # (quest.stats_from_quantized): selection stays bitwise
            # because kernel and oracle rank the same sound bounds
            _qu_case("int8-ragged", seed=7, kv_dtype="int8"),
            _qu_case("fp8-page-ties-tail", seed=8, b=3,
                     lengths=(9, 17, 30), dup=True, kv_dtype="fp8"),
        ),
    ),
    KernelOp(
        name="paged_ring",
        build=_build_paged_ring,
        policy=ParityPolicy(atol=2e-5, bf16_atol=2e-2),
        cases=(
            _ring_case("wrap-mix"),                    # filling + wrapped
            _ring_case("unwrapped", seed=1, pos=(5, 20)),
            _ring_case("softcap", seed=2, softcap=20.0, pos=(23, 24)),
            _ring_case("window-lt-cap", seed=3, window=6, pos=(100, 7)),
            _ring_case("bf16-kv", seed=4, dtype=jnp.bfloat16,
                       pos=(31, 64)),
            _ring_case("single-block-ring", seed=5, rb=1, window=8,
                       pos=(3, 50)),
            _ring_case("int8-wrap-mix", seed=6, kv_dtype="int8"),
            _ring_case("fp8-softcap-tail", seed=7, softcap=20.0,
                       pos=(23, 11), kv_dtype="fp8"),
        ),
    ),
)

_PAIRS, _IDS = all_cases(KERNEL_OPS)


@pytest.mark.parametrize("op,case", _PAIRS, ids=_IDS)
def test_kernel_matches_oracle(op, case):
    """Differential sweep: every kernel op == its ref.py oracle under the
    op's declared parity policy."""
    run_differential(op, case)


# ----------------------------------------------- fused selection property

def _selection_case(seed, b, nb, lengths, gs, sink, window, dup=False):
    """Kernel selection vs the reference value_aware_topk selection."""
    kvh, g = 2, 2
    args, kw, kq = _paged_fixture(
        seed=seed, b=b, kvh=kvh, g=g, gs=gs, nb=nb, bs=8, hd=16, p=6, l=12,
        sink=sink, window=window, lengths=lengths, dup=dup)
    _, sel = paged_socket_attend(*args, with_selection=True, **kw)
    _, sel_ref = paged_socket_attend_ref(*args, top_k=kq, **kw)
    return np.asarray(sel), np.asarray(sel_ref), kw


@pytest.mark.parametrize("seed,b,nb,lengths,gs,sink,window,dup", [
    (10, 2, 4, (13, 29), 2, 4, 4, False),     # ragged mid-context
    (11, 2, 3, (24, 5), 1, 4, 4, False),      # pooled + ctx < sink+window
    (12, 3, 4, (1, 17, 32), 2, 4, 4, True),   # exact score ties
    (13, 1, 2, (16,), 1, 8, 8, False),        # everything forced
    (14, 2, 4, (32, 31), 2, 0, 4, False),     # no sinks, window only
])
def test_fused_selection_matches_reference(seed, b, nb, lengths, gs, sink,
                                           window, dup):
    """The fused kernel's selected set must equal the reference
    ``socket_attend`` selection (value_aware_topk) exactly: sink+window
    forcing, ragged lengths, budget floors, holes in the block table."""
    sel, sel_ref, kw = _selection_case(seed, b, nb, lengths, gs, sink,
                                       window, dup)
    np.testing.assert_array_equal(sel, sel_ref)
    # sanity on the semantics themselves, not just parity
    for i, ln in enumerate(lengths):
        assert not sel[i, :, ln:].any(), "selected past the live length"
        forced = min(ln, sink + window)
        per_head = sel[i].sum(axis=-1)
        assert (per_head >= min(forced, int(kw["budget"][i]))).all(), \
            "budget floor must keep the forced sink+window set selected"


@given(data=st.data())
@settings(deadline=None)   # example count / derandomization come from the
def test_fused_selection_property(data):   # profile pinned in conftest.py
    """Hypothesis sweep of the same contract over random geometries:
    random block tables with holes (shuffled physical pages), ragged
    lengths including contexts shorter than sink+window (the PR-1
    budget-floor regression case)."""
    seed = data.draw(st.integers(0, 2**16), label="seed")
    b = data.draw(st.integers(1, 3), label="batch")
    nb = data.draw(st.integers(1, 4), label="blocks_per_seq")
    gs = data.draw(st.sampled_from([1, 2]), label="score_groups")
    sink = data.draw(st.integers(0, 8), label="sink")
    window = data.draw(st.integers(0, 8), label="window")
    n = nb * 8
    lengths = tuple(
        data.draw(st.integers(1, n), label=f"len{i}") for i in range(b))
    dup = data.draw(st.booleans(), label="duplicate_keys")
    sel, sel_ref, _ = _selection_case(seed, b, nb, lengths, gs, sink,
                                      window, dup)
    np.testing.assert_array_equal(sel, sel_ref)


# ------------------------------------------------------- special regressions

def test_flash_decode_all_masked_rows_are_finite():
    """A fully-masked (empty-selection) row must not produce NaNs."""
    q = jnp.ones((1, 2, 32))
    k = jnp.ones((1, 64, 32))
    v = jnp.ones((1, 64, 32))
    mask = jnp.zeros((1, 64), bool)
    out = flash_decode(q, k, v, mask, scale=0.1, block_k=64)
    assert bool(jnp.all(jnp.isfinite(out)))


def test_flash_decode_raw_launcher_pads_tail():
    """The raw Pallas launcher (not the padding ``ops.py`` wrapper) must
    accept ``K % block_k != 0`` and ``K < block_k`` — it used to raise a
    trace-time ValueError, so any caller bypassing the wrapper (or a
    wrapper regression) broke on ragged selection widths."""
    from repro.kernels.flash_decode.flash_decode import flash_decode_pallas
    for seed, (bh, g, k, hd, blk) in enumerate(
            ((2, 4, 70, 32, 32),      # tail block: 70 % 32 != 0
             (1, 2, 13, 32, 64))):    # whole buffer shorter than block_k
        k1, k2, k3, k4 = jax.random.split(jax.random.PRNGKey(seed), 4)
        q = jax.random.normal(k1, (bh, g, hd))
        kk = jax.random.normal(k2, (bh, k, hd))
        vv = jax.random.normal(k3, (bh, k, hd))
        mask = jax.random.bernoulli(k4, 0.7, (bh, k)).at[:, 0].set(True)
        out = flash_decode_pallas(q, kk, vv, mask, scale=1 / np.sqrt(hd),
                                  block_k=blk, interpret=True)
        ref = flash_decode_ref(q, kk, vv, mask, scale=1 / np.sqrt(hd))
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   atol=1e-5)


def test_paged_attention_rejects_bad_packing():
    """The fused kernel must fail fast when the packed width cannot be
    viewed as whole tables of P planes (hashing.num_words pads to make
    this divisible — a hand-rolled 3-word layout with P=7 cannot be)."""
    nb, bs, hd, p, l = 2, 8, 16, 7, 10       # 3 words = 96 bits, 96 % 7 != 0
    q = jnp.zeros((1, 1, 1, hd))
    kv = jnp.zeros((3, 1, bs, hd))
    bits = jnp.zeros((3, 1, bs, 3), jnp.uint32)
    vn = jnp.zeros((3, 1, bs))
    u = jnp.zeros((1, 1, 1, l, p))
    bt = jnp.asarray([[1, 2]], jnp.int32)
    with pytest.raises(ValueError, match="not a multiple"):
        paged_socket_attend(q, kv, kv, bits, vn, u, bt, length=9, budget=4,
                            num_tables=l, num_planes=p, tau=0.4, scale=0.25,
                            sink_tokens=2, window_tokens=2)


def test_flash_prefill_matches_model_attention(rng):
    """Kernel == the model's XLA attention path (same math)."""
    from repro.configs import get_config
    from repro.models import attention as attn
    from repro.models import param as pm

    cfg = get_config("minitron-8b").smoke()
    params = pm.unbox(attn.init_attention(cfg, rng))
    b, t = 2, 64
    x = jax.random.normal(jax.random.fold_in(rng, 1), (b, t, cfg.d_model))
    positions = jnp.broadcast_to(jnp.arange(t), (b, t))
    y_model = attn.attention_train(cfg, params, x, positions, "global")
    assert y_model.shape == (b, t, cfg.d_model)
    assert bool(jnp.all(jnp.isfinite(y_model)))
