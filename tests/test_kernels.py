"""Per-kernel allclose sweeps vs the pure-jnp ref.py oracles
(interpret=True on CPU; identical code paths lower to TPU)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import hashing, socket
from repro.kernels.flash_decode import flash_decode, flash_decode_ref
from repro.kernels.flash_prefill import flash_prefill, flash_prefill_ref
from repro.kernels.socket_score import socket_score, socket_score_ref


@pytest.mark.parametrize("p,l,n,g,bh", [
    (10, 60, 1024, 4, 2),   # paper operating point
    (8, 60, 512, 1, 2),     # LongBench setting
    (16, 40, 2048, 8, 1),   # wide-plane variant
    (10, 37, 512, 2, 2),    # unaligned table count
    (6, 12, 256, 2, 3),     # smoke-scale
])
def test_socket_score_kernel_sweep(p, l, n, g, bh):
    d = 64
    rng = jax.random.PRNGKey(p * l + n)
    kk, kq, kw, kv = jax.random.split(rng, 4)
    w = hashing.make_hash_params(kw, d, p, l)
    keys = jax.random.normal(kk, (bh, n, d))
    q = jax.random.normal(kq, (bh, g, d))
    bits = hashing.pack_signs(hashing.hash_keys_signs(w, keys))
    u = socket.soft_hash_query(w, q)
    vnorm = jax.random.uniform(kv, (bh, n)) + 0.5
    out = socket_score(bits, u, vnorm, num_tables=l, num_planes=p, tau=0.4)
    ref = socket_score_ref(bits, u, vnorm, num_tables=l, num_planes=p,
                           tau=0.4)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=1e-4,
                               atol=1e-6)


@pytest.mark.parametrize("block_n", [128, 256, 512])
def test_socket_score_block_shapes(block_n):
    p, l, n, g, bh, d = 10, 60, 1024, 2, 1, 32
    rng = jax.random.PRNGKey(block_n)
    w = hashing.make_hash_params(rng, d, p, l)
    keys = jax.random.normal(jax.random.fold_in(rng, 1), (bh, n, d))
    q = jax.random.normal(jax.random.fold_in(rng, 2), (bh, g, d))
    bits = hashing.pack_signs(hashing.hash_keys_signs(w, keys))
    u = socket.soft_hash_query(w, q)
    out = socket_score(bits, u, None, num_tables=l, num_planes=p, tau=0.4,
                       block_n=block_n)
    ref = socket_score_ref(bits, u, None, num_tables=l, num_planes=p,
                           tau=0.4)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=1e-4,
                               atol=1e-6)


@pytest.mark.parametrize("bh,g,k,hd,dtype", [
    (4, 4, 1024, 128, jnp.float32),
    (2, 1, 512, 64, jnp.bfloat16),
    (3, 8, 768, 128, jnp.float32),
    (2, 2, 100, 32, jnp.float32),    # K not a block multiple (padding)
    (1, 6, 640, 256, jnp.bfloat16),
])
def test_flash_decode_sweep(bh, g, k, hd, dtype):
    rng = jax.random.PRNGKey(k + hd)
    k1, k2, k3, k4 = jax.random.split(rng, 4)
    q = jax.random.normal(k1, (bh, g, hd), dtype)
    kk = jax.random.normal(k2, (bh, k, hd), dtype)
    vv = jax.random.normal(k3, (bh, k, hd), dtype)
    mask = jax.random.bernoulli(k4, 0.7, (bh, k)).at[:, 0].set(True)
    out = flash_decode(q, kk, vv, mask, scale=1 / np.sqrt(hd), block_k=256)
    ref = flash_decode_ref(q, kk, vv, mask, scale=1 / np.sqrt(hd))
    tol = 2e-2 if dtype == jnp.bfloat16 else 1e-5
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=tol)


def test_flash_decode_all_masked_rows_are_finite():
    """A fully-masked (empty-selection) row must not produce NaNs."""
    q = jnp.ones((1, 2, 32))
    k = jnp.ones((1, 64, 32))
    v = jnp.ones((1, 64, 32))
    mask = jnp.zeros((1, 64), bool)
    out = flash_decode(q, k, v, mask, scale=0.1, block_k=64)
    assert bool(jnp.all(jnp.isfinite(out)))


@pytest.mark.parametrize("bh,s,hd,window,dtype", [
    (2, 512, 64, 0, jnp.float32),
    (2, 1024, 128, 0, jnp.float32),
    (2, 512, 64, 128, jnp.float32),      # sliding window
    (1, 256, 128, 64, jnp.bfloat16),
    (1, 384, 32, 0, jnp.float32),        # non-pow2 seq
])
def test_flash_prefill_sweep(bh, s, hd, window, dtype):
    rng = jax.random.PRNGKey(s + hd + window)
    k1, k2, k3 = jax.random.split(rng, 3)
    q = jax.random.normal(k1, (bh, s, hd), dtype)
    k = jax.random.normal(k2, (bh, s, hd), dtype)
    v = jax.random.normal(k3, (bh, s, hd), dtype)
    out = flash_prefill(q, k, v, scale=1 / np.sqrt(hd), window=window,
                        block_q=128, block_k=128)
    ref = flash_prefill_ref(q, k, v, scale=1 / np.sqrt(hd), window=window)
    tol = 3e-2 if dtype == jnp.bfloat16 else 1e-5
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=tol)


def test_flash_prefill_matches_model_attention(rng):
    """Kernel == the model's XLA attention path (same math)."""
    from repro.configs import get_config
    from repro.models import attention as attn
    from repro.models import param as pm

    cfg = get_config("minitron-8b").smoke()
    params = pm.unbox(attn.init_attention(cfg, rng))
    b, t = 2, 64
    x = jax.random.normal(jax.random.fold_in(rng, 1), (b, t, cfg.d_model))
    positions = jnp.broadcast_to(jnp.arange(t), (b, t))
    y_model = attn.attention_train(cfg, params, x, positions, "global")
    assert y_model.shape == (b, t, cfg.d_model)
    assert bool(jnp.all(jnp.isfinite(y_model)))
