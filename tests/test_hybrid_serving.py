"""Per-layer heterogeneous cache plans on the continuous engine:
gemma3-pattern (5:1 local:global), jamba-pattern (attn:mamba hybrid) and
pure-SSM models must serve with exact token parity vs the static path,
bounded sliding-window block demand, token-exact preemption resume of
SSM state, and pool contents that are a pure function of the live
requests (scrub-on-reuse)."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.configs.base import LayerSpec
from repro.serving import FINISHED, Request


def _gemma3_like(backend="socket"):
    """gemma3 smoke pattern (5 local + 1 global, local remainder), one
    group to keep the CPU parity runs fast."""
    return get_config("gemma3-27b").smoke().replace(
        num_groups=1, attention_backend=backend)


def _jamba_like(backend="socket"):
    """jamba smoke pattern (1 attn : 7 mamba, MoE every other layer),
    dropless MoE so static-vs-continuous comparisons are exact (token
    dropping depends on batch composition)."""
    cfg = get_config("jamba-v0.1-52b").smoke().replace(
        num_groups=1, attention_backend=backend)
    return cfg.replace(capacity_factor=float(cfg.num_experts))


def _local_only():
    """Sliding-window-only stack: block demand must be ring-bounded."""
    local = LayerSpec(kind="attn", attn_type="local", mlp="dense")
    return get_config("gemma3-27b").smoke().replace(
        pattern=(local, local), num_groups=1, remainder=())


def _run_engine(cfg, prompts, steps, rng_seed=0, engine=None, **kw):
    from repro.serving.engine import ContinuousBatchingEngine
    if engine is None:
        engine = ContinuousBatchingEngine(
            cfg, rng=jax.random.PRNGKey(rng_seed), **kw)
    reqs = [Request(prompt=list(p), max_new_tokens=steps, arrival=0.0)
            for p in prompts]
    metrics = engine.run(reqs, realtime=False)
    return engine, reqs, metrics


# ------------------------------------------------------ cache-plan shapes


def test_cache_plan_derivation():
    cfg = _gemma3_like()
    kinds = [p.kind for p in cfg.cache_plan()]
    assert kinds == ["ring"] * 5 + ["paged"] + ["ring"]
    rb, rows = cfg.ring_geometry()
    bs = cfg.serving.block_size
    assert rb <= -(-cfg.sliding_window // bs) + 1   # the acceptance bound
    assert rows == rb * bs and rows >= min(
        cfg.sliding_window, cfg.serving.max_context)

    jam = _jamba_like()
    kinds = [p.kind for p in jam.cache_plan()]
    assert kinds.count("paged") == 1 and kinds.count("state") == 7

    assert all(p.kind == "state" and p.ring_blocks == 0
               for p in get_config("mamba2-780m").smoke().cache_plan())


def test_layer_cache_spec_resolution():
    from repro.models import backends as bk

    cfg = _gemma3_like()
    spec_g = bk.layer_cache_spec(cfg, cfg.pattern[5])
    assert spec_g.kind == "paged" and {"k", "v", "bits", "vnorm"} <= set(
        spec_g.leaves)
    spec_l = bk.layer_cache_spec(cfg, cfg.pattern[0])
    assert spec_l.kind == "ring" and set(spec_l.leaves) == {"k", "v"}
    assert spec_l.ring_blocks == cfg.ring_geometry()[0]
    spec_s = bk.layer_cache_spec(_jamba_like(), _jamba_like().pattern[0])
    assert spec_s.kind == "state" and spec_s.leaves == {}


def test_pool_layout_per_kind():
    """Pool leaves follow the plan: ring layers get full block_size pages
    (no window truncation), mamba layers one row per decode slot."""
    from repro.serving import paged

    cfg = _jamba_like()
    sv = cfg.serving
    pages = paged.init_paged_caches(cfg, sv)
    g = pages["groups"]
    # pattern slot 4 is the attention layer; others are mamba
    assert g["slot_4"]["k"].shape[1:] == (
        sv.num_blocks, cfg.num_kv_heads, sv.block_size, cfg.head_dim)
    assert g["slot_0"]["ssm"].shape[1] == sv.max_batch
    assert g["slot_0"]["conv"].shape[1] == sv.max_batch

    cfg_g = _gemma3_like()
    pages = paged.init_paged_caches(cfg_g, cfg_g.serving)
    # local layers' pages are block_size rows even though window > bs
    assert pages["groups"]["slot_0"]["k"].shape[3] == cfg_g.serving.block_size
    assert set(pages["groups"]["slot_0"]) == {"k", "v"}


# ------------------------------------------------------------ token parity


@pytest.mark.parametrize("make_cfg,backend", [
    (_gemma3_like, "socket"), (_gemma3_like, "dense"),
    (_jamba_like, "socket"), (_jamba_like, "dense"),
])
def test_hybrid_continuous_matches_static(make_cfg, backend):
    """Mixed prompt lengths through the heterogeneous paged engine must
    reproduce each request served alone by the static engine
    token-for-token — paged-native (socket) and gather/scatter fallback
    (dense) paths both."""
    from repro.launch.serve import run_serve

    cfg = make_cfg(backend)
    steps = 6
    rng = np.random.default_rng(1)
    prompts = [rng.integers(0, cfg.vocab_size, size=p) for p in (8, 24)]

    refs = []
    for pr in prompts:
        toks, _, _ = run_serve(cfg, 1, len(pr), steps - 1, seed=0,
                               prompt=pr[None])
        refs.append(np.asarray(toks)[0].tolist())

    _, reqs, _ = _run_engine(cfg, prompts, steps)
    for r, ref in zip(reqs, refs):
        assert r.state == FINISHED
        assert r.generated == ref, (r.generated, ref)


def test_mamba_only_continuous_matches_static_with_zero_blocks():
    """Pure-SSM serving: exact parity AND zero pool blocks ever
    consumed (admission is slot-gated only)."""
    from repro.launch.serve import run_serve

    cfg = get_config("mamba2-780m").smoke()
    steps = 6
    rng = np.random.default_rng(2)
    prompts = [rng.integers(0, cfg.vocab_size, size=p) for p in (8, 24)]
    refs = []
    for pr in prompts:
        toks, _, _ = run_serve(cfg, 1, len(pr), steps - 1, seed=0,
                               prompt=pr[None])
        refs.append(np.asarray(toks)[0].tolist())
    engine, reqs, _ = _run_engine(cfg, prompts, steps)
    for r, ref in zip(reqs, refs):
        assert r.generated == ref, (r.generated, ref)
        assert r.blocks == []
    assert engine.pool.num_used == 0
    assert engine.pool.num_free == cfg.serving.num_blocks - 1


# ------------------------------------------------- bounded window demand


def test_window_layers_never_exceed_ring_block_bound():
    """A sliding-window-only model generating far past its window must
    finish from a pool sized at the ring bound — per-slot demand never
    exceeds ceil(window/block_size)+1 blocks (zero preemptions proves
    no slot ever asked for more)."""
    cfg = _local_only()
    rb, _ = cfg.ring_geometry()
    bs = cfg.serving.block_size
    assert rb <= -(-cfg.sliding_window // bs) + 1
    # 2 slots, pool of exactly 2*rb usable blocks; context grows to
    # 8 + 40 = 48 tokens = 6 linear blocks/request (12 > pool) — only
    # ring-bounded accounting can serve this without preemption.
    cfg = cfg.replace(serving=cfg.serving.replace(
        num_blocks=2 * rb + 1, max_batch=2, max_blocks_per_seq=8))
    rng = np.random.default_rng(3)
    prompts = [rng.integers(0, cfg.vocab_size, size=8) for _ in range(2)]
    engine, reqs, metrics = _run_engine(cfg, prompts, steps=40)
    assert metrics.preemptions == 0
    for r in reqs:
        assert r.state == FINISHED and len(r.generated) == 40
    assert engine.pool.num_used == 0


def test_ring_parity_across_window_wrap():
    """Local-only static-vs-continuous parity with generation wrapping
    the ring several times (ring recycling must shadow exactly the
    static ring buffer)."""
    from repro.launch.serve import run_serve

    cfg = _local_only()
    steps = 40                                 # wraps the 32-token window
    rng = np.random.default_rng(4)
    prompt = rng.integers(0, cfg.vocab_size, size=8)
    toks, _, _ = run_serve(cfg, 1, 8, steps - 1, seed=0,
                           prompt=prompt[None])
    ref = np.asarray(toks)[0].tolist()
    _, reqs, _ = _run_engine(cfg, [prompt], steps)
    assert reqs[0].generated == ref


# -------------------------------------------------- preemption + scrubbing


def test_mamba_preemption_resume_is_token_exact():
    """Pool pressure on a jamba-like hybrid forces recompute-preemption;
    resume must reproduce the SSM state bit-exactly (re-prefill of the
    original prompt + decode replay), giving the same tokens as an
    unpressured pool."""
    cfg = _jamba_like()
    rng = np.random.default_rng(5)
    prompts = [rng.integers(0, cfg.vocab_size, size=16).tolist()
               for _ in range(2)]

    def serve(num_blocks):
        eng, reqs, metrics = _run_engine(
            cfg.replace(serving=cfg.serving.replace(
                num_blocks=num_blocks, max_batch=2)),
            prompts, steps=24)
        return reqs, metrics

    pressured, m = serve(num_blocks=9)
    calm, mc = serve(num_blocks=48)
    assert m.preemptions > 0 and mc.preemptions == 0
    for p, c in zip(pressured, calm):
        assert len(p.generated) == 24
        assert p.generated == c.generated


@pytest.mark.parametrize("make_cfg", [_gemma3_like, _jamba_like])
def test_pool_history_independence(make_cfg):
    """Scrub-on-reuse: outputs must not depend on what previous owners
    left in recycled pool blocks or slot state.  (a) poison every
    ring/state leaf with large finite garbage before serving; (b) serve a
    second batch on a warm engine whose freed blocks get recycled
    (LIFO) — both must match a fresh zero-pool engine bit-for-bit."""
    from repro.serving.engine import ContinuousBatchingEngine

    cfg = make_cfg()
    rng = np.random.default_rng(6)
    prompts_a = [rng.integers(0, cfg.vocab_size, size=12) for _ in range(2)]
    prompts_b = [rng.integers(0, cfg.vocab_size, size=20) for _ in range(2)]

    def fresh(prompts):
        _, reqs, _ = _run_engine(cfg, prompts, steps=5)
        return [r.generated for r in reqs]

    want_a, want_b = fresh(prompts_a), fresh(prompts_b)

    # (a) poisoned pool: ring + state leaves filled with garbage
    eng = ContinuousBatchingEngine(cfg, rng=jax.random.PRNGKey(0))
    from repro.models import backends as bk

    def poison(tree, specs):
        for i, spec in enumerate(specs):
            if bk.layer_cache_handler(cfg, spec).kind == "paged":
                continue
            tree[f"slot_{i}"] = {
                name: jnp.full_like(leaf, 1e4)
                for name, leaf in tree[f"slot_{i}"].items()}
    poison(eng.pages["groups"], cfg.pattern)
    poison(eng.pages["remainder"], cfg.remainder)
    _, reqs, _ = _run_engine(cfg, prompts_a, steps=5, engine=eng)
    assert [r.generated for r in reqs] == want_a

    # (b) warm engine: batch B reuses blocks/slots freed by batch A
    eng2, _, _ = _run_engine(cfg, prompts_a, steps=5)
    assert eng2.pool.num_used == 0
    _, reqs_b, _ = _run_engine(cfg, prompts_b, steps=5, engine=eng2)
    assert [r.generated for r in reqs_b] == want_b


# -------------------------------------------------------- gather hygiene


def test_hybrid_paged_engine_gather_trace_is_bounded():
    """Under a hybrid config the paged engine must still never
    materialize full K/V views: global layers read only metadata leaves
    plus O(top_k) rows, ring layers only their window-bounded ring view,
    state layers nothing at all."""
    from repro.core import socket as sk
    from repro.models import backends as bk

    cfg = _gemma3_like("socket")
    rng = np.random.default_rng(7)
    prompts = [rng.integers(0, cfg.vocab_size, size=12) for _ in range(2)]
    bk.gather_trace_reset()
    _run_engine(cfg, prompts, steps=4)
    trace = bk.gather_trace()
    assert trace, "paged path not exercised"
    full_leaves = {name for kind, name, _ in trace if kind == "leaf"}
    assert full_leaves <= {"bits", "vnorm"}, full_leaves
    kq = sk.topk_budget(bk.socket_config_of(cfg), cfg.serving.max_context)
    ring_rows = cfg.ring_geometry()[1]
    saw_ring = False
    for kind, name, shape in trace:
        if kind == "rows":
            assert name in ("k", "v") and shape[-2] == kq, (name, shape)
        elif kind == "ring":
            saw_ring = True
            assert name in ("k", "v") and shape[2] == ring_rows, (
                name, shape)
    assert saw_ring, "ring layers never decoded through the ring view"


def test_hybrid_footprint_accounting():
    """gather_footprint: window layers report bounded bytes (independent
    of max_context), mamba layers ~0 gathered."""
    from repro.serving.paged import gather_footprint

    cfg = _gemma3_like("socket")
    fp = gather_footprint(cfg)
    assert fp["num_ring_layers"] == 6 and fp["num_paged_layers"] == 1
    rb, rows = cfg.ring_geometry()
    sv = cfg.serving
    per_layer = fp["window_bytes_per_step"] // fp["num_ring_layers"]
    itemsize = jnp.dtype(cfg.compute_dtype).itemsize
    assert per_layer == 2 * sv.max_batch * cfg.num_kv_heads * rows * \
        cfg.head_dim * itemsize
    assert fp["window_bytes_per_step"] < fp["full_view_bytes_per_step"]
    assert fp["paged_bytes_per_step"] > 0

    jam = gather_footprint(_jamba_like("socket"))
    assert jam["num_state_layers"] == 7
    assert jam["state_bytes_per_step"] > 0       # informational, O(1)

    mam = gather_footprint(get_config("mamba2-780m").smoke())
    assert mam["paged_bytes_per_step"] == 0      # nothing gathered at all
    assert mam["full_view_bytes_per_step"] == 0
    assert mam["num_state_layers"] > 0


def test_bucket_padding_excluded_from_mamba_state():
    """mamba_train(last_index=...) must return the state at last_index:
    bit-for-bit independent of the padding *content* (pad rows are exact
    identity steps — the recompute-resume guarantee), and equal to the
    unpadded run up to chunking-order float reassociation."""
    from repro.models import mamba as mb
    from repro.models import param as pm

    cfg = get_config("mamba2-780m").smoke()
    rng = jax.random.PRNGKey(0)
    params = pm.unbox(mb.init_mamba(cfg, rng))
    x = jax.random.normal(jax.random.fold_in(rng, 1), (2, 24, cfg.d_model))
    li = jnp.asarray([9, 17], jnp.int32)
    _, padded = mb.mamba_train(cfg, params, x, return_state=True,
                               last_index=li)

    # scribble over every position past last_index: state must not move
    mask = jnp.arange(24)[None, :, None] <= li[:, None, None]
    x_garbled = jnp.where(mask, x, 1e3 * jax.random.normal(
        jax.random.fold_in(rng, 2), x.shape))
    _, garbled = mb.mamba_train(cfg, params, x_garbled, return_state=True,
                                last_index=li)
    np.testing.assert_array_equal(np.asarray(padded["ssm"]),
                                  np.asarray(garbled["ssm"]))
    np.testing.assert_array_equal(np.asarray(padded["conv"]),
                                  np.asarray(garbled["conv"]))

    # and it is the state at last_index (unpadded reference)
    for b, n in enumerate([10, 18]):
        _, exact = mb.mamba_train(cfg, params, x[b:b + 1, :n],
                                  return_state=True)
        np.testing.assert_allclose(np.asarray(padded["ssm"][b]),
                                   np.asarray(exact["ssm"][0]), atol=1e-5)
        np.testing.assert_allclose(
            np.asarray(padded["conv"][b]).astype(np.float32),
            np.asarray(exact["conv"][0]).astype(np.float32), atol=1e-5)
