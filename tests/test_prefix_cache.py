"""Prefix cache subsystem: refcounted pool units, radix-tree
insert/match/split/evict units, facade policy (final-token cap,
page-aligned matches for page-granular plans, live-sharer pinning),
scheduler cache-eviction tier, and the engine-level guarantees — hit-vs-
cold token parity per backend, CoW invariant (shared pages bitwise
frozen), tail-page CoW with scrub (poisoned pool), preemption with
shared pages, hybrid fallback, and cache events in the trace."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.serving import FINISHED, BlockPool, Request, Scheduler
from repro.serving.prefix_cache import PrefixCache, RadixIndex
from repro.serving.prefix_cache.workloads import (chatbot_prompts,
                                                  rag_prompts)


def _smoke(backend="socket"):
    return get_config("stablelm-12b").smoke().replace(
        attention_backend=backend)


def _with_cache(cfg, on=True, **sv):
    return cfg.replace(serving=cfg.serving.replace(prefix_cache=on, **sv))


def _run(cfg, prompts, steps, engine=None, seed=0):
    from repro.serving.engine import ContinuousBatchingEngine
    if engine is None:
        engine = ContinuousBatchingEngine(cfg, rng=jax.random.PRNGKey(0),
                                          sample_seed=seed)
    reqs = [Request(prompt=list(p), max_new_tokens=steps, arrival=0.0)
            for p in prompts]
    metrics = engine.run(reqs, realtime=False)
    return engine, reqs, metrics


def _shared_prefix_prompts(rng, share=17, uniques=(7, 7, 11), vocab=256):
    base = rng.integers(0, vocab, size=share).tolist()
    return [base + rng.integers(0, vocab, size=u).tolist()
            for u in uniques]


# ------------------------------------------------------------ pool units


def test_pool_refcount_lifecycle():
    pool = BlockPool(6)
    (b,) = pool.alloc(1)
    assert pool.refcount(b) == 1 and not pool.is_shared(b)
    pool.ref(b)
    assert pool.refcount(b) == 2 and pool.is_shared(b)
    assert pool.stats()["shared"] == 1
    free_before = pool.num_free
    pool.free([b])                       # deref: still held by one owner
    assert pool.refcount(b) == 1 and pool.num_free == free_before
    pool.free([b])                       # last holder: back on free list
    assert pool.refcount(b) == 0 and pool.num_free == free_before + 1
    with pytest.raises(ValueError, match="double free"):
        pool.free([b])
    with pytest.raises(ValueError, match="unallocated"):
        pool.ref(b)
    with pytest.raises(ValueError, match="trash"):
        pool.ref(0)


# ----------------------------------------------------------- radix units


def _toks(rng, n):
    return rng.integers(0, 256, size=n).tolist()


def test_radix_insert_match_roundtrip():
    idx = RadixIndex(4)
    rng = np.random.default_rng(0)
    t = _toks(rng, 11)                   # 2 full pages + 3 spare tokens
    assert idx.insert(t, [5, 6]) == [5, 6]
    blocks, full, tail = idx.match(t)
    assert (blocks, full, tail) == ([5, 6], 2, None)
    # re-inserting the same pages under different blocks adopts nothing —
    # existing physical pages win
    assert idx.insert(t, [7, 8]) == []
    assert idx.match(t)[0] == [5, 6]
    assert idx.num_blocks == 2
    # an unrelated prompt matches nothing
    assert idx.match(_toks(rng, 8)) == ([], 0, None)


def test_radix_split_on_mid_edge_divergence():
    idx = RadixIndex(4)
    rng = np.random.default_rng(1)
    a, b, c, d = (_toks(rng, 4) for _ in range(4))
    idx.insert(a + b + c, [1, 2, 3])     # one compressed 3-page edge
    assert idx.insert(a + b + d, [9, 9, 4]) == [4]   # a+b reused
    assert idx.match(a + b + c) == ([1, 2, 3], 3, None)
    assert idx.match(a + b + d) == ([1, 2, 4], 3, None)
    # the shared prefix is now its own (split) edge
    assert idx.match(a + b) == ([1, 2], 2, None)
    # diverging INSIDE the split-off deep edge still returns the prefix
    blocks, full, _ = idx.match(a + b + c[:len(c)] + _toks(rng, 4))
    assert blocks == [1, 2, 3] and full == 3


def test_radix_tail_insert_and_match():
    idx = RadixIndex(4)
    rng = np.random.default_rng(2)
    t = _toks(rng, 10)                   # 2 full pages + 2-row tail
    # tail without its full pages indexed is refused
    assert not idx.insert_tail(t, 30, 10)
    idx.insert(t, [1, 2])
    assert idx.insert_tail(t, 30, 10)
    assert not idx.insert_tail(t, 31, 10)     # identical run: dedup
    assert idx.num_tail_blocks == 1
    # a prompt sharing the pages + 1 tail row matches into the tail
    probe = t[:9] + _toks(rng, 4)
    blocks, full, tail = idx.match(probe)
    assert blocks == [1, 2] and full == 2
    entry, rows = tail
    assert entry.block == 30 and rows == 1
    # mid-edge stop returns no tail (no node sits there)
    half = t[:2] + _toks(rng, 6)
    assert idx.match(half) == ([], 0, None)


def test_radix_evict_lru_leaves_inward():
    idx = RadixIndex(4)
    rng = np.random.default_rng(3)
    a, b, c = (_toks(rng, 4) for _ in range(3))
    idx.insert(a + b, [1, 2])
    idx.insert(a + c, [1, 3])            # branch: [a] -> {b: 2, c: 3}
    idx.match(a + b)                     # refresh the b-branch
    freed = idx.evict(1, can_evict=lambda blk: True)
    assert freed == [3]                  # LRU leaf (c-branch) goes first
    # trimming proceeds deep-end-first and never drops a page a longer
    # cached prefix still needs before that deeper page is gone
    freed = idx.evict(2, can_evict=lambda blk: True)
    assert freed == [2, 1] and idx.num_blocks == 0
    assert idx.match(a + b) == ([], 0, None)


def test_radix_evict_stops_at_pinned_blocks():
    idx = RadixIndex(4)
    rng = np.random.default_rng(4)
    a, b = _toks(rng, 4), _toks(rng, 4)
    idx.insert(a + b, [1, 2])
    # block 1 pinned (a live request shares it): the deep page 2 can go,
    # but the edge trim must stop at the pinned shallow page
    freed = idx.evict(5, can_evict=lambda blk: blk != 1)
    assert freed == [2]
    assert idx.match(a) == ([1], 1, None)
    assert idx.evict(5, can_evict=lambda blk: True) == [1]


# ---------------------------------------------------------- facade units


def test_prefix_cache_match_caps_at_final_token():
    pool = BlockPool(12)
    pc = PrefixCache(pool, block_size=4)
    rng = np.random.default_rng(5)
    t = _toks(rng, 8)                    # exact page multiple
    blocks = pool.alloc(2)
    pc.insert(t, blocks, committed=8)
    pool.free(blocks)                    # tree refs keep them alive
    got, cached = pc.match(t)
    assert cached == 7 and got == blocks     # final token always prefills
    got, cached = pc.match(t + _toks(rng, 3))
    assert cached == 8 and got == blocks     # longer prompt: full pages


def test_prefix_cache_page_aligns_without_tail_sharing():
    pool = BlockPool(12)
    pc = PrefixCache(pool, block_size=4, tail_shareable=False)
    rng = np.random.default_rng(6)
    t = _toks(rng, 10)
    blocks = pool.alloc(3)
    pc.insert(t, blocks, committed=10, include_tail=True)
    assert pc.index.num_tail_blocks == 0     # tail page never indexed
    got, cached = pc.match(t[:8])            # would cap to 7 mid-page
    assert cached == 4 and got == blocks[:1]  # aligned down to page edge


def test_prefix_cache_insert_tail_only_when_owner_quiesces():
    pool = BlockPool(12)
    pc = PrefixCache(pool, block_size=4)
    rng = np.random.default_rng(7)
    t = _toks(rng, 10)
    blocks = pool.alloc(3)
    pc.insert(t, blocks, committed=10, include_tail=False)  # activate()
    assert pc.index.num_tail_blocks == 0
    assert pool.refcount(blocks[2]) == 1
    pc.insert(t, blocks, committed=10, include_tail=True)   # finish()
    assert pc.index.num_tail_blocks == 1
    assert pool.refcount(blocks[2]) == 2


def test_prefix_cache_evict_skips_live_sharers():
    pool = BlockPool(12)
    pc = PrefixCache(pool, block_size=4)
    rng = np.random.default_rng(8)
    t = _toks(rng, 12)
    blocks = pool.alloc(3)
    pc.insert(t, blocks, committed=12)
    pool.free(blocks)                    # owner gone: tree-only, rc 1
    pool.ref(blocks[0])                  # a "live request" pins page 0
    assert pc.evictable_blocks() == 2
    assert pc.evict(3) == 2              # deep pages drop, pinned stays
    assert pool.is_allocated(blocks[0])
    assert not pool.is_allocated(blocks[2])
    got, cached = pc.match(t)
    assert got == [blocks[0]] and cached == 4


def test_scheduler_alloc_uses_cache_eviction_tier():
    """Cache eviction is the first reclamation tier: an admission whose
    deficit is covered by tree-only pages evicts them instead of failing
    (and never preempts anyone)."""
    pool = BlockPool(8)                  # 7 usable blocks
    s = Scheduler(pool, max_batch=2, max_blocks_per_seq=8, block_size=4,
                  prefill_chunk=8)
    pc = s.prefix_cache = PrefixCache(pool, block_size=4)
    rng = np.random.default_rng(9)
    stale = _toks(rng, 20)
    blocks = pool.alloc(5)
    pc.insert(stale, blocks, committed=20)
    pool.free(blocks)                    # 5 tree-only pages, 2 free
    r = Request(prompt=_toks(rng, 16), max_new_tokens=4, arrival=0.0)
    s.submit(r)
    assert s.try_admit(0.0) is r         # needs 2 + headroom: evicts 1
    assert pool.is_allocated(r.blocks[0])
    assert pc.shared_blocks < 5


# --------------------------------------------------- engine: parity


@pytest.mark.parametrize("backend", ["socket", "dense", "hard_lsh",
                                     "quest"])
def test_hit_vs_cold_token_parity(backend):
    """Cache-on serving of a shared-prefix workload must generate the
    exact cold-path tokens for every paged backend and the dense
    fallback — and must actually hit (the shared prefix spans 2 full
    pages; admissions serialize with prefill completion, so every
    later request sees the first one's pages)."""
    cfg = _smoke(backend)
    rng = np.random.default_rng(10)
    prompts = _shared_prefix_prompts(rng, vocab=cfg.vocab_size)
    _, cold, _ = _run(_with_cache(cfg, False), prompts, steps=6)
    eng, warm, _ = _run(_with_cache(cfg, True), prompts, steps=6)
    assert eng.prefix_cache is not None
    reg = eng.registry
    assert reg.value("prefix_cache_hits_total") >= 2
    assert reg.value("prefix_cache_cached_tokens_total") >= 2 * 16
    for w, c in zip(warm, cold):
        assert w.state == FINISHED and w.generated == c.generated, backend


def test_quest_shares_page_aligned_only():
    """Quest's per-page min/max stats summarize every row of a page, so
    its plan shares page-aligned prefixes only (no tail entries, CoW
    structurally unreachable) — and a direct CoW clone on such a plan
    refuses at trace time."""
    from repro.serving import paged
    from repro.serving.engine import ContinuousBatchingEngine

    cfg = _with_cache(_smoke("quest"))
    eng = ContinuousBatchingEngine(cfg, rng=jax.random.PRNGKey(0))
    assert eng.prefix_cache is not None
    assert not eng.prefix_cache.tail_shareable
    with pytest.raises(ValueError, match="page-granular"):
        paged.clone_block(cfg, eng.pages, 1, 2, 3)


def test_warm_engine_tail_hit_triggers_cow_and_stays_exact():
    """Second batch on a warm engine: its prompt extends a finished
    request's prompt past the partial tail page, so admission installs
    the shared tail, the first chunk starts mid-page, and the engine
    must CoW-clone (with scrub) before writing — token output identical
    to a cold engine."""
    cfg = _with_cache(_smoke("socket"))
    rng = np.random.default_rng(11)
    first = rng.integers(0, cfg.vocab_size, size=21).tolist()
    ext = first + rng.integers(0, cfg.vocab_size, size=11).tolist()

    _, cold, _ = _run(_with_cache(cfg, False), [ext], steps=5)
    eng, _, _ = _run(cfg, [first], steps=5)
    assert eng.prefix_cache.index.num_tail_blocks == 1
    eng, warm, _ = _run(cfg, [ext], steps=5, engine=eng)
    reg = eng.registry
    assert reg.value("prefix_cache_hits_total") == 1
    # 2 full pages + 5 tail rows matched; the chunk write un-shares the
    # tail page via exactly one CoW clone
    assert reg.value("prefix_cache_cached_tokens_total") == 21
    assert reg.value("prefix_cache_cow_total") == 1
    assert warm[0].generated == cold[0].generated


def test_cow_invariant_shared_pages_bitwise_frozen():
    """Property test for the CoW contract: across an entire warm serve,
    any physical page with pool refcount > 1 is bitwise unchanged from
    one engine iteration to the next (writers must clone, never mutate
    in place)."""
    cfg = _with_cache(_smoke("socket"))
    rng = np.random.default_rng(12)
    prompts = _shared_prefix_prompts(rng, share=21, uniques=(9, 13, 7),
                                     vocab=cfg.vocab_size)
    eng, _, _ = _run(cfg, [prompts[0]], steps=6)   # seed the cache

    def paged_leaves(pages):
        return [lf for lf in jax.tree_util.tree_leaves(pages)
                if hasattr(lf, "shape") and lf.ndim >= 1
                and lf.shape[0] == eng.pool.num_blocks]

    prev = {}
    checked = [0]

    def hook(engine, _it):
        shared = {b for b in range(1, engine.pool.num_blocks)
                  if engine.pool.is_shared(b)}
        snap = {b: [np.asarray(lf[b]) for lf in paged_leaves(engine.pages)]
                for b in shared}
        for b in shared & set(prev):
            for old, new in zip(prev[b], snap[b]):
                np.testing.assert_array_equal(old, new, err_msg=(
                    f"shared block {b} mutated in place"))
            checked[0] += 1
        prev.clear()
        prev.update(snap)

    eng.iter_hook = hook
    eng, warm, _ = _run(cfg, prompts[1:], steps=6, engine=eng)
    eng.iter_hook = None
    assert checked[0] > 0, "no shared pages were ever live across steps"
    assert eng.registry.value("prefix_cache_hits_total") >= 2
    assert all(r.state == FINISHED for r in warm)


def test_poisoned_pool_shared_prefix_parity():
    """Scrub-on-clone: with every paged leaf pre-poisoned, a warm serve
    through shared pages + tail CoW must still match a clean cold
    engine bit-for-bit — if the clone path kept (or the share path
    exposed) any non-written row, the poison would surface in the
    logits."""
    from repro.serving.engine import ContinuousBatchingEngine

    cfg = _with_cache(_smoke("socket"))
    rng = np.random.default_rng(13)
    first = rng.integers(0, cfg.vocab_size, size=21).tolist()
    ext = first + rng.integers(0, cfg.vocab_size, size=11).tolist()
    _, cold, _ = _run(_with_cache(cfg, False), [ext], steps=5)

    eng = ContinuousBatchingEngine(cfg, rng=jax.random.PRNGKey(0))
    # poison every allocatable page (block 0 — the trash page — keeps its
    # init fill; its masking is a separate, pre-existing guarantee)
    eng.pages = jax.tree_util.tree_map(
        lambda lf: lf.at[1:].set(jnp.asarray(1e4).astype(lf.dtype)),
        eng.pages)
    eng, _, _ = _run(cfg, [first], steps=5, engine=eng)
    eng, warm, _ = _run(cfg, [ext], steps=5, engine=eng)
    assert eng.registry.value("prefix_cache_cow_total") >= 1
    assert warm[0].generated == cold[0].generated


@pytest.mark.parametrize("kv_dtype", ["int8", "fp8"])
def test_poisoned_pool_cow_scrub_parity_quantized(kv_dtype):
    """Same scrub-on-clone contract under quantized pool pages: the CoW
    clone must copy/scrub the K/V leaves *and* their per-row scale
    leaves (a stale scale re-scales poisoned quantized rows into the
    logits just as surely as a stale key row would).  Warm-vs-cold
    parity is quantized-vs-itself — exact within the storage mode."""
    from repro.serving.engine import ContinuousBatchingEngine

    cfg = _with_cache(_smoke("socket"), kv_dtype=kv_dtype)
    if kv_dtype == "fp8":
        # the fp8 dtype matrix requires the fused attend path
        import dataclasses
        cfg = cfg.replace(socket=dataclasses.replace(
            cfg.socket, use_paged_kernel=True))
    rng = np.random.default_rng(13)
    first = rng.integers(0, cfg.vocab_size, size=21).tolist()
    ext = first + rng.integers(0, cfg.vocab_size, size=11).tolist()
    _, cold, _ = _run(_with_cache(cfg, False), [ext], steps=5)

    eng = ContinuousBatchingEngine(cfg, rng=jax.random.PRNGKey(0))
    paths = [jax.tree_util.keystr(p) for p, _ in
             jax.tree_util.tree_flatten_with_path(eng.pages)[0]]
    assert any("k_scale" in s for s in paths) and \
        any("v_scale" in s for s in paths), \
        "quantized plan must carry scale leaves"
    # poison with a value finite in every leaf dtype: 1e4 saturates to
    # NaN in float8_e4m3fn (no inf encoding), which would defeat the
    # attention mask on never-written tail rows rather than exercise
    # the scrub-on-clone contract
    eng.pages = jax.tree_util.tree_map(
        lambda lf: lf.at[1:].set(jnp.asarray(100.0).astype(lf.dtype)),
        eng.pages)
    eng, _, _ = _run(cfg, [first], steps=5, engine=eng)
    eng, warm, _ = _run(cfg, [ext], steps=5, engine=eng)
    assert eng.registry.value("prefix_cache_cow_total") >= 1
    assert warm[0].generated == cold[0].generated


@pytest.mark.parametrize("backend,kv_dtype", [
    ("socket", "int8"), ("dense", "int8"), ("hard_lsh", "int8"),
    ("quest", "int8"), ("socket", "fp8")])
def test_hit_vs_cold_token_parity_quantized(backend, kv_dtype):
    """Prefix warm hits under quantized pages: a cache-on serve must
    reproduce the cache-off tokens of the *same* storage mode exactly —
    sharing a quantized page shares its scale rows with it.  int8 runs
    the unfused XLA dequant-gather paths; fp8 requires (and so covers)
    the fused socket kernel."""
    cfg = _smoke(backend)
    if kv_dtype == "fp8":
        import dataclasses
        cfg = cfg.replace(socket=dataclasses.replace(
            cfg.socket, use_paged_kernel=True))
    cfg = cfg.replace(serving=cfg.serving.replace(kv_dtype=kv_dtype))
    rng = np.random.default_rng(10)
    prompts = _shared_prefix_prompts(rng, vocab=cfg.vocab_size)
    _, cold, _ = _run(_with_cache(cfg, False), prompts, steps=6)
    eng, warm, _ = _run(_with_cache(cfg, True), prompts, steps=6)
    assert eng.prefix_cache is not None
    assert eng.registry.value("prefix_cache_hits_total") >= 2
    for w, c in zip(warm, cold):
        assert w.state == FINISHED and w.generated == c.generated, backend


# ------------------------------------------- engine: pressure + fallback


def test_preemption_with_shared_pages_token_exact():
    """Pool pressure on a cache-on shared-prefix workload: preemptions
    (of requests holding shared pages) and cache evictions interleave,
    and the run must still reproduce the calm cache-off tokens."""
    cfg = _with_cache(_smoke("socket"))
    rng = np.random.default_rng(14)
    prompts = _shared_prefix_prompts(rng, share=17, uniques=(7, 7),
                                     vocab=cfg.vocab_size)
    _, calm, mc = _run(_with_cache(cfg, False, num_blocks=48), prompts,
                       steps=20)
    hot_cfg = _with_cache(cfg, True, num_blocks=10, max_batch=2)
    eng, hot, mh = _run(hot_cfg, prompts, steps=20)
    assert mh.preemptions > 0 and mc.preemptions == 0
    for h, c in zip(hot, calm):
        assert h.state == FINISHED and len(h.generated) == 20
        assert h.generated == c.generated


def test_preemption_quantized_token_parity():
    """Quantized-vs-itself parity under pool pressure: preempting and
    re-prefilling a request re-quantizes the same prompt rows, so an
    int8 pressured run must reproduce the calm int8 tokens exactly (a
    preempt/resume that round-tripped rows through a second quantize
    would drift here)."""
    cfg = _with_cache(_smoke("socket"), kv_dtype="int8")
    rng = np.random.default_rng(14)
    prompts = _shared_prefix_prompts(rng, share=17, uniques=(7, 7),
                                     vocab=cfg.vocab_size)
    _, calm, mc = _run(_with_cache(cfg, False, num_blocks=48), prompts,
                       steps=20)
    eng, hot, mh = _run(_with_cache(cfg, True, num_blocks=10, max_batch=2),
                        prompts, steps=20)
    assert mh.preemptions > 0 and mc.preemptions == 0
    for h, c in zip(hot, calm):
        assert h.state == FINISHED and h.generated == c.generated


def test_eviction_under_pressure_never_frees_live_sharers():
    """While the pressured run above executes, every cache eviction must
    leave shared (refcount > 1) pages allocated — checked continuously
    via the iteration hook."""
    cfg = _with_cache(_smoke("socket"), num_blocks=10, max_batch=2)
    rng = np.random.default_rng(15)
    prompts = _shared_prefix_prompts(rng, share=17, uniques=(7, 7),
                                     vocab=cfg.vocab_size)

    def hook(engine, _it):
        for r in list(engine.scheduler.running.values()) + \
                engine.scheduler.prefilling:
            for b in r.blocks:
                assert engine.pool.is_allocated(b), (
                    f"request {r.rid} holds freed block {b}")

    from repro.serving.engine import ContinuousBatchingEngine
    eng = ContinuousBatchingEngine(cfg, rng=jax.random.PRNGKey(0))
    eng.iter_hook = hook
    _, reqs, m = _run(cfg, prompts, steps=20, engine=eng)
    assert all(r.state == FINISHED for r in reqs)
    assert eng.registry.value("prefix_cache_evicted_total") >= 0
    assert m.preemptions > 0


def test_hybrid_plans_fall_back_to_no_share():
    """gemma3's ring layers recycle their page prefix in place, so the
    prefix-cache flag must degrade to a plain serve (no cache object,
    tokens unchanged) rather than sharing unsoundly."""
    from repro.serving.engine import ContinuousBatchingEngine

    cfg = get_config("gemma3-27b").smoke().replace(num_groups=1)
    rng = np.random.default_rng(16)
    prompts = [rng.integers(0, cfg.vocab_size, size=n).tolist()
               for n in (12, 20)]
    _, off, _ = _run(_with_cache(cfg, False), prompts, steps=4)
    eng = ContinuousBatchingEngine(_with_cache(cfg, True),
                                   rng=jax.random.PRNGKey(0))
    assert eng.prefix_cache is None
    _, on, _ = _run(_with_cache(cfg, True), prompts, steps=4, engine=eng)
    for a, b in zip(on, off):
        assert a.state == FINISHED and a.generated == b.generated


def test_legacy_prefill_falls_back_to_no_share():
    """Whole-bucket prefill has no chunk cursor, so a cache hit cannot
    resume mid-prompt: the flag degrades to no cache."""
    from repro.serving.engine import ContinuousBatchingEngine

    cfg = _with_cache(_smoke("socket"), prefill_chunk=0)
    eng = ContinuousBatchingEngine(cfg, rng=jax.random.PRNGKey(0))
    assert eng.prefix_cache is None


# --------------------------------------------------- workloads + events


def test_workload_generators_shape_and_overlap():
    chat = chatbot_prompts(6, sessions=2, system_len=16, turn_len=12,
                           max_prompt_len=48, vocab_size=256, seed=0)
    assert len(chat) == 6 and all(len(p) <= 48 for p in chat)
    # consecutive turns of one session extend the previous turn's prompt
    assert chat[2][:len(chat[0])] == chat[0]
    assert chat[3][:len(chat[1])] == chat[1]
    # sessions differ past the shared system prompt
    assert chat[0][:16] == chat[1][:16] and chat[0] != chat[1]

    rag = rag_prompts(5, prompt_len=40, overlap=0.6, vocab_size=256,
                      seed=0)
    assert all(len(p) == 40 for p in rag)
    shared = rag[0][:24]
    assert all(p[:24] == shared for p in rag)
    assert len({tuple(p) for p in rag}) == 5
    with pytest.raises(ValueError, match="overlap"):
        rag_prompts(2, overlap=1.5)


def test_trace_carries_cache_events_and_validates(tmp_path):
    from repro.serving.obs import (Observability, events,
                                   write_chrome_trace)
    from repro.serving.engine import ContinuousBatchingEngine

    cfg = _with_cache(_smoke("socket"))
    rng = np.random.default_rng(17)
    first = rng.integers(0, cfg.vocab_size, size=21).tolist()
    ext = first + rng.integers(0, cfg.vocab_size, size=11).tolist()
    path = tmp_path / "trace.jsonl"
    obs = Observability(str(path))
    eng = ContinuousBatchingEngine(cfg, rng=jax.random.PRNGKey(0),
                                   obs=obs)
    _run(cfg, [first], steps=5, engine=eng)
    _run(cfg, [ext], steps=5, engine=eng)
    obs.close()
    with open(path) as f:
        evs = events.validate_jsonl(f)
    kinds = {e["ev"] for e in evs}
    assert {"cache_hit", "cache_miss", "page_share", "cow_copy"} <= kinds
    assert evs[0]["prefix_cache"] is True
    out = tmp_path / "chrome.json"
    trace = write_chrome_trace(str(path), str(out))
    names = {t.get("name", "") for t in trace["traceEvents"]}
    assert any(n.startswith("cache hit") for n in names)
    assert "cow copy" in names
