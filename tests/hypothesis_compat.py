"""Optional-``hypothesis`` shim for the property-based tests.

The container image does not guarantee ``hypothesis`` is installed, and a
bare ``from hypothesis import ...`` at module scope aborts the *whole*
tier-1 collection.  Importing ``given``/``settings``/``st`` from here keeps
the non-property tests in those modules running everywhere: when
``hypothesis`` is available the real decorators are re-exported; when it
is missing, ``@given`` turns the test into an explicit skip and the
strategy constructors become inert placeholders (they are only evaluated
at decoration time).
"""

import pytest

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:
    HAVE_HYPOTHESIS = False

    def given(*_args, **_kwargs):
        def deco(fn):
            def stub(*a, **k):
                pytest.skip("hypothesis not installed")
            stub.__name__ = fn.__name__
            stub.__doc__ = fn.__doc__
            return stub
        return deco

    def settings(*_args, **_kwargs):
        return lambda fn: fn

    class _StubStrategies:
        """Accepts any ``st.<name>(...)`` call made inside ``@given``."""

        def __getattr__(self, name):
            def factory(*_a, **_k):
                return None
            return factory

    st = _StubStrategies()
