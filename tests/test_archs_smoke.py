"""Deliverable (f): one REDUCED-config smoke test per assigned architecture
— instantiate, one forward/train step on CPU, assert shapes + no NaNs;
plus prefill→decode equals the train-path forward token-for-token."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ASSIGNED, get_config
from repro.models import (decode_step, forward_train, init_model,
                          loss_and_metrics, prefill)
from repro.models import param as pm


def _batch(cfg, rng, b=2, s=32, extra=0):
    if cfg.input_mode == "tokens":
        toks = jax.random.randint(rng, (b, s + extra), 0, cfg.vocab_size)
        return {"tokens": toks[:, :s], "labels": toks[:, :s]}, toks
    emb = jax.random.normal(rng, (b, s + extra, cfg.d_model))
    labels = jax.random.randint(rng, (b, s), 0, cfg.vocab_size)
    return {"embeds": emb[:, :s], "labels": labels}, emb


@pytest.mark.parametrize("arch", ASSIGNED)
def test_smoke_forward_and_train_step(arch):
    cfg = get_config(arch).smoke()
    rng = jax.random.PRNGKey(0)
    params = pm.unbox(init_model(cfg, rng))
    batch, _ = _batch(cfg, rng)

    logits, aux = forward_train(cfg, params, batch)
    assert logits.shape == (2, 32, cfg.padded_vocab())
    assert bool(jnp.all(jnp.isfinite(logits))), f"{arch}: NaN logits"

    loss, metrics = loss_and_metrics(cfg, params, batch)
    assert bool(jnp.isfinite(loss))
    grads = jax.grad(lambda p: loss_and_metrics(cfg, p, batch)[0])(params)
    gn = sum(float(jnp.sum(jnp.abs(g))) for g in
             jax.tree_util.tree_leaves(grads))
    assert np.isfinite(gn) and gn > 0, f"{arch}: bad grads"


@pytest.mark.parametrize("arch", ASSIGNED)
def test_smoke_decode_matches_forward(arch):
    """prefill + decode_step == forward_train positionwise (dense backend,
    dropless MoE so the comparison is exact)."""
    cfg = get_config(arch).smoke().replace(attention_backend="dense")
    if cfg.num_experts:
        cfg = cfg.replace(capacity_factor=float(cfg.num_experts))
    rng = jax.random.PRNGKey(0)
    params = pm.unbox(init_model(cfg, rng))
    b, s, extra = 2, 32, 3
    batch, full = _batch(cfg, rng, b, s, extra)
    full_batch = {"tokens": full} if cfg.input_mode == "tokens" else \
        {"embeds": full}
    logits_full, _ = forward_train(cfg, params, full_batch)

    pre = {k: v for k, v in batch.items() if k != "labels"}
    logits_p, caches = prefill(cfg, params, pre, capacity=s + 8)
    np.testing.assert_allclose(np.asarray(logits_p[:, 0]),
                               np.asarray(logits_full[:, s - 1]),
                               atol=5e-4)
    for t in range(s, s + extra):
        inp = full[:, t:t + 1]
        logits_d, caches = decode_step(cfg, params, caches, inp,
                                       jnp.int32(t))
        np.testing.assert_allclose(np.asarray(logits_d[:, 0]),
                                   np.asarray(logits_full[:, t]),
                                   atol=5e-4)


@pytest.mark.parametrize("arch", ["stablelm-12b", "gemma3-27b",
                                  "jamba-v0.1-52b"])
def test_smoke_socket_decode_runs(arch):
    """SOCKET decode backend produces finite outputs on every family that
    has attention layers."""
    cfg = get_config(arch).smoke()
    assert cfg.attention_backend == "socket"
    rng = jax.random.PRNGKey(0)
    params = pm.unbox(init_model(cfg, rng))
    batch, full = _batch(cfg, rng, extra=1)
    pre = {k: v for k, v in batch.items() if k != "labels"}
    _, caches = prefill(cfg, params, pre, capacity=64)
    inp = full[:, 32:33]
    logits, caches = decode_step(cfg, params, caches, inp, jnp.int32(32))
    assert bool(jnp.all(jnp.isfinite(logits)))


@pytest.mark.parametrize("backend", ["dense", "socket", "quest",
                                     "hard_lsh"])
def test_all_decode_backends(backend):
    cfg = get_config("minitron-8b").smoke().replace(
        attention_backend=backend)
    rng = jax.random.PRNGKey(1)
    params = pm.unbox(init_model(cfg, rng))
    batch, full = _batch(cfg, rng, extra=1)
    pre = {k: v for k, v in batch.items() if k != "labels"}
    _, caches = prefill(cfg, params, pre, capacity=64)
    logits, _ = decode_step(cfg, params, caches, full[:, 32:33],
                            jnp.int32(32))
    assert bool(jnp.all(jnp.isfinite(logits))), backend


def test_param_counts_match_literature():
    expected = {
        "musicgen-medium": (1.5e9, 2.2e9),
        "gemma3-27b": (26e9, 30e9),
        "stablelm-12b": (11e9, 13e9),
        "minitron-8b": (8e9, 10.5e9),
        "gemma-7b": (8e9, 10e9),
        "mixtral-8x22b": (138e9, 143e9),
        "llama4-maverick-400b-a17b": (380e9, 410e9),
        "jamba-v0.1-52b": (50e9, 53e9),
        "mamba2-780m": (0.75e9, 0.9e9),
        "internvl2-26b": (18e9, 21e9),
    }
    for arch, (lo, hi) in expected.items():
        n = get_config(arch).param_count()
        assert lo <= n <= hi, f"{arch}: {n/1e9:.2f}B not in [{lo},{hi}]"


def test_active_params_moe():
    llama4 = get_config("llama4-maverick-400b-a17b")
    assert llama4.active_param_count() < 20e9      # ~a17b
    mixtral = get_config("mixtral-8x22b")
    assert 35e9 < mixtral.active_param_count() < 45e9
