import os
import subprocess
import sys

import numpy as np
import pytest

# NOTE: no XLA_FLAGS here on purpose — unit/smoke tests must see exactly
# one device (the dry-run sets its own 512-device flag in its own process).

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
SRC = os.path.join(REPO, "src")

# Hypothesis profiles, pinned per environment: the CI kernels job selects
# "ci" (derandomized, more examples) via HYPOTHESIS_PROFILE; local runs
# default to the quick randomized "dev" profile.  Registered here so every
# property test in the suite shares one policy.
try:
    from hypothesis import settings as _hsettings

    _hsettings.register_profile("dev", max_examples=25, deadline=None)
    _hsettings.register_profile("ci", derandomize=True, max_examples=150,
                                deadline=None)
    _hsettings.load_profile(os.environ.get("HYPOTHESIS_PROFILE", "dev"))
except ModuleNotFoundError:          # optional dep (tests/hypothesis_compat)
    pass


@pytest.fixture
def rng():
    import jax
    return jax.random.PRNGKey(0)


def run_subprocess_devices(code: str, devices: int = 8,
                           timeout: int = 600) -> str:
    """Run ``code`` in a fresh python with N host-platform devices."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    proc = subprocess.run([sys.executable, "-c", code], env=env,
                          capture_output=True, text=True, timeout=timeout)
    assert proc.returncode == 0, (
        f"subprocess failed\nSTDOUT:\n{proc.stdout}\nSTDERR:\n{proc.stderr}")
    return proc.stdout
