"""Distribution-layer tests.

Sharding-rule units run in-process (no devices needed); everything needing
multiple devices runs in a subprocess with
``XLA_FLAGS=--xla_force_host_platform_device_count=8`` so the main pytest
process keeps seeing exactly one device.
"""

import numpy as np
import pytest

from conftest import run_subprocess_devices


# ------------------------------------------------------- rule units (1 dev)

def test_logical_to_spec_divisibility_fallback():
    import jax
    from jax.sharding import PartitionSpec
    from repro.distributed.sharding import logical_to_spec
    mesh = jax.make_mesh((1,), ("model",))   # single device is fine
    log = []
    spec = logical_to_spec(("heads", None), (24, 4), mesh, None, log)
    assert spec == PartitionSpec("model", None)  # 24 % 1 == 0
    # fake a 16-wide axis via rules on a 1-dev mesh isn't possible; the
    # real 16-way behaviour is covered by the dry-run fallback logs.


def test_unknown_logical_axis_raises():
    import jax
    from repro.distributed.sharding import logical_to_spec
    mesh = jax.make_mesh((1,), ("model",))
    with pytest.raises(KeyError):
        logical_to_spec(("not_an_axis",), (8,), mesh)


def test_lsc_is_identity_without_mesh():
    import jax.numpy as jnp
    from repro.distributed.sharding import lsc
    x = jnp.ones((4, 4))
    assert lsc(x, "batch", "embed") is x


# --------------------------------------------------- multi-device (subproc)

def test_compressed_psum_exact_and_error_feedback():
    run_subprocess_devices("""
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P
from repro.distributed.compression import compressed_psum
from repro.distributed.sharding import shard_map

mesh = jax.make_mesh((8,), ("data",))
f = shard_map(lambda g, e: compressed_psum({"w": g}, {"w": e}, "data"),
              mesh=mesh, in_specs=(P("data", None), P("data", None)),
              out_specs=({"w": P(None, None)}, {"w": P("data", None)}),
              check_vma=False)
g = jax.random.normal(jax.random.PRNGKey(0), (8, 128))
exact = jnp.mean(g, axis=0)
e = jnp.zeros((8, 128))
synced, eo = f(g, e)
err1 = float(jnp.max(jnp.abs(synced["w"][0] - exact)))
assert err1 < 0.05, err1

# error feedback: simulate SGD where compression error is carried —
# the AVERAGE of compressed steps converges to the average of exact steps
w_c = jnp.zeros((128,)); w_x = jnp.zeros((128,)); e = jnp.zeros((8, 128))
for i in range(40):
    gi = g + 0.01 * jax.random.normal(jax.random.PRNGKey(i), g.shape)
    synced, eo = f(gi, e); e = eo["w"]
    w_c = w_c - 0.1 * synced["w"][0]
    w_x = w_x - 0.1 * jnp.mean(gi, axis=0)
drift = float(jnp.max(jnp.abs(w_c - w_x)))
assert drift < 0.02, drift
print("OK", err1, drift)
""")


def test_context_parallel_socket_attend():
    run_subprocess_devices("""
import jax, jax.numpy as jnp, numpy as np
from repro.distributed.context_parallel import context_parallel_socket_attend
from repro.core import socket, hashing

mesh = jax.make_mesh((8,), ("data",))
cfg = socket.SocketConfig(num_planes=8, num_tables=24, tau=0.4,
                          sparsity=4.0, sink_tokens=8, window_tokens=8,
                          min_k=16)
d, n, B, KVH, G = 32, 1024, 1, 2, 2
rng = jax.random.PRNGKey(1)
kk, kv, kq, kw = jax.random.split(rng, 4)
w = hashing.make_hash_params(kw, d, 8, 24)
keys = jax.random.normal(kk, (B,KVH,n,d))
vals = jax.random.normal(kv, (B,KVH,n,d))
side = socket.precompute_key_hashes(cfg, w, keys, vals)
q = 2.0*keys[:,:,500][:, :, None, None, :] + 0.1*jax.random.normal(kq,(B,KVH,G,1,d))
out = context_parallel_socket_attend(cfg, mesh, ("data",), w, q, keys,
                                     vals, side.bits,
                                     side.vnorm.astype(jnp.float32),
                                     length=900, scale=1/np.sqrt(d))
ref = socket.socket_attend(cfg, w, q, keys, vals, side, length=900,
                           scale=1/np.sqrt(d))
rel = float(jnp.linalg.norm(out-ref)/jnp.linalg.norm(ref))
assert rel < 0.08, rel
assert out.shape == ref.shape
print("OK", rel)
""")


def test_gpipe_forward_matches_sequential():
    run_subprocess_devices("""
import jax, jax.numpy as jnp, numpy as np
from repro.distributed.pipeline import gpipe_forward

mesh = jax.make_mesh((4,), ("stage",))
stages, layers_per, d = 4, 2, 16
rng = jax.random.PRNGKey(0)
ws = jax.random.normal(rng, (stages, layers_per, d, d)) * 0.2

def stage_fn(params, x):
    for i in range(layers_per):
        x = jnp.tanh(x @ params[i])
    return x

x = jax.random.normal(jax.random.fold_in(rng, 1), (8, d))
out = gpipe_forward(mesh, "stage", stage_fn, ws, x, num_micro=4)

ref = x
for s in range(stages):
    ref = stage_fn(ws[s], ref)
np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-5)
print("OK")
""")


def test_pjit_train_step_multi_device():
    """End-to-end sharded train step on a (4, 2) mesh with FSDP+TP rules."""
    run_subprocess_devices("""
import jax, jax.numpy as jnp
from repro.configs import get_config
from repro.distributed import sharding as shd
from repro.launch import specs as sp
from repro.optim import AdamWConfig, init_adamw
from repro.runtime.steps import make_train_step
from repro.models import param as pm, transformer as tfm

mesh = jax.make_mesh((4, 2), ("data", "model"))
cfg = get_config("minitron-8b").smoke().replace(num_groups=1)
ocfg = AdamWConfig()
rules = {}
with shd.activate_mesh(mesh, rules):
    params_sds, params_sh = sp.param_specs(cfg, mesh, rules, [])
    opt_sds, opt_sh = sp.opt_specs(ocfg, params_sds, params_sh, mesh,
                                   rules, [])
    params = pm.unbox(tfm.init_model(cfg, jax.random.PRNGKey(0)))
    params = jax.tree_util.tree_map(jax.device_put, params, params_sh)
    opt = init_adamw(ocfg, params)
    opt = jax.tree_util.tree_map(jax.device_put, opt, opt_sh)
    step = jax.jit(make_train_step(cfg, ocfg, accum=2,
                                   grad_shardings=params_sh),
                   in_shardings=(params_sh, opt_sh, None),
                   out_shardings=(params_sh, opt_sh, None),
                   donate_argnums=(0, 1))
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (4, 64),
                                          0, cfg.vocab_size),
             "labels": jax.random.randint(jax.random.PRNGKey(2), (4, 64),
                                          0, cfg.vocab_size)}
    p2, o2, m = step(params, opt, batch)
assert jnp.isfinite(m["loss"])
print("OK", float(m["loss"]))
""")


def test_elastic_trainer_shrinks_mesh():
    """Trainer loses devices mid-run, rebuilds a smaller mesh, resumes
    from checkpoint and finishes."""
    run_subprocess_devices("""
import jax, numpy as np, tempfile
from jax.sharding import Mesh
from repro.configs import get_config
from repro.data import DataConfig
from repro.optim import AdamWConfig
from repro.optim.schedule import ScheduleConfig
from repro.runtime.fault_tolerance import FailureInjector
from repro.runtime.train_loop import Trainer, TrainLoopConfig

def mesh_factory(devices):
    n = len(devices)
    # largest power-of-two data axis
    while n & (n - 1):
        n -= 1
    return Mesh(np.asarray(devices[:n]).reshape(n, 1), ("data", "model"))

cfg = get_config("minitron-8b").smoke().replace(num_groups=1,
                                                attention_backend="dense")
ocfg = AdamWConfig(schedule=ScheduleConfig(peak_lr=1e-3, warmup_steps=2,
                                           decay_steps=12))
loop = TrainLoopConfig(total_steps=12, checkpoint_every=4)
data = DataConfig(seq_len=32, global_batch=8, vocab_size=cfg.vocab_size)
inj = FailureInjector(schedule={6: "lose_device:4"})
with tempfile.TemporaryDirectory() as d:
    tr = Trainer(cfg, ocfg, loop, data, d, mesh_factory=mesh_factory,
                 injector=inj)
    assert tr.mesh.devices.size == 8
    log = tr.run()
    assert tr.rebuild_count == 1
    assert tr.mesh.devices.size == 4, tr.mesh.devices.size
    assert tr.step == 12
print("OK elastic: 8 -> 4 devices")
""", devices=8, timeout=900)


def test_alltoall_moe_matches_global_and_differentiates():
    """The shard_map EP dispatch must be bit-exact vs global dispatch
    (matched dropless capacity) and give matching gradients."""
    run_subprocess_devices("""
import jax, jax.numpy as jnp, numpy as np
from repro.configs.base import ModelConfig
from repro.distributed import sharding as shd
from repro.models import moe as moe_mod, param as pm

mesh = jax.make_mesh((2, 4), ("data", "model"))
cfg = ModelConfig(name="t", family="moe", d_model=32, d_ff=64,
                  num_experts=8, num_experts_per_tok=2,
                  capacity_factor=8.0, mlp_activation="swiglu",
                  moe_dispatch="alltoall")
params = pm.unbox(moe_mod.init_moe(cfg, jax.random.PRNGKey(0)))
x = jax.random.normal(jax.random.PRNGKey(1), (4, 16, 32))

y_ref, _ = moe_mod.apply_moe(cfg.replace(moe_dispatch="global"), params, x)
with shd.activate_mesh(mesh):
    y_a2a, _ = jax.jit(lambda p, xx: moe_mod.apply_moe(cfg, p, xx))(params, x)
np.testing.assert_allclose(np.asarray(y_ref), np.asarray(y_a2a), atol=1e-5)

def loss_g(p):
    y, _ = moe_mod.apply_moe(cfg.replace(moe_dispatch="global"), p, x)
    return jnp.sum(y ** 2)

def loss_a(p):
    y, _ = moe_mod.apply_moe(cfg, p, x)
    return jnp.sum(y ** 2)

g_ref = jax.grad(loss_g)(params)
with shd.activate_mesh(mesh):
    g_a2a = jax.jit(jax.grad(loss_a))(params)
for k in ("w_gate", "w_up", "w_down"):
    np.testing.assert_allclose(np.asarray(g_ref[k]), np.asarray(g_a2a[k]),
                               atol=2e-4)
print("OK a2a forward+grads exact")
""")


def test_context_parallel_pooled_selection():
    run_subprocess_devices("""
import jax, jax.numpy as jnp, numpy as np
from repro.distributed.context_parallel import context_parallel_socket_attend
from repro.core import socket, hashing
from repro.baselines import oracle

mesh = jax.make_mesh((4,), ("data",))
cfg = socket.SocketConfig(num_planes=8, num_tables=24, tau=0.4,
                          sparsity=4.0, sink_tokens=8, window_tokens=8,
                          min_k=16, selection="pooled")
d, n, B, KVH, G = 32, 512, 1, 2, 2
rng = jax.random.PRNGKey(2)
kk, kv, kq, kw = jax.random.split(rng, 4)
w = hashing.make_hash_params(kw, d, 8, 24)
keys = jax.random.normal(kk, (B,KVH,n,d))
vals = jax.random.normal(kv, (B,KVH,n,d))
side = socket.precompute_key_hashes(cfg, w, keys, vals)
q = 3.0*keys[:,:,300][:, :, None, None, :] + 0.1*jax.random.normal(kq,(B,KVH,G,1,d))
out = context_parallel_socket_attend(cfg, mesh, ("data",), w, q, keys,
                                     vals, side.bits,
                                     side.vnorm.astype(jnp.float32),
                                     length=480, scale=1/np.sqrt(d))
dense = oracle.dense_attention(q, keys, vals, scale=1/np.sqrt(d), length=480)
rel = float(jnp.linalg.norm(out-dense)/jnp.linalg.norm(dense))
assert rel < 0.08, rel
print("OK pooled cp", rel)
""")
