"""SOCKET core invariants: the factorization identity, hard-LSH limit,
selection semantics and end-to-end sparse-attention quality."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis_compat import given, settings, st

from repro.baselines import oracle
from repro.core import hashing, socket


def _setup(rng, d=32, n=128, p=6, l=8, tau=0.4):
    cfg = socket.SocketConfig(num_planes=p, num_tables=l, tau=tau)
    kw, kk, kq = jax.random.split(rng, 3)
    w = hashing.make_hash_params(kw, d, p, l)
    keys = jax.random.normal(kk, (n, d))
    q = jax.random.normal(kq, (d,))
    return cfg, w, keys, q


@settings(max_examples=15, deadline=None)
@given(p=st.integers(2, 8), l=st.integers(1, 12),
       tau=st.floats(0.05, 2.0))
def test_factorization_identity(p, l, tau):
    """DESIGN.md §2: the product-form score == explicit corner softmax
    gather (the paper's eq. 3) — exactly, for every P, L, tau."""
    rng = jax.random.PRNGKey(p * 100 + l)
    cfg, w, keys, q = _setup(rng, p=p, l=l, tau=tau)
    signs = hashing.hash_keys_signs(w, keys)
    ids = hashing.signs_to_bucket_ids(signs)
    u = socket.soft_hash_query(w, q)
    probs = socket.bucket_probs_explicit(u, tau)
    ref = socket.soft_scores_gather(ids, probs)
    out = socket.soft_scores_factorized(cfg, hashing.pack_signs(signs), u)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-3, atol=1e-5)


def test_logz_matches_logsumexp(rng):
    cfg, w, keys, q = _setup(rng, p=8, l=6, tau=0.3)
    u = socket.soft_hash_query(w, q)
    corners = jnp.asarray(hashing.hypercube_corners(8))
    logits = jnp.einsum("lp,rp->lr", u, corners) / 0.3
    ref = jax.scipy.special.logsumexp(logits, axis=-1)
    out = socket.log_normalizer(u, 0.3)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=1e-5)


def test_chunked_scoring_exact(rng):
    cfg, w, keys, q = _setup(rng, n=256)
    signs = hashing.hash_keys_signs(w, keys)
    packed = hashing.pack_signs(signs)
    u = socket.soft_hash_query(w, q)
    full = socket.soft_scores_factorized(cfg, packed, u)
    chunked = socket.soft_scores_factorized(
        cfg.replace(score_chunk=32), packed, u)
    np.testing.assert_allclose(np.asarray(full), np.asarray(chunked),
                               rtol=1e-5, atol=1e-7)


def test_int8_storage_matches_packed(rng):
    cfg, w, keys, q = _setup(rng)
    signs = hashing.hash_keys_signs(w, keys)
    u = socket.soft_hash_query(w, q)
    s_packed = socket.soft_scores_factorized(cfg, hashing.pack_signs(signs),
                                             u)
    cfg8 = cfg.replace(bits_storage="int8")
    flat = (signs.astype(jnp.int8) * 2 - 1).reshape(keys.shape[0], -1)
    s_int8 = socket.soft_scores_factorized(cfg8, flat, u)
    np.testing.assert_allclose(np.asarray(s_packed), np.asarray(s_int8),
                               rtol=1e-5, atol=1e-7)


def test_tau_to_zero_recovers_hard_lsh(rng):
    """Section 5.3: tau -> 0 turns soft scores into collision counts / L."""
    cfg, w, keys, q = _setup(rng, p=4, l=16, tau=1e-3)
    signs = hashing.hash_keys_signs(w, keys)
    q_signs = hashing.hash_keys_signs(w, q[None])[0]       # (L, P)
    collisions = jnp.sum(jnp.all(signs == q_signs[None], axis=-1), axis=-1)
    scores = socket.soft_scores_factorized(cfg, hashing.pack_signs(signs),
                                           socket.soft_hash_query(w, q))
    np.testing.assert_allclose(np.asarray(scores),
                               np.asarray(collisions, dtype=np.float32),
                               atol=1e-3)


def test_scores_rank_by_similarity(rng):
    """fig. 1's claim: closer keys get higher soft scores (in expectation).
    Uses a scale large enough for the signal to dominate hash noise."""
    d = 48
    cfg = socket.SocketConfig(num_planes=10, num_tables=200, tau=0.4)
    kw, kq, kn = jax.random.split(rng, 3)
    q = jax.random.normal(kq, (d,))
    k_close = q + 0.2 * jax.random.normal(kn, (d,))
    k_mid = q + 1.0 * jax.random.normal(jax.random.fold_in(kn, 1), (d,))
    k_far = -q
    keys = jnp.stack([k_close, k_mid, k_far])
    w = hashing.make_hash_params(kw, d, 10, 200)
    signs = hashing.hash_keys_signs(w, keys)
    s = socket.soft_scores_factorized(cfg, hashing.pack_signs(signs),
                                      socket.soft_hash_query(w, q))
    assert s[0] > s[1] > s[2]


def test_value_aware_topk_forces_sink_and_window():
    cfg = socket.SocketConfig(sink_tokens=4, window_tokens=4, min_k=16)
    n, length = 64, 50
    scores = jnp.zeros((n,))
    vnorm = jnp.ones((n,))
    idx, mask = socket.value_aware_topk(cfg, scores, vnorm, k=16,
                                        length=length, n_total=n)
    got = set(np.asarray(idx).tolist())
    assert {0, 1, 2, 3} <= got, "sink tokens must be selected"
    assert {46, 47, 48, 49} <= got, "local window must be selected"
    assert all(i < length for i in got)
    assert bool(jnp.all(mask))


def test_topk_excludes_invalid_slots():
    cfg = socket.SocketConfig(sink_tokens=2, window_tokens=2, min_k=8)
    n, length = 32, 10
    scores = jnp.ones((n,)) * jnp.arange(n)  # later slots score higher
    vnorm = jnp.ones((n,))
    idx, mask = socket.value_aware_topk(cfg, scores, vnorm, k=8,
                                        length=length, n_total=n)
    sel = np.asarray(idx)[np.asarray(mask)]
    assert sel.max() < length


def test_socket_attend_approximates_dense_on_heavy_hitters(rng):
    """The paper's regime: concentrated attention => sparse ≈ dense."""
    d, n, B, KVH, G = 64, 512, 2, 2, 2
    cfg = socket.SocketConfig(num_planes=10, num_tables=60, tau=0.4,
                              sparsity=8.0, sink_tokens=8, window_tokens=8,
                              min_k=32)
    kw, kk, kv, kq = jax.random.split(rng, 4)
    w = hashing.make_hash_params(kw, d, 10, 60)
    keys = jax.random.normal(kk, (B, KVH, n, d))
    vals = jax.random.normal(kv, (B, KVH, n, d))
    # heavy hitter: q strongly aligned with key 100 (scaled up)
    q = 3.0 * keys[:, :, 100][:, :, None, None, :] + \
        0.1 * jax.random.normal(kq, (B, KVH, G, 1, d))
    side = socket.precompute_key_hashes(cfg, w, keys, vals)
    out = socket.socket_attend(cfg, w, q, keys, vals, side, length=n,
                               scale=1 / np.sqrt(d))
    ref = oracle.dense_attention(q, keys, vals, scale=1 / np.sqrt(d),
                                 length=n)
    rel = float(jnp.linalg.norm(out - ref) / jnp.linalg.norm(ref))
    assert rel < 0.05, f"sparse attention too far from dense: {rel}"


def test_qhead_selection_mode(rng):
    d, n, B, KVH, G = 32, 128, 1, 2, 2
    cfg = socket.SocketConfig(num_planes=8, num_tables=24, sparsity=4.0,
                              sink_tokens=4, window_tokens=4, min_k=16,
                              selection="qhead")
    kw, kk, kv, kq = jax.random.split(rng, 4)
    w = hashing.make_hash_params(kw, d, 8, 24)
    keys = jax.random.normal(kk, (B, KVH, n, d))
    vals = jax.random.normal(kv, (B, KVH, n, d))
    q = keys[:, :, 10][:, :, None, None, :] + 0.1 * jax.random.normal(
        kq, (B, KVH, G, 1, d))
    side = socket.precompute_key_hashes(cfg, w, keys, vals)
    out = socket.socket_attend(cfg, w, q, keys, vals, side, length=n)
    assert out.shape == (B, KVH, G, 1, d)
    assert bool(jnp.all(jnp.isfinite(out)))


def test_kernel_path_matches_xla_path(rng):
    d, n, B, KVH, G = 32, 512, 2, 2, 2
    cfg = socket.SocketConfig(num_planes=8, num_tables=24, tau=0.4,
                              sparsity=4.0, sink_tokens=4, window_tokens=4,
                              min_k=32)
    kw, kk, kv, kq = jax.random.split(rng, 4)
    w = hashing.make_hash_params(kw, d, 8, 24)
    keys = jax.random.normal(kk, (B, KVH, n, d))
    vals = jax.random.normal(kv, (B, KVH, n, d))
    q = keys[:, :, 100][:, :, None, None, :] + 0.1 * jax.random.normal(
        kq, (B, KVH, G, 1, d))
    side = socket.precompute_key_hashes(cfg, w, keys, vals)
    a = socket.socket_attend(cfg, w, q, keys, vals, side, length=n,
                             use_kernel=False)
    b = socket.socket_attend(cfg, w, q, keys, vals, side, length=n,
                             use_kernel=True)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-5)
