"""Baseline scorers: each must retrieve a planted near neighbour; MagicPig
estimator accuracy; PQ build determinism; budget accounting."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.baselines import (hard_lsh, hash_attn, magicpig, oracle, pqcache,
                             quest)
from repro.core import socket, hashing


def _planted(rng, d=64, n=512, target=37):
    kk, kv, kq = jax.random.split(rng, 3)
    keys = jax.random.normal(kk, (n, d))
    values = jax.random.normal(kv, (n, d))
    q = 2.0 * keys[target] + 0.05 * jax.random.normal(kq, (d,))
    return keys, values, q


def test_oracle_scorer(rng):
    keys, values, q = _planted(rng)
    st = oracle.build(None, rng, keys, values)
    assert int(jnp.argmax(oracle.score(st, q))) == 37


def test_hard_lsh_finds_neighbor(rng):
    keys, values, q = _planted(rng)
    cfg = hard_lsh.HardLSHConfig(num_planes=2, num_tables=300)
    st = hard_lsh.build(cfg, rng, keys, values)
    s = hard_lsh.score(st, cfg, q)
    assert int(jnp.argmax(s)) == 37
    assert cfg.bits_per_token == 600


def test_hash_attn_finds_neighbor(rng):
    keys, values, q = _planted(rng)
    cfg = hash_attn.HashAttnConfig(num_bits=128)
    st = hash_attn.build(cfg, rng, keys, values)
    assert int(jnp.argmax(hash_attn.score(st, cfg, q))) == 37


def test_quest_page_bounds(rng):
    keys, values, q = _planted(rng)
    cfg = quest.QuestConfig(page_size=16)
    st = quest.build(cfg, rng, keys, values)
    ps = quest.score_pages(st, q)
    n_pages = ps.shape[0]
    # upper-bound property: every page bound >= any member's true score.
    # (argmax over *bounds* need not hit the planted page — a page of
    # diverse keys can carry a looser, larger bound; that granularity gap
    # is exactly what the paper contrasts SOCKET against.)
    true = keys @ q
    for page in range(n_pages):
        members = true[page * 16:(page + 1) * 16]
        assert float(ps[page]) >= float(members.max()) - 1e-4
    # retrieval: the planted page must still rank well ahead of the bulk,
    # so a modest page budget keeps the true neighbour attendable
    rank = int(jnp.sum(ps > ps[37 // 16]))
    assert rank < n_pages // 4, rank


def test_pqcache_scores_and_determinism(rng):
    keys, values, q = _planted(rng)
    cfg = pqcache.PQConfig(num_subspaces=16, nbits=4, kmeans_iters=4)
    st1 = pqcache.build(cfg, rng, keys, values)
    st2 = pqcache.build(cfg, rng, keys, values)
    np.testing.assert_array_equal(np.asarray(st1.codes),
                                  np.asarray(st2.codes))
    s = pqcache.score(st1, cfg, q)
    # ADC approximates inner products
    corr = float(jnp.corrcoef(s, keys @ q)[0, 1])
    assert corr > 0.7, corr
    assert int(jnp.argmax(s)) == 37


def test_magicpig_estimator_reasonable(rng):
    keys, values, q = _planted(rng)
    cfg = magicpig.MagicPigConfig(num_planes=4, num_tables=64,
                                  min_collisions=1)
    st = magicpig.build(cfg, rng, keys, values)
    y = magicpig.attend_estimate(cfg, st, q, keys, values, scale=0.125)
    ref = oracle.dense_attention(q[None, None, None, None],
                                 keys[None, None], values[None, None],
                                 scale=0.125)[0, 0, 0, 0]
    rel = float(jnp.linalg.norm(y - ref) / jnp.linalg.norm(ref))
    assert rel < 0.25, rel


def test_socket_vs_hard_lsh_at_equal_budget(rng):
    """The paper's two hard-LSH findings at a 600-bit budget (Tables 2/7):
    (a) hard LSH at SOCKET's own (P=10, L=60) collapses (Table 2: avg
    score 10 vs 85); (b) the best *tuned* hard LSH (P=2, L=300) is merely
    slightly worse — SOCKET matches or beats it."""
    d, n, k = 64, 2048, 64

    def recall_for(score_fn, q, true_top):
        got = set(np.asarray(jax.lax.top_k(score_fn(q), k)[1]).tolist())
        return len(got & true_top) / k

    kk, kq = jax.random.split(rng)
    keys = jax.random.normal(kk, (n, d))
    cfg = socket.SocketConfig(num_planes=10, num_tables=60, tau=0.4)
    w = hashing.make_hash_params(jax.random.fold_in(rng, 1), d, 10, 60)
    signs = hashing.hash_keys_signs(w, keys)
    packed = hashing.pack_signs(signs)
    h_tuned = hard_lsh.HardLSHConfig(num_planes=2, num_tables=300)
    st_tuned = hard_lsh.build(h_tuned, jax.random.fold_in(rng, 2), keys,
                              keys)
    h_same = hard_lsh.HardLSHConfig(num_planes=10, num_tables=60)
    st_same = hard_lsh.build(h_same, jax.random.fold_in(rng, 3), keys,
                             keys)

    r = {"socket": [], "hard_tuned": [], "hard_same": []}
    for trial in range(8):
        kt = jax.random.fold_in(kq, trial)
        q = keys[trial * 10] + 0.5 * jax.random.normal(kt, (d,))
        true_top = set(np.asarray(jax.lax.top_k(keys @ q, k)[1]).tolist())
        r["socket"].append(recall_for(
            lambda qq: socket.soft_scores_factorized(
                cfg, packed, socket.soft_hash_query(w, qq)), q, true_top))
        r["hard_tuned"].append(recall_for(
            lambda qq: hard_lsh.score(st_tuned, h_tuned, qq), q, true_top))
        r["hard_same"].append(recall_for(
            lambda qq: hard_lsh.score(st_same, h_same, qq), q, true_top))

    m = {key: float(np.mean(v)) for key, v in r.items()}
    # (a) Table 2: hard LSH at (10, 60) is catastrophically worse
    assert m["socket"] > m["hard_same"] + 0.2, m
    # (b) Table 7: SOCKET >= the best tuned hard LSH (within noise)
    assert m["socket"] >= m["hard_tuned"] - 0.05, m
    assert m["socket"] > 0.45, m
