"""Serving subsystem: block pool, scheduler lifecycle, and continuous
ragged-decode parity against the static lockstep engine."""

import numpy as np
import pytest

from repro.serving import (DECODE, FINISHED, WAITING, BlockPool, Request,
                           Scheduler, TRASH_BLOCK)

# --------------------------------------------------------------- block pool


def test_pool_alloc_free_roundtrip():
    pool = BlockPool(num_blocks=8)          # block 0 reserved
    assert pool.num_free == 7
    a = pool.alloc(3)
    assert len(a) == 3 and TRASH_BLOCK not in a
    assert pool.num_free == 4 and pool.num_used == 3
    b = pool.alloc(4)
    assert pool.num_free == 0
    assert pool.alloc(1) is None            # exhausted
    pool.free(a)
    assert pool.num_free == 3
    pool.free(b)
    assert pool.num_free == 7 and pool.num_used == 0


def test_pool_alloc_is_all_or_nothing():
    pool = BlockPool(num_blocks=4)
    assert pool.alloc(5) is None
    assert pool.num_free == 3               # state unchanged on failure
    got = pool.alloc(3)
    assert sorted(got) == [1, 2, 3]


def test_pool_rejects_bad_frees():
    pool = BlockPool(num_blocks=4)
    blocks = pool.alloc(2)
    pool.free(blocks)
    with pytest.raises(ValueError):
        pool.free(blocks)                   # double free
    with pytest.raises(ValueError):
        pool.free([TRASH_BLOCK])            # trash page is not freeable


# ---------------------------------------------------------------- scheduler


def _sched(num_blocks=16, max_batch=2, max_nb=8, bs=8):
    return Scheduler(BlockPool(num_blocks), max_batch=max_batch,
                     max_blocks_per_seq=max_nb, block_size=bs)


def test_scheduler_admission_is_fcfs_and_slot_gated():
    s = _sched(max_batch=2)
    reqs = [Request(prompt=[1] * 8, max_new_tokens=4, arrival=0.1 * i)
            for i in range(3)]
    for r in reqs:
        s.submit(r)
    first = s.try_admit(now=1.0)
    second = s.try_admit(now=1.0)
    assert (first.rid, second.rid) == (reqs[0].rid, reqs[1].rid)
    assert s.try_admit(now=1.0) is None     # both slots taken
    assert first.state == "prefill" and first.blocks
    s.activate(first)
    s.activate(second)
    s.finish(first, now=2.0)
    assert first.state == FINISHED and first.blocks == []
    third = s.try_admit(now=2.0)            # freed slot admits the queue head
    assert third.rid == reqs[2].rid


def test_scheduler_respects_arrival_times():
    s = _sched()
    r = Request(prompt=[1] * 8, max_new_tokens=4, arrival=5.0)
    s.submit(r)
    assert s.try_admit(now=1.0) is None     # not arrived yet
    assert s.try_admit(now=5.0) is not None


def test_scheduler_admission_accounts_free_blocks():
    # pool of 3 usable blocks; a 2-block prompt + 1 headroom fits, but a
    # second identical request must wait until the first frees its blocks.
    s = _sched(num_blocks=4, max_batch=2, bs=8)
    a = Request(prompt=[1] * 16, max_new_tokens=4, arrival=0.0)
    b = Request(prompt=[2] * 16, max_new_tokens=4, arrival=0.0)
    s.submit(a)
    s.submit(b)
    got = s.try_admit(now=0.0)
    assert got.rid == a.rid
    assert s.try_admit(now=0.0) is None     # blocks exhausted, slot free
    s.activate(a)
    s.finish(a, now=1.0)
    assert s.try_admit(now=1.0).rid == b.rid


def test_scheduler_preempts_lru_on_block_exhaustion():
    # 5 usable blocks, two 2-block requests admitted (4 used, 1 free);
    # both then need a 3rd block -> the LRU one is preempted, requeued
    # with its generated tokens intact, and its blocks are freed.
    s = _sched(num_blocks=6, max_batch=2, bs=8)
    a = Request(prompt=[1] * 16, max_new_tokens=20, arrival=0.0)
    b = Request(prompt=[2] * 16, max_new_tokens=20, arrival=0.1)
    s.submit(a)
    s.submit(b)
    for r in (s.try_admit(1.0), s.try_admit(1.0)):
        s.activate(r)
    a.generated = [7, 8]
    b.generated = [9]
    a.pos = 18                              # wants block 3 (covers idx 18)
    b.pos = 17
    runnable = s.ensure_decode_blocks()
    assert len(runnable) == 1               # one survivor, one preempted
    preempted, survivor = (a, b) if a.state == WAITING else (b, a)
    assert survivor.state == DECODE and len(survivor.blocks) == 3
    assert preempted.blocks == [] and preempted.preemptions == 1
    assert preempted in s.waiting
    # generated tokens preserved and folded into the re-prefill prompt
    assert preempted.effective_prompt[:16] == preempted.prompt
    assert len(preempted.effective_prompt) == 16 + len(preempted.generated)


def test_scheduler_admits_pool_filling_request_without_headroom():
    # lifetime blocks == prompt blocks == whole pool: no decode block will
    # ever be needed, so admission must not demand +1 headroom (it used to,
    # leaving the request unadmittable forever -> engine spin).
    s = _sched(num_blocks=4, max_batch=1, bs=8)
    r = Request(prompt=[1] * 22, max_new_tokens=2, arrival=0.0)
    s.submit(r)
    got = s.try_admit(now=0.0)
    assert got is r and len(r.blocks) == 3


def test_scheduler_rejects_unservable_requests():
    s = _sched(num_blocks=4, max_nb=64, bs=8)
    with pytest.raises(ValueError):         # needs more than the whole pool
        s.submit(Request(prompt=[1] * 64, max_new_tokens=8, arrival=0.0))
    with pytest.raises(ValueError):         # exceeds per-seq block table
        _sched(max_nb=2).submit(
            Request(prompt=[1] * 32, max_new_tokens=8, arrival=0.0))


# ------------------------------------------------- continuous-engine parity


def _smoke_cfg(backend):
    from repro.configs import get_config
    return get_config("stablelm-12b").smoke().replace(
        attention_backend=backend)


@pytest.mark.parametrize("backend", ["socket", "dense", "hard_lsh",
                                     "quest"])
def test_continuous_matches_static_same_length(backend):
    """Same-length requests through the paged ragged engine reproduce the
    static lockstep engine token-for-token (same params, same prompts) —
    for every paged-capable backend plus the dense gather fallback."""
    import jax
    from repro.launch.serve import run_serve
    from repro.serving.engine import ContinuousBatchingEngine

    cfg = _smoke_cfg(backend)
    batch, plen, steps = 3, 24, 8
    rng = np.random.default_rng(0)
    prompts = rng.integers(0, cfg.vocab_size, size=(batch, plen))
    static_toks, _, _ = run_serve(cfg, batch, plen, steps, seed=0,
                                  prompt=prompts)

    engine = ContinuousBatchingEngine(cfg, rng=jax.random.PRNGKey(0))
    reqs = [Request(prompt=prompts[i].tolist(),
                    max_new_tokens=steps + 1, arrival=0.0)
            for i in range(batch)]
    engine.run(reqs, realtime=False)

    static_toks = np.asarray(static_toks)
    for i, r in enumerate(reqs):
        assert r.state == FINISHED
        assert r.generated == static_toks[i].tolist(), (
            f"request {i}: {r.generated} != {static_toks[i].tolist()}")


@pytest.mark.parametrize("backend", ["socket", "hard_lsh", "quest"])
def test_continuous_mixed_lengths_match_per_request_static(backend):
    """Ragged batch of different prompt lengths: every request must decode
    exactly as if it were served alone by the static engine (all
    paged-capable backends)."""
    import jax
    from repro.launch.serve import run_serve
    from repro.serving.engine import ContinuousBatchingEngine

    cfg = _smoke_cfg(backend)
    steps = 6
    rng = np.random.default_rng(1)
    plens = [8, 24]
    prompts = [rng.integers(0, cfg.vocab_size, size=(1, p)) for p in plens]

    refs = []
    for pr in prompts:
        toks, _, _ = run_serve(cfg, 1, pr.shape[1], steps, seed=0,
                               prompt=pr)
        refs.append(np.asarray(toks)[0].tolist())

    engine = ContinuousBatchingEngine(cfg, rng=jax.random.PRNGKey(0))
    reqs = [Request(prompt=pr[0].tolist(), max_new_tokens=steps + 1,
                    arrival=0.0) for pr in prompts]
    engine.run(reqs, realtime=False)
    for r, ref in zip(reqs, refs):
        assert r.generated == ref, (r.generated, ref)


def test_continuous_engine_preemption_end_to_end():
    """A pool too small for the full working set forces preemption; every
    request must still finish with the full token budget AND the exact
    token sequence an unpressured pool produces (recompute-resume goes
    through the sparse decode path, not the prefill logits)."""
    import jax
    from repro.serving.engine import ContinuousBatchingEngine

    cfg = _smoke_cfg("socket")
    rng = np.random.default_rng(2)
    prompts = [rng.integers(0, cfg.vocab_size, size=16).tolist()
               for _ in range(2)]

    def serve(num_blocks):
        eng = ContinuousBatchingEngine(
            cfg.replace(serving=cfg.serving.replace(
                num_blocks=num_blocks, max_batch=2)),
            rng=jax.random.PRNGKey(0))
        reqs = [Request(prompt=p, max_new_tokens=24, arrival=0.0)
                for p in prompts]
        metrics = eng.run(reqs, realtime=False)
        return eng, reqs, metrics

    # 8 usable blocks; two requests each admitted at 2 prompt blocks but
    # growing to 5 over 24 generated tokens (10 total > 8) -> exhaustion.
    engine, reqs, metrics = serve(num_blocks=9)
    for r in reqs:
        assert r.state == FINISHED and len(r.generated) == 24
    assert metrics.preemptions > 0          # the pool really was too small
    assert engine.pool.num_used == 0        # everything returned

    _, calm_reqs, calm_metrics = serve(num_blocks=48)
    assert calm_metrics.preemptions == 0
    for pressured, calm in zip(reqs, calm_reqs):
        assert pressured.generated == calm.generated


def test_engine_rejects_unsupported_configs():
    import dataclasses

    from repro.configs import get_config
    from repro.serving.engine import ContinuousBatchingEngine

    with pytest.raises(NotImplementedError):   # embeddings-input frontend
        ContinuousBatchingEngine(get_config("musicgen-medium").smoke())
    with pytest.raises(ValueError):            # unregistered backend name
        ContinuousBatchingEngine(_smoke_cfg("flashinfer"))
    cfg = _smoke_cfg("quest")                  # page/block geometry clash
    with pytest.raises(ValueError):
        ContinuousBatchingEngine(cfg.replace(
            quest=dataclasses.replace(cfg.quest, page_size=3)))


def test_scheduler_per_kind_block_accounting():
    """The host half of the per-layer cache plan: sliding-window-only
    demand is capped at the circular page list (never more than
    ceil(window/block_size)+1 blocks per slot), SSM-only models hold no
    blocks and are admitted on decode slots alone."""
    from repro.serving.block_pool import BlockPool

    ring = Scheduler(BlockPool(16), max_batch=2, max_blocks_per_seq=8,
                     block_size=8, has_paged_layers=False, ring_blocks=4)
    r = Request(prompt=[1] * 8, max_new_tokens=200, arrival=0.0)
    ring.submit(r)
    ring.activate(ring.try_admit(0.0))
    for step in range(200):                    # pos 8 .. 207
        runnable = ring.ensure_decode_blocks()
        assert runnable == [r]
        assert len(r.blocks) <= 4              # == ceil(32/8) <= +1 bound
        r.pos += 1
    assert len(r.blocks) == 4
    assert ring.pool.num_used == 4             # bounded despite 200 tokens

    ssm = Scheduler(BlockPool(2), max_batch=2, max_blocks_per_seq=8,
                    block_size=8, has_paged_layers=False, ring_blocks=0)
    a = Request(prompt=[1] * 64, max_new_tokens=100, arrival=0.0)
    ssm.submit(a)                              # 1-usable-block pool: fine
    got = ssm.try_admit(0.0)
    assert got is a and a.blocks == []
    ssm.activate(a)
    a.pos = 500
    assert ssm.ensure_decode_blocks() == [a] and a.blocks == []
    ssm.finish(a, 0.0)
    assert ssm.pool.num_used == 0


# ----------------------------------------------------------------- sampling


def test_sample_tokens_top_p_and_masking():
    """Unit contract of the jitted sampler: tiny top-p degenerates to
    argmax, padded-vocab ids are never emitted, and per-slot keys make
    the stream deterministic."""
    import jax
    import jax.numpy as jnp

    from repro.serving import sampling

    rng = np.random.default_rng(0)
    logits = jnp.asarray(rng.normal(size=(3, 16)), jnp.float32)
    keys = sampling.slot_keys(0, 3)

    tok, keys2 = sampling.sample_tokens(logits, keys, temperature=0.7,
                                        top_p=1e-9, vocab_size=16)
    assert tok.tolist() == np.argmax(np.asarray(logits), -1).tolist()
    assert keys2.shape == keys.shape and not np.array_equal(
        np.asarray(keys2), np.asarray(keys))

    # vocab padded 16 -> 24: the tail must never be sampled
    padded = jnp.pad(logits, ((0, 0), (0, 8)), constant_values=50.0)
    for i in range(20):
        k = sampling.slot_keys(i, 3)
        tok, _ = sampling.sample_tokens(padded, k, temperature=2.0,
                                        top_p=1.0, vocab_size=16)
        assert int(jnp.max(tok)) < 16

    t1, _ = sampling.sample_tokens(logits, keys, temperature=1.0,
                                   top_p=0.9, vocab_size=16)
    t2, _ = sampling.sample_tokens(logits, keys, temperature=1.0,
                                   top_p=0.9, vocab_size=16)
    assert t1.tolist() == t2.tolist()          # same keys, same draw


def test_continuous_engine_sampling_smoke():
    """temperature/top-p serving: deterministic per seed, sensitive to
    the seed, ids in-vocab; greedy default is covered bit-exactly by the
    static-parity tests above."""
    import jax

    cfg = _smoke_cfg("socket")
    rng = np.random.default_rng(5)
    prompt = rng.integers(0, cfg.vocab_size, size=12).tolist()

    def serve(seed):
        from repro.serving.engine import ContinuousBatchingEngine
        eng = ContinuousBatchingEngine(cfg, rng=jax.random.PRNGKey(0),
                                       temperature=0.8, top_p=0.95,
                                       sample_seed=seed)
        reqs = [Request(prompt=list(prompt), max_new_tokens=6,
                        arrival=0.0)]
        eng.run(reqs, realtime=False)
        return reqs[0].generated

    a, b, c = serve(0), serve(0), serve(7)
    assert a == b
    assert a != c
    assert all(0 <= t < cfg.vocab_size for t in a)


def test_paged_engine_never_materializes_kv_views():
    """With a paged-capable backend the engine must not gather contiguous
    K/V views: per decode step only the metadata leaves are materialized
    and K/V rows are gathered at the static top-k count."""
    import jax
    from repro.core import socket as sk
    from repro.models import backends as bk
    from repro.serving.engine import ContinuousBatchingEngine

    cfg = _smoke_cfg("socket")
    engine = ContinuousBatchingEngine(cfg, rng=jax.random.PRNGKey(0))
    assert engine.backend.supports_paged
    rng = np.random.default_rng(3)
    reqs = [Request(prompt=rng.integers(0, cfg.vocab_size, size=12).tolist(),
                    max_new_tokens=4, arrival=0.0) for _ in range(2)]
    bk.gather_trace_reset()
    engine.run(reqs, realtime=False)
    trace = bk.gather_trace()
    assert trace, "paged path not exercised"
    full_leaves = {name for kind, name, _ in trace if kind == "leaf"}
    assert full_leaves <= {"bits", "vnorm"}, full_leaves
    kq = sk.topk_budget(bk.socket_config_of(cfg), cfg.serving.max_context)
    for kind, name, shape in trace:
        if kind == "rows":
            assert name in ("k", "v") and shape[-2] == kq, (name, shape)


def _fused_smoke_cfg(backend):
    """Smoke config with the backend's fused-paged gate flipped
    (hard_lsh shares SOCKET's gate; quest has its own)."""
    import dataclasses

    cfg = _smoke_cfg(backend)
    if backend == "quest":
        return cfg.replace(quest=dataclasses.replace(
            cfg.quest, use_paged_kernel=True))
    return cfg.replace(socket=dataclasses.replace(
        cfg.socket, use_paged_kernel=True))


@pytest.mark.parametrize("backend,fused_name", [
    ("socket", "paged_attention"),
    ("hard_lsh", "paged_hard_lsh"),
    ("quest", "paged_quest"),
])
def test_fused_paged_engine_launches_zero_kv_gathers(backend, fused_name):
    """With the backend's fused-paged gate on, the decode step must not
    materialize *any* logical leaf view and must gather *zero* K/V rows
    — the O(top_k) XLA gathers of the unfused paged path drop to none;
    the fused kernel consumes the pool + block table in place (only the
    "fused" dispatch marker may appear in the trace)."""
    import jax
    from repro.models import backends as bk
    from repro.serving.engine import ContinuousBatchingEngine

    cfg = _fused_smoke_cfg(backend)
    engine = ContinuousBatchingEngine(cfg, rng=jax.random.PRNGKey(0))
    rng = np.random.default_rng(3)
    reqs = [Request(prompt=rng.integers(0, cfg.vocab_size, size=12).tolist(),
                    max_new_tokens=4, arrival=0.0) for _ in range(2)]
    bk.gather_trace_reset()
    engine.run(reqs, realtime=False)
    trace = bk.gather_trace()
    assert trace, "decode step never traced"
    kinds = {kind for kind, _, _ in trace}
    assert kinds == {"fused"}, trace
    assert any(name == fused_name for _, name, _ in trace), trace


@pytest.mark.parametrize("backend", ["socket", "hard_lsh", "quest"])
def test_fused_engine_tokens_match_unfused_paged_engine(backend):
    """The fused kernels are a drop-in routing change: the continuous
    engine must produce the same greedy tokens with and without them."""
    import jax
    from repro.serving.engine import ContinuousBatchingEngine

    rng = np.random.default_rng(11)
    prompts = [rng.integers(0, 250, size=n).tolist() for n in (9, 17, 23)]

    def run(fused):
        cfg = _fused_smoke_cfg(backend) if fused else _smoke_cfg(backend)
        engine = ContinuousBatchingEngine(cfg, rng=jax.random.PRNGKey(0))
        reqs = [Request(prompt=list(p), max_new_tokens=5, arrival=0.0)
                for p in prompts]
        engine.run(reqs, realtime=False)
        return [r.generated for r in reqs]

    assert run(True) == run(False)


def test_ring_fused_hybrid_gathers_no_ring_views_and_matches_tokens():
    """gemma3's sliding-window layers through the fused Pallas ring pass
    (``cfg.use_ring_kernel``): greedy tokens identical to the XLA ring
    path, and the decode trace shows no bounded-window "ring" gathers —
    only the fused dispatch markers (global socket layers keep their
    unfused metadata gathers here)."""
    import jax
    from repro.configs import get_config
    from repro.models import backends as bk
    from repro.serving.engine import ContinuousBatchingEngine

    rng = np.random.default_rng(13)
    prompts = [rng.integers(0, 250, size=n).tolist() for n in (9, 21)]

    def run(ring_fused):
        cfg = get_config("gemma3-27b").smoke().replace(
            use_ring_kernel=ring_fused)
        engine = ContinuousBatchingEngine(cfg, rng=jax.random.PRNGKey(0))
        reqs = [Request(prompt=list(p), max_new_tokens=5, arrival=0.0)
                for p in prompts]
        bk.gather_trace_reset()
        engine.run(reqs, realtime=False)
        return [r.generated for r in reqs], bk.gather_trace()

    toks_off, trace_off = run(False)
    toks_on, trace_on = run(True)
    assert toks_on == toks_off
    assert any(kind == "ring" for kind, _, _ in trace_off), trace_off
    assert not any(kind == "ring" for kind, _, _ in trace_on), trace_on
    assert any(name == "paged_ring" for _, name, _ in trace_on), trace_on
