"""End-to-end behaviour tests for the whole system: train a small model
and watch the loss drop, serve with SOCKET vs dense and compare outputs,
full launcher entry points."""

import json
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.data import DataConfig
from repro.launch.serve import run_serve
from repro.optim import AdamWConfig
from repro.optim.schedule import ScheduleConfig
from repro.runtime.train_loop import Trainer, TrainLoopConfig


def test_end_to_end_training_learns(tmp_path):
    """The synthetic stream plants copy spans; a small model trained a few
    dozen steps must show a substantially decreasing loss."""
    cfg = get_config("minitron-8b").smoke().replace(num_groups=2)
    ocfg = AdamWConfig(schedule=ScheduleConfig(peak_lr=3e-3,
                                               warmup_steps=5,
                                               decay_steps=40))
    loop = TrainLoopConfig(total_steps=40, checkpoint_every=20)
    data = DataConfig(seq_len=64, global_batch=4,
                      vocab_size=cfg.vocab_size, seed=0)
    tr = Trainer(cfg, ocfg, loop, data, str(tmp_path),
                 mesh_factory=lambda d: None)
    log = tr.run()
    first = np.mean([m["loss"] for m in log[:5]])
    last = np.mean([m["loss"] for m in log[-5:]])
    assert last < first - 0.3, (first, last)


@pytest.mark.parametrize("backend", ["socket", "dense"])
def test_serving_pipeline(backend):
    cfg = get_config("stablelm-12b").smoke().replace(
        attention_backend=backend, num_groups=2)
    toks, prefill_s, decode_s = run_serve(cfg, batch=2, prompt_len=64,
                                          decode_steps=8)
    assert toks.shape == (2, 9)
    assert prefill_s > 0 and decode_s > 0


def test_socket_vs_dense_serving_agreement():
    """With moderate sparsity the SOCKET decode trajectory should mostly
    agree with dense decode (greedy tokens)."""
    import dataclasses
    base = get_config("minitron-8b").smoke().replace(num_groups=2)
    sock = dataclasses.replace(base.socket, sparsity=2.0, min_k=64)
    outs = {}
    for backend in ("dense", "socket"):
        cfg = base.replace(attention_backend=backend, socket=sock)
        toks, _, _ = run_serve(cfg, batch=2, prompt_len=64,
                               decode_steps=12, seed=3)
        outs[backend] = np.asarray(toks)
    agree = float(np.mean(outs["dense"] == outs["socket"]))
    assert agree >= 0.5, f"greedy agreement too low: {agree}"


def _repo_root():
    import os
    return os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def test_train_launcher_cli(tmp_path):
    import os
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    proc = subprocess.run(
        [sys.executable, "-m", "repro.launch.train", "--arch",
         "mamba2-780m", "--smoke", "--steps", "8", "--batch", "2",
         "--seq", "64", "--ckpt", str(tmp_path)],
        capture_output=True, text=True, timeout=600, env=env,
        cwd=_repo_root())
    assert proc.returncode == 0, proc.stderr[-2000:]
    out = json.loads(proc.stdout[proc.stdout.index("{"):])
    assert out["steps"] == 8


def test_serve_launcher_cli():
    import os
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    proc = subprocess.run(
        [sys.executable, "-m", "repro.launch.serve", "--arch",
         "gemma-7b", "--smoke", "--batch", "1", "--prompt-len", "64",
         "--decode-steps", "4", "--backend", "socket"],
        capture_output=True, text=True, timeout=600, env=env,
        cwd=_repo_root())
    assert proc.returncode == 0, proc.stderr[-2000:]
    out = json.loads(proc.stdout[proc.stdout.index("{"):])
    assert out["decode_tokens_per_s"] > 0
