"""Quickstart: SOCKET in 60 seconds.

Builds the hash index over a batch of keys (Algorithm 1), soft-hashes a
query (Algorithm 2), scores + selects + attends (Algorithm 3), and
compares against dense attention and hard LSH at the same memory budget.

    PYTHONPATH=src python examples/quickstart.py
"""

import jax
import jax.numpy as jnp
import numpy as np

from repro.baselines import hard_lsh, oracle
from repro.core import hashing, socket


def main():
    rng = jax.random.PRNGKey(0)
    d, n = 128, 8192
    b, kvh, g = 1, 2, 4

    print(f"context: {n} tokens, head_dim {d}, {kvh} KV heads x {g} "
          f"q-heads\n")

    # --- a long-context cache with a planted heavy hitter ---------------
    kk, kv, kq, kw = jax.random.split(rng, 4)
    keys = jax.random.normal(kk, (b, kvh, n, d))
    values = jax.random.normal(kv, (b, kvh, n, d))
    target = 4321
    q = 2.5 * keys[:, :, target][:, :, None, None, :] + \
        0.3 * jax.random.normal(kq, (b, kvh, g, 1, d))

    # --- Algorithm 1: prefill-time index (600-bit/token) ------------------
    cfg = socket.SocketConfig(num_planes=10, num_tables=60, tau=0.4,
                              sparsity=16.0, sink_tokens=16,
                              window_tokens=16, min_k=64)
    w = hashing.make_hash_params(kw, d, cfg.num_planes, cfg.num_tables)
    side = socket.precompute_key_hashes(cfg, w, keys, values)
    bits_per_token = side.bits.shape[-1] * 32
    print(f"index built: {bits_per_token} bits/token "
          f"(vs {d*16} bits of bf16 keys = "
          f"{d*16/bits_per_token:.1f}x traffic reduction)")

    # --- Algorithms 2+3: sparse decode attention -------------------------
    out = socket.socket_attend(cfg, w, q, keys, values, side, length=n,
                               scale=1 / np.sqrt(d))
    ref = oracle.dense_attention(q, keys, values, scale=1 / np.sqrt(d),
                                 length=n)
    rel = float(jnp.linalg.norm(out - ref) / jnp.linalg.norm(ref))
    budget = socket.topk_budget(cfg, n)
    print(f"SOCKET:  attended {budget}/{n} tokens "
          f"({n/budget:.0f}x sparsity), rel err vs dense = {rel:.4f}")

    # --- the scoring itself: does it find the heavy hitter? --------------
    u = socket.soft_hash_query(w, q[0, 0, 0, 0])
    scores = socket.soft_scores_factorized(cfg, side.bits[0, 0], u)
    print(f"SOCKET:  heavy hitter rank = "
          f"{int(jnp.sum(scores > scores[target]))} of {n}")

    # --- hard LSH at the same budget --------------------------------------
    hcfg = hard_lsh.HardLSHConfig(num_planes=10, num_tables=60)
    hst = hard_lsh.build(hcfg, kw, keys[0, 0], values[0, 0])
    hs = hard_lsh.score(hst, hcfg, q[0, 0, 0, 0])
    print(f"hardLSH: heavy hitter rank = "
          f"{int(jnp.sum(hs > hs[target]))} of {n} (same 600-bit budget; "
          f"max collision count = {int(hs.max())} of {hcfg.num_tables})")


if __name__ == "__main__":
    main()
