"""Long-context serving with SOCKET sparse decode.

Prefills a batch of long prompts (building the SOCKET bit-cache alongside
the KV cache), then decodes with sparse attention, reporting per-phase
timing and the SOCKET-vs-dense greedy-token agreement.

    PYTHONPATH=src python examples/serve_longcontext.py \
        --arch stablelm-12b --prompt-len 1024 --decode-steps 32
"""

import argparse
import dataclasses
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.launch.serve import run_serve


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="stablelm-12b")
    ap.add_argument("--batch", type=int, default=2)
    ap.add_argument("--prompt-len", type=int, default=1024)
    ap.add_argument("--decode-steps", type=int, default=32)
    ap.add_argument("--sparsity", type=float, default=8.0)
    args = ap.parse_args()

    base = get_config(args.arch).smoke().replace(num_groups=2)
    sock = dataclasses.replace(base.socket, sparsity=args.sparsity,
                               min_k=64, sink_tokens=32, window_tokens=32)

    results = {}
    for backend in ("dense", "socket"):
        cfg = base.replace(attention_backend=backend, socket=sock)
        toks, prefill_s, decode_s = run_serve(
            cfg, args.batch, args.prompt_len, args.decode_steps, seed=5)
        results[backend] = {
            "tokens": np.asarray(toks),
            "prefill_s": prefill_s,
            "decode_s": decode_s,
            "decode_tok_per_s": args.batch * args.decode_steps / decode_s,
        }

    agree = float(np.mean(results["dense"]["tokens"] ==
                          results["socket"]["tokens"]))
    budget = max(64, int(np.ceil((args.prompt_len + args.decode_steps)
                                 / args.sparsity)))
    print(json.dumps({
        "arch": args.arch,
        "context": args.prompt_len,
        "sparsity": f"{args.sparsity}x "
                    f"(~{budget} of {args.prompt_len} tokens attended)",
        "dense": {k: round(v, 3) for k, v in results["dense"].items()
                  if k != "tokens"},
        "socket": {k: round(v, 3) for k, v in results["socket"].items()
                   if k != "tokens"},
        "greedy_agreement": agree,
        "note": "greedy agreement on an UNTRAINED model is a noise-level "
                "metric (near-flat logits flip argmax on tiny diffs); the "
                "attention-output fidelity benchmarks "
                "(benchmarks/bench_accuracy.py) measure the real quantity",
    }, indent=2))


if __name__ == "__main__":
    main()
