"""End-to-end training driver: a ~100M-parameter decoder trained for a few
hundred steps on the synthetic copy-task stream, with checkpointing and
mid-run restore.

CPU-friendly default (~21M params, 120 steps):

    PYTHONPATH=src python examples/train_small_lm.py

The full ~100M/300-step configuration (what you'd run on accelerators):

    PYTHONPATH=src python examples/train_small_lm.py --full
"""

import argparse
import json
import tempfile

from repro.configs.base import LayerSpec, ModelConfig
from repro.data import DataConfig
from repro.optim import AdamWConfig
from repro.optim.schedule import ScheduleConfig
from repro.runtime.train_loop import Trainer, TrainLoopConfig


def model_config(full: bool) -> ModelConfig:
    if full:
        return ModelConfig(
            name="lm-100m", family="dense", d_model=640, num_heads=10,
            num_kv_heads=10, head_dim=64, d_ff=2560, vocab_size=32768,
            pattern=(LayerSpec(),), num_groups=10,
            attention_backend="dense")
    return ModelConfig(
        name="lm-21m", family="dense", d_model=384, num_heads=6,
        num_kv_heads=6, head_dim=64, d_ff=1536, vocab_size=8192,
        pattern=(LayerSpec(),), num_groups=6, attention_backend="dense")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--steps", type=int, default=None)
    ap.add_argument("--ckpt", default=None)
    args = ap.parse_args()

    cfg = model_config(args.full)
    steps = args.steps or (300 if args.full else 120)
    seq = 512 if args.full else 128
    batch = 16 if args.full else 4
    ckpt_dir = args.ckpt or tempfile.mkdtemp(prefix="repro_lm_")

    print(f"model: {cfg.name} ({cfg.param_count()/1e6:.0f}M params), "
          f"{steps} steps x {batch}x{seq} tokens -> {ckpt_dir}")

    ocfg = AdamWConfig(schedule=ScheduleConfig(
        peak_lr=6e-4, warmup_steps=max(10, steps // 20),
        decay_steps=steps))
    loop = TrainLoopConfig(total_steps=steps,
                           checkpoint_every=max(25, steps // 6))
    data = DataConfig(seq_len=seq, global_batch=batch,
                      vocab_size=cfg.vocab_size, seed=0, copy_prob=0.7)

    trainer = Trainer(cfg, ocfg, loop, data, ckpt_dir)
    log = trainer.run()

    window = max(5, steps // 20)
    print(json.dumps({
        "loss_first": sum(m["loss"] for m in log[:window]) / window,
        "loss_last": sum(m["loss"] for m in log[-window:]) / window,
        "mean_step_s": round(trainer.straggler.mean_latency, 3),
        "checkpoints": trainer.ckpt.all_steps(),
    }, indent=2))

    # demonstrate exact restore: a new Trainer resumes from the checkpoint
    resumed = Trainer(cfg, ocfg,
                      TrainLoopConfig(total_steps=steps + 5,
                                      checkpoint_every=1000),
                      data, ckpt_dir)
    assert resumed.step == steps, "restore did not pick up the final step"
    resumed.run()
    print(f"resumed cleanly from step {steps} -> {resumed.step}")


if __name__ == "__main__":
    main()
