"""Fault-tolerance drill: inject a mid-run failure + device loss, watch the
supervisor restore from checkpoint onto a smaller mesh and finish.

Run with 8 simulated devices:

    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
        PYTHONPATH=src python examples/elastic_recovery.py
"""

import json
import tempfile

import jax
import numpy as np
from jax.sharding import Mesh

from repro.configs import get_config
from repro.data import DataConfig
from repro.optim import AdamWConfig
from repro.optim.schedule import ScheduleConfig
from repro.runtime.fault_tolerance import FailureInjector
from repro.runtime.train_loop import Trainer, TrainLoopConfig


def mesh_factory(devices):
    n = len(devices)
    while n & (n - 1):          # largest power of two
        n -= 1
    if n <= 1:
        return None
    return Mesh(np.asarray(devices[:n]).reshape(n, 1), ("data", "model"))


def main():
    n_dev = len(jax.devices())
    cfg = get_config("minitron-8b").smoke().replace(
        num_groups=2, attention_backend="dense")
    ocfg = AdamWConfig(schedule=ScheduleConfig(peak_lr=1e-3,
                                               warmup_steps=4,
                                               decay_steps=24))
    loop = TrainLoopConfig(total_steps=24, checkpoint_every=6)
    data = DataConfig(seq_len=64, global_batch=8,
                      vocab_size=cfg.vocab_size)

    # step 13: two devices fail (simulated) — the supervisor must restore
    # the step-12 checkpoint onto the 4-device mesh and keep going
    injector = FailureInjector(schedule={13: f"lose_device:{n_dev // 2}"})

    with tempfile.TemporaryDirectory() as ckpt:
        trainer = Trainer(cfg, ocfg, loop, data, ckpt,
                          mesh_factory=mesh_factory, injector=injector)
        before = trainer.mesh.devices.size if trainer.mesh else 1
        log = trainer.run()
        after = trainer.mesh.devices.size if trainer.mesh else 1

    print(json.dumps({
        "devices_before": before,
        "devices_after": after,
        "mesh_rebuilds": trainer.rebuild_count,
        "completed_steps": trainer.step,
        "final_loss": round(log[-1]["loss"], 4),
        "straggler_events": len(trainer.straggler.events),
    }, indent=2))
    assert trainer.rebuild_count >= 1 and trainer.step == 24


if __name__ == "__main__":
    main()
