"""Generate the EXPERIMENTS.md dry-run / roofline markdown tables from the
recorded JSON cells.

    python experiments/make_tables.py [--dir experiments/dryrun]
"""

import argparse
import glob
import json
import os


def load(dirname):
    cells = {}
    for f in glob.glob(os.path.join(dirname, "*.json")):
        rec = json.load(open(f))
        key = os.path.basename(f)[:-5]
        cells[key] = rec
    return cells


def fmt_row(rec, model_flops=None):
    rl = rec.get("roofline")
    if not isinstance(rl, dict):
        return None
    dom = rl["dominant"]
    useful = ""
    if model_flops:
        hlo_global = rl["flops_per_device"] * rec["chips"]
        useful = f"{model_flops / max(hlo_global, 1):.2f}"
    return (f"| {rec['arch']} | {rec['shape']} | "
            f"{rl['compute_s']:.4f} | {rl['memory_s']:.4f} | "
            f"{rl['collective_s']:.4f} | **{dom}** | "
            f"{rec.get('hbm_per_device_gb', float('nan')):.1f} | "
            f"{useful} |")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="experiments/dryrun")
    args = ap.parse_args()
    cells = load(args.dir)

    import sys
    sys.path.insert(0, "src")
    from repro.configs import get_config
    from repro.launch.specs import SHAPES
    from repro.models.transformer import model_flops_per_token

    shapes_order = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]

    for variant, suffix in (("baseline", "__single"),
                            ("optimized", "__single__opt"),
                            ("multi-pod", "__multi")):
        rows = []
        for key, rec in sorted(cells.items()):
            if not key.endswith(suffix):
                continue
            if suffix == "__single" and key.endswith("__single__opt"):
                continue
            cfg = get_config(rec["arch"])
            sh = SHAPES[rec["shape"]]
            training = sh.kind == "train"
            tokens = sh.global_batch * (sh.seq_len if sh.kind != "decode"
                                        else 1)
            mf = model_flops_per_token(cfg, sh.seq_len,
                                       training=training) * tokens
            r = fmt_row(rec, model_flops=mf)
            if r:
                rows.append((rec["arch"],
                             shapes_order.index(rec["shape"]), r))
        rows.sort()
        print(f"\n### {variant} ({len(rows)} cells)\n")
        print("| arch | shape | compute_s | memory_s | collective_s | "
              "dominant | HBM GB/dev | useful-FLOP ratio |")
        print("|---|---|---|---|---|---|---|---|")
        for _, _, r in rows:
            print(r)


if __name__ == "__main__":
    main()
