"""Oracle scorers: exact q.k top-k and dense attention references.

``oracle top-k`` reads the full keys (what SOCKET avoids) and provides the
ground-truth ranking used by the fig. 2 metrics (precision / Jaccard /
NDCG) and by the accuracy benchmarks' recall computations.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

__all__ = ["OracleState", "build", "score", "dense_attention"]


@dataclasses.dataclass
class OracleState:
    keys: jax.Array  # (..., N, d)


def build(cfg, rng: jax.Array, keys: jax.Array, values: jax.Array
          ) -> OracleState:
    del cfg, rng, values
    return OracleState(keys=keys)


def score(state: OracleState, q: jax.Array) -> jax.Array:
    """Exact inner products ``(..., N)``."""
    return jnp.einsum("...nd,...d->...n", state.keys.astype(jnp.float32),
                      q.astype(jnp.float32))


def dense_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    scale: float, length=None) -> jax.Array:
    """Full softmax attention (decode reference).

    q: (B,KVH,G,T,hd); k/v: (B,KVH,N,hd).  ``length`` may be a scalar or a
    ``(B,)`` vector of per-request lengths (ragged serving batch).
    """
    logits = jnp.einsum("bhgtd,bhnd->bhgtn", q.astype(jnp.float32),
                        k.astype(jnp.float32)) * scale
    if length is not None:
        from repro.core.socket import per_batch
        n = k.shape[2]
        length = per_batch(jnp.asarray(length, jnp.int32), logits.ndim)
        logits = jnp.where(jnp.arange(n) < length, logits, -1e30)
    w = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhgtn,bhnd->bhgtd", w, v.astype(jnp.float32))
    return out.astype(q.dtype)
