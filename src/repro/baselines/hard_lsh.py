"""Traditional (hard) LSH scorer — the paper's central ablation.

Scores keys by the number of tables in which the key's bucket equals the
query's bucket (eq. (3) left):

    s_hard(k_j, q) = sum_l  I[ b_j^(l) == b_q^(l) ]

Same storage as SOCKET (bucket ids / packed sign bits); only the query-side
rule differs.  The paper shows this needs (P=2, L>=300) — i.e. >= 600 bits
and 2.8-4.3x the memory/time — to approach SOCKET's (P=10, L=60) retrieval
quality (Table 2, Table 7).

Implementation note: with the packed ±1 sign bits, a hard collision in
table l is ``all_p(sign_q == sign_k)`` which equals
``sum_p s_q * s_k == P`` — so the hard count is also expressible as a ±1
contraction followed by a threshold, and shares the SOCKET Pallas kernel's
data path (DESIGN.md §2).  tau -> 0 in SOCKET recovers exactly this score
divided by L (Section 5.3), which the tests verify.
"""

from __future__ import annotations

import dataclasses
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.core import hashing, socket

__all__ = ["HardLSHConfig", "build", "score", "attend"]


@dataclasses.dataclass(frozen=True)
class HardLSHConfig:
    num_planes: int = 2
    num_tables: int = 300
    sparsity: float = 10.0
    sink_tokens: int = 128
    window_tokens: int = 128
    min_k: int = 16

    @property
    def bits_per_token(self) -> int:
        return self.num_planes * self.num_tables


@dataclasses.dataclass
class HardLSHState:
    w: jax.Array        # (L, P, d)
    packed: jax.Array   # (..., N, W) uint32
    vnorm: jax.Array    # (..., N)


def build(cfg: HardLSHConfig, rng: jax.Array, keys: jax.Array,
          values: jax.Array) -> HardLSHState:
    """Prefill: identical to SOCKET's Algorithm 1 (hash + pack + vnorm)."""
    d = keys.shape[-1]
    w = hashing.make_hash_params(rng, d, cfg.num_planes, cfg.num_tables)
    signs = hashing.hash_keys_signs(w, keys)
    packed = hashing.pack_signs(signs)
    vnorm = jnp.linalg.norm(values.astype(jnp.float32), axis=-1)
    return HardLSHState(w=w, packed=packed, vnorm=vnorm)


def score(state: HardLSHState, cfg: HardLSHConfig, q: jax.Array) -> jax.Array:
    """Hard collision counts ``(..., N)`` for query ``q (..., d)``.

    ±1-contraction form: collide_l  <=>  (S_k . s_q) == P.
    """
    l, p = cfg.num_tables, cfg.num_planes
    q_signs = jnp.sign(jnp.einsum("...d,lpd->...lp", q.astype(jnp.float32),
                                  state.w.astype(jnp.float32)))
    q_signs = jnp.where(q_signs == 0, 1.0, q_signs)
    k_signs = hashing.unpack_signs(state.packed, l, p)          # (...,N,L,P)
    agree = jnp.einsum("...nlp,...lp->...nl", k_signs, q_signs)
    return jnp.sum(agree >= p, axis=-1).astype(jnp.float32)


def attend(cfg: HardLSHConfig, state: HardLSHState, q: jax.Array,
           k_cache: jax.Array, v_cache: jax.Array, *, length,
           scale: float) -> jax.Array:
    """Decode attention with hard-LSH selection (matches socket_attend API).

    q: (B, KVH, G, 1, hd); caches (B, KVH, N, hd).
    """
    n = k_cache.shape[2]
    kq = max(cfg.min_k, int(jnp.ceil(n / cfg.sparsity)))
    kq = min(kq, n)
    s = score(state, cfg, q[..., 0, :])                  # (B,KVH,G,N)
    s = jnp.sum(s, axis=2)                               # group-sum
    sel_cfg = socket.SocketConfig(
        sparsity=cfg.sparsity, sink_tokens=cfg.sink_tokens,
        window_tokens=cfg.window_tokens, min_k=cfg.min_k)
    idx, sel_mask = socket.value_aware_topk(
        sel_cfg, s, state.vnorm, k=kq, length=length, n_total=n)
    k_sel = jnp.take_along_axis(k_cache, idx[..., None], axis=2)
    v_sel = jnp.take_along_axis(v_cache, idx[..., None], axis=2)
    return socket.sparse_attention_over_subset(q, k_sel, v_sel, sel_mask,
                                               scale=scale)
