"""Sparse-attention baselines the paper compares against (Section 6).

Every baseline exposes the same scorer interface so the benchmark harness
and the model's attention backend can swap them freely:

    build(cfg, rng, keys, values)  -> state  (prefill-time index)
    score(state, q)                -> (..., N) float32 scores

* :mod:`repro.baselines.hard_lsh`   — traditional LSH collision counting
  (the paper's primary ablation, Tables 2/3/7).
* :mod:`repro.baselines.quest`      — Quest page-level min/max bounds [43].
* :mod:`repro.baselines.oracle`     — exact top-k by q.k (upper bound).
* :mod:`repro.baselines.hash_attn`  — HashAttention-style Hamming scorer
  [13] (random signed projections; learned mappings replaced by random,
  matching our data-agnostic evaluation).
* :mod:`repro.baselines.magicpig`   — MagicPig-style LSH importance sampling
  estimator [8] (sampling-based, not top-k).
* :mod:`repro.baselines.pqcache`    — PQCache-lite product quantization [55]
  (data-dependent: k-means codebooks; exists mainly to demonstrate the TTFT
  gap in fig. 3a).
"""

from repro.baselines import hard_lsh, hash_attn, magicpig, oracle, pqcache, quest

__all__ = ["hard_lsh", "hash_attn", "magicpig", "oracle", "pqcache", "quest"]
