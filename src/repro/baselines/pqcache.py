"""PQCache-lite: product-quantization scorer [55].

PQCache quantizes keys with product quantization (PQ): split d into ``m``
sub-vectors, k-means each sub-space into ``2**nbits`` centroids, store
per-key code indices.  Scoring a query = per-subspace inner products with
the codebooks (ADC lookup tables) + code gathers.

The index build is *data-dependent* (k-means over the prefix keys) — this
is exactly the TTFT cost the paper's fig. 3a contrasts with SOCKET's
data-agnostic random projections; ``benchmarks/bench_ttft.py`` measures the
build-time gap.  The k-means here is a few Lloyd iterations, jit-compiled.
"""

from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp

__all__ = ["PQConfig", "build", "score"]


@dataclasses.dataclass(frozen=True)
class PQConfig:
    num_subspaces: int = 16     # m
    nbits: int = 4              # 2**4 = 16 centroids per subspace
    kmeans_iters: int = 8
    sparsity: float = 10.0

    @property
    def num_centroids(self) -> int:
        return 1 << self.nbits

    @property
    def bits_per_token(self) -> int:
        return self.num_subspaces * self.nbits


@dataclasses.dataclass
class PQState:
    codebooks: jax.Array  # (m, C, dsub)
    codes: jax.Array      # (..., N, m) int32


def _kmeans(rng: jax.Array, x: jax.Array, c: int, iters: int) -> jax.Array:
    """Lloyd's algorithm on (N, dsub) points -> (C, dsub) centroids."""
    n = x.shape[0]
    idx = jax.random.choice(rng, n, (c,), replace=n < c)
    cent = x[idx]

    def step(cent, _):
        d2 = jnp.sum((x[:, None] - cent[None]) ** 2, axis=-1)  # (N, C)
        assign = jnp.argmin(d2, axis=-1)
        one_hot = jax.nn.one_hot(assign, c, dtype=x.dtype)     # (N, C)
        counts = jnp.maximum(one_hot.sum(0), 1.0)
        new = (one_hot.T @ x) / counts[:, None]
        # keep old centroid where a cluster went empty
        new = jnp.where((one_hot.sum(0) > 0)[:, None], new, cent)
        return new, None

    cent, _ = jax.lax.scan(step, cent, None, length=iters)
    return cent


@partial(jax.jit, static_argnames=("m", "c", "iters"))
def _build_impl(rng: jax.Array, keys2d: jax.Array, m: int, c: int,
                iters: int):
    n, d = keys2d.shape
    dsub = d // m
    sub = keys2d.reshape(n, m, dsub).transpose(1, 0, 2)        # (m, N, dsub)
    rngs = jax.random.split(rng, m)
    codebooks = jax.vmap(lambda r, x: _kmeans(r, x, c, iters))(rngs, sub)
    d2 = jnp.sum((sub[:, :, None] - codebooks[:, None]) ** 2, axis=-1)
    codes = jnp.argmin(d2, axis=-1).T.astype(jnp.int32)        # (N, m)
    return codebooks, codes


def build(cfg: PQConfig, rng: jax.Array, keys: jax.Array,
          values: jax.Array) -> PQState:
    del values
    *lead, n, d = keys.shape
    if d % cfg.num_subspaces:
        raise ValueError(f"d={d} not divisible by m={cfg.num_subspaces}")
    keys2d = keys.reshape(-1, d).astype(jnp.float32)
    codebooks, codes = _build_impl(rng, keys2d, cfg.num_subspaces,
                                   cfg.num_centroids, cfg.kmeans_iters)
    return PQState(codebooks=codebooks,
                   codes=codes.reshape(*lead, n, cfg.num_subspaces))


def score(state: PQState, cfg: PQConfig, q: jax.Array) -> jax.Array:
    """ADC inner-product estimate ``(..., N)`` for query ``(..., d)``."""
    m, c, dsub = state.codebooks.shape
    qs = q.reshape(*q.shape[:-1], m, dsub).astype(jnp.float32)
    # lookup tables: (..., m, C)
    lut = jnp.einsum("...md,mcd->...mc", qs,
                     state.codebooks.astype(jnp.float32))
    # gather per key code: codes (..., N, m)
    lut_b = lut[..., None, :, :]                                # (...,1,m,C)
    picked = jnp.take_along_axis(lut_b, state.codes[..., None],
                                 axis=-1)[..., 0]               # (...,N,m)
    return picked.sum(-1)
