"""MagicPig-style LSH importance sampling [8].

MagicPig *samples* candidate keys via hard LSH collisions and corrects with
importance weights to build an unbiased estimator of softmax attention —
contrast with SOCKET's deterministic top-k retrieval (paper Section 2).

We reproduce the estimator's skeleton:

  1. candidate set = keys colliding with the query in >= ``min_collisions``
     of L tables (random, query-dependent size);
  2. sampling probability proxy ``p_j ~ (collision_rate_j)`` from the
     SimHash collision identity;
  3. attention estimate  y = sum_{j in C} softmax_w(k_j.q) / p_j * v_j,
     renormalized.

For jit-ability the candidate set is realized as a mask (static shapes).
The paper's Tables 1/8 show this approach collapsing at high sparsity when
dense fallback layers are removed — our accuracy benchmark reproduces that
qualitative behaviour on synthetic data.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.core import hashing

__all__ = ["MagicPigConfig", "build", "attend_estimate"]


@dataclasses.dataclass(frozen=True)
class MagicPigConfig:
    num_planes: int = 8
    num_tables: int = 128       # paper uses ~1024 bits/token budgets
    min_collisions: int = 2     # K in MagicPig's (K, L) scheme
    sparsity: float = 10.0

    @property
    def bits_per_token(self) -> int:
        return self.num_planes * self.num_tables


@dataclasses.dataclass
class MagicPigState:
    w: jax.Array
    packed: jax.Array
    vnorm: jax.Array


def build(cfg: MagicPigConfig, rng: jax.Array, keys: jax.Array,
          values: jax.Array) -> MagicPigState:
    w = hashing.make_hash_params(rng, keys.shape[-1], cfg.num_planes,
                                 cfg.num_tables)
    signs = hashing.hash_keys_signs(w, keys)
    vnorm = jnp.linalg.norm(values.astype(jnp.float32), axis=-1)
    return MagicPigState(w=w, packed=hashing.pack_signs(signs), vnorm=vnorm)


def collision_counts(state: MagicPigState, cfg: MagicPigConfig,
                     q: jax.Array) -> jax.Array:
    q_signs = jnp.sign(jnp.einsum("...d,lpd->...lp", q.astype(jnp.float32),
                                  state.w.astype(jnp.float32)))
    q_signs = jnp.where(q_signs == 0, 1.0, q_signs)
    k_signs = hashing.unpack_signs(state.packed, cfg.num_tables,
                                   cfg.num_planes)
    agree = jnp.einsum("...nlp,...lp->...nl", k_signs, q_signs)
    return jnp.sum(agree >= cfg.num_planes, axis=-1)   # (..., N)


def attend_estimate(cfg: MagicPigConfig, state: MagicPigState, q: jax.Array,
                    keys: jax.Array, values: jax.Array, *, scale: float
                    ) -> jax.Array:
    """Importance-sampled attention estimate for a single query ``(..., d)``.

    keys/values: (..., N, d).  Returns (..., d).
    """
    counts = collision_counts(state, cfg, q)           # (..., N)
    cand = counts >= cfg.min_collisions

    # SimHash collision probability per table: c(theta)^P; estimate from
    # the empirical collision rate (add-one smoothing), then the candidate
    # inclusion probability under L tables ~ 1 - (1 - c^P)^L clipped.
    c_hat = (counts + 1.0) / (cfg.num_tables + 2.0)
    p_incl = 1.0 - jnp.power(1.0 - c_hat, cfg.num_tables)
    p_incl = jnp.clip(p_incl, 1e-6, 1.0)

    logits = jnp.einsum("...nd,...d->...n", keys.astype(jnp.float32),
                        q.astype(jnp.float32)) * scale
    logits = jnp.where(cand, logits, -1e30)
    w = jax.nn.softmax(logits, axis=-1) / p_incl
    w = jnp.where(cand, w, 0.0)
    w = w / jnp.maximum(jnp.sum(w, axis=-1, keepdims=True), 1e-9)
    return jnp.einsum("...n,...nd->...d", w, values.astype(jnp.float32))
