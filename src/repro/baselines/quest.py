"""Quest-style page-level selection [43] (Tang et al., ICML 2024).

At prefill, each page (contiguous block of ``page_size`` tokens) stores the
element-wise min and max of its keys.  At decode, a page's upper bound on
the query-key inner product is

    ub(page) = sum_d max(q_d * min_d, q_d * max_d)

and the top pages by upper bound are attended densely.  Data-dependent only
through the cached statistics (no training), but selection granularity is a
page, not a token — the paper contrasts this with SOCKET's token-level soft
scores.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["QuestConfig", "build", "score_pages", "page_budget",
           "select_tokens", "attend"]


@dataclasses.dataclass(frozen=True)
class QuestConfig:
    page_size: int = 16
    sparsity: float = 10.0
    sink_tokens: int = 128
    window_tokens: int = 128
    min_pages: int = 4

    def bits_per_token(self, d: int) -> int:
        # two bf16 stats vectors per page amortized over the page
        return int(2 * d * 16 / self.page_size)


@dataclasses.dataclass
class QuestState:
    kmin: jax.Array   # (..., n_pages, d)
    kmax: jax.Array   # (..., n_pages, d)


def build(cfg: QuestConfig, rng: jax.Array, keys: jax.Array,
          values: jax.Array) -> QuestState:
    del rng, values
    *lead, n, d = keys.shape
    ps = cfg.page_size
    n_pages = (n + ps - 1) // ps
    pad = n_pages * ps - n
    if pad:
        pad_cfg = [(0, 0)] * (keys.ndim - 2) + [(0, pad), (0, 0)]
        kmin_src = jnp.pad(keys, pad_cfg, constant_values=np.inf)
        kmax_src = jnp.pad(keys, pad_cfg, constant_values=-np.inf)
    else:
        kmin_src = kmax_src = keys
    kmin = kmin_src.reshape(*lead, n_pages, ps, d).min(axis=-2)
    kmax = kmax_src.reshape(*lead, n_pages, ps, d).max(axis=-2)
    return QuestState(kmin=kmin, kmax=kmax)


def score_pages(state: QuestState, q: jax.Array) -> jax.Array:
    """Upper-bound page scores ``(..., n_pages)`` for query ``(..., d)``."""
    qf = q.astype(jnp.float32)[..., None, :]
    lo = qf * state.kmin.astype(jnp.float32)
    hi = qf * state.kmax.astype(jnp.float32)
    return jnp.sum(jnp.maximum(lo, hi), axis=-1)


def token_scores(state: QuestState, cfg: QuestConfig, q: jax.Array,
                 n: int) -> jax.Array:
    """Broadcast page scores back to token granularity (for the shared
    benchmark interface: every token inherits its page's upper bound)."""
    ps = score_pages(state, q)                      # (..., n_pages)
    rep = jnp.repeat(ps, cfg.page_size, axis=-1)
    return rep[..., :n]


def page_budget(cfg: QuestConfig, n_pages: int, n: int) -> int:
    """Static page-selection budget for a token capacity of ``n`` (shared
    by :func:`select_tokens` and the serving gather accounting)."""
    budget_tokens = max(cfg.min_pages * cfg.page_size,
                        int(np.ceil(n / cfg.sparsity)))
    return min(n_pages, max(cfg.min_pages, budget_tokens // cfg.page_size))


def select_tokens(cfg: QuestConfig, state: QuestState, q: jax.Array, *,
                  length, n: int):
    """Top-page selection expanded to token indices for one decode step.

    q: (B,KVH,G,1,hd); ``length`` scalar or per-request ``(B,)`` vector;
    ``n``: token capacity of the cache the indices address.  Sink-prefix
    and trailing-window pages are force-included; pages past ``length``
    are masked out.  Returns (idx ``(B,KVH,k_pages*ps)`` int32, validity
    mask of the same shape).
    """
    from repro.core import socket as sk

    b, kvh = q.shape[:2]
    ps = cfg.page_size
    n_pages = state.kmin.shape[-2]
    k_pages = page_budget(cfg, n_pages, n)

    # explicit G axis on the stats: (B,KVH,1,n_pages,d) against q's
    # (B,KVH,G,·,d) — rank-only broadcasting silently misaligned B with G
    # whenever batch != group size
    state_g = QuestState(kmin=state.kmin[..., None, :, :],
                         kmax=state.kmax[..., None, :, :])
    scores = score_pages(state_g, q[..., 0, :])     # (B,KVH,G,n_pages)
    scores = jnp.sum(scores, axis=2)                # (B,KVH,n_pages)

    # (B,) per-request ragged lengths broadcast against (B,KVH,n_pages)
    length = sk.per_batch(jnp.asarray(length, jnp.int32), 3)
    page_pos = jnp.arange(n_pages, dtype=jnp.int32)
    page_start = page_pos * ps
    valid = page_start < length
    forced = (page_start < cfg.sink_tokens) | (
        page_start >= length - cfg.window_tokens - ps)
    eff = jnp.where(forced, jnp.float32(np.finfo(np.float32).max), scores)
    eff = jnp.where(valid, eff, sk.NEG_INF)
    _, top_pages = jax.lax.top_k(eff, k_pages)       # (B,KVH,k_pages)

    # expand pages to token indices
    offs = jnp.arange(ps, dtype=jnp.int32)
    idx = (top_pages[..., None] * ps + offs).reshape(b, kvh, k_pages * ps)
    idx = jnp.minimum(idx, n - 1)
    return idx, idx < length


def attend(cfg: QuestConfig, state: QuestState, q: jax.Array,
           k_cache: jax.Array, v_cache: jax.Array, *, length,
           scale: float) -> jax.Array:
    """Decode attention over the top pages (q: (B,KVH,G,1,hd))."""
    from repro.core import socket as sk

    idx, sel_mask = select_tokens(cfg, state, q, length=length,
                                  n=k_cache.shape[2])
    k_sel = jnp.take_along_axis(k_cache, idx[..., None], axis=2)
    v_sel = jnp.take_along_axis(v_cache, idx[..., None], axis=2)
    return sk.sparse_attention_over_subset(q, k_sel, v_sel, sel_mask,
                                           scale=scale)
