"""HashAttention-style Hamming scorer [13].

HashAttention maps queries and keys into Hamming space with *learned*
projections and scores by negative Hamming distance over a fixed bit
budget (128 bits/token in the paper's Table 1).  Offline we replace the
learned mapping with random signed projections (the data-agnostic analogue)
— the scoring data path (bit codes + popcount-style agreement) is what
matters for the systems comparison.

Note the relationship to hard LSH with (P=bits, L=1): HashAttention ranks
by *partial* agreement (Hamming similarity), not by exact bucket collision,
so it degrades more gracefully than hard LSH but still quantizes each
plane's evidence to one bit — SOCKET's tanh scores keep the magnitude
information (Lemma 4 discussion).
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.core import hashing

__all__ = ["HashAttnConfig", "build", "score"]


@dataclasses.dataclass(frozen=True)
class HashAttnConfig:
    num_bits: int = 128
    sparsity: float = 10.0

    @property
    def bits_per_token(self) -> int:
        return self.num_bits


@dataclasses.dataclass
class HashAttnState:
    w: jax.Array       # (1, bits, d) — a single "table" of `bits` planes
    packed: jax.Array  # (..., N, W)


def build(cfg: HashAttnConfig, rng: jax.Array, keys: jax.Array,
          values: jax.Array) -> HashAttnState:
    del values
    d = keys.shape[-1]
    w = hashing.make_hash_params(rng, d, cfg.num_bits, 1)
    signs = hashing.hash_keys_signs(w, keys)
    return HashAttnState(w=w, packed=hashing.pack_signs(signs))


def score(state: HashAttnState, cfg: HashAttnConfig, q: jax.Array
          ) -> jax.Array:
    """Hamming similarity = number of agreeing bits, ``(..., N)``."""
    q_signs = jnp.sign(jnp.einsum("...d,lpd->...lp", q.astype(jnp.float32),
                                  state.w.astype(jnp.float32)))
    q_signs = jnp.where(q_signs == 0, 1.0, q_signs)
    k_signs = hashing.unpack_signs(state.packed, 1, cfg.num_bits)
    agree = jnp.einsum("...nlp,...lp->...n", k_signs, q_signs)
    # agree in [-bits, bits]; shift to agreement count
    return (agree + cfg.num_bits) * 0.5
