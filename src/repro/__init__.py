"""SOCKET on TPU: soft-LSH sparse attention as a production JAX framework.

Reproduction of "SOCKET: SOft Collision Kernel EsTimator for Sparse
Attention" (Joshi et al., 2026) — see DESIGN.md for the system inventory
and the TPU adaptation of the paper's CUDA/Triton kernels.
"""

__version__ = "1.0.0"
