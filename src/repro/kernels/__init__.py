"""Pallas TPU kernels for SOCKET's perf-critical paths.

* socket_score    — the paper's CUDA scoring kernel, TPU-adapted
                    (bit-packed streaming + factorized corner softmax,
                    DESIGN.md §2).
* flash_decode    — online-softmax GQA decode over the gathered top-k
                    subset (the paper's Triton Flash-Decode analogue).
* flash_prefill   — causal flash-attention forward for the dense prefill.
* paged_attention — fused score→select→attend over the serving engine's
                    block table (one pass over the paged pool, no score
                    / index / gathered-K/V materialization in HBM).

Each kernel ships ``ops.py`` (jitted wrapper; interpret=True off-TPU) and
``ref.py`` (pure-jnp oracle driven by ``tests/kernel_harness.py``).
See README.md in this directory for the layout contract.
"""

from repro.kernels import (flash_decode, flash_prefill, paged_attention,
                           socket_score)

__all__ = ["flash_decode", "flash_prefill", "paged_attention",
           "socket_score"]
