"""Pallas TPU kernels for SOCKET's perf-critical paths.

* socket_score  — the paper's CUDA scoring kernel, TPU-adapted (bit-packed
                  streaming + factorized corner softmax, DESIGN.md §2).
* flash_decode  — online-softmax GQA decode over the gathered top-k subset
                  (the paper's Triton Flash-Decode backend analogue).
* flash_prefill — causal flash-attention forward for the dense prefill.

Each kernel ships ``ops.py`` (jitted wrapper; interpret=True off-TPU) and
``ref.py`` (pure-jnp oracle used by the allclose test sweeps).
"""

from repro.kernels import flash_decode, flash_prefill, socket_score

__all__ = ["flash_decode", "flash_prefill", "socket_score"]
