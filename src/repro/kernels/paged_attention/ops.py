"""Jitted public wrapper for the fused SOCKET paged-attention kernel.

Accepts the serving engine's natural layouts (5-D decode query, paged
pool leaves, per-request block table / length / budget vectors) and
launches :func:`paged_attention_pallas`; on non-TPU backends the kernel
runs in interpret mode (bit-exact semantics) — set ``interpret=False``
on real TPU.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels.paged_attention.paged_attention import (
    paged_attention_pallas)


def _auto_interpret() -> bool:
    return jax.default_backend() != "tpu"


@functools.partial(jax.jit, static_argnames=(
    "num_tables", "num_planes", "tau", "scale", "sink_tokens",
    "window_tokens", "interpret", "with_selection"))
def _attend_flat(q, k_pages, v_pages, bits_pages, vnorm_pages, u, bt,
                 length, budget, *, num_tables, num_planes, tau, scale,
                 sink_tokens, window_tokens, interpret, with_selection):
    return paged_attention_pallas(
        q, k_pages, v_pages, bits_pages, vnorm_pages, u, bt, length, budget,
        num_tables=num_tables, num_planes=num_planes, tau=tau, scale=scale,
        sink_tokens=sink_tokens, window_tokens=window_tokens,
        interpret=interpret, with_selection=with_selection)


def paged_socket_attend(q: jax.Array, k_pages: jax.Array, v_pages: jax.Array,
                        bits_pages: jax.Array, vnorm_pages: jax.Array,
                        u: jax.Array, block_table: jax.Array, *,
                        length, budget, num_tables: int, num_planes: int,
                        tau: float, scale: float, sink_tokens: int,
                        window_tokens: int,
                        interpret: Optional[bool] = None,
                        with_selection: bool = False):
    """Fused score→select→attend over the paged pool for one decode step.

    Shapes:
      q            (B, KVH, G, 1, hd) or (B, KVH, G, hd)
      k/v_pages    (NB, KVH, bs, hd)
      bits_pages   uint32 (NB, KVH, bs, W)
      vnorm_pages  (NB, KVH, bs)
      u            f32 (B, KVH, GS, L, P)  (GS=1 for pooled selection)
      block_table  int32 (B, nb)
      length       int32 scalar or (B,)
      budget       int32 scalar or (B,)  (dynamic top-k budget, <= cap)

    Returns attention output in q's layout (f32), plus the int32
    ``(B, KVH, nb, bs)`` selection mask when ``with_selection``.
    """
    interpret = _auto_interpret() if interpret is None else interpret
    orig5 = q.ndim == 5
    if orig5:
        b, kvh, g, t, hd = q.shape
        assert t == 1
        q = q.reshape(b, kvh, g, hd)
    b = q.shape[0]
    length = jnp.broadcast_to(jnp.asarray(length, jnp.int32), (b,))
    budget = jnp.broadcast_to(jnp.asarray(budget, jnp.int32), (b,))
    out = _attend_flat(
        q, k_pages, v_pages, bits_pages, vnorm_pages, u, block_table,
        length, budget, num_tables=num_tables, num_planes=num_planes,
        tau=float(tau), scale=float(scale), sink_tokens=int(sink_tokens),
        window_tokens=int(window_tokens), interpret=interpret,
        with_selection=with_selection)
    if with_selection:
        out, sel = out
        sel = sel.reshape(*sel.shape[:2], -1).astype(bool)  # (B,KVH,N)
    if orig5:
        out = out[:, :, :, None]                            # (B,KVH,G,1,hd)
    return (out, sel) if with_selection else out
