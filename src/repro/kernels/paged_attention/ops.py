"""Jitted public wrapper for the fused SOCKET paged-attention kernel.

Accepts the serving engine's natural layouts (5-D decode query, paged
pool leaves, per-request block table / length / budget vectors) and
launches :func:`paged_attention_pallas`; on non-TPU backends the kernel
runs in interpret mode (bit-exact semantics) — set ``interpret=False``
on real TPU.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels.paged_attention.paged_attention import (
    paged_attention_pallas)
from repro.kernels.paged_attention.paged_hard_lsh import paged_hard_lsh_pallas
from repro.kernels.paged_attention.paged_quest import paged_quest_pallas
from repro.kernels.paged_attention.paged_ring import paged_ring_pallas


def _auto_interpret() -> bool:
    return jax.default_backend() != "tpu"


@functools.partial(jax.jit, static_argnames=(
    "num_tables", "num_planes", "tau", "scale", "sink_tokens",
    "window_tokens", "interpret", "with_selection"))
def _attend_flat(q, k_pages, v_pages, bits_pages, vnorm_pages, u, bt,
                 length, budget, k_scale, v_scale, *, num_tables,
                 num_planes, tau, scale, sink_tokens, window_tokens,
                 interpret, with_selection):
    return paged_attention_pallas(
        q, k_pages, v_pages, bits_pages, vnorm_pages, u, bt, length, budget,
        num_tables=num_tables, num_planes=num_planes, tau=tau, scale=scale,
        sink_tokens=sink_tokens, window_tokens=window_tokens,
        interpret=interpret, with_selection=with_selection,
        k_scale=k_scale, v_scale=v_scale)


def paged_socket_attend(q: jax.Array, k_pages: jax.Array, v_pages: jax.Array,
                        bits_pages: jax.Array, vnorm_pages: jax.Array,
                        u: jax.Array, block_table: jax.Array, *,
                        length, budget, num_tables: int, num_planes: int,
                        tau: float, scale: float, sink_tokens: int,
                        window_tokens: int,
                        interpret: Optional[bool] = None,
                        with_selection: bool = False,
                        k_scale: Optional[jax.Array] = None,
                        v_scale: Optional[jax.Array] = None):
    """Fused score→select→attend over the paged pool for one decode step.

    Shapes:
      q            (B, KVH, G, 1, hd) or (B, KVH, G, hd)
      k/v_pages    (NB, KVH, bs, hd)  (bf16/int8/fp8 storage)
      bits_pages   uint32 (NB, KVH, bs, W)
      vnorm_pages  (NB, KVH, bs)
      u            f32 (B, KVH, GS, L, P)  (GS=1 for pooled selection)
      block_table  int32 (B, nb)
      length       int32 scalar or (B,)
      budget       int32 scalar or (B,)  (dynamic top-k budget, <= cap)
      k/v_scale    (NB, KVH, bs) per-row dequant scales (quantized pools
                   only — both or neither; dequantized in-kernel)

    Returns attention output in q's layout (f32), plus the int32
    ``(B, KVH, nb, bs)`` selection mask when ``with_selection``.
    """
    interpret = _auto_interpret() if interpret is None else interpret
    orig5 = q.ndim == 5
    if orig5:
        b, kvh, g, t, hd = q.shape
        assert t == 1
        q = q.reshape(b, kvh, g, hd)
    b = q.shape[0]
    length = jnp.broadcast_to(jnp.asarray(length, jnp.int32), (b,))
    budget = jnp.broadcast_to(jnp.asarray(budget, jnp.int32), (b,))
    out = _attend_flat(
        q, k_pages, v_pages, bits_pages, vnorm_pages, u, block_table,
        length, budget, k_scale, v_scale,
        num_tables=num_tables, num_planes=num_planes,
        tau=float(tau), scale=float(scale), sink_tokens=int(sink_tokens),
        window_tokens=int(window_tokens), interpret=interpret,
        with_selection=with_selection)
    if with_selection:
        out, sel = out
        sel = sel.reshape(*sel.shape[:2], -1).astype(bool)  # (B,KVH,N)
    if orig5:
        out = out[:, :, :, None]                            # (B,KVH,G,1,hd)
    return (out, sel) if with_selection else out


@functools.partial(jax.jit, static_argnames=(
    "num_tables", "num_planes", "scale", "sink_tokens", "window_tokens",
    "interpret", "with_selection"))
def _hard_lsh_flat(q, k_pages, v_pages, bits_pages, vnorm_pages, u_signs,
                   bt, length, budget, k_scale, v_scale, *, num_tables,
                   num_planes, scale, sink_tokens, window_tokens, interpret,
                   with_selection):
    return paged_hard_lsh_pallas(
        q, k_pages, v_pages, bits_pages, vnorm_pages, u_signs, bt, length,
        budget, num_tables=num_tables, num_planes=num_planes, scale=scale,
        sink_tokens=sink_tokens, window_tokens=window_tokens,
        interpret=interpret, with_selection=with_selection,
        k_scale=k_scale, v_scale=v_scale)


def paged_hard_lsh_attend(q: jax.Array, k_pages: jax.Array,
                          v_pages: jax.Array, bits_pages: jax.Array,
                          vnorm_pages: jax.Array, u_signs: jax.Array,
                          block_table: jax.Array, *, length, budget,
                          num_tables: int, num_planes: int, scale: float,
                          sink_tokens: int, window_tokens: int,
                          interpret: Optional[bool] = None,
                          with_selection: bool = False,
                          k_scale: Optional[jax.Array] = None,
                          v_scale: Optional[jax.Array] = None):
    """Fused hard-collision score→select→attend for one decode step.

    Same shapes as :func:`paged_socket_attend` except the query-side
    hash is ``u_signs`` — f32 ±1 plane signs ``(B, KVH, GS, L, P)``
    (``where(u >= 0, +1, -1)`` of the soft hash).
    """
    interpret = _auto_interpret() if interpret is None else interpret
    orig5 = q.ndim == 5
    if orig5:
        b, kvh, g, t, hd = q.shape
        assert t == 1
        q = q.reshape(b, kvh, g, hd)
    b = q.shape[0]
    length = jnp.broadcast_to(jnp.asarray(length, jnp.int32), (b,))
    budget = jnp.broadcast_to(jnp.asarray(budget, jnp.int32), (b,))
    out = _hard_lsh_flat(
        q, k_pages, v_pages, bits_pages, vnorm_pages, u_signs, block_table,
        length, budget, k_scale, v_scale,
        num_tables=num_tables, num_planes=num_planes,
        scale=float(scale), sink_tokens=int(sink_tokens),
        window_tokens=int(window_tokens), interpret=interpret,
        with_selection=with_selection)
    if with_selection:
        out, sel = out
        sel = sel.reshape(*sel.shape[:2], -1).astype(bool)  # (B,KVH,N)
    if orig5:
        out = out[:, :, :, None]                            # (B,KVH,G,1,hd)
    return (out, sel) if with_selection else out


@functools.partial(jax.jit, static_argnames=(
    "page_size", "scale", "sink_tokens", "window_tokens", "interpret",
    "with_selection"))
def _quest_flat(q, k_pages, v_pages, kmin_pages, kmax_pages, bt, length,
                page_budget, k_scale, v_scale, *, page_size, scale,
                sink_tokens, window_tokens, interpret, with_selection):
    return paged_quest_pallas(
        q, k_pages, v_pages, kmin_pages, kmax_pages, bt, length,
        page_budget, page_size=page_size, scale=scale,
        sink_tokens=sink_tokens, window_tokens=window_tokens,
        interpret=interpret, with_selection=with_selection,
        k_scale=k_scale, v_scale=v_scale)


def paged_quest_attend(q: jax.Array, k_pages: jax.Array, v_pages: jax.Array,
                       kmin_pages: jax.Array, kmax_pages: jax.Array,
                       block_table: jax.Array, *, length, page_budget,
                       page_size: int, scale: float, sink_tokens: int,
                       window_tokens: int,
                       interpret: Optional[bool] = None,
                       with_selection: bool = False,
                       k_scale: Optional[jax.Array] = None,
                       v_scale: Optional[jax.Array] = None):
    """Fused page-granular Quest select→attend for one decode step.

    Shapes:
      q              (B, KVH, G, 1, hd) or (B, KVH, G, hd)
      k/v_pages      (NB, KVH, bs, hd)  (bf16/int8/fp8 storage)
      kmin/kmax      (NB, KVH, bs / page_size, hd) per-page key bounds
                     (over *dequantized* keys under quantized storage)
      block_table    int32 (B, nb)
      length         int32 scalar or (B,)
      page_budget    int scalar or (B,) — pages to attend (the static
                     ``baselines.quest.page_budget``)
      k/v_scale      (NB, KVH, bs) per-row dequant scales (quantized
                     pools only — both or neither)
    """
    interpret = _auto_interpret() if interpret is None else interpret
    orig5 = q.ndim == 5
    if orig5:
        b, kvh, g, t, hd = q.shape
        assert t == 1
        q = q.reshape(b, kvh, g, hd)
    b = q.shape[0]
    length = jnp.broadcast_to(jnp.asarray(length, jnp.int32), (b,))
    page_budget = jnp.broadcast_to(jnp.asarray(page_budget, jnp.int32), (b,))
    out = _quest_flat(
        q, k_pages, v_pages, kmin_pages, kmax_pages, block_table, length,
        page_budget, k_scale, v_scale,
        page_size=int(page_size), scale=float(scale),
        sink_tokens=int(sink_tokens), window_tokens=int(window_tokens),
        interpret=interpret, with_selection=with_selection)
    if with_selection:
        out, sel = out
        sel = sel.reshape(*sel.shape[:2], -1).astype(bool)  # (B,KVH,N)
    if orig5:
        out = out[:, :, :, None]                            # (B,KVH,G,1,hd)
    return (out, sel) if with_selection else out


@functools.partial(jax.jit, static_argnames=(
    "window", "softcap", "scale", "interpret"))
def _ring_flat(q, k_pages, v_pages, bt, pos, k_scale, v_scale, *, window,
               softcap, scale, interpret):
    return paged_ring_pallas(q, k_pages, v_pages, bt, pos, window=window,
                             softcap=softcap, scale=scale,
                             interpret=interpret,
                             k_scale=k_scale, v_scale=v_scale)


def paged_ring_attend(q: jax.Array, k_pages: jax.Array, v_pages: jax.Array,
                      block_table: jax.Array, *, pos, window: int,
                      softcap: float, scale: float,
                      interpret: Optional[bool] = None,
                      k_scale: Optional[jax.Array] = None,
                      v_scale: Optional[jax.Array] = None):
    """Fused sliding-window decode over the circular page list.

    Shapes:
      q            (B, KVH, G, 1, hd) or (B, KVH, G, hd)
      k/v_pages    (NB, KVH, bs, hd)  (bf16/int8/fp8 storage)
      block_table  int32 (B, ring_blocks) — the ring slice of the table
      pos          int32 scalar or (B,) — the decode token's position
                   (already written to its ring slot)
      k/v_scale    (NB, KVH, bs) per-row dequant scales (quantized pools
                   only — both or neither; dequantized in-kernel)
    """
    interpret = _auto_interpret() if interpret is None else interpret
    orig5 = q.ndim == 5
    if orig5:
        b, kvh, g, t, hd = q.shape
        assert t == 1
        q = q.reshape(b, kvh, g, hd)
    b = q.shape[0]
    pos = jnp.broadcast_to(jnp.asarray(pos, jnp.int32), (b,))
    out = _ring_flat(q, k_pages, v_pages, block_table, pos, k_scale, v_scale,
                     window=int(window), softcap=float(softcap),
                     scale=float(scale), interpret=interpret)
    if orig5:
        out = out[:, :, :, None]                            # (B,KVH,G,1,hd)
    return out
