"""Pallas TPU kernel: fused SOCKET paged decode attention.

One decode step for the serving engine's paged pool: per (request, KV
head) the kernel streams that request's pages **once through VMEM** via
the block table (scalar-prefetch index maps — the same mechanism as
jax's reference ``paged_attention`` kernel) and performs the whole
SOCKET decode pipeline without materializing scores, indices, or
gathered K/V in HBM:

1. **Score pass** (grid phase 0): for each page, unpack the packed hash
   bits in-register, evaluate the factorized soft-collision score
   (identical math to ``kernels/socket_score``), weight by the value
   norms, overlay the forced sink/recency-window ``+FLT_MAX`` and the
   invalid-slot ``-1e30``, and append the per-token effective score to a
   VMEM scratch ring ``eff (nb, block_size)``.  Only the bits/vnorm
   leaves move — at deployment settings ~64x less HBM traffic than K/V.
2. **Select** (phase 1, first page): a 32-step radix descent over the
   sortable-uint32 view of ``eff`` finds the exact ``budget``-th largest
   value (the per-request dynamic top-k budget, ``k_r = clip(ceil(len_r
   / sparsity), min_k, k_cap)``) — a *threshold*, not an index list, so
   nothing round-trips to the host and no index tensor is written.
   Tie counts are resolved in index order to replicate
   ``jax.lax.top_k``'s stable lowest-index-first semantics bit for bit.
3. **Attend pass** (phase 1): rescan the VMEM score ring page by page,
   reconstruct each page's selection mask from the threshold (+ a
   running tie counter in SMEM), and fold the selected rows of the K/V
   pages into a flash-style online softmax (fp32 running ``m, l, acc``
   exactly as ``kernels/flash_decode``), emitting ``acc / l`` on the
   final page.

Selection semantics are the full ``core.socket.value_aware_topk``
contract: sink + recency-window forcing, per-request ragged budgets
under a static cap, trash-page-0 / not-yet-written slots masked by the
per-request length.  The selected *set* is exactly the reference's
(property-tested in ``tests/test_kernels.py``); the attention output
matches the score→top-k→flash_decode composition to accumulation-order
rounding (the fused kernel folds rows in logical order, the unfused
path in selection-rank order).

Grid = (B, KVH, 2, nb) with the page axis innermost (sequential on
TPU); phase 0 is the score pass, phase 1 the attend pass.  Index maps
pin the K/V page index to ``bt[b, 0]`` during the score phase (and the
bits/vnorm index during the attend phase), so Pallas's revisiting
pipeline fetches each page's K/V exactly once.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30
FLT_MAX = float(np.finfo(np.float32).max)


def _sort_key(eff: jax.Array) -> jax.Array:
    """Order-preserving f32 -> uint32 map (radix-select key space)."""
    u = jax.lax.bitcast_convert_type(eff, jnp.uint32)
    neg = (u >> jnp.uint32(31)) == jnp.uint32(1)
    return u ^ jnp.where(neg, jnp.uint32(0xFFFFFFFF), jnp.uint32(0x80000000))


def _fused_kernel(bt_ref, len_ref, bud_ref,                 # scalar prefetch
                  q_ref, bits_ref, vnorm_ref, u_ref, logz_ref, k_ref, v_ref,
                  *rest, num_planes: int, l_pad: int, tau: float,
                  scale: float, sink: int, window: int, block_size: int,
                  num_seq_blocks: int, with_selection: bool,
                  mode: str = "socket", quantized: bool = False):
    if quantized:
        ks_ref, vs_ref = rest[0], rest[1]
        rest = rest[2:]
    if with_selection:
        out_ref, sel_ref = rest[0], rest[1]
        eff_scr, m_scr, l_scr, acc_scr, thr_scr, ties_scr, cnt_scr = rest[2:]
    else:
        out_ref = rest[0]
        eff_scr, m_scr, l_scr, acc_scr, thr_scr, ties_scr, cnt_scr = rest[1:]

    b = pl.program_id(0)
    phase = pl.program_id(2)
    i = pl.program_id(3)
    length = len_ref[b]

    # ---- phase 0: score this page into the VMEM ring --------------------
    @pl.when(phase == 0)
    def _score():
        words = bits_ref[0, 0]                    # (bs, W) uint32
        bs, w = words.shape
        shifts = jax.lax.broadcasted_iota(jnp.uint32, (1, 1, 32), 2)
        bits = (words[:, :, None] >> shifts) & jnp.uint32(1)
        signs = bits.reshape(bs, w * 32).astype(jnp.float32) * 2.0 - 1.0
        signs = signs.reshape(bs, l_pad, num_planes)

        u = u_ref[0, 0]                           # (GS, l_pad, P) f32
        if mode == "socket":
            logz = logz_ref[0, 0]                 # (GS, l_pad)
            # factorized score, same reduction order as the XLA reference:
            # exp(logits - logZ) summed over tables first, then the group
            logits = jnp.einsum("nlp,glp->gnl", signs, u) / tau
            z = jnp.exp(logits - logz[:, None, :])   # (GS, bs, l_pad)
            scores = jnp.sum(jnp.sum(z, axis=-1), axis=0)       # (bs,)
        else:                                     # hard_lsh
            # u holds the query's ±1 plane signs (0 in the padded table
            # slots, so agree < P there and padding never counts); a key
            # collides in a table iff every plane sign agrees — the ±1
            # inner product attains P exactly in that case.
            agree = jnp.einsum("nlp,glp->gnl", signs, u)
            hits = (agree >= jnp.float32(num_planes)).astype(jnp.float32)
            scores = jnp.sum(jnp.sum(hits, axis=-1), axis=0)    # (bs,)
        eff = scores * vnorm_ref[0, 0].astype(jnp.float32)

        pos = (jax.lax.broadcasted_iota(jnp.int32, (bs, 1), 0).reshape(bs)
               + i * block_size)
        forced = (pos < sink) | (pos >= length - window)
        eff = jnp.where(forced, jnp.float32(FLT_MAX), eff)
        eff = jnp.where(pos < length, eff, jnp.float32(NEG_INF))
        eff_scr[i] = eff
        if with_selection:
            sel_ref[0, 0, 0] = jnp.zeros((sel_ref.shape[-1],), jnp.int32)

    # ---- phase 1, first page: radix-select the budget threshold ---------
    @pl.when((phase == 1) & (i == 0))
    def _select():
        keys = _sort_key(eff_scr[...])            # (nb, bs)
        bud = bud_ref[b]

        def body(t, prefix):
            shift = jnp.uint32(31) - t.astype(jnp.uint32)
            cand = prefix | (jnp.uint32(1) << shift)
            cnt = jnp.sum((keys >= cand).astype(jnp.int32))
            return jnp.where(cnt >= bud, cand, prefix)

        # largest T with count(keys >= T) >= budget == the budget-th
        # largest key (attained), built MSB-first
        thr = jax.lax.fori_loop(0, 32, body, jnp.uint32(0))
        thr_scr[0] = thr
        ties_scr[0] = bud - jnp.sum((keys > thr).astype(jnp.int32))
        cnt_scr[0] = 0
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    # ---- phase 1: masked online-softmax over this K/V page --------------
    @pl.when(phase == 1)
    def _attend():
        eff = eff_scr[i]                          # (bs,)
        bs = eff.shape[0]
        keys = _sort_key(eff)
        thr = thr_scr[0]
        gt = keys > thr
        eq = keys == thr
        # stable tie-break by index: position j takes a threshold tie iff
        # (# earlier ties) < ties_needed.  Exclusive prefix count via a
        # strict lower-triangular matmul (no cumsum primitive on Mosaic).
        r = jax.lax.broadcasted_iota(jnp.int32, (bs, bs), 0)
        c = jax.lax.broadcasted_iota(jnp.int32, (bs, bs), 1)
        before = (r < c).astype(jnp.float32)
        prior = jax.lax.dot_general(eq.astype(jnp.float32).reshape(1, bs),
                                    before, (((1,), (0,)), ((), ())))
        tie_rank = cnt_scr[0] + prior.reshape(bs).astype(jnp.int32)
        sel = gt | (eq & (tie_rank < ties_scr[0]))
        sel = sel & (eff > jnp.float32(NEG_INF / 2))
        cnt_scr[0] = cnt_scr[0] + jnp.sum(eq.astype(jnp.int32))
        if with_selection:
            sel_ref[0, 0, 0] = sel.astype(jnp.int32)

        q = q_ref[0, 0].astype(jnp.float32)       # (G, hd)
        k = k_ref[0, 0].astype(jnp.float32)       # (bs, hd)
        v = v_ref[0, 0].astype(jnp.float32)
        if quantized:
            # int8/fp8 pool pages: per-row absmax scales ride along as
            # (bs,) leaves — dequantize in-register, never in HBM.
            k = k * ks_ref[0, 0].astype(jnp.float32)[:, None]
            v = v * vs_ref[0, 0].astype(jnp.float32)[:, None]
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ()))) * scale
        s = jnp.where(sel[None, :], s, NEG_INF)   # (G, bs)

        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new[:, None])
        p = jnp.where(sel[None, :], p, 0.0)
        l_scr[...] = l_scr[...] * alpha + jnp.sum(p, axis=-1)
        acc_scr[...] = acc_scr[...] * alpha[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())))
        m_scr[...] = m_new

        @pl.when(i == num_seq_blocks - 1)
        def _done():
            out_ref[0, 0] = (acc_scr[...] /
                             jnp.maximum(l_scr[...], 1e-30)[:, None]
                             ).astype(out_ref.dtype)


def _fused_call(kernel, q, bits_pages, vnorm_pages, u_pad, logz_pad,
                k_pages, v_pages, block_table, length, budget, *,
                with_selection: bool, interpret: bool,
                k_scale=None, v_scale=None):
    """Shared launch plumbing for the socket/hard_lsh fused kernels: the
    two-phase (score, attend) grid with dual scalar-prefetch index maps
    and the VMEM score ring + online-softmax scratch layout.

    ``k_scale``/``v_scale`` (NB, KVH, bs) ride along as extra attend-phase
    page streams when the K/V pool is quantized (int8/fp8 storage)."""
    b, kvh, g, hd = q.shape
    bs, w = bits_pages.shape[2], bits_pages.shape[3]
    nb = block_table.shape[1]
    gs, l_pad, num_planes = u_pad.shape[2:]

    # K/V pages are pinned to bt[b, 0] during the score phase (and
    # bits/vnorm during the attend phase) so the revisiting pipeline
    # fetches each leaf once per page, not once per phase.
    in_specs = [
        pl.BlockSpec((1, 1, g, hd), lambda b, h, ph, i, *s: (b, h, 0, 0)),
        pl.BlockSpec((1, 1, bs, w),
                     lambda b, h, ph, i, bt, ln, bd: (bt[b, i * (1 - ph)],
                                                      h, 0, 0)),
        pl.BlockSpec((1, 1, bs),
                     lambda b, h, ph, i, bt, ln, bd: (bt[b, i * (1 - ph)],
                                                      h, 0)),
        pl.BlockSpec((1, 1, gs, l_pad, num_planes),
                     lambda b, h, ph, i, *s: (b, h, 0, 0, 0)),
        pl.BlockSpec((1, 1, gs, l_pad),
                     lambda b, h, ph, i, *s: (b, h, 0, 0)),
        pl.BlockSpec((1, 1, bs, hd),
                     lambda b, h, ph, i, bt, ln, bd: (bt[b, i * ph], h, 0, 0)),
        pl.BlockSpec((1, 1, bs, hd),
                     lambda b, h, ph, i, bt, ln, bd: (bt[b, i * ph], h, 0, 0)),
    ]
    operands = [q, bits_pages, vnorm_pages, u_pad, logz_pad,
                k_pages, v_pages]
    if k_scale is not None:
        # per-row dequant scales stream with the K/V pages (attend phase)
        for _ in range(2):
            in_specs.append(pl.BlockSpec(
                (1, 1, bs),
                lambda b, h, ph, i, bt, ln, bd: (bt[b, i * ph], h, 0)))
        operands += [k_scale, v_scale]
    out_shape = [jax.ShapeDtypeStruct((b, kvh, g, hd), jnp.float32)]
    out_specs = [pl.BlockSpec((1, 1, g, hd),
                              lambda b, h, ph, i, *s: (b, h, 0, 0))]
    if with_selection:
        out_shape.append(jax.ShapeDtypeStruct((b, kvh, nb, bs), jnp.int32))
        out_specs.append(pl.BlockSpec((1, 1, 1, bs),
                                      lambda b, h, ph, i, *s: (b, h, i, 0)))

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,
        grid=(b, kvh, 2, nb),
        in_specs=in_specs,
        out_specs=out_specs,
        scratch_shapes=[
            pltpu.VMEM((nb, bs), jnp.float32),    # eff score ring
            pltpu.VMEM((g,), jnp.float32),        # m
            pltpu.VMEM((g,), jnp.float32),        # l
            pltpu.VMEM((g, hd), jnp.float32),     # acc
            pltpu.SMEM((1,), jnp.uint32),         # threshold key
            pltpu.SMEM((1,), jnp.int32),          # ties still to take
            pltpu.SMEM((1,), jnp.int32),          # ties consumed so far
        ],
    )
    out = pl.pallas_call(
        kernel, grid_spec=grid_spec, out_shape=out_shape,
        interpret=interpret,
    )(block_table.astype(jnp.int32), length.astype(jnp.int32),
      budget.astype(jnp.int32), *operands)
    return tuple(out) if with_selection else out[0]


def paged_attention_pallas(q: jax.Array, k_pages: jax.Array,
                           v_pages: jax.Array, bits_pages: jax.Array,
                           vnorm_pages: jax.Array, u: jax.Array,
                           block_table: jax.Array, length: jax.Array,
                           budget: jax.Array, *, num_tables: int,
                           num_planes: int, tau: float, scale: float,
                           sink_tokens: int, window_tokens: int,
                           interpret: bool = True,
                           with_selection: bool = False,
                           k_scale: Optional[jax.Array] = None,
                           v_scale: Optional[jax.Array] = None):
    """Launch the fused kernel.

    Args:
      q:           (B, KVH, G, hd) query heads for this KV head group.
      k/v_pages:   (NB, KVH, bs, hd) paged pool leaves (bf16/int8/fp8).
      k/v_scale:   (NB, KVH, bs) per-row dequant scales — both or neither;
                   when given the attend pass dequantizes in-register.
      bits_pages:  uint32 (NB, KVH, bs, W) packed sign bits.
      vnorm_pages: (NB, KVH, bs) value norms (any float dtype).
      u:           f32 (B, KVH, GS, L, P) query soft-hash (GS=1 pooled).
      block_table: int32 (B, nb) physical block ids (trash-padded).
      length:      int32 (B,) live context length per request.
      budget:      int32 (B,) dynamic top-k budget per request.

    Returns:
      f32 (B, KVH, G, hd) attention output; with ``with_selection`` also
      an int32 (B, KVH, nb, bs) selection mask (test/debug only — it is
      exactly the HBM materialization the production path avoids).
    """
    b, kvh, g, hd = q.shape
    nblocks, _, bs, w = bits_pages.shape
    nb = block_table.shape[1]
    _, _, gs, l, p = u.shape
    if l != num_tables or p != num_planes:
        raise ValueError("u shape mismatch")
    if (w * 32) % num_planes:
        raise ValueError(
            f"packed width {w*32} bits not a multiple of P={num_planes}")
    if k_pages.shape[2] != bs or v_pages.shape[2] != bs \
            or vnorm_pages.shape[2] != bs:
        raise ValueError("page pools disagree on block_size")
    if (k_scale is None) != (v_scale is None):
        raise ValueError("k_scale/v_scale must be given together")
    l_pad = (w * 32) // num_planes

    from repro.core import socket as sk
    logz = sk.log_normalizer(u.astype(jnp.float32), tau)   # (B,KVH,GS,L)
    pad_l = l_pad - l
    u_pad = jnp.pad(u.astype(jnp.float32),
                    ((0, 0), (0, 0), (0, 0), (0, pad_l), (0, 0)))
    logz_pad = jnp.pad(logz, ((0, 0), (0, 0), (0, 0), (0, pad_l)),
                       constant_values=jnp.float32(1e30))

    kernel = functools.partial(
        _fused_kernel, num_planes=num_planes, l_pad=l_pad, tau=float(tau),
        scale=float(scale), sink=int(sink_tokens), window=int(window_tokens),
        block_size=bs, num_seq_blocks=nb, with_selection=with_selection,
        mode="socket", quantized=k_scale is not None)
    return _fused_call(kernel, q, bits_pages, vnorm_pages, u_pad, logz_pad,
                       k_pages, v_pages, block_table, length, budget,
                       with_selection=with_selection, interpret=interpret,
                       k_scale=k_scale, v_scale=v_scale)
