"""Pallas TPU kernel: fused Quest paged decode attention.

One decode step of the Quest baseline over the serving engine's paged
pool, streaming each request's pages once through VMEM via the block
table (the same two-phase scalar-prefetch layout as the fused SOCKET
kernel) with **page-granular** selection:

1. **Score pass** (grid phase 0): each pool block carries ``block_size /
   page_size`` min/max stat rows (the ``kmin``/``kmax`` leaves); the
   per-page upper bound ``sum_d max(q_d * kmin_d, q_d * kmax_d)`` is
   summed over the GQA group and appended to a VMEM page-score ring
   ``eff (nb, pages_per_block)`` with the sink/window ``+FLT_MAX``
   forcing and past-``length`` ``-1e30`` overlays of
   :func:`repro.baselines.quest.select_tokens`.
2. **Select** (phase 1, first block): the 32-step radix descent finds
   the exact ``page_budget``-th largest page score (the shared
   :func:`repro.baselines.quest.page_budget`), ties resolved in flat
   page order to replicate ``jax.lax.top_k``'s stable semantics.
3. **Attend pass** (phase 1): each block's page-selection mask is
   reconstructed from the threshold (+ SMEM tie counter), expanded to
   rows (a row attends iff its page is selected AND its position is
   live), and the selected rows fold into the flash-style online
   softmax.

Unlike SOCKET's token selection, pages past ``length`` are *not*
filtered out of the selection itself — ``lax.top_k`` in the reference
takes ``page_budget`` pages unconditionally and row validity is applied
afterwards (``idx < length``), which the kernel mirrors exactly.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.paged_attention.paged_attention import (
    FLT_MAX, NEG_INF, _sort_key)

__all__ = ["paged_quest_pallas"]


def _quest_kernel(bt_ref, len_ref, bud_ref,                 # scalar prefetch
                  q_ref, kmin_ref, kmax_ref, k_ref, v_ref,
                  *rest, page_size: int, scale: float, sink: int,
                  window: int, block_size: int, num_seq_blocks: int,
                  with_selection: bool, quantized: bool = False):
    if quantized:
        ks_ref, vs_ref = rest[0], rest[1]
        rest = rest[2:]
    if with_selection:
        out_ref, sel_ref = rest[0], rest[1]
        eff_scr, m_scr, l_scr, acc_scr, thr_scr, ties_scr, cnt_scr = rest[2:]
    else:
        out_ref = rest[0]
        eff_scr, m_scr, l_scr, acc_scr, thr_scr, ties_scr, cnt_scr = rest[1:]

    b = pl.program_id(0)
    phase = pl.program_id(2)
    i = pl.program_id(3)
    length = len_ref[b]
    ppb = block_size // page_size

    # ---- phase 0: score this block's pages into the VMEM ring -----------
    @pl.when(phase == 0)
    def _score():
        q = q_ref[0, 0].astype(jnp.float32)       # (G, hd)
        kmin = kmin_ref[0, 0].astype(jnp.float32)  # (ppb, hd)
        kmax = kmax_ref[0, 0].astype(jnp.float32)
        scores = jnp.zeros((ppb,), jnp.float32)
        for gi in range(q_ref.shape[2]):          # static GQA group loop
            qg = q[gi][None, :]                   # (1, hd)
            scores = scores + jnp.sum(
                jnp.maximum(kmin * qg, kmax * qg), axis=-1)
        page_start = (jax.lax.broadcasted_iota(jnp.int32, (ppb, 1), 0)
                      .reshape(ppb) * page_size + i * block_size)
        forced = (page_start < sink) | \
            (page_start >= length - window - page_size)
        eff = jnp.where(forced, jnp.float32(FLT_MAX), scores)
        eff = jnp.where(page_start < length, eff, jnp.float32(NEG_INF))
        eff_scr[i] = eff
        if with_selection:
            sel_ref[0, 0, 0] = jnp.zeros((sel_ref.shape[-1],), jnp.int32)

    # ---- phase 1, first block: radix-select the page-budget threshold ---
    @pl.when((phase == 1) & (i == 0))
    def _select():
        keys = _sort_key(eff_scr[...])            # (nb, ppb)
        bud = bud_ref[b]

        def body(t, prefix):
            shift = jnp.uint32(31) - t.astype(jnp.uint32)
            cand = prefix | (jnp.uint32(1) << shift)
            cnt = jnp.sum((keys >= cand).astype(jnp.int32))
            return jnp.where(cnt >= bud, cand, prefix)

        thr = jax.lax.fori_loop(0, 32, body, jnp.uint32(0))
        thr_scr[0] = thr
        ties_scr[0] = bud - jnp.sum((keys > thr).astype(jnp.int32))
        cnt_scr[0] = 0
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    # ---- phase 1: masked online-softmax over this K/V block -------------
    @pl.when(phase == 1)
    def _attend():
        eff = eff_scr[i]                          # (ppb,)
        keys = _sort_key(eff)
        thr = thr_scr[0]
        gt = keys > thr
        eq = keys == thr
        r = jax.lax.broadcasted_iota(jnp.int32, (ppb, ppb), 0)
        c = jax.lax.broadcasted_iota(jnp.int32, (ppb, ppb), 1)
        before = (r < c).astype(jnp.float32)
        prior = jax.lax.dot_general(eq.astype(jnp.float32).reshape(1, ppb),
                                    before, (((1,), (0,)), ((), ())))
        tie_rank = cnt_scr[0] + prior.reshape(ppb).astype(jnp.int32)
        sel_page = gt | (eq & (tie_rank < ties_scr[0]))
        cnt_scr[0] = cnt_scr[0] + jnp.sum(eq.astype(jnp.int32))

        # expand the page mask to rows via a one-hot matmul (row r belongs
        # to local page r // page_size) — reshape-free for Mosaic
        rr = jax.lax.broadcasted_iota(jnp.int32, (block_size, ppb), 0)
        cc = jax.lax.broadcasted_iota(jnp.int32, (block_size, ppb), 1)
        expand = ((rr // page_size) == cc).astype(jnp.float32)
        row_sel = jax.lax.dot_general(
            expand, sel_page.astype(jnp.float32).reshape(ppb, 1),
            (((1,), (0,)), ((), ()))).reshape(block_size) > 0.5
        pos = (jax.lax.broadcasted_iota(jnp.int32, (block_size, 1), 0)
               .reshape(block_size) + i * block_size)
        sel = row_sel & (pos < length)
        if with_selection:
            sel_ref[0, 0, 0] = sel.astype(jnp.int32)

        q = q_ref[0, 0].astype(jnp.float32)       # (G, hd)
        k = k_ref[0, 0].astype(jnp.float32)       # (bs, hd)
        v = v_ref[0, 0].astype(jnp.float32)
        if quantized:
            # int8/fp8 pool pages: per-row absmax scales ride along as
            # (bs,) leaves — dequantize in-register, never in HBM.  The
            # kmin/kmax stats already bound the *dequantized* keys
            # (cfg.quest.stats_from_quantized), so scoring is untouched.
            k = k * ks_ref[0, 0].astype(jnp.float32)[:, None]
            v = v * vs_ref[0, 0].astype(jnp.float32)[:, None]
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ()))) * scale
        s = jnp.where(sel[None, :], s, NEG_INF)   # (G, bs)

        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new[:, None])
        p = jnp.where(sel[None, :], p, 0.0)
        l_scr[...] = l_scr[...] * alpha + jnp.sum(p, axis=-1)
        acc_scr[...] = acc_scr[...] * alpha[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())))
        m_scr[...] = m_new

        @pl.when(i == num_seq_blocks - 1)
        def _done():
            out_ref[0, 0] = (acc_scr[...] /
                             jnp.maximum(l_scr[...], 1e-30)[:, None]
                             ).astype(out_ref.dtype)


def paged_quest_pallas(q: jax.Array, k_pages: jax.Array, v_pages: jax.Array,
                       kmin_pages: jax.Array, kmax_pages: jax.Array,
                       block_table: jax.Array, length: jax.Array,
                       page_budget: jax.Array, *, page_size: int,
                       scale: float, sink_tokens: int, window_tokens: int,
                       interpret: bool = True,
                       with_selection: bool = False,
                       k_scale=None, v_scale=None):
    """Launch the fused Quest kernel.

    Args:
      q:             (B, KVH, G, hd) query heads for this KV head group.
      k/v_pages:     (NB, KVH, bs, hd) paged pool leaves (bf16/int8/fp8).
      k/v_scale:     (NB, KVH, bs) per-row dequant scales — both or
                     neither; when given the attend pass dequantizes
                     in-register.
      kmin/kmax_pages: (NB, KVH, bs / page_size, hd) per-page key bounds.
      block_table:   int32 (B, nb) physical block ids (trash-padded).
      length:        int32 (B,) live context length per request.
      page_budget:   int32 (B,) pages to select per request (the static
                     ``baselines.quest.page_budget``; vector for launch
                     symmetry with the token kernels).

    Returns:
      f32 (B, KVH, G, hd) attention output; with ``with_selection`` also
      an int32 (B, KVH, nb, bs) selected-rows mask (test/debug only).
    """
    b, kvh, g, hd = q.shape
    bs = k_pages.shape[2]
    nb = block_table.shape[1]
    if v_pages.shape[2] != bs:
        raise ValueError("page pools disagree on block_size")
    if bs % page_size:
        raise ValueError(
            f"page_size {page_size} must divide block_size {bs}")
    ppb = bs // page_size
    if kmin_pages.shape[2] != ppb or kmax_pages.shape[2] != ppb:
        raise ValueError(
            f"kmin/kmax pools must carry {ppb} stat rows per block")
    if (k_scale is None) != (v_scale is None):
        raise ValueError("k_scale/v_scale must be given together")

    kernel = functools.partial(
        _quest_kernel, page_size=int(page_size), scale=float(scale),
        sink=int(sink_tokens), window=int(window_tokens), block_size=bs,
        num_seq_blocks=nb, with_selection=with_selection,
        quantized=k_scale is not None)

    in_specs = [
        pl.BlockSpec((1, 1, g, hd), lambda b, h, ph, i, *s: (b, h, 0, 0)),
        pl.BlockSpec((1, 1, ppb, hd),
                     lambda b, h, ph, i, bt, ln, bd: (bt[b, i * (1 - ph)],
                                                      h, 0, 0)),
        pl.BlockSpec((1, 1, ppb, hd),
                     lambda b, h, ph, i, bt, ln, bd: (bt[b, i * (1 - ph)],
                                                      h, 0, 0)),
        pl.BlockSpec((1, 1, bs, hd),
                     lambda b, h, ph, i, bt, ln, bd: (bt[b, i * ph], h, 0, 0)),
        pl.BlockSpec((1, 1, bs, hd),
                     lambda b, h, ph, i, bt, ln, bd: (bt[b, i * ph], h, 0, 0)),
    ]
    operands = [q, kmin_pages, kmax_pages, k_pages, v_pages]
    if k_scale is not None:
        # per-row dequant scales stream with the K/V pages (attend phase)
        for _ in range(2):
            in_specs.append(pl.BlockSpec(
                (1, 1, bs),
                lambda b, h, ph, i, bt, ln, bd: (bt[b, i * ph], h, 0)))
        operands += [k_scale, v_scale]
    out_shape = [jax.ShapeDtypeStruct((b, kvh, g, hd), jnp.float32)]
    out_specs = [pl.BlockSpec((1, 1, g, hd),
                              lambda b, h, ph, i, *s: (b, h, 0, 0))]
    if with_selection:
        out_shape.append(jax.ShapeDtypeStruct((b, kvh, nb, bs), jnp.int32))
        out_specs.append(pl.BlockSpec((1, 1, 1, bs),
                                      lambda b, h, ph, i, *s: (b, h, i, 0)))

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,
        grid=(b, kvh, 2, nb),
        in_specs=in_specs,
        out_specs=out_specs,
        scratch_shapes=[
            pltpu.VMEM((nb, ppb), jnp.float32),   # page-score ring
            pltpu.VMEM((g,), jnp.float32),        # m
            pltpu.VMEM((g,), jnp.float32),        # l
            pltpu.VMEM((g, hd), jnp.float32),     # acc
            pltpu.SMEM((1,), jnp.uint32),         # threshold key
            pltpu.SMEM((1,), jnp.int32),          # ties still to take
            pltpu.SMEM((1,), jnp.int32),          # ties consumed so far
        ],
    )
    out = pl.pallas_call(
        kernel, grid_spec=grid_spec, out_shape=out_shape,
        interpret=interpret,
    )(block_table.astype(jnp.int32), length.astype(jnp.int32),
      page_budget.astype(jnp.int32), *operands)
    return tuple(out) if with_selection else out[0]
