from repro.kernels.paged_attention.ops import (
    paged_hard_lsh_attend, paged_quest_attend, paged_ring_attend,
    paged_socket_attend)
from repro.kernels.paged_attention.ref import (
    paged_hard_lsh_attend_ref, paged_quest_attend_ref, paged_ring_attend_ref,
    paged_socket_attend_ref)

__all__ = [
    "paged_socket_attend", "paged_socket_attend_ref",
    "paged_hard_lsh_attend", "paged_hard_lsh_attend_ref",
    "paged_quest_attend", "paged_quest_attend_ref",
    "paged_ring_attend", "paged_ring_attend_ref",
]
