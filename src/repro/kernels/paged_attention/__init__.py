from repro.kernels.paged_attention.ops import paged_socket_attend
from repro.kernels.paged_attention.ref import paged_socket_attend_ref

__all__ = ["paged_socket_attend", "paged_socket_attend_ref"]
