"""Pure-jnp oracle for the fused paged-attention kernel.

Materializes the logical per-request views the fused kernel refuses to
build (test-only!), then runs the unfused reference composition the
kernel replaces: factorized soft-collision scoring
(``socket_score_ref``) → ``value_aware_topk`` (sink/window forcing,
ragged lengths, dynamic budgets) → masked softmax attention over the
selected rows (``flash_decode_ref``).

Returns both the attention output and the selected-token mask so tests
can pin the kernel's *selection* exactly while holding the output to a
float tolerance (the kernel folds rows in logical order, the reference
in selection-rank order — same math, different rounding).
"""

from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.core import socket as sk
from repro.kernels.flash_decode.ref import flash_decode_ref
from repro.kernels.socket_score.ref import socket_score_ref


def _logical(pages: jax.Array, bt: jax.Array) -> jax.Array:
    """(NB, KVH, bs, *rest), (B, nb) -> (B, KVH, nb*bs, *rest)."""
    from repro.models.backends.base import gather_block_leaf
    return gather_block_leaf(pages, bt)


def _logical_kv(pages: jax.Array, scale_pages, bt: jax.Array) -> jax.Array:
    """Logical K/V view, dequantized when per-row scales are given."""
    x = _logical(pages, bt)
    if scale_pages is None:
        return x
    s = _logical(scale_pages, bt).astype(jnp.float32)
    return x.astype(jnp.float32) * s[..., None]


def paged_socket_attend_ref(q: jax.Array, k_pages: jax.Array,
                            v_pages: jax.Array, bits_pages: jax.Array,
                            vnorm_pages: jax.Array, u: jax.Array,
                            block_table: jax.Array, *, length, budget,
                            num_tables: int, num_planes: int, tau: float,
                            scale: float, sink_tokens: int,
                            window_tokens: int, top_k: int,
                            k_scale=None,
                            v_scale=None) -> Tuple[jax.Array, jax.Array]:
    """Oracle for :func:`ops.paged_socket_attend`.

    Same shapes as the kernel wrapper plus ``top_k`` — the static
    selection cap (any value >= max(budget); the backend uses
    ``core.socket.topk_budget``).

    Returns ``(out f32 (B, KVH, G, hd), selected bool (B, KVH, N))``.
    """
    if q.ndim == 5:
        q = q[:, :, :, 0]
    b, kvh, g, hd = q.shape
    bits = _logical(bits_pages, block_table)          # (B,KVH,N,W)
    vnorm = _logical(vnorm_pages, block_table).astype(jnp.float32)
    kc = _logical_kv(k_pages, k_scale, block_table)
    vc = _logical_kv(v_pages, v_scale, block_table)
    n = bits.shape[2]

    gs = u.shape[2]
    scores = socket_score_ref(
        bits.reshape(b * kvh, n, -1), u.reshape(b * kvh, gs, *u.shape[3:]),
        None, num_tables=num_tables, num_planes=num_planes, tau=tau)
    scores = scores.reshape(b, kvh, n)

    cfg = sk.SocketConfig(num_planes=num_planes, num_tables=num_tables,
                          tau=tau, sink_tokens=sink_tokens,
                          window_tokens=window_tokens)
    length = jnp.broadcast_to(jnp.asarray(length, jnp.int32), (b,))
    budget = jnp.broadcast_to(jnp.asarray(budget, jnp.int32), (b,))
    idx, mask = sk.value_aware_topk(cfg, scores, vnorm, k=top_k,
                                    length=length, n_total=n, budget=budget)

    k_sel = jnp.take_along_axis(kc, idx[..., None], axis=2)
    v_sel = jnp.take_along_axis(vc, idx[..., None], axis=2)
    out = flash_decode_ref(q.reshape(b * kvh, g, hd),
                           k_sel.reshape(b * kvh, top_k, hd),
                           v_sel.reshape(b * kvh, top_k, hd),
                           mask.reshape(b * kvh, top_k), scale=scale)

    bidx = jnp.arange(b)[:, None, None]
    hidx = jnp.arange(kvh)[None, :, None]
    selected = jnp.zeros((b, kvh, n), bool).at[bidx, hidx, idx].max(mask)
    return out.reshape(b, kvh, g, hd), selected


def paged_hard_lsh_attend_ref(q: jax.Array, k_pages: jax.Array,
                              v_pages: jax.Array, bits_pages: jax.Array,
                              vnorm_pages: jax.Array, u_signs: jax.Array,
                              block_table: jax.Array, *, length, budget,
                              num_tables: int, num_planes: int, scale: float,
                              sink_tokens: int, window_tokens: int,
                              top_k: int, k_scale=None,
                              v_scale=None) -> Tuple[jax.Array, jax.Array]:
    """Oracle for :func:`ops.paged_hard_lsh_attend`.

    Identical composition to the socket oracle with the factorized soft
    score replaced by the backend's hard collision counts
    (``u_signs``: f32 ±1 query plane signs, ``(B, KVH, G, L, P)``).
    """
    from repro.models.backends.hard_lsh import _hard_collision_scores

    if q.ndim == 5:
        q = q[:, :, :, 0]
    b, kvh, g, hd = q.shape
    bits = _logical(bits_pages, block_table)          # (B,KVH,N,W)
    vnorm = _logical(vnorm_pages, block_table).astype(jnp.float32)
    kc = _logical_kv(k_pages, k_scale, block_table)
    vc = _logical_kv(v_pages, v_scale, block_table)
    n = bits.shape[2]

    cfg = sk.SocketConfig(num_planes=num_planes, num_tables=num_tables,
                          tau=1.0, sink_tokens=sink_tokens,
                          window_tokens=window_tokens)
    scores = _hard_collision_scores(cfg, bits, u_signs)   # (B,KVH,G,N)
    scores = jnp.sum(scores, axis=2)

    length = jnp.broadcast_to(jnp.asarray(length, jnp.int32), (b,))
    budget = jnp.broadcast_to(jnp.asarray(budget, jnp.int32), (b,))
    idx, mask = sk.value_aware_topk(cfg, scores, vnorm, k=top_k,
                                    length=length, n_total=n, budget=budget)

    k_sel = jnp.take_along_axis(kc, idx[..., None], axis=2)
    v_sel = jnp.take_along_axis(vc, idx[..., None], axis=2)
    out = flash_decode_ref(q.reshape(b * kvh, g, hd),
                           k_sel.reshape(b * kvh, top_k, hd),
                           v_sel.reshape(b * kvh, top_k, hd),
                           mask.reshape(b * kvh, top_k), scale=scale)

    bidx = jnp.arange(b)[:, None, None]
    hidx = jnp.arange(kvh)[None, :, None]
    selected = jnp.zeros((b, kvh, n), bool).at[bidx, hidx, idx].max(mask)
    return out.reshape(b, kvh, g, hd), selected


def paged_quest_attend_ref(q: jax.Array, k_pages: jax.Array,
                           v_pages: jax.Array, kmin_pages: jax.Array,
                           kmax_pages: jax.Array, block_table: jax.Array, *,
                           length, page_size: int, sparsity: float,
                           min_pages: int, scale: float, sink_tokens: int,
                           window_tokens: int, k_scale=None,
                           v_scale=None) -> Tuple[jax.Array, jax.Array]:
    """Oracle for :func:`ops.paged_quest_attend`.

    Materializes the logical per-request kmin/kmax stat views and runs
    the exact baseline composition: ``baselines.quest.select_tokens``
    (page-granular top-k with sink/window forcing and ragged lengths)
    → masked softmax attention over the selected rows.
    """
    from repro.baselines import quest as quest_mod

    if q.ndim == 4:
        q = q[:, :, :, None]                          # (B,KVH,G,1,hd)
    b, kvh, g, _, hd = q.shape
    kc = _logical_kv(k_pages, k_scale, block_table)   # (B,KVH,N,hd)
    vc = _logical_kv(v_pages, v_scale, block_table)
    kmin = _logical(kmin_pages, block_table)          # (B,KVH,n_pages,hd)
    kmax = _logical(kmax_pages, block_table)
    n = kc.shape[2]

    qcfg = quest_mod.QuestConfig(page_size=page_size, sparsity=sparsity,
                                 sink_tokens=sink_tokens,
                                 window_tokens=window_tokens,
                                 min_pages=min_pages)
    state = quest_mod.QuestState(kmin=kmin, kmax=kmax)
    length = jnp.broadcast_to(jnp.asarray(length, jnp.int32), (b,))
    idx, mask = quest_mod.select_tokens(qcfg, state, q, length=length, n=n)
    kt = idx.shape[-1]                                # k_pages * page_size

    k_sel = jnp.take_along_axis(kc, idx[..., None], axis=2)
    v_sel = jnp.take_along_axis(vc, idx[..., None], axis=2)
    out = flash_decode_ref(q[:, :, :, 0].reshape(b * kvh, g, hd),
                           k_sel.reshape(b * kvh, kt, hd),
                           v_sel.reshape(b * kvh, kt, hd),
                           mask.reshape(b * kvh, kt), scale=scale)

    bidx = jnp.arange(b)[:, None, None]
    hidx = jnp.arange(kvh)[None, :, None]
    selected = jnp.zeros((b, kvh, n), bool).at[bidx, hidx, idx].max(mask)
    return out.reshape(b, kvh, g, hd), selected


def paged_ring_attend_ref(q: jax.Array, k_pages: jax.Array,
                          v_pages: jax.Array, block_table: jax.Array, *,
                          pos, window: int, softcap: float,
                          scale: float, k_scale=None,
                          v_scale=None) -> jax.Array:
    """Oracle for :func:`ops.paged_ring_attend`.

    Gathers the circular page list (``block_table`` is the ring slice)
    and applies the sliding-window mask in plain jnp — the exact math of
    ``attention_decode``'s local-layer XLA path: logits·scale → softcap
    → window mask → softmax.
    """
    if q.ndim == 5:
        q = q[:, :, :, 0]
    b, kvh, g, hd = q.shape
    kc = _logical_kv(k_pages, k_scale,
                     block_table).astype(jnp.float32)        # (B,KVH,cap,hd)
    vc = _logical_kv(v_pages, v_scale, block_table).astype(jnp.float32)
    cap = kc.shape[2]

    pos = jnp.broadcast_to(jnp.asarray(pos, jnp.int32), (b,))
    sl = jnp.arange(cap, dtype=jnp.int32)
    ring_pos = pos[:, None] - ((pos[:, None] - sl) % cap)    # (B, cap)
    valid = (ring_pos >= 0) & (pos[:, None] - ring_pos < window)

    s = jnp.einsum("bhgd,bhnd->bhgn", q.astype(jnp.float32), kc) * scale
    if softcap:
        s = softcap * jnp.tanh(s / softcap)
    s = jnp.where(valid[:, None, None, :], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhgn,bhnd->bhgd", p, vc)
