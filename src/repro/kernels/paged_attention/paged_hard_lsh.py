"""Pallas TPU kernel: fused hard-LSH paged decode attention.

The tau -> 0 ablation of the fused SOCKET kernel: identical two-phase
streaming over the block table (scalar-prefetch index maps, VMEM score
ring, exact radix-select of the per-request budget, selected-rows-only
online-softmax rescan — all shared with
:mod:`~repro.kernels.paged_attention.paged_attention` via
``_fused_kernel(mode="hard_lsh")``), but phase 0 scores by **hard
collision counting** instead of the soft kernel estimate:

    count_j = sum_l 1[ every plane sign of table l agrees with the query ]

evaluated in-register from the same packed uint32 hash words.  The query
side is the host-precomputed ±1 sign pattern of its soft hash
(``sign(tanh(Wq))`` == ``sign(Wq)``), one per q head — the backend's
``u_signs = where(u >= 0, +1, -1)``.  A table collides iff the ±1 inner
product over its P planes attains exactly P, so the agreement test is a
single einsum + compare, integer-exact in f32.

Padding contract: ``num_words`` rounds the packed width up so W*32 is a
multiple of P; the padded table slots unpack to all ``-1`` signs
(packed bits are zero-padded), and the launcher zero-pads ``u_signs``
there — agreement is then 0 < P, so padding tables never count (the
hard-LSH analogue of the socket kernel's ``logZ = +inf`` padding).

Selection and attention semantics are exactly the backend's XLA path:
``value_aware_topk`` over ``count * ||v||`` with sink/window forcing,
ragged lengths and per-request dynamic budgets, then masked
online-softmax attention over the selected rows.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.paged_attention.paged_attention import (
    _fused_call, _fused_kernel)

__all__ = ["paged_hard_lsh_pallas"]


def paged_hard_lsh_pallas(q: jax.Array, k_pages: jax.Array,
                          v_pages: jax.Array, bits_pages: jax.Array,
                          vnorm_pages: jax.Array, u_signs: jax.Array,
                          block_table: jax.Array, length: jax.Array,
                          budget: jax.Array, *, num_tables: int,
                          num_planes: int, scale: float,
                          sink_tokens: int, window_tokens: int,
                          interpret: bool = True,
                          with_selection: bool = False,
                          k_scale=None, v_scale=None):
    """Launch the fused hard-LSH kernel.

    Args:
      q:           (B, KVH, G, hd) query heads for this KV head group.
      k/v_pages:   (NB, KVH, bs, hd) paged pool leaves (bf16/int8/fp8).
      k/v_scale:   (NB, KVH, bs) per-row dequant scales — both or neither;
                   when given the attend pass dequantizes in-register.
      bits_pages:  uint32 (NB, KVH, bs, W) packed sign bits.
      vnorm_pages: (NB, KVH, bs) value norms (any float dtype).
      u_signs:     f32 ±1 (B, KVH, G, L, P) query hash plane signs.
      block_table: int32 (B, nb) physical block ids (trash-padded).
      length:      int32 (B,) live context length per request.
      budget:      int32 (B,) dynamic top-k budget per request.

    Returns:
      f32 (B, KVH, G, hd) attention output; with ``with_selection`` also
      an int32 (B, KVH, nb, bs) selection mask (test/debug only).
    """
    bs, w = bits_pages.shape[2], bits_pages.shape[3]
    nb = block_table.shape[1]
    _, _, gs, l, p = u_signs.shape
    if l != num_tables or p != num_planes:
        raise ValueError("u_signs shape mismatch")
    if (w * 32) % num_planes:
        raise ValueError(
            f"packed width {w*32} bits not a multiple of P={num_planes}")
    if k_pages.shape[2] != bs or v_pages.shape[2] != bs \
            or vnorm_pages.shape[2] != bs:
        raise ValueError("page pools disagree on block_size")
    if (k_scale is None) != (v_scale is None):
        raise ValueError("k_scale/v_scale must be given together")
    l_pad = (w * 32) // num_planes

    # zero-pad the query signs over the alignment tables: padded key bits
    # unpack to -1 signs, and sum(-1 * 0) == 0 < P never counts a table.
    u_pad = jnp.pad(u_signs.astype(jnp.float32),
                    ((0, 0), (0, 0), (0, 0), (0, l_pad - l), (0, 0)))
    logz_pad = jnp.zeros(u_pad.shape[:-1], jnp.float32)   # unused in-kernel

    kernel = functools.partial(
        _fused_kernel, num_planes=num_planes, l_pad=l_pad, tau=1.0,
        scale=float(scale), sink=int(sink_tokens),
        window=int(window_tokens), block_size=bs, num_seq_blocks=nb,
        with_selection=with_selection, mode="hard_lsh",
        quantized=k_scale is not None)
    return _fused_call(kernel, q, bits_pages, vnorm_pages, u_pad, logz_pad,
                       k_pages, v_pages, block_table, length, budget,
                       with_selection=with_selection, interpret=interpret,
                       k_scale=k_scale, v_scale=v_scale)
