"""Pallas TPU kernel: sliding-window (ring) paged decode attention.

Hybrid models' local layers keep only the last ``sliding_window`` tokens
in a circular page list (``RingView``): slot ``s`` of the ring holds the
most recent token with ``position % capacity == s``.  The XLA path
materializes the ring K/V via a pool gather and applies the window mask
in plain jnp; this kernel instead streams the ring blocks straight from
the paged pool via the block table (scalar-prefetch index maps) and
applies the mask in-register — zero gathered bytes per step.

Per (request, KV head) the grid walks the ring blocks once; for each
slot the kernel reconstructs the position of the token currently stored
there,

    ring_pos = pos - ((pos - slot) % capacity)

(the newest absolute position congruent to the slot; ``%`` is jnp's
non-negative modulo), masks slots that are empty (``ring_pos < 0``) or
aged out of the window (``pos - ring_pos >= window``), and folds the
live rows into a flash-style online softmax.  Gemma-style logit
softcapping (``c * tanh(s / c)``) is applied **before** masking, exactly
as the XLA reference; ``softcap == 0`` statically disables it.

There is no selection phase — every in-window token attends — so the
grid is single-phase: (B, KVH, ring_blocks).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.paged_attention.paged_attention import NEG_INF

__all__ = ["paged_ring_pallas"]


def _ring_kernel(bt_ref, pos_ref,                           # scalar prefetch
                 q_ref, k_ref, v_ref, *rest, scale: float, window: int,
                 softcap: float, block_size: int, ring_blocks: int,
                 quantized: bool = False):
    if quantized:
        ks_ref, vs_ref = rest[0], rest[1]
        rest = rest[2:]
    out_ref, m_scr, l_scr, acc_scr = rest
    b = pl.program_id(0)
    i = pl.program_id(2)
    pos = pos_ref[b]
    cap = ring_blocks * block_size

    @pl.when(i == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    slot = (jax.lax.broadcasted_iota(jnp.int32, (block_size, 1), 0)
            .reshape(block_size) + i * block_size)
    ring_pos = pos - ((pos - slot) % cap)
    valid = (ring_pos >= 0) & (pos - ring_pos < window)

    q = q_ref[0, 0].astype(jnp.float32)           # (G, hd)
    k = k_ref[0, 0].astype(jnp.float32)           # (bs, hd)
    v = v_ref[0, 0].astype(jnp.float32)
    if quantized:
        # int8/fp8 ring pages: per-row absmax scales ride along as (bs,)
        # leaves — dequantize in-register, never in HBM.
        k = k * ks_ref[0, 0].astype(jnp.float32)[:, None]
        v = v * vs_ref[0, 0].astype(jnp.float32)[:, None]
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ()))) * scale
    if softcap:                                   # static no-op at 0.0
        s = softcap * jnp.tanh(s / softcap)
    s = jnp.where(valid[None, :], s, NEG_INF)     # (G, bs)

    m_prev = m_scr[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new[:, None])
    p = jnp.where(valid[None, :], p, 0.0)
    l_scr[...] = l_scr[...] * alpha + jnp.sum(p, axis=-1)
    acc_scr[...] = acc_scr[...] * alpha[:, None] + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())))
    m_scr[...] = m_new

    @pl.when(i == ring_blocks - 1)
    def _done():
        out_ref[0, 0] = (acc_scr[...] /
                         jnp.maximum(l_scr[...], 1e-30)[:, None]
                         ).astype(out_ref.dtype)


def paged_ring_pallas(q: jax.Array, k_pages: jax.Array, v_pages: jax.Array,
                      block_table: jax.Array, pos: jax.Array, *,
                      window: int, softcap: float, scale: float,
                      interpret: bool = True, k_scale=None, v_scale=None):
    """Launch the ring decode kernel.

    Args:
      q:           (B, KVH, G, hd) query heads for this KV head group.
      k/v_pages:   (NB, KVH, bs, hd) paged pool leaves (bf16/int8/fp8).
      k/v_scale:   (NB, KVH, bs) per-row dequant scales — both or neither;
                   when given each streamed page dequantizes in-register.
      block_table: int32 (B, ring_blocks) — the circular page list only
                   (callers slice the full table to the ring geometry).
      pos:         int32 (B,) absolute position of the decode token (the
                   query's own position; it has already been written to
                   its ring slot).
      window:      sliding-window length in tokens.
      softcap:     attention logit softcap (0.0 disables).

    Returns f32 (B, KVH, G, hd) attention output.
    """
    b, kvh, g, hd = q.shape
    bs = k_pages.shape[2]
    rb = block_table.shape[1]
    if v_pages.shape[2] != bs:
        raise ValueError("page pools disagree on block_size")
    if (k_scale is None) != (v_scale is None):
        raise ValueError("k_scale/v_scale must be given together")

    kernel = functools.partial(
        _ring_kernel, scale=float(scale), window=int(window),
        softcap=float(softcap), block_size=bs, ring_blocks=rb,
        quantized=k_scale is not None)

    in_specs = [
        pl.BlockSpec((1, 1, g, hd), lambda b, h, i, *s: (b, h, 0, 0)),
        pl.BlockSpec((1, 1, bs, hd),
                     lambda b, h, i, bt, ps: (bt[b, i], h, 0, 0)),
        pl.BlockSpec((1, 1, bs, hd),
                     lambda b, h, i, bt, ps: (bt[b, i], h, 0, 0)),
    ]
    operands = [q, k_pages, v_pages]
    if k_scale is not None:
        # per-row dequant scales stream with the K/V pages
        for _ in range(2):
            in_specs.append(pl.BlockSpec(
                (1, 1, bs), lambda b, h, i, bt, ps: (bt[b, i], h, 0)))
        operands += [k_scale, v_scale]

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(b, kvh, rb),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, 1, g, hd), lambda b, h, i, *s: (b, h, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((g,), jnp.float32),        # m
            pltpu.VMEM((g,), jnp.float32),        # l
            pltpu.VMEM((g, hd), jnp.float32),     # acc
        ],
    )
    return pl.pallas_call(
        kernel, grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, kvh, g, hd), jnp.float32),
        interpret=interpret,
    )(block_table.astype(jnp.int32), pos.astype(jnp.int32), *operands)
