"""Pallas TPU kernel: flash decode over the SOCKET-selected KV subset.

One decode step of GQA attention for a single KV head's group of G query
heads against the K gathered rows (the top-k ∪ sink ∪ window selection).
Mirrors the paper's Triton "Flash Decode" backend: split-K online softmax
with fp32 running (m, l, acc) state.

Grid = (BH, K // block_k); the K axis is the innermost (sequential on TPU)
grid dimension, so the kernel accumulates across K blocks in VMEM scratch
and writes the normalised output on the final block:

  per step  : q (G, hd) resident; k/v block (block_k, hd); mask (block_k,)
  scratch   : m (G,), l (G,), acc (G, hd)  — all fp32
  epilogue  : out = acc / l

VMEM per step at (G=8, hd=256, block_k=512): ~1.3 MiB.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BLOCK_K = 512
NEG_INF = -1e30


def _decode_kernel(q_ref, k_ref, v_ref, mask_ref, out_ref, m_scr, l_scr,
                   acc_scr, *, scale: float, num_k_blocks: int):
    kb = pl.program_id(1)

    @pl.when(kb == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0].astype(jnp.float32)              # (G, hd)
    k = k_ref[0].astype(jnp.float32)              # (block_k, hd)
    v = v_ref[0].astype(jnp.float32)
    valid = mask_ref[0]                           # (block_k,) bool

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ()))) * scale
    s = jnp.where(valid[None, :], s, NEG_INF)     # (G, block_k)

    m_prev = m_scr[...]                           # (G,)
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
    alpha = jnp.exp(m_prev - m_new)               # (G,)
    p = jnp.exp(s - m_new[:, None])               # (G, block_k)
    p = jnp.where(valid[None, :], p, 0.0)

    l_scr[...] = l_scr[...] * alpha + jnp.sum(p, axis=-1)
    acc_scr[...] = acc_scr[...] * alpha[:, None] + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())))
    m_scr[...] = m_new

    @pl.when(kb == num_k_blocks - 1)
    def _done():
        out_ref[0] = (acc_scr[...] /
                      jnp.maximum(l_scr[...], 1e-30)[:, None]
                      ).astype(out_ref.dtype)


def flash_decode_pallas(q: jax.Array, k: jax.Array, v: jax.Array,
                        mask: jax.Array, *, scale: float,
                        block_k: int = DEFAULT_BLOCK_K,
                        interpret: bool = True) -> jax.Array:
    """q (BH, G, hd); k/v (BH, K, hd); mask (BH, K) -> f32 (BH, G, hd).

    ``K`` need not divide ``block_k``: the tail (and a whole short
    ``K < block_k`` buffer) is padded to the block boundary with
    mask-off rows, which the kernel already scores as ``-inf``.
    """
    bh, g, hd = q.shape
    kk = k.shape[1]
    blk = max(1, min(block_k, kk))
    pad = (-kk) % blk
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0)))
        mask = jnp.pad(mask, ((0, 0), (0, pad)))
    block_k = blk
    nkb = (kk + pad) // block_k
    kernel = functools.partial(_decode_kernel, scale=float(scale),
                               num_k_blocks=nkb)
    return pl.pallas_call(
        kernel,
        grid=(bh, nkb),
        in_specs=[
            pl.BlockSpec((1, g, hd), lambda b, i: (b, 0, 0)),
            pl.BlockSpec((1, block_k, hd), lambda b, i: (b, i, 0)),
            pl.BlockSpec((1, block_k, hd), lambda b, i: (b, i, 0)),
            pl.BlockSpec((1, block_k), lambda b, i: (b, i)),
        ],
        out_specs=pl.BlockSpec((1, g, hd), lambda b, i: (b, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, g, hd), jnp.float32),
        scratch_shapes=[
            pltpu.VMEM((g,), jnp.float32),
            pltpu.VMEM((g,), jnp.float32),
            pltpu.VMEM((g, hd), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v, mask)
