"""Pure-jnp oracle for the flash-decode kernel: masked softmax attention of
G query heads against K gathered key/value rows (one KV head)."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def flash_decode_ref(q: jax.Array, k: jax.Array, v: jax.Array,
                     mask: jax.Array, *, scale: float) -> jax.Array:
    """q (BH, G, hd); k/v (BH, K, hd); mask (BH, K) bool -> (BH, G, hd)."""
    logits = jnp.einsum("bgd,bkd->bgk", q.astype(jnp.float32),
                        k.astype(jnp.float32)) * scale
    logits = jnp.where(mask[:, None, :], logits, -1e30)
    w = jax.nn.softmax(logits, axis=-1)
    return jnp.einsum("bgk,bkd->bgd", w, v.astype(jnp.float32))
