"""Jitted wrapper for flash_decode, accepting the model's (B, KVH, ...)
layout and padding K to the block size with masked rows."""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels.flash_decode.flash_decode import (DEFAULT_BLOCK_K,
                                                     flash_decode_pallas)


def _auto_interpret() -> bool:
    return jax.default_backend() != "tpu"


@functools.partial(jax.jit, static_argnames=("scale", "block_k", "interpret"))
def _decode_flat(q, k, v, mask, *, scale, block_k, interpret):
    return flash_decode_pallas(q, k, v, mask, scale=scale, block_k=block_k,
                               interpret=interpret)


def flash_decode(q: jax.Array, k: jax.Array, v: jax.Array,
                 mask: jax.Array, *, scale: float,
                 block_k: int = DEFAULT_BLOCK_K,
                 interpret: Optional[bool] = None) -> jax.Array:
    """Sparse decode attention.

    q (B, KVH, G, 1, hd) or (BH, G, hd); k/v (B, KVH, K, hd) or (BH, K, hd);
    mask (B, KVH, K) / (BH, K).  Returns attention output in q's layout.
    """
    interpret = _auto_interpret() if interpret is None else interpret
    orig5 = q.ndim == 5
    if orig5:
        b, kvh, g, t, hd = q.shape
        assert t == 1
        q2 = q.reshape(b * kvh, g, hd)
        k2 = k.reshape(b * kvh, *k.shape[2:])
        v2 = v.reshape(b * kvh, *v.shape[2:])
        m2 = mask.reshape(b * kvh, mask.shape[-1])
    else:
        q2, k2, v2, m2 = q, k, v, mask
    kk = k2.shape[1]
    blk = min(block_k, kk)
    pad = (-kk) % blk
    if pad:
        k2 = jnp.pad(k2, ((0, 0), (0, pad), (0, 0)))
        v2 = jnp.pad(v2, ((0, 0), (0, pad), (0, 0)))
        m2 = jnp.pad(m2, ((0, 0), (0, pad)))
    out = _decode_flat(q2, k2, v2, m2, scale=float(scale), block_k=blk,
                       interpret=interpret)
    if orig5:
        out = out.reshape(b, kvh, g, 1, hd).astype(q.dtype)
    return out
