"""Jitted wrapper for the causal flash-attention prefill kernel."""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels.flash_prefill.flash_prefill import (DEFAULT_BLOCK_K,
                                                       DEFAULT_BLOCK_Q,
                                                       flash_prefill_pallas)


def _auto_interpret() -> bool:
    return jax.default_backend() != "tpu"


@functools.partial(jax.jit, static_argnames=("scale", "window", "block_q",
                                             "block_k", "interpret"))
def _prefill_flat(q, k, v, *, scale, window, block_q, block_k, interpret):
    return flash_prefill_pallas(q, k, v, scale=scale, window=window,
                                block_q=block_q, block_k=block_k,
                                interpret=interpret)


def flash_prefill(q: jax.Array, k: jax.Array, v: jax.Array, *, scale: float,
                  window: int = 0, block_q: int = DEFAULT_BLOCK_Q,
                  block_k: int = DEFAULT_BLOCK_K,
                  interpret: Optional[bool] = None) -> jax.Array:
    """Causal attention.  q/k/v (BH, S, hd); returns f32 (BH, S, hd)."""
    interpret = _auto_interpret() if interpret is None else interpret
    s = q.shape[1]
    bq = min(block_q, s)
    bk = min(block_k, s)
    while s % bq:
        bq //= 2
    while s % bk:
        bk //= 2
    return _prefill_flat(q, k, v, scale=float(scale), window=int(window),
                         block_q=bq, block_k=bk, interpret=interpret)
