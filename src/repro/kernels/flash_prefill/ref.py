"""Pure-jnp oracle for causal (optionally sliding-window) flash attention."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def flash_prefill_ref(q: jax.Array, k: jax.Array, v: jax.Array, *,
                      scale: float, window: int = 0) -> jax.Array:
    """q/k/v (BH, S, hd) -> (BH, S, hd), causal; window>0 = sliding window."""
    s = q.shape[1]
    logits = jnp.einsum("bqd,bkd->bqk", q.astype(jnp.float32),
                        k.astype(jnp.float32)) * scale
    qi = jnp.arange(s)[:, None]
    ki = jnp.arange(s)[None, :]
    mask = ki <= qi
    if window > 0:
        mask &= (qi - ki) < window
    logits = jnp.where(mask[None], logits, -1e30)
    w = jax.nn.softmax(logits, axis=-1)
    return jnp.einsum("bqk,bkd->bqd", w, v.astype(jnp.float32))
