"""Pallas TPU kernel: causal flash-attention forward (prefill path).

Classic FlashAttention-2 style tiling: grid = (BH, Q blocks, K blocks) with
the K axis innermost/sequential; fp32 (m, l, acc) scratch carried across K
blocks, normalised write-back on the last visited K block.  Supports an
optional sliding window (gemma3/mixtral local layers).

Block skipping: K blocks strictly above the causal diagonal (or entirely
outside the window) contribute nothing; their work is masked out.  (A
production variant would prune them from the grid with a custom index map;
masked execution keeps the kernel simple and the FLOP accounting explicit —
see EXPERIMENTS.md §Perf.)

VMEM at (block_q=512, block_k=512, hd=256): q/k/v tiles 3·512·256·4 B ≈
1.5 MiB + acc 512·256·4 B — comfortable.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BLOCK_Q = 512
DEFAULT_BLOCK_K = 512
NEG_INF = -1e30


def _prefill_kernel(q_ref, k_ref, v_ref, out_ref, m_scr, l_scr, acc_scr, *,
                    scale: float, block_q: int, block_k: int,
                    num_k_blocks: int, window: int):
    qb = pl.program_id(1)
    kb = pl.program_id(2)

    @pl.when(kb == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q_pos = qb * block_q + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, block_k), 0)
    k_pos = kb * block_k + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, block_k), 1)
    mask = k_pos <= q_pos
    if window > 0:
        mask &= (q_pos - k_pos) < window

    q = q_ref[0].astype(jnp.float32)
    k = k_ref[0].astype(jnp.float32)
    v = v_ref[0].astype(jnp.float32)

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ()))) * scale
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_scr[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.where(mask, jnp.exp(s - m_new[:, None]), 0.0)

    l_scr[...] = l_scr[...] * alpha + jnp.sum(p, axis=-1)
    acc_scr[...] = acc_scr[...] * alpha[:, None] + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())))
    m_scr[...] = m_new

    @pl.when(kb == num_k_blocks - 1)
    def _done():
        out_ref[0] = (acc_scr[...] /
                      jnp.maximum(l_scr[...], 1e-30)[:, None]
                      ).astype(out_ref.dtype)


def flash_prefill_pallas(q: jax.Array, k: jax.Array, v: jax.Array, *,
                         scale: float, window: int = 0,
                         block_q: int = DEFAULT_BLOCK_Q,
                         block_k: int = DEFAULT_BLOCK_K,
                         interpret: bool = True) -> jax.Array:
    """q/k/v (BH, S, hd) -> f32 (BH, S, hd) causal attention."""
    bh, s, hd = q.shape
    if s % block_q or s % block_k:
        raise ValueError(f"S={s} must be a multiple of block sizes")
    nkb = s // block_k
    kernel = functools.partial(
        _prefill_kernel, scale=float(scale), block_q=block_q,
        block_k=block_k, num_k_blocks=nkb, window=int(window))
    return pl.pallas_call(
        kernel,
        grid=(bh, s // block_q, nkb),
        in_specs=[
            pl.BlockSpec((1, block_q, hd), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, block_k, hd), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, block_k, hd), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, hd), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, s, hd), jnp.float32),
        scratch_shapes=[
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q, hd), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
