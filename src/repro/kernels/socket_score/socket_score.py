"""Pallas TPU kernel: SOCKET soft-collision scoring (paper Algorithm 4).

TPU adaptation of the paper's CUDA scoring kernel (DESIGN.md §2): instead
of gathering per-key bucket probabilities from a LUT (random-access —
wrong primitive for TPU), the kernel streams the *bit-packed* sign matrix
from HBM, unpacks it in-register with shift/mask ops, and evaluates the
exact factorized score

    score[n] = vnorm[n] * sum_g sum_l exp( <S_nl, u_gl> / tau - logZ_gl )

Memory behaviour (the point of SOCKET): per token the kernel reads
``W*4 = 80`` bytes of packed bits + 4 bytes of vnorm instead of the 256 B
of bf16 keys a dense decode reads — a 3.2x HBM-traffic reduction, which is
what makes sparse decode profitable at long context on TPU v5e
(819 GB/s HBM).

Tiling: grid = (BH, N // block_n).  Per step the kernel holds
  bits  (block_n, W)   uint32   — block_n=512, W=20 → 40 KiB
  u     (G, L, P)      f32      — ≤ 8·64·16·4 = 32 KiB   (VMEM resident)
  logz  (G, L)         f32
  vnorm (block_n,)     f32
  out   (block_n,)     f32
comfortably inside VMEM.  The contraction (block_n, L, P) x (G, L, P) is
vector-unit work (P is far below the 128-lane MXU contraction width; see
EXPERIMENTS.md §Perf for the measured compute/memory balance and the
pooled-query G=1 operating point that keeps the kernel memory-bound).

The unpack exploits that ``W*32`` is a multiple of 128 (W=20 → 640 lanes):
tables are processed in a (L_pad, P) view with L padded to W*32/P and the
padding neutralised via logZ = +inf (=> exp(-inf) = 0 contribution).
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK_N = 512


def _score_kernel(bits_ref, u_ref, logz_ref, vnorm_ref, out_ref, *,
                  num_planes: int, l_pad: int, tau: float,
                  bits_format: str = "packed"):
    """One (bh, n-block) tile."""
    if bits_format == "packed":
        words = bits_ref[0]                      # (block_n, W) uint32
        block_n, w = words.shape

        # ---- unpack W uint32 words -> (block_n, W*32) ±1 float32 --------
        shifts = jax.lax.broadcasted_iota(jnp.uint32, (1, 1, 32), 2)
        bits = (words[:, :, None] >> shifts) & jnp.uint32(1)
        signs = bits.reshape(block_n, w * 32).astype(jnp.float32) * 2.0 - 1.0
        # padded-table view: (block_n, L_pad, P); pad tables contribute 0
        # via logz = +inf supplied by the wrapper.
        signs = signs.reshape(block_n, l_pad, num_planes)
    else:                                        # "int8": ±1 plane bytes
        planes = bits_ref[0]                     # (block_n, L*P) int8
        block_n = planes.shape[0]
        signs = planes.astype(jnp.float32).reshape(block_n, l_pad,
                                                   num_planes)

    u = u_ref[0]                                 # (G, L_pad, P) f32
    logz = logz_ref[0]                           # (G, L_pad)
    g = u.shape[0]

    # ---- per-table logits + exp + reduce --------------------------------
    # (block_n, 1, L_pad, P) * (1, G, L_pad, P) -> sum over P
    prod = signs[:, None] * u[None]              # (block_n, G, L_pad, P)
    logits = jnp.sum(prod, axis=-1) / tau        # (block_n, G, L_pad)
    z = jnp.exp(logits - logz[None])             # (block_n, G, L_pad)
    scores = jnp.sum(z, axis=(1, 2))             # (block_n,)

    out_ref[0] = scores * vnorm_ref[0]


def socket_score_pallas(bits: jax.Array, u: jax.Array,
                        vnorm: Optional[jax.Array], *, num_tables: int,
                        num_planes: int, tau: float,
                        block_n: int = DEFAULT_BLOCK_N,
                        interpret: bool = True) -> jax.Array:
    """Launch the scoring kernel.

    Args:
      bits:  uint32 (BH, N, W) packed sign bits, or int8 (BH, N, L*P)
             ±1 plane bytes (``bits_storage="int8"`` — format inferred
             from the dtype; no unpack, no table padding).
      u:     f32 (BH, G, L, P) query soft-hash.
      vnorm: f32 (BH, N) value norms, or None.

    Returns:
      f32 (BH, N) scores (group-summed, value-weighted).
    """
    bh, n, w = bits.shape
    _, g, l, p = u.shape
    if l != num_tables or p != num_planes:
        raise ValueError("u shape mismatch")
    bits_format = "int8" if bits.dtype == jnp.int8 else "packed"
    if bits_format == "packed":
        if (w * 32) % num_planes:
            raise ValueError(
                f"packed width {w*32} bits not a multiple of P="
                f"{num_planes}; choose P dividing 32*W")
        l_pad = (w * 32) // num_planes
    else:
        if w != l * p:
            raise ValueError(
                f"int8 bits width {w} != L*P = {l * p}")
        l_pad = l                                 # no padding tables

    # logZ (+inf on padding tables kills their contribution exactly)
    from repro.core import socket as sk
    logz = sk.log_normalizer(u.astype(jnp.float32), tau)       # (BH,G,L)
    pad_l = l_pad - l
    u_pad = jnp.pad(u.astype(jnp.float32),
                    ((0, 0), (0, 0), (0, pad_l), (0, 0)))
    logz_pad = jnp.pad(logz, ((0, 0), (0, 0), (0, pad_l)),
                       constant_values=jnp.float32(1e30))

    if vnorm is None:
        vnorm = jnp.ones((bh, n), jnp.float32)
    vnorm = vnorm.astype(jnp.float32)

    if n % block_n:
        raise ValueError(f"N={n} not a multiple of block_n={block_n}")

    kernel = functools.partial(_score_kernel, num_planes=num_planes,
                               l_pad=l_pad, tau=float(tau),
                               bits_format=bits_format)
    return pl.pallas_call(
        kernel,
        grid=(bh, n // block_n),
        in_specs=[
            pl.BlockSpec((1, block_n, w), lambda b, i: (b, i, 0)),
            pl.BlockSpec((1, g, l_pad, num_planes), lambda b, i: (b, 0, 0, 0)),
            pl.BlockSpec((1, g, l_pad), lambda b, i: (b, 0, 0)),
            pl.BlockSpec((1, block_n), lambda b, i: (b, i)),
        ],
        out_specs=pl.BlockSpec((1, block_n), lambda b, i: (b, i)),
        out_shape=jax.ShapeDtypeStruct((bh, n), jnp.float32),
        interpret=interpret,
    )(bits, u_pad, logz_pad, vnorm)
