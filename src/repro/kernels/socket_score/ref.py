"""Pure-jnp oracle for the SOCKET scoring kernel.

Computes exactly what the Pallas kernel computes (the factorized soft
collision score, DESIGN.md §2), shape-for-shape:

    scores[bh, n] = vnorm[bh, n] * sum_g sum_l exp( (S . u)/tau - logZ )

Inputs:
  bits  : uint32 (BH, N, W)     packed ±1 sign bits (hashing.pack_signs),
                                or int8 (BH, N, L*P) ±1 plane bytes
  u     : f32    (BH, G, L, P)  query soft-hash (socket.soft_hash_query)
  vnorm : f32    (BH, N)        value norms (or None for unweighted scores)
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.core import hashing, socket


def socket_score_ref(bits: jax.Array, u: jax.Array,
                     vnorm: Optional[jax.Array], *, num_tables: int,
                     num_planes: int, tau: float) -> jax.Array:
    """Returns f32 (BH, N) group-summed, value-weighted scores."""
    if bits.dtype == jnp.int8:                    # ±1 plane bytes (BH,N,L*P)
        signs = bits.astype(jnp.float32).reshape(
            *bits.shape[:-1], num_tables, num_planes)
    else:
        signs = hashing.unpack_signs(bits, num_tables,
                                     num_planes)   # (BH,N,L,P)
    logits = jnp.einsum("bnlp,bglp->bgnl", signs, u.astype(jnp.float32))
    logits = logits / tau
    logz = socket.log_normalizer(u.astype(jnp.float32), tau)    # (BH,G,L)
    z = jnp.exp(logits - logz[:, :, None, :])                   # (BH,G,N,L)
    scores = jnp.sum(z, axis=(1, 3))                            # (BH,N)
    if vnorm is not None:
        scores = scores * vnorm.astype(jnp.float32)
    return scores
