"""Jitted public wrapper for the SOCKET scoring kernel.

Accepts the model's natural layouts and flattens to the kernel's (BH, ...)
convention; on non-TPU backends runs the Pallas kernel in interpret mode
(bit-exact semantics) — set ``interpret=False`` on real TPU.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels.socket_score.socket_score import (DEFAULT_BLOCK_N,
                                                     socket_score_pallas)


def _auto_interpret() -> bool:
    return jax.default_backend() != "tpu"


@functools.partial(jax.jit, static_argnames=("num_tables", "num_planes",
                                             "tau", "block_n", "interpret"))
def _score_flat(bits, u, vnorm, *, num_tables, num_planes, tau, block_n,
                interpret):
    return socket_score_pallas(bits, u, vnorm, num_tables=num_tables,
                               num_planes=num_planes, tau=tau,
                               block_n=block_n, interpret=interpret)


def socket_score(bits: jax.Array, u: jax.Array,
                 vnorm: Optional[jax.Array] = None, *, num_tables: int,
                 num_planes: int, tau: float,
                 block_n: int = DEFAULT_BLOCK_N,
                 interpret: Optional[bool] = None) -> jax.Array:
    """Score keys for one decode step.

    Shapes (model layout):
      bits  uint32 (B, KVH, N, W)  or (BH, N, W)
      u     f32    (B, KVH, G, L, P) or (BH, G, L, P)
      vnorm        (B, KVH, N) or (BH, N) or None

    Returns scores f32 matching the leading layout: (B, KVH, N) / (BH, N).
    """
    interpret = _auto_interpret() if interpret is None else interpret
    squeeze = False
    if bits.ndim == 4:
        b, kvh, n, w = bits.shape
        bits = bits.reshape(b * kvh, n, w)
        u = u.reshape(b * kvh, *u.shape[2:])
        if vnorm is not None:
            vnorm = vnorm.reshape(b * kvh, n)
        squeeze = (b, kvh)
    n = bits.shape[1]
    blk = min(block_n, n)
    while n % blk:
        blk //= 2
    out = _score_flat(bits, u, vnorm, num_tables=num_tables,
                      num_planes=num_planes, tau=float(tau), block_n=blk,
                      interpret=interpret)
    if squeeze:
        out = out.reshape(*squeeze, n)
    return out
