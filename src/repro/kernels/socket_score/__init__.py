from repro.kernels.socket_score.ops import socket_score
from repro.kernels.socket_score.ref import socket_score_ref

__all__ = ["socket_score", "socket_score_ref"]
