"""Continuous-batching serving engine (sglang/vLLM-style, JAX-static).

Each iteration interleaves **prefill** (admit up to
``serving.max_prefill_per_iter`` waiting requests, one jitted
bucket-padded forward each, caches written straight into the paged pool)
with one **ragged decode step** over all running slots, a single
jit-compiled function with a per-slot ``pos`` vector (masked slots point
at the trash page).  Static shapes throughout — one decode compile
total, one prefill compile per bucket.

Layers are cached per the **per-layer cache plan** (``cfg.cache_plan()``):
global-attention layers hold backend-paged KV (+ SOCKET bits / Quest
stats) addressed linearly by the block table; sliding-window layers a
bounded circular page ring; Mamba layers O(1) per-slot state holding no
blocks at all.  Heterogeneous layouts (gemma3's 5:1 local:global,
jamba's attn:mamba hybrid, pure-SSM mamba2) all serve continuously.

For **paged-capable** backends (``DecodeBackend.supports_paged``: socket,
hard_lsh, quest) — or models without global-attention layers — the
decode step hands the page pool + block tables straight to the model:
appends write to pages in place and global attention reads only the
small metadata leaves plus the selected ``O(top_k)`` K/V rows — no
contiguous K/V view is ever materialized.  Otherwise (dense) the engine
falls back to the gather/scatter round trip (``paged.gather_views`` /
``scatter_token``), which is still window-bounded for ring layers and
free for state layers.

Sampling is greedy by default (bit-exact vs the static engine);
``temperature > 0`` switches the jitted step to temperature + top-p
sampling with one seeded PRNG stream per decode slot
(:mod:`repro.serving.sampling`).  ``input_mode == "tokens"`` only.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import backends as bk
from repro.models import param as pm
from repro.models import transformer as tfm
from repro.runtime.steps import make_prefill_step, make_serve_step
from repro.serving import paged, sampling
from repro.serving.block_pool import TRASH_BLOCK, BlockPool
from repro.serving.scheduler import Request, Scheduler

__all__ = ["ContinuousBatchingEngine", "ServeMetrics"]


@dataclasses.dataclass
class ServeMetrics:
    """Aggregate serving metrics for one engine run."""

    num_requests: int
    total_generated: int
    wall_s: float
    throughput_tok_s: float
    ttft_s_mean: float
    ttft_s_p99: float
    token_latency_s_p50: float
    token_latency_s_p99: float
    preemptions: int
    decode_iters: int

    def to_json(self) -> Dict:
        return {k: (round(v, 6) if isinstance(v, float) else v)
                for k, v in dataclasses.asdict(self).items()}


def _percentile(xs: List[float], q: float) -> float:
    return float(np.percentile(np.asarray(xs), q)) if xs else float("nan")


class ContinuousBatchingEngine:
    """Paged-cache continuous batching over one model replica."""

    def __init__(self, cfg: ModelConfig, params=None,
                 rng: Optional[jax.Array] = None, *,
                 temperature: float = 0.0, top_p: float = 1.0,
                 sample_seed: int = 0):
        self._validate(cfg)
        self.cfg = cfg
        self.serving = cfg.serving
        self.serving.validate()
        if params is None:
            rng = rng if rng is not None else jax.random.PRNGKey(0)
            params = pm.unbox(tfm.init_model(cfg, rng))
        self.params = params
        self.backend = bk.get_backend(cfg.attention_backend)
        plan = cfg.cache_plan()
        has_paged = any(p.kind == "paged" for p in plan)
        ring_blocks = max((p.ring_blocks for p in plan
                           if p.kind == "ring"), default=0)
        # page-native decode: paged-capable backend, or no global layer
        # consumes the backend at all (ring/state layers are page-native
        # by construction)
        self._paged_native = self.backend.supports_paged or not has_paged
        self.temperature = float(temperature)
        self.top_p = float(top_p)
        self._keys = sampling.slot_keys(sample_seed, self.serving.max_batch)
        self.pages = paged.init_paged_caches(cfg, self.serving)
        self.pool = BlockPool(self.serving.num_blocks)
        self.scheduler = Scheduler(
            self.pool, max_batch=self.serving.max_batch,
            max_blocks_per_seq=self.serving.max_blocks_per_seq,
            block_size=self.serving.block_size,
            has_paged_layers=has_paged, ring_blocks=ring_blocks)
        self._decode_fn = self._build_decode()
        self._prefill_fns: Dict[int, callable] = {}

    @staticmethod
    def _validate(cfg: ModelConfig) -> None:
        if cfg.input_mode != "tokens":
            raise NotImplementedError(
                "continuous engine serves token models only")
        if any(s.kind == "attn" and s.attn_type == "global"
               for s in cfg.layer_specs):
            # resolves the backend (ValueError on unknown names) and
            # validates its cache layout against the serving geometry
            # (e.g. quest's page_size must divide block_size)
            bk.get_backend(cfg.attention_backend).cache_spec(cfg)
        if cfg.decode_cp_axes:
            raise NotImplementedError(
                "ragged decode + context-parallel SOCKET is a ROADMAP item")

    # --------------------------------------------------------------- jit
    def _pick(self, logits: jax.Array, keys: jax.Array):
        """Next-token choice from one step's ``(B, 1, V)`` logits."""
        last = logits[:, -1]
        if self.temperature > 0:
            return sampling.sample_tokens(
                last, keys, temperature=self.temperature, top_p=self.top_p,
                vocab_size=self.cfg.vocab_size)
        return jnp.argmax(last, axis=-1), keys

    def _build_decode(self):
        serve = make_serve_step(self.cfg)
        cfg = self.cfg

        if self._paged_native:
            # page-native path: the pool + block tables go straight into
            # the model; no contiguous K/V view is ever materialized.
            def step(params, pages, keys, tokens, bt, pos):
                logits, pages = serve(params, pages, tokens, pos, bt)
                tok, keys = self._pick(logits, keys)
                return tok, keys, pages
        else:
            def step(params, pages, keys, tokens, bt, pos):
                views = paged.gather_views(cfg, pages, bt)
                logits, views = serve(params, views, tokens, pos)
                pages = paged.scatter_token(cfg, pages, views, bt, pos)
                tok, keys = self._pick(logits, keys)
                return tok, keys, pages

        return jax.jit(step, donate_argnums=(1,))

    def _bt_row_len(self, bucket: int) -> int:
        """Prefill block-table row length: the bucket's blocks, but at
        least the circular window pages (a short prompt's ring still
        spans ``ring_blocks`` table entries; unallocated ones are
        trash)."""
        return max(bucket // self.serving.block_size,
                   self.scheduler.ring_blocks)

    def _prefill_fn(self, bucket: int):
        if bucket not in self._prefill_fns:
            prefill = make_prefill_step(self.cfg, bucket, bucketed=True,
                                        paged=True)

            def step(params, pages, keys, tokens, last_index, bt_row,
                     slot):
                logits, caches = prefill(params, {"tokens": tokens},
                                         last_index)
                pages = paged.write_prefill(self.cfg, pages, caches,
                                            bt_row, slot)
                tok, key = self._pick(logits, keys[slot][None])
                keys = keys.at[slot].set(key[0])
                return tok, keys, pages

            self._prefill_fns[bucket] = jax.jit(step, donate_argnums=(1,))
        return self._prefill_fns[bucket]

    def warmup(self) -> None:
        """Trigger every jit compile (decode step + all prefill buckets)
        against the trash page, so a subsequent run's TTFT and latency
        percentiles measure serving, not compilation.  Sampling keys are
        not consumed (warmup randomness is discarded)."""
        sv = self.serving
        tokens = jnp.zeros((sv.max_batch, 1), jnp.int32)
        bt = jnp.full((sv.max_batch, sv.max_blocks_per_seq), TRASH_BLOCK,
                      jnp.int32)
        pos = jnp.zeros((sv.max_batch,), jnp.int32)
        _, _, self.pages = self._decode_fn(self.params, self.pages,
                                           self._keys, tokens, bt, pos)
        for bucket in sv.prefill_buckets:
            bt_row = jnp.full((self._bt_row_len(bucket),), TRASH_BLOCK,
                              jnp.int32)
            _, _, self.pages = self._prefill_fn(bucket)(
                self.params, self.pages, self._keys,
                jnp.zeros((1, bucket), jnp.int32),
                jnp.zeros((1,), jnp.int32), bt_row, jnp.int32(0))

    def _bucket_for(self, n: int) -> int:
        for b in sorted(self.serving.prefill_buckets):
            if b >= n:
                return b
        raise ValueError(f"prompt of {n} tokens exceeds largest prefill "
                         f"bucket {max(self.serving.prefill_buckets)}")

    # -------------------------------------------------------------- run
    def run(self, requests: List[Request],
            realtime: bool = True) -> ServeMetrics:
        """Serve ``requests`` (arrival times in seconds relative to run
        start) to completion.  ``realtime=False`` treats arrivals as
        already-arrived (offline batch; deterministic, used by tests)."""
        sched = self.scheduler
        sv = self.serving
        for r in requests:
            sched.submit(r)
        t0 = time.perf_counter()
        now = lambda: (time.perf_counter() - t0) if realtime else \
            float("inf")
        decode_iters = 0

        while sched.has_work:
            # ---------------- prefill phase -----------------------------
            for _ in range(sv.max_prefill_per_iter):
                req = sched.try_admit(now())
                if req is None:
                    break
                self._prefill_one(req)
                first = now() if realtime else 0.0
                if req.t_first_token is None:
                    req.t_first_token = first
                sched.activate(req)
                if req.done:          # max_new_tokens == 1 degenerate case
                    sched.finish(req, now() if realtime else 0.0)

            # ---------------- ragged decode phase -----------------------
            runnable = sched.ensure_decode_blocks()
            if not runnable:
                if sched.waiting and not sched.running:
                    nxt = min(r.arrival for r in sched.waiting)
                    wait = nxt - now()
                    if realtime and wait > 0:
                        time.sleep(min(wait, 0.05))
                continue
            t_it = time.perf_counter()
            tokens = np.zeros((sv.max_batch, 1), np.int32)
            bt = np.full((sv.max_batch, sv.max_blocks_per_seq),
                         TRASH_BLOCK, np.int32)
            pos = np.zeros((sv.max_batch,), np.int32)
            for r in runnable:
                tokens[r.slot, 0] = r.input_token(r.pos)
                bt[r.slot, :len(r.blocks)] = r.blocks
                pos[r.slot] = r.pos
            next_tok, self._keys, self.pages = self._decode_fn(
                self.params, self.pages, self._keys, jnp.asarray(tokens),
                jnp.asarray(bt), jnp.asarray(pos))
            next_tok = np.asarray(next_tok)
            it_s = time.perf_counter() - t_it
            decode_iters += 1
            for r in runnable:
                # post-preemption replay: steps whose output token is
                # already recorded only rebuild the cache — the
                # recomputation is identical, so the produced token is
                # discarded, not re-sampled (token-exact resume).
                replaying = r.pos - len(r.prompt) + 1 < len(r.generated)
                if not replaying:
                    r.generated.append(int(next_tok[r.slot]))
                    r.token_latencies.append(it_s)
                r.pos += 1
                if r.done and not replaying:
                    sched.finish(r, now() if realtime else 0.0)

        wall = time.perf_counter() - t0
        return self._metrics(requests, wall, decode_iters)

    def _prefill_one(self, req: Request) -> None:
        prompt = req.prefill_tokens
        bucket = self._bucket_for(len(prompt))
        tokens = np.zeros((1, bucket), np.int32)
        tokens[0, :len(prompt)] = prompt
        bt_row = np.full((self._bt_row_len(bucket),), TRASH_BLOCK,
                         np.int32)
        bt_row[:len(req.blocks)] = req.blocks
        first_tok, self._keys, self.pages = self._prefill_fn(bucket)(
            self.params, self.pages, self._keys, jnp.asarray(tokens),
            jnp.asarray([len(prompt) - 1], jnp.int32),
            jnp.asarray(bt_row), jnp.int32(req.slot))
        if not req.generated:
            req.generated.append(int(np.asarray(first_tok)[0]))
        # resumed after preemption: the prefill only rebuilt the prompt's
        # caches (KV pages / window ring / SSM state — bit-exact
        # recomputation); recorded tokens now replay through the decode
        # path (the backend that originally produced them), so generation
        # is token-exact regardless of pool pressure.

    def _metrics(self, requests: List[Request], wall: float,
                 decode_iters: int) -> ServeMetrics:
        ttfts = [r.t_first_token - r.arrival for r in requests
                 if r.t_first_token is not None]
        lats = [t for r in requests for t in r.token_latencies]
        total = sum(len(r.generated) for r in requests)
        return ServeMetrics(
            num_requests=len(requests),
            total_generated=total,
            wall_s=wall,
            throughput_tok_s=total / wall if wall > 0 else float("nan"),
            ttft_s_mean=float(np.mean(ttfts)) if ttfts else float("nan"),
            ttft_s_p99=_percentile(ttfts, 99),
            token_latency_s_p50=_percentile(lats, 50),
            token_latency_s_p99=_percentile(lats, 99),
            preemptions=sum(r.preemptions for r in requests),
            decode_iters=decode_iters,
        )
