"""Continuous-batching serving engine (sglang/vLLM-style, JAX-static).

Execution model — the **token-budget mixed step** (default,
``serving.prefill_chunk > 0``): each iteration the scheduler grants at
most ONE fixed-size prefill chunk (``PrefillChunk`` cursor on the
request, per-chunk block growth) alongside the full ragged decode batch,
and a single jitted call runs both.  Chunk queries attend over the pages
earlier chunks committed (prefix-extension attention — see
:func:`repro.models.attention.attention_prefill_chunk`), sliding-window
rings thread the chunk through the circular page list, and Mamba state
carries across chunks in the per-slot state rows.  Consequences:

* decode stall per iteration is bounded by one chunk, not one prompt —
  no head-of-line blocking on long-context prefills;
* exactly TWO compiles total (mixed step + decode-only step) instead of
  one per prefill bucket;
* prompts are bounded only by ``max_blocks_per_seq * block_size``, not
  by the largest prefill bucket.

``serving.prefill_chunk == 0`` keeps the legacy alternating phases:
whole-prompt bucket-padded prefill (one compile per bucket, prompts
beyond the largest bucket rejected), then one ragged decode step.

Layers are cached per the **per-layer cache plan** (``cfg.cache_plan()``):
global-attention layers hold backend-paged KV (+ SOCKET bits / Quest
stats) addressed linearly by the block table; sliding-window layers a
bounded circular page ring; Mamba layers O(1) per-slot state holding no
blocks at all.  Heterogeneous layouts (gemma3's 5:1 local:global,
jamba's attn:mamba hybrid, pure-SSM mamba2) all serve continuously.

For **paged-capable** backends (``DecodeBackend.supports_paged``: socket,
hard_lsh, quest) — or models without global-attention layers — the
decode step hands the page pool + block tables straight to the model:
appends write to pages in place and global attention reads only the
small metadata leaves plus the selected ``O(top_k)`` K/V rows — no
contiguous K/V view is ever materialized.  Otherwise (dense) the engine
falls back to the gather/scatter round trip (``paged.gather_views`` /
``scatter_token``), which is still window-bounded for ring layers and
free for state layers.

Sampling is greedy by default (bit-exact vs the static engine);
``temperature > 0`` switches the jitted step to temperature + top-p
sampling.  Each request owns its PRNG key (folded from the engine seed
and the request's submission index, stored on the ``Request`` and
re-installed into the slot on every admission), and slot key streams
only advance while their request is active — a request's sample stream
is a pure function of (seed, submission index, token index), so
preemption resume replays sampled generations bit-exactly and batch
composition never perturbs a request's randomness.
``input_mode == "tokens"`` only.
"""

from __future__ import annotations

import contextlib
import dataclasses
import math
import time
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import backends as bk
from repro.models import param as pm
from repro.models import transformer as tfm
from repro.runtime.steps import (make_chunk_prefill_step, make_prefill_step,
                                 make_serve_step)
from repro.serving import paged, sampling
from repro.serving.block_pool import TRASH_BLOCK, BlockPool
from repro.serving.obs import Observability
from repro.serving.obs.metrics import Registry
from repro.serving.prefix_cache import PrefixCache
from repro.serving.scheduler import (PREFILL, PrefillChunk, Request,
                                     Scheduler)

__all__ = ["ContinuousBatchingEngine", "ServeMetrics"]


@dataclasses.dataclass
class ServeMetrics:
    """Aggregate serving metrics for one engine run."""

    num_requests: int
    total_generated: int
    wall_s: float
    throughput_tok_s: float
    ttft_s_mean: float
    ttft_s_p99: float
    token_latency_s_p50: float
    token_latency_s_p99: float
    preemptions: int
    decode_iters: int
    prefill_chunks: int
    # longest wall-clock gap between consecutive token emissions of any
    # single request — the head-of-line-blocking metric chunked prefill
    # exists to bound (legacy mode: a long co-tenant prompt lands here)
    intertoken_stall_s_max: float
    # p99 over jitted step-call durations (mixed or decode-only)
    decode_iter_s_p99: float

    def to_json(self) -> Dict:
        """Strict-JSON dict: non-finite floats (empty-series percentiles
        are NaN) become ``None``/``null`` — ``NaN`` is not JSON and a
        default ``json.dump`` of it breaks every compliant consumer."""
        out = {}
        for k, v in dataclasses.asdict(self).items():
            if isinstance(v, float):
                v = round(v, 6) if math.isfinite(v) else None
            out[k] = v
        return out


class ContinuousBatchingEngine:
    """Paged-cache continuous batching over one model replica."""

    def __init__(self, cfg: ModelConfig, params=None,
                 rng: Optional[jax.Array] = None, *,
                 temperature: float = 0.0, top_p: float = 1.0,
                 sample_seed: int = 0,
                 obs: Optional[Observability] = None):
        self._validate(cfg)
        self.cfg = cfg
        self.serving = cfg.serving
        self.serving.validate()
        if params is None:
            rng = rng if rng is not None else jax.random.PRNGKey(0)
            params = pm.unbox(tfm.init_model(cfg, rng))
        self.params = params
        self.backend = bk.get_backend(cfg.attention_backend)
        plan = cfg.cache_plan()
        has_paged = any(p.kind == "paged" for p in plan)
        ring_blocks = max((p.ring_blocks for p in plan
                           if p.kind == "ring"), default=0)
        self._has_state = any(p.kind == "state" for p in plan)
        # page-native decode: paged-capable backend, or no global layer
        # consumes the backend at all (ring/state layers are page-native
        # by construction)
        self._paged_native = self.backend.supports_paged or not has_paged
        self.temperature = float(temperature)
        self.top_p = float(top_p)
        self._sample_base = jax.random.PRNGKey(sample_seed)
        self._submitted = 0
        self._keys = sampling.slot_keys(sample_seed, self.serving.max_batch)
        self.pages = paged.init_paged_caches(cfg, self.serving)
        self.pool = BlockPool(self.serving.num_blocks)
        self.scheduler = Scheduler(
            self.pool, max_batch=self.serving.max_batch,
            max_blocks_per_seq=self.serving.max_blocks_per_seq,
            block_size=self.serving.block_size,
            has_paged_layers=has_paged, ring_blocks=ring_blocks,
            prefill_chunk=self.serving.prefill_chunk)
        self._decode_fn = self._build_decode()
        self._mixed_fn = self._build_mixed() if self.chunked else None
        self._prefilling: Optional[Request] = None
        self._prefill_fns: Dict[int, callable] = {}
        # ---- prefix cache ------------------------------------------------
        # Cross-request page reuse is valid exactly when (a) chunked
        # prefill is on (a hit IS a prefill starting at a nonzero
        # cursor — legacy bucketed prefill has no cursor), and (b) every
        # layer is paged: ring layers recycle their block-table prefix
        # circularly (a "shared prefix" would be rewritten in place) and
        # Mamba state is per-slot, not per-page, so a cached prefix
        # would resume with the wrong recurrent state.  Hybrids fall
        # back to no-share cleanly — the flag stays on, no cache is
        # built, serving is unchanged.
        self.prefix_cache = None
        self._cow_fn = None
        if self.serving.prefix_cache and self.chunked and has_paged \
                and ring_blocks == 0 and not self._has_state:
            spec = self.backend.cache_spec(cfg)
            # granularity>1 leaves (Quest per-page stats) summarize every
            # row of a page: partial-page sharing or a partial CoW keep
            # would score junk keys, so such plans share page-aligned
            # prefixes only (and structurally never hit the CoW path).
            tail_ok = all(s.granularity == 1 for s in spec.values())
            self.prefix_cache = PrefixCache(
                self.pool, block_size=self.serving.block_size,
                tail_shareable=tail_ok)
            self.scheduler.prefix_cache = self.prefix_cache

            def _clone(pages, src, dst, keep):
                return paged.clone_block(self.cfg, pages, src, dst, keep)

            self._cow_fn = jax.jit(_clone, donate_argnums=(0,))
        # test hook: called as iter_hook(engine, iteration) at the end of
        # every engine iteration (CoW invariant property tests snapshot
        # shared pages here); None in production.
        self.iter_hook = None
        # (iteration, rid, chunk.start, chunk.tokens) per chunk co-run —
        # lets tests pin "never more than one chunk per decode iteration"
        self.chunk_trace: List[Tuple[int, int, int, int]] = []
        # ---- observability ----------------------------------------------
        # The metrics registry is always on (pure-Python counters; the
        # end-of-run ServeMetrics derive from it).  Everything else —
        # tracer, probe, profiler — only exists when ``obs`` is given:
        # with obs=None the hot loop allocates zero tracing objects per
        # step (pinned by tests/test_observability.py).
        self.obs = obs
        self.registry = Registry()
        self._bind_instruments(self.registry)
        self._probe_fn = None
        self._compile_seen: set = set()
        self._probe_capable = (
            (cfg.attention_backend in ("hard_lsh", "quest")
             or (cfg.attention_backend == "socket"
                 and cfg.socket.selection in ("kvhead", "pooled")))
            and has_paged)
        if obs is not None:
            counts = paged.cache_kind_counts(cfg)
            obs.tracer.ensure_start(
                arch=cfg.name, backend=cfg.attention_backend,
                prefill_chunk=self.serving.prefill_chunk,
                layers_paged=counts["paged"], layers_ring=counts["ring"],
                layers_state=counts["state"],
                prefix_cache=self.prefix_cache is not None)

    @property
    def chunked(self) -> bool:
        return self.serving.prefill_chunk > 0

    # ------------------------------------------------------ observability
    def _bind_instruments(self, reg: Registry) -> None:
        """Create (or re-bind, at run start) the run-scoped serving
        series.  ``exact=True``: these histograms also retain samples,
        so end-of-run ServeMetrics percentiles are byte-identical to a
        direct ``np.percentile`` over the recorded series."""
        self._c_tokens = reg.counter("serve_tokens_total")
        self._h_ttft = reg.histogram("serve_ttft_s", exact=True)
        self._h_lat = reg.histogram("serve_token_latency_s", exact=True)
        self._h_stall = reg.histogram("serve_intertoken_stall_s",
                                      exact=True)
        self._h_iter = reg.histogram("serve_iter_s", exact=True)

    def _set_gauges(self, reg: Registry) -> None:
        st = self.pool.stats()
        reg.gauge("pool_blocks_free").set(st["free"])
        reg.gauge("pool_blocks_used").set(st["used"])
        reg.gauge("pool_blocks_high_water").set(st["high_water"])
        sched = self.scheduler
        reg.gauge("batch_running").set(len(sched.running))
        reg.gauge("batch_prefilling").set(len(sched.prefilling))
        reg.gauge("batch_waiting").set(len(sched.waiting))
        if self.prefix_cache is not None:
            reg.gauge("prefix_cache_shared_blocks").set(
                self.prefix_cache.shared_blocks)
            reg.gauge("prefix_cache_evictable_blocks").set(
                self.prefix_cache.evictable_blocks())

    def _note_call(self, tag: str, seconds: float) -> None:
        """First dispatch of a jitted shape = trace + compile + run;
        record it as a compile event so latency analysis can discount
        it (warmup marks the shapes it covers)."""
        if tag in self._compile_seen:
            return
        self._compile_seen.add(tag)
        if self.obs is not None:
            self.obs.tracer.emit("compile", fn=tag,
                                 seconds=round(seconds, 6))

    def _note_token(self, req: Request, w: float) -> None:
        """Per-emitted-token bookkeeping shared by the decode loop and
        the first-token prefill sites."""
        self._c_tokens.inc()
        req.token_walls.append(w)
        if len(req.token_walls) >= 2:
            self._h_stall.record(req.token_walls[-1]
                                 - req.token_walls[-2])

    def _note_first_token(self, req: Request, t: float) -> None:
        req.t_first_token = t
        ttft = t - req.arrival
        self._h_ttft.record(ttft)
        if self.obs is not None:
            self.obs.tracer.emit("first_token", rid=req.rid,
                                 ttft_s=round(ttft, 6))

    @staticmethod
    def _validate(cfg: ModelConfig) -> None:
        if cfg.input_mode != "tokens":
            raise NotImplementedError(
                "continuous engine serves token models only")
        if any(s.kind == "attn" and s.attn_type == "global"
               for s in cfg.layer_specs):
            # resolves the backend (ValueError on unknown names) and
            # validates its cache layout against the serving geometry
            # (e.g. quest's page_size must divide block_size)
            bk.get_backend(cfg.attention_backend).cache_spec(cfg)
        if cfg.decode_cp_axes:
            raise NotImplementedError(
                "ragged decode + context-parallel SOCKET is a ROADMAP item")

    # --------------------------------------------------------------- jit
    def _pick(self, logits: jax.Array, keys: jax.Array):
        """Next-token choice from one step's ``(B, 1, V)`` logits."""
        last = logits[:, -1]
        if self.temperature > 0:
            return sampling.sample_tokens(
                last, keys, temperature=self.temperature, top_p=self.top_p,
                vocab_size=self.cfg.vocab_size)
        return jnp.argmax(last, axis=-1), keys

    def _decode_body(self, serve, params, pages, keys, tokens, bt, pos,
                     active):
        """Shared ragged-decode body of the decode-only and mixed steps.

        ``active`` (``(B,)`` bool) marks slots holding a runnable
        request: inactive slots keep their per-slot state rows (a
        chunk-owner's Mamba state must survive the decode iterations
        between its chunks) and their PRNG keys (a request's sample
        stream advances exactly once per emitted token, never while the
        slot idles — the replay-exact resume invariant).
        """
        if self._paged_native:
            # page-native path: the pool + block tables go straight into
            # the model; no contiguous K/V view is ever materialized.
            logits, new_pages = serve(params, pages, tokens, pos, bt)
        else:
            views = paged.gather_views(self.cfg, pages, bt)
            logits, views = serve(params, views, tokens, pos)
            new_pages = paged.scatter_token(self.cfg, pages, views, bt, pos)
        if self._has_state:
            new_pages = paged.keep_state_rows(self.cfg, pages, new_pages,
                                              active)
        tok, new_keys = self._pick(logits, keys)
        keys = jnp.where(active[:, None], new_keys, keys)
        return tok, keys, new_pages

    def _build_decode(self):
        serve = make_serve_step(self.cfg)

        def step(params, pages, keys, tokens, bt, pos, active):
            return self._decode_body(serve, params, pages, keys, tokens,
                                     bt, pos, active)

        return jax.jit(step, donate_argnums=(1,))

    def _build_mixed(self):
        """The token-budget mixed step: one prefill chunk + the full
        ragged decode batch in ONE jitted call.  The chunk runs first
        (its writes land in blocks disjoint from every decoding
        request), then the decode batch; ``ch_final`` gates whether the
        chunk's logits consume the slot's PRNG key (only the final chunk
        emits a token)."""
        serve = make_serve_step(self.cfg)
        chunk_fn = make_chunk_prefill_step(self.cfg)

        def step(params, pages, keys, ch_tokens, ch_bt, ch_slot, ch_hist,
                 ch_last, ch_final, tokens, bt, pos, active):
            logits_c, pages = chunk_fn(params, pages, ch_tokens, ch_bt,
                                       ch_slot, ch_hist, ch_last)
            tok_c, key_c = self._pick(logits_c, keys[ch_slot][None])
            keys = keys.at[ch_slot].set(
                jnp.where(ch_final, key_c[0], keys[ch_slot]))
            tok, keys, pages = self._decode_body(
                serve, params, pages, keys, tokens, bt, pos, active)
            return tok_c[0], tok, keys, pages

        return jax.jit(step, donate_argnums=(1,))

    def _bt_row_len(self, bucket: int) -> int:
        """Prefill block-table row length: the bucket's blocks, but at
        least the circular window pages (a short prompt's ring still
        spans ``ring_blocks`` table entries; unallocated ones are
        trash)."""
        return max(bucket // self.serving.block_size,
                   self.scheduler.ring_blocks)

    def _chunk_bt_len(self) -> int:
        """Chunk block-table row length: the full per-request table plus
        one chunk of slack, so the final (padded) chunk's block window
        never clamps — its overhang entries are trash."""
        sv = self.serving
        return sv.max_blocks_per_seq + sv.prefill_chunk // sv.block_size

    def _prefill_fn(self, bucket: int):
        if bucket not in self._prefill_fns:
            prefill = make_prefill_step(self.cfg, bucket, bucketed=True,
                                        paged=True)

            def step(params, pages, keys, tokens, last_index, bt_row,
                     slot):
                logits, caches = prefill(params, {"tokens": tokens},
                                         last_index)
                pages = paged.write_prefill(self.cfg, pages, caches,
                                            bt_row, slot)
                tok, key = self._pick(logits, keys[slot][None])
                keys = keys.at[slot].set(key[0])
                return tok, keys, pages

            self._prefill_fns[bucket] = jax.jit(step, donate_argnums=(1,))
        return self._prefill_fns[bucket]

    def warmup(self, requests: Optional[List[Request]] = None) -> None:
        """Trigger the jit compiles a run will need against the trash
        page, so a subsequent run's TTFT and latency percentiles measure
        serving, not compilation.  Chunked mode needs exactly TWO shapes
        (mixed + decode-only) regardless of the workload; legacy mode
        warms one prefill compile per bucket — only the buckets
        ``requests`` will actually hit when given, all of them otherwise.
        Sampling keys are not consumed (warmup randomness is
        discarded)."""
        sv = self.serving
        tokens = jnp.zeros((sv.max_batch, 1), jnp.int32)
        bt = jnp.full((sv.max_batch, sv.max_blocks_per_seq), TRASH_BLOCK,
                      jnp.int32)
        pos = jnp.zeros((sv.max_batch,), jnp.int32)
        active = jnp.zeros((sv.max_batch,), bool)
        t_w = time.perf_counter()
        _, _, self.pages = self._decode_fn(self.params, self.pages,
                                           self._keys, tokens, bt, pos,
                                           active)
        self._note_call("decode", time.perf_counter() - t_w)
        if self.chunked:
            ch_bt = jnp.full((self._chunk_bt_len(),), TRASH_BLOCK,
                             jnp.int32)
            t_w = time.perf_counter()
            _, _, _, self.pages = self._mixed_fn(
                self.params, self.pages, self._keys,
                jnp.zeros((1, sv.prefill_chunk), jnp.int32), ch_bt,
                jnp.int32(0), jnp.int32(0), jnp.zeros((1,), jnp.int32),
                jnp.asarray(False), tokens, bt, pos, active)
            self._note_call("mixed", time.perf_counter() - t_w)
            if self._cow_fn is not None:
                # clone trash onto trash: compiles the CoW kernel without
                # touching any real page (keep=0 scrubs block 0, whose
                # contents are never read unmasked anyway)
                t_w = time.perf_counter()
                self.pages = self._cow_fn(self.pages, jnp.int32(0),
                                          jnp.int32(0), jnp.int32(0))
                self._note_call("cow_clone", time.perf_counter() - t_w)
            return
        buckets = sv.prefill_buckets if requests is None else sorted(
            {self._bucket_for(len(r.prefill_tokens)) for r in requests})
        for bucket in buckets:
            bt_row = jnp.full((self._bt_row_len(bucket),), TRASH_BLOCK,
                              jnp.int32)
            t_w = time.perf_counter()
            _, _, self.pages = self._prefill_fn(bucket)(
                self.params, self.pages, self._keys,
                jnp.zeros((1, bucket), jnp.int32),
                jnp.zeros((1,), jnp.int32), bt_row, jnp.int32(0))
            self._note_call(f"prefill_{bucket}",
                            time.perf_counter() - t_w)

    def _bucket_for(self, n: int) -> int:
        for b in sorted(self.serving.prefill_buckets):
            if b >= n:
                return b
        raise ValueError(
            f"prompt of {n} tokens exceeds largest prefill bucket "
            f"{max(self.serving.prefill_buckets)} (chunked prefill — "
            f"serving.prefill_chunk > 0 — serves prompts up to "
            f"max_context {self.serving.max_context})")

    # -------------------------------------------------------------- keys
    def _register(self, req: Request) -> None:
        """Assign the request's sampling key at first submission: folded
        from the engine seed and the submission index, so the stream is
        deterministic per workload and survives preemption (re-submission
        keeps the stored key)."""
        if req.sample_key is None:
            req.sample_key = np.asarray(
                jax.random.fold_in(self._sample_base, self._submitted))
        self._submitted += 1

    def _install_key(self, req: Request) -> None:
        """(Re-)install the request's key into its slot at admission.
        Replay after preemption then re-advances the stream exactly as
        the original run did — one consumption per emitted token."""
        keys = np.array(self._keys)          # writable host copy
        keys[req.slot] = req.sample_key
        self._keys = jnp.asarray(keys)

    # -------------------------------------------------------------- run
    def run(self, requests: List[Request],
            realtime: bool = True) -> ServeMetrics:
        """Serve ``requests`` (arrival times in seconds relative to run
        start) to completion.  ``realtime=False`` treats arrivals as
        already-arrived (offline batch; deterministic, used by tests)."""
        sched = self.scheduler
        sv = self.serving
        obs = self.obs
        tracer = obs.tracer if obs is not None else None
        probe = obs.probe if obs is not None and obs.probe.every > 0 \
            and self._probe_capable else None
        profiler = obs.profiler if obs is not None else None
        reg = self.registry = Registry()    # run-scoped, like the metrics
        self._bind_instruments(reg)
        sched.bind_obs(reg, tracer)
        self.chunk_trace = []               # per-run, like the metrics
        run_ord = tracer.begin_run(requests=len(requests)) if tracer \
            else 0
        for r in requests:
            self._register(r)
            sched.submit(r)
            if tracer:
                tracer.emit("submit", rid=r.rid,
                            prompt_tokens=len(r.prompt),
                            max_new_tokens=r.max_new_tokens,
                            arrival=float(r.arrival))
        t0 = time.perf_counter()
        wall = lambda: time.perf_counter() - t0
        now = wall if realtime else (lambda: float("inf"))
        stamp = wall if realtime else (lambda: 0.0)
        decode_iters = 0
        c_iters_mixed = reg.counter("serve_iters_total", kind="mixed")
        c_iters_decode = reg.counter("serve_iters_total", kind="decode")
        c_chunks = reg.counter("serve_chunks_total")

        while sched.has_work:
            chunk: Optional[PrefillChunk] = None
            if self.chunked:
                # decode-table growth FIRST (it may evict the prefiller,
                # which must not happen after a chunk has been granted —
                # the granted chunk's block ids would be dangling)...
                runnable = sched.ensure_decode_blocks()
                if self.prefix_cache is not None:
                    self._resolve_decode_cow(runnable)
                if self._prefilling is not None and \
                        self._prefilling.state != PREFILL:
                    self._prefilling = None  # evicted by decode growth/CoW
                # ...then the chunk grant (alloc-only — its cache-evict
                # tier frees refcount-1 pages, never a live request — so
                # it cannot invalidate the runnable snapshot)
                if self._prefilling is None:
                    req = sched.try_admit(now())
                    if req is not None:
                        self._install_key(req)
                        self._prefilling = req
                if self._prefilling is not None:
                    chunk = sched.grant_chunk(self._prefilling)
                    if chunk is None and \
                            self._prefilling.state != PREFILL:
                        self._prefilling = None   # safety self-preempt
                if chunk is not None and self.prefix_cache is not None \
                        and not self._resolve_chunk_cow(self._prefilling,
                                                        chunk):
                    # the prefiller itself was preempted making room for
                    # its CoW clone — the granted chunk is void
                    chunk = None
                    self._prefilling = None
                if self.prefix_cache is not None:
                    # CoW allocation may have LRU-preempted decoders out
                    # of the snapshot taken above
                    runnable = [r for r in runnable
                                if sched.running.get(r.slot) is r]
            else:
                # legacy order: whole-prompt prefill phase, then growth —
                # a request admitted this iteration decodes this
                # iteration (ensure-first would cost every admission one
                # extra iteration of inter-token latency)
                for _ in range(sv.max_prefill_per_iter):
                    req = sched.try_admit(now())
                    if req is None:
                        break
                    self._install_key(req)
                    self._prefill_one(req, wall)
                    if req.t_first_token is None:
                        self._note_first_token(req, stamp())
                    sched.activate(req)
                    if req.done:      # max_new_tokens == 1 degenerate case
                        sched.finish(req, stamp())
                runnable = sched.ensure_decode_blocks()

            # ---------------- ragged decode (+ chunk) -------------------
            if not runnable and chunk is None:
                if sched.waiting and not sched.running and \
                        self._prefilling is None:
                    nxt = min(r.arrival for r in sched.waiting)
                    wait = nxt - now()
                    if realtime and wait > 0:
                        time.sleep(min(wait, 0.05))
                continue
            if profiler is not None:
                profiler.maybe_start(decode_iters, tracer)
            t_it = time.perf_counter()
            tokens = np.zeros((sv.max_batch, 1), np.int32)
            bt = np.full((sv.max_batch, sv.max_blocks_per_seq),
                         TRASH_BLOCK, np.int32)
            pos = np.zeros((sv.max_batch,), np.int32)
            active = np.zeros((sv.max_batch,), bool)
            for r in runnable:
                tokens[r.slot, 0] = r.input_token(r.pos)
                bt[r.slot, :len(r.blocks)] = r.blocks
                pos[r.slot] = r.pos
                active[r.slot] = True
            if probe is not None and runnable \
                    and probe.due(decode_iters):
                self._run_probe(decode_iters, tokens, bt, pos, active,
                                runnable)
            kind = "decode" if chunk is None else "mixed"
            ann = profiler.annotate(kind) if profiler is not None \
                else contextlib.nullcontext()
            if chunk is not None:
                with ann:
                    first_tok, next_tok = self._run_mixed(
                        chunk, tokens, bt, pos, active)
                self.chunk_trace.append((decode_iters,
                                         self._prefilling.rid,
                                         chunk.start, chunk.tokens))
                c_chunks.inc()
                self._finish_chunk(chunk, first_tok, wall, stamp)
            else:
                with ann:
                    next_tok, self._keys, self.pages = self._decode_fn(
                        self.params, self.pages, self._keys,
                        jnp.asarray(tokens), jnp.asarray(bt),
                        jnp.asarray(pos), jnp.asarray(active))
            next_tok = np.asarray(next_tok)
            it_s = time.perf_counter() - t_it
            self._note_call(kind, it_s)
            self._h_iter.record(it_s)
            (c_iters_mixed if chunk is not None else c_iters_decode).inc()
            for r in runnable:
                # post-preemption replay: steps whose output token is
                # already recorded only rebuild the cache — the
                # recomputation is identical, so the produced token is
                # discarded, not re-sampled (token-exact resume).
                replaying = r.pos - len(r.prompt) + 1 < len(r.generated)
                if not replaying:
                    r.generated.append(int(next_tok[r.slot]))
                    r.token_latencies.append(it_s)
                    self._h_lat.record(it_s)
                    self._note_token(r, wall())
                r.pos += 1
                if r.done and not replaying:
                    sched.finish(r, stamp())
            self._set_gauges(reg)
            if tracer:
                st = self.pool.stats()
                tracer.emit(
                    "step", iter=decode_iters, kind=kind,
                    occupancy=int(active.sum()),
                    chunk_tokens=chunk.tokens if chunk is not None else 0,
                    step_s=round(it_s, 6), pool_free=st["free"],
                    pool_used=st["used"],
                    pool_high_water=st["high_water"],
                    waiting=len(sched.waiting),
                    prefilling=len(sched.prefilling),
                    running=len(sched.running))
            decode_iters += 1
            if self.iter_hook is not None:
                self.iter_hook(self, decode_iters)
            if profiler is not None:
                profiler.maybe_stop(decode_iters, tracer)

        if profiler is not None:
            profiler.stop(tracer)           # run shorter than the window
        wall_total = time.perf_counter() - t0
        m = self._metrics(requests, wall_total)
        if tracer:
            tracer.end_run(run_ord, requests=len(requests),
                           generated=m.total_generated,
                           wall_s=round(wall_total, 6))
        return m

    # --------------------------------------------------------------- cow
    def _cow(self, req: Request, idx: int, keep: int) -> bool:
        """Un-share block ``idx`` of ``req`` before a write: allocate a
        fresh block (cache-evict, then LRU-preempt tiers), device-clone
        the page's first ``keep`` token rows across every paged leaf —
        scrubbing the rest to init fill, so the donor's tokens past the
        matched prefix (or its generated continuation) never leak into
        the new owner — and swap it into the request's table.  The old
        block is deref'd, never mutated: the CoW invariant.  Returns
        False iff ``req`` itself was preempted to make room."""
        old = req.blocks[idx]
        new = self.scheduler.cow_alloc(req)
        if new is None:
            return False
        t_c = time.perf_counter()
        self.pages = self._cow_fn(self.pages, jnp.int32(old),
                                  jnp.int32(new), jnp.int32(keep))
        self._note_call("cow_clone", time.perf_counter() - t_c)
        req.blocks[idx] = new
        self.pool.free([old])
        self.registry.counter("prefix_cache_cow_total").inc()
        if self.obs is not None:
            self.obs.tracer.emit("cow_copy", rid=req.rid, block=old,
                                 clone=new, keep_tokens=keep)
        return True

    def _resolve_chunk_cow(self, req: Request,
                           chunk: PrefillChunk) -> bool:
        """Clone any shared block the granted chunk would write.  Only
        the chunk's FIRST block can be shared — a cache hit's cursor may
        sit mid-way through the matched tail page — but every touched
        block is checked (cheap, and keeps the invariant local).  Returns
        False iff ``req`` was preempted while allocating a clone."""
        bs = self.serving.block_size
        first = chunk.start // bs
        last = (chunk.start + chunk.tokens - 1) // bs
        for idx in range(first, min(last + 1, len(req.blocks))):
            if self.pool.is_shared(req.blocks[idx]):
                if not self._cow(req, idx,
                                 keep=max(0, chunk.start - idx * bs)):
                    return False
        return True

    def _resolve_decode_cow(self, runnable: List[Request]) -> None:
        """Enforce the CoW invariant for the decode batch.  Structurally
        a decode write position (``pos >= prompt_len``) can never sit in
        a shared page — the cache indexes prompt-pure pages only, and a
        hit's tail page is un-shared by the first chunk write — but the
        invariant is cheap to enforce locally rather than by global
        argument, and it stays correct under future insert policies."""
        bs = self.serving.block_size
        for r in runnable:
            idx = r.pos // bs
            if idx < len(r.blocks) and self.pool.is_shared(r.blocks[idx]):
                self._cow(r, idx, keep=r.pos % bs)

    # ------------------------------------------------------------- chunk
    def _run_mixed(self, chunk: PrefillChunk, tokens, bt, pos, active):
        """Dispatch the mixed step for ``chunk`` plus the decode batch."""
        req = self._prefilling
        sv = self.serving
        c = sv.prefill_chunk
        ch_tokens = np.zeros((1, c), np.int32)
        ch_tokens[0, :chunk.tokens] = \
            req.prefill_tokens[chunk.start:chunk.start + chunk.tokens]
        ch_bt = np.full((self._chunk_bt_len(),), TRASH_BLOCK, np.int32)
        ch_bt[:len(req.blocks)] = req.blocks
        first_tok, next_tok, self._keys, self.pages = self._mixed_fn(
            self.params, self.pages, self._keys, jnp.asarray(ch_tokens),
            jnp.asarray(ch_bt), jnp.int32(req.slot), jnp.int32(chunk.start),
            jnp.asarray([chunk.tokens - 1], jnp.int32),
            jnp.asarray(chunk.final), jnp.asarray(tokens), jnp.asarray(bt),
            jnp.asarray(pos), jnp.asarray(active))
        return first_tok, next_tok

    def _finish_chunk(self, chunk: PrefillChunk, first_tok, wall,
                      stamp) -> None:
        """Advance the cursor; on the final chunk record the first token
        (unless replay already holds it) and activate into decode."""
        req = self._prefilling
        sched = self.scheduler
        sched.advance_chunk(req, chunk)
        if not chunk.final:
            return
        if not req.generated:
            req.generated.append(int(np.asarray(first_tok)))
            self._note_token(req, wall())
        if req.t_first_token is None:
            self._note_first_token(req, stamp())
        sched.activate(req)
        if req.done:                  # max_new_tokens == 1 degenerate case
            sched.finish(req, stamp())
        self._prefilling = None

    def _prefill_one(self, req: Request, wall) -> None:
        prompt = req.prefill_tokens
        bucket = self._bucket_for(len(prompt))
        tokens = np.zeros((1, bucket), np.int32)
        tokens[0, :len(prompt)] = prompt
        bt_row = np.full((self._bt_row_len(bucket),), TRASH_BLOCK,
                         np.int32)
        bt_row[:len(req.blocks)] = req.blocks
        t_p = time.perf_counter()
        first_tok, self._keys, self.pages = self._prefill_fn(bucket)(
            self.params, self.pages, self._keys, jnp.asarray(tokens),
            jnp.asarray([len(prompt) - 1], jnp.int32),
            jnp.asarray(bt_row), jnp.int32(req.slot))
        self._note_call(f"prefill_{bucket}", time.perf_counter() - t_p)
        if not req.generated:
            req.generated.append(int(np.asarray(first_tok)[0]))
            self._note_token(req, wall())
        # resumed after preemption: the prefill only rebuilt the prompt's
        # caches (KV pages / window ring / SSM state — bit-exact
        # recomputation); recorded tokens now replay through the decode
        # path (the backend that originally produced them), so generation
        # is token-exact regardless of pool pressure.

    # ------------------------------------------------------------- probe
    def _run_probe(self, iteration: int, tokens, bt, pos, active,
                   runnable: List[Request]) -> None:
        """Sampled selection-quality probe: re-run the current decode
        batch through a shadow step traced with the capture flag up
        (:mod:`repro.models.backends.probe`), so every sparse layer
        (socket / hard_lsh / quest) ships per-request recall /
        budget-utilization / forced-share stats to the host — then reduce over the active slots and emit
        one ``probe`` event per layer.  The shadow step is jitted
        WITHOUT donation (the production step still needs these pages)
        and its outputs are discarded; the production decode fn contains
        zero probe ops."""
        from repro.models.backends import probe as bprobe
        if self._probe_fn is None:
            serve = make_serve_step(self.cfg)

            def step(params, pages, keys, tokens, bt, pos, active):
                return self._decode_body(serve, params, pages, keys,
                                         tokens, bt, pos, active)

            self._probe_fn = jax.jit(step)
        t_p = time.perf_counter()
        bprobe.drain()                      # drop anything stale
        with bprobe.capture():
            self._probe_fn(self.params, self.pages, self._keys,
                           jnp.asarray(tokens), jnp.asarray(bt),
                           jnp.asarray(pos), jnp.asarray(active))
            jax.effects_barrier()           # flush the stat callbacks
        self._note_call("probe", time.perf_counter() - t_p)
        stats = bprobe.drain()
        rows = self.obs.probe.add(iteration, stats,
                                  [r.slot for r in runnable])
        for row in rows:
            self.obs.tracer.emit("probe", **row)
            if row["recall"] is not None:
                self.registry.histogram("probe_recall").record(
                    row["recall"])
                self.registry.histogram(
                    "probe_budget_utilization").record(
                        row["budget_utilization"])

    # ----------------------------------------------------------- metrics
    def _metrics(self, requests: List[Request],
                 wall: float) -> ServeMetrics:
        """End-of-run aggregate, derived entirely from the run's metrics
        registry.  The serving histograms retain exact samples
        (``exact=True``), so the percentiles below are byte-identical to
        ``np.percentile`` over the per-request series the engine used to
        aggregate directly (pinned by tests/test_observability.py)."""
        reg = self.registry
        total = int(reg.value("serve_tokens_total"))
        return ServeMetrics(
            num_requests=len(requests),
            total_generated=total,
            wall_s=wall,
            throughput_tok_s=total / wall if wall > 0 else float("nan"),
            ttft_s_mean=self._h_ttft.mean_exact(),
            ttft_s_p99=self._h_ttft.percentile_exact(99),
            token_latency_s_p50=self._h_lat.percentile_exact(50),
            token_latency_s_p99=self._h_lat.percentile_exact(99),
            preemptions=int(reg.value("serve_preemptions_total")),
            decode_iters=int(reg.value("serve_iters_total")),
            prefill_chunks=int(reg.value("serve_chunks_total")),
            intertoken_stall_s_max=self._h_stall.max_exact(),
            decode_iter_s_p99=self._h_iter.percentile_exact(99),
        )
