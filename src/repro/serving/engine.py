"""Continuous-batching serving engine (sglang/vLLM-style, JAX-static).

Each iteration interleaves **prefill** (admit up to
``serving.max_prefill_per_iter`` waiting requests, one jitted
bucket-padded forward each, KV written straight into the paged pool) with
one **ragged decode step** over all running slots, a single jit-compiled
function with a per-slot ``pos`` vector (masked slots point at the trash
page).  Static shapes throughout — one decode compile total, one prefill
compile per bucket.

For **paged-capable** backends (``DecodeBackend.supports_paged``: socket,
hard_lsh, quest) the decode step hands the page pool + block tables
straight to the model: appends write to pages in place and attention
reads only the small metadata leaves plus the selected ``O(top_k)`` K/V
rows (``PagedView``) — no contiguous cache view is ever materialized.
Backends that need the whole context every step (dense) fall back to the
gather/scatter round trip (``paged.gather_views`` / ``scatter_token``).

Greedy sampling; ``input_mode == "tokens"``, all-attention all-global
layouts only (sliding-window rings and SSM state are per-slot, not paged
— ROADMAP open item).
"""

from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import backends as bk
from repro.models import param as pm
from repro.models import transformer as tfm
from repro.runtime.steps import make_prefill_step, make_serve_step
from repro.serving import paged
from repro.serving.block_pool import TRASH_BLOCK, BlockPool
from repro.serving.scheduler import Request, Scheduler

__all__ = ["ContinuousBatchingEngine", "ServeMetrics"]


@dataclasses.dataclass
class ServeMetrics:
    """Aggregate serving metrics for one engine run."""

    num_requests: int
    total_generated: int
    wall_s: float
    throughput_tok_s: float
    ttft_s_mean: float
    ttft_s_p99: float
    token_latency_s_p50: float
    token_latency_s_p99: float
    preemptions: int
    decode_iters: int

    def to_json(self) -> Dict:
        return {k: (round(v, 6) if isinstance(v, float) else v)
                for k, v in dataclasses.asdict(self).items()}


def _percentile(xs: List[float], q: float) -> float:
    return float(np.percentile(np.asarray(xs), q)) if xs else float("nan")


class ContinuousBatchingEngine:
    """Paged-KV continuous batching over one model replica."""

    def __init__(self, cfg: ModelConfig, params=None,
                 rng: Optional[jax.Array] = None):
        self._validate(cfg)
        self.cfg = cfg
        self.serving = cfg.serving
        self.serving.validate()
        if params is None:
            rng = rng if rng is not None else jax.random.PRNGKey(0)
            params = pm.unbox(tfm.init_model(cfg, rng))
        self.params = params
        self.backend = bk.get_backend(cfg.attention_backend)
        self.pages = paged.init_paged_caches(cfg, self.serving)
        self.pool = BlockPool(self.serving.num_blocks)
        self.scheduler = Scheduler(
            self.pool, max_batch=self.serving.max_batch,
            max_blocks_per_seq=self.serving.max_blocks_per_seq,
            block_size=self.serving.block_size)
        self._decode_fn = self._build_decode()
        self._prefill_fns: Dict[int, callable] = {}

    @staticmethod
    def _validate(cfg: ModelConfig) -> None:
        if cfg.input_mode != "tokens":
            raise NotImplementedError(
                "continuous engine serves token models only")
        for spec in cfg.layer_specs:
            if spec.kind != "attn" or spec.attn_type != "global":
                raise NotImplementedError(
                    "continuous engine requires all-global attention "
                    f"layers (got kind={spec.kind!r} "
                    f"attn_type={spec.attn_type!r})")
        # resolves the backend (ValueError on unknown names) and validates
        # its cache layout against the serving geometry (e.g. quest's
        # page_size must divide block_size)
        bk.get_backend(cfg.attention_backend).cache_spec(cfg)
        if cfg.decode_cp_axes:
            raise NotImplementedError(
                "ragged decode + context-parallel SOCKET is a ROADMAP item")

    # --------------------------------------------------------------- jit
    def _build_decode(self):
        serve = make_serve_step(self.cfg)
        bs = self.serving.block_size

        if self.backend.supports_paged:
            # page-native path: the pool + block tables go straight into
            # the model; no K/V view is ever materialized.
            def step(params, pages, tokens, bt, pos):
                logits, pages = serve(params, pages, tokens, pos, bt)
                return jnp.argmax(logits[:, -1], axis=-1), pages
        else:
            gran = {name: s.granularity for name, s in
                    self.backend.cache_spec(self.cfg).items()}

            def step(params, pages, tokens, bt, pos):
                views = paged.gather_views(pages, bt)
                logits, views = serve(params, views, tokens, pos)
                pages = paged.scatter_token(pages, views, bt, pos, bs,
                                            granularity=gran)
                return jnp.argmax(logits[:, -1], axis=-1), pages

        return jax.jit(step, donate_argnums=(1,))

    def _prefill_fn(self, bucket: int):
        if bucket not in self._prefill_fns:
            prefill = make_prefill_step(self.cfg, bucket, bucketed=True)

            def step(params, pages, tokens, last_index, bt_row):
                logits, caches = prefill(params, {"tokens": tokens},
                                         last_index)
                pages = paged.write_prefill(pages, caches, bt_row)
                return jnp.argmax(logits[:, -1], axis=-1), pages

            self._prefill_fns[bucket] = jax.jit(step, donate_argnums=(1,))
        return self._prefill_fns[bucket]

    def warmup(self) -> None:
        """Trigger every jit compile (decode step + all prefill buckets)
        against the trash page, so a subsequent run's TTFT and latency
        percentiles measure serving, not compilation."""
        sv = self.serving
        tokens = jnp.zeros((sv.max_batch, 1), jnp.int32)
        bt = jnp.full((sv.max_batch, sv.max_blocks_per_seq), TRASH_BLOCK,
                      jnp.int32)
        pos = jnp.zeros((sv.max_batch,), jnp.int32)
        _, self.pages = self._decode_fn(self.params, self.pages, tokens,
                                        bt, pos)
        for bucket in sv.prefill_buckets:
            bt_row = jnp.full((bucket // sv.block_size,), TRASH_BLOCK,
                              jnp.int32)
            _, self.pages = self._prefill_fn(bucket)(
                self.params, self.pages,
                jnp.zeros((1, bucket), jnp.int32),
                jnp.zeros((1,), jnp.int32), bt_row)

    def _bucket_for(self, n: int) -> int:
        for b in sorted(self.serving.prefill_buckets):
            if b >= n:
                return b
        raise ValueError(f"prompt of {n} tokens exceeds largest prefill "
                         f"bucket {max(self.serving.prefill_buckets)}")

    # -------------------------------------------------------------- run
    def run(self, requests: List[Request],
            realtime: bool = True) -> ServeMetrics:
        """Serve ``requests`` (arrival times in seconds relative to run
        start) to completion.  ``realtime=False`` treats arrivals as
        already-arrived (offline batch; deterministic, used by tests)."""
        sched = self.scheduler
        sv = self.serving
        for r in requests:
            sched.submit(r)
        t0 = time.perf_counter()
        now = lambda: (time.perf_counter() - t0) if realtime else \
            float("inf")
        decode_iters = 0

        while sched.has_work:
            # ---------------- prefill phase -----------------------------
            for _ in range(sv.max_prefill_per_iter):
                req = sched.try_admit(now())
                if req is None:
                    break
                self._prefill_one(req)
                first = now() if realtime else 0.0
                if req.t_first_token is None:
                    req.t_first_token = first
                sched.activate(req)
                if req.done:          # max_new_tokens == 1 degenerate case
                    sched.finish(req, now() if realtime else 0.0)

            # ---------------- ragged decode phase -----------------------
            runnable = sched.ensure_decode_blocks()
            if not runnable:
                if sched.waiting and not sched.running:
                    nxt = min(r.arrival for r in sched.waiting)
                    wait = nxt - now()
                    if realtime and wait > 0:
                        time.sleep(min(wait, 0.05))
                continue
            t_it = time.perf_counter()
            tokens = np.zeros((sv.max_batch, 1), np.int32)
            bt = np.full((sv.max_batch, sv.max_blocks_per_seq),
                         TRASH_BLOCK, np.int32)
            pos = np.zeros((sv.max_batch,), np.int32)
            for r in runnable:
                tokens[r.slot, 0] = r.input_token(r.pos)
                bt[r.slot, :len(r.blocks)] = r.blocks
                pos[r.slot] = r.pos
            next_tok, self.pages = self._decode_fn(
                self.params, self.pages, jnp.asarray(tokens),
                jnp.asarray(bt), jnp.asarray(pos))
            next_tok = np.asarray(next_tok)
            it_s = time.perf_counter() - t_it
            decode_iters += 1
            for r in runnable:
                # post-preemption replay: steps whose output token is
                # already recorded only rebuild KV — the recomputation is
                # identical, so the produced token is discarded, not
                # re-sampled (token-exact resume).
                replaying = r.pos - len(r.prompt) + 1 < len(r.generated)
                if not replaying:
                    r.generated.append(int(next_tok[r.slot]))
                    r.token_latencies.append(it_s)
                r.pos += 1
                if r.done and not replaying:
                    sched.finish(r, now() if realtime else 0.0)

        wall = time.perf_counter() - t0
        return self._metrics(requests, wall, decode_iters)

    def _prefill_one(self, req: Request) -> None:
        prompt = req.prefill_tokens
        bucket = self._bucket_for(len(prompt))
        tokens = np.zeros((1, bucket), np.int32)
        tokens[0, :len(prompt)] = prompt
        bt_row = np.full((bucket // self.serving.block_size,), TRASH_BLOCK,
                         np.int32)
        bt_row[:len(req.blocks)] = req.blocks
        first_tok, self.pages = self._prefill_fn(bucket)(
            self.params, self.pages, jnp.asarray(tokens),
            jnp.asarray([len(prompt) - 1], jnp.int32),
            jnp.asarray(bt_row))
        if not req.generated:
            req.generated.append(int(np.asarray(first_tok)[0]))
        # resumed after preemption: the prefill only rebuilt the prompt's
        # KV; recorded tokens now replay through the decode path (the
        # backend that originally produced them), so generation is
        # token-exact regardless of pool pressure.

    def _metrics(self, requests: List[Request], wall: float,
                 decode_iters: int) -> ServeMetrics:
        ttfts = [r.t_first_token - r.arrival for r in requests
                 if r.t_first_token is not None]
        lats = [t for r in requests for t in r.token_latencies]
        total = sum(len(r.generated) for r in requests)
        return ServeMetrics(
            num_requests=len(requests),
            total_generated=total,
            wall_s=wall,
            throughput_tok_s=total / wall if wall > 0 else float("nan"),
            ttft_s_mean=float(np.mean(ttfts)) if ttfts else float("nan"),
            ttft_s_p99=_percentile(ttfts, 99),
            token_latency_s_p50=_percentile(lats, 50),
            token_latency_s_p99=_percentile(lats, 99),
            preemptions=sum(r.preemptions for r in requests),
            decode_iters=decode_iters,
        )
