"""Host-side block accounting for the paged KV + SOCKET bit-cache pool.

The device-side pool (see :mod:`repro.serving.paged`) is a set of
``num_blocks`` fixed-size pages per layer, shared by every layer: one
physical block id addresses the same page index in every layer's K, V,
packed-hash-bit and value-norm arrays, so a single allocation covers the
whole stack (the vLLM layout, adapted to JAX static shapes).

Block 0 is reserved as the **trash page**: padded block-table entries and
masked (inactive) decode slots read from and write to it, which keeps the
jitted engine step free of conditionals.  It is never handed out.

This module is deliberately jax-free — pure Python accounting that the
scheduler drives — so pool invariants are unit-testable in microseconds.
"""

from __future__ import annotations

from typing import List, Optional

__all__ = ["TRASH_BLOCK", "BlockPool"]

TRASH_BLOCK = 0


class BlockPool:
    """Free-list allocator over physical block ids ``1..num_blocks-1``.

    Blocks are **reference counted** so the prefix cache can share one
    physical page between the radix index and any number of running
    requests: :meth:`alloc` hands out blocks at refcount 1, each
    additional holder calls :meth:`ref`, and :meth:`free` is a deref that
    only returns the block to the free list when the count reaches zero.
    The copy-on-write invariant lives one layer up (engine/scheduler): a
    block with refcount > 1 is never written in place — writers clone it
    first (see :mod:`repro.serving.prefix_cache`).
    """

    def __init__(self, num_blocks: int):
        if num_blocks < 2:
            raise ValueError("need >= 2 blocks (block 0 is the trash page)")
        self.num_blocks = num_blocks
        # LIFO free list: recently freed blocks are reused first (warm).
        self._free: List[int] = list(range(num_blocks - 1, 0, -1))
        self._refs = [0] * num_blocks
        # peak simultaneous allocation over the pool's lifetime — the
        # capacity-planning number (how many blocks this workload
        # actually needed)
        self.high_water = 0

    @property
    def num_free(self) -> int:
        return len(self._free)

    @property
    def num_used(self) -> int:
        return (self.num_blocks - 1) - len(self._free)

    def alloc(self, n: int) -> Optional[List[int]]:
        """Allocate ``n`` blocks, or return None (state unchanged) if the
        pool cannot satisfy the request — all-or-nothing.  ``n == 0``
        succeeds with an empty list (SSM-only requests hold no blocks;
        see the scheduler's per-kind accounting)."""
        if n < 0:
            raise ValueError(n)
        if n > len(self._free):
            return None
        blocks = [self._free.pop() for _ in range(n)]
        for b in blocks:
            self._refs[b] = 1
        if self.num_used > self.high_water:
            self.high_water = self.num_used
        return blocks

    def ref(self, block: int) -> None:
        """Take an additional reference on an allocated block (page
        sharing: the radix index and each matching request all hold one
        ref on the same physical page)."""
        if block == TRASH_BLOCK:
            raise ValueError("attempt to ref the trash block")
        if self._refs[block] == 0:
            raise ValueError(f"ref of unallocated block {block}")
        self._refs[block] += 1

    def stats(self) -> dict:
        """Occupancy snapshot for step records / gauges."""
        return {"free": self.num_free, "used": self.num_used,
                "shared": sum(1 for r in self._refs if r > 1),
                "high_water": self.high_water}

    def free(self, blocks: List[int]) -> None:
        """Drop one reference per listed block; blocks whose count hits
        zero return to the free list (others stay live for their
        remaining holders)."""
        for b in blocks:
            if b == TRASH_BLOCK:
                raise ValueError("attempt to free the trash block")
            if self._refs[b] == 0:
                raise ValueError(f"double free of block {b}")
            self._refs[b] -= 1
            if self._refs[b] == 0:
                self._free.append(b)

    def refcount(self, block: int) -> int:
        return self._refs[block]

    def is_shared(self, block: int) -> bool:
        return self._refs[block] > 1

    def is_allocated(self, block: int) -> bool:
        return self._refs[block] > 0
