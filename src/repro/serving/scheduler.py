"""Request lifecycle + continuous-batching scheduler (host side, jax-free).

Lifecycle::

    WAITING --admit--> PREFILL --activate--> DECODE --finish--> FINISHED
       ^                  |                    |
       +--- preempt (blocks freed, cursor reset) ---+

Admission is by free-block accounting: a waiting request is admitted only
when a decode slot is free and the pool can cover its first prefill grant
(the whole prompt in legacy whole-bucket mode, one chunk when
``prefill_chunk > 0``) plus one block of decode headroom.  Block demand
follows the per-layer cache plan (see :meth:`Scheduler._blocks_for`):
linear with context when any global-attention layer pages, capped at the
circular window page list for sliding-window-only models, zero for
SSM-only models.

Under **chunked prefill** the admitted request stays in PREFILL across
iterations while :meth:`Scheduler.grant_chunk` hands the engine one
:class:`PrefillChunk` at a time, growing the block table through the same
per-kind accounting; the :attr:`Request.prefill_pos` cursor tracks the
committed prompt prefix.  A request preempted mid-prefill (its blocks are
gone) re-chunks from cursor 0 on re-admission — chunk boundaries are a
pure function of the prompt length, so the recompute is bit-exact.

On pool exhaustion mid-decode the scheduler preempts the
least-recently-used running request (recompute-style: its blocks are
freed and it re-enters the waiting queue keeping its generated tokens; on
re-admission the original prompt is re-prefilled — rebuilding paged KV,
window rings and SSM state bit-exactly — and recorded tokens replay
through the decode path — resume is token-exact, see
:attr:`Request.prefill_tokens`).
"""

from __future__ import annotations

import dataclasses
import itertools
import math
from typing import Dict, List, Optional

from repro.serving.block_pool import BlockPool

__all__ = ["Request", "PrefillChunk", "Scheduler",
           "WAITING", "PREFILL", "DECODE", "FINISHED"]

WAITING = "waiting"
PREFILL = "prefill"
DECODE = "decode"
FINISHED = "finished"

_rid = itertools.count()


@dataclasses.dataclass(frozen=True)
class PrefillChunk:
    """One granted prefill chunk: the engine runs prompt tokens
    ``[start, start + tokens)`` this iteration (``final`` marks the chunk
    whose last real token produces the request's first output)."""

    start: int
    tokens: int
    final: bool


@dataclasses.dataclass
class Request:
    """One serving request and its mutable engine-side state."""

    prompt: List[int]                      # original prompt token ids
    max_new_tokens: int
    arrival: float = 0.0                   # seconds relative to run start
    rid: int = dataclasses.field(default_factory=lambda: next(_rid))

    state: str = WAITING
    slot: Optional[int] = None             # decode slot while running
    blocks: List[int] = dataclasses.field(default_factory=list)
    generated: List[int] = dataclasses.field(default_factory=list)
    pos: int = 0                           # next cache index to write
    prefill_pos: int = 0                   # chunked-prefill cursor
    # prefix-cache hit length at the latest admission (0 = miss/disabled):
    # the prefill cursor starts here instead of 0
    cached_tokens: int = 0
    last_used: int = 0                     # scheduler clock, for LRU
    preemptions: int = 0
    # per-request sampling PRNG key (np.ndarray (2,) uint32), assigned by
    # the engine at first submission and RE-installed on every admission,
    # so temperature/top-p streams replay bit-exactly after preemption
    # and never depend on the slot's previous occupants.
    sample_key: Optional[object] = None

    # metrics (seconds relative to run start)
    t_first_token: Optional[float] = None
    t_finished: Optional[float] = None
    token_latencies: List[float] = dataclasses.field(default_factory=list)
    # wall-clock emission time of each token (engine-relative seconds) —
    # feeds the max inter-token-stall metric
    token_walls: List[float] = dataclasses.field(default_factory=list)

    @property
    def effective_prompt(self) -> List[int]:
        """Original prompt plus everything already generated — after a
        preemption the KV for generated tokens is gone and gets recomputed,
        but the tokens themselves are kept."""
        return self.prompt + self.generated

    @property
    def prefill_tokens(self) -> List[int]:
        """Tokens whose KV the (re-)prefill builds: always the *original*
        prompt.  Generated tokens are NOT re-prefilled on resume — prefill
        runs dense attention, but their KV was originally produced under
        the sparse decode backend, so re-prefilling them would change the
        hidden states and hence the continuation.  Instead the engine
        *replays* the recorded tokens through the decode path (see
        :meth:`input_token`), which repeats the original computation
        exactly — preemption is token-exact, not just count-exact."""
        return self.prompt

    def input_token(self, pos: int) -> int:
        """The token consumed by a decode step writing at cache index
        ``pos``; during post-preemption replay this is a recorded token
        rather than the last generated one."""
        i = pos - len(self.prompt)
        assert 0 <= i < len(self.generated), (pos, len(self.prompt),
                                              len(self.generated))
        return self.generated[i]

    @property
    def num_remaining(self) -> int:
        return self.max_new_tokens - len(self.generated)

    @property
    def done(self) -> bool:
        return len(self.generated) >= self.max_new_tokens


class Scheduler:
    """Slot + block bookkeeping for the continuous-batching engine.

    ``has_paged_layers`` / ``ring_blocks`` carry the host half of the
    per-layer cache plan (``cfg.cache_plan()``): with any global-attention
    layer, block demand grows linearly with context (every block id is
    live in that layer's pages); with only sliding-window layers it is
    capped at ``ring_blocks`` (the circular page list recycles the ids in
    place); SSM-only models hold zero blocks and are admitted on free
    decode slots alone.
    """

    def __init__(self, pool: BlockPool, *, max_batch: int,
                 max_blocks_per_seq: int, block_size: int,
                 has_paged_layers: bool = True, ring_blocks: int = 0,
                 prefill_chunk: int = 0):
        self.pool = pool
        self.max_batch = max_batch
        self.max_blocks_per_seq = max_blocks_per_seq
        self.block_size = block_size
        self.has_paged_layers = has_paged_layers
        self.ring_blocks = ring_blocks
        self.prefill_chunk = prefill_chunk     # 0 = whole-prompt prefill
        self.waiting: List[Request] = []       # FCFS by (arrival, rid)
        self.prefilling: List[Request] = []    # admitted, mid-prefill
        self.running: Dict[int, Request] = {}  # slot -> request
        self._free_slots = list(range(max_batch - 1, -1, -1))
        self._clock = 0
        # cross-request prefix cache (set by the engine when enabled and
        # the config supports it: chunked prefill + all-paged plan).
        # When present it changes three things here: admission matches
        # prompts against the radix index and starts the prefill cursor
        # past the cached prefix; block allocation gains a first
        # reclamation tier (LRU cache eviction) ahead of
        # recompute-preemption; and committed prompt pages are indexed at
        # activation / finish / preemption so later requests can share
        # them.
        self.prefix_cache = None
        # observability (bound by the engine per run; None = standalone)
        self.registry = None
        self.tracer = None

    # -------------------------------------------------------- observability
    def bind_obs(self, registry=None, tracer=None) -> None:
        """Attach the engine's per-run metrics registry and (optional)
        event tracer.  The scheduler emits its own lifecycle events —
        admission, chunk grants/withholds, preemptions (by cause),
        finishes — so the trace sees scheduling decisions, not just
        their engine-side consequences."""
        self.registry = registry
        self.tracer = tracer
        if self.prefix_cache is not None:
            self.prefix_cache.bind_obs(registry, tracer)

    def _emit(self, event_type: str, **fields) -> None:
        if self.tracer is not None:
            self.tracer.emit(event_type, **fields)

    def _count(self, name: str, **labels) -> None:
        if self.registry is not None:
            self.registry.counter(name, **labels).inc()

    # ------------------------------------------------------------- intake
    def submit(self, req: Request) -> None:
        if not req.prompt:
            raise ValueError(f"request {req.rid} has an empty prompt")
        need = self._blocks_for(len(req.prompt) + req.max_new_tokens)
        if need > self.max_blocks_per_seq:
            raise ValueError(
                f"request {req.rid} needs {need} blocks > "
                f"max_blocks_per_seq={self.max_blocks_per_seq}")
        if need > self.pool.num_blocks - 1:
            raise ValueError(
                f"request {req.rid} needs {need} blocks over its lifetime "
                f"but the pool only has {self.pool.num_blocks - 1} — "
                "unservable even alone (the engine would spin forever)")
        req.state = WAITING
        self.waiting.append(req)
        self.waiting.sort(key=lambda r: (r.arrival, r.rid))

    def _blocks_for(self, tokens: int) -> int:
        """Blocks a request holding ``tokens`` cache tokens occupies,
        under the per-kind accounting (see class docstring)."""
        full = -(-tokens // self.block_size)
        if self.has_paged_layers:
            return full
        if self.ring_blocks:
            return min(full, self.ring_blocks)
        return 0

    def _alloc(self, n: int):
        """Pool allocation with the prefix-cache reclamation tier: when
        the free list cannot cover ``n``, LRU-evict unpinned cached pages
        (tree-only, refcount 1) to make up the deficit before reporting
        failure — cached-but-idle data is always cheaper to drop than
        preempting a live request (recompute) or stalling a prefill."""
        got = self.pool.alloc(n)
        if got is None and self.prefix_cache is not None:
            self.prefix_cache.evict(n - self.pool.num_free)
            got = self.pool.alloc(n)
        return got

    # ---------------------------------------------------------- admission
    def try_admit(self, now: float) -> Optional[Request]:
        """Pop the first arrived waiting request that fits (free slot AND
        first-grant blocks + 1 decode-headroom block); allocate those
        blocks and move it to PREFILL.  Returns None if nothing fits.

        The first grant is the whole prompt in legacy mode, just the
        first chunk under chunked prefill — a long prompt is admissible
        long before the pool could hold all of it (later chunks grow the
        table via :meth:`grant_chunk`)."""
        if not self._free_slots:
            return None
        for i, req in enumerate(self.waiting):
            if req.arrival > now:
                break                       # sorted: nothing arrived yet
            p = len(req.prefill_tokens)
            # prefix-cache match: pin (ref) the shared blocks BEFORE any
            # eviction below can run — matched pages are refcount-1
            # (tree-only) until pinned, i.e. themselves evictable.
            shared, cached = [], 0
            if self.prefix_cache is not None:
                shared, cached = self.prefix_cache.match(req.prefill_tokens)
                for b in shared:
                    self.pool.ref(b)
            first = min(cached + self.prefill_chunk, p) \
                if self.prefill_chunk else p
            first_blocks = self._blocks_for(first)
            need = first_blocks - len(shared)
            lifetime = self._blocks_for(
                len(req.effective_prompt) + req.num_remaining)
            # decode headroom only if the request will ever grow past its
            # first-grant blocks — otherwise a prompt filling the whole
            # pool could pass submit() yet never admit (engine would spin).
            headroom = 1 if lifetime > first_blocks else 0
            deficit = need + headroom - self.pool.num_free
            if deficit > 0 and self.prefix_cache is not None and \
                    self.prefix_cache.evictable_blocks() >= deficit:
                self.prefix_cache.evict(deficit)
            if need + headroom > self.pool.num_free:
                if shared:
                    self.pool.free(shared)  # unpin: admission failed
                continue                    # try a smaller request behind it
            blocks = self.pool.alloc(need)
            assert blocks is not None
            self.waiting.pop(i)
            req.blocks = shared + blocks
            req.slot = self._free_slots.pop()
            req.state = PREFILL
            req.pos = len(req.prefill_tokens)
            req.prefill_pos = cached        # a hit is a prefill starting
            req.cached_tokens = cached      # at a nonzero cursor
            self.prefilling.append(req)
            if self.prefix_cache is not None:
                if cached > 0:
                    self._count("prefix_cache_hits_total")
                    if self.registry is not None:
                        self.registry.counter(
                            "prefix_cache_cached_tokens_total").inc(cached)
                        self.registry.histogram(
                            "prefix_cache_cached_tokens").record(cached)
                    self._emit("cache_hit", rid=req.rid, cached_tokens=cached,
                               prompt_tokens=p, shared_blocks=len(shared))
                else:
                    self._count("prefix_cache_misses_total")
                    self._emit("cache_miss", rid=req.rid, prompt_tokens=p)
                if self.registry is not None:
                    self.registry.counter(
                        "prefix_cache_prompt_tokens_total").inc(p)
            # admission-queue wait: only measurable under realtime
            # clocks (offline runs pass now=inf — everything "arrived")
            wait = now - req.arrival if math.isfinite(now) else None
            if wait is not None and self.registry is not None:
                self.registry.histogram("admission_wait_s").record(wait)
            self._emit("admit", rid=req.rid, slot=req.slot,
                       blocks=len(req.blocks),
                       resume=req.preemptions > 0,
                       **({"wait_s": round(wait, 6)}
                          if wait is not None else {}))
            return req
        return None

    def grant_chunk(self, req: Request) -> Optional[PrefillChunk]:
        """Grant the next prefill chunk for a PREFILL-state request,
        growing its block table to cover the chunk end through the
        per-kind accounting.  Prefill never evicts decoders: on pool
        exhaustion the grant is simply withheld (None, request stays
        PREFILL) and retried next iteration — decoders always finish
        within ``max_new_tokens`` steps and free their blocks, so the
        chunk eventually proceeds (eager eviction ping-pongs: the
        evicted decoder re-admits cheaply and evicts the prefiller right
        back).  Decode *growth* may preempt the prefiller instead
        (:meth:`ensure_decode_blocks`) — in-flight tokens outrank queued
        prompts.  If the pool cannot cover the chunk while nothing else
        holds blocks — unreachable while :meth:`submit`'s lifetime guard
        holds — the request is preempted as a safety valve."""
        assert self.prefill_chunk and req.state == PREFILL
        self._clock += 1
        req.last_used = self._clock
        p = len(req.prefill_tokens)
        end = min(req.prefill_pos + self.prefill_chunk, p)
        while len(req.blocks) < self._blocks_for(end):
            got = self._alloc(1)
            if got is not None:
                req.blocks.extend(got)
                continue
            if self.running or len(self.prefilling) > 1:
                # wait for blocks to free up
                self._count("serve_chunks_withheld_total")
                self._emit("chunk_withheld", rid=req.rid,
                           free_blocks=self.pool.num_free)
                return None
            self.preempt(req, cause="prefill_stall")  # no progress at all
            return None
        chunk = PrefillChunk(start=req.prefill_pos,
                             tokens=end - req.prefill_pos, final=end == p)
        self._emit("chunk_grant", rid=req.rid, start=chunk.start,
                   tokens=chunk.tokens, final=chunk.final,
                   blocks=len(req.blocks))
        return chunk

    def advance_chunk(self, req: Request, chunk: PrefillChunk) -> None:
        """The engine ran ``chunk``; move the cursor past it."""
        assert req.state == PREFILL and req.prefill_pos == chunk.start
        req.prefill_pos += chunk.tokens

    def activate(self, req: Request) -> None:
        """Prefill done; request joins the ragged decode batch.  With the
        prefix cache, this is where the prompt's **full** pages become
        shareable: they are immutable from here on (decode writes land
        strictly past the prompt).  The partial tail page — which decode
        *does* keep writing — is only indexed once the owner stops
        touching it (:meth:`finish` / preemption after prefill)."""
        assert req.state == PREFILL
        self.prefilling.remove(req)
        req.state = DECODE
        self.running[req.slot] = req
        if self.prefix_cache is not None:
            self.prefix_cache.insert(req.prefill_tokens, req.blocks,
                                     committed=len(req.prefill_tokens),
                                     include_tail=False, rid=req.rid)

    # ----------------------------------------------------------- stepping
    def ensure_decode_blocks(self) -> List[Request]:
        """Grow each running request's block table to cover writing index
        ``pos`` (capped by the per-kind accounting: sliding-window-only
        demand stops at ``ring_blocks``, SSM-only at zero); preempt LRU
        victims on exhaustion — mid-prefill requests are eligible victims
        too (in-flight decodes outrank queued prompts; a preempted
        prefill re-chunks from cursor 0 bit-exactly).  Returns the
        requests runnable this step (sorted by slot)."""
        self._clock += 1
        for slot in sorted(self.running):
            req = self.running.get(slot)
            if req is None:
                continue
            req.last_used = self._clock
            while len(req.blocks) < self._blocks_for(req.pos + 1):
                got = self._alloc(1)
                if got is not None:
                    req.blocks.extend(got)
                    continue
                victim = self._lru_victim()
                self.preempt(victim, cause="decode_growth")
                if victim is req:
                    break
        return [self.running[s] for s in sorted(self.running)]

    def _lru_victim(self) -> Request:
        # Mid-prefill requests are evicted before any decoder: they hold
        # pages but no in-flight generation (re-chunking from cursor 0
        # redoes prefill work only, never emitted tokens), which is the
        # "in-flight tokens outrank queued prompts" policy — LRU clocks
        # alone would favor the prefiller (stamped fresher by its grant
        # each iteration) and evict an active decoder instead.
        pool = self.prefilling or list(self.running.values())
        return min(pool, key=lambda r: (r.last_used, -r.arrival, -r.rid))

    def preempt(self, req: Request, cause: str = "manual") -> None:
        """Free the request's slot + blocks and requeue it (recompute).
        A request caught mid-chunked-prefill loses its committed pages,
        so its chunk cursor resets — re-chunking is bit-exact because
        chunk boundaries depend only on the prompt length.

        ``cause`` labels the eviction for the preemption counter/event:
        ``decode_growth`` (a running request's table had to grow on an
        exhausted pool), ``prefill_stall`` (the grant_chunk safety
        valve), or ``manual`` (direct callers/tests)."""
        assert req.state == DECODE or req.state == PREFILL
        self._count("serve_preemptions_total", cause=cause)
        self._emit("preempt", rid=req.rid, cause=cause, state=req.state,
                   blocks_freed=len(req.blocks))
        if self.prefix_cache is not None and req.blocks:
            # Index the committed prefix before freeing: the pages stay
            # alive under the tree's ref (evictable, but often still
            # there at re-admission — the preempted request re-matches
            # its own pages and resumes its prefill near where it left
            # off instead of recomputing from cursor 0).
            committed = len(req.prefill_tokens) if req.state == DECODE \
                else req.prefill_pos
            self.prefix_cache.insert(req.prefill_tokens, req.blocks,
                                     committed=committed,
                                     include_tail=req.state == DECODE,
                                     rid=req.rid)
        self.pool.free(req.blocks)
        req.blocks = []
        if req in self.prefilling:
            self.prefilling.remove(req)
        self.running.pop(req.slot, None)
        self._free_slots.append(req.slot)
        req.slot = None
        req.prefill_pos = 0
        req.preemptions += 1
        self.submit(req)

    def cow_alloc(self, req: Request):
        """One block for a copy-on-write clone (the engine needs it to
        un-share a page ``req`` is about to write).  Escalates through
        the same tiers as decode growth — cache eviction, then LRU
        preemption — and returns None if ``req`` itself ended up the
        victim (then there is nothing left to clone for)."""
        while True:
            got = self._alloc(1)
            if got is not None:
                return got[0]
            victim = self._lru_victim()
            self.preempt(victim, cause="cow")
            if victim is req:
                return None

    def finish(self, req: Request, now: float) -> None:
        assert req.state == DECODE
        self._count("serve_requests_total")
        self._emit("finish", rid=req.rid, generated=len(req.generated),
                   preemptions=req.preemptions)
        if self.prefix_cache is not None and req.blocks:
            # full pages + the now-quiescent partial tail page become
            # shareable; the tree's refs keep them alive past the free.
            self.prefix_cache.insert(req.prefill_tokens, req.blocks,
                                     committed=len(req.prefill_tokens),
                                     include_tail=True, rid=req.rid)
        self.pool.free(req.blocks)
        req.blocks = []
        self.running.pop(req.slot)
        self._free_slots.append(req.slot)
        req.slot = None
        req.state = FINISHED
        req.t_finished = now

    # ------------------------------------------------------------- status
    @property
    def has_work(self) -> bool:
        return bool(self.waiting or self.prefilling or self.running)

    @property
    def num_running(self) -> int:
        return len(self.running)
