"""Device-side paged cache pool: KV pages + SOCKET side-cache pages.

Layout: every layer-cache leaf of the standard decode cache (see
:func:`repro.models.transformer.init_decode_caches`) is re-homed with the
batch axis replaced by the **physical block axis** and the capacity axis by
the **block size**::

    k / v   : (num_blocks, KVH, block_size, hd)
    bits    : (num_blocks, KVH, block_size, W)     (SOCKET packed hash bits)
    vnorm   : (num_blocks, KVH, block_size)        (SOCKET value norms)

Grouped (scan-stacked) layers carry a leading group axis; all per-leaf
helpers are plain rank-polymorphic functions lifted over that axis with
``jax.vmap``.  One block id addresses the same page in every layer, so the
host allocator (:mod:`repro.serving.block_pool`) hands out one id list per
request for the whole stack.

The ragged engine step gathers each slot's block table into the standard
contiguous ``(B, KVH, max_context, ...)`` view, runs the unmodified model
decode, then scatters the one newly written token per slot back to its
page.  This is the XLA-portable formulation; a Pallas paged-attention
kernel that consumes block tables directly is the TPU fast path this
layout is designed for (ROADMAP open item).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ServingSettings
from repro.models import transformer as tfm

__all__ = ["init_paged_caches", "gather_views", "scatter_token",
           "write_prefill"]


def init_paged_caches(cfg: ModelConfig, serving: ServingSettings):
    """Zero-initialized paged pool, reusing the model's cache builder with
    batch=num_blocks and capacity=block_size."""
    serving.validate()
    return tfm.init_decode_caches(cfg, batch=serving.num_blocks,
                                  capacity=serving.block_size)


# ------------------------------------------------------------------ leaves

def _gather_leaf(pages: jax.Array, bt: jax.Array) -> jax.Array:
    """(NB, KVH, bs, *rest), (B, nb) -> (B, KVH, nb*bs, *rest)."""
    b, nb = bt.shape
    g = pages[bt]                            # (B, nb, KVH, bs, *rest)
    g = jnp.moveaxis(g, 2, 1)                # (B, KVH, nb, bs, *rest)
    return g.reshape(b, pages.shape[1], nb * pages.shape[2],
                     *pages.shape[3:])


def _scatter_leaf(pages: jax.Array, view: jax.Array, blk: jax.Array,
                  off: jax.Array, pos: jax.Array) -> jax.Array:
    """Write the token each slot produced at ``view[b, :, pos[b]]`` into
    physical page ``blk[b]`` offset ``off[b]``.  Inactive slots carry
    ``blk == TRASH_BLOCK``; duplicate trash writes are benign."""
    b = view.shape[0]
    tok = view[jnp.arange(b), :, pos]        # (B, KVH, *rest)
    return pages.at[blk, :, off].set(tok.astype(pages.dtype))


def _write_prefill_leaf(pages: jax.Array, leaf: jax.Array,
                        bt_row: jax.Array) -> jax.Array:
    """Scatter a batch=1 prefill cache leaf (1, KVH, bucket, *rest) into
    pages addressed by ``bt_row`` ((bucket/bs,) block ids, trash-padded)."""
    kvh, bucket = leaf.shape[1], leaf.shape[2]
    bs = pages.shape[2]
    nb = bucket // bs
    blocks = leaf[0].reshape(kvh, nb, bs, *leaf.shape[3:])
    blocks = jnp.moveaxis(blocks, 1, 0)      # (nb, KVH, bs, *rest)
    return pages.at[bt_row].set(blocks.astype(pages.dtype))


# ------------------------------------------------------------------- tree

def gather_views(pages, bt: jax.Array):
    """Materialize the ragged batch's contiguous cache views.

    bt: (B, max_blocks_per_seq) int32 physical block ids (trash-padded).
    Returns a cache pytree shaped exactly like
    ``init_decode_caches(cfg, B, max_context)``.
    """
    grouped = jax.vmap(_gather_leaf, in_axes=(0, None))
    return {
        "groups": jax.tree_util.tree_map(
            lambda p: grouped(p, bt), pages["groups"]),
        "remainder": jax.tree_util.tree_map(
            lambda p: _gather_leaf(p, bt), pages["remainder"]),
    }


def scatter_token(pages, views, bt: jax.Array, pos: jax.Array,
                  block_size: int):
    """Write each slot's newly decoded token back from the contiguous view
    into its page; returns the updated pool pytree."""
    b = bt.shape[0]
    blk = bt[jnp.arange(b), pos // block_size]   # (B,) physical blocks
    off = pos % block_size
    grouped = jax.vmap(
        lambda p, v: _scatter_leaf(p, v, blk, off, pos), in_axes=(0, 0))
    return {
        "groups": jax.tree_util.tree_map(
            grouped, pages["groups"], views["groups"]),
        "remainder": jax.tree_util.tree_map(
            lambda p, v: _scatter_leaf(p, v, blk, off, pos),
            pages["remainder"], views["remainder"]),
    }


def write_prefill(pages, caches, bt_row: jax.Array):
    """Scatter a freshly prefilled (batch=1, capacity=bucket) cache pytree
    into the pool.  ``bt_row``: (bucket/block_size,) block ids — entries
    past the request's real block count point at the trash page."""
    grouped = jax.vmap(
        lambda p, c: _write_prefill_leaf(p, c, bt_row), in_axes=(0, 0))
    return {
        "groups": jax.tree_util.tree_map(
            grouped, pages["groups"], caches["groups"]),
        "remainder": jax.tree_util.tree_map(
            lambda p, c: _write_prefill_leaf(p, c, bt_row),
            pages["remainder"], caches["remainder"]),
    }
