"""Device-side paged cache pool, organised by the per-layer cache plan.

Each layer of the stack resolves to one cache handler
(:func:`repro.models.backends.layer_cache_handler`, mirroring
``cfg.cache_plan()``):

* **paged** (global attention) — every leaf of the backend's
  ``cache_spec`` re-homed with the batch axis replaced by the physical
  block axis and the capacity axis by the block size (divided by the
  leaf's sequence granularity)::

      k / v   : (num_blocks, KVH, block_size, hd)
      bits    : (num_blocks, KVH, block_size, W)   (SOCKET hash bits)
      vnorm   : (num_blocks, KVH, block_size)      (SOCKET value norms)
      kmin/max: (num_blocks, KVH, block_size/ps, hd) (Quest page stats)

* **ring** (sliding-window attention) — K/V pages of the same geometry,
  but addressed circularly through the first ``ring_blocks`` block-table
  entries, so per-slot block demand is bounded by the window.

* **state** (Mamba/SSD) — conv tail + recurrent state as one row per
  decode slot (``(max_batch, ...)``), no block table at all.

Grouped (scan-stacked) layers carry a leading group axis; handler calls
are lifted over it with ``jax.vmap``.  One block id addresses the same
page in every paged/ring layer, so the host allocator
(:mod:`repro.serving.block_pool`) hands out one id list per request for
the whole stack — ring layers simply recycle the list's head.

**Paged-capable backends** (``DecodeBackend.supports_paged``) consume
the pool directly through ``PagedView``/``RingView`` — the engine passes
the pool + block tables into ``decode_step`` and no contiguous K/V view
is ever materialized for global layers (ring views are window-bounded by
construction; state needs no view at all).  For the remaining backends
(dense) the engine falls back to the gather/scatter round trip below:
materialize each slot's contiguous views, run the unmodified decode,
write the updated rows back.  :func:`gather_footprint` quantifies the
per-step traffic of both regimes, per layer kind.
"""

from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig, ServingSettings
from repro.models import backends as bk
from repro.models import transformer as tfm

__all__ = ["init_paged_caches", "gather_views", "scatter_token",
           "write_prefill", "keep_state_rows", "clone_block",
           "gather_footprint", "cache_kind_counts", "kv_row_bytes",
           "pool_block_bytes"]


def init_paged_caches(cfg: ModelConfig, serving: ServingSettings):
    """Zero-initialized pool, reusing the model's cache builder with
    batch=num_blocks and capacity=block_size (per-kind layout overrides
    via ``pool=serving`` — see :func:`tfm.init_decode_caches`)."""
    serving.validate()
    return tfm.init_decode_caches(cfg, batch=serving.num_blocks,
                                  capacity=serving.block_size,
                                  pool=serving)


def _map_slots(cfg: ModelConfig, fn, *trees):
    """Apply ``fn(handler, *subtrees)`` per layer slot, vmapped over the
    group axis for the scan-stacked pattern slots."""
    def over(specs, grouped, *subtrees):
        out = {}
        for i, spec in enumerate(specs):
            h = bk.layer_cache_handler(cfg, spec)
            subs = [t[f"slot_{i}"] for t in subtrees]
            out[f"slot_{i}"] = jax.vmap(lambda *xs, _h=h: fn(_h, *xs))(
                *subs) if grouped else fn(h, *subs)
        return out
    return {
        "groups": over(cfg.pattern, True,
                       *[t["groups"] for t in trees]),
        "remainder": over(cfg.remainder, False,
                          *[t["remainder"] for t in trees]),
    }


def gather_views(cfg: ModelConfig, pages, bt: jax.Array):
    """Materialize the ragged batch's contiguous cache views (the dense
    fallback path): full logical views for paged layers, window-bounded
    rings for ring layers, the per-slot state rows as-is for state
    layers.

    bt: (B, max_blocks_per_seq) int32 physical block ids (trash-padded).
    """
    return _map_slots(cfg, lambda h, p: h.gather(cfg, p, bt), pages)


def scatter_token(cfg: ModelConfig, pages, views, bt: jax.Array,
                  pos: jax.Array):
    """Write what a decode step updated in the contiguous views back into
    the pool: the one new row for paged layers, the one ring row (with
    page-opening scrub) for ring layers, the whole per-slot state for
    state layers."""
    return _map_slots(
        cfg, lambda h, p, v: h.scatter(cfg, p, v, bt, pos), pages, views)


def write_prefill(cfg: ModelConfig, pages, caches, bt_row: jax.Array,
                  slot: jax.Array):
    """Scatter a freshly prefilled (batch=1, capacity=bucket) cache pytree
    into the pool.  ``bt_row``: block ids sized ``max(bucket /
    block_size, ring_blocks)`` — entries past the request's real block
    count point at the trash page.  ``slot``: the request's decode slot
    (receives the Mamba state rows)."""
    return _map_slots(
        cfg, lambda h, p, c: h.write_prefill(cfg, p, c, bt_row, slot),
        pages, caches)


def keep_state_rows(cfg: ModelConfig, before, after, active: jax.Array):
    """Preserve inactive decode slots' per-slot **state** rows across a
    decode step: the jitted ragged step updates every Mamba slot row
    unconditionally (masked attention slots write the trash page, but
    state rows have no trash row to absorb the garbage).  With the legacy
    whole-prompt prefill that was harmless — a slot's state was only live
    while the slot decoded.  Under chunked prefill a slot's state must
    survive the decode iterations running *between* its chunks, so state
    leaves take the post-step value only where ``active`` (``(B,)``
    bool) marks a runnable request; paged/ring leaves pass through
    untouched (their inactive-slot writes already land in the trash
    page)."""
    def sel(h, old, new):
        if h.kind != "state":
            return new
        return {name: jnp.where(
            active.reshape((-1,) + (1,) * (new[name].ndim - 1)),
            new[name], old[name]) for name in new}
    return _map_slots(cfg, sel, before, after)


def clone_block(cfg: ModelConfig, pages, src, dst, keep_tokens):
    """Copy-on-write clone: duplicate physical page ``src`` into ``dst``
    across every **paged**-kind leaf, keeping only the rows covering the
    first ``keep_tokens`` tokens and resetting the rest to the leaf's
    init fill value.  The scrub is what makes sharing safe: a shared tail
    page's rows past the matched prefix hold the *donor's* tokens (or its
    generated continuation), and — the PR 2 lesson — the pool never
    scrubs device memory on free, so without it stale rows would leak
    into the clone's owner.

    Ring/state leaves pass through untouched (the prefix cache is gated
    off for plans that have any); leaves with ``granularity > 1``
    (Quest's per-page stats) cannot keep a partial page soundly, so this
    raises at trace time if one is present with ``keep_tokens`` possibly
    nonzero — the cache policy page-aligns matches for such plans,
    making the CoW path unreachable.

    ``src``/``dst``/``keep_tokens`` are traced int32 scalars — one
    compile serves every clone.
    """
    def fn(h, p):
        if h.kind != "paged":
            return p
        leaves = h.spec(cfg).leaves
        out = {}
        for name, leaf in p.items():
            s = leaves[name]
            if s.granularity != 1:
                raise ValueError(
                    f"CoW clone of page-granular leaf {name!r} is unsound "
                    "(partial-page stats would cover scrubbed rows); the "
                    "prefix cache must page-align matches for this plan")
            page = leaf[src]                      # (KVH, rows, *suffix)
            row = jnp.arange(leaf.shape[2], dtype=jnp.int32)
            keepmask = (row < jnp.asarray(keep_tokens, jnp.int32)).reshape(
                (1, -1) + (1,) * len(s.suffix))
            page = jnp.where(keepmask, page,
                             jnp.asarray(s.fill, leaf.dtype))
            out[name] = leaf.at[dst].set(page)
        return out
    return _map_slots(cfg, fn, pages)


# -------------------------------------------------------------- accounting

def _leaf_row_bytes(s, cdt) -> float:
    """Bytes one *token* of leaf ``s`` occupies (suffix width x storage
    itemsize, amortized over the leaf's sequence granularity)."""
    width = int(np.prod(s.suffix, dtype=np.int64)) if s.suffix else 1
    return width * jnp.dtype(s.leaf_dtype(cdt)).itemsize / s.granularity


def kv_row_bytes(cfg: ModelConfig, names=("k", "v", "k_scale",
                                          "v_scale")) -> int:
    """Per-token K/V storage bytes across one KV head set — dtype-sized
    quantized payload plus the full-precision per-row scale leaves when
    the plan stores int8/fp8 pages."""
    spec = bk.kv_leaf_specs(cfg)
    cdt = jnp.dtype(cfg.compute_dtype)
    return int(cfg.num_kv_heads * sum(
        _leaf_row_bytes(spec[nm], cdt) for nm in names if nm in spec))


def pool_block_bytes(cfg: ModelConfig) -> Dict[str, int]:
    """Bytes one physical pool block occupies, per layer **kind** and in
    total per block id (a block id addresses the same page in every
    paged/ring layer).  Sums every leaf of the resolved cache spec at its
    own storage dtype — int8/fp8 K/V pages, f32 scale rows, uint32 hash
    words, page-granular stats — so pool capacity math (bench residency,
    bytes/token reporting) tracks ``cfg.serving.kv_dtype``."""
    sv = cfg.serving
    cdt = jnp.dtype(cfg.compute_dtype)
    counts = cache_kind_counts(cfg)
    out = {"paged": 0, "ring": 0}
    for spec_l in cfg.layer_specs:
        plan = cfg.plan_for(spec_l)
        if plan.kind == "state":
            continue
        leaves = bk.layer_cache_spec(cfg, spec_l).leaves
        out[plan.kind] += int(cfg.num_kv_heads * sv.block_size * sum(
            _leaf_row_bytes(s, cdt) for s in leaves.values()))
    out["per_block_id"] = out["paged"] + out["ring"]
    out["num_paged_layers"] = counts["paged"]
    out["num_ring_layers"] = counts["ring"]
    return out


def cache_kind_counts(cfg: ModelConfig) -> Dict[str, int]:
    """Layer count per cache kind (``paged``/``ring``/``state``) under
    the per-layer plan — shared by the footprint model below and the
    serving observability layer's per-kind pool gauges."""
    counts = {"paged": 0, "ring": 0, "state": 0}
    for spec in cfg.layer_specs:
        counts[cfg.plan_for(spec).kind] += 1
    return counts


def gather_footprint(cfg: ModelConfig) -> Dict[str, int]:
    """Per-decode-step gathered bytes for the whole stack, full-view vs
    paged, broken down by layer kind (reported by
    ``benchmarks/bench_serving.py``).

    ``full_view_bytes_per_step``: every global-attention cache leaf
    materialized at ``(max_batch, KVH, max_context, ...)`` plus
    context-length K/V views for the window layers — what a plan-less
    pager would move.  ``paged_bytes_per_step``: global layers move
    metadata leaves plus the backend's ``selected_rows`` K/V rows (≈ 0
    with the fused paged kernel), window layers their *bounded* ring
    views (``window_bytes_per_step``), Mamba layers ≈ 0
    (``state_bytes_per_step`` reports the per-slot state size that moves
    through registers regardless — no gather, no growth with context).
    """
    sv = cfg.serving
    b, n = sv.max_batch, sv.max_context
    kvh = cfg.num_kv_heads
    cdt = jnp.dtype(cfg.compute_dtype)
    counts = cache_kind_counts(cfg)

    full = paged = window = 0
    selected = 0
    fused = False
    if counts["paged"]:
        backend = bk.get_backend(cfg.attention_backend)
        spec = backend.cache_spec(cfg)

        def leaf_bytes(s):
            width = int(np.prod(s.suffix, dtype=np.int64)) if s.suffix \
                else 1
            return b * kvh * s.rows(n) * width * jnp.dtype(
                s.leaf_dtype(cdt)).itemsize

        full_l = sum(leaf_bytes(s) for s in spec.values())
        # K/V storage leaves (quantized payload + its scale rows) are the
        # gather-on-demand set; metadata leaves stream in full
        kv_names = [nm for nm in ("k", "v", "k_scale", "v_scale")
                    if nm in spec]
        kv_bytes = sum(leaf_bytes(spec[nm]) for nm in kv_names)
        selected = backend.selected_rows(cfg, n)
        row_b = kvh * sum(_leaf_row_bytes(spec[nm], cdt)
                          for nm in kv_names)
        paged_l = (full_l - kv_bytes) + int(b * selected * row_b)
        fused = backend.supports_paged and backend.fused_paged(cfg)
        if fused:
            paged_l = 0
        if not backend.supports_paged:
            paged_l = full_l
        full += full_l * counts["paged"]
        paged += paged_l * counts["paged"]
    ring_fused = False
    if counts["ring"]:
        ring_rows = cfg.ring_geometry()[1]
        row_b = kv_row_bytes(cfg)       # dtype-sized K/V + scale rows
        ring_l = b * ring_rows * row_b
        full_l = b * n * row_b
        ring_fused = bool(cfg.use_ring_kernel)
        # the fused ring pass streams the circular page list in-kernel:
        # no XLA gather materializes the bounded window view
        window = 0 if ring_fused else ring_l * counts["ring"]
        full += full_l * counts["ring"]
        paged += window
    state = 0
    if counts["state"]:
        di, hd, st = cfg.d_inner, cfg.ssm_head_dim, cfg.ssm_state
        nh = cfg.ssm_heads
        conv_dim = di + 2 * st
        state_l = b * (nh * hd * st * 4 +
                       (cfg.ssm_conv_width - 1) * conv_dim * cdt.itemsize)
        state = state_l * counts["state"]

    return {
        "full_view_bytes_per_step": int(full),
        "paged_bytes_per_step": int(paged),
        "window_bytes_per_step": int(window),
        "state_bytes_per_step": int(state),
        "selected_rows": int(selected),
        "fused_paged_kernel": bool(fused),
        "fused_ring_kernel": bool(ring_fused),
        "num_paged_layers": counts["paged"],
        "num_ring_layers": counts["ring"],
        "num_state_layers": counts["state"],
    }
