"""Device-side paged cache pool: KV pages + backend metadata pages.

Layout: every layer-cache leaf of the standard decode cache (see
:func:`repro.models.transformer.init_decode_caches`) is re-homed with the
batch axis replaced by the **physical block axis** and the capacity axis by
the **block size** (divided by the leaf's sequence granularity — Quest's
page-granular min/max rows pack ``block_size / page_size`` rows per
block)::

    k / v   : (num_blocks, KVH, block_size, hd)
    bits    : (num_blocks, KVH, block_size, W)     (SOCKET packed hash bits)
    vnorm   : (num_blocks, KVH, block_size)        (SOCKET value norms)
    kmin/max: (num_blocks, KVH, block_size/ps, hd) (Quest page stats)

Grouped (scan-stacked) layers carry a leading group axis; all per-leaf
helpers are plain rank-polymorphic functions lifted over that axis with
``jax.vmap``.  One block id addresses the same page in every layer, so the
host allocator (:mod:`repro.serving.block_pool`) hands out one id list per
request for the whole stack.

**Paged-capable backends** (``DecodeBackend.supports_paged``) consume this
pool directly through :class:`repro.models.backends.PagedView` — the
engine passes the pool + block tables into ``decode_step`` and no
contiguous view is ever materialized for K/V.  For the remaining backends
(dense) the engine falls back to the gather/scatter round trip below:
materialize each slot's ``(B, KVH, max_context, ...)`` view, run the
unmodified decode, scatter the one new token back.  That XLA-portable
path is memory-traffic-bound at long context — :func:`gather_footprint`
quantifies the difference.
"""

from __future__ import annotations

from typing import Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig, ServingSettings
from repro.models import backends as bk
from repro.models import transformer as tfm

__all__ = ["init_paged_caches", "gather_views", "scatter_token",
           "write_prefill", "gather_footprint"]


def init_paged_caches(cfg: ModelConfig, serving: ServingSettings):
    """Zero-initialized paged pool, reusing the model's cache builder with
    batch=num_blocks and capacity=block_size."""
    serving.validate()
    return tfm.init_decode_caches(cfg, batch=serving.num_blocks,
                                  capacity=serving.block_size)


def _leaf_name(path) -> str:
    return path[-1].key


# ------------------------------------------------------------------ leaves

def _gather_leaf(pages: jax.Array, bt: jax.Array) -> jax.Array:
    """(NB, KVH, rows_pb, *rest), (B, nb) -> (B, KVH, nb*rows_pb, *rest)."""
    return bk.gather_block_leaf(pages, bt)


def _scatter_leaf(pages: jax.Array, view: jax.Array, blk: jax.Array,
                  pos: jax.Array, block_size: int, gran: int) -> jax.Array:
    """Write the row each slot updated at token index ``pos[b]`` (view row
    ``pos // gran``) into physical page ``blk[b]`` row ``(pos %
    block_size) // gran``.  Inactive slots carry ``blk == TRASH_BLOCK``;
    duplicate trash writes are benign."""
    b = view.shape[0]
    row = view[jnp.arange(b), :, pos // gran]    # (B, KVH, *rest)
    off = (pos % block_size) // gran
    return pages.at[blk, :, off].set(row.astype(pages.dtype))


def _write_prefill_leaf(pages: jax.Array, leaf: jax.Array,
                        bt_row: jax.Array) -> jax.Array:
    """Scatter a batch=1 prefill cache leaf (1, KVH, rows, *rest) into
    pages addressed by ``bt_row`` ((bucket/bs,) block ids, trash-padded)."""
    kvh, rows = leaf.shape[1], leaf.shape[2]
    rows_pb = pages.shape[2]
    nb = rows // rows_pb
    blocks = leaf[0].reshape(kvh, nb, rows_pb, *leaf.shape[3:])
    blocks = jnp.moveaxis(blocks, 1, 0)      # (nb, KVH, rows_pb, *rest)
    return pages.at[bt_row].set(blocks.astype(pages.dtype))


# ------------------------------------------------------------------- tree

def gather_views(pages, bt: jax.Array):
    """Materialize the ragged batch's contiguous cache views.

    bt: (B, max_blocks_per_seq) int32 physical block ids (trash-padded).
    Returns a cache pytree shaped exactly like
    ``init_decode_caches(cfg, B, max_context)``.
    """
    grouped = jax.vmap(_gather_leaf, in_axes=(0, None))
    return {
        "groups": jax.tree_util.tree_map(
            lambda p: grouped(p, bt), pages["groups"]),
        "remainder": jax.tree_util.tree_map(
            lambda p: _gather_leaf(p, bt), pages["remainder"]),
    }


def scatter_token(pages, views, bt: jax.Array, pos: jax.Array,
                  block_size: int,
                  granularity: Optional[Dict[str, int]] = None):
    """Write each slot's newly updated row back from the contiguous view
    into its page; returns the updated pool pytree.

    ``granularity``: optional leaf-name -> tokens-per-row map (from the
    backend's ``cache_spec``) for page-granular metadata leaves; token-
    granular leaves may be omitted.
    """
    gran = granularity or {}
    b = bt.shape[0]
    blk = bt[jnp.arange(b), pos // block_size]   # (B,) physical blocks

    def scatter(path, p, v):
        g = gran.get(_leaf_name(path), 1)
        fn = lambda pp, vv: _scatter_leaf(pp, vv, blk, pos, block_size, g)
        if path[0].key == "groups":
            return jax.vmap(fn)(p, v)
        return fn(p, v)

    return jax.tree_util.tree_map_with_path(scatter, pages, views)


def write_prefill(pages, caches, bt_row: jax.Array):
    """Scatter a freshly prefilled (batch=1, capacity=bucket) cache pytree
    into the pool.  ``bt_row``: (bucket/block_size,) block ids — entries
    past the request's real block count point at the trash page."""
    grouped = jax.vmap(
        lambda p, c: _write_prefill_leaf(p, c, bt_row), in_axes=(0, 0))
    return {
        "groups": jax.tree_util.tree_map(
            grouped, pages["groups"], caches["groups"]),
        "remainder": jax.tree_util.tree_map(
            lambda p, c: _write_prefill_leaf(p, c, bt_row),
            pages["remainder"], caches["remainder"]),
    }


# -------------------------------------------------------------- accounting

def gather_footprint(cfg: ModelConfig) -> Dict[str, int]:
    """Per-decode-step gathered bytes for the whole stack, full-view vs
    paged (the tentpole's memory-traffic win, reported by
    ``benchmarks/bench_serving.py``).

    ``full_view_bytes_per_step``: every cache leaf materialized at
    ``(max_batch, KVH, max_context, ...)`` — the gather/scatter fallback.
    ``paged_bytes_per_step``: metadata leaves in full (bits/vnorm or page
    min/max — tens of times smaller than K/V) plus only the backend's
    ``selected_rows`` K/V rows; equals the full-view cost for backends
    that are not paged-capable.  With the fused paged kernel
    (``cfg.socket.use_paged_kernel``) even those gathers disappear —
    the kernel consumes the pool + block table in place, so the
    per-step *materialized* bytes are ≈ 0 (``fused_paged_kernel`` flags
    the regime; HBM still streams pages, but through VMEM once, with
    no intermediate buffers written back).
    """
    backend = bk.get_backend(cfg.attention_backend)
    spec = backend.cache_spec(cfg)
    sv = cfg.serving
    b, n = sv.max_batch, sv.max_context
    kvh = cfg.num_kv_heads
    cdt = jnp.dtype(cfg.compute_dtype)

    def leaf_bytes(s):
        width = int(np.prod(s.suffix, dtype=np.int64)) if s.suffix else 1
        return b * kvh * s.rows(n) * width * jnp.dtype(
            s.leaf_dtype(cdt)).itemsize

    full = sum(leaf_bytes(s) for s in spec.values())
    kv_bytes = leaf_bytes(spec["k"]) + leaf_bytes(spec["v"])
    rows = backend.selected_rows(cfg, n)
    paged = (full - kv_bytes) + 2 * b * kvh * rows * cfg.head_dim * \
        cdt.itemsize
    fused = backend.supports_paged and backend.fused_paged(cfg)
    if fused:
        paged = 0
    layers = sum(1 for s in cfg.layer_specs
                 if s.kind == "attn" and s.attn_type == "global")
    return {
        "full_view_bytes_per_step": int(full) * layers,
        "paged_bytes_per_step":
            int(paged if backend.supports_paged else full) * layers,
        "selected_rows": int(rows),
        "fused_paged_kernel": bool(fused),
    }
