"""Temperature + nucleus (top-p) sampling for the jitted decode step.

Greedy (``temperature == 0``) stays pure argmax — bit-identical to the
pre-sampling engine, so static-vs-continuous parity tests and
replay-exact preemption are unaffected by default.

Sampling threads one PRNG key **per decode slot** (seeded by folding the
slot index into the engine seed): each step splits the slot's key,
samples from the temperature-scaled, top-p-truncated distribution, and
carries the fresh half forward.  Per-slot keys keep a slot's sample
stream independent of which other slots happen to be live — the ragged
batch composition does not perturb a request's randomness.

Post-preemption *replay* steps reuse recorded tokens and discard the
sampled one (see the engine), so resumed requests keep their original
text; the slot's key stream still advances, which only affects tokens
that were never sampled before.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["slot_keys", "sample_tokens"]

NEG_INF = -1e30


def slot_keys(seed: int, max_batch: int) -> jax.Array:
    """(max_batch, 2) uint32 — one independent PRNG key per decode slot."""
    base = jax.random.PRNGKey(seed)
    return jax.vmap(lambda i: jax.random.fold_in(base, i))(
        jnp.arange(max_batch))


def sample_tokens(logits: jax.Array, keys: jax.Array, *,
                  temperature: float, top_p: float,
                  vocab_size: int):
    """Sample one token per row.  ``logits`` (B, V); ``keys`` (B, 2).

    Returns ``(tokens (B,) int32, new_keys (B, 2))``.  Rows beyond
    ``vocab_size`` (the padded vocab tail) are masked out so sampling can
    never emit an invalid id.  ``top_p`` keeps the smallest prefix of the
    sorted distribution whose mass reaches ``top_p`` (the top token is
    always kept; exact ties at the cutoff logit are all kept).
    """
    assert temperature > 0.0, "temperature 0 is greedy — use argmax"
    logits = logits.astype(jnp.float32)
    v = logits.shape[-1]
    if vocab_size < v:
        pad = jnp.arange(v) >= vocab_size
        logits = jnp.where(pad[None], NEG_INF, logits)
    logits = logits / temperature
    if top_p < 1.0:
        sorted_l = jnp.flip(jnp.sort(logits, axis=-1), axis=-1)
        probs = jax.nn.softmax(sorted_l, axis=-1)
        csum = jnp.cumsum(probs, axis=-1)
        keep = (csum - probs) < top_p          # mass strictly before token
        cutoff = jnp.min(jnp.where(keep, sorted_l, jnp.inf), axis=-1,
                         keepdims=True)
        logits = jnp.where(logits >= cutoff, logits, NEG_INF)
    split = jax.vmap(jax.random.split)(keys)   # (B, 2, 2)
    tok = jax.vmap(jax.random.categorical)(split[:, 1], logits)
    return tok.astype(jnp.int32), split[:, 0]
