"""Continuous-batching serving subsystem (paged KV + SOCKET bit-cache).

See :mod:`repro.serving.engine` for the engine,
:mod:`repro.serving.scheduler` for the request lifecycle,
:mod:`repro.serving.block_pool` / :mod:`repro.serving.paged` for the
host- and device-side halves of the paged pool,
:mod:`repro.serving.prefix_cache` for cross-request page reuse, and
:mod:`repro.serving.obs` for the observability layer (event tracing,
metrics registry, selection probe, profiling).  Design notes in
``src/repro/serving/README.md``.
"""

from repro.serving.block_pool import TRASH_BLOCK, BlockPool
from repro.serving.prefix_cache import PrefixCache, RadixIndex
from repro.serving.scheduler import (DECODE, FINISHED, PREFILL, WAITING,
                                     PrefillChunk, Request, Scheduler)

__all__ = ["BlockPool", "TRASH_BLOCK", "Request", "PrefillChunk",
           "Scheduler", "WAITING", "PREFILL", "DECODE", "FINISHED",
           "PrefixCache", "RadixIndex",
           "ContinuousBatchingEngine", "ServeMetrics", "Observability"]


def __getattr__(name):
    # Engine (and its jax-heavy deps) loads lazily so pure-host users of
    # the pool/scheduler — and their unit tests — stay import-light.
    if name in ("ContinuousBatchingEngine", "ServeMetrics"):
        from repro.serving import engine
        return getattr(engine, name)
    if name == "Observability":
        from repro.serving.obs import Observability
        return Observability
    raise AttributeError(name)
