"""Synthetic high-prefix-overlap workloads for the prefix cache.

Two generators modeling the traffic patterns where cross-request reuse
pays (both deterministic given a seed, emitting token-id lists directly —
the engine is tokenizer-free):

* **chatbot** — multi-turn sessions: every session shares one system
  prompt, and turn ``t``'s prompt is the full conversation so far plus a
  new user turn, so consecutive turns overlap on everything but the new
  turn.  Requests are submitted in round-robin turn order (turn 0 of all
  sessions, then turn 1, ...), the order a live chat service sees.
* **rag** — shared-template retrieval: every request starts with the same
  ``overlap``-fraction template (system prompt + retrieval scaffold) and
  ends with a unique query/context, giving a directly tunable overlap
  knob for benchmarking hit-rate vs TTFT curves.
"""

from __future__ import annotations

from typing import List

import numpy as np

__all__ = ["chatbot_prompts", "rag_prompts"]


def _tokens(rng: np.random.Generator, n: int, vocab_size: int) -> List[int]:
    return rng.integers(0, vocab_size, size=n).tolist()


def chatbot_prompts(num_requests: int, *, sessions: int = 2,
                    system_len: int = 16, turn_len: int = 12,
                    max_prompt_len: int = 0, vocab_size: int = 256,
                    seed: int = 0) -> List[List[int]]:
    """Multi-turn chat prompts (see module docstring).  ``max_prompt_len``
    > 0 truncates long conversations keep-first, which preserves the
    shared prefix (late turns of a long session degenerate to identical
    prompts — still realistic cache traffic)."""
    rng = np.random.default_rng(seed)
    system = _tokens(rng, system_len, vocab_size)
    histories = [list(system) for _ in range(sessions)]
    turns = -(-num_requests // sessions)
    prompts: List[List[int]] = []
    for _ in range(turns):
        for s in range(sessions):
            if len(prompts) >= num_requests:
                break
            histories[s] = histories[s] + _tokens(rng, turn_len, vocab_size)
            prompt = histories[s]
            if max_prompt_len > 0:
                prompt = prompt[:max_prompt_len]
            prompts.append(list(prompt))
    return prompts


def rag_prompts(num_requests: int, *, prompt_len: int = 48,
                overlap: float = 0.6, vocab_size: int = 256,
                seed: int = 0) -> List[List[int]]:
    """Shared-template prompts: the first ``round(overlap * prompt_len)``
    tokens are identical across requests, the rest unique per request."""
    if not 0.0 <= overlap <= 1.0:
        raise ValueError(f"overlap must be in [0, 1], got {overlap}")
    rng = np.random.default_rng(seed)
    shared_len = int(round(overlap * prompt_len))
    template = _tokens(rng, shared_len, vocab_size)
    return [template + _tokens(rng, prompt_len - shared_len, vocab_size)
            for _ in range(num_requests)]
