"""Cross-request KV prefix reuse: radix-indexed, refcounted, copy-on-write.

See :mod:`repro.serving.prefix_cache.cache` for the subsystem contract.
"""

from repro.serving.prefix_cache.cache import PrefixCache
from repro.serving.prefix_cache.radix import RadixIndex
from repro.serving.prefix_cache.workloads import chatbot_prompts, rag_prompts

__all__ = ["PrefixCache", "RadixIndex", "chatbot_prompts", "rag_prompts"]
