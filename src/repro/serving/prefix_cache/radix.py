"""Radix (compressed trie) index over committed KV pages, keyed by token ids.

This is the host-side lookup structure behind cross-request prefix reuse
(the sglang "RadixAttention" idea, adapted to this engine's paged pool):
every **full, prompt-pure** page a request commits is inserted under its
``block_size``-token chunk path, so a later request whose prompt shares a
token prefix can install the existing physical blocks instead of
re-prefilling them.

Structure:

* Edges are **compressed**: a node's ``keys``/``blocks`` lists hold one or
  more consecutive pages (parallel lists), so a long unbranched prompt is
  one node, not one node per page.  Inserting a prompt that diverges
  mid-edge splits the node at the divergence point (classic radix split).
* Each node additionally carries ``tails``: partially-filled final pages
  (a prompt whose length is not a multiple of ``block_size``), keyed by
  their token run.  Tail pages are shareable too — a sharer copies the
  matching rows out via copy-on-write before writing row ``j`` — but they
  never become part of the page path (only full pages extend the trie).
* Every traversal stamps ``last_use`` from a monotone clock; eviction
  walks **leaves inward** in LRU order, dropping pages from the deep end
  of edges first so the tree never references a freed block that a longer
  cached prefix still needs.

The index never touches device memory and holds no refcounts itself —
the :class:`~repro.serving.prefix_cache.cache.PrefixCache` facade pairs
it with the :class:`~repro.serving.block_pool.BlockPool` and decides what
is actually evictable (pool refcount 1 = only the tree holds the page).
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional, Sequence, Tuple

__all__ = ["RadixIndex", "RadixNode", "TailEntry"]

PageKey = Tuple[int, ...]


@dataclasses.dataclass
class TailEntry:
    """A shareable partially-filled final page hanging off a node."""

    tokens: Tuple[int, ...]     # the partial page's token run (< block_size)
    block: int
    last_use: int = 0


class RadixNode:
    """One compressed edge: ``keys[i]`` (a ``block_size``-token tuple) is
    the page chunk whose KV lives in physical block ``blocks[i]``."""

    __slots__ = ("keys", "blocks", "children", "tails", "parent",
                 "last_use")

    def __init__(self, keys: List[PageKey], blocks: List[int],
                 parent: Optional["RadixNode"]):
        assert len(keys) == len(blocks)
        self.keys = keys
        self.blocks = blocks
        self.children: Dict[PageKey, RadixNode] = {}
        self.tails: Dict[Tuple[int, ...], TailEntry] = {}
        self.parent = parent
        self.last_use = 0

    @property
    def is_leaf(self) -> bool:
        return not self.children and not self.tails


def _common_prefix(a: Sequence[int], b: Sequence[int]) -> int:
    n = 0
    for x, y in zip(a, b):
        if x != y:
            break
        n += 1
    return n


class RadixIndex:
    """Token-keyed radix tree mapping prompt prefixes to page lists."""

    def __init__(self, block_size: int):
        self.block_size = block_size
        self.root = RadixNode([], [], None)
        self._clock = 0
        self.num_blocks = 0          # pages referenced by the tree
        self.num_tail_blocks = 0     # of which, tail entries

    # ---------------------------------------------------------------- util
    def _tick(self) -> int:
        self._clock += 1
        return self._clock

    def _pages(self, tokens: Sequence[int]) -> List[PageKey]:
        bs = self.block_size
        return [tuple(tokens[i * bs:(i + 1) * bs])
                for i in range(len(tokens) // bs)]

    # --------------------------------------------------------------- match
    def match(self, tokens: Sequence[int]) -> Tuple[
            List[int], int, Optional[Tuple[TailEntry, int]]]:
        """Longest cached prefix of ``tokens``.

        Returns ``(blocks, full_pages, tail)``: the physical blocks of the
        matched full pages, how many full pages matched, and — when the
        walk ended exactly on a node boundary — the best partially
        matching tail entry there as ``(entry, matched_rows)`` (None
        otherwise).  Stamps ``last_use`` along the path."""
        pages = self._pages(tokens)
        now = self._tick()
        node = self.root
        node.last_use = now
        blocks: List[int] = []
        pi = 0
        while pi < len(pages):
            child = node.children.get(pages[pi])
            if child is None:
                break
            child.last_use = now
            k = 0
            while (k < len(child.keys) and pi < len(pages)
                   and child.keys[k] == pages[pi]):
                blocks.append(child.blocks[k])
                k += 1
                pi += 1
            if k < len(child.keys):
                # diverged (or ran out of prompt) mid-edge: no node sits
                # at this point, so no tail entries can apply here.
                return blocks, pi, None
            node = child
        rem = tokens[pi * self.block_size:]
        best: Optional[Tuple[TailEntry, int]] = None
        if rem:
            for entry in node.tails.values():
                j = _common_prefix(entry.tokens, rem)
                if j > 0 and (best is None or j > best[1]):
                    best = (entry, j)
            if best is not None:
                best[0].last_use = now
        return blocks, pi, best

    # -------------------------------------------------------------- insert
    def _split(self, node: RadixNode, k: int) -> RadixNode:
        """Split ``node``'s edge after ``k`` pages; returns the (new)
        upper node holding ``keys[:k]``.  The original node keeps the deep
        part plus all children/tails."""
        assert 0 < k < len(node.keys)
        upper = RadixNode(node.keys[:k], node.blocks[:k], node.parent)
        upper.last_use = node.last_use
        parent = node.parent
        assert parent is not None
        parent.children[upper.keys[0]] = upper
        node.keys = node.keys[k:]
        node.blocks = node.blocks[k:]
        node.parent = upper
        upper.children[node.keys[0]] = node
        return upper

    def _walk_insert(self, pages: List[PageKey]) -> Tuple[RadixNode, int]:
        """Walk ``pages`` from the root, splitting any edge the path exits
        mid-way, and return ``(node, consumed)`` where ``node`` ends
        exactly at page boundary ``consumed``."""
        node = self.root
        pi = 0
        while pi < len(pages):
            child = node.children.get(pages[pi])
            if child is None:
                return node, pi
            k = _common_prefix(child.keys, pages[pi:])
            pi += k
            if k < len(child.keys):
                return self._split(child, k), pi
            node = child
        return node, pi

    def insert(self, tokens: Sequence[int],
               blocks: Sequence[int]) -> List[int]:
        """Index the full pages of ``tokens`` (``len(blocks)`` pages;
        callers pass only prompt-pure, fully-committed pages).  Pages
        already present keep their existing physical blocks.  Returns the
        blocks newly adopted by the tree (caller takes a pool ref on
        each)."""
        pages = self._pages(tokens)[:len(blocks)]
        if not pages:
            return []
        node, pi = self._walk_insert(pages)
        node.last_use = self._tick()
        if pi == len(pages):
            return []
        fresh = list(blocks[pi:len(pages)])
        child = RadixNode(pages[pi:], fresh, node)
        child.last_use = node.last_use
        node.children[pages[pi]] = child
        self.num_blocks += len(fresh)
        return fresh

    def insert_tail(self, tokens: Sequence[int], block: int,
                    prompt_len: int) -> bool:
        """Index the partial final page of a length-``prompt_len`` prompt
        (rows ``[0, prompt_len % block_size)`` of ``block``).  The full
        pages must already be indexed (insert them first).  Returns True
        if the tree adopted ``block``."""
        bs = self.block_size
        run = tuple(tokens[(prompt_len // bs) * bs:prompt_len])
        assert 0 < len(run) < bs
        node, pi = self._walk_insert(self._pages(tokens)[:prompt_len // bs])
        if pi < prompt_len // bs:
            return False               # full pages not (fully) indexed
        node.last_use = self._tick()
        if run in node.tails:
            return False               # identical run already shareable
        node.tails[run] = TailEntry(run, block, node.last_use)
        self.num_blocks += 1
        self.num_tail_blocks += 1
        return True

    # ------------------------------------------------------------ eviction
    def _evictables(self) -> List[Tuple[int, RadixNode, object]]:
        """All currently trimmable units, leaves inward: every tail entry,
        plus the deepest page of every leaf node (dropping it exposes the
        next page up).  Returned as ``(last_use, node, unit)`` where unit
        is a TailEntry or the string ``"edge"``."""
        out: List[Tuple[int, RadixNode, object]] = []
        stack = [self.root]
        while stack:
            node = stack.pop()
            stack.extend(node.children.values())
            for entry in node.tails.values():
                out.append((entry.last_use, node, entry))
            if node is not self.root and node.is_leaf:
                out.append((node.last_use, node, "edge"))
        return out

    def evict(self, want: int, can_evict: Callable[[int], bool]) -> List[int]:
        """Drop up to ``want`` pages in LRU order, skipping blocks
        ``can_evict`` rejects (pages a live request still holds).  Returns
        the dropped physical blocks (caller derefs them in the pool)."""
        freed: List[int] = []
        while len(freed) < want:
            progressed = False
            for _, node, unit in sorted(self._evictables(),
                                        key=lambda t: t[0]):
                if len(freed) >= want:
                    break
                if isinstance(unit, TailEntry):
                    if not can_evict(unit.block):
                        continue
                    del node.tails[unit.tokens]
                    freed.append(unit.block)
                    self.num_blocks -= 1
                    self.num_tail_blocks -= 1
                    progressed = True
                else:
                    # trim the leaf edge from its deep end while allowed
                    while (node.keys and len(freed) < want
                           and node.is_leaf
                           and can_evict(node.blocks[-1])):
                        node.keys.pop()
                        freed.append(node.blocks.pop())
                        self.num_blocks -= 1
                        progressed = True
                    if not node.keys and node.parent is not None:
                        # fully trimmed: detach (parent may become a leaf,
                        # picked up by the next sweep)
                        for key, child in list(node.parent.children.items()):
                            if child is node:
                                del node.parent.children[key]
            if not progressed:
                break
        return freed

    # -------------------------------------------------------------- status
    def stats(self) -> dict:
        return {"blocks": self.num_blocks,
                "tail_blocks": self.num_tail_blocks}
