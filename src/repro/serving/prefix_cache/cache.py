"""Prefix cache facade: radix index + pool refcounts + CoW policy.

Ties the token-keyed :class:`~repro.serving.prefix_cache.radix.RadixIndex`
to the refcounted :class:`~repro.serving.block_pool.BlockPool` and owns
every policy decision the scheduler/engine consult:

* **match** — longest cached prefix of a prompt, capped at
  ``len(prompt) - 1`` (the final prompt token is always prefilled so the
  request computes its first-output logits), and page-aligned **down**
  unless tail pages are shareable (``tail_shareable`` is False whenever
  any paged leaf has ``granularity > 1`` — quest's per-page min/max
  stats summarize *all* rows of a page, so sharing a partially-valid
  page, or partially keeping one under CoW, would score junk keys).
* **insert** — full prompt-pure pages index at activation (they are
  immutable from that point: decode writes land strictly past the
  prompt), the partial tail page only once the owner stops writing it
  (finish, or preemption after prefill completed).  The tree takes one
  pool ref per adopted page, which is what keeps page data alive after
  its producing request is gone.
* **evict** — LRU trim of pages only the tree still references
  (pool refcount 1); this is the engine's *first* reclamation tier,
  ahead of recompute-preemption.

The refcount/CoW contract: a block with pool refcount > 1 is never
written in place.  The engine enforces it by cloning (with scrub) the
one page a cache hit can write into — see ``engine._resolve_cow``.
"""

from __future__ import annotations

from typing import List, Optional, Sequence, Tuple

from repro.serving.block_pool import BlockPool
from repro.serving.prefix_cache.radix import RadixIndex

__all__ = ["PrefixCache"]


class PrefixCache:
    """Cross-request KV page reuse over a refcounted block pool."""

    def __init__(self, pool: BlockPool, *, block_size: int,
                 tail_shareable: bool = True):
        self.pool = pool
        self.block_size = block_size
        self.tail_shareable = tail_shareable
        self.index = RadixIndex(block_size)
        self._held: set = set()        # blocks the tree holds a ref on
        # observability (bound by the engine per run; None = standalone)
        self.registry = None
        self.tracer = None

    # -------------------------------------------------------- observability
    def bind_obs(self, registry=None, tracer=None) -> None:
        self.registry = registry
        self.tracer = tracer

    def _emit(self, event_type: str, **fields) -> None:
        if self.tracer is not None:
            self.tracer.emit(event_type, **fields)

    def _count(self, name: str, n: int = 1, **labels) -> None:
        if self.registry is not None:
            self.registry.counter(name, **labels).inc(n)

    # --------------------------------------------------------------- query
    @property
    def shared_blocks(self) -> int:
        """Pages the tree currently references (the shared-block gauge)."""
        return self.index.num_blocks

    def evictable_blocks(self) -> int:
        """Pages reclaimable right now (tree-only, refcount 1)."""
        return sum(1 for b in self._held if self.pool.refcount(b) == 1)

    def match(self, prompt: Sequence[int]) -> Tuple[List[int], int]:
        """Longest usable cached prefix of ``prompt``.

        Returns ``(blocks, cached_tokens)`` — the physical blocks covering
        the cached prefix (possibly ending in a partially-valid tail page)
        and its token length.  Takes **no** refs; the caller pins the
        blocks (``pool.ref``) before any eviction can run."""
        p = len(prompt)
        blocks, full_pages, tail = self.index.match(prompt)
        cached = full_pages * self.block_size
        out = list(blocks)
        if tail is not None and self.tail_shareable:
            entry, rows = tail
            out.append(entry.block)
            cached += rows
        cached = min(cached, p - 1)    # final prompt token always prefills
        if not self.tail_shareable:
            cached -= cached % self.block_size
        if cached <= 0:
            return [], 0
        return out[:-(-cached // self.block_size)], cached

    # -------------------------------------------------------------- insert
    def insert(self, prompt: Sequence[int], blocks: Sequence[int],
               committed: int, include_tail: bool = False,
               rid: Optional[int] = None) -> int:
        """Index the committed, prompt-pure prefix of a request: the first
        ``min(committed, len(prompt)) // block_size`` full pages, plus —
        only when ``include_tail`` (the owner has stopped writing the
        page: finish, or preemption after prefill completed) — the
        partial tail page.  Generated-token KV is never indexed (decode
        produces it under the sparse backend; a dense re-prefill of the
        same tokens would differ bitwise) — sharers CoW-scrub any
        generated rows sitting past the prompt in a shared tail page.
        Returns pages adopted."""
        p = len(prompt)
        full = min(committed, p) // self.block_size
        adopted = self.index.insert(prompt, list(blocks[:full]))
        tail = False
        if (include_tail and committed >= p and p % self.block_size
                and self.tail_shareable and len(blocks) > full
                and self.index.insert_tail(prompt, blocks[full], p)):
            adopted.append(blocks[full])
            tail = True
        for b in adopted:
            self.pool.ref(b)
            self._held.add(b)
        if adopted:
            self._count("prefix_cache_pages_shared_total", n=len(adopted))
            self._emit("page_share", rid=rid if rid is not None else -1,
                       blocks=len(adopted), tail=tail)
        return len(adopted)

    # ------------------------------------------------------------ eviction
    def evict(self, want: int) -> int:
        """First reclamation tier: LRU-drop up to ``want`` tree-only pages
        back to the pool free list.  Pages any live request still shares
        (refcount > 1) are pinned and skipped.  Returns pages freed."""
        if want <= 0:
            return 0
        freed = self.index.evict(
            want, can_evict=lambda b: self.pool.refcount(b) == 1)
        for b in freed:
            self._held.discard(b)
        self.pool.free(freed)
        if freed:
            self._count("prefix_cache_evicted_total", n=len(freed))
            self._emit("cache_evict", blocks=len(freed),
                       remaining_blocks=self.index.num_blocks)
        return len(freed)

    def stats(self) -> dict:
        return {"shared_blocks": self.index.num_blocks,
                "tail_blocks": self.index.num_tail_blocks,
                "evictable": self.evictable_blocks()}
