"""Structured event tracer: validated events to memory and/or JSONL.

One :class:`Tracer` serves one engine's lifetime (it may span several
``run()`` calls; the trace opens with one ``trace_start`` version
handshake and each run is bracketed by ``run_start``/``run_end``).
Timestamps are seconds since the tracer's epoch (``time.perf_counter``
based — monotonic, sub-μs).

Every event is validated against :data:`~repro.serving.obs.events
.EVENT_SCHEMA` at emit time and serialized strictly (non-finite floats
become ``null``), so a written trace is schema-valid by construction —
CI re-validates the file anyway (``python -m repro.serving.obs.validate``)
to pin the contract.

The tracer is only ever constructed when observability is requested:
the engine's disabled path holds no tracer at all and allocates zero
event objects per step (asserted in ``tests/test_observability.py``).
"""

from __future__ import annotations

import os
import time
from typing import Dict, List, Optional

from repro.serving.obs import events as ev

__all__ = ["Tracer"]


class Tracer:
    """Event bus writing validated events to an in-memory list (always —
    the Perfetto exporter and tests consume it) and, when ``path`` is
    given, streaming them to a JSONL file (line-buffered, so a crashed
    run still leaves a readable trace)."""

    def __init__(self, path: Optional[str] = None):
        self.path = path
        self.events: List[Dict] = []
        self._t0 = time.perf_counter()
        if path and os.path.dirname(path):
            os.makedirs(os.path.dirname(path), exist_ok=True)
        self._file = open(path, "w", buffering=1) if path else None
        self._runs = 0
        self._started = False

    def now(self) -> float:
        """Seconds since the tracer epoch."""
        return time.perf_counter() - self._t0

    def emit(self, event_type: str, **fields) -> Dict:
        event = {"ev": event_type, "ts": round(self.now(), 6)}
        event.update(ev.sanitize(fields))
        ev.validate_event(event)
        self.events.append(event)
        if self._file is not None:
            self._file.write(ev.strict_dumps(event) + "\n")
        return event

    def ensure_start(self, **meta) -> None:
        """Emit the ``trace_start`` version handshake once per tracer
        (the engine calls this before its first event — warmup compiles
        included)."""
        if not self._started:
            self._started = True
            self.emit("trace_start", schema=ev.SCHEMA_VERSION, **meta)

    def begin_run(self, *, requests: int) -> int:
        """Open a run: emits ``run_start`` with a per-tracer run
        ordinal; returns the ordinal."""
        self.ensure_start()
        run = self._runs
        self._runs += 1
        self.emit("run_start", run=run, requests=requests)
        return run

    def end_run(self, run: int, *, requests: int, generated: int,
                wall_s: float) -> None:
        self.emit("run_end", run=run, requests=requests,
                  generated=generated, wall_s=wall_s)

    def close(self) -> None:
        if self._file is not None:
            self._file.close()
            self._file = None

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()
