"""Engine-side sampler for the sparse-selection quality probe.

Every ``every`` decode iterations the engine re-runs the current decode
batch through a shadow step (separately jitted, no donation — see
:meth:`ContinuousBatchingEngine._run_probe`) that stages
:func:`repro.models.backends.probe.selection_stats` callbacks, then
hands the drained per-layer, per-slot stats here.  This class reduces
them over the *active* slots (padded slots carry garbage) into one row
per probed layer, accumulates the rows for the bench JSON, and keeps a
running summary.

Cost model: one probe step ≈ one decode step plus a dense ``(B, KVH, N)``
attention-mass reference per probed layer (the thing SOCKET exists to
avoid — this is why the probe is sampled, not always-on) plus one extra
compile the first time it fires.  ``every=0`` disables the probe; the
engine then never builds the shadow step.
"""

from __future__ import annotations

from typing import Dict, List, Sequence

import numpy as np

__all__ = ["SelectionProbe"]

# stats reduced by mean over active slots -> row field name
_MEANS = (("recall", "recall"),
          ("budget_utilization", "budget_utilization"),
          ("forced_share", "forced_share"),
          ("selected", "selected_mean"),
          ("budget", "budget_mean"))


class SelectionProbe:
    """Sampling policy + reduction + accumulation for probe stats."""

    def __init__(self, every: int = 0):
        self.every = int(every)
        self.rows: List[Dict] = []          # one dict per (iteration, layer)

    def due(self, iteration: int) -> bool:
        return self.every > 0 and iteration % self.every == 0

    def add(self, iteration: int, layer_stats: Sequence[Dict],
            slots: Sequence[int]) -> List[Dict]:
        """Reduce one shadow step's drained stats (one dict of ``(B,)``
        arrays per probed layer, execution order) over the active
        ``slots``; returns the new rows (also retained on ``rows``)."""
        sel = np.asarray(list(slots), np.int32)
        new: List[Dict] = []
        for layer, st in enumerate(layer_stats):
            row = {"iter": iteration, "layer": layer,
                   "requests": int(sel.size),
                   "static_k": int(np.asarray(st["static_k"]))}
            for key, name in _MEANS:
                vals = np.asarray(st[key], np.float64)[sel]
                row[name] = round(float(np.mean(vals)), 6) if sel.size \
                    else None
            new.append(row)
        self.rows.extend(new)
        return new

    def summary(self) -> Dict:
        """Row-count + per-field means over everything sampled so far
        (strict-JSON-safe; None when nothing was sampled)."""
        out: Dict = {"probe_steps": len({r["iter"] for r in self.rows}),
                     "rows": len(self.rows)}
        for _, name in _MEANS:
            vals = [r[name] for r in self.rows if r[name] is not None]
            out[name] = round(float(np.mean(vals)), 6) if vals else None
        return out
