"""Chrome ``trace_event`` exporter: open a serving run in Perfetto.

Converts a schema-valid serving trace (list of events or a JSONL file)
into the Chrome trace-event JSON format that https://ui.perfetto.dev and
``chrome://tracing`` load directly:

* one **thread per request** (pid 1) with complete-span ("X") events for
  its lifecycle phases — ``queued`` (submit→admit, and preempt→re-admit),
  ``prefill`` (admit→first token) and ``decode`` (first token→finish) —
  plus instant markers for preemptions and withheld chunk grants;
* **counter tracks** (pid 0) from the per-iteration step records: pool
  occupancy (free/used blocks) and batch occupancy
  (running/prefilling/waiting);
* instant events for compiles and the probe's per-layer recall rows.

Timestamps are microseconds (the trace-event unit) from the tracer epoch.
"""

from __future__ import annotations

from typing import Dict, List, Optional

from repro.serving.obs import events as ev_schema

__all__ = ["chrome_trace", "write_chrome_trace"]

_US = 1e6


def _span(name, ts, dur, pid, tid, args=None) -> Dict:
    out = {"name": name, "ph": "X", "ts": ts * _US, "dur": max(dur, 0.0)
           * _US, "pid": pid, "tid": tid}
    if args:
        out["args"] = args
    return out


def _instant(name, ts, pid, tid, args=None) -> Dict:
    out = {"name": name, "ph": "i", "s": "t", "ts": ts * _US, "pid": pid,
           "tid": tid}
    if args:
        out["args"] = args
    return out


def _counter(name, ts, values: Dict) -> Dict:
    return {"name": name, "ph": "C", "ts": ts * _US, "pid": 0, "tid": 0,
            "args": values}


def chrome_trace(events: List[Dict]) -> Dict:
    """Build the ``{"traceEvents": [...]}`` object from parsed events."""
    out: List[Dict] = [
        {"name": "process_name", "ph": "M", "pid": 0, "tid": 0,
         "args": {"name": "engine"}},
        {"name": "process_name", "ph": "M", "pid": 1, "tid": 0,
         "args": {"name": "requests"}},
    ]
    # ---- per-request lifecycle threads ----------------------------------
    per_rid: Dict[int, List[Dict]] = {}
    for e in events:
        if "rid" in e:
            per_rid.setdefault(e["rid"], []).append(e)
    for rid in sorted(per_rid):
        tid = rid + 1
        out.append({"name": "thread_name", "ph": "M", "pid": 1, "tid": tid,
                    "args": {"name": f"req {rid}"}})
        open_name: Optional[str] = None
        open_ts = 0.0
        last_ts = 0.0
        for e in per_rid[rid]:
            kind, ts = e["ev"], e["ts"]
            last_ts = ts
            if kind == "submit":
                open_name, open_ts = "queued", ts
            elif kind == "admit":
                if open_name:
                    out.append(_span(open_name, open_ts, ts - open_ts, 1,
                                     tid))
                open_name, open_ts = "prefill", ts
            elif kind == "first_token":
                if open_name:
                    out.append(_span(open_name, open_ts, ts - open_ts, 1,
                                     tid, {"ttft_s": e["ttft_s"]}))
                open_name, open_ts = "decode", ts
            elif kind == "preempt":
                if open_name:
                    out.append(_span(open_name, open_ts, ts - open_ts, 1,
                                     tid))
                out.append(_instant(f"preempt ({e['cause']})", ts, 1, tid,
                                    {"blocks_freed": e["blocks_freed"]}))
                open_name, open_ts = "queued", ts
            elif kind == "finish":
                if open_name:
                    out.append(_span(open_name, open_ts, ts - open_ts, 1,
                                     tid, {"generated": e["generated"]}))
                open_name = None
            elif kind == "chunk_grant":
                out.append(_instant(
                    f"chunk +{e['tokens']}", ts, 1, tid,
                    {"start": e["start"], "final": e["final"]}))
            elif kind == "chunk_withheld":
                out.append(_instant("chunk withheld", ts, 1, tid,
                                    {"free_blocks": e["free_blocks"]}))
            elif kind == "cache_hit":
                out.append(_instant(
                    f"cache hit +{e['cached_tokens']}", ts, 1, tid,
                    {"cached_tokens": e["cached_tokens"],
                     "prompt_tokens": e["prompt_tokens"],
                     "shared_blocks": e["shared_blocks"]}))
            elif kind == "page_share":
                out.append(_instant(f"share {e['blocks']}p", ts, 1, tid,
                                    {"tail": e["tail"]}))
            elif kind == "cow_copy":
                out.append(_instant("cow copy", ts, 1, tid,
                                    {"block": e["block"],
                                     "clone": e["clone"],
                                     "keep_tokens": e["keep_tokens"]}))
        if open_name:                    # run ended mid-phase
            out.append(_span(open_name, open_ts, last_ts - open_ts, 1,
                             tid))
    # ---- engine counters + instants -------------------------------------
    for e in events:
        kind, ts = e["ev"], e["ts"]
        if kind == "step":
            out.append(_counter("pool_blocks", ts,
                                {"free": e["pool_free"],
                                 "used": e["pool_used"]}))
            out.append(_counter("batch", ts,
                                {"running": e["running"],
                                 "prefilling": e["prefilling"],
                                 "waiting": e["waiting"]}))
        elif kind == "compile":
            out.append(_span(f"compile {e['fn']}",
                             ts - e["seconds"], e["seconds"], 0, 0))
        elif kind == "probe":
            out.append(_counter(f"probe_recall_l{e['layer']}", ts,
                                {"recall": e["recall"]}))
        elif kind == "cache_evict":
            out.append(_instant(f"cache evict {e['blocks']}p", ts, 0, 0,
                                {"remaining_blocks":
                                 e["remaining_blocks"]}))
    return {"traceEvents": out, "displayTimeUnit": "ms"}


def write_chrome_trace(events_or_path, out_path: str) -> Dict:
    """Export to ``out_path``; accepts parsed events or a JSONL path."""
    if isinstance(events_or_path, str):
        with open(events_or_path) as f:
            events = ev_schema.validate_jsonl(f)
    else:
        events = list(events_or_path)
    trace = chrome_trace(events)
    with open(out_path, "w") as f:
        f.write(ev_schema.strict_dumps(trace))
    return trace
