"""Versioned event schema for the serving trace (JSONL, one event/line).

Every event is a flat JSON object with two implicit fields — ``ev`` (the
event type) and ``ts`` (seconds since the tracer's epoch, float) — plus
the per-type fields tabulated in :data:`EVENT_SCHEMA`.  The first line
of every trace is a ``trace_start`` event carrying
:data:`SCHEMA_VERSION`; consumers must refuse traces whose version they
do not understand.  One trace covers one engine's lifetime (warmup
compiles included); each ``run()`` is bracketed by ``run_start`` /
``run_end``.

The schema is **strict** both ways: :func:`validate_event` rejects
missing fields, wrong types, and unknown fields, so an emitted trace and
the schema can never drift apart silently (the tracer validates every
event at emit time, and CI re-validates the written file).

JSON is strict too: ``NaN``/``Infinity`` are not JSON — floats that are
not finite are serialized as ``null`` (:func:`sanitize`), and the
loaders here reject the non-strict tokens outright
(:func:`strict_loads`).
"""

from __future__ import annotations

import json
import math
from typing import Any, Dict, Iterable, List

__all__ = ["SCHEMA_VERSION", "EVENT_SCHEMA", "validate_event",
           "validate_jsonl", "sanitize", "strict_dumps", "strict_loads"]

SCHEMA_VERSION = 1

# Field type specs: int / float / str / bool.  ``float`` accepts ints
# (JSON has one number type) and ``None`` (a sanitized non-finite value);
# every other type is exact.  ``?`` prefix marks the field optional.
EVENT_SCHEMA: Dict[str, Dict[str, type]] = {
    # one per trace file — version handshake + engine metadata (warmup
    # compiles may precede the first run, so runs are bracketed by
    # run_start/run_end instead)
    "trace_start": {"schema": int, "?arch": str, "?backend": str,
                    "?prefill_chunk": int, "?layers_paged": int,
                    "?layers_ring": int, "?layers_state": int},
    "run_start": {"run": int, "requests": int},
    "run_end": {"run": int, "requests": int, "generated": int,
                "wall_s": float},
    # ---- request lifecycle ------------------------------------------------
    "submit": {"rid": int, "prompt_tokens": int, "max_new_tokens": int,
               "arrival": float},
    "admit": {"rid": int, "slot": int, "blocks": int, "resume": bool,
              "?wait_s": float},
    "chunk_grant": {"rid": int, "start": int, "tokens": int, "final": bool,
                    "blocks": int},
    "chunk_withheld": {"rid": int, "free_blocks": int},
    "preempt": {"rid": int, "cause": str, "state": str,
                "blocks_freed": int},
    "first_token": {"rid": int, "ttft_s": float},
    "finish": {"rid": int, "generated": int, "preemptions": int},
    # ---- per-iteration step record ---------------------------------------
    "step": {"iter": int, "kind": str, "occupancy": int,
             "chunk_tokens": int, "step_s": float, "pool_free": int,
             "pool_used": int, "pool_high_water": int, "waiting": int,
             "prefilling": int, "running": int},
    # first execution of a jitted shape (trace + compile + first run)
    "compile": {"fn": str, "seconds": float},
    # ---- sampled selection-quality probe (one event per probed layer) ----
    "probe": {"iter": int, "layer": int, "requests": int, "static_k": int,
              "recall": float, "budget_utilization": float,
              "forced_share": float, "selected_mean": float,
              "budget_mean": float},
    # ---- profiler lifecycle ----------------------------------------------
    "profile_start": {"dir": str, "steps": int},
    "profile_stop": {"dir": str},
}


def sanitize(obj: Any) -> Any:
    """Recursively replace non-finite floats with ``None`` (JSON has no
    NaN/Infinity; the non-strict tokens Python emits by default are
    rejected by every compliant parser)."""
    if isinstance(obj, float):
        return obj if math.isfinite(obj) else None
    if isinstance(obj, dict):
        return {k: sanitize(v) for k, v in obj.items()}
    if isinstance(obj, (list, tuple)):
        return [sanitize(v) for v in obj]
    return obj


def strict_dumps(obj: Any, **kw) -> str:
    """``json.dumps`` with non-finite floats as ``null`` — never the
    non-strict ``NaN``/``Infinity`` tokens."""
    return json.dumps(sanitize(obj), allow_nan=False, **kw)


def _reject_constant(tok: str):
    raise ValueError(
        f"non-strict JSON token {tok!r} (NaN/Infinity must be serialized "
        "as null — see repro.serving.obs.events.sanitize)")


def strict_loads(s: str) -> Any:
    """``json.loads`` rejecting the non-strict ``NaN``/``Infinity`` tokens."""
    return json.loads(s, parse_constant=_reject_constant)


def _type_ok(value: Any, spec: type) -> bool:
    if spec is float:
        # JSON has one number type; None is a sanitized non-finite float
        return value is None or (isinstance(value, (int, float))
                                 and not isinstance(value, bool))
    if spec is int:
        return isinstance(value, int) and not isinstance(value, bool)
    return isinstance(value, spec)


def validate_event(event: Dict[str, Any]) -> None:
    """Raise ``ValueError`` unless ``event`` conforms to the schema."""
    ev = event.get("ev")
    if ev not in EVENT_SCHEMA:
        raise ValueError(f"unknown event type {ev!r}")
    if not _type_ok(event.get("ts"), float) or event.get("ts") is None:
        raise ValueError(f"{ev}: missing/invalid ts: {event.get('ts')!r}")
    fields = EVENT_SCHEMA[ev]
    known = {"ev", "ts"}
    for name, spec in fields.items():
        optional = name.startswith("?")
        name = name[1:] if optional else name
        known.add(name)
        if name not in event:
            if optional:
                continue
            raise ValueError(f"{ev}: missing field {name!r}")
        if not _type_ok(event[name], spec):
            raise ValueError(
                f"{ev}: field {name!r} expected {spec.__name__}, got "
                f"{event[name]!r}")
    extra = set(event) - known
    if extra:
        raise ValueError(f"{ev}: unknown fields {sorted(extra)}")


def validate_jsonl(lines: Iterable[str]) -> List[Dict[str, Any]]:
    """Validate a trace (an iterable of JSONL lines); returns the parsed
    events.  The first event must be a ``trace_start`` carrying a known
    schema version; parsing is strict (no NaN tokens)."""
    events = []
    for i, line in enumerate(lines):
        if not line.strip():
            continue
        try:
            event = strict_loads(line)
        except ValueError as e:
            raise ValueError(f"line {i + 1}: {e}") from None
        validate_event(event)
        events.append(event)
    if not events:
        raise ValueError("empty trace")
    head = events[0]
    if head["ev"] != "trace_start":
        raise ValueError(
            f"trace must open with trace_start, got {head['ev']!r}")
    if head["schema"] != SCHEMA_VERSION:
        raise ValueError(
            f"unsupported trace schema {head['schema']} "
            f"(this reader understands {SCHEMA_VERSION})")
    return events
