"""Versioned event schema for the serving trace (JSONL, one event/line).

Every event is a flat JSON object with two implicit fields — ``ev`` (the
event type) and ``ts`` (seconds since the tracer's epoch, float) — plus
the per-type fields tabulated in :data:`EVENT_SCHEMA`.  The first line
of every trace is a ``trace_start`` event carrying
:data:`SCHEMA_VERSION`; consumers must refuse traces whose version they
do not understand.  One trace covers one engine's lifetime (warmup
compiles included); each ``run()`` is bracketed by ``run_start`` /
``run_end``.

The schema is **strict** both ways: :func:`validate_event` rejects
missing fields, wrong types, and unknown fields, so an emitted trace and
the schema can never drift apart silently (the tracer validates every
event at emit time, and CI re-validates the written file).

JSON is strict too: ``NaN``/``Infinity`` are not JSON — floats that are
not finite are serialized as ``null`` (:func:`sanitize`), and the
loaders here reject the non-strict tokens outright
(:func:`strict_loads`).
"""

from __future__ import annotations

import json
import math
from typing import Any, Dict, Iterable, List

__all__ = ["SCHEMA_VERSION", "SUPPORTED_SCHEMAS", "EVENT_SCHEMA",
           "EVENT_SCHEMA_V1", "validate_event", "validate_jsonl",
           "sanitize", "strict_dumps", "strict_loads"]

SCHEMA_VERSION = 2

# Field type specs: int / float / str / bool.  ``float`` accepts ints
# (JSON has one number type) and ``None`` (a sanitized non-finite value);
# every other type is exact.  ``?`` prefix marks the field optional.
#
# This dict is the CURRENT (v2) schema; v1 — before the prefix-cache
# events — is frozen below as :data:`EVENT_SCHEMA_V1`, and
# :func:`validate_jsonl` checks each trace against the schema its
# handshake declares, so both generations of traces stay readable.
EVENT_SCHEMA: Dict[str, Dict[str, type]] = {
    # one per trace file — version handshake + engine metadata (warmup
    # compiles may precede the first run, so runs are bracketed by
    # run_start/run_end instead)
    "trace_start": {"schema": int, "?arch": str, "?backend": str,
                    "?prefill_chunk": int, "?layers_paged": int,
                    "?layers_ring": int, "?layers_state": int,
                    "?prefix_cache": bool},
    "run_start": {"run": int, "requests": int},
    "run_end": {"run": int, "requests": int, "generated": int,
                "wall_s": float},
    # ---- request lifecycle ------------------------------------------------
    "submit": {"rid": int, "prompt_tokens": int, "max_new_tokens": int,
               "arrival": float},
    "admit": {"rid": int, "slot": int, "blocks": int, "resume": bool,
              "?wait_s": float},
    "chunk_grant": {"rid": int, "start": int, "tokens": int, "final": bool,
                    "blocks": int},
    "chunk_withheld": {"rid": int, "free_blocks": int},
    "preempt": {"rid": int, "cause": str, "state": str,
                "blocks_freed": int},
    "first_token": {"rid": int, "ttft_s": float},
    "finish": {"rid": int, "generated": int, "preemptions": int},
    # ---- per-iteration step record ---------------------------------------
    "step": {"iter": int, "kind": str, "occupancy": int,
             "chunk_tokens": int, "step_s": float, "pool_free": int,
             "pool_used": int, "pool_high_water": int, "waiting": int,
             "prefilling": int, "running": int},
    # first execution of a jitted shape (trace + compile + first run)
    "compile": {"fn": str, "seconds": float},
    # ---- sampled selection-quality probe (one event per probed layer) ----
    "probe": {"iter": int, "layer": int, "requests": int, "static_k": int,
              "recall": float, "budget_utilization": float,
              "forced_share": float, "selected_mean": float,
              "budget_mean": float},
    # ---- profiler lifecycle ----------------------------------------------
    "profile_start": {"dir": str, "steps": int},
    "profile_stop": {"dir": str},
    # ---- prefix cache (v2) -----------------------------------------------
    # admission-time match result (one per admission when the cache is on)
    "cache_hit": {"rid": int, "cached_tokens": int, "prompt_tokens": int,
                  "shared_blocks": int},
    "cache_miss": {"rid": int, "prompt_tokens": int},
    # a request's committed pages adopted by the radix index
    "page_share": {"rid": int, "blocks": int, "tail": bool},
    # copy-on-write un-share: ``block`` cloned into ``clone``, first
    # ``keep_tokens`` rows kept, the rest scrubbed to init fill
    "cow_copy": {"rid": int, "block": int, "clone": int,
                 "keep_tokens": int},
    # LRU reclamation of tree-only pages (the first eviction tier)
    "cache_evict": {"blocks": int, "remaining_blocks": int},
}

_V2_EVENTS = ("cache_hit", "cache_miss", "page_share", "cow_copy",
              "cache_evict")

# v1, frozen: no prefix-cache events, no trace_start.prefix_cache field.
EVENT_SCHEMA_V1: Dict[str, Dict[str, type]] = {
    ev: dict(fields) for ev, fields in EVENT_SCHEMA.items()
    if ev not in _V2_EVENTS}
EVENT_SCHEMA_V1["trace_start"] = {
    k: v for k, v in EVENT_SCHEMA["trace_start"].items()
    if k != "?prefix_cache"}

SUPPORTED_SCHEMAS: Dict[int, Dict[str, Dict[str, type]]] = {
    1: EVENT_SCHEMA_V1, 2: EVENT_SCHEMA}


def sanitize(obj: Any) -> Any:
    """Recursively replace non-finite floats with ``None`` (JSON has no
    NaN/Infinity; the non-strict tokens Python emits by default are
    rejected by every compliant parser)."""
    if isinstance(obj, float):
        return obj if math.isfinite(obj) else None
    if isinstance(obj, dict):
        return {k: sanitize(v) for k, v in obj.items()}
    if isinstance(obj, (list, tuple)):
        return [sanitize(v) for v in obj]
    return obj


def strict_dumps(obj: Any, **kw) -> str:
    """``json.dumps`` with non-finite floats as ``null`` — never the
    non-strict ``NaN``/``Infinity`` tokens."""
    return json.dumps(sanitize(obj), allow_nan=False, **kw)


def _reject_constant(tok: str):
    raise ValueError(
        f"non-strict JSON token {tok!r} (NaN/Infinity must be serialized "
        "as null — see repro.serving.obs.events.sanitize)")


def strict_loads(s: str) -> Any:
    """``json.loads`` rejecting the non-strict ``NaN``/``Infinity`` tokens."""
    return json.loads(s, parse_constant=_reject_constant)


def _type_ok(value: Any, spec: type) -> bool:
    if spec is float:
        # JSON has one number type; None is a sanitized non-finite float
        return value is None or (isinstance(value, (int, float))
                                 and not isinstance(value, bool))
    if spec is int:
        return isinstance(value, int) and not isinstance(value, bool)
    return isinstance(value, spec)


def validate_event(event: Dict[str, Any], version: int = SCHEMA_VERSION,
                   ) -> None:
    """Raise ``ValueError`` unless ``event`` conforms to the schema of
    ``version`` (the current one by default — what the tracer enforces
    at emit time)."""
    schema = SUPPORTED_SCHEMAS[version]
    ev = event.get("ev")
    if ev not in schema:
        raise ValueError(f"unknown event type {ev!r} (schema v{version})")
    if not _type_ok(event.get("ts"), float) or event.get("ts") is None:
        raise ValueError(f"{ev}: missing/invalid ts: {event.get('ts')!r}")
    fields = schema[ev]
    known = {"ev", "ts"}
    for name, spec in fields.items():
        optional = name.startswith("?")
        name = name[1:] if optional else name
        known.add(name)
        if name not in event:
            if optional:
                continue
            raise ValueError(f"{ev}: missing field {name!r}")
        if not _type_ok(event[name], spec):
            raise ValueError(
                f"{ev}: field {name!r} expected {spec.__name__}, got "
                f"{event[name]!r}")
    extra = set(event) - known
    if extra:
        raise ValueError(f"{ev}: unknown fields {sorted(extra)}")


def validate_jsonl(lines: Iterable[str]) -> List[Dict[str, Any]]:
    """Validate a trace (an iterable of JSONL lines); returns the parsed
    events.  The first event must be a ``trace_start`` carrying a known
    schema version; every event then validates against **that** version's
    schema — a v1 trace stays valid, a v1 trace containing v2-only
    events does not.  Parsing is strict (no NaN tokens)."""
    events = []
    for i, line in enumerate(lines):
        if not line.strip():
            continue
        try:
            event = strict_loads(line)
        except ValueError as e:
            raise ValueError(f"line {i + 1}: {e}") from None
        events.append(event)
    if not events:
        raise ValueError("empty trace")
    head = events[0]
    if head.get("ev") != "trace_start":
        raise ValueError(
            f"trace must open with trace_start, got {head.get('ev')!r}")
    version = head.get("schema")
    if version not in SUPPORTED_SCHEMAS:
        raise ValueError(
            f"unsupported trace schema {version} (this reader "
            f"understands {sorted(SUPPORTED_SCHEMAS)})")
    for event in events:
        validate_event(event, version=version)
    return events
