"""Trace + artifact validator CLI (the contract CI pins).

Usage::

    python -m repro.serving.obs.validate TRACE.jsonl \
        [--json artifact.json ...] [--perfetto out.trace.json]

* ``TRACE.jsonl`` — validated line by line against the strict event
  schema (version handshake, field presence/types, no unknown fields,
  no non-strict NaN/Infinity tokens); prints an event-count summary.
* ``--json FILE`` (repeatable) — the file must parse as **strict** JSON
  (``NaN``/``Infinity`` tokens are rejected; a metrics or bench artifact
  containing them would break every compliant consumer).
* ``--perfetto OUT`` — additionally export the trace to Chrome
  trace-event JSON loadable at https://ui.perfetto.dev.

Exit status 0 iff every check passes.
"""

from __future__ import annotations

import argparse
import sys
from collections import Counter
from typing import List, Optional

from repro.serving.obs import events as ev
from repro.serving.obs import perfetto


def main(argv: Optional[List[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.serving.obs.validate", description=__doc__,
        formatter_class=argparse.RawDescriptionHelpFormatter)
    ap.add_argument("trace", nargs="?", default=None,
                    help="serving trace (JSONL) to validate")
    ap.add_argument("--json", action="append", default=[], metavar="FILE",
                    help="artifact that must parse as strict JSON "
                         "(repeatable)")
    ap.add_argument("--perfetto", default=None, metavar="OUT",
                    help="also export the trace to Chrome trace-event "
                         "JSON")
    args = ap.parse_args(argv)
    if args.trace is None and not args.json:
        ap.error("nothing to validate: give a trace and/or --json files")
    if args.perfetto and not args.trace:
        ap.error("--perfetto needs a trace")

    failed = False
    if args.trace:
        try:
            with open(args.trace) as f:
                events = ev.validate_jsonl(f)
        except (OSError, ValueError) as e:
            print(f"FAIL {args.trace}: {e}", file=sys.stderr)
            return 1
        counts = Counter(e["ev"] for e in events)
        summary = " ".join(f"{k}={counts[k]}" for k in sorted(counts))
        print(f"OK {args.trace}: {len(events)} events "
              f"(schema v{events[0]['schema']}) {summary}")
        if args.perfetto:
            trace = perfetto.write_chrome_trace(events, args.perfetto)
            print(f"OK {args.perfetto}: {len(trace['traceEvents'])} "
                  "trace events")
    for path in args.json:
        try:
            with open(path) as f:
                ev.strict_loads(f.read())
            print(f"OK {path}: strict JSON")
        except (OSError, ValueError) as e:
            print(f"FAIL {path}: {e}", file=sys.stderr)
            failed = True
    return 1 if failed else 0


if __name__ == "__main__":
    sys.exit(main())
