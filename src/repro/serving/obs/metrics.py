"""Streaming metrics registry: counters, gauges, log-bucket histograms.

Pure host Python (jax-free), cheap enough to stay always-on in the
serving hot loop: one counter increment is a dict lookup + integer add,
one histogram record is a ``math.log`` + dict increment.

Histograms are **log-bucketed**: values land in geometric buckets
``growth^i``, so p50/p99 stream without retaining samples, with relative
error bounded by ``growth - 1`` (default 5%).  The serving engine's
run-scoped timing histograms additionally keep exact samples
(``exact=True`` — bounded by tokens-per-run), so end-of-run
:class:`~repro.serving.engine.ServeMetrics` percentiles are derived from
the registry yet byte-identical to a direct ``np.percentile`` over the
recorded series — live metrics and the end-of-run aggregate cannot
disagree.

Exposition: :meth:`Registry.prometheus_text` (text format 0.0.4) and
:meth:`Registry.snapshot` (strict JSON — NaN never appears; see
:mod:`repro.serving.obs.events`).
"""

from __future__ import annotations

import math
from typing import Dict, List, Optional, Tuple

import numpy as np

__all__ = ["Counter", "Gauge", "Histogram", "Registry"]

LabelKey = Tuple[Tuple[str, str], ...]


def _labels_key(labels: Dict[str, str]) -> LabelKey:
    return tuple(sorted((k, str(v)) for k, v in labels.items()))


def _labels_str(key: LabelKey) -> str:
    if not key:
        return ""
    return "{" + ",".join(f'{k}="{v}"' for k, v in key) + "}"


class Counter:
    """Monotonic counter."""

    kind = "counter"

    def __init__(self):
        self.value = 0

    def inc(self, n: int = 1) -> None:
        if n < 0:
            raise ValueError(f"counter increment must be >= 0, got {n}")
        self.value += n

    def to_json(self):
        return self.value


class Gauge:
    """Last-write-wins instantaneous value."""

    kind = "gauge"

    def __init__(self):
        self.value = 0.0

    def set(self, v: float) -> None:
        self.value = v

    def to_json(self):
        return self.value


class Histogram:
    """Log-bucket streaming histogram (positive values).

    Bucket ``i`` covers ``(growth^(i-1), growth^i]``; zero and negative
    values land in a dedicated underflow bucket.  ``percentile`` walks
    the cumulative counts and answers with the bucket's geometric
    midpoint — relative error ≤ ``growth - 1`` — while count/sum/min/max
    are tracked exactly.  ``exact=True`` additionally retains the raw
    samples for :meth:`percentile_exact` / :meth:`mean_exact` (use only
    for run-bounded series)."""

    kind = "histogram"

    def __init__(self, growth: float = 1.05, exact: bool = False):
        assert growth > 1.0, growth
        self.growth = growth
        self._log_growth = math.log(growth)
        self.buckets: Dict[int, int] = {}   # bucket index -> count
        self.underflow = 0                  # values <= 0
        self.count = 0
        self.total = 0.0
        self.vmin = math.inf
        self.vmax = -math.inf
        self.samples: Optional[List[float]] = [] if exact else None

    def record(self, v: float) -> None:
        v = float(v)
        self.count += 1
        self.total += v
        self.vmin = min(self.vmin, v)
        self.vmax = max(self.vmax, v)
        if v <= 0.0:
            self.underflow += 1
        else:
            i = math.ceil(math.log(v) / self._log_growth)
            self.buckets[i] = self.buckets.get(i, 0) + 1
        if self.samples is not None:
            self.samples.append(v)

    # ---- streaming estimates (no samples retained) -----------------------
    def percentile(self, q: float) -> float:
        """Nearest-rank percentile from the log buckets (NaN if empty)."""
        if self.count == 0:
            return float("nan")
        rank = max(1, math.ceil(q / 100.0 * self.count))
        if rank <= self.underflow:
            return min(self.vmin, 0.0)
        seen = self.underflow
        for i in sorted(self.buckets):
            seen += self.buckets[i]
            if seen >= rank:
                # geometric midpoint of (growth^(i-1), growth^i],
                # clamped into the exactly-tracked value range
                mid = self.growth ** (i - 0.5)
                return min(max(mid, self.vmin), self.vmax)
        return self.vmax

    # ---- exact views (exact=True only) -----------------------------------
    def percentile_exact(self, q: float) -> float:
        assert self.samples is not None, "histogram not exact"
        return float(np.percentile(np.asarray(self.samples), q)) \
            if self.samples else float("nan")

    def mean_exact(self) -> float:
        assert self.samples is not None, "histogram not exact"
        return float(np.mean(self.samples)) if self.samples \
            else float("nan")

    def max_exact(self) -> float:
        assert self.samples is not None, "histogram not exact"
        return max(self.samples) if self.samples else float("nan")

    def to_json(self):
        return {
            "count": self.count,
            "sum": self.total,
            "min": self.vmin if self.count else None,
            "max": self.vmax if self.count else None,
            "p50": self.percentile(50) if self.count else None,
            "p99": self.percentile(99) if self.count else None,
        }

    def prometheus_buckets(self):
        """Cumulative ``(le, count)`` pairs for text exposition."""
        out = []
        cum = self.underflow
        if self.underflow:
            out.append((0.0, cum))
        for i in sorted(self.buckets):
            cum += self.buckets[i]
            out.append((self.growth ** i, cum))
        out.append((math.inf, self.count))
        return out


class Registry:
    """Named instrument registry with labels.

    ``counter/gauge/histogram(name, **labels)`` create-or-return the
    instrument for that (name, labels) pair; all instruments under one
    name must share a kind.  One registry instance covers one engine run
    (the engine creates a fresh one per ``run()``), so snapshots are
    run-scoped like :class:`~repro.serving.engine.ServeMetrics`."""

    def __init__(self):
        # name -> (kind, {labels_key -> instrument})
        self._families: Dict[str, Tuple[str, Dict[LabelKey, object]]] = {}

    def _get(self, name: str, factory, labels: Dict[str, str]):
        key = _labels_key(labels)
        fam = self._families.get(name)
        if fam is None:
            inst = factory()
            self._families[name] = (inst.kind, {key: inst})
            return inst
        kind, children = fam
        inst = children.get(key)
        if inst is None:
            inst = factory()
            if inst.kind != kind:
                raise ValueError(
                    f"{name} is a {kind}, not a {inst.kind}")
            children[key] = inst
        return inst

    def counter(self, name: str, **labels) -> Counter:
        return self._get(name, Counter, labels)

    def gauge(self, name: str, **labels) -> Gauge:
        return self._get(name, Gauge, labels)

    def histogram(self, name: str, *, growth: float = 1.05,
                  exact: bool = False, **labels) -> Histogram:
        return self._get(
            name, lambda: Histogram(growth=growth, exact=exact), labels)

    def value(self, name: str) -> float:
        """Sum of a counter/gauge family across labels (0 if absent)."""
        fam = self._families.get(name)
        if fam is None:
            return 0
        return sum(inst.value for inst in fam[1].values())

    def get(self, name: str, **labels):
        """The existing instrument, or None."""
        fam = self._families.get(name)
        return None if fam is None else fam[1].get(_labels_key(labels))

    # ---- exposition ------------------------------------------------------
    def snapshot(self) -> Dict:
        """Strict-JSON-safe nested dict of every instrument."""
        from repro.serving.obs.events import sanitize
        out = {}
        for name, (kind, children) in sorted(self._families.items()):
            fam = {}
            for key, inst in sorted(children.items()):
                fam[_labels_str(key) or "_"] = inst.to_json()
            out[name] = {"kind": kind, "values": fam}
        return sanitize(out)

    def prometheus_text(self) -> str:
        """Prometheus text exposition format 0.0.4."""
        lines = []
        for name, (kind, children) in sorted(self._families.items()):
            lines.append(f"# TYPE {name} {kind}")
            for key, inst in sorted(children.items()):
                ls = _labels_str(key)
                if kind in ("counter", "gauge"):
                    lines.append(f"{name}{ls} {_fmt(inst.value)}")
                    continue
                for le, cum in inst.prometheus_buckets():
                    le_s = "+Inf" if math.isinf(le) else _fmt(le)
                    blabels = dict(key)
                    blabels["le"] = le_s
                    lines.append(
                        f"{name}_bucket{_labels_str(_labels_key(blabels))}"
                        f" {cum}")
                lines.append(f"{name}_sum{ls} {_fmt(inst.total)}")
                lines.append(f"{name}_count{ls} {inst.count}")
        return "\n".join(lines) + "\n"


def _fmt(v) -> str:
    if isinstance(v, float) and not math.isfinite(v):
        # prometheus text allows +Inf/-Inf/NaN spellings
        return "+Inf" if v == math.inf else ("-Inf" if v == -math.inf
                                             else "NaN")
    return repr(v) if isinstance(v, float) else str(v)
