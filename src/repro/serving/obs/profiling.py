"""Profiler hooks: ``jax.profiler`` trace capture around K engine steps.

``--profile-dir`` wires a :class:`Profiler` into the engine loop: the
capture starts at iteration ``start_step``, runs for ``steps``
iterations, and stops (also force-stopped at run end if the run is
shorter).  Each jitted dispatch inside the window is wrapped in a
``jax.profiler.TraceAnnotation`` named after the step kind
(``mixed``/``decode``/``probe``), so the timeline in TensorBoard /
Perfetto attributes device time to engine phases.

Start/stop are mirrored into the event trace (``profile_start`` /
``profile_stop``) so the JSONL timeline and the profiler window can be
aligned.  Outside the window :meth:`annotate` is a null context —
profiling adds nothing to un-profiled steps.
"""

from __future__ import annotations

import contextlib
from typing import Optional

import jax

__all__ = ["Profiler"]


class Profiler:
    """Window-of-K-steps ``jax.profiler`` capture for the engine loop."""

    def __init__(self, profile_dir: str, steps: int = 20,
                 start_step: int = 0):
        assert steps > 0, steps
        self.profile_dir = profile_dir
        self.steps = int(steps)
        self.start_step = int(start_step)
        self.active = False
        self._done = False                  # one window per run

    def maybe_start(self, iteration: int, tracer=None) -> None:
        if self._done or self.active or iteration < self.start_step:
            return
        jax.profiler.start_trace(self.profile_dir)
        self.active = True
        self._stop_at = iteration + self.steps
        if tracer is not None:
            tracer.emit("profile_start", dir=self.profile_dir,
                        steps=self.steps)

    def maybe_stop(self, iteration: int, tracer=None) -> None:
        """Stop after the window's last step has dispatched (called with
        the next iteration number)."""
        if self.active and iteration >= self._stop_at:
            self.stop(tracer)

    def stop(self, tracer=None) -> None:
        """Force-stop (run end); idempotent."""
        if not self.active:
            return
        jax.profiler.stop_trace()
        self.active = False
        self._done = True
        if tracer is not None:
            tracer.emit("profile_stop", dir=self.profile_dir)

    def annotate(self, name: str):
        """Named trace annotation inside the window, null context outside."""
        if self.active:
            return jax.profiler.TraceAnnotation(name)
        return contextlib.nullcontext()
