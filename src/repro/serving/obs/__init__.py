"""Serving observability: event tracing, metrics, probes, profiling.

Four decoupled pieces (see ``serving/README.md`` for the operator view):

* :mod:`~repro.serving.obs.events` + :mod:`~repro.serving.obs.tracing` —
  versioned, strictly-validated JSONL event trace of request lifecycles
  and per-iteration step records;
* :mod:`~repro.serving.obs.metrics` — streaming counters / gauges /
  log-bucket histograms with Prometheus text and strict-JSON snapshot
  exposition (always on: the engine derives its end-of-run
  ``ServeMetrics`` from this registry);
* :mod:`~repro.serving.obs.probe` — sampled SOCKET selection-quality
  probe (recall vs dense top-k, budget utilization, forced share);
* :mod:`~repro.serving.obs.profiling` — ``jax.profiler`` capture around
  a window of engine steps.

:class:`Observability` bundles the opt-in pieces.  The engine takes
``obs=None`` by default and then holds **no tracer, no probe and no
profiler at all** — the disabled hot loop allocates zero tracing objects
per step (pinned by ``tests/test_observability.py``).
"""

from __future__ import annotations

from typing import Optional

from repro.serving.obs.events import (EVENT_SCHEMA, SCHEMA_VERSION, sanitize,
                                      strict_dumps, strict_loads,
                                      validate_event, validate_jsonl)
from repro.serving.obs.metrics import Counter, Gauge, Histogram, Registry
from repro.serving.obs.perfetto import chrome_trace, write_chrome_trace
from repro.serving.obs.probe import SelectionProbe
from repro.serving.obs.profiling import Profiler
from repro.serving.obs.tracing import Tracer

__all__ = ["Observability", "Tracer", "Registry", "Counter", "Gauge",
           "Histogram", "SelectionProbe", "Profiler", "chrome_trace",
           "write_chrome_trace", "validate_event", "validate_jsonl",
           "sanitize", "strict_dumps", "strict_loads", "EVENT_SCHEMA",
           "SCHEMA_VERSION", "warn_once"]

_WARNED: set = set()


def warn_once(key: str, message: str) -> None:
    """Emit ``message`` as a :class:`UserWarning` the first time ``key``
    is seen in this process — for hot-path fallbacks (a jitted serving
    step that silently reroutes should say so exactly once, not per
    step)."""
    if key in _WARNED:
        return
    _WARNED.add(key)
    import warnings
    warnings.warn(message, UserWarning, stacklevel=3)


class Observability:
    """Opt-in observability bundle handed to the serving engine.

    Constructing one enables tracing; the pieces are individually
    optional on top:

    * ``trace_path`` — stream the event trace to a JSONL file (events
      are always kept in memory on ``tracer.events``);
    * ``probe_every`` — sample the selection-quality probe every N
      engine iterations (0 = never);
    * ``profile_dir`` — capture a ``jax.profiler`` trace of
      ``profile_steps`` iterations starting at ``profile_start_step``.
    """

    def __init__(self, trace_path: Optional[str] = None, *,
                 probe_every: int = 0,
                 profile_dir: Optional[str] = None,
                 profile_steps: int = 20,
                 profile_start_step: int = 0):
        self.tracer = Tracer(trace_path)
        self.probe = SelectionProbe(every=probe_every)
        self.profiler = Profiler(
            profile_dir, steps=profile_steps,
            start_step=profile_start_step) if profile_dir else None

    def probe_summary(self):
        return self.probe.summary()

    def close(self) -> None:
        self.tracer.close()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()
