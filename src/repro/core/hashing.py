"""SimHash (signed-random-projection) machinery shared by SOCKET and hard LSH.

Implements Algorithm 1 of the paper (PrecomputeKeyHashes): every key vector
is projected by ``L`` independent tables of ``P`` Gaussian hyperplanes and
reduced to its sign pattern.  The sign pattern *is* the bucket id
(``R = 2**P`` buckets per table).  We keep two physical encodings:

* ``signs``      — boolean ``(..., N, L, P)`` tensor (test/oracle friendly),
* ``packed``     — ``uint32 (..., N, W)`` bit-packed words, ``W = ceil(L*P/32)``
                   — 600 bits/token for the paper's (P=10, L=60) setting.

The packed form is the deployment format: it is what the Pallas scoring
kernel streams from HBM and what the KV cache stores alongside K/V.
"""

from __future__ import annotations

import dataclasses
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

__all__ = [
    "HashParams",
    "make_hash_params",
    "hash_keys_signs",
    "signs_to_bucket_ids",
    "pack_signs",
    "unpack_signs",
    "num_words",
    "hypercube_corners",
]


@dataclasses.dataclass(frozen=True)
class HashParams:
    """Static description of an LSH ensemble."""

    num_planes: int  # P
    num_tables: int  # L

    @property
    def num_buckets(self) -> int:  # R
        return 1 << self.num_planes

    @property
    def bits_per_token(self) -> int:
        return self.num_planes * self.num_tables

    @property
    def words_per_token(self) -> int:
        return num_words(self.num_tables, self.num_planes)


def num_words(num_tables: int, num_planes: int) -> int:
    """uint32 words storing one token's hash bits.

    Rounded up so that ``W*32`` is a multiple of ``P`` — the Pallas scoring
    kernel views the unpacked bits as (W*32/P) padded tables, which keeps
    the in-kernel layout reshape-free (padding tables are neutralised with
    logZ=+inf).  For the paper's (P=10, L=60) this stores 640 bits/token
    (600 useful + 40 alignment), still ~3.2x below the 2048 bits of a bf16
    key.
    """
    w = (num_tables * num_planes + 31) // 32
    while (w * 32) % num_planes:
        w += 1
    return w


def make_hash_params(key: jax.Array, d: int, num_planes: int, num_tables: int,
                     dtype=jnp.float32) -> jax.Array:
    """Sample the Gaussian hyperplanes ``W`` with shape ``(L, P, d)``.

    These are *data-agnostic* (the paper's central design point): no
    calibration pass, no k-means — index build cost is one RNG call, which
    is why SOCKET's TTFT beats clustering-based baselines (paper fig. 3a).
    """
    w = jax.random.normal(key, (num_tables, num_planes, d), dtype=jnp.float32)
    return w.astype(dtype)


def hash_keys_signs(w: jax.Array, keys: jax.Array) -> jax.Array:
    """Algorithm 1 line 6: ``sign(W^(l) k_j)`` for every key and table.

    Args:
      w:    ``(L, P, d)`` hyperplanes.
      keys: ``(..., N, d)`` key vectors.

    Returns:
      boolean ``(..., N, L, P)`` — True where the projection is >= 0.
    """
    # (..., N, d) x (L, P, d) -> (..., N, L, P)
    proj = jnp.einsum("...nd,lpd->...nlp", keys.astype(jnp.float32),
                      w.astype(jnp.float32))
    return proj >= 0.0


def signs_to_bucket_ids(signs: jax.Array) -> jax.Array:
    """Encode per-table sign patterns as integer bucket ids in ``[0, 2**P)``.

    Bit i of the bucket id is sign bit of plane i (LSB = plane 0).
    """
    p = signs.shape[-1]
    if p > 31:
        raise ValueError(f"P={p} too large for int32 bucket ids")
    weights = (1 << np.arange(p)).astype(np.int32)
    return jnp.sum(signs.astype(jnp.int32) * weights, axis=-1)


def pack_signs(signs: jax.Array) -> jax.Array:
    """Pack boolean ``(..., N, L, P)`` into ``uint32 (..., N, W)``.

    Bit layout: flatten (L, P) row-major (table-major, plane-minor), pad to a
    multiple of 32 with zeros, then bit ``b`` of word ``w`` stores flat bit
    ``w*32 + b``.  The layout is mirrored exactly by :func:`unpack_signs` and
    by the Pallas kernel's in-register unpack.
    """
    *lead, n, l, p = signs.shape
    flat = signs.reshape(*lead, n, l * p)
    w = num_words(l, p)
    pad = w * 32 - l * p
    if pad:
        flat = jnp.pad(flat, [(0, 0)] * (flat.ndim - 1) + [(0, pad)])
    grouped = flat.reshape(*lead, n, w, 32).astype(jnp.uint32)
    shifts = jnp.arange(32, dtype=jnp.uint32)
    return jnp.sum(grouped << shifts, axis=-1).astype(jnp.uint32)


def unpack_signs(packed: jax.Array, num_tables: int, num_planes: int,
                 dtype=jnp.float32) -> jax.Array:
    """Inverse of :func:`pack_signs`, returning ±1 values.

    Args:
      packed: ``uint32 (..., N, W)``.

    Returns:
      ``(..., N, L, P)`` in ``dtype`` with values in {-1, +1}.
    """
    *lead, n, w = packed.shape
    shifts = jnp.arange(32, dtype=jnp.uint32)
    bits = (packed[..., None] >> shifts) & jnp.uint32(1)  # (..., N, W, 32)
    flat = bits.reshape(*lead, n, w * 32)[..., : num_tables * num_planes]
    signs = flat.astype(dtype) * 2.0 - 1.0
    return signs.reshape(*lead, n, num_tables, num_planes)


def hypercube_corners(num_planes: int) -> np.ndarray:
    """All ``R = 2**P`` corners ``c_r in {-1, +1}^P`` (bit i of r = plane i).

    Only used by the oracle (explicit-softmax) scoring path and tests —
    the production path never materializes the corner set thanks to the
    product factorization (DESIGN.md §2).
    """
    r = 1 << num_planes
    ids = np.arange(r)[:, None]
    planes = np.arange(num_planes)[None, :]
    bits = (ids >> planes) & 1
    return (bits * 2 - 1).astype(np.float32)
