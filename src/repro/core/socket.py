"""SOCKET: soft collision kernel estimation for sparse attention.

Implements Algorithms 1-3 of the paper:

* :func:`precompute_key_hashes`   — Algorithm 1 (prefill-time index build).
* :func:`soft_hash_query`         — Algorithm 2 (query soft hashing).
* :func:`soft_scores_factorized`  — the production scoring path (exact
  algebraic rewrite of eq. (3); see DESIGN.md §2).
* :func:`soft_scores_gather`      — the paper's literal LUT-gather
  formulation (oracle; used for tests and GPU-parity checks).
* :func:`value_aware_topk`        — Algorithm 3 selection (value-norm
  weighted, with sink + local-window union).
* :func:`sparse_attention_over_subset` — exact softmax attention over the
  selected subset (Algorithm 3 lines 6-7).
* :func:`socket_attend`           — the full decode-time composition.

Shapes use the cache layout ``(B, KVH, S, ...)``; queries are
``(B, KVH, G, qlen, hd)`` where ``G`` is the GQA group size (q heads per
KV head).  Everything is jit/pjit-friendly (static shapes; masking instead
of dynamic slicing).
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import hashing

__all__ = [
    "SocketConfig",
    "SocketCache",
    "precompute_key_hashes",
    "soft_hash_query",
    "log_normalizer",
    "bucket_probs_explicit",
    "soft_scores_gather",
    "soft_scores_factorized",
    "value_aware_topk",
    "per_batch",
    "sparse_attention_over_subset",
    "socket_attend",
    "topk_budget",
    "dynamic_topk_budget",
]

NEG_INF = -1e30


@dataclasses.dataclass(frozen=True)
class SocketConfig:
    """Hyper-parameters of the SOCKET scorer (paper Table 13 defaults)."""

    num_planes: int = 10          # P
    num_tables: int = 60          # L
    tau: float = 0.5              # soft-hash temperature
    sparsity: float = 10.0        # N / k  (k = budget)
    sink_tokens: int = 128        # always-attended prefix tokens
    window_tokens: int = 128      # always-attended local window
    min_k: int = 16               # floor for the top-k budget
    selection: str = "kvhead"     # "kvhead" | "qhead" (DESIGN.md §7.4)
    bits_storage: str = "packed"  # "packed" (uint32 words) | "int8" (±1)
    score_dtype: str = "float32"
    # XLA-path scoring chunk (keys per scan step); bounds the live unpacked
    # sign buffer at long context (0 = unchunked).  The Pallas kernel
    # streams blocks natively and ignores this.
    score_chunk: int = 0

    @property
    def hash_params(self) -> hashing.HashParams:
        return hashing.HashParams(self.num_planes, self.num_tables)

    @property
    def bits_per_token(self) -> int:
        return self.num_planes * self.num_tables

    def replace(self, **kw) -> "SocketConfig":
        return dataclasses.replace(self, **kw)


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class SocketCache:
    """Per-layer SOCKET side-cache living next to the KV cache.

    ``bits``   — ``uint32 (B, KVH, S, W)`` packed sign bits (or
                 ``int8 (B, KVH, S, L*P)`` when ``bits_storage == 'int8'``).
    ``vnorm``  — ``(B, KVH, S)`` value L2 norms (bf16 in deployment).
    """

    bits: jax.Array
    vnorm: jax.Array

    def tree_flatten(self):
        return (self.bits, self.vnorm), None

    @classmethod
    def tree_unflatten(cls, aux, children):
        del aux
        return cls(*children)


def topk_budget(cfg: SocketConfig, n: int) -> int:
    """Selection budget k for a context of length n (static python int).

    Floored at the forced sink+window count: those tokens are *always*
    attended (paper §6), so a budget smaller than their count would
    silently evict the recency window (forced ties sort by index, keeping
    only the prefix sinks) — at deployment settings (sink=window=128,
    sparsity=10) that used to happen for every context under 2560 tokens.
    """
    forced = min(n, cfg.sink_tokens + cfg.window_tokens)
    k = max(cfg.min_k, forced, int(np.ceil(n / cfg.sparsity)))
    return min(k, n)


def dynamic_topk_budget(cfg: SocketConfig, length: jax.Array,
                        cap: int) -> jax.Array:
    """Traced per-request budget for a ragged batch: ``ceil(len/sparsity)``
    with the same ``min_k`` and forced sink+window floors as
    :func:`topk_budget`, clamped to the static selection size ``cap``
    (``cap = topk_budget(cfg, n_view)`` guarantees the floors fit)."""
    length = jnp.asarray(length, jnp.int32)
    forced = jnp.minimum(length, cfg.sink_tokens + cfg.window_tokens)
    k = jnp.maximum(
        jnp.ceil(length.astype(jnp.float32) /
                 cfg.sparsity).astype(jnp.int32), forced)
    return jnp.clip(k, cfg.min_k, cap)


# ---------------------------------------------------------------------------
# Algorithm 1 — prefill
# ---------------------------------------------------------------------------

def precompute_key_hashes(cfg: SocketConfig, w: jax.Array, keys: jax.Array,
                          values: jax.Array) -> SocketCache:
    """Build the SOCKET side-cache for freshly computed keys/values.

    Args:
      w:      ``(L, P, d)`` hyperplanes (per layer; data-agnostic).
      keys:   ``(B, KVH, S, d)``.
      values: ``(B, KVH, S, d)``.
    """
    signs = hashing.hash_keys_signs(w, keys)          # (B,KVH,S,L,P) bool
    if cfg.bits_storage == "packed":
        bits = hashing.pack_signs(signs)              # (B,KVH,S,W) uint32
    elif cfg.bits_storage == "int8":
        bits = (signs.astype(jnp.int8) * 2 - 1).reshape(
            *signs.shape[:-2], cfg.num_tables * cfg.num_planes)
    else:
        raise ValueError(cfg.bits_storage)
    vnorm = jnp.linalg.norm(values.astype(jnp.float32), axis=-1)
    return SocketCache(bits=bits, vnorm=vnorm.astype(jnp.bfloat16))


# ---------------------------------------------------------------------------
# Algorithm 2 — query soft hashing
# ---------------------------------------------------------------------------

def soft_hash_query(w: jax.Array, q: jax.Array) -> jax.Array:
    """``u^(l) = tanh(W^(l) q) / sqrt(d)`` — Algorithm 2 line 3.

    Args:
      w: ``(L, P, d)``; q: ``(..., d)``.

    Returns:
      ``(..., L, P)`` float32.
    """
    d = q.shape[-1]
    proj = jnp.einsum("...d,lpd->...lp", q.astype(jnp.float32),
                      w.astype(jnp.float32))
    return jnp.tanh(proj) / jnp.sqrt(jnp.float32(d))


def log_normalizer(u: jax.Array, tau: float) -> jax.Array:
    """``log Z^(l) = sum_i log(2 cosh(u_i / tau))`` (DESIGN.md §2).

    Numerically stable form: ``log(2cosh(x)) = |x| + log1p(exp(-2|x|))``.
    """
    x = u / tau
    ax = jnp.abs(x)
    return jnp.sum(ax + jnp.log1p(jnp.exp(-2.0 * ax)), axis=-1)


def bucket_probs_explicit(u: jax.Array, tau: float) -> jax.Array:
    """Explicit softmax over all ``R = 2**P`` corners (Algorithm 2 lines 4-7).

    O(L * 2^P) memory — oracle/GPU-parity path only.

    Args:
      u: ``(..., L, P)``.
    Returns:
      ``(..., L, R)`` probabilities.
    """
    p = u.shape[-1]
    corners = jnp.asarray(hashing.hypercube_corners(p))   # (R, P)
    logits = jnp.einsum("...lp,rp->...lr", u, corners) / tau
    return jax.nn.softmax(logits, axis=-1)


# ---------------------------------------------------------------------------
# Scoring — eq. (3), two equivalent forms
# ---------------------------------------------------------------------------

def soft_scores_gather(bucket_ids: jax.Array, probs: jax.Array) -> jax.Array:
    """Paper-literal scoring: gather each key's bucket probability per table.

    Args:
      bucket_ids: ``(..., N, L)`` int32 in [0, R).
      probs:      ``(..., L, R)`` soft bucket distribution for the query.

    Returns:
      ``(..., N)`` soft collision scores  ``s_soft = sum_l p(b_j^l | q)``.
    """
    picked = jnp.take_along_axis(
        probs[..., None, :, :],                       # (...,1,L,R)
        bucket_ids[..., :, :, None],                  # (...,N,L,1)
        axis=-1,
    )[..., 0]                                         # (...,N,L)
    return jnp.sum(picked, axis=-1)


def _score_block(cfg: SocketConfig, bits: jax.Array, u: jax.Array,
                 logz: jax.Array) -> jax.Array:
    l, p = cfg.num_tables, cfg.num_planes
    sdt = jnp.dtype(cfg.score_dtype)   # bf16 halves the unpacked-sign
    # buffer at long context; fp32 (default) is exact for small tau
    if cfg.bits_storage == "packed":
        signs = hashing.unpack_signs(bits, l, p, dtype=sdt)
    else:
        signs = bits.reshape(*bits.shape[:-1], l, p).astype(sdt)
    logits = jnp.einsum("...nlp,...lp->...nl", signs,
                        u.astype(sdt),
                        preferred_element_type=jnp.float32) / cfg.tau
    z = jnp.exp(logits - logz[..., None, :])          # (..., N, L)
    return jnp.sum(z, axis=-1)


def soft_scores_factorized(cfg: SocketConfig, bits: jax.Array,
                           u: jax.Array) -> jax.Array:
    """Production scoring path — exact rewrite of the corner softmax.

    ``score_j = sum_l exp( (S_j^(l) . u^(l)) / tau  -  logZ^(l) )``

    where ``S`` are the stored ±1 sign bits.  This replaces the GPU gather
    with a dense ±1 contraction (DESIGN.md §2).  The Pallas kernel
    (kernels/socket_score) computes the same expression with streaming
    bit-unpack; this jnp version is the XLA fallback / dry-run path.

    When ``cfg.score_chunk`` divides N, keys are scored under ``lax.scan``
    in chunks so the live unpacked-sign buffer stays bounded at long
    context (scores are per-key independent, so chunking is exact).

    Args:
      bits: packed ``uint32 (..., N, W)`` or int8 ``(..., N, L*P)``.
      u:    ``(..., L, P)`` query soft hash (see :func:`soft_hash_query`).

    Returns:
      ``(..., N)`` float32 scores (identical to :func:`soft_scores_gather`).
    """
    logz = log_normalizer(u, cfg.tau)                 # (..., L)
    n = bits.shape[-2]
    c = cfg.score_chunk
    if c and n > c and n % c == 0:
        nc = n // c
        blocks = jnp.moveaxis(
            bits.reshape(*bits.shape[:-2], nc, c, bits.shape[-1]), -3, 0)

        def body(_, blk):
            return None, _score_block(cfg, blk, u, logz)

        _, out = jax.lax.scan(body, None, blocks)     # (nc, ..., c)
        moved = jnp.moveaxis(out, 0, -2)              # (..., nc, c)
        return moved.reshape(*moved.shape[:-2], n)
    return _score_block(cfg, bits, u, logz)


# ---------------------------------------------------------------------------
# Algorithm 3 — value-aware top-k selection + exact attention on the subset
# ---------------------------------------------------------------------------

def per_batch(x: jax.Array, ndim: int) -> jax.Array:
    """Reshape a ``(B,)`` per-request scalar (e.g. a ragged batch's length
    vector) so it broadcasts against a ``(B, ..., N)`` tensor of rank
    ``ndim``; scalars pass through unchanged."""
    if x.ndim == 1:
        return x.reshape(x.shape[0], *([1] * (ndim - 1)))
    return x


def value_aware_topk(cfg: SocketConfig, scores: jax.Array, vnorm: jax.Array,
                     *, k: int, length: jax.Array | int,
                     n_total: int,
                     budget: Optional[jax.Array] = None,
                     ) -> Tuple[jax.Array, jax.Array]:
    """Select indices of the k keys with largest ``score * ||v||``.

    Sink tokens (prefix) and the trailing local window are force-included by
    overriding their effective score to +inf (standard practice in the
    sparse-attention literature; paper §6 keeps 128 sink+window tokens).
    Invalid (not-yet-written) cache slots are masked to -inf.

    Args:
      scores: ``(..., N)`` soft collision scores.
      vnorm:  ``(..., N)`` value norms.
      k:      static selection budget (includes sink/window).
      length: current valid context length — dynamic scalar, int, or a
              ``(B,)`` vector of per-request lengths (ragged serving batch).
      n_total: static cache capacity N.
      budget: optional dynamic per-request budget ``(B,)`` (or scalar)
              ≤ ``k``; selections ranked past it are masked out.  This is
              how the serving engine applies the paper's ``k = N/sparsity``
              with N = each request's *live* context length while keeping
              the top-k shape static.  Forced sink/window tokens sort
              first (+inf), so they survive any budget ≥ their count.

    Returns:
      (indices ``(..., k)`` int32, validity mask ``(..., k)`` bool).
    """
    pos = jnp.arange(n_total, dtype=jnp.int32)
    length = per_batch(jnp.asarray(length, jnp.int32), scores.ndim)
    valid = pos < length
    eff = scores.astype(jnp.float32) * vnorm.astype(jnp.float32)
    forced = (pos < cfg.sink_tokens) | (pos >= length - cfg.window_tokens)
    eff = jnp.where(forced, jnp.float32(np.finfo(np.float32).max), eff)
    eff = jnp.where(valid, eff, NEG_INF)
    top_vals, top_idx = jax.lax.top_k(eff, k)
    mask = top_vals > NEG_INF / 2
    if budget is not None:
        budget = per_batch(jnp.asarray(budget, jnp.int32), scores.ndim)
        mask = mask & (jnp.arange(k, dtype=jnp.int32) < budget)
    return top_idx.astype(jnp.int32), mask


def sparse_attention_over_subset(q: jax.Array, k_sel: jax.Array,
                                 v_sel: jax.Array, sel_mask: jax.Array,
                                 *, scale: float) -> jax.Array:
    """Exact softmax attention restricted to the selected subset (eq. (2)).

    Args:
      q:      ``(B, KVH, G, T, hd)``  (T = query length, 1 for decode).
      k_sel:  ``(B, KVH, K, hd)`` gathered keys.
      v_sel:  ``(B, KVH, K, hd)`` gathered values.
      sel_mask: ``(B, KVH, K)`` bool validity of each selected slot.
    Returns:
      ``(B, KVH, G, T, hd)``.
    """
    logits = jnp.einsum("bhgtd,bhkd->bhgtk", q.astype(jnp.float32),
                        k_sel.astype(jnp.float32)) * scale
    logits = jnp.where(sel_mask[:, :, None, None, :], logits, NEG_INF)
    w = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhgtk,bhkd->bhgtd", w, v_sel.astype(jnp.float32))
    return out.astype(q.dtype)


def socket_attend(cfg: SocketConfig, w_hash: jax.Array, q: jax.Array,
                  k_cache: jax.Array, v_cache: jax.Array,
                  side: SocketCache, *, length: jax.Array | int,
                  scale: Optional[float] = None,
                  use_kernel: bool = False,
                  budget: Optional[jax.Array] = None) -> jax.Array:
    """Full SOCKET decode attention (Algorithms 2+3) for one new query step.

    Args:
      w_hash:  ``(L, P, d)`` hyperplanes for this layer.
      q:       ``(B, KVH, G, 1, hd)`` query (GQA grouped layout).
      k_cache: ``(B, KVH, N, hd)``; v_cache same.
      side:    SocketCache with bits ``(B, KVH, N, W)`` and vnorm.
      length:  valid prefix length of the cache (scalar or ``(B,)`` for a
               ragged serving batch).
      use_kernel: route scoring through the Pallas kernel (TPU path).
      budget: optional dynamic per-request top-k budget (see
              :func:`value_aware_topk`).

    Returns:
      attention output ``(B, KVH, G, 1, hd)``.
    """
    b, kvh, g, t, hd = q.shape
    n = k_cache.shape[2]
    scale = scale if scale is not None else 1.0 / np.sqrt(hd)
    kq = topk_budget(cfg, n)

    # --- Algorithm 2: soft-hash the query heads --------------------------
    if cfg.selection == "pooled":
        # TPU operating point (DESIGN.md §2): one soft-hash per KV head
        # from the group-mean query — G x less scoring work/memory
        u = soft_hash_query(w_hash, jnp.mean(q[..., 0, :], axis=2))
    else:
        u = soft_hash_query(w_hash, q[..., 0, :])      # (B,KVH,G,L,P)

    # --- scoring (factorized form; optionally the Pallas kernel) --------
    if use_kernel:
        if cfg.selection not in ("kvhead", "pooled"):
            raise NotImplementedError(
                "the Pallas scoring kernel group-sums scores (kvhead "
                "selection); use the XLA path for per-q-head selection")
        from repro.kernels.socket_score import ops as score_ops
        scores = score_ops.socket_score(
            side.bits, u, vnorm=None, num_tables=cfg.num_tables,
            num_planes=cfg.num_planes, tau=cfg.tau)    # (B,KVH,N) (G-summed)
    elif cfg.selection == "pooled":
        scores = soft_scores_factorized(cfg, side.bits, u)  # (B,KVH,N)
    else:
        bits = side.bits[:, :, None]                   # (B,KVH,1,N,·)
        scores = soft_scores_factorized(cfg, bits, u)  # (B,KVH,G,N)

    if cfg.selection in ("kvhead", "pooled"):
        # group-marginal collision mass: sum over the query group's heads.
        if not use_kernel and cfg.selection == "kvhead":
            scores = jnp.sum(scores, axis=2)           # (B,KVH,N)
    elif cfg.selection == "qhead":
        # per-q-head selection: fold G into the head axis for selection,
        # then attention must gather per (kvh, g).  More faithful to the
        # paper's single-head exposition but loses the shared KV gather.
        pass
    else:
        raise ValueError(cfg.selection)

    vnorm = side.vnorm.astype(jnp.float32)
    if cfg.selection in ("kvhead", "pooled"):
        idx, sel_mask = value_aware_topk(
            cfg, scores, vnorm, k=kq, length=length, n_total=n,
            budget=budget)
        k_sel = jnp.take_along_axis(k_cache, idx[..., None], axis=2)
        v_sel = jnp.take_along_axis(v_cache, idx[..., None], axis=2)
        return sparse_attention_over_subset(q, k_sel, v_sel, sel_mask,
                                            scale=scale)

    # per-q-head route
    idx, sel_mask = value_aware_topk(
        cfg, scores, vnorm[:, :, None], k=kq, length=length, n_total=n,
        budget=budget)
    k_sel = jnp.take_along_axis(k_cache[:, :, None], idx[..., None], axis=3)
    v_sel = jnp.take_along_axis(v_cache[:, :, None], idx[..., None], axis=3)
    logits = jnp.einsum("bhgtd,bhgkd->bhgtk", q.astype(jnp.float32),
                        k_sel.astype(jnp.float32)) * scale
    logits = jnp.where(sel_mask[:, :, :, None, :], logits, NEG_INF)
    wts = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhgtk,bhgkd->bhgtd", wts, v_sel.astype(jnp.float32))
    return out.astype(q.dtype)
