"""Theory-side objects from Section 5 of the paper.

These are not used on the inference path; they exist so the test-suite can
validate the paper's theoretical claims numerically:

* angular kernel weights ``w_j`` (eq. (4)) and angular attention ``y*``;
* the population soft-count weights ``w_tau_j`` and their Monte-Carlo
  estimates over tables (Lemma 5 / 6 — finite-L concentration);
* the value-aware sampling estimator ``T(q)`` (eq. (6), Lemma 7);
* the soft-bucketization bias ``eps_tau`` (Theorem 3 discussion);
* Lemma 4's closed-form correlation ``Gamma = C q^T W^T s_hat`` for
  arbitrary per-plane score rules, with the hard (sign) and soft (tanh)
  instantiations compared in tests.
"""

from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import hashing, socket

__all__ = [
    "angular_weights",
    "angular_attention",
    "soft_count_attention",
    "sampling_estimator",
    "eps_tau_monte_carlo",
    "lemma4_gamma",
]


def angular_weights(q: jax.Array, keys: jax.Array, p: int) -> jax.Array:
    """Angular kernel weights ``w_j = (1 - arccos(cos_sim)/pi)^P`` (eq. 4)."""
    qn = q / jnp.linalg.norm(q)
    kn = keys / jnp.linalg.norm(keys, axis=-1, keepdims=True)
    cos = jnp.clip(kn @ qn, -1.0, 1.0)
    return (1.0 - jnp.arccos(cos) / jnp.pi) ** p


def angular_attention(q: jax.Array, keys: jax.Array, values: jax.Array,
                      p: int) -> jax.Array:
    """``y* = sum_j a_j v_j`` with ``a_j = w_j / Z`` (Section 5)."""
    w = angular_weights(q, keys, p)
    return (w / jnp.sum(w)) @ values


def soft_count_attention(cfg: socket.SocketConfig, rng: jax.Array,
                         q: jax.Array, keys: jax.Array,
                         values: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Finite-L soft-count attention ``y_{tau,L}`` (eq. 5) and weights.

    Returns (y, a_tilde) where a_tilde are the normalized soft weights.
    """
    d = q.shape[-1]
    w = hashing.make_hash_params(rng, d, cfg.num_planes, cfg.num_tables)
    signs = hashing.hash_keys_signs(w, keys[None])[0]          # (N, L, P)
    u = socket.soft_hash_query(w, q)                           # (L, P)
    logits = jnp.einsum("nlp,lp->nl", signs.astype(jnp.float32) * 2 - 1,
                        u) / cfg.tau
    logz = socket.log_normalizer(u, cfg.tau)
    s = jnp.exp(logits - logz[None, :])                        # (N, L)
    w_tilde = jnp.mean(s, axis=-1)                             # (1/L) sum_l
    a_tilde = w_tilde / jnp.sum(w_tilde)
    return a_tilde @ values, a_tilde


def sampling_estimator(rng: jax.Array, a_tilde: jax.Array, values: jax.Array,
                       m: int) -> jax.Array:
    """Value-aware importance-sampling estimator ``T(q)`` (eq. 6)."""
    vn = jnp.linalg.norm(values, axis=-1)
    p = a_tilde * vn
    p = p / jnp.sum(p)
    idx = jax.random.choice(rng, a_tilde.shape[0], (m,), p=p)
    contrib = (a_tilde[idx] / p[idx])[:, None] * values[idx]
    return jnp.mean(contrib, axis=0)


def eps_tau_monte_carlo(rng: jax.Array, q: jax.Array, tau: float,
                        num_planes: int, n_tables: int = 256) -> jax.Array:
    """``eps_tau(q) = E[1 - p_tau(b_q | q)]`` estimated over random tables.

    Theorem 3: eps_tau -> 0 as tau -> 0 (fixed P) and -> 1 - 1/R as
    tau -> inf.  Uses the factorized form: with x = u/tau,
    ``p(b_q|q) = prod_i exp(|x_i|) / (2 cosh(x_i))`` since the hard bucket
    takes sign(u_i) on every plane.
    """
    d = q.shape[-1]
    w = hashing.make_hash_params(rng, d, num_planes, n_tables)
    u = socket.soft_hash_query(w, q)                           # (T, P)
    x = jnp.abs(u) / tau
    # log p(b_q) = sum_i [ x_i - log(2 cosh x_i) ] = -sum_i log1p(exp(-2x))
    log_p = -jnp.sum(jnp.log1p(jnp.exp(-2.0 * x)), axis=-1)
    return jnp.mean(1.0 - jnp.exp(log_p))


def lemma4_gamma(q: jax.Array, w_orth: jax.Array, s: jax.Array) -> jax.Array:
    """Closed-form correlation ``Gamma = C q^T W^T s_hat`` (Lemma 4).

    Args:
      q:      unit-norm query ``(d,)``.
      w_orth: orthonormal plane matrix ``(P, d)``.
      s:      per-plane scores ``(P,)``.
    """
    c = jnp.sqrt(2.0 / jnp.pi)
    s_hat = s / jnp.linalg.norm(s)
    return c * (w_orth @ q) @ s_hat
