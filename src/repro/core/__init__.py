"""SOCKET core: soft-collision LSH scoring for sparse attention.

The paper's primary contribution (Algorithms 1-3 + the theory of Section 5)
lives here; model integration is in ``repro.models``, the Pallas scoring /
decode kernels in ``repro.kernels``.
"""

from repro.core.hashing import (HashParams, hash_keys_signs, hypercube_corners,
                                make_hash_params, num_words, pack_signs,
                                signs_to_bucket_ids, unpack_signs)
from repro.core.socket import (SocketCache, SocketConfig, bucket_probs_explicit,
                               log_normalizer, precompute_key_hashes,
                               socket_attend, soft_hash_query,
                               soft_scores_factorized, soft_scores_gather,
                               sparse_attention_over_subset, topk_budget,
                               value_aware_topk)

__all__ = [
    "HashParams", "SocketCache", "SocketConfig", "bucket_probs_explicit",
    "hash_keys_signs", "hypercube_corners", "log_normalizer",
    "make_hash_params", "num_words", "pack_signs", "precompute_key_hashes",
    "signs_to_bucket_ids", "socket_attend", "soft_hash_query",
    "soft_scores_factorized", "soft_scores_gather",
    "sparse_attention_over_subset", "topk_budget", "unpack_signs",
    "value_aware_topk",
]
