"""AdamW from scratch (no optax): global-norm clipping, decoupled weight
decay with a path-based mask, non-trainable-parameter freezing (SOCKET hash
planes, Mamba A_log is trainable), optional 8-bit moment states.

Optimizer state is a pytree congruent with the parameters, so pjit's FSDP
sharding of parameters automatically gives ZeRO-style sharded optimizer
states (m, v inherit the parameter PartitionSpecs; int8 states inherit
nothing — they are flat per-leaf buffers sharded by their own rules).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.optim import quantized_state as q8
from repro.optim.schedule import ScheduleConfig, learning_rate

__all__ = ["AdamWConfig", "init_adamw", "adamw_update", "is_trainable_path",
           "wants_weight_decay"]


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip_norm: float = 1.0
    state_bits: int = 32            # 32 | 8
    schedule: ScheduleConfig = ScheduleConfig()


def is_trainable_path(path: str) -> bool:
    """hash planes are data-agnostic constants (never trained)."""
    return "hash_w" not in path


def wants_weight_decay(path: str, leaf: jax.Array) -> bool:
    if leaf.ndim < 2:
        return False
    for tag in ("norm", "scale", "A_log", "dt_bias", "conv_b"):
        if tag in path:
            return False
    return True


def _map_with_path(fn: Callable, tree, *rest):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    rest_flat = [jax.tree_util.tree_leaves(r) for r in rest]
    out = [fn(jax.tree_util.keystr(p), x, *(r[i] for r in rest_flat))
           for i, (p, x) in enumerate(flat)]
    return jax.tree_util.tree_unflatten(treedef, out)


def init_adamw(cfg: AdamWConfig, params) -> Dict[str, Any]:
    def _moment(path, p):
        if not is_trainable_path(path):
            return jnp.zeros((), jnp.float32)   # placeholder, never used
        if cfg.state_bits == 8:
            return q8.qzeros_like(p)
        return jnp.zeros_like(p, jnp.float32)

    return {
        "step": jnp.zeros((), jnp.int32),
        "m": _map_with_path(lambda p, x: _moment(p, x), params),
        "v": _map_with_path(lambda p, x: _moment(p, x), params),
    }


def _global_norm(tree) -> jax.Array:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree_util.tree_leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def adamw_update(cfg: AdamWConfig, grads, state, params
                 ) -> Tuple[Any, Any, Dict[str, jax.Array]]:
    """One AdamW step.  Returns (new_params, new_state, metrics)."""
    step = state["step"] + 1
    lr = learning_rate(cfg.schedule, step)

    gnorm = _global_norm(grads)
    clip = jnp.minimum(1.0, cfg.grad_clip_norm / (gnorm + 1e-9))

    b1, b2 = cfg.b1, cfg.b2
    bc1 = 1.0 - b1 ** step.astype(jnp.float32)
    bc2 = 1.0 - b2 ** step.astype(jnp.float32)

    is_q8 = cfg.state_bits == 8

    def upd(path, p, g, m, v):
        if not is_trainable_path(path):
            return p, m, v

        def core(p_, g_, m_, v_):
            g_ = g_.astype(jnp.float32) * clip
            m_f = q8.dequantize(m_, p_.shape, power=3) if is_q8 else m_
            v_f = q8.dequantize(v_, p_.shape, power=6) if is_q8 else v_
            m_new = b1 * m_f + (1 - b1) * g_
            v_new = b2 * v_f + (1 - b2) * jnp.square(g_)
            update = (m_new / bc1) / (jnp.sqrt(v_new / bc2) + cfg.eps)
            if cfg.weight_decay and wants_weight_decay(path, p):
                update = update + cfg.weight_decay * p_.astype(jnp.float32)
            p_new = (p_.astype(jnp.float32) - lr * update).astype(p_.dtype)
            if is_q8:
                m_new = q8.quantize(m_new, power=3)
                v_new = q8.quantize(v_new, power=6)
            return p_new, m_new, v_new

        if is_q8 and p.ndim >= 2 and p.shape[0] > 1:
            # scan the update over the leading (scan-group / expert) dim so
            # the transient fp32 de-quantized moments are one slice, not
            # the whole 20 GB stacked tensor (llama4 §Perf: 125 -> ~35 GB)
            def body(_, xs):
                return None, core(*xs)
            _, (p_new, m_new, v_new) = jax.lax.scan(body, None,
                                                    (p, g, m, v))
            return p_new, m_new, v_new
        return core(p, g, m, v)

    flat_p, treedef = jax.tree_util.tree_flatten_with_path(params)
    flat_g = jax.tree_util.tree_leaves(grads)
    m_leaves = jax.tree_util.tree_flatten(
        state["m"], is_leaf=lambda x: isinstance(x, dict) and "q" in x)[0] \
        if is_q8 else jax.tree_util.tree_leaves(state["m"])
    v_leaves = jax.tree_util.tree_flatten(
        state["v"], is_leaf=lambda x: isinstance(x, dict) and "q" in x)[0] \
        if is_q8 else jax.tree_util.tree_leaves(state["v"])

    outs = [upd(jax.tree_util.keystr(path), p, g, m, v)
            for (path, p), g, m, v in zip(flat_p, flat_g, m_leaves,
                                          v_leaves)]
    new_p = jax.tree_util.tree_unflatten(treedef, [o[0] for o in outs])
    new_m = jax.tree_util.tree_unflatten(treedef, [o[1] for o in outs])
    new_v = jax.tree_util.tree_unflatten(treedef, [o[2] for o in outs])
    new_state = {"step": step, "m": new_m, "v": new_v}
    return new_p, new_state, {"grad_norm": gnorm, "lr": lr}
