"""Blockwise-quantized optimizer moments (8-bit Adam states).

Required to fit the ≥100B-parameter assigned architectures on 16 GB/chip
meshes: fp32 (m, v) for llama4-maverick-400b is 3.2 TB (12.5 GB/chip on 512
chips) — int8 moments with per-block fp32 scales cut that 4x
(EXPERIMENTS.md §Dry-run memory table).

Layout: quantization blocks run along the LAST axis only, so ``q`` keeps
the parameter's rank and leading-dim shapes — and therefore the
parameter's sharding.  (An earlier flat layout forced XLA to reshape
sharded weights to 1-D inside the update, which replicates the full fp32
moment on every device — a measured 4x/21 GB-per-buffer temp blowup on
mixtral.  Never flatten a sharded tensor.)

The second moment ``v`` spans many orders of magnitude inside a block;
linear int8 would underflow small entries to 0 and explode their updates
through ``m / (sqrt(0)+eps)``.  ``v`` therefore goes through a 6th-root
companding transform (``power=6``; ``m`` uses power=3) — ratios of 4e9 inside a block still
quantize to non-zero bins; tests show a companded-int8 Adam trajectory
tracks fp32 within a few percent.  (bitsandbytes solves this with a
dynamic-exponent code; root-companding is the TPU-friendly equivalent —
pure VPU math, no LUT.)
"""

from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np

# Block size 16: must DIVIDE every sharded last-dim chunk (d_model/16 or
# d_ff/16 on the 16-way axes, down to 336 for gemma3) — a block straddling
# a shard boundary forces XLA to all-gather the whole tensor just to
# reshape for (de)quantization (measured 60 GB/step on llama4 — §Perf).
# Cost: one f32 scale per 16 int8 values (25% overhead vs 1.6% at 512).
BLOCK = 16

__all__ = ["quantize", "dequantize", "qzeros_like", "BLOCK", "padded_dim"]


def padded_dim(d: int) -> int:
    blk = min(BLOCK, max(d, 1))
    nb = (d + blk - 1) // blk
    return nb * blk


def _blocks(x: jax.Array) -> Tuple[jax.Array, int, int]:
    """Pad the last dim to a BLOCK multiple; return (x_padded, nb, blk)."""
    if x.ndim == 0:
        x = x[None]
    d = x.shape[-1]
    blk = min(BLOCK, max(d, 1))
    nb = (d + blk - 1) // blk
    pad = nb * blk - d
    if pad:
        x = jnp.pad(x, [(0, 0)] * (x.ndim - 1) + [(0, pad)])
    return x, nb, blk


def qzeros_like(x: jax.Array) -> Dict[str, jax.Array]:
    shape = x.shape if x.ndim else (1,)
    d = shape[-1]
    blk = min(BLOCK, max(d, 1))
    nb = (d + blk - 1) // blk
    return {"q": jnp.zeros(shape[:-1] + (nb * blk,), jnp.int8),
            "scale": jnp.zeros(shape[:-1] + (nb,), jnp.float32)}


def quantize(x: jax.Array, power: int = 1) -> Dict[str, jax.Array]:
    xp, nb, blk = _blocks(x.astype(jnp.float32))
    g = xp.reshape(*xp.shape[:-1], nb, blk)
    if power != 1:
        g = jnp.sign(g) * jnp.abs(g) ** (1.0 / power)
    scale = jnp.max(jnp.abs(g), axis=-1) / 127.0            # (..., nb)
    scale_safe = jnp.maximum(scale, 1e-30)
    q = jnp.clip(jnp.round(g / scale_safe[..., None]), -127, 127)
    return {"q": q.astype(jnp.int8).reshape(xp.shape),
            "scale": scale.astype(jnp.float32)}


def dequantize(qs: Dict[str, jax.Array], shape: Tuple[int, ...],
               power: int = 1) -> jax.Array:
    d = shape[-1] if shape else 1
    nb = qs["scale"].shape[-1]
    blk = qs["q"].shape[-1] // nb
    g = qs["q"].astype(jnp.float32).reshape(*qs["q"].shape[:-1], nb, blk)
    g = g * qs["scale"][..., None]
    if power != 1:
        g = jnp.sign(g) * jnp.abs(g) ** power
    out = g.reshape(*qs["q"].shape[:-1], nb * blk)[..., :d]
    return out.reshape(shape)
