"""Learning-rate schedules (pure functions of the step counter)."""

from __future__ import annotations

import dataclasses

import jax.numpy as jnp

__all__ = ["ScheduleConfig", "learning_rate"]


@dataclasses.dataclass(frozen=True)
class ScheduleConfig:
    peak_lr: float = 3e-4
    warmup_steps: int = 100
    decay_steps: int = 10_000
    end_lr_frac: float = 0.1
    kind: str = "cosine"     # "cosine" | "linear" | "constant"


def learning_rate(cfg: ScheduleConfig, step: jnp.ndarray) -> jnp.ndarray:
    step = step.astype(jnp.float32)
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    if cfg.kind == "constant":
        decay = 1.0
    else:
        frac = jnp.clip((step - cfg.warmup_steps) /
                        jnp.maximum(cfg.decay_steps - cfg.warmup_steps, 1),
                        0.0, 1.0)
        if cfg.kind == "cosine":
            decay = cfg.end_lr_frac + (1 - cfg.end_lr_frac) * 0.5 * (
                1 + jnp.cos(jnp.pi * frac))
        elif cfg.kind == "linear":
            decay = 1.0 - (1.0 - cfg.end_lr_frac) * frac
        else:
            raise ValueError(cfg.kind)
    return cfg.peak_lr * warm * decay
