"""Optimizers: AdamW (+8-bit states), LR schedules, gradient compression."""

from repro.optim.adamw import AdamWConfig, adamw_update, init_adamw
from repro.optim.schedule import ScheduleConfig, learning_rate

__all__ = ["AdamWConfig", "ScheduleConfig", "adamw_update", "init_adamw",
           "learning_rate"]
