"""Context-parallel SOCKET decode: sequence-sharded KV + distributed merge.

For ``long_500k`` (batch=1, 524288-token cache) the batch axis cannot be
sharded, so the KV cache (and the SOCKET bit cache) shards its *sequence*
axis across devices.  This module is the explicit shard_map implementation
of one decode-attention step under that layout — the controlled alternative
to letting XLA's SPMD partitioner invent the schedule:

  1. every shard scores its local keys (packed bits -> factorized scores);
  2. local value-aware top-k_local (k_local = ceil(k / shards));
  3. exact local attention over the local selection with *unnormalized*
     online-softmax stats (m_i, l_i, o_i);
  4. one tiny all-gather of (m, l, o) triples + closed-form merge:
        m* = max m_i;  l* = Σ l_i e^{m_i - m*};  o* = Σ o_i e^{m_i - m*}/l*

Communication per step = shards x (G x hd + 2G) floats — independent of
context length (vs. all-gathering N scores: 2 MB+ per head at 500k).
The union of local top-ks is a superset-quality approximation of global
top-k: it differs from exact global top-k only when one shard holds more
than k_local of the true top-k (tests measure recall ≥ the paper's
operating regime; a two-round exact variant is an EXPERIMENTS.md §Perf
candidate).
"""

from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core import socket
from repro.distributed.sharding import shard_map

__all__ = ["context_parallel_socket_attend", "merge_partials"]


def merge_partials(m: jax.Array, l: jax.Array, o: jax.Array,
                   axis_name) -> jax.Array:
    """Merge per-shard online-softmax partials along ``axis_name``.

    m: (..., 1) row max; l: (..., 1) normalizer; o: (..., hd) unnormalized
    value accumulation (already divided by local l — we re-multiply).

    Uses pmax + two psums (2·G·(hd+2) floats per step) instead of the
    gather-everything formulation (shards× more traffic) — §Perf
    iteration 1 on the decode cells.
    """
    m_star = jax.lax.pmax(m, axis_name)
    w = l * jnp.exp(m - m_star)
    l_star = jax.lax.psum(w, axis_name)
    o_star = jax.lax.psum(o * w, axis_name)
    return o_star / jnp.maximum(l_star, 1e-30)


def _local_attend(cfg: socket.SocketConfig, w_hash, q, k_loc, v_loc, bits,
                  vnorm, lo, global_length, k_budget, scale):
    """Score + top-k + *partial* attention over this shard's keys.

    q: (B,KVH,G,1,hd); k/v_loc: (B,KVH,Nl,hd); ``lo`` = global index of the
    shard's first row.  Sink/window forcing uses *global* positions, so
    only the shard holding the prefix forces sinks and only the shard
    holding ``length`` forces the trailing window.  Returns (m, l, o)
    partials: (B,KVH,G,1,1), (B,KVH,G,1,1), (B,KVH,G,1,hd).
    """
    n_loc = k_loc.shape[2]
    if cfg.selection == "pooled":
        u = socket.soft_hash_query(w_hash,
                                   jnp.mean(q[..., 0, :], axis=2))
        scores = socket.soft_scores_factorized(cfg, bits, u)  # (B,KVH,Nl)
    else:
        u = socket.soft_hash_query(w_hash, q[..., 0, :])
        scores = socket.soft_scores_factorized(
            cfg, bits[:, :, None], u)                  # (B,KVH,G,Nl)
        scores = jnp.sum(scores, axis=2)               # kvhead selection

    gpos = lo + jnp.arange(n_loc, dtype=jnp.int32)
    glen = jnp.asarray(global_length, jnp.int32)
    valid = gpos < glen
    forced = (gpos < cfg.sink_tokens) | (gpos >= glen - cfg.window_tokens)
    eff = scores.astype(jnp.float32) * vnorm.astype(jnp.float32)
    eff = jnp.where(forced, jnp.float32(np.finfo(np.float32).max), eff)
    eff = jnp.where(valid, eff, socket.NEG_INF)
    _, idx = jax.lax.top_k(eff, k_budget)
    idx = idx.astype(jnp.int32)
    sel_mask = jnp.take_along_axis(
        jnp.broadcast_to(valid, eff.shape), idx, axis=-1)
    k_sel = jnp.take_along_axis(k_loc, idx[..., None], axis=2)
    v_sel = jnp.take_along_axis(v_loc, idx[..., None], axis=2)
    logits = jnp.einsum("bhgtd,bhkd->bhgtk", q.astype(jnp.float32),
                        k_sel.astype(jnp.float32)) * scale
    logits = jnp.where(sel_mask[:, :, None, None, :], logits, -1e30)
    m = jnp.max(logits, axis=-1, keepdims=True)        # (B,KVH,G,1,1)
    p = jnp.exp(logits - m)
    p = jnp.where(sel_mask[:, :, None, None, :], p, 0.0)
    l = jnp.sum(p, axis=-1, keepdims=True)
    o = jnp.einsum("bhgtk,bhkd->bhgtd", p, v_sel.astype(jnp.float32))
    o = o / jnp.maximum(l, 1e-30)
    return m, l, o


def _sub(axes):
    if not axes:
        return None
    return axes[0] if len(axes) == 1 else tuple(axes)


def context_parallel_socket_attend(
        cfg: socket.SocketConfig, mesh: Mesh, seq_axes: Tuple[str, ...],
        w_hash: jax.Array, q: jax.Array, k_cache: jax.Array,
        v_cache: jax.Array, bits: jax.Array, vnorm: jax.Array,
        *, length, scale: float,
        batch_axes: Tuple[str, ...] = ()) -> jax.Array:
    """SOCKET decode attention with the cache sequence axis sharded over
    ``seq_axes`` (e.g. ("data",) or ("model",) or ("data", "model")), and
    the batch axis optionally sharded over ``batch_axes``.

    Shapes (global): q (B,KVH,G,1,hd); k/v (B,KVH,N,hd);
    bits (B,KVH,N,W); vnorm (B,KVH,N).
    """
    n = k_cache.shape[2]
    seq_axes = tuple(a for a in seq_axes if a in mesh.shape)
    batch_axes = tuple(a for a in batch_axes if a in mesh.shape)
    shards = int(np.prod([mesh.shape[a] for a in seq_axes]))
    k_total = socket.topk_budget(cfg, n)
    k_local = max(cfg.min_k, -(-k_total // shards))
    axis = seq_axes[0] if len(seq_axes) == 1 else seq_axes
    bax = _sub(batch_axes)

    cache_spec = P(bax, None, axis, None)
    flat_spec = P(bax, None, axis)
    rep = P(bax, None, None, None, None)

    def body(q_l, k_l, v_l, bits_l, vnorm_l, length_l):
        # this shard covers global rows [lo, lo+Nl)
        if isinstance(axis, tuple):
            sizes = [mesh.shape[a] for a in axis]
            idx = jax.lax.axis_index(axis[0])
            for a in axis[1:]:
                idx = idx * mesh.shape[a] + jax.lax.axis_index(a)
        else:
            idx = jax.lax.axis_index(axis)
        n_l = k_l.shape[2]
        lo = idx * n_l
        m, l, o = _local_attend(cfg, w_hash, q_l, k_l, v_l, bits_l,
                                vnorm_l, lo, length_l, k_local, scale)
        merged = merge_partials(m, l, o, axis)
        return merged.astype(q_l.dtype)

    fn = shard_map(
        body, mesh=mesh,
        in_specs=(rep, cache_spec, cache_spec, cache_spec, flat_spec, P()),
        out_specs=rep,
        check_vma=False,
    )
    return fn(q, k_cache.astype(q.dtype), v_cache.astype(q.dtype), bits,
              vnorm, jnp.asarray(length, jnp.int32))
