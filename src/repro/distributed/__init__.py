"""Distribution: sharding rules, context parallelism, pipeline, compression."""

from repro.distributed import sharding

__all__ = ["sharding"]
