"""Pipeline parallelism (GPipe schedule) over a "stage" mesh axis.

The production dry-run meshes use (pod, data, model); for deployments that
prefer pipeline over wider TP (e.g. cross-pod pipelining to hide DCI
latency), this module runs a stage-partitioned stack under shard_map with
``collective_permute`` boundary transfers and the standard GPipe
microbatch schedule:

    for t in range(num_micro + stages - 1):        # fill + steady + drain
        x = stage_fn(stage_params, x)  if active
        x = ppermute(x, stage -> stage+1)

Each device holds ``layers/stages`` contiguous layers; bubble fraction is
``(stages-1)/(num_micro+stages-1)``.  Forward-only is exposed for serving;
training composes with jax.grad through shard_map (linear collectives
differentiate), validated in tests against the unpipelined stack.
"""

from __future__ import annotations

import functools
from typing import Callable, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P

from repro.distributed.sharding import shard_map

__all__ = ["gpipe_forward"]


def gpipe_forward(mesh: Mesh, stage_axis: str, stage_fn: Callable,
                  stage_params, x: jax.Array, num_micro: int) -> jax.Array:
    """Run ``stage_fn`` as a GPipe pipeline.

    Args:
      stage_fn: (params_slice, x_micro) -> x_micro, one stage's layers.
      stage_params: pytree whose leaves have a leading ``stages`` dim,
        sharded over ``stage_axis``.
      x: (B, ...) global input batch, replicated across stages.
      num_micro: number of microbatches (must divide B).

    Returns (B, ...) outputs (valid on the last stage; replicated out).
    """
    stages = mesh.shape[stage_axis]
    b = x.shape[0]
    if b % num_micro:
        raise ValueError(f"batch {b} must divide into {num_micro} micro")
    mb = b // num_micro
    perm_fwd = [(i, (i + 1) % stages) for i in range(stages)]

    def body(params_l, x_l):
        # params_l leaves: (1, ...) — this stage's slice
        params_local = jax.tree_util.tree_map(lambda p: p[0], params_l)
        stage_id = jax.lax.axis_index(stage_axis)
        micro = x_l.reshape(num_micro, mb, *x_l.shape[1:])

        n_ticks = num_micro + stages - 1
        buf = jnp.zeros((mb, *x_l.shape[1:]), x_l.dtype)
        outs = jnp.zeros_like(micro)

        def tick(t, carry):
            buf, outs = carry
            # stage 0 ingests microbatch t (when in range)
            feed = micro[jnp.clip(t, 0, num_micro - 1)]
            cur = jnp.where(stage_id == 0, feed, buf)
            active = (t - stage_id >= 0) & (t - stage_id < num_micro)
            y = stage_fn(params_local, cur)
            y = jnp.where(active, y, buf)
            # last stage records its finished microbatch
            out_idx = jnp.clip(t - stages + 1, 0, num_micro - 1)
            record = active & (stage_id == stages - 1)
            outs = jax.lax.dynamic_update_index_in_dim(
                outs, jnp.where(record, y, outs[out_idx]), out_idx, 0)
            # shift activations one stage forward
            buf = jax.lax.ppermute(y, stage_axis, perm_fwd)
            return buf, outs

        _, outs = jax.lax.fori_loop(0, n_ticks, tick, (buf, outs))
        # broadcast the last stage's finished outputs to every stage
        outs = jax.lax.all_gather(outs, stage_axis)[stages - 1]
        return outs.reshape(b, *x_l.shape[1:])

    fn = shard_map(
        body, mesh=mesh,
        in_specs=(P(stage_axis), P()),
        out_specs=P(),
        check_vma=False)
    return fn(stage_params, x)
