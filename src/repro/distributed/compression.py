"""Int8 error-feedback gradient compression for the slow (cross-pod) axis.

The multi-pod mesh's "pod" axis crosses data-center interconnect, which is
an order of magnitude slower than intra-pod ICI.  Synchronizing fp32/bf16
gradients across it costs ``2 bytes x params / pod_bw`` per step; int8
compression cuts that 2-4x at equal convergence when combined with error
feedback (Seide et al. 2014; 1-bit Adam lineage):

    e_t      : persistent per-leaf error buffer (same sharding as grads)
    compress : q = quantize(g + e);  e' = (g + e) - dequantize(q)
    sync     : psum(q) over "pod" (int32 accumulate of int8 payloads)
    result   : dequantize(psum) / num_pods

Exposed as a shard_map-compatible transform: ``compressed_psum`` runs
*inside* shard_map (per-shard arrays + explicit axis name), and
``CompressedDP.wrap`` turns a local-grad function into a cross-pod-synced
one.  Tests verify (a) exactness as quantization -> 0, (b) error-feedback
bias correction over repeated steps, (c) equivalence with plain psum on
smooth objectives.
"""

from __future__ import annotations

import functools
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

__all__ = ["compress_leaf", "decompress_leaf", "compressed_psum",
           "init_error_buffers"]


def compress_leaf(x: jax.Array) -> Dict[str, jax.Array]:
    """Per-tensor absmax int8 quantization (leaf granularity is enough for
    the pod axis — per-block scales would double the scale traffic)."""
    xf = x.astype(jnp.float32)
    scale = jnp.max(jnp.abs(xf)) / 127.0
    scale_safe = jnp.maximum(scale, 1e-30)
    q = jnp.clip(jnp.round(xf / scale_safe), -127, 127).astype(jnp.int8)
    return {"q": q, "scale": scale}


def decompress_leaf(c: Dict[str, jax.Array]) -> jax.Array:
    return c["q"].astype(jnp.float32) * c["scale"]


def init_error_buffers(grads: Any) -> Any:
    return jax.tree_util.tree_map(
        lambda g: jnp.zeros(g.shape, jnp.float32), grads)


def compressed_psum(grads: Any, errors: Any, axis_name: str
                    ) -> Tuple[Any, Any]:
    """Error-feedback int8 all-reduce over ``axis_name``.

    Must run inside shard_map/pmap where ``axis_name`` is bound.
    Returns (synced_grads_fp32_mean, new_error_buffers).
    """
    n = jax.lax.psum(1, axis_name)

    def leaf(g, e):
        corrected = g.astype(jnp.float32) + e
        c = compress_leaf(corrected)
        local_deq = decompress_leaf(c)
        new_e = corrected - local_deq
        # int8 payload accumulates exactly in int32; scales are averaged —
        # each shard's contribution is q_i * scale_i, so we psum the
        # dequantized-by-own-scale values in one shot by scaling first.
        contrib = c["q"].astype(jnp.float32) * c["scale"]
        total = jax.lax.psum(contrib, axis_name)
        return total / n, new_e

    flat_g, tdef = jax.tree_util.tree_flatten(grads)
    flat_e = jax.tree_util.tree_leaves(errors)
    outs = [leaf(g, e) for g, e in zip(flat_g, flat_e)]
    synced = jax.tree_util.tree_unflatten(tdef, [o[0] for o in outs])
    new_err = jax.tree_util.tree_unflatten(tdef, [o[1] for o in outs])
    return synced, new_err
