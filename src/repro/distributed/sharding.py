"""Logical-axis sharding rules (MaxText-style) + divisibility-safe mapping.

Parameters and activations are annotated with *logical* axis names; a rule
table maps them to mesh axes.  :func:`logical_to_spec` silently drops a
mapping when the dimension size is not divisible by the mesh-axis extent
(e.g. musicgen's 24 heads on a 16-way "model" axis) and records the
fallback so DESIGN.md/EXPERIMENTS.md can report every replication decision.

The module keeps a process-global "current mesh" context so model code can
call :func:`lsc` (logical sharding constraint) unconditionally — it is the
identity when no mesh is active (unit tests, single-device smoke runs).
"""

from __future__ import annotations

import contextlib
import threading
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec

__all__ = [
    "DEFAULT_RULES", "activate_mesh", "current_mesh", "fallback_log", "lsc",
    "logical_to_spec", "named_sharding", "shard_map", "spec_for_shape",
]


def shard_map(f, *, mesh, in_specs, out_specs, check_vma: bool = True):
    """Version-portable ``shard_map``.

    Newer jax exposes ``jax.shard_map`` with the replication check named
    ``check_vma``; jax 0.4.x only has ``jax.experimental.shard_map``, and
    some releases in between ship ``jax.shard_map`` with the flag still
    named ``check_rep`` — so the kwarg name is chosen by signature, not
    by version.  Every shard_map in this repo goes through this wrapper
    so all three toolchains work unmodified.
    """
    import inspect

    if hasattr(jax, "shard_map"):
        sm = jax.shard_map
    else:
        from jax.experimental.shard_map import shard_map as sm
    params = inspect.signature(sm).parameters
    check_kw = "check_vma" if "check_vma" in params else "check_rep"
    return sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
              **{check_kw: check_vma})

# logical axis -> mesh axis (or tuple of mesh axes)
DEFAULT_RULES: Dict[str, Optional[Tuple[str, ...]]] = {
    # activations
    "batch": ("pod", "data"),
    "seq": None,
    # residual-stream sequence dim: ("model",) enables Megatron-style
    # sequence parallelism of activations between blocks
    "act_seq": None,
    "embed": None,
    "q_heads": ("model",),
    # weights
    "embed_w": ("data",),          # FSDP: weight shards over data axis
    "vocab": ("model",),
    "heads": ("model",),
    "kv_heads": ("model",),
    "head_dim": None,
    "mlp": ("model",),
    "experts": ("model",),
    "expert_mlp": ("model",),      # intra-expert TP (moe_parallelism="tp")
    "moe_capacity": ("data",),     # (E, C, ·) dispatch-buffer capacity dim
    "moe_tokens": ("data",),       # flattened (N·k, ·) assignment tensors
    # kv-cache
    "cache_batch": ("pod", "data"),
    "cache_heads": ("model",),
    "cache_seq": None,
    "cache_seq_cp": ("pod", "data"),  # context parallel (long_500k decode)
    # misc
    "groups": None,                 # scan-group stacking axis
    "tables": None,
    "conv": ("model",),
    "ssm_heads": ("model",),
    "ssm_inner": ("model",),
    "state": None,
}

_CTX = threading.local()


class _MeshContext:
    def __init__(self, mesh: Mesh, rules: Dict[str, Optional[Tuple[str, ...]]]):
        self.mesh = mesh
        self.rules = rules
        self.fallbacks: List[str] = []


def _ctx() -> Optional[_MeshContext]:
    return getattr(_CTX, "ctx", None)


@contextlib.contextmanager
def activate_mesh(mesh: Mesh, rules: Optional[Dict] = None):
    """Install ``mesh`` (+ optional rule overrides) for model-code ``lsc``."""
    merged = dict(DEFAULT_RULES)
    if rules:
        merged.update(rules)
    prev = _ctx()
    _CTX.ctx = _MeshContext(mesh, merged)
    try:
        with mesh:
            yield _CTX.ctx
    finally:
        _CTX.ctx = prev


def current_mesh() -> Optional[Mesh]:
    c = _ctx()
    return c.mesh if c else None


def fallback_log() -> List[str]:
    c = _ctx()
    return c.fallbacks if c else []


def _axis_size(mesh: Mesh, names: Sequence[str]) -> int:
    total = 1
    for n in names:
        if n in mesh.shape:
            total *= mesh.shape[n]
    return total


def logical_to_spec(logical_axes: Sequence[Optional[str]],
                    shape: Sequence[int],
                    mesh: Mesh,
                    rules: Optional[Dict] = None,
                    log: Optional[List[str]] = None) -> PartitionSpec:
    """Map per-dim logical names to a PartitionSpec, checking divisibility.

    ``rules`` are *overrides* merged on top of DEFAULT_RULES.
    """
    rules = {**DEFAULT_RULES, **(rules or {})}
    entries = []
    for dim, name in enumerate(logical_axes):
        if name is None:
            entries.append(None)
            continue
        if name not in rules:
            raise KeyError(f"unknown logical axis {name!r}")
        mapped = rules[name]
        if mapped is None:
            entries.append(None)
            continue
        mesh_axes = tuple(a for a in mapped if a in mesh.shape)
        if not mesh_axes:
            entries.append(None)
            continue
        size = _axis_size(mesh, mesh_axes)
        if shape[dim] % size != 0:
            if log is not None:
                log.append(
                    f"replicated dim {dim} ({name}={shape[dim]}) — not "
                    f"divisible by mesh axes {mesh_axes} (size {size})")
            entries.append(None)
        else:
            entries.append(mesh_axes if len(mesh_axes) > 1 else mesh_axes[0])
    return PartitionSpec(*entries)


def named_sharding(mesh: Mesh, logical_axes: Sequence[Optional[str]],
                   shape: Sequence[int], rules: Optional[Dict] = None,
                   log: Optional[List[str]] = None) -> NamedSharding:
    return NamedSharding(mesh, logical_to_spec(logical_axes, shape, mesh,
                                               rules, log))


def spec_for_shape(mesh: Mesh, logical_axes: Sequence[Optional[str]],
                   shape: Sequence[int]) -> PartitionSpec:
    return logical_to_spec(logical_axes, shape, mesh)


def lsc(x: jax.Array, *logical_axes: Optional[str]) -> jax.Array:
    """Logical sharding constraint on an activation (no-op without a mesh).

    Example: ``x = lsc(x, "batch", "seq", "embed")``.
    """
    c = _ctx()
    if c is None:
        return x
    if len(logical_axes) != x.ndim:
        raise ValueError(f"lsc: {len(logical_axes)} axes for rank-{x.ndim}")
    spec = logical_to_spec(logical_axes, x.shape, c.mesh, c.rules,
                           c.fallbacks)
    return jax.lax.with_sharding_constraint(x, NamedSharding(c.mesh, spec))
