"""Async, atomic, sharding-aware checkpointing.

Layout per step::

    <dir>/step_000001230/
        manifest.json        # treedef, shapes, dtypes, extra metadata
        arrays.npz           # one entry per leaf (host-gathered)
    <dir>/LATEST             # atomic pointer file (rename-swapped)

Design points for fleet operation:
* **atomic**: writes go to ``step_X.tmp`` then ``os.replace`` — a crash
  mid-save can never corrupt the restore point.
* **async**: ``save()`` snapshots leaves to host memory and hands the file
  IO to a background thread; training resumes immediately (the snapshot
  cost is one device→host copy).
* **resharding restore**: ``restore(..., shardings=)`` places each leaf
  with ``jax.device_put`` under the *current* mesh — restoring onto a
  different topology (elastic restart after losing a pod) just works.
* **retention**: keeps the newest ``keep`` checkpoints.

(For >1 host, each process would write ``arrays.<proc>.npz`` of its
addressable shards; this container is single-process so the full gather
path is exercised.)
"""

from __future__ import annotations

import json
import os
import pickle
import shutil
import threading
import time
from typing import Any, Dict, List, Optional

import jax
import numpy as np

__all__ = ["Checkpointer"]


class Checkpointer:
    def __init__(self, directory: str, keep: int = 3):
        self.directory = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None

    # ----------------------------------------------------------------- util
    def _step_dir(self, step: int) -> str:
        return os.path.join(self.directory, f"step_{step:012d}")

    def latest_step(self) -> Optional[int]:
        pointer = os.path.join(self.directory, "LATEST")
        if not os.path.exists(pointer):
            return None
        with open(pointer) as f:
            return int(f.read().strip())

    def all_steps(self) -> List[int]:
        out = []
        for name in os.listdir(self.directory):
            if name.startswith("step_") and not name.endswith(".tmp"):
                out.append(int(name[5:]))
        return sorted(out)

    def wait(self):
        """Block until any in-flight async save finishes (re-raising)."""
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err

    # ----------------------------------------------------------------- save
    def save(self, step: int, tree: Any, extra: Optional[Dict] = None,
             blocking: bool = False):
        """Snapshot ``tree`` (a pytree of jax/np arrays) at ``step``."""
        self.wait()
        leaves, treedef = jax.tree_util.tree_flatten(tree)
        # device -> host snapshot happens NOW (so training can mutate
        # donated buffers immediately after we return)
        host_leaves = [np.asarray(x) for x in leaves]
        manifest = {
            "step": step,
            "treedef": pickle.dumps(
                jax.tree_util.tree_structure(tree)).hex(),
            "shapes": [list(x.shape) for x in host_leaves],
            "dtypes": [str(x.dtype) for x in host_leaves],
            "extra": extra or {},
            "time": time.time(),
        }

        def _write():
            try:
                final = self._step_dir(step)
                tmp = final + ".tmp"
                if os.path.exists(tmp):
                    shutil.rmtree(tmp)
                os.makedirs(tmp)
                np.savez(os.path.join(tmp, "arrays.npz"),
                         **{f"leaf_{i}": x for i, x in
                            enumerate(host_leaves)})
                with open(os.path.join(tmp, "manifest.json"), "w") as f:
                    json.dump(manifest, f)
                if os.path.exists(final):
                    shutil.rmtree(final)
                os.replace(tmp, final)
                ptr_tmp = os.path.join(self.directory, "LATEST.tmp")
                with open(ptr_tmp, "w") as f:
                    f.write(str(step))
                os.replace(ptr_tmp,
                           os.path.join(self.directory, "LATEST"))
                self._gc()
            except BaseException as e:  # noqa: BLE001
                self._error = e

        if blocking:
            _write()
            self.wait()
        else:
            self._thread = threading.Thread(target=_write, daemon=True)
            self._thread.start()

    def _gc(self):
        steps = self.all_steps()
        for s in steps[:-self.keep] if self.keep else []:
            shutil.rmtree(self._step_dir(s), ignore_errors=True)

    # -------------------------------------------------------------- restore
    def restore(self, step: Optional[int] = None,
                shardings: Any = None) -> Dict:
        """Load a checkpoint; returns {"tree", "step", "extra"}.

        ``shardings``: optional pytree of NamedSharding congruent with the
        saved tree — leaves are device_put onto the current mesh
        (resharding restore for elastic topology changes).
        """
        step = self.latest_step() if step is None else step
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.directory}")
        d = self._step_dir(step)
        with open(os.path.join(d, "manifest.json")) as f:
            manifest = json.load(f)
        treedef = pickle.loads(bytes.fromhex(manifest["treedef"]))
        npz = np.load(os.path.join(d, "arrays.npz"))
        leaves = [npz[f"leaf_{i}"] for i in range(len(manifest["shapes"]))]
        tree = jax.tree_util.tree_unflatten(treedef, leaves)
        if shardings is not None:
            tree = jax.tree_util.tree_map(
                lambda x, s: jax.device_put(x, s), tree, shardings)
        return {"tree": tree, "step": step, "extra": manifest["extra"]}
