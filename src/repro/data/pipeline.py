"""Deterministic, resumable, host-sharded LM data pipeline.

Two sources behind one iterator interface:

* :class:`SyntheticLMSource` — an infinite deterministic token stream
  (mixture of Zipf-distributed unigrams + embedded copy/retrieval spans so
  models have something learnable; the retrieval spans also make the
  long-context benchmarks non-trivial).
* :class:`MemmapLMSource` — pre-tokenized ``uint32`` flat files (memmap) cut
  into sequences, shuffled by a seeded permutation per epoch.

The iterator state is two integers (epoch, step) + the seed — trivially
checkpointable and exactly resumable (``state_dict`` / ``load_state_dict``),
which the fault-tolerance tests rely on.  Each host materializes only its
shard: ``global_batch`` rows are split by (process_index, num_processes);
within a host the per-device split is pjit's job.
"""

from __future__ import annotations

import dataclasses
import os
import threading
import queue
from typing import Dict, Iterator, Optional

import numpy as np

__all__ = ["DataConfig", "SyntheticLMSource", "MemmapLMSource",
           "HostDataLoader"]


@dataclasses.dataclass(frozen=True)
class DataConfig:
    seq_len: int = 1024
    global_batch: int = 8
    vocab_size: int = 32000
    seed: int = 0
    zipf_a: float = 1.2
    copy_span: int = 32          # length of embedded retrieval spans
    copy_prob: float = 0.5       # fraction of sequences with a span
    prefetch: int = 2


class SyntheticLMSource:
    """Deterministic synthetic LM batches.

    Every (epoch, step, row) is generated from a counter-based RNG, so any
    batch can be regenerated independently of iteration order — exact
    resume after preemption is free.
    """

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        # precomputed zipf-ish unigram distribution over a capped alphabet
        v = min(cfg.vocab_size, 32768)
        ranks = np.arange(1, v + 1, dtype=np.float64)
        probs = ranks ** (-cfg.zipf_a)
        self._probs = (probs / probs.sum()).astype(np.float64)
        self._alphabet = np.arange(v, dtype=np.uint32)

    def row(self, epoch: int, step: int, row_idx: int) -> np.ndarray:
        cfg = self.cfg
        seed = (hash((cfg.seed, epoch, step, row_idx)) & 0x7FFFFFFF)
        rng = np.random.default_rng(seed)
        toks = rng.choice(self._alphabet, size=cfg.seq_len + 1,
                          p=self._probs).astype(np.int32)
        if rng.random() < cfg.copy_prob and cfg.seq_len > 4 * cfg.copy_span:
            # plant span twice: learnable long-range copy structure
            span = toks[8:8 + cfg.copy_span]
            dst = int(rng.integers(cfg.seq_len // 2,
                                   cfg.seq_len - cfg.copy_span))
            toks[dst:dst + cfg.copy_span] = span
        return toks

    def batch(self, epoch: int, step: int, rows: range) -> Dict[str, np.ndarray]:
        data = np.stack([self.row(epoch, step, r) for r in rows])
        return {"tokens": data[:, :-1].astype(np.int32),
                "labels": data[:, 1:].astype(np.int32)}


class MemmapLMSource:
    """Flat pre-tokenized uint32 file -> shuffled fixed-length sequences."""

    def __init__(self, cfg: DataConfig, path: str):
        self.cfg = cfg
        self._data = np.memmap(path, dtype=np.uint32, mode="r")
        self.num_seqs = (len(self._data) - 1) // cfg.seq_len
        if self.num_seqs <= 0:
            raise ValueError(f"{path} too small for seq_len={cfg.seq_len}")

    def _perm(self, epoch: int) -> np.ndarray:
        rng = np.random.default_rng((self.cfg.seed, epoch))
        return rng.permutation(self.num_seqs)

    def batch(self, epoch: int, step: int, rows: range) -> Dict[str, np.ndarray]:
        perm = self._perm(epoch)
        out_t, out_l = [], []
        for r in rows:
            idx = perm[(step * self.cfg.global_batch + r) % self.num_seqs]
            lo = idx * self.cfg.seq_len
            chunk = np.asarray(self._data[lo:lo + self.cfg.seq_len + 1],
                               dtype=np.int64)
            out_t.append(chunk[:-1])
            out_l.append(chunk[1:])
        return {"tokens": np.stack(out_t).astype(np.int32),
                "labels": np.stack(out_l).astype(np.int32)}


class HostDataLoader:
    """Host-sharded, prefetching, exactly-resumable loader."""

    def __init__(self, cfg: DataConfig, source=None, process_index: int = 0,
                 num_processes: int = 1):
        self.cfg = cfg
        self.source = source or SyntheticLMSource(cfg)
        if cfg.global_batch % num_processes:
            raise ValueError("global_batch must divide across hosts")
        per_host = cfg.global_batch // num_processes
        self._rows = range(process_index * per_host,
                           (process_index + 1) * per_host)
        self._epoch = 0
        self._step = 0
        self._q: "queue.Queue" = queue.Queue(maxsize=max(cfg.prefetch, 1))
        self._thread: Optional[threading.Thread] = None
        self._stop = threading.Event()

    # ------------------------------------------------------------- state
    def state_dict(self) -> Dict[str, int]:
        return {"epoch": self._epoch, "step": self._step,
                "seed": self.cfg.seed}

    def load_state_dict(self, state: Dict[str, int]):
        self._drain()
        self._epoch = int(state["epoch"])
        self._step = int(state["step"])

    # ---------------------------------------------------------- iteration
    def _produce(self):
        epoch, step = self._epoch, self._step
        while not self._stop.is_set():
            batch = self.source.batch(epoch, step, self._rows)
            # blocking put with timeout so shutdown is prompt
            while not self._stop.is_set():
                try:
                    self._q.put((epoch, step, batch), timeout=0.1)
                    break
                except queue.Full:
                    continue
            step += 1
            epoch_len = getattr(self.source, "num_seqs", 0)
            if epoch_len and step * self.cfg.global_batch >= epoch_len:
                epoch, step = epoch + 1, 0

    def _ensure_thread(self):
        if self._thread is None or not self._thread.is_alive():
            self._stop.clear()
            self._thread = threading.Thread(target=self._produce,
                                            daemon=True)
            self._thread.start()

    def _drain(self):
        self._stop.set()
        if self._thread is not None:
            self._thread.join(timeout=2.0)
        self._thread = None
        while not self._q.empty():
            self._q.get_nowait()

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        return self

    def __next__(self) -> Dict[str, np.ndarray]:
        self._ensure_thread()
        epoch, step, batch = self._q.get()
        self._epoch, self._step = epoch, step + 1
        return batch

    def close(self):
        self._drain()
