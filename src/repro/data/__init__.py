"""Data pipelines (synthetic + memmap token sources)."""

from repro.data.pipeline import (DataConfig, HostDataLoader, MemmapLMSource,
                                 SyntheticLMSource)

__all__ = ["DataConfig", "HostDataLoader", "MemmapLMSource",
           "SyntheticLMSource"]
