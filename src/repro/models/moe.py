"""Mixture-of-Experts FFN with sort-based (MegaBlocks-style) dispatch.

Dispatch avoids the O(N·E·C) one-hot tensor of the classic Switch
implementation (impossible at llama4's E=128): token→expert assignments are
argsorted by expert, positions-within-expert computed by a cumulative
count, and tokens scattered into an (E, C, d) buffer.  Capacity overflow
drops tokens (standard; ``capacity_factor`` controls slack) — the residual
connection carries dropped tokens through unchanged.

Parallelism (cfg.moe_parallelism):
* ``"ep"`` — expert axis sharded over "model"; the scatter/gather between
  batch-sharded tokens and expert-sharded buffers lowers to all-to-all
  style collectives under pjit.
* ``"tp"`` — experts replicated, each expert's d_ff sharded over "model"
  (mixtral's 8 experts cannot split 16 ways).

Router losses: Switch load-balancing loss + router z-loss, returned as
scalars for the train loop.
"""

from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.distributed.sharding import lsc
from repro.models import param as pm

__all__ = ["init_moe", "apply_moe"]


def init_moe(cfg: ModelConfig, rng: jax.Array) -> Dict:
    d, ff, e = cfg.d_model, cfg.d_ff, cfg.num_experts
    k1, k2, k3, k4 = jax.random.split(rng, 4)
    dtype = jnp.dtype(cfg.param_dtype)
    s_in, s_out = 1.0 / np.sqrt(d), 1.0 / np.sqrt(ff)
    ff_axis = None if cfg.moe_parallelism == "ep" else "expert_mlp"
    e_axis = "experts" if cfg.moe_parallelism == "ep" else None
    return {
        "router": pm.normal(k1, (d, e), ("embed_w", None), stddev=s_in,
                            dtype=jnp.float32),
        "w_gate": pm.normal(k2, (e, d, ff), (e_axis, "embed_w", ff_axis),
                            stddev=s_in, dtype=dtype),
        "w_up": pm.normal(k3, (e, d, ff), (e_axis, "embed_w", ff_axis),
                          stddev=s_in, dtype=dtype),
        "w_down": pm.normal(k4, (e, ff, d), (e_axis, ff_axis, "embed_w"),
                            stddev=s_out, dtype=dtype),
    }


def _router(cfg: ModelConfig, params: Dict, x2d: jax.Array):
    """Top-k routing.  x2d: (N, d) -> (top_idx, top_probs, aux_losses)."""
    logits = x2d.astype(jnp.float32) @ params["router"].astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)                    # (N, E)
    top_probs, top_idx = jax.lax.top_k(probs, cfg.num_experts_per_tok)
    top_probs = top_probs / jnp.maximum(
        jnp.sum(top_probs, axis=-1, keepdims=True), 1e-9)

    # Switch load-balance loss: E * sum_e f_e * P_e
    e = cfg.num_experts
    one_hot = jax.nn.one_hot(top_idx[:, 0], e, dtype=jnp.float32)
    f = jnp.mean(one_hot, axis=0)
    p_mean = jnp.mean(probs, axis=0)
    lb_loss = e * jnp.sum(f * p_mean)
    z_loss = cfg.router_z_loss * jnp.mean(
        jax.scipy.special.logsumexp(logits, axis=-1) ** 2)
    return top_idx, top_probs, {"moe_lb_loss": lb_loss,
                                "moe_z_loss": z_loss}


def _dispatch_ffn(cfg: ModelConfig, params: Dict, x2d: jax.Array,
                  capacity: int) -> Tuple[jax.Array, Dict]:
    """Sort-dispatch + expert FFN + combine over flat tokens (N, d)."""
    n, d = x2d.shape
    e, k = cfg.num_experts, cfg.num_experts_per_tok
    cdt = jnp.dtype(cfg.compute_dtype)

    top_idx, top_probs, aux = _router(cfg, params, x2d)

    # ---- sort-based dispatch -------------------------------------------
    flat_expert = top_idx.reshape(n * k)                       # (NK,)
    flat_token = jnp.repeat(jnp.arange(n, dtype=jnp.int32), k)
    flat_prob = top_probs.reshape(n * k)

    order = jnp.argsort(flat_expert)                           # stable
    sorted_expert = flat_expert[order]
    sorted_token = flat_token[order]
    sorted_prob = flat_prob[order]

    counts = jnp.bincount(sorted_expert, length=e)             # (E,)
    starts = jnp.concatenate([jnp.zeros((1,), counts.dtype),
                              jnp.cumsum(counts)[:-1]])
    pos_in_expert = jnp.arange(n * k) - starts[sorted_expert]
    keep = pos_in_expert < capacity
    slot = jnp.where(keep, pos_in_expert, capacity - 1).astype(jnp.int32)

    # scatter tokens into (E, C, d) buffers (dropped tokens masked to 0).
    # The capacity dim shards over "data" — without this the buffers
    # replicate whenever E doesn't divide the model axis (mixtral: 8
    # experts on 16-way TP => 32 GB/device/buffer; measured in §Perf).
    gathered = jnp.take(x2d, sorted_token, axis=0).astype(cdt)  # (NK, d)
    gathered = jnp.where(keep[:, None], gathered, 0.0)
    gathered = lsc(gathered, "moe_tokens", "embed")
    buf = jnp.zeros((e, capacity, d), cdt)
    buf = buf.at[sorted_expert, slot].add(gathered)
    buf = lsc(buf, "experts", "moe_capacity", "embed")

    # ---- expert FFN (batched GEMMs over the expert axis) ---------------
    wg = params["w_gate"].astype(cdt)
    wu = params["w_up"].astype(cdt)
    wd = params["w_down"].astype(cdt)
    gate = jnp.einsum("ecd,edf->ecf", buf, wg)
    up = jnp.einsum("ecd,edf->ecf", buf, wu)
    act = jax.nn.gelu(gate, approximate=True) if cfg.mlp_activation == \
        "geglu" else jax.nn.silu(gate)
    h = lsc(act * up, "experts", "moe_capacity",
            None if cfg.moe_parallelism == "ep" else "mlp")
    out_buf = jnp.einsum("ecf,efd->ecd", h, wd)                # (E, C, d)
    out_buf = lsc(out_buf, "experts", "moe_capacity", "embed")

    # ---- combine back to tokens ----------------------------------------
    expert_out = out_buf[sorted_expert, slot]                  # (NK, d)
    expert_out = jnp.where(keep[:, None], expert_out, 0.0)
    weighted = expert_out * sorted_prob[:, None].astype(cdt)
    y2d = jnp.zeros((n, d), cdt).at[sorted_token].add(weighted)
    return y2d.astype(x2d.dtype), aux


def _capacity_for(cfg: ModelConfig, n: int, t: int) -> int:
    e, k = cfg.num_experts, cfg.num_experts_per_tok
    if t == 1:
        # decode: guarantee dropless routing (worst case: every token on
        # the same expert); capacity drops would corrupt generation.
        capacity = n * k
    else:
        capacity = int(np.ceil(n * k / e * cfg.capacity_factor))
        capacity = max(capacity, 4)
    return min(capacity, n * k)


def apply_moe(cfg: ModelConfig, params: Dict, x: jax.Array
              ) -> Tuple[jax.Array, Dict]:
    """MoE FFN.  x: (B, T, d) -> (y, aux_losses).

    ``cfg.moe_dispatch``:
    * "global" — one sort over all B*T tokens (best load balancing; the
      token<->expert order crossing becomes global collective traffic);
    * "batch"  — vmapped per-batch-row dispatch: every gather/scatter stays
      inside the row's data shard, so the only cross-device traffic is the
      expert GEMM itself.  Measured 40x collective reduction on jamba
      prefill_32k (§Perf iteration 2).  Capacity is per-row (slightly more
      drops under cross-row imbalance).
    """
    b, t, d = x.shape
    if cfg.moe_dispatch == "alltoall" and t > 1:
        from repro.distributed import sharding as _shd
        mesh = _shd.current_mesh()
        model = dict(mesh.shape).get("model", 1) if mesh else 1
        if mesh is not None and cfg.num_experts % model == 0 and model > 1:
            return _apply_moe_alltoall(cfg, params, x, mesh)
        # fall through to global dispatch when not applicable
    if cfg.moe_dispatch == "batch" and b > 1 and t > 1:
        capacity = _capacity_for(cfg, t, t)

        def row(x_row):
            return _dispatch_ffn(cfg, params, x_row, capacity)

        y, aux = jax.vmap(row)(x)
        aux = jax.tree_util.tree_map(jnp.mean, aux)
        return y.astype(x.dtype), aux

    x2d = x.reshape(b * t, d)
    capacity = _capacity_for(cfg, b * t, t)
    y2d, aux = _dispatch_ffn(cfg, params, x2d, capacity)
    return y2d.reshape(b, t, d).astype(x.dtype), aux


# ---------------------------------------------------------------------------
# Expert-parallel all-to-all dispatch (moe_dispatch="alltoall")
# ---------------------------------------------------------------------------

def _grouped_ffn(cfg: ModelConfig, wg, wu, wd, tokens2d, expert_ids,
                 e_count: int, capacity: int):
    """FFN over tokens with *precomputed* local expert ids (N, ) in
    [0, e_count); sort-dispatch into (e_count, capacity, d) and combine.
    Returns (N, d) outputs (zero rows where dropped)."""
    n, d = tokens2d.shape
    cdt = tokens2d.dtype
    order = jnp.argsort(expert_ids)
    sorted_e = expert_ids[order]
    sorted_tok = order.astype(jnp.int32)
    counts = jnp.bincount(sorted_e, length=e_count)
    starts = jnp.concatenate([jnp.zeros((1,), counts.dtype),
                              jnp.cumsum(counts)[:-1]])
    pos = jnp.arange(n) - starts[sorted_e]
    keep = pos < capacity
    slot = jnp.where(keep, pos, capacity - 1).astype(jnp.int32)
    gathered = jnp.where(keep[:, None], tokens2d[sorted_tok], 0.0)
    buf = jnp.zeros((e_count, capacity, d), cdt)
    buf = buf.at[sorted_e, slot].add(gathered)
    gate = jnp.einsum("ecd,edf->ecf", buf, wg)
    up = jnp.einsum("ecd,edf->ecf", buf, wu)
    act = jax.nn.gelu(gate, approximate=True) if cfg.mlp_activation == \
        "geglu" else jax.nn.silu(gate)
    out_buf = jnp.einsum("ecf,efd->ecd", act * up, wd)
    out = jnp.where(keep[:, None], out_buf[sorted_e, slot], 0.0)
    return jnp.zeros((n, d), cdt).at[sorted_tok].add(out)


def _apply_moe_alltoall(cfg: ModelConfig, params: Dict, x: jax.Array,
                        mesh) -> Tuple[jax.Array, Dict]:
    """shard_map expert parallelism with explicit all-to-all exchange.

    Token layout: (B->data, T->model); experts: E sharded over "model"
    (E_local = E/model per device).  Every device routes its local tokens,
    packs per-destination send buffers, all-to-alls them along "model",
    runs its local experts, and reverses the exchange.  Traffic per MoE
    layer = 2 x (local tokens x k x d) bf16 — the information-theoretic
    minimum for EP — instead of the replicate+all-reduce XLA emits for a
    global order-crossing scatter (measured 32 GB f32 per layer on jamba
    prefill_32k; see EXPERIMENTS.md §Perf).
    """
    from jax.sharding import PartitionSpec as P

    from repro.distributed.sharding import shard_map

    b, t, d = x.shape
    e, k = cfg.num_experts, cfg.num_experts_per_tok
    model = dict(mesh.shape).get("model", 1)
    e_local = e // model
    cdt = jnp.dtype(cfg.compute_dtype)

    batch_axes = tuple(a for a in ("pod", "data") if a in mesh.shape)
    bax = batch_axes[0] if len(batch_axes) == 1 else (
        tuple(batch_axes) if batch_axes else None)
    if bax is not None and b % int(np.prod(
            [mesh.shape[a] for a in batch_axes])):
        bax = None
    tax = "model" if (t % model == 0 and "model" in mesh.shape) else None

    def body(x_l, router_w, wg_l, wu_l, wd_l):
        b_l, t_l, _ = x_l.shape
        n_l = b_l * t_l
        x2d = x_l.reshape(n_l, d)
        top_idx, top_probs, aux = _router(cfg, params, x2d)
        aux = jax.tree_util.tree_map(
            lambda v: jax.lax.pmean(v, tuple(mesh.shape.keys())), aux)

        dest = (top_idx // e_local).astype(jnp.int32)      # (n_l, k)
        local_e = (top_idx % e_local).astype(jnp.int32)
        flat_dest = dest.reshape(-1)
        cap = int(np.ceil(n_l * k / model * cfg.capacity_factor))
        cap = max(cap, 8)

        # slot of each assignment inside its destination page
        order = jnp.argsort(flat_dest)
        inv = jnp.argsort(order)                            # stable inverse
        counts = jnp.bincount(flat_dest, length=model)
        starts = jnp.concatenate([jnp.zeros((1,), counts.dtype),
                                  jnp.cumsum(counts)[:-1]])
        pos_sorted = jnp.arange(n_l * k) - starts[flat_dest[order]]
        pos = pos_sorted[inv]                               # assignment slot
        keep = pos < cap
        slot = jnp.where(keep, pos, cap - 1).astype(jnp.int32)

        src_tok = jnp.repeat(jnp.arange(n_l, dtype=jnp.int32), k)
        send_x = jnp.zeros((model, cap, d), cdt)
        send_x = send_x.at[flat_dest, slot].add(
            jnp.where(keep[:, None], x2d[src_tok].astype(cdt), 0.0))
        send_e = jnp.zeros((model, cap), jnp.int32)
        send_e = send_e.at[flat_dest, slot].max(
            jnp.where(keep, local_e.reshape(-1), 0))

        recv_x = jax.lax.all_to_all(send_x, "model", 0, 0, tiled=True)
        recv_e = jax.lax.all_to_all(send_e, "model", 0, 0, tiled=True)
        # recv: (model*cap, d) tokens for MY experts
        out = _grouped_ffn(cfg, wg_l[0] if e_local == 1 else wg_l,
                           wu_l[0] if e_local == 1 else wu_l,
                           wd_l[0] if e_local == 1 else wd_l,
                           recv_x.reshape(model * cap, d),
                           recv_e.reshape(model * cap),
                           e_local, model * cap) \
            if e_local > 1 else None
        if e_local == 1:
            gate = recv_x.reshape(model * cap, d) @ wg_l[0]
            up = recv_x.reshape(model * cap, d) @ wu_l[0]
            act = jax.nn.gelu(gate, approximate=True) if \
                cfg.mlp_activation == "geglu" else jax.nn.silu(gate)
            out = (act * up) @ wd_l[0]
        back = jax.lax.all_to_all(out.reshape(model, cap, d), "model",
                                  0, 0, tiled=True).reshape(model, cap, d)
        # gather results back to assignments and weight by router probs
        res = back[flat_dest, slot]                         # (n_l*k, d)
        res = jnp.where(keep[:, None], res, 0.0)
        wts = top_probs.reshape(-1).astype(cdt)
        y2d = jnp.zeros((n_l, d), cdt).at[src_tok].add(res * wts[:, None])
        return y2d.reshape(b_l, t_l, d).astype(x_l.dtype), aux

    in_specs = (P(bax, tax, None), P(None, None),
                P("model", None, None), P("model", None, None),
                P("model", None, None))
    aux_spec = {"moe_lb_loss": P(), "moe_z_loss": P()}
    fn = shard_map(body, mesh=mesh,
                   in_specs=in_specs,
                   out_specs=(P(bax, tax, None), aux_spec),
                   check_vma=False)
    return fn(x, params["router"].astype(jnp.float32),
              params["w_gate"].astype(cdt), params["w_up"].astype(cdt),
              params["w_down"].astype(cdt))
