"""Mamba-2 (SSD — state-space duality) block [arXiv:2405.21060].

Chunked SSD forward for training/prefill (a port of the paper's
``ssd_minimal_discrete`` to jnp, organised as: intra-chunk quadratic part +
inter-chunk recurrent state passing via ``lax.scan``), plus an O(1)-state
decode step.  Layout: x (B, S, d_model) -> in_proj -> [z | xc | B | C | dt]
-> causal depthwise conv over (xc,B,C) -> SSD -> gated RMSNorm -> out_proj.

SOCKET does not apply to these layers (no KV cache) — DESIGN.md §5.
"""

from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.distributed.sharding import lsc
from repro.models import param as pm

__all__ = ["init_mamba", "mamba_train", "mamba_decode", "init_mamba_cache"]

N_GROUPS = 1  # B/C shared across heads (mamba2 default n_groups=1)


def _dims(cfg: ModelConfig):
    di = cfg.d_inner
    nh = cfg.ssm_heads
    st = cfg.ssm_state
    conv_dim = di + 2 * N_GROUPS * st
    return di, nh, st, conv_dim


def init_mamba(cfg: ModelConfig, rng: jax.Array) -> Dict:
    d = cfg.d_model
    di, nh, st, conv_dim = _dims(cfg)
    k1, k2, k3, k4 = jax.random.split(rng, 4)
    dtype = jnp.dtype(cfg.param_dtype)
    s = 1.0 / np.sqrt(d)
    proj_out = 2 * di + 2 * N_GROUPS * st + nh   # z, xc, B, C, dt
    # dt bias initialised so softplus(dt_bias) spans [1e-3, 1e-1]
    dt = jnp.exp(jax.random.uniform(k3, (nh,)) *
                 (np.log(0.1) - np.log(1e-3)) + np.log(1e-3))
    dt_bias = dt + jnp.log(-jnp.expm1(-dt))      # inverse softplus
    return {
        "in_proj": pm.normal(k1, (d, proj_out), ("embed_w", "ssm_inner"),
                             stddev=s, dtype=dtype),
        "conv_w": pm.normal(k2, (conv_dim, cfg.ssm_conv_width),
                            ("conv", None), stddev=0.5, dtype=dtype),
        "conv_b": pm.zeros((conv_dim,), ("conv",), dtype=dtype),
        "A_log": pm.constant(jnp.log(jnp.arange(1, nh + 1, dtype=jnp.float32)),
                             ("ssm_heads",)),
        "dt_bias": pm.constant(dt_bias.astype(jnp.float32), ("ssm_heads",)),
        "D": pm.ones((nh,), ("ssm_heads",)),
        "norm_scale": pm.ones((di,), ("ssm_inner",)),
        "out_proj": pm.normal(k4, (di, d), ("ssm_inner", "embed_w"),
                              stddev=1.0 / np.sqrt(di), dtype=dtype),
    }


def _split_proj(cfg: ModelConfig, proj: jax.Array):
    di, nh, st, _ = _dims(cfg)
    z, xc, bmat, cmat, dt = jnp.split(
        proj, [di, 2 * di, 2 * di + N_GROUPS * st,
               2 * di + 2 * N_GROUPS * st], axis=-1)
    return z, xc, bmat, cmat, dt


def _causal_conv(cfg: ModelConfig, params: Dict, u: jax.Array,
                 conv0=None) -> jax.Array:
    """Depthwise causal conv1d.  u: (B, S, C).

    ``conv0``: optional ``(B, K-1, C)`` carried tail of the *previous*
    segment's conv inputs (chunked prefill) — replaces the zero left-pad
    so a chunk's first outputs see exactly the history a whole-sequence
    run would."""
    w = params["conv_w"].astype(u.dtype)            # (C, K)
    k = w.shape[1]
    pad = jnp.pad(u, ((0, 0), (k - 1, 0), (0, 0))) if conv0 is None else \
        jnp.concatenate([conv0.astype(u.dtype), u], axis=1)
    out = jnp.zeros_like(u)
    for i in range(k):   # K=4: unrolled taps beat conv_general on TPU VPU
        out = out + pad[:, i:i + u.shape[1]] * w[None, None, :, i]
    return jax.nn.silu(out + params["conv_b"].astype(u.dtype))


def _segsum(x: jax.Array) -> jax.Array:
    """Stable segment-sum: out[..., i, j] = sum_{j<k<=i} x[..., k]."""
    t = x.shape[-1]
    cs = jnp.cumsum(x, axis=-1)
    seg = cs[..., :, None] - cs[..., None, :]
    mask = jnp.tril(jnp.ones((t, t), bool), k=0)
    return jnp.where(mask, seg, -jnp.inf)


def _ssd_chunked(cfg: ModelConfig, xh: jax.Array, dt: jax.Array,
                 a_coef: jax.Array, bmat: jax.Array, cmat: jax.Array,
                 h0: jax.Array | None = None):
    """Chunked SSD.  Shapes:
      xh (B,S,nh,hd) — inputs per head;  dt (B,S,nh) — discretization;
      a_coef (nh,) negative;  bmat/cmat (B,S,G,st).
    Returns (y (B,S,nh,hd), h_final (B,nh,hd,st)).
    """
    b, s, nh, hd = xh.shape
    st = bmat.shape[-1]
    q = min(cfg.ssm_chunk, s)
    s_orig = s
    if s % q:
        # zero-pad to a chunk multiple: dt=0 on padding => decay=1 and no
        # input contribution, so the carried state is unaffected.
        pad = q - s % q
        xh = jnp.pad(xh, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        bmat = jnp.pad(bmat, ((0, 0), (0, pad), (0, 0), (0, 0)))
        cmat = jnp.pad(cmat, ((0, 0), (0, pad), (0, 0), (0, 0)))
        s = s + pad
    nc = s // q

    # broadcast groups to heads
    bmat = jnp.repeat(bmat, nh // N_GROUPS, axis=2)   # (B,S,nh,st)
    cmat = jnp.repeat(cmat, nh // N_GROUPS, axis=2)

    xb = (xh * dt[..., None]).reshape(b, nc, q, nh, hd)
    da = (dt * a_coef[None, None, :]).reshape(b, nc, q, nh)  # (B,NC,Q,nh)
    bm = bmat.reshape(b, nc, q, nh, st)
    cm = cmat.reshape(b, nc, q, nh, st)

    da_t = jnp.transpose(da, (0, 1, 3, 2))            # (B,NC,nh,Q)
    da_cum = jnp.cumsum(da_t, axis=-1)                # within-chunk cumsum

    # 1. intra-chunk (quadratic) term
    l_mat = jnp.exp(_segsum(da_t))                    # (B,NC,nh,Q,Q)
    scores = jnp.einsum("bcqhs,bckhs->bchqk", cm, bm)  # (B,NC,nh,Q,Q)
    y_diag = jnp.einsum("bchqk,bchqk,bckhd->bcqhd",
                        scores, l_mat, xb)

    # 2. chunk-final states
    decay_states = jnp.exp(da_cum[..., -1:] - da_cum)  # (B,NC,nh,Q)
    states = jnp.einsum("bchq,bcqhs,bcqhd->bchds",
                        decay_states, bm, xb)          # (B,NC,nh,hd,st)

    # 3. inter-chunk recurrence over chunk index
    chunk_decay = jnp.exp(da_cum[..., -1])             # (B,NC,nh)

    def scan_fn(h, inp):
        st_c, dec = inp
        h_new = h * dec[..., None, None] + st_c
        return h_new, h

    if h0 is None:
        h0 = jnp.zeros((b, nh, hd, st), xh.dtype)
    h_final, h_prev = jax.lax.scan(
        scan_fn, h0,
        (jnp.moveaxis(states, 1, 0), jnp.moveaxis(chunk_decay, 1, 0)))
    h_prev = jnp.moveaxis(h_prev, 0, 1)                # (B,NC,nh,hd,st)

    # 4. contribution of carried state to each position
    state_decay = jnp.exp(da_cum)                      # (B,NC,nh,Q)
    y_off = jnp.einsum("bcqhs,bchds,bchq->bcqhd", cm, h_prev, state_decay)

    y = (y_diag + y_off).reshape(b, s, nh, hd)
    return y[:, :s_orig], h_final


def mamba_train(cfg: ModelConfig, params: Dict, x: jax.Array,
                h0=None, conv0=None, return_state: bool = False,
                last_index=None):
    """Full-sequence Mamba-2 block.  x: (B, S, d_model).

    ``last_index``: optional ``(B,)`` int32 of per-row last *real*
    positions (the serving engine pads prompts to a static bucket).
    Positions past it get ``dt = 0`` — decay ``exp(0·a) = 1`` and zero
    input, so they are exact identity steps and the returned ``ssm``
    state is the state at ``last_index``, bit-for-bit (the same trick
    the chunked SSD uses internally for chunk padding); the conv tail is
    likewise taken ending at ``last_index``.  Outputs at real positions
    are causal and unaffected.

    ``h0`` / ``conv0``: optional carried SSD state ``(B, nh, hd, st)``
    and conv tail ``(B, conv_width-1, conv_dim)`` from an earlier
    segment — chunked prefill runs the prompt through this function one
    chunk at a time, threading both through ``return_state``.  When the
    segment length is a multiple of ``ssm_chunk`` the chunk boundaries
    land on the SSD scan grid and the state trajectory is bit-identical
    to a whole-sequence run.
    """
    b, s, d = x.shape
    di, nh, st, conv_dim = _dims(cfg)
    cdt = jnp.dtype(cfg.compute_dtype)
    proj = x.astype(cdt) @ params["in_proj"].astype(cdt)
    z, xc, bmat, cmat, dt = _split_proj(cfg, proj)

    conv_in = jnp.concatenate([xc, bmat, cmat], axis=-1)  # (B,S,conv_dim)
    conv_out = _causal_conv(cfg, params, conv_in, conv0=conv0)
    xc, bmat, cmat = jnp.split(conv_out, [di, di + N_GROUPS * st], axis=-1)

    dt = jax.nn.softplus(dt.astype(jnp.float32) +
                         params["dt_bias"].astype(jnp.float32))
    if last_index is not None:
        li = jnp.asarray(last_index, jnp.int32)
        keep = jnp.arange(s, dtype=jnp.int32)[None, :] <= li[:, None]
        dt = dt * keep[..., None]
    a_coef = -jnp.exp(params["A_log"].astype(jnp.float32))

    xh = xc.reshape(b, s, nh, cfg.ssm_head_dim).astype(jnp.float32)
    bg = bmat.reshape(b, s, N_GROUPS, st).astype(jnp.float32)
    cg = cmat.reshape(b, s, N_GROUPS, st).astype(jnp.float32)
    if h0 is not None:
        h0 = h0.astype(jnp.float32)
    y, h_final = _ssd_chunked(cfg, xh, dt, a_coef, bg, cg, h0)
    y = y + xh * params["D"].astype(jnp.float32)[None, None, :, None]
    y = y.reshape(b, s, di).astype(cdt)

    # gated RMSNorm
    y = y * jax.nn.silu(z)
    var = jnp.mean(jnp.square(y.astype(jnp.float32)), axis=-1, keepdims=True)
    y = (y.astype(jnp.float32) * jax.lax.rsqrt(var + 1e-6) *
         params["norm_scale"].astype(jnp.float32)).astype(cdt)
    out = y @ params["out_proj"].astype(cdt)
    if return_state:
        kw = cfg.ssm_conv_width - 1
        # conv0 given: the carried tail prefixes conv_in, so a chunk whose
        # real tokens number fewer than kw reaches back into the previous
        # chunk's rows instead of zeroing them.
        ext = conv_in if conv0 is None else jnp.concatenate(
            [conv0.astype(conv_in.dtype), conv_in], axis=1)
        off = 0 if conv0 is None else kw
        if last_index is None:
            conv_tail = ext[:, -kw:]
            if ext.shape[1] < kw:
                conv_tail = jnp.pad(
                    ext, ((0, 0), (kw - ext.shape[1], 0), (0, 0)))
        else:
            idx = off + li[:, None] - kw + 1 + \
                jnp.arange(kw, dtype=jnp.int32)
            tail = jnp.take_along_axis(ext, jnp.maximum(idx, 0)[..., None],
                                       axis=1)
            conv_tail = jnp.where((idx >= 0)[..., None], tail, 0)
        return out.astype(x.dtype), {"ssm": h_final,
                                     "conv": conv_tail.astype(cdt)}
    return out.astype(x.dtype)


def init_mamba_cache(cfg: ModelConfig, batch: int, dtype=None) -> Dict:
    dtype = dtype or jnp.dtype(cfg.compute_dtype)
    di, nh, st, conv_dim = _dims(cfg)
    return {
        "ssm": jnp.zeros((batch, nh, cfg.ssm_head_dim, st), jnp.float32),
        "conv": jnp.zeros((batch, cfg.ssm_conv_width - 1, conv_dim), dtype),
    }


def mamba_cache_logical_axes() -> Dict:
    return {"ssm": ("cache_batch", "ssm_heads", None, None),
            "conv": ("cache_batch", None, "conv")}


def mamba_decode(cfg: ModelConfig, params: Dict, x: jax.Array,
                 cache: Dict) -> Tuple[jax.Array, Dict]:
    """Single-token recurrent update.  x: (B, 1, d_model)."""
    b = x.shape[0]
    di, nh, st, conv_dim = _dims(cfg)
    cdt = jnp.dtype(cfg.compute_dtype)
    proj = x[:, 0].astype(cdt) @ params["in_proj"].astype(cdt)  # (B, ·)
    z, xc, bmat, cmat, dt = _split_proj(cfg, proj)

    conv_in = jnp.concatenate([xc, bmat, cmat], axis=-1)        # (B, conv_dim)
    hist = jnp.concatenate([cache["conv"], conv_in[:, None]], axis=1)
    w = params["conv_w"].astype(cdt)                            # (C, K)
    conv_out = jnp.einsum("bkc,ck->bc", hist, w) + \
        params["conv_b"].astype(cdt)
    conv_out = jax.nn.silu(conv_out)
    xc, bmat, cmat = jnp.split(conv_out, [di, di + N_GROUPS * st], axis=-1)

    dt = jax.nn.softplus(dt.astype(jnp.float32) +
                         params["dt_bias"].astype(jnp.float32))   # (B, nh)
    a_coef = -jnp.exp(params["A_log"].astype(jnp.float32))        # (nh,)
    xh = xc.reshape(b, nh, cfg.ssm_head_dim).astype(jnp.float32)
    bg = jnp.repeat(bmat.reshape(b, N_GROUPS, st), nh // N_GROUPS,
                    axis=1).astype(jnp.float32)
    cg = jnp.repeat(cmat.reshape(b, N_GROUPS, st), nh // N_GROUPS,
                    axis=1).astype(jnp.float32)

    decay = jnp.exp(dt * a_coef[None])                            # (B, nh)
    h = cache["ssm"] * decay[..., None, None] + jnp.einsum(
        "bhd,bhs->bhds", xh * dt[..., None], bg)
    y = jnp.einsum("bhds,bhs->bhd", h, cg) + \
        xh * params["D"].astype(jnp.float32)[None, :, None]
    y = y.reshape(b, di).astype(cdt)

    y = y * jax.nn.silu(z)
    var = jnp.mean(jnp.square(y.astype(jnp.float32)), axis=-1, keepdims=True)
    y = (y.astype(jnp.float32) * jax.lax.rsqrt(var + 1e-6) *
         params["norm_scale"].astype(jnp.float32)).astype(cdt)
    out = (y @ params["out_proj"].astype(cdt))[:, None]
    new_cache = {"ssm": h,
                 "conv": hist[:, 1:].astype(cache["conv"].dtype)}
    return out.astype(x.dtype), new_cache
