"""The decoder stack: heterogeneous blocks, scan-over-groups, train /
prefill / decode entry points.

A model is ``pattern × num_groups + remainder`` blocks (configs.base).  The
repeated pattern is executed under ``jax.lax.scan`` with group-stacked
parameters so the lowered HLO contains ONE copy of the pattern body
regardless of depth — essential for 48-62-layer architectures both for
compile time (single-core CPU here, and real TPU fleets) and HLO size.
Remat policy is applied to the scan body.
"""

from __future__ import annotations

import functools
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import LayerSpec, ModelConfig
from repro.distributed.sharding import lsc
from repro.models import attention as attn
from repro.models import mamba as mb
from repro.models import moe as moe_mod
from repro.models import param as pm
from repro.models.layers import (apply_mlp, embed_tokens, init_embedding,
                                 init_mlp, init_rmsnorm, lm_head, rmsnorm)

__all__ = ["init_model", "forward_train", "loss_and_metrics", "prefill",
           "prefill_chunk", "decode_step", "init_decode_caches",
           "decode_cache_axes", "model_flops_per_token"]


# ------------------------------------------------------------------ blocks

def _init_block(cfg: ModelConfig, rng: jax.Array, spec: LayerSpec) -> Dict:
    k_mix, k_mlp = jax.random.split(rng)
    params: Dict = {"norm_mix": init_rmsnorm(cfg.d_model)}
    if spec.kind == "attn":
        params["attn"] = attn.init_attention(cfg, k_mix)
    else:
        params["mamba"] = mb.init_mamba(cfg, k_mix)
    if spec.mlp == "dense":
        params["norm_mlp"] = init_rmsnorm(cfg.d_model)
        params["mlp"] = init_mlp(cfg, k_mlp)
    elif spec.mlp == "moe":
        params["norm_mlp"] = init_rmsnorm(cfg.d_model)
        params["moe"] = moe_mod.init_moe(cfg, k_mlp)
    return params


def _block_train(cfg: ModelConfig, params: Dict, spec: LayerSpec,
                 x: jax.Array, positions: jax.Array):
    aux = {"moe_lb_loss": jnp.float32(0), "moe_z_loss": jnp.float32(0)}
    h = rmsnorm(params["norm_mix"], x)
    if spec.kind == "attn":
        h = attn.attention_train(cfg, params["attn"], h, positions,
                                 spec.attn_type)
    else:
        h = mb.mamba_train(cfg, params["mamba"], h)
    x = x + h
    if spec.mlp == "dense":
        x = x + apply_mlp(cfg, params["mlp"],
                          rmsnorm(params["norm_mlp"], x))
    elif spec.mlp == "moe":
        y, aux = moe_mod.apply_moe(cfg, params["moe"],
                                   rmsnorm(params["norm_mlp"], x))
        x = x + y
    return lsc(x, "batch", "act_seq", "embed"), aux


def _block_prefill(cfg: ModelConfig, params: Dict, spec: LayerSpec,
                   x: jax.Array, positions: jax.Array, capacity: int,
                   last_index=None, paged: bool = False):
    h = rmsnorm(params["norm_mix"], x)
    if spec.kind == "attn":
        h, cache = attn.attention_prefill(cfg, params["attn"], h, positions,
                                          spec.attn_type, capacity,
                                          last_index=last_index, paged=paged)
    else:
        h, cache = mb.mamba_train(cfg, params["mamba"], h,
                                  return_state=True, last_index=last_index)
    x = x + h
    if spec.mlp == "dense":
        x = x + apply_mlp(cfg, params["mlp"], rmsnorm(params["norm_mlp"], x))
    elif spec.mlp == "moe":
        y, _ = moe_mod.apply_moe(cfg, params["moe"],
                                 rmsnorm(params["norm_mlp"], x))
        x = x + y
    return lsc(x, "batch", "act_seq", "embed"), cache


def _block_prefill_chunk(cfg: ModelConfig, params: Dict, spec: LayerSpec,
                         x: jax.Array, positions: jax.Array, cache: Dict,
                         bt_row: jax.Array, slot: jax.Array,
                         history: jax.Array, last_index: jax.Array):
    """One block's share of one prefill chunk, writing the pool in place
    (see :func:`prefill_chunk`)."""
    h = rmsnorm(params["norm_mix"], x)
    if spec.kind == "attn":
        h, cache = attn.attention_prefill_chunk(
            cfg, params["attn"], h, positions, spec.attn_type, cache,
            bt_row, history, last_index)
    else:
        # Mamba state carries across chunks through the per-slot rows:
        # read the previous chunk's SSD state + conv tail, run the chunk
        # (padding past last_index is exact identity steps), write back.
        # The FIRST chunk starts from zeros — the slot row still holds the
        # previous occupant's state (nothing scrubs it on free).
        first = jnp.asarray(history, jnp.int32) == 0
        h0 = jnp.where(first, 0.0, cache["ssm"][slot][None])
        conv0 = jnp.where(first, 0.0, cache["conv"][slot][None])
        h, st = mb.mamba_train(cfg, params["mamba"], h, h0=h0, conv0=conv0,
                               return_state=True, last_index=last_index)
        cache = {
            "ssm": cache["ssm"].at[slot].set(
                st["ssm"][0].astype(cache["ssm"].dtype)),
            "conv": cache["conv"].at[slot].set(
                st["conv"][0].astype(cache["conv"].dtype)),
        }
    x = x + h
    if spec.mlp == "dense":
        x = x + apply_mlp(cfg, params["mlp"], rmsnorm(params["norm_mlp"], x))
    elif spec.mlp == "moe":
        y, _ = moe_mod.apply_moe(cfg, params["moe"],
                                 rmsnorm(params["norm_mlp"], x))
        x = x + y
    return x, cache


def _block_decode(cfg: ModelConfig, params: Dict, spec: LayerSpec,
                  x: jax.Array, cache: Dict, pos: jax.Array,
                  block_tables=None):
    h = rmsnorm(params["norm_mix"], x)
    if spec.kind == "attn":
        h, cache = attn.attention_decode(cfg, params["attn"], h, cache, pos,
                                         spec.attn_type,
                                         block_tables=block_tables)
    else:
        h, cache = mb.mamba_decode(cfg, params["mamba"], h, cache)
    x = x + h
    if spec.mlp == "dense":
        x = x + apply_mlp(cfg, params["mlp"], rmsnorm(params["norm_mlp"], x))
    elif spec.mlp == "moe":
        y, _ = moe_mod.apply_moe(cfg, params["moe"],
                                 rmsnorm(params["norm_mlp"], x))
        x = x + y
    return x, cache


def _block_cache(cfg: ModelConfig, spec: LayerSpec, batch: int,
                 capacity: int, long_context: bool, pool=None):
    """``pool`` (a ``ServingSettings``) switches to paged-pool layout:
    local-attention leaves become full ``block_size``-row pages (the ring
    handler addresses them circularly; no window truncation) and Mamba
    state is one row per decode slot instead of per block."""
    if spec.kind == "attn":
        ring_cap = pool.block_size if (
            pool is not None and spec.attn_type == "local") else None
        return attn.init_attention_cache(cfg, batch, capacity,
                                         spec.attn_type,
                                         long_context=long_context,
                                         ring_capacity=ring_cap)
    if pool is not None:
        return mb.init_mamba_cache(cfg, pool.max_batch)
    return mb.init_mamba_cache(cfg, batch)


def _block_cache_axes(cfg: ModelConfig, spec: LayerSpec, long_context: bool):
    if spec.kind == "attn":
        return attn.cache_logical_axes(cfg, spec.attn_type, long_context)
    return mb.mamba_cache_logical_axes()


# ------------------------------------------------------------------- model

def init_model(cfg: ModelConfig, rng: jax.Array):
    """Boxed parameter tree: {embed, groups, remainder, final_norm}."""
    k_emb, k_grp, k_rem = jax.random.split(rng, 3)
    params: Dict = {"embed": {}}
    emb = init_embedding(cfg, k_emb)
    if cfg.input_mode != "tokens":
        emb.pop("table", None)     # frontend stub supplies embeddings
    params["embed"] = emb

    group_trees = []
    for g in range(cfg.num_groups):
        kg = jax.random.fold_in(k_grp, g)
        tree = {}
        for i, spec in enumerate(cfg.pattern):
            tree[f"slot_{i}"] = _init_block(cfg, jax.random.fold_in(kg, i),
                                            spec)
        group_trees.append(tree)
    params["groups"] = pm.stack_boxed(group_trees)

    params["remainder"] = {
        f"slot_{i}": _init_block(cfg, jax.random.fold_in(k_rem, i), spec)
        for i, spec in enumerate(cfg.remainder)
    }
    params["final_norm"] = init_rmsnorm(cfg.d_model)
    return params


def _remat(cfg: ModelConfig, fn):
    # prevent_cse=False: we only ever remat inside lax.scan, where the loop
    # boundary already prevents CSE; True inserts barrier ops that XLA:CPU
    # handles by duplicating the saved carry stack in f32 (2.5x temps).
    if cfg.remat_policy == "none":
        return fn
    if cfg.remat_policy == "full":
        return jax.checkpoint(fn, prevent_cse=False,
                              policy=jax.checkpoint_policies.
                              nothing_saveable)
    if cfg.remat_policy == "dots":
        return jax.checkpoint(
            fn, prevent_cse=False,
            policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)
    raise ValueError(cfg.remat_policy)


def _input_embed(cfg: ModelConfig, params, batch: Dict) -> jax.Array:
    if cfg.input_mode == "tokens":
        return embed_tokens(cfg, params["embed"], batch["tokens"])
    return lsc(batch["embeds"].astype(jnp.dtype(cfg.compute_dtype)),
               "batch", "act_seq", "embed")


@jax.custom_vjp
def _fwd_barrier(x):
    # optimization_barrier has no differentiation rule in this jax; the
    # barrier is only needed on the forward carry (see group_body), so give
    # it a pass-through gradient.
    return jax.lax.optimization_barrier(x)


def _fwd_barrier_fwd(x):
    return _fwd_barrier(x), None


def _fwd_barrier_bwd(_, g):
    return (g,)


_fwd_barrier.defvjp(_fwd_barrier_fwd, _fwd_barrier_bwd)


def forward_train(cfg: ModelConfig, params, batch: Dict):
    """Full forward.  batch: {tokens|embeds, (positions)} -> (logits, aux)."""
    x = _input_embed(cfg, params, batch)
    b, s, _ = x.shape
    positions = batch.get("positions")
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))

    def group_body(carry, gparams):
        x, lb, zl = carry
        for i, spec in enumerate(cfg.pattern):
            x, aux = _block_train(cfg, gparams[f"slot_{i}"], spec, x,
                                  positions)
            lb = lb + aux["moe_lb_loss"]
            zl = zl + aux["moe_z_loss"]
        # barrier: stops XLA from hoisting the backward pass's f32 upcast
        # of the saved carry into the forward loop (which would materialize
        # a duplicate f32 residual stack — observed 2.5x temp blowup).
        x = _fwd_barrier(x)
        return (x, lb, zl), None

    body = _remat(cfg, group_body)
    (x, lb, zl), _ = jax.lax.scan(
        body, (x, jnp.float32(0), jnp.float32(0)), params["groups"])

    for i, spec in enumerate(cfg.remainder):
        x, aux = _block_train(cfg, params["remainder"][f"slot_{i}"], spec,
                              x, positions)
        lb = lb + aux["moe_lb_loss"]
        zl = zl + aux["moe_z_loss"]

    x = rmsnorm(params["final_norm"], x)
    logits = lm_head(cfg, params["embed"], x)
    n_moe = sum(1 for sp in cfg.layer_specs if sp.mlp == "moe") or 1
    return logits, {"moe_lb_loss": lb / n_moe, "moe_z_loss": zl / n_moe}


def loss_and_metrics(cfg: ModelConfig, params, batch: Dict,
                     lb_coef: float = 0.01):
    """Causal-LM loss.  batch[labels] (B,S) int32, -1 = padding."""
    logits, aux = forward_train(cfg, params, batch)
    labels = batch["labels"]
    v = logits.shape[-1]
    # mask out padded vocab entries
    if v > cfg.vocab_size:
        pad_mask = jnp.arange(v) >= cfg.vocab_size
        logits = jnp.where(pad_mask[None, None], -1e30, logits)
    valid = labels >= 0
    labels_safe = jnp.maximum(labels, 0)
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    token_ll = jnp.take_along_axis(logp, labels_safe[..., None],
                                   axis=-1)[..., 0]
    denom = jnp.maximum(jnp.sum(valid), 1)
    ce = -jnp.sum(jnp.where(valid, token_ll, 0.0)) / denom
    loss = ce + lb_coef * aux["moe_lb_loss"] + aux["moe_z_loss"]
    metrics = {"loss": loss, "ce": ce, "tokens": denom,
               "moe_lb_loss": aux["moe_lb_loss"]}
    return loss, metrics


# ----------------------------------------------------------------- serving

def init_decode_caches(cfg: ModelConfig, batch: int, capacity: int,
                       long_context: bool = False, pool=None):
    """Cache pytree: {"groups": stacked-per-group, "remainder": {...}}.

    ``pool``: optional ``ServingSettings`` — build the serving engine's
    paged pool instead (``batch = num_blocks``, ``capacity = block_size``;
    see :func:`_block_cache` for the per-kind layout differences).
    """
    def one_group():
        return {f"slot_{i}": _block_cache(cfg, spec, batch, capacity,
                                          long_context, pool)
                for i, spec in enumerate(cfg.pattern)}

    groups = jax.tree_util.tree_map(
        lambda *xs: jnp.stack(xs), *[one_group()
                                     for _ in range(cfg.num_groups)]) \
        if cfg.num_groups > 1 else jax.tree_util.tree_map(
            lambda x: x[None], one_group())
    rem = {f"slot_{i}": _block_cache(cfg, spec, batch, capacity,
                                     long_context, pool)
           for i, spec in enumerate(cfg.remainder)}
    return {"groups": groups, "remainder": rem}


def decode_cache_axes(cfg: ModelConfig, long_context: bool = False):
    groups = {f"slot_{i}": jax.tree_util.tree_map(
        lambda ax: ("groups",) + tuple(ax) if isinstance(ax, tuple) else ax,
        _block_cache_axes(cfg, spec, long_context),
        is_leaf=lambda x: isinstance(x, tuple))
        for i, spec in enumerate(cfg.pattern)}
    rem = {f"slot_{i}": _block_cache_axes(cfg, spec, long_context)
           for i, spec in enumerate(cfg.remainder)}
    return {"groups": groups, "remainder": rem}


def prefill(cfg: ModelConfig, params, batch: Dict, capacity: int,
            last_index=None, paged: bool = False):
    """Process the prompt, returning (last-token logits, caches).

    ``last_index``: optional ``(B,)`` int32 of per-request last *real*
    prompt positions.  The serving engine pads prompts up to a static
    bucket length; without it the returned logits would belong to the
    padding garbage rather than each prompt's true final token — and the
    sliding-window rings / Mamba states would absorb the padding (both
    are built *at* ``last_index`` when it is given).

    ``paged``: build caches in the serving engine's pool geometry where
    it differs from the static one (page-aligned local rings).
    """
    x = _input_embed(cfg, params, batch)
    b, s, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))

    def group_body(x, gparams):
        caches = {}
        for i, spec in enumerate(cfg.pattern):
            x, caches[f"slot_{i}"] = _block_prefill(
                cfg, gparams[f"slot_{i}"], spec, x, positions, capacity,
                last_index, paged)
        return x, caches

    x, group_caches = jax.lax.scan(group_body, x, params["groups"])

    rem_caches = {}
    for i, spec in enumerate(cfg.remainder):
        x, rem_caches[f"slot_{i}"] = _block_prefill(
            cfg, params["remainder"][f"slot_{i}"], spec, x, positions,
            capacity, last_index, paged)

    if last_index is None:
        x = x[:, -1:]
    else:
        x = x[jnp.arange(b), jnp.asarray(last_index, jnp.int32)][:, None]
    x = rmsnorm(params["final_norm"], x)
    logits = lm_head(cfg, params["embed"], x)
    return logits, {"groups": group_caches, "remainder": rem_caches}


def prefill_chunk(cfg: ModelConfig, params, caches, tokens: jax.Array,
                  *, bt_row: jax.Array, slot: jax.Array,
                  history: jax.Array, last_index: jax.Array):
    """One prefix-extension prefill chunk for the whole stack, directly
    against the serving engine's page pool.

    ``tokens``: ``(1, C)`` chunk token ids (the final chunk zero-padded to
    the static chunk length); ``caches``: the pool pytree (pages written
    in place, chunk attention reads committed history through the block
    table); ``bt_row``: ``(max_blocks_per_seq + C/block_size,)``
    trash-padded block ids; ``slot``: the request's decode slot (carries
    Mamba state across chunks); ``history``: tokens committed by earlier
    chunks (traced — one compile for every chunk index); ``last_index``:
    ``(1,)`` last real in-chunk index.

    Returns ``(logits (1,1,V) at last_index, updated caches)`` — the
    logits are only meaningful on the final chunk, where ``history +
    last_index + 1 == len(prompt)``.
    """
    x = embed_tokens(cfg, params["embed"], tokens)
    b, c, _ = x.shape
    positions = (jnp.asarray(history, jnp.int32) +
                 jnp.arange(c, dtype=jnp.int32))[None]
    positions = jnp.broadcast_to(positions, (b, c))

    def group_body(x, xs):
        gparams, gcache = xs
        new_caches = {}
        for i, spec in enumerate(cfg.pattern):
            x, new_caches[f"slot_{i}"] = _block_prefill_chunk(
                cfg, gparams[f"slot_{i}"], spec, x, positions,
                gcache[f"slot_{i}"], bt_row, slot, history, last_index)
        return x, new_caches

    x, group_caches = jax.lax.scan(
        group_body, x, (params["groups"], caches["groups"]))

    rem_caches = {}
    for i, spec in enumerate(cfg.remainder):
        x, rem_caches[f"slot_{i}"] = _block_prefill_chunk(
            cfg, params["remainder"][f"slot_{i}"], spec, x, positions,
            caches["remainder"][f"slot_{i}"], bt_row, slot, history,
            last_index)

    li = jnp.asarray(last_index, jnp.int32).reshape(b)
    x = x[jnp.arange(b), li][:, None]
    x = rmsnorm(params["final_norm"], x)
    logits = lm_head(cfg, params["embed"], x)
    return logits, {"groups": group_caches, "remainder": rem_caches}


def decode_step(cfg: ModelConfig, params, caches, inputs: jax.Array,
                pos: jax.Array, block_tables=None):
    """One token for the whole stack.

    inputs: (B, 1) token ids or (B, 1, d) embeddings; pos: scalar int32 or
    a ``(B,)`` vector of per-request positions (ragged serving batch — see
    :func:`repro.models.attention.attention_decode`).

    ``block_tables``: per-request ``(B, blocks_per_seq)`` physical block
    ids — when given, ``caches`` is the serving engine's page pool (leaves
    ``(num_blocks, KVH, block_size, ...)``, shared block ids across
    layers) and attention layers read/write it through ``PagedView``.
    Returns (logits (B,1,V), updated caches).
    """
    if cfg.input_mode == "tokens":
        x = embed_tokens(cfg, params["embed"], inputs)
    else:
        x = inputs.astype(jnp.dtype(cfg.compute_dtype))

    def group_body(x, xs):
        gparams, gcache = xs
        new_caches = {}
        for i, spec in enumerate(cfg.pattern):
            x, new_caches[f"slot_{i}"] = _block_decode(
                cfg, gparams[f"slot_{i}"], spec, x, gcache[f"slot_{i}"],
                pos, block_tables)
        return x, new_caches

    x, new_group_caches = jax.lax.scan(
        group_body, x, (params["groups"], caches["groups"]))

    new_rem = {}
    for i, spec in enumerate(cfg.remainder):
        x, new_rem[f"slot_{i}"] = _block_decode(
            cfg, params["remainder"][f"slot_{i}"], spec, x,
            caches["remainder"][f"slot_{i}"], pos, block_tables)

    x = rmsnorm(params["final_norm"], x)
    logits = lm_head(cfg, params["embed"], x)
    return logits, {"groups": new_group_caches, "remainder": new_rem}


# ------------------------------------------------------------- accounting

def model_flops_per_token(cfg: ModelConfig, seq_len: int,
                          training: bool = True) -> float:
    """MODEL_FLOPS: 6·N_active·D-style accounting (+ attention quadratic
    term), for the roofline's useful-compute ratio."""
    n_active = cfg.active_param_count()
    mult = 6.0 if training else 2.0
    flops = mult * n_active
    # attention score+value flops per token: 2 * 2 * H * hd * attended
    attended = 0.0
    for spec in cfg.layer_specs:
        if spec.kind != "attn":
            continue
        span = seq_len / 2 if spec.attn_type == "global" else min(
            cfg.sliding_window, seq_len / 2)
        attended += span
    flops += mult / 3 * 2 * 2 * cfg.num_heads * cfg.head_dim * attended * 3
    return flops
