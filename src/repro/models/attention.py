"""GQA attention with pluggable sparse decode backends.

Training/prefill: dense causal attention (XLA einsum path — the Pallas
``flash_prefill`` kernel is the TPU fast path and is validated against the
same math in tests).  Local layers apply a sliding-window mask.

Decode: the KV cache is ``(B, KVH, N, hd)``.  Global layers dispatch on
``cfg.attention_backend``:

* ``socket``    — the paper's technique (Algorithms 1-3): packed hash bits +
                  value norms live in the cache; scoring via the factorized
                  soft-collision kernel; exact attention over top-k.
* ``hard_lsh``  — same cached bits, hard collision counting (ablation).
* ``quest``     — page min/max metadata + page top-k.
* ``dense``     — full attention (baseline / roofline reference).

Local (sliding-window) layers decode from a ring buffer of ``window`` slots
— for gemma3's 5:1 pattern this keeps the long_500k cache bounded by the
window on 52 of 62 layers (DESIGN.md §5).
"""

from __future__ import annotations

import math
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.baselines import oracle
from repro.configs.base import ModelConfig
from repro.core import hashing, socket
from repro.distributed import sharding as shd
from repro.distributed.sharding import lsc
from repro.models import param as pm
from repro.models.layers import apply_rope, init_rmsnorm, rmsnorm, softcap

__all__ = ["init_attention", "attention_train", "attention_prefill",
           "attention_decode", "init_attention_cache", "socket_config_of"]

NEG_INF = -1e30


def socket_config_of(cfg: ModelConfig) -> socket.SocketConfig:
    s = cfg.socket
    return socket.SocketConfig(
        num_planes=s.num_planes, num_tables=s.num_tables, tau=s.tau,
        sparsity=s.sparsity, sink_tokens=s.sink_tokens,
        window_tokens=s.window_tokens, min_k=s.min_k,
        bits_storage=s.bits_storage, score_chunk=s.score_chunk,
        score_dtype=s.score_dtype, selection=s.selection)


def _eff_heads(cfg: ModelConfig) -> Tuple[int, int]:
    """(num_heads, num_kv_heads) after optional zero-padding for TP."""
    if not cfg.logical_pad_heads:
        return cfg.num_heads, cfg.num_kv_heads
    pad = 16

    def up(x):
        return ((x + pad - 1) // pad) * pad

    h = up(cfg.num_heads)
    kv = cfg.num_kv_heads
    while h % kv:  # keep exact grouping
        h += pad
    return h, kv


# ------------------------------------------------------------------ init

def init_attention(cfg: ModelConfig, rng: jax.Array) -> Dict:
    d, hd = cfg.d_model, cfg.head_dim
    h, kv = _eff_heads(cfg)
    k1, k2, k3, k4, k5 = jax.random.split(rng, 5)
    s = 1.0 / np.sqrt(d)
    so = 1.0 / np.sqrt(h * hd)
    dtype = jnp.dtype(cfg.param_dtype)
    params = {
        "wq": pm.normal(k1, (d, h, hd), ("embed_w", "heads", None),
                        stddev=s, dtype=dtype),
        "wk": pm.normal(k2, (d, kv, hd), ("embed_w", "kv_heads", None),
                        stddev=s, dtype=dtype),
        "wv": pm.normal(k3, (d, kv, hd), ("embed_w", "kv_heads", None),
                        stddev=s, dtype=dtype),
        "wo": pm.normal(k4, (h, hd, d), ("heads", None, "embed_w"),
                        stddev=so, dtype=dtype),
    }
    if cfg.logical_pad_heads and h != cfg.num_heads:
        # zero the padded q heads and their output rows => exact function.
        mask = (jnp.arange(h) < cfg.num_heads).astype(dtype)
        params["wq"].value = params["wq"].value * mask[None, :, None]
        params["wo"].value = params["wo"].value * mask[:, None, None]
    if cfg.qk_norm:
        params["q_norm"] = init_rmsnorm(hd)
        params["k_norm"] = init_rmsnorm(hd)
    # SOCKET hyperplanes (Algorithm 1): data-agnostic, never trained.
    sset = cfg.socket
    params["hash_w"] = pm.constant(
        jax.random.normal(k5, (sset.num_tables, sset.num_planes, hd),
                          jnp.float32),
        ("tables", None, None))
    return params


# ------------------------------------------------------------- projections

def _project_qkv(cfg: ModelConfig, params: Dict, x: jax.Array,
                 positions: jax.Array):
    cdt = jnp.dtype(cfg.compute_dtype)
    x = x.astype(cdt)
    q = jnp.einsum("btd,dhk->bthk", x, params["wq"].astype(cdt))
    k = jnp.einsum("btd,dhk->bthk", x, params["wk"].astype(cdt))
    v = jnp.einsum("btd,dhk->bthk", x, params["wv"].astype(cdt))
    if cfg.qk_norm:
        q = rmsnorm(params["q_norm"], q)
        k = rmsnorm(params["k_norm"], k)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


def _merge_heads(cfg: ModelConfig, params: Dict, ctx: jax.Array) -> jax.Array:
    cdt = jnp.dtype(cfg.compute_dtype)
    return jnp.einsum("bthk,hkd->btd", ctx.astype(cdt),
                      params["wo"].astype(cdt))


# ------------------------------------------------------------------ train

def _use_repeat_kv(h_eff: int, kv: int) -> bool:
    """GQA sharding strategy (DESIGN.md §4): the grouped (kv, g) einsum
    layout cannot be sharded when kv_heads doesn't divide the model axis —
    XLA then replicates *all* heads and the (B,H,T,S) logits explode.
    Repeating K/V up to the flat q-head axis keeps 16-way head sharding at
    the cost of a cheap KV broadcast (k/v are tiny next to the logits)."""
    mesh = shd.current_mesh()
    if mesh is None:
        return False
    model = dict(mesh.shape).get("model", 1)
    return (kv % model != 0) and (h_eff % model == 0) and h_eff != kv


def _attn_chunk(cfg: ModelConfig, qg: jax.Array, k: jax.Array, v: jax.Array,
                q_offset, attn_type: str, scale: float,
                repeat_kv: bool) -> jax.Array:
    """Attention of a block of queries against the full K/V (exact,
    full-row softmax).

    grouped:   qg (B, cq, KV, G, hd); k/v (B, S, KV, hd)
    repeat_kv: qg (B, cq, H, hd);     k/v (B, S, H, hd)  (pre-repeated)
    """
    cq = qg.shape[1]
    s = k.shape[1]
    if repeat_kv:
        logits = jnp.einsum("bthd,bshd->bhts", qg.astype(jnp.float32),
                            k.astype(jnp.float32)) * scale
    else:
        logits = jnp.einsum("btkgd,bskd->bkgts", qg.astype(jnp.float32),
                            k.astype(jnp.float32)) * scale
    logits = softcap(logits, cfg.attn_logit_softcap)
    ti = q_offset + jnp.arange(cq)[:, None]
    si = jnp.arange(s)[None, :]
    mask = si <= ti
    if attn_type == "local":
        mask &= (ti - si) < cfg.sliding_window
    if repeat_kv:
        logits = jnp.where(mask[None, None], logits, NEG_INF)
        w = jax.nn.softmax(logits, axis=-1)
        return jnp.einsum("bhts,bshd->bthd", w, v.astype(jnp.float32))
    logits = jnp.where(mask[None, None, None], logits, NEG_INF)
    w = jax.nn.softmax(logits, axis=-1)
    return jnp.einsum("bkgts,bskd->btkgd", w, v.astype(jnp.float32))


def attention_train(cfg: ModelConfig, params: Dict, x: jax.Array,
                    positions: jax.Array, attn_type: str) -> jax.Array:
    """Dense causal attention (optionally sliding-window) for training.

    x: (B, T, d); positions: (B, T).  When ``cfg.attn_q_chunk`` divides T,
    queries are processed in chunks under ``lax.scan`` so the live logits
    buffer is (chunk, T) instead of (T, T) — the XLA-path equivalent of the
    flash_prefill kernel's memory behaviour (exact same math).
    """
    b, t, d = x.shape
    h_eff = params["wq"].shape[1]
    kv = params["wk"].shape[1]
    g = h_eff // kv
    q, k, v = _project_qkv(cfg, params, x, positions)
    q = lsc(q, "batch", "seq", "q_heads", None)
    scale = 1.0 / np.sqrt(cfg.head_dim)

    repeat_kv = _use_repeat_kv(h_eff, kv)
    if repeat_kv:
        qg = q                                       # (b,t,h,hd)
        k = lsc(jnp.repeat(k, g, axis=2), "batch", "seq", "q_heads", None)
        v = lsc(jnp.repeat(v, g, axis=2), "batch", "seq", "q_heads", None)
    else:
        qg = q.reshape(b, t, kv, g, cfg.head_dim)

    cq = cfg.attn_q_chunk
    if cq and t > cq and t % cq == 0:
        nc = t // cq
        q_chunks = jnp.moveaxis(
            qg.reshape(b, nc, cq, *qg.shape[2:]), 1, 0)
        offsets = jnp.arange(nc, dtype=jnp.int32) * cq

        def body(_, inp):
            qc, off = inp
            return None, _attn_chunk(cfg, qc, k, v, off, attn_type, scale,
                                     repeat_kv)

        _, ctx_chunks = jax.lax.scan(body, None, (q_chunks, offsets))
        ctx = jnp.moveaxis(ctx_chunks, 0, 1)
    else:
        ctx = _attn_chunk(cfg, qg, k, v, 0, attn_type, scale, repeat_kv)
    ctx = ctx.reshape(b, t, h_eff, cfg.head_dim).astype(x.dtype)
    return _merge_heads(cfg, params, ctx)


# ------------------------------------------------------------------ cache

def init_attention_cache(cfg: ModelConfig, batch: int, capacity: int,
                         attn_type: str, dtype=None,
                         long_context: bool = False) -> Dict:
    """Allocate one layer's decode cache (zeros); returns the pytree.

    ``long_context`` switches the sequence axis to context-parallel
    sharding (annotated logically; physical placement set by the launcher).
    """
    dtype = dtype or jnp.dtype(cfg.compute_dtype)
    _, kv = _eff_heads(cfg)
    hd = cfg.head_dim
    if attn_type == "local":
        cap = min(capacity, cfg.sliding_window)
        return {
            "k": jnp.zeros((batch, kv, cap, hd), dtype),
            "v": jnp.zeros((batch, kv, cap, hd), dtype),
        }
    cache = {
        "k": jnp.zeros((batch, kv, capacity, hd), dtype),
        "v": jnp.zeros((batch, kv, capacity, hd), dtype),
    }
    backend = cfg.attention_backend
    if backend in ("socket", "hard_lsh"):
        scfg = socket_config_of(cfg)
        if scfg.bits_storage == "packed":
            w = hashing.num_words(scfg.num_tables, scfg.num_planes)
            cache["bits"] = jnp.zeros((batch, kv, capacity, w), jnp.uint32)
        else:
            cache["bits"] = jnp.zeros(
                (batch, kv, capacity, scfg.num_tables * scfg.num_planes),
                jnp.int8)
        cache["vnorm"] = jnp.zeros((batch, kv, capacity), jnp.bfloat16)
    elif backend == "quest":
        ps = 16
        n_pages = (capacity + ps - 1) // ps
        cache["kmin"] = jnp.full((batch, kv, n_pages, hd), np.inf, dtype)
        cache["kmax"] = jnp.full((batch, kv, n_pages, hd), -np.inf, dtype)
    return cache


def cache_logical_axes(cfg: ModelConfig, attn_type: str,
                       long_context: bool = False) -> Dict:
    """Logical axis names mirroring :func:`init_attention_cache`."""
    seq = "cache_seq_cp" if long_context else "cache_seq"
    base = {"k": ("cache_batch", "cache_heads", seq, None),
            "v": ("cache_batch", "cache_heads", seq, None)}
    if attn_type == "local":
        return {"k": ("cache_batch", "cache_heads", "cache_seq", None),
                "v": ("cache_batch", "cache_heads", "cache_seq", None)}
    backend = cfg.attention_backend
    if backend in ("socket", "hard_lsh"):
        base["bits"] = ("cache_batch", "cache_heads", seq, None)
        base["vnorm"] = ("cache_batch", "cache_heads", seq)
    elif backend == "quest":
        base["kmin"] = ("cache_batch", "cache_heads", seq, None)
        base["kmax"] = ("cache_batch", "cache_heads", seq, None)
    return base


# ---------------------------------------------------------------- prefill

def attention_prefill(cfg: ModelConfig, params: Dict, x: jax.Array,
                      positions: jax.Array, attn_type: str,
                      capacity: int) -> Tuple[jax.Array, Dict]:
    """Forward over the prompt + build this layer's decode cache.

    Output matches :func:`attention_train`; cache covers positions [0, T).
    """
    b, t, _ = x.shape
    y = attention_train(cfg, params, x, positions, attn_type)
    q, k, v = _project_qkv(cfg, params, x, positions)  # recompute, cheap
    kc = jnp.swapaxes(k, 1, 2)   # (B,KV,T,hd)
    vc = jnp.swapaxes(v, 1, 2)
    cache = init_attention_cache(cfg, b, capacity, attn_type,
                                 dtype=kc.dtype)
    if attn_type == "local":
        cap = cache["k"].shape[2]
        # last `cap` tokens into ring slots (position p -> slot p % cap)
        take = jnp.arange(cap)
        src = jnp.maximum(t - cap, 0) + take          # positions kept
        slot = src % cap
        cache["k"] = cache["k"].at[:, :, slot].set(
            jnp.take(kc, src, axis=2))
        cache["v"] = cache["v"].at[:, :, slot].set(
            jnp.take(vc, src, axis=2))
        return y, cache
    cache["k"] = cache["k"].at[:, :, :t].set(kc)
    cache["v"] = cache["v"].at[:, :, :t].set(vc)
    backend = cfg.attention_backend
    if backend in ("socket", "hard_lsh"):
        scfg = socket_config_of(cfg)
        side = socket.precompute_key_hashes(
            scfg, jax.lax.stop_gradient(params["hash_w"]), kc, vc)
        cache["bits"] = cache["bits"].at[:, :, :t].set(side.bits)
        cache["vnorm"] = cache["vnorm"].at[:, :, :t].set(
            side.vnorm.astype(cache["vnorm"].dtype))
    elif backend == "quest":
        ps = 16
        n_pages_t = (t + ps - 1) // ps
        pad = n_pages_t * ps - t
        kpad_min = jnp.pad(kc, ((0, 0), (0, 0), (0, pad), (0, 0)),
                           constant_values=np.inf)
        kpad_max = jnp.pad(kc, ((0, 0), (0, 0), (0, pad), (0, 0)),
                           constant_values=-np.inf)
        kmin = kpad_min.reshape(b, kc.shape[1], n_pages_t, ps,
                                cfg.head_dim).min(axis=3)
        kmax = kpad_max.reshape(b, kc.shape[1], n_pages_t, ps,
                                cfg.head_dim).max(axis=3)
        cache["kmin"] = cache["kmin"].at[:, :, :n_pages_t].set(kmin)
        cache["kmax"] = cache["kmax"].at[:, :, :n_pages_t].set(kmax)
    return y, cache


# ----------------------------------------------------------------- decode

def _decode_update_global(cfg: ModelConfig, params: Dict, cache: Dict,
                          k_new: jax.Array, v_new: jax.Array,
                          pos: jax.Array) -> Dict:
    """Append the new token's K/V (+ backend metadata) at index ``pos``.

    ``pos`` is a scalar (whole batch at one position) or a ``(B,)`` vector
    of per-request positions (ragged serving batch → per-row scatter).
    """
    cache = dict(cache)
    kc = jnp.swapaxes(k_new, 1, 2)  # (B,KV,1,hd)
    vc = jnp.swapaxes(v_new, 1, 2)
    b, kv, _, hd = kc.shape
    ragged = jnp.ndim(pos) == 1
    if ragged:
        bidx = jnp.arange(b)
        cache["k"] = cache["k"].at[bidx, :, pos].set(
            kc[:, :, 0].astype(cache["k"].dtype))
        cache["v"] = cache["v"].at[bidx, :, pos].set(
            vc[:, :, 0].astype(cache["v"].dtype))
    else:
        cache["k"] = jax.lax.dynamic_update_slice(
            cache["k"], kc.astype(cache["k"].dtype), (0, 0, pos, 0))
        cache["v"] = jax.lax.dynamic_update_slice(
            cache["v"], vc.astype(cache["v"].dtype), (0, 0, pos, 0))
    backend = cfg.attention_backend
    if backend in ("socket", "hard_lsh"):
        scfg = socket_config_of(cfg)
        side = socket.precompute_key_hashes(scfg, params["hash_w"], kc, vc)
        if ragged:
            bidx = jnp.arange(b)
            cache["bits"] = cache["bits"].at[bidx, :, pos].set(
                side.bits[:, :, 0])
            cache["vnorm"] = cache["vnorm"].at[bidx, :, pos].set(
                side.vnorm[:, :, 0].astype(cache["vnorm"].dtype))
        else:
            cache["bits"] = jax.lax.dynamic_update_slice(
                cache["bits"], side.bits, (0, 0, pos, 0))
            cache["vnorm"] = jax.lax.dynamic_update_slice(
                cache["vnorm"], side.vnorm.astype(cache["vnorm"].dtype),
                (0, 0, pos))
    elif backend == "quest":
        page = pos // 16
        if ragged:
            bidx = jnp.arange(b)
            knew = kc[:, :, 0]
            cache["kmin"] = cache["kmin"].at[bidx, :, page].min(
                knew.astype(cache["kmin"].dtype))
            cache["kmax"] = cache["kmax"].at[bidx, :, page].max(
                knew.astype(cache["kmax"].dtype))
        else:
            old_min = jax.lax.dynamic_slice(
                cache["kmin"], (0, 0, page, 0), (b, kv, 1, hd))
            old_max = jax.lax.dynamic_slice(
                cache["kmax"], (0, 0, page, 0), (b, kv, 1, hd))
            cache["kmin"] = jax.lax.dynamic_update_slice(
                cache["kmin"], jnp.minimum(old_min,
                                           kc.astype(old_min.dtype)),
                (0, 0, page, 0))
            cache["kmax"] = jax.lax.dynamic_update_slice(
                cache["kmax"], jnp.maximum(old_max,
                                           kc.astype(old_max.dtype)),
                (0, 0, page, 0))
    return cache


def _hard_lsh_decode_scores(scfg: socket.SocketConfig, bits: jax.Array,
                            u_signs: jax.Array) -> jax.Array:
    """Hard collision counts from the same packed bits (tau->0 ablation)."""
    l, p = scfg.num_tables, scfg.num_planes
    k_signs = hashing.unpack_signs(bits, l, p)           # (B,KV,N,L,P)
    agree = jnp.einsum("bknlp,bkglp->bkgnl", k_signs, u_signs)
    return jnp.sum((agree >= p).astype(jnp.float32), axis=-1)


def attention_decode(cfg: ModelConfig, params: Dict, x: jax.Array,
                     cache: Dict, pos: jax.Array, attn_type: str,
                     ) -> Tuple[jax.Array, Dict]:
    """One decode step.  x: (B, 1, d); pos: scalar int32 (current index)
    OR a ``(B,)`` int32 vector of per-request indices (ragged serving
    batch — each row of the batch sits at its own context length).

    In the ragged case SOCKET's top-k budget is applied *per request* from
    each live length (``k_r = clip(ceil(len_r / sparsity), min_k, k_cap)``)
    via dynamic masking under a static ``top_k`` — the serving-engine
    realization of the paper's ``k = N / sparsity``.

    Returns (y (B,1,d), updated cache).
    """
    b = x.shape[0]
    hd = cfg.head_dim
    h_eff = params["wq"].shape[1]
    kv = params["wk"].shape[1]
    g = h_eff // kv
    scale = 1.0 / np.sqrt(hd)
    ragged = jnp.ndim(pos) == 1
    positions = jnp.reshape(pos, (b, 1)).astype(jnp.int32) if ragged \
        else jnp.full((b, 1), pos, jnp.int32)
    q, k_new, v_new = _project_qkv(cfg, params, x, positions)
    qg = jnp.transpose(q.reshape(b, 1, kv, g, hd), (0, 2, 3, 1, 4))
    # qg: (B, KV, G, 1, hd)

    if attn_type == "local":
        cap = cache["k"].shape[2]
        slot = pos % cap
        cache = dict(cache)
        if ragged:
            bidx = jnp.arange(b)
            cache["k"] = cache["k"].at[bidx, :, slot].set(
                k_new[:, 0].astype(cache["k"].dtype))
            cache["v"] = cache["v"].at[bidx, :, slot].set(
                v_new[:, 0].astype(cache["v"].dtype))
        else:
            cache["k"] = jax.lax.dynamic_update_slice(
                cache["k"],
                jnp.swapaxes(k_new, 1, 2).astype(cache["k"].dtype),
                (0, 0, slot, 0))
            cache["v"] = jax.lax.dynamic_update_slice(
                cache["v"],
                jnp.swapaxes(v_new, 1, 2).astype(cache["v"].dtype),
                (0, 0, slot, 0))
        # ring-slot absolute positions; invalid slots masked out
        sl = jnp.arange(cap, dtype=jnp.int32)
        pos_b = pos[:, None] if ragged else pos     # (B,1) | scalar
        ring_pos = pos_b - ((pos_b - sl) % cap)      # (B,cap) | (cap,)
        valid = ring_pos >= 0
        if not ragged:
            valid = valid[None]
        logits = jnp.einsum("bkgtd,bknd->bkgtn", qg.astype(jnp.float32),
                            cache["k"].astype(jnp.float32)) * scale
        logits = softcap(logits, cfg.attn_logit_softcap)
        logits = jnp.where(valid[:, None, None, None], logits, NEG_INF)
        w = jax.nn.softmax(logits, axis=-1)
        ctx = jnp.einsum("bkgtn,bknd->bkgtd", w,
                         cache["v"].astype(jnp.float32))
    else:
        cache = _decode_update_global(cfg, params, cache, k_new, v_new, pos)
        length = pos + 1
        backend = cfg.attention_backend
        if ragged and backend in ("socket", "hard_lsh"):
            scfg = socket_config_of(cfg)
            budget = socket.dynamic_topk_budget(
                scfg, length, socket.topk_budget(scfg, cache["k"].shape[2]))
        else:
            budget = None
        if backend == "dense":
            ctx = oracle.dense_attention(qg, cache["k"], cache["v"],
                                         scale=scale, length=length)
        elif backend == "socket":
            scfg = socket_config_of(cfg)
            mesh = shd.current_mesh()
            if cfg.decode_cp_axes and mesh is not None and any(
                    a in mesh.shape for a in cfg.decode_cp_axes):
                if ragged:
                    raise NotImplementedError(
                        "ragged decode + context-parallel SOCKET: use the "
                        "pjit/XLA path (decode_cp_axes=())")
                # §Perf: shard_map context-parallel path — local top-k per
                # sequence shard + psum online-softmax merge; avoids
                # materializing the (B,KVH,N) global score tensor
                from repro.distributed.context_parallel import \
                    context_parallel_socket_attend
                ctx = context_parallel_socket_attend(
                    scfg, mesh, cfg.decode_cp_axes, params["hash_w"], qg,
                    cache["k"], cache["v"], cache["bits"],
                    cache["vnorm"].astype(jnp.float32),
                    length=length, scale=scale,
                    batch_axes=cfg.decode_cp_batch_axes)
            else:
                ctx = socket.socket_attend(
                    scfg, params["hash_w"], qg, cache["k"], cache["v"],
                    socket.SocketCache(bits=cache["bits"],
                                       vnorm=cache["vnorm"]),
                    length=length, scale=scale, budget=budget)
        elif backend == "hard_lsh":
            scfg = socket_config_of(cfg)
            n = cache["k"].shape[2]
            u = socket.soft_hash_query(params["hash_w"], qg[..., 0, :])
            u_signs = jnp.where(u >= 0, 1.0, -1.0)
            scores = _hard_lsh_decode_scores(scfg, cache["bits"], u_signs)
            scores = jnp.sum(scores, axis=2)
            kq = socket.topk_budget(scfg, n)
            idx, sel_mask = socket.value_aware_topk(
                scfg, scores, cache["vnorm"].astype(jnp.float32), k=kq,
                length=length, n_total=n, budget=budget)
            k_sel = jnp.take_along_axis(cache["k"], idx[..., None], axis=2)
            v_sel = jnp.take_along_axis(cache["v"], idx[..., None], axis=2)
            ctx = socket.sparse_attention_over_subset(
                qg, k_sel, v_sel, sel_mask, scale=scale)
        elif backend == "quest":
            from repro.baselines import quest as quest_mod
            qcfg = quest_mod.QuestConfig(
                page_size=16, sparsity=cfg.socket.sparsity,
                sink_tokens=cfg.socket.sink_tokens,
                window_tokens=cfg.socket.window_tokens)
            state = quest_mod.QuestState(kmin=cache["kmin"],
                                         kmax=cache["kmax"])
            ctx = quest_mod.attend(qcfg, state, qg, cache["k"], cache["v"],
                                   length=length, scale=scale)
        else:
            raise ValueError(backend)

    ctx = jnp.transpose(ctx, (0, 3, 1, 2, 4)).reshape(b, 1, h_eff, hd)
    return _merge_heads(cfg, params, ctx.astype(x.dtype)), cache
