"""GQA attention with pluggable sparse decode backends.

Training/prefill: dense causal attention (XLA einsum path — the Pallas
``flash_prefill`` kernel is the TPU fast path and is validated against the
same math in tests).  Local layers apply a sliding-window mask.

Chunked serving prefill (:func:`attention_prefill_chunk`) runs the same
dense math one ``prefill_chunk`` at a time directly against the engine's
page pool: the chunk's K/V + backend metadata are committed first, then
its queries attend causally over the paged logical view (prefix-extension
attention — the in-chunk causal mask composes with the context earlier
chunks committed; local layers compose the pre-write ring with in-chunk
K/V under the window mask).

Decode — the ``DecodeBackend`` / ``KVView`` contract
----------------------------------------------------

Global layers own no backend logic: every decode backend (``socket``,
``hard_lsh``, ``quest``, ``dense``, …) is one module in
:mod:`repro.models.backends` implementing the
:class:`~repro.models.backends.DecodeBackend` interface and registered
under its ``cfg.attention_backend`` name:

* ``cache_spec(cfg)``     — declarative leaf layout (trailing shape,
                            dtype, sequence granularity, init fill);
                            :func:`init_attention_cache` and
                            :func:`cache_logical_axes` derive from it.
* ``prefill_build(...)``  — prompt K/V rows + backend metadata into a
                            fresh contiguous cache.
* ``append(...)``         — one new token through a ``KVView``.
* ``attend(...)``         — decode attention against a ``KVView``.

A :class:`~repro.models.backends.KVView` hides cache layout:
``ContiguousView`` wraps the standard ``(B, KVH, N, ...)`` cache used by
the static/batch path; ``PagedView`` wraps the serving engine's page pool
plus a per-request block table (pass ``block_tables`` to
:func:`attention_decode`).  Backends whose ``attend`` touches K/V only
through indexed ``gather_rows`` (top-k selection) declare
``supports_paged`` — the serving engine then skips contiguous-view
materialization entirely and per decode step moves only the small
metadata leaves plus ``O(top_k)`` K/V rows.

**Adding a backend**: write one module under ``models/backends/``
implementing the four methods against the ``KVView`` API, register it in
``models/backends/__init__.py``, and it is reachable from training-free
decode, the static serve path and (if paged-capable) the continuous
engine, with sharding axes and paged-pool layout derived from its spec.

``pos`` may be a scalar (lockstep batch) or a ``(B,)`` vector of
per-request positions (ragged serving batch); backends derive per-request
sparsity budgets from the vector case.

Local (sliding-window) layers decode from a ring buffer of ``window``
slots — for gemma3's 5:1 pattern this keeps the long_500k cache bounded
by the window on 52 of 62 layers (DESIGN.md §5).  On the continuous
engine the ring lives in pool pages (cache-plan kind ``"ring"``): pass
``block_tables`` and the layer reads/writes through a
:class:`~repro.models.backends.RingView`, whose circular page list
bounds per-slot block demand at ``ceil(window / block_size)`` — same
attention math, recycled pages (``cfg.ring_geometry()``).
"""

from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.distributed import sharding as shd
from repro.distributed.sharding import lsc
from repro.models import backends
from repro.models import param as pm
from repro.models.backends import kvquant, socket_config_of
from repro.models.layers import apply_rope, init_rmsnorm, rmsnorm, softcap

__all__ = ["init_attention", "attention_train", "attention_prefill",
           "attention_prefill_chunk", "attention_decode",
           "init_attention_cache", "socket_config_of"]

NEG_INF = -1e30


def _eff_heads(cfg: ModelConfig) -> Tuple[int, int]:
    """(num_heads, num_kv_heads) after optional zero-padding for TP."""
    if not cfg.logical_pad_heads:
        return cfg.num_heads, cfg.num_kv_heads
    pad = 16

    def up(x):
        return ((x + pad - 1) // pad) * pad

    h = up(cfg.num_heads)
    kv = cfg.num_kv_heads
    while h % kv:  # keep exact grouping
        h += pad
    return h, kv


# ------------------------------------------------------------------ init

def init_attention(cfg: ModelConfig, rng: jax.Array) -> Dict:
    d, hd = cfg.d_model, cfg.head_dim
    h, kv = _eff_heads(cfg)
    k1, k2, k3, k4, k5 = jax.random.split(rng, 5)
    s = 1.0 / np.sqrt(d)
    so = 1.0 / np.sqrt(h * hd)
    dtype = jnp.dtype(cfg.param_dtype)
    params = {
        "wq": pm.normal(k1, (d, h, hd), ("embed_w", "heads", None),
                        stddev=s, dtype=dtype),
        "wk": pm.normal(k2, (d, kv, hd), ("embed_w", "kv_heads", None),
                        stddev=s, dtype=dtype),
        "wv": pm.normal(k3, (d, kv, hd), ("embed_w", "kv_heads", None),
                        stddev=s, dtype=dtype),
        "wo": pm.normal(k4, (h, hd, d), ("heads", None, "embed_w"),
                        stddev=so, dtype=dtype),
    }
    if cfg.logical_pad_heads and h != cfg.num_heads:
        # zero the padded q heads and their output rows => exact function.
        mask = (jnp.arange(h) < cfg.num_heads).astype(dtype)
        params["wq"].value = params["wq"].value * mask[None, :, None]
        params["wo"].value = params["wo"].value * mask[:, None, None]
    if cfg.qk_norm:
        params["q_norm"] = init_rmsnorm(hd)
        params["k_norm"] = init_rmsnorm(hd)
    # SOCKET hyperplanes (Algorithm 1): data-agnostic, never trained.
    sset = cfg.socket
    params["hash_w"] = pm.constant(
        jax.random.normal(k5, (sset.num_tables, sset.num_planes, hd),
                          jnp.float32),
        ("tables", None, None))
    return params


# ------------------------------------------------------------- projections

def _project_qkv(cfg: ModelConfig, params: Dict, x: jax.Array,
                 positions: jax.Array):
    cdt = jnp.dtype(cfg.compute_dtype)
    x = x.astype(cdt)
    q = jnp.einsum("btd,dhk->bthk", x, params["wq"].astype(cdt))
    k = jnp.einsum("btd,dhk->bthk", x, params["wk"].astype(cdt))
    v = jnp.einsum("btd,dhk->bthk", x, params["wv"].astype(cdt))
    if cfg.qk_norm:
        q = rmsnorm(params["q_norm"], q)
        k = rmsnorm(params["k_norm"], k)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


def _merge_heads(cfg: ModelConfig, params: Dict, ctx: jax.Array) -> jax.Array:
    cdt = jnp.dtype(cfg.compute_dtype)
    return jnp.einsum("bthk,hkd->btd", ctx.astype(cdt),
                      params["wo"].astype(cdt))


# ------------------------------------------------------------------ train

def _use_repeat_kv(h_eff: int, kv: int) -> bool:
    """GQA sharding strategy (DESIGN.md §4): the grouped (kv, g) einsum
    layout cannot be sharded when kv_heads doesn't divide the model axis —
    XLA then replicates *all* heads and the (B,H,T,S) logits explode.
    Repeating K/V up to the flat q-head axis keeps 16-way head sharding at
    the cost of a cheap KV broadcast (k/v are tiny next to the logits)."""
    mesh = shd.current_mesh()
    if mesh is None:
        return False
    model = dict(mesh.shape).get("model", 1)
    return (kv % model != 0) and (h_eff % model == 0) and h_eff != kv


def _attn_chunk(cfg: ModelConfig, qg: jax.Array, k: jax.Array, v: jax.Array,
                q_offset, attn_type: str, scale: float,
                repeat_kv: bool) -> jax.Array:
    """Attention of a block of queries against the full K/V (exact,
    full-row softmax).

    grouped:   qg (B, cq, KV, G, hd); k/v (B, S, KV, hd)
    repeat_kv: qg (B, cq, H, hd);     k/v (B, S, H, hd)  (pre-repeated)
    """
    cq = qg.shape[1]
    s = k.shape[1]
    if repeat_kv:
        logits = jnp.einsum("bthd,bshd->bhts", qg.astype(jnp.float32),
                            k.astype(jnp.float32)) * scale
    else:
        logits = jnp.einsum("btkgd,bskd->bkgts", qg.astype(jnp.float32),
                            k.astype(jnp.float32)) * scale
    logits = softcap(logits, cfg.attn_logit_softcap)
    ti = q_offset + jnp.arange(cq)[:, None]
    si = jnp.arange(s)[None, :]
    mask = si <= ti
    if attn_type == "local":
        mask &= (ti - si) < cfg.sliding_window
    if repeat_kv:
        logits = jnp.where(mask[None, None], logits, NEG_INF)
        w = jax.nn.softmax(logits, axis=-1)
        return jnp.einsum("bhts,bshd->bthd", w, v.astype(jnp.float32))
    logits = jnp.where(mask[None, None, None], logits, NEG_INF)
    w = jax.nn.softmax(logits, axis=-1)
    return jnp.einsum("bkgts,bskd->btkgd", w, v.astype(jnp.float32))


def attention_train(cfg: ModelConfig, params: Dict, x: jax.Array,
                    positions: jax.Array, attn_type: str) -> jax.Array:
    """Dense causal attention (optionally sliding-window) for training.

    x: (B, T, d); positions: (B, T).  When ``cfg.attn_q_chunk`` divides T,
    queries are processed in chunks under ``lax.scan`` so the live logits
    buffer is (chunk, T) instead of (T, T) — the XLA-path equivalent of the
    flash_prefill kernel's memory behaviour (exact same math).
    """
    b, t, d = x.shape
    h_eff = params["wq"].shape[1]
    kv = params["wk"].shape[1]
    g = h_eff // kv
    q, k, v = _project_qkv(cfg, params, x, positions)
    q = lsc(q, "batch", "seq", "q_heads", None)
    scale = 1.0 / np.sqrt(cfg.head_dim)

    repeat_kv = _use_repeat_kv(h_eff, kv)
    if repeat_kv:
        qg = q                                       # (b,t,h,hd)
        k = lsc(jnp.repeat(k, g, axis=2), "batch", "seq", "q_heads", None)
        v = lsc(jnp.repeat(v, g, axis=2), "batch", "seq", "q_heads", None)
    else:
        qg = q.reshape(b, t, kv, g, cfg.head_dim)

    cq = cfg.attn_q_chunk
    if cq and t > cq and t % cq == 0:
        nc = t // cq
        q_chunks = jnp.moveaxis(
            qg.reshape(b, nc, cq, *qg.shape[2:]), 1, 0)
        offsets = jnp.arange(nc, dtype=jnp.int32) * cq

        def body(_, inp):
            qc, off = inp
            return None, _attn_chunk(cfg, qc, k, v, off, attn_type, scale,
                                     repeat_kv)

        _, ctx_chunks = jax.lax.scan(body, None, (q_chunks, offsets))
        ctx = jnp.moveaxis(ctx_chunks, 0, 1)
    else:
        ctx = _attn_chunk(cfg, qg, k, v, 0, attn_type, scale, repeat_kv)
    ctx = ctx.reshape(b, t, h_eff, cfg.head_dim).astype(x.dtype)
    return _merge_heads(cfg, params, ctx)


# ------------------------------------------------------------------ cache

def init_attention_cache(cfg: ModelConfig, batch: int, capacity: int,
                         attn_type: str, dtype=None,
                         long_context: bool = False,
                         ring_capacity: Optional[int] = None) -> Dict:
    """Allocate one layer's decode cache (zeros); returns the pytree.

    ``long_context`` switches the sequence axis to context-parallel
    sharding (annotated logically; physical placement set by the launcher).
    ``ring_capacity`` overrides the local-layer ring length (the paged
    engine needs page-aligned rings, ``ring_blocks * block_size``, instead
    of the static path's ``min(capacity, window)``).
    """
    dtype = dtype or jnp.dtype(cfg.compute_dtype)
    _, kv = _eff_heads(cfg)
    if attn_type == "local":
        cap = ring_capacity if ring_capacity is not None else \
            min(capacity, cfg.sliding_window)
        # same leaf layout as the ring pool pages: quantized storage adds
        # the k_scale/v_scale leaves here too (kv_leaf_specs resolves
        # serving.kv_dtype)
        return {name: jnp.full((batch, kv, cap, *s.suffix), s.fill,
                               s.leaf_dtype(dtype))
                for name, s in backends.kv_leaf_specs(cfg).items()}
    backend = backends.get_backend(cfg.attention_backend)
    return backend.init_cache(cfg, batch, kv, capacity, dtype)


def cache_logical_axes(cfg: ModelConfig, attn_type: str,
                       long_context: bool = False) -> Dict:
    """Logical axis names mirroring :func:`init_attention_cache`."""
    if attn_type == "local":
        return {name: ("cache_batch", "cache_heads", "cache_seq") +
                (None,) * len(s.suffix)
                for name, s in backends.kv_leaf_specs(cfg).items()}
    seq = "cache_seq_cp" if long_context else "cache_seq"
    return backends.get_backend(cfg.attention_backend).cache_axes(cfg, seq)


# ---------------------------------------------------------------- prefill

def attention_prefill(cfg: ModelConfig, params: Dict, x: jax.Array,
                      positions: jax.Array, attn_type: str,
                      capacity: int, last_index=None,
                      paged: bool = False) -> Tuple[jax.Array, Dict]:
    """Forward over the prompt + build this layer's decode cache.

    Output matches :func:`attention_train`; cache covers positions [0, T).

    ``last_index``: optional ``(B,)`` per-row last *real* positions for
    bucket-padded prompts — the local ring then keeps the window ending
    at ``last_index`` instead of the (padding-garbage) bucket end.
    ``paged``: build the local ring at the serving engine's page-aligned
    capacity (``cfg.ring_geometry()``) so it scatters 1:1 into pool pages.
    """
    b, t, _ = x.shape
    y = attention_train(cfg, params, x, positions, attn_type)
    q, k, v = _project_qkv(cfg, params, x, positions)  # recompute, cheap
    kc = jnp.swapaxes(k, 1, 2)   # (B,KV,T,hd)
    vc = jnp.swapaxes(v, 1, 2)
    if attn_type == "local":
        cap = cfg.ring_geometry()[1] if paged else \
            min(capacity, cfg.sliding_window)
        li = jnp.full((b,), t - 1, jnp.int32) if last_index is None else \
            jnp.asarray(last_index, jnp.int32)
        # ring slot s holds the newest kept position p ≡ s (mod cap); the
        # same formula the decode step uses to reconstruct slot positions
        sl = jnp.arange(cap, dtype=jnp.int32)
        ring_pos = li[:, None] - ((li[:, None] - sl[None]) % cap)  # (B,cap)
        valid = (ring_pos >= 0)[:, None, :, None]
        idx = jnp.clip(ring_pos, 0, t - 1)[:, None, :, None]
        ring_k = jnp.where(valid, jnp.take_along_axis(kc, idx, axis=2), 0)
        ring_v = jnp.where(valid, jnp.take_along_axis(vc, idx, axis=2), 0)
        kvd = backends.kv_quant_mode(cfg)
        if kvquant.is_quantized(kvd):
            kq, ks = kvquant.quantize(ring_k, kvd)
            vq, vs = kvquant.quantize(ring_v, kvd)
            return y, {"k": kq, "v": vq, "k_scale": ks, "v_scale": vs}
        if kvd == "bf16":
            ring_k = ring_k.astype(jnp.bfloat16)
            ring_v = ring_v.astype(jnp.bfloat16)
        cache = {"k": ring_k, "v": ring_v}
        return y, cache
    cache = init_attention_cache(cfg, b, capacity, attn_type,
                                 dtype=kc.dtype)
    backend = backends.get_backend(cfg.attention_backend)
    return y, backend.prefill_build(cfg, params, cache, kc, vc)


def attention_prefill_chunk(cfg: ModelConfig, params: Dict, x: jax.Array,
                            positions: jax.Array, attn_type: str,
                            cache: Dict, bt_row: jax.Array,
                            history: jax.Array, last_index: jax.Array,
                            ) -> Tuple[jax.Array, Dict]:
    """One **prefix-extension** prefill chunk straight against the pool.

    The chunked engine feeds the prompt through the stack
    ``prefill_chunk`` tokens at a time; this is one attention layer's
    share of one chunk.  ``x`` is ``(1, C, d)`` (one chunk per engine
    iteration), ``positions`` the absolute token positions ``history +
    [0, C)``, ``cache`` this layer's *pool* leaves, ``bt_row`` the
    request's trash-padded block-id row, ``history`` the number of
    prompt tokens already committed by earlier chunks (a traced scalar —
    one compile covers every chunk index), and ``last_index`` the
    ``(1,)`` last *real* in-chunk index (the final chunk is padded to the
    static chunk length).

    Global layers write the chunk's K/V + backend metadata into their
    pages first (reusing the backend's ``prefill_build`` on a chunk-sized
    mini cache), then attend causally over the paged logical view — the
    ``si <= ti`` mask composes in-chunk causality with the committed
    context, which is exactly the prefix-extension contract.  Local
    layers attend over the pre-write circular ring (history) plus the
    in-chunk K/V under the sliding-window mask, then write the chunk's
    real rows into the ring with the usual page-opening scrub; padded
    rows are routed to the trash page so ring slots only ever hold
    positions the decode-side ring arithmetic can reconstruct.
    """
    b, t, _ = x.shape
    hd = cfg.head_dim
    h_eff = params["wq"].shape[1]
    kv = params["wk"].shape[1]
    g = h_eff // kv
    scale = 1.0 / np.sqrt(hd)
    q, k, v = _project_qkv(cfg, params, x, positions)
    kc = jnp.swapaxes(k, 1, 2)                       # (B, KV, C, hd)
    vc = jnp.swapaxes(v, 1, 2)
    bs = cfg.serving.block_size
    cache = dict(cache)
    qg = q.reshape(b, t, kv, g, hd)
    li = jnp.asarray(last_index, jnp.int32).reshape(b)

    if attn_type == "local":
        rb, cap = cfg.ring_geometry()
        w = cfg.sliding_window
        # history ring as of position history-1: slot s holds the newest
        # committed position p ≡ s (mod cap); slots never written (or
        # fallen out of the window) mask out.  Gathered BEFORE the chunk
        # writes, so early chunk queries still see positions a later
        # in-chunk token will recycle.
        ring_k = backends.gather_block_leaf(cache["k"], bt_row[None, :rb])
        ring_v = backends.gather_block_leaf(cache["v"], bt_row[None, :rb])
        kvd = backends.kv_quant_mode(cfg)
        if kvquant.is_quantized(kvd):
            ring_k = kvquant.dequantize(ring_k, backends.gather_block_leaf(
                cache["k_scale"], bt_row[None, :rb]))
            ring_v = kvquant.dequantize(ring_v, backends.gather_block_leaf(
                cache["v_scale"], bt_row[None, :rb]))
        sl = jnp.arange(cap, dtype=jnp.int32)
        lp = jnp.asarray(history, jnp.int32) - 1
        rp = lp - ((lp - sl) % cap)                          # (cap,)
        ti = history + jnp.arange(t, dtype=jnp.int32)        # (t,)
        ring_mask = (rp[None, :] >= 0) & (ti[:, None] - rp[None, :] < w)
        ij = jnp.arange(t, dtype=jnp.int32)
        in_mask = (ij[None, :] <= ij[:, None]) & \
            (ij[:, None] - ij[None, :] < w)
        k_all = jnp.concatenate([ring_k, kc], axis=2)    # (B,KV,cap+C,hd)
        v_all = jnp.concatenate([ring_v, vc], axis=2)
        logits = jnp.einsum("btkgd,bknd->bkgtn", qg.astype(jnp.float32),
                            k_all.astype(jnp.float32)) * scale
        logits = softcap(logits, cfg.attn_logit_softcap)
        mask = jnp.concatenate([ring_mask, in_mask], axis=1)  # (t, cap+C)
        logits = jnp.where(mask[None, None, None], logits, NEG_INF)
        wts = jax.nn.softmax(logits, axis=-1)
        ctx = jnp.einsum("bkgtn,bknd->btkgd", wts,
                         v_all.astype(jnp.float32))
        ctx = ctx.reshape(b, t, h_eff, hd)

        def body(j, cc):
            pos = jnp.full((b,), history + j, jnp.int32)
            blk = bt_row[(pos // bs) % rb]
            # padded rows (j > last_index) go to the trash page (block 0)
            blk = jnp.where(j <= li, blk, jnp.zeros_like(blk))
            vals = {"k": kc[:, :, j], "v": vc[:, :, j]}
            if kvquant.is_quantized(kvd):
                vals["k"], vals["k_scale"] = kvquant.quantize(vals["k"], kvd)
                vals["v"], vals["v_scale"] = kvquant.quantize(vals["v"], kvd)
            return {name: backends.ring_write_page(
                cc[name], blk, pos, vals[name], block_size=bs,
                ring_blocks=rb, window=w) for name in cc}

        ring_names = [n for n in ("k", "v", "k_scale", "v_scale")
                      if n in cache]
        ring_leaves = jax.lax.fori_loop(
            0, t, body, {n: cache[n] for n in ring_names})
        cache.update(ring_leaves)
    else:
        backend = backends.get_backend(cfg.attention_backend)
        # chunk-sized mini cache through the backend's own prefill_build:
        # K/V plus metadata (SOCKET bits/vnorm, Quest page stats) land in
        # the chunk's pages block-aligned (C % block_size == 0, and every
        # leaf granularity divides block_size by construction).
        mini = backend.init_cache(cfg, b, kv, t,
                                  jnp.dtype(cfg.compute_dtype))
        mini = backend.prefill_build(cfg, params, mini, kc, vc)
        block0 = jnp.asarray(history, jnp.int32) // bs
        spec = backend.cache_spec(cfg)
        for name in cache:
            if spec[name].granularity == 1:
                # row-granular commit: supports a mid-page chunk start
                # (prefix-cache hit resuming past the shared tail page)
                # and routes final-chunk padding to the trash page.
                cache[name] = backends.write_chunk_rows(
                    cache[name], mini[name], bt_row, history, li[0])
            else:
                # page-granular metadata (Quest min/max): whole-block
                # scatter — chunk starts are page-aligned here (the
                # prefix cache only shares page-aligned prefixes when
                # any leaf has granularity > 1).
                cache[name] = backends.write_chunk_blocks(
                    cache[name], mini[name], bt_row, block0)
        # prefix-extension attend over the paged logical view: the chunk's
        # own rows were just committed, so the causal si <= ti mask covers
        # both the earlier chunks' pages and in-chunk causality; trash
        # rows sit past every real query's position.
        k_full = backends.gather_block_leaf(cache["k"], bt_row[None])
        v_full = backends.gather_block_leaf(cache["v"], bt_row[None])
        if kvquant.is_quantized(backends.kv_quant_mode(cfg)):
            k_full = kvquant.dequantize(k_full, backends.gather_block_leaf(
                cache["k_scale"], bt_row[None]))
            v_full = kvquant.dequantize(v_full, backends.gather_block_leaf(
                cache["v_scale"], bt_row[None]))
        ctx = _attn_chunk(cfg, qg, jnp.swapaxes(k_full, 1, 2),
                          jnp.swapaxes(v_full, 1, 2), history, "global",
                          scale, repeat_kv=False)
        ctx = ctx.reshape(b, t, h_eff, hd)

    return _merge_heads(cfg, params, ctx.astype(x.dtype)), cache


# ----------------------------------------------------------------- decode

def attention_decode(cfg: ModelConfig, params: Dict, x: jax.Array,
                     cache: Dict, pos: jax.Array, attn_type: str,
                     block_tables: Optional[jax.Array] = None,
                     ) -> Tuple[jax.Array, Dict]:
    """One decode step.  x: (B, 1, d); pos: scalar int32 (current index)
    OR a ``(B,)`` int32 vector of per-request indices (ragged serving
    batch — each row of the batch sits at its own context length).

    ``block_tables``: when given (``(B, blocks_per_seq)`` physical block
    ids), ``cache`` is the serving engine's **page pool** rather than a
    contiguous cache — the backend appends and attends through a
    :class:`~repro.models.backends.PagedView`, so paged-capable backends
    never materialize the full per-request K/V view.

    In the ragged case the sparse backends' top-k budget is applied *per
    request* from each live length (``k_r = clip(ceil(len_r / sparsity),
    min_k, k_cap)``) via dynamic masking under a static ``top_k`` — the
    serving-engine realization of the paper's ``k = N / sparsity``.

    Returns (y (B,1,d), updated cache/pool).
    """
    b = x.shape[0]
    hd = cfg.head_dim
    h_eff = params["wq"].shape[1]
    kv = params["wk"].shape[1]
    g = h_eff // kv
    scale = 1.0 / np.sqrt(hd)
    ragged = jnp.ndim(pos) == 1
    positions = jnp.reshape(pos, (b, 1)).astype(jnp.int32) if ragged \
        else jnp.full((b, 1), pos, jnp.int32)
    q, k_new, v_new = _project_qkv(cfg, params, x, positions)
    qg = jnp.transpose(q.reshape(b, 1, kv, g, hd), (0, 2, 3, 1, 4))
    # qg: (B, KV, G, 1, hd)

    if attn_type == "local":
        ring_fused = block_tables is not None and cfg.use_ring_kernel
        kvd = backends.kv_quant_mode(cfg)
        quantized = kvquant.is_quantized(kvd)
        if block_tables is not None:
            # paged ring: the block table's first ring_blocks entries are
            # a circular page list (plan kind "ring"); the bounded ring
            # view (window-sized) then runs the same attention math.
            rb, cap = cfg.ring_geometry()
            spec = backends.kv_leaf_specs(cfg)
            view = backends.RingView(
                {name: cache[name] for name in spec},
                spec, block_tables,
                cfg.serving.block_size, rb, cfg.sliding_window)
            backends.write_token_kv(cfg, view, pos, k_new[:, 0],
                                    v_new[:, 0])
            cache = dict(cache)
            cache.update(view.arrays)
            if ring_fused:
                # fused Pallas ring pass: stream the circular page list
                # straight from the pool, window mask (and dequant, for
                # quantized pages) in-kernel — the leaf() gather below
                # never materializes.
                from repro.kernels.paged_attention import ops as pa_ops
                ctx = pa_ops.paged_ring_attend(
                    qg, cache["k"], cache["v"], block_tables[:, :rb],
                    pos=pos, window=cfg.sliding_window,
                    softcap=cfg.attn_logit_softcap, scale=scale,
                    k_scale=cache.get("k_scale"),
                    v_scale=cache.get("v_scale"))
                backends.record_fused("paged_ring", ctx.shape)
            else:
                ring_k = backends.dequant_leaf(cfg, view, "k")
                ring_v = backends.dequant_leaf(cfg, view, "v")
        else:
            cap = cache["k"].shape[2]
            slot = pos % cap
            cache = dict(cache)
            vals = {"k": jnp.swapaxes(k_new, 1, 2),
                    "v": jnp.swapaxes(v_new, 1, 2)}       # (B,KV,1,hd)
            if quantized:
                vals["k"], vals["k_scale"] = kvquant.quantize(vals["k"], kvd)
                vals["v"], vals["v_scale"] = kvquant.quantize(vals["v"], kvd)
            for name, val in vals.items():
                a = cache[name]
                if ragged:
                    bidx = jnp.arange(b)
                    cache[name] = a.at[bidx, :, slot].set(
                        val[:, :, 0].astype(a.dtype))
                else:
                    cache[name] = jax.lax.dynamic_update_slice(
                        a, val.astype(a.dtype),
                        (0, 0, slot) + (0,) * (a.ndim - 3))
            ring_k, ring_v = cache["k"], cache["v"]
            if quantized:
                ring_k = kvquant.dequantize(ring_k, cache["k_scale"])
                ring_v = kvquant.dequantize(ring_v, cache["v_scale"])
        if not ring_fused:
            # ring-slot absolute positions; invalid slots masked out.  The
            # window bound is a no-op when cap <= window (static path) but
            # trims page-aligned rings that hold slightly more than a
            # window.
            sl = jnp.arange(cap, dtype=jnp.int32)
            pos_b = pos[:, None] if ragged else pos     # (B,1) | scalar
            ring_pos = pos_b - ((pos_b - sl) % cap)      # (B,cap) | (cap,)
            valid = (ring_pos >= 0) & \
                (pos_b - ring_pos < cfg.sliding_window)
            if not ragged:
                valid = valid[None]
            logits = jnp.einsum("bkgtd,bknd->bkgtn",
                                qg.astype(jnp.float32),
                                ring_k.astype(jnp.float32)) * scale
            logits = softcap(logits, cfg.attn_logit_softcap)
            logits = jnp.where(valid[:, None, None, None], logits, NEG_INF)
            w = jax.nn.softmax(logits, axis=-1)
            ctx = jnp.einsum("bkgtn,bknd->bkgtd", w,
                             ring_v.astype(jnp.float32))
    else:
        backend = backends.get_backend(cfg.attention_backend)
        spec = backend.cache_spec(cfg)
        if block_tables is None:
            view = backends.ContiguousView(cache, spec)
        else:
            view = backends.PagedView(cache, spec, block_tables,
                                      block_size=cfg.serving.block_size)
        backend.append(cfg, params, view, jnp.swapaxes(k_new, 1, 2),
                       jnp.swapaxes(v_new, 1, 2), pos)
        ctx = backend.attend(cfg, params, qg, view, length=pos + 1,
                             scale=scale)
        cache = view.arrays

    ctx = jnp.transpose(ctx, (0, 3, 1, 2, 4)).reshape(b, 1, h_eff, hd)
    return _merge_heads(cfg, params, ctx.astype(x.dtype)), cache
