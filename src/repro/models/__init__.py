"""Composable model definitions (attention/MoE/Mamba-2 decoder stacks)."""

from repro.models import attention, layers, mamba, moe, param, transformer
from repro.models.transformer import (decode_cache_axes, decode_step,
                                      forward_train, init_decode_caches,
                                      init_model, loss_and_metrics, prefill)

__all__ = [
    "attention", "decode_cache_axes", "decode_step", "forward_train",
    "init_decode_caches", "init_model", "layers", "loss_and_metrics",
    "mamba", "moe", "param", "prefill", "transformer",
]
