"""Shared neural layers: RMSNorm, RoPE, gated MLPs, embeddings."""

from __future__ import annotations

from typing import Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.distributed.sharding import lsc
from repro.models import param as pm

__all__ = ["rmsnorm", "init_rmsnorm", "apply_rope", "init_mlp", "apply_mlp",
           "init_embedding", "embed_tokens", "lm_head", "softcap"]


# --------------------------------------------------------------------- norm

def init_rmsnorm(d: int) -> Dict:
    return {"scale": pm.ones((d,), (None,))}


def rmsnorm(params: Dict, x: jax.Array, eps: float = 1e-6) -> jax.Array:
    dtype = x.dtype
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    return (y * (1.0 + params["scale"].astype(jnp.float32))).astype(dtype)


def softcap(logits: jax.Array, cap: float) -> jax.Array:
    """Gemma-style logit soft-capping: cap * tanh(x / cap)."""
    if cap <= 0.0:
        return logits
    return cap * jnp.tanh(logits / cap)


# --------------------------------------------------------------------- rope

def apply_rope(x: jax.Array, positions: jax.Array,
               theta: float) -> jax.Array:
    """Rotary embedding.  x: (B, T, H, hd); positions: (B, T) int32."""
    hd = x.shape[-1]
    half = hd // 2
    freq = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    angles = positions[..., None].astype(jnp.float32) * freq  # (B,T,half)
    cos = jnp.cos(angles)[:, :, None, :]
    sin = jnp.sin(angles)[:, :, None, :]
    x1, x2 = x[..., :half], x[..., half:]
    rotated = jnp.concatenate([x1 * cos - x2 * sin,
                               x2 * cos + x1 * sin], axis=-1)
    return rotated.astype(x.dtype)


# --------------------------------------------------------------------- mlp

def init_mlp(cfg: ModelConfig, rng: jax.Array) -> Dict:
    d, ff = cfg.d_model, cfg.d_ff
    k1, k2, k3 = jax.random.split(rng, 3)
    s_in = 1.0 / np.sqrt(d)
    s_out = 1.0 / np.sqrt(ff)
    dtype = jnp.dtype(cfg.param_dtype)
    return {
        "w_gate": pm.normal(k1, (d, ff), ("embed_w", "mlp"), stddev=s_in,
                            dtype=dtype),
        "w_up": pm.normal(k2, (d, ff), ("embed_w", "mlp"), stddev=s_in,
                          dtype=dtype),
        "w_down": pm.normal(k3, (ff, d), ("mlp", "embed_w"), stddev=s_out,
                            dtype=dtype),
    }


def apply_mlp(cfg: ModelConfig, params: Dict, x: jax.Array) -> jax.Array:
    cdt = jnp.dtype(cfg.compute_dtype)
    x = x.astype(cdt)
    gate = x @ params["w_gate"].astype(cdt)
    up = x @ params["w_up"].astype(cdt)
    act = jax.nn.gelu(gate, approximate=True) if cfg.mlp_activation == \
        "geglu" else jax.nn.silu(gate)
    h = lsc(act * up, "batch", "seq", "mlp")
    return h @ params["w_down"].astype(cdt)


# ------------------------------------------------------------ embeddings

def init_embedding(cfg: ModelConfig, rng: jax.Array) -> Dict:
    dtype = jnp.dtype(cfg.param_dtype)
    v = cfg.padded_vocab()
    out = {"table": pm.normal(rng, (v, cfg.d_model), ("vocab", "embed_w"),
                              stddev=1.0, dtype=dtype)}
    if not cfg.tie_embeddings:
        k2 = jax.random.fold_in(rng, 1)
        out["head"] = pm.normal(k2, (cfg.d_model, v), ("embed_w", "vocab"),
                                stddev=1.0 / np.sqrt(cfg.d_model),
                                dtype=dtype)
    return out


def embed_tokens(cfg: ModelConfig, params: Dict, tokens: jax.Array
                 ) -> jax.Array:
    cdt = jnp.dtype(cfg.compute_dtype)
    x = jnp.take(params["table"], tokens, axis=0).astype(cdt)
    return lsc(x * jnp.asarray(np.sqrt(cfg.d_model), cdt),
               "batch", "act_seq", "embed")


def lm_head(cfg: ModelConfig, params: Dict, x: jax.Array) -> jax.Array:
    cdt = jnp.dtype(cfg.compute_dtype)
    w = params.get("head")
    if w is None:
        w = params["table"].T
    logits = x.astype(cdt) @ w.astype(cdt)
    return lsc(logits, "batch", "seq", "vocab")
