"""Selection-quality probe capture for sparse decode backends.

The serving engine's sampled probe (see
:mod:`repro.serving.obs.probe`) answers "is the kernel still selecting
the right tokens under serving pressure?" by re-running one decode step
through a **separately-jitted shadow step** traced inside
:func:`capture`.  While the capture flag is up (a trace-time Python
flag, so the production decode step contains zero probe ops),
``SocketBackend.attend``:

* routes through the unfused XLA selection path (the fused Pallas kernel
  never materializes indices — and its selected set is pinned elsewhere
  to match :func:`~repro.core.socket.value_aware_topk` exactly, so the
  XLA selection *is* the fused kernel's selection);
* computes :func:`selection_stats` in-graph — budget utilization,
  selection recall against the exact dense attention-mass top-k, and the
  forced sink/window share — and ships the small per-request vectors to
  the host through ``jax.debug.callback`` (fires once per attention
  layer, in execution order, including under ``lax.scan``).

The host drains :func:`drain` after the shadow step executes; call order
identifies the layer.  The probe runs **off the hot path**: the shadow
step is its own compile, its outputs are discarded, and nothing here is
ever staged into the production step.
"""

from __future__ import annotations

import contextlib
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp

from repro.core import socket as sk

__all__ = ["capture", "capturing", "drain", "emit", "selection_stats"]

_CAPTURING = False
_SINK: List[Dict] = []


def capturing() -> bool:
    """True while a probe shadow step is being traced (trace-time flag)."""
    return _CAPTURING


@contextlib.contextmanager
def capture():
    """Raise the capture flag for tracing (and executing) a shadow step."""
    global _CAPTURING
    prev, _CAPTURING = _CAPTURING, True
    try:
        yield
    finally:
        _CAPTURING = prev


def _sink_cb(stats: Dict) -> None:
    _SINK.append({k: jax.device_get(v) for k, v in stats.items()})


def emit(stats: Dict) -> None:
    """Stage a host callback delivering ``stats`` (a dict of small
    arrays) at execution time; one call per probed attention layer.
    ``ordered=True`` pins execution order to program order, so the
    drained list indexes layers deterministically (scan iterations
    included)."""
    jax.debug.callback(_sink_cb, stats, ordered=True)


def drain() -> List[Dict]:
    """Pop everything the last shadow-step execution delivered, in layer
    execution order."""
    out, _SINK[:] = list(_SINK), []
    return out


def selection_stats(scfg: sk.SocketConfig, q: jax.Array, k_full: jax.Array,
                    vnorm: jax.Array, idx: jax.Array, sel_mask: jax.Array,
                    *, length, budget: Optional[jax.Array],
                    static_k: int, scale: float) -> Dict[str, jax.Array]:
    """Per-request selection-quality stats for one layer's decode step.

    The dense reference is the exact attention mass each key would
    receive under full (non-sparse) attention: ``softmax(q·k)`` summed
    over the query group — its top-``m`` set (``m`` = the request's
    realized selection count) is what a perfect selector with the same
    budget would pick.  Recall is the fraction of that reference set the
    SOCKET selection actually covered.

    Args:
      q:        ``(B, KVH, G, 1, hd)`` this step's queries.
      k_full:   ``(B, KVH, N, hd)`` the logical key view (probe-only
                materialization — the production path never does this).
      vnorm:    ``(B, KVH, N)`` value norms (kept for schema parity /
                future value-weighted reference variants).
      idx:      ``(B, KVH, K)`` selected logical indices.
      sel_mask: ``(B, KVH, K)`` selection validity (budget applied).
      length:   scalar or ``(B,)`` live context lengths.
      budget:   per-request dynamic budgets ``(B,)`` or None (static).
      static_k: the static selection width K.
      scale:    attention logit scale.

    Returns dict of ``(B,)`` float32 vectors: ``recall``,
    ``budget_utilization`` (selected / static K), ``forced_share``
    (fraction of selections that were force-included sink/window
    tokens), ``selected`` and ``budget`` (counts, KVH-averaged where
    applicable).  Inactive slots (length 0) report zeros; the engine
    masks them out with its ``active`` vector anyway.
    """
    del vnorm
    b, kvh, n = k_full.shape[0], k_full.shape[1], k_full.shape[2]
    pos = jnp.arange(n, dtype=jnp.int32)
    length_b = jnp.broadcast_to(jnp.asarray(length, jnp.int32), (b,))
    valid = pos[None] < length_b[:, None]                     # (B, N)

    # exact dense attention mass per key, summed over the query group
    logits = jnp.einsum("bhgtd,bhnd->bhgtn", q.astype(jnp.float32),
                        k_full.astype(jnp.float32)) * scale
    logits = jnp.where(valid[:, None, None, None, :], logits, sk.NEG_INF)
    mass = jnp.sum(jax.nn.softmax(logits, axis=-1), axis=(2, 3))  # (B,KVH,N)

    m = jnp.sum(sel_mask, axis=-1)                            # (B, KVH)
    _, dense_idx = jax.lax.top_k(mass, static_k)              # (B,KVH,K)
    bidx = jnp.arange(b)[:, None, None]
    hidx = jnp.arange(kvh)[None, :, None]
    dense_keep = (jnp.arange(static_k)[None, None, :] < m[:, :, None]) \
        & valid[:, None][bidx, jnp.zeros_like(hidx), dense_idx]

    def onehot(indices, keep):
        base = jnp.zeros((b, kvh, n), jnp.int32)
        return base.at[bidx, hidx, indices].add(keep.astype(jnp.int32)) > 0

    sel_set = onehot(idx, sel_mask)
    dense_set = onehot(dense_idx, dense_keep)
    inter = jnp.sum(sel_set & dense_set, axis=-1)             # (B, KVH)
    denom = jnp.maximum(1, jnp.sum(dense_set, axis=-1))
    recall = jnp.mean(inter / denom, axis=1)                  # (B,)

    forced = (pos[None] < scfg.sink_tokens) | \
        (pos[None] >= (length_b[:, None] - scfg.window_tokens))
    forced_sel = forced[:, None][bidx, jnp.zeros_like(hidx), idx]  # (B,KVH,K)
    n_forced = jnp.sum(sel_mask & forced_sel, axis=-1)
    forced_share = jnp.mean(n_forced / jnp.maximum(1, m), axis=1)

    selected = jnp.mean(m.astype(jnp.float32), axis=1)        # (B,)
    if budget is None:
        budget_b = jnp.full((b,), static_k, jnp.float32)
    else:
        budget_b = jnp.broadcast_to(jnp.asarray(budget),
                                    (b,)).astype(jnp.float32)
    return {
        "recall": recall.astype(jnp.float32),
        "budget_utilization": selected / float(static_k),
        "forced_share": forced_share.astype(jnp.float32),
        "selected": selected,
        "budget": budget_b,
        "static_k": jnp.int32(static_k),
    }
