"""O(1)-state cache handler for Mamba/SSD layers.

Mamba layers decode from a fixed-size recurrent state (conv tail + SSD
state) — there is nothing sequence-shaped to page, so on the continuous
engine their "cache" is one row per decode slot (batch axis =
``serving.max_batch``) and they consume **zero** pool blocks.  The
jitted ragged decode step updates all slot rows every iteration (inactive
slots integrate trash-token garbage, like masked attention slots write
the trash page); correctness comes from prefill fully overwriting a
slot's state at admission — which also scrubs the previous occupant's
state, the state analogue of ring-page scrub-on-open.

Preemption-resume is exact by recomputation: re-prefilling the original
prompt reproduces the SSD state at the prompt's last token bit-for-bit
(same jitted chunked-SSD function, same inputs; bucket padding is
excluded from the state via ``last_index`` dt-masking in
:func:`repro.models.mamba.mamba_train`), and the recorded tokens then
replay through the same decode step that produced them.
"""

from __future__ import annotations

import jax

from repro.models.backends import base

__all__ = ["StateCacheHandler"]


class StateCacheHandler(base.LayerCacheHandler):
    kind = "state"

    def spec(self, cfg) -> base.LayerCacheSpec:
        # leaves empty: state shapes come from mamba.init_mamba_cache
        # (they are not (batch, KVH, rows, ...)-shaped LeafSpec leaves).
        return base.LayerCacheSpec(kind="state", leaves={})

    def write_prefill(self, cfg, pages, cache, bt_row, slot):
        del bt_row
        return {name: pages[name].at[slot].set(
            cache[name][0].astype(pages[name].dtype)) for name in pages}

    def gather(self, cfg, pages, bt):
        del bt                               # no block table at all
        return dict(pages)

    def scatter(self, cfg, pages, views, bt, pos):
        del bt, pos                          # decode updated slots in full
        return {name: views[name].astype(pages[name].dtype)
                for name in pages}
