"""SOCKET decode backend (the paper's technique, Algorithms 1-3).

Cache leaves: K/V plus the side-cache of packed hash bits and value norms
(Algorithm 1).  ``attend`` soft-hashes the query (Algorithm 2), scores
every cached key with the factorized soft-collision kernel — through the
Pallas scoring kernel when ``cfg.socket.use_score_kernel`` is set — runs
value-aware top-k (Algorithm 3), and attends exactly over the selected
subset (``flash_decode`` when ``cfg.socket.use_flash_decode``).

Paged-capable: scoring reads only the bits/vnorm leaves (~64x smaller
than K/V at deployment settings), and K/V are touched only at the
``top_k ∪ sink ∪ window`` rows the selection returns — the serving engine
never materializes contiguous K/V views for this backend.

With ``cfg.socket.use_paged_kernel`` the whole PagedView pipeline runs
as ONE fused Pallas pass (``kernels/paged_attention``): the pool leaves
and block table go into the kernel verbatim, which streams pages once —
scoring bits in-register, radix-selecting the per-request budget
threshold, and folding the selected K/V rows into an online softmax —
so even the ``O(top_k)`` XLA row gathers disappear.  Contiguous callers
keep the socket_score + flash_decode pair.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import hashing
from repro.core import socket as sk
from repro.models.backends import base
from repro.models.backends import probe as bprobe
from repro.models.backends.base import ContiguousView, KVView, LeafSpec

__all__ = ["SocketBackend", "socket_config_of"]


def socket_config_of(cfg) -> sk.SocketConfig:
    """Map the model config's :class:`SocketSettings` to the scorer's
    :class:`~repro.core.socket.SocketConfig`."""
    s = cfg.socket
    return sk.SocketConfig(
        num_planes=s.num_planes, num_tables=s.num_tables, tau=s.tau,
        sparsity=s.sparsity, sink_tokens=s.sink_tokens,
        window_tokens=s.window_tokens, min_k=s.min_k,
        bits_storage=s.bits_storage, score_chunk=s.score_chunk,
        score_dtype=s.score_dtype, selection=s.selection)


class SocketBackend(base.DecodeBackend):
    name = "socket"
    supports_paged = True

    # ---- layout ---------------------------------------------------------
    def cache_spec(self, cfg):
        scfg = socket_config_of(cfg)
        spec = base.kv_leaf_specs(cfg)
        if scfg.bits_storage == "packed":
            w = hashing.num_words(scfg.num_tables, scfg.num_planes)
            spec["bits"] = LeafSpec(suffix=(w,), dtype=jnp.uint32)
        else:
            spec["bits"] = LeafSpec(
                suffix=(scfg.num_tables * scfg.num_planes,), dtype=jnp.int8)
        spec["vnorm"] = LeafSpec(suffix=(), dtype=jnp.bfloat16)
        return spec

    # ---- ops ------------------------------------------------------------
    def prefill_build(self, cfg, params, cache, kc, vc):
        t = kc.shape[2]
        cache = base.write_prefill_kv(cfg, cache, kc, vc)
        scfg = socket_config_of(cfg)
        side = sk.precompute_key_hashes(
            scfg, jax.lax.stop_gradient(params["hash_w"]), kc, vc)
        cache["bits"] = cache["bits"].at[:, :, :t].set(side.bits)
        cache["vnorm"] = cache["vnorm"].at[:, :, :t].set(
            side.vnorm.astype(cache["vnorm"].dtype))
        return cache

    def append(self, cfg, params, view: KVView, kc, vc, pos):
        base.write_token_kv(cfg, view, pos, kc[:, :, 0], vc[:, :, 0])
        # side-cache from the ORIGINAL full-precision K/V: selection is
        # untouched by K/V quantization by construction
        scfg = socket_config_of(cfg)
        side = sk.precompute_key_hashes(scfg, params["hash_w"], kc, vc)
        view.write_token("bits", pos, side.bits[:, :, 0])
        view.write_token("vnorm", pos, side.vnorm[:, :, 0])

    def _budget(self, cfg, length, n):
        """Ragged per-request top-k budget (None for scalar length)."""
        if jnp.ndim(length) != 1:
            return None
        scfg = socket_config_of(cfg)
        return sk.dynamic_topk_budget(scfg, length,
                                      sk.topk_budget(scfg, n))

    @staticmethod
    def _soft_hash(scfg, params, q):
        """Query soft-hash for the selection mode: pooled hashes the
        group-mean query once per KV head ((B,KVH,L,P) — G x less scoring
        work/memory, the TPU operating point of DESIGN.md §2), else each
        q head ((B,KVH,G,L,P))."""
        if scfg.selection == "pooled":
            return sk.soft_hash_query(params["hash_w"],
                                      jnp.mean(q[..., 0, :], axis=2))
        return sk.soft_hash_query(params["hash_w"], q[..., 0, :])

    def _scores(self, cfg, params, q, view: KVView):
        """(soft-hash u, collision scores) for the selection mode."""
        scfg = socket_config_of(cfg)
        u = self._soft_hash(scfg, params, q)
        bits = view.leaf("bits")
        if cfg.socket.use_score_kernel:
            if scfg.selection not in ("kvhead", "pooled"):
                raise NotImplementedError(
                    "the Pallas scoring kernel group-sums scores (kvhead "
                    "selection); use the XLA path for per-q-head selection")
            # bits_storage='int8' streams the ±1 plane bytes directly (the
            # kernel skips the unpack; format inferred from the dtype)
            from repro.kernels.socket_score import ops as score_ops
            # kernel wants (B,KVH,G,L,P); pooled hashes once per KV head
            u_k = u[:, :, None] if scfg.selection == "pooled" else u
            scores = score_ops.socket_score(
                bits, u_k, vnorm=None, num_tables=scfg.num_tables,
                num_planes=scfg.num_planes, tau=scfg.tau)  # (B,KVH,N), G-sum
        elif scfg.selection == "pooled":
            scores = sk.soft_scores_factorized(scfg, bits, u)  # (B,KVH,N)
        else:
            scores = sk.soft_scores_factorized(
                scfg, bits[:, :, None], u)                     # (B,KVH,G,N)
            if scfg.selection == "kvhead":
                # group-marginal collision mass: sum over the query group
                scores = jnp.sum(scores, axis=2)
        return scores

    def _attend_fused(self, cfg, params, q, view, *, length, scale, budget):
        """Fused paged path: one Pallas pass over the block table."""
        scfg = socket_config_of(cfg)
        if scfg.bits_storage != "packed":
            raise NotImplementedError(
                "the fused paged kernel streams packed uint32 hash words; "
                "bits_storage='int8' must use the unfused paged path")
        if scfg.selection not in ("kvhead", "pooled"):
            raise NotImplementedError(
                "the fused paged kernel group-sums scores (kvhead/pooled "
                "selection); per-q-head selection has no fused path")
        if view.block_size % 8:
            raise NotImplementedError(
                f"fused paged kernel needs block_size % 8 == 0 (f32 "
                f"sublane tiling), got {view.block_size}")
        u = self._soft_hash(scfg, params, q)
        if scfg.selection == "pooled":
            u = u[:, :, None]                       # (B,KVH,1,L,P)
        kq = sk.topk_budget(scfg, view.n_tokens)
        if budget is None:
            budget = jnp.full((q.shape[0],), kq, jnp.int32)
        from repro.kernels.paged_attention import ops as pa_ops
        out = pa_ops.paged_socket_attend(
            q, view.arrays["k"], view.arrays["v"], view.arrays["bits"],
            view.arrays["vnorm"], u, view.block_table, length=length,
            budget=budget, num_tables=scfg.num_tables,
            num_planes=scfg.num_planes, tau=scfg.tau, scale=scale,
            sink_tokens=scfg.sink_tokens, window_tokens=scfg.window_tokens,
            k_scale=base.kv_scales_of(view.arrays, "k"),
            v_scale=base.kv_scales_of(view.arrays, "v"))
        base.record_fused("paged_attention", out.shape)
        return out.astype(q.dtype)

    def attend(self, cfg, params, q, view: KVView, *, length, scale):
        scfg = socket_config_of(cfg)
        if scfg.selection not in ("kvhead", "pooled", "qhead"):
            raise ValueError(scfg.selection)
        n = view.n_tokens
        budget = self._budget(cfg, length, n)

        # Probe shadow steps take the unfused XLA route even when the
        # fused kernel is on: the fused pass never materializes its
        # selection, and it is pinned elsewhere (differential harness)
        # to match value_aware_topk exactly — so the XLA selection
        # probed below IS the fused kernel's selection.
        if cfg.socket.use_paged_kernel and isinstance(view, base.PagedView) \
                and not bprobe.capturing():
            return self._attend_fused(cfg, params, q, view, length=length,
                                      scale=scale, budget=budget)

        mesh = None
        if isinstance(view, ContiguousView) and cfg.decode_cp_axes:
            from repro.distributed import sharding as shd
            mesh = shd.current_mesh()
            if mesh is not None and not any(a in mesh.shape
                                            for a in cfg.decode_cp_axes):
                mesh = None
        if mesh is not None:
            if jnp.ndim(length) == 1:
                # the shard_map fast path merges per-shard top-k under a
                # single scalar length; ragged batches take the pjit/XLA
                # route instead of crashing mid-serve
                from repro.serving.obs import warn_once
                warn_once(
                    "socket-ragged-cp-fallback",
                    "ragged decode + context-parallel SOCKET has no "
                    "shard_map path yet; falling back to the pjit/XLA "
                    "path for this step (scalar-length decode keeps the "
                    "context-parallel fast path)")
                mesh = None
        if mesh is not None:
            # §Perf: shard_map context-parallel path — local top-k per
            # sequence shard + psum online-softmax merge; avoids
            # materializing the (B,KVH,N) global score tensor
            from repro.distributed.context_parallel import \
                context_parallel_socket_attend
            cache = view.arrays
            return context_parallel_socket_attend(
                scfg, mesh, cfg.decode_cp_axes, params["hash_w"], q,
                base.dequant_leaf(cfg, view, "k"),
                base.dequant_leaf(cfg, view, "v"), cache["bits"],
                cache["vnorm"].astype(jnp.float32),
                length=length, scale=scale,
                batch_axes=cfg.decode_cp_batch_axes)

        scores = self._scores(cfg, params, q, view)
        vnorm = view.leaf("vnorm").astype(jnp.float32)
        kq = sk.topk_budget(scfg, n)
        if scfg.selection in ("kvhead", "pooled"):
            idx, sel_mask = sk.value_aware_topk(
                scfg, scores, vnorm, k=kq, length=length, n_total=n,
                budget=budget)
            if bprobe.capturing():
                # probe reference reads the DEQUANTIZED cached keys — the
                # same values the attend phase sees, so recall measures
                # selection quality at the serving precision
                bprobe.emit(bprobe.selection_stats(
                    scfg, q, base.dequant_leaf(cfg, view, "k"), vnorm,
                    idx, sel_mask, length=length, budget=budget,
                    static_k=kq, scale=scale))
            k_sel, v_sel = base.gather_kv_rows(cfg, view, idx)
            return base.subset_attention(cfg, q, k_sel, v_sel, sel_mask,
                                         scale=scale)
        # per-q-head selection: fold G into the selection axis, gather per
        # (kvh, g).  More faithful to the paper's single-head exposition
        # but loses the shared KV gather (and the flash_decode layout).
        idx, sel_mask = sk.value_aware_topk(
            scfg, scores, vnorm[:, :, None], k=kq, length=length,
            n_total=n, budget=budget)
        k_sel, v_sel = base.gather_kv_rows(cfg, view, idx)  # (B,KVH,G,K,hd)
        logits = jnp.einsum("bhgtd,bhgkd->bhgtk", q.astype(jnp.float32),
                            k_sel.astype(jnp.float32)) * scale
        logits = jnp.where(sel_mask[:, :, :, None, :], logits, sk.NEG_INF)
        wts = jax.nn.softmax(logits, axis=-1)
        out = jnp.einsum("bhgtk,bhgkd->bhgtd", wts,
                         v_sel.astype(jnp.float32))
        return out.astype(q.dtype)

    # ---- accounting -----------------------------------------------------
    def selected_rows(self, cfg, n):
        return sk.topk_budget(socket_config_of(cfg), n)

    def fused_paged(self, cfg):
        return bool(cfg.socket.use_paged_kernel)
