"""Dense decode backend (full attention; baseline / roofline reference).

Not paged-capable: every step reads the whole K/V context, so the serving
engine materializes contiguous views for it (`paged.gather_views`) — the
memory-traffic-bound path the sparse backends exist to avoid.
"""

from __future__ import annotations

from repro.baselines import oracle
from repro.models.backends import base
from repro.models.backends.base import KVView

__all__ = ["DenseBackend"]


class DenseBackend(base.DecodeBackend):
    name = "dense"
    supports_paged = False

    def cache_spec(self, cfg):
        return base.kv_leaf_specs(cfg)

    def prefill_build(self, cfg, params, cache, kc, vc):
        del cfg, params
        return base.write_prefill_kv(cache, kc, vc)

    def append(self, cfg, params, view: KVView, kc, vc, pos):
        del cfg, params
        view.write_token("k", pos, kc[:, :, 0])
        view.write_token("v", pos, vc[:, :, 0])

    def attend(self, cfg, params, q, view: KVView, *, length, scale):
        del cfg, params
        return oracle.dense_attention(q, view.leaf("k"), view.leaf("v"),
                                      scale=scale, length=length)
