"""Dense decode backend (full attention; baseline / roofline reference).

Not paged-capable: every step reads the whole K/V context, so the serving
engine materializes contiguous views for it (`paged.gather_views`) — the
memory-traffic-bound path the sparse backends exist to avoid.
"""

from __future__ import annotations

from repro.baselines import oracle
from repro.models.backends import base
from repro.models.backends.base import KVView

__all__ = ["DenseBackend"]


class DenseBackend(base.DecodeBackend):
    name = "dense"
    supports_paged = False

    def cache_spec(self, cfg):
        return base.kv_leaf_specs(cfg)

    def prefill_build(self, cfg, params, cache, kc, vc):
        del params
        return base.write_prefill_kv(cfg, cache, kc, vc)

    def append(self, cfg, params, view: KVView, kc, vc, pos):
        del params
        base.write_token_kv(cfg, view, pos, kc[:, :, 0], vc[:, :, 0])

    def attend(self, cfg, params, q, view: KVView, *, length, scale):
        del params
        return oracle.dense_attention(q, base.dequant_leaf(cfg, view, "k"),
                                      base.dequant_leaf(cfg, view, "v"),
                                      scale=scale, length=length)
