"""Hard-LSH decode backend (tau -> 0 ablation of SOCKET).

Shares SOCKET's cache layout (packed sign bits + value norms) and
value-aware top-k, but scores by *hard* collision counting: a key scores
the number of tables whose every plane sign agrees with the query's.
Paged-capable for the same reason SOCKET is — scoring reads only the bits
leaf, K/V only at the selected rows.
"""

from __future__ import annotations

import jax.numpy as jnp

from repro.core import hashing
from repro.core import socket as sk
from repro.models.backends import base
from repro.models.backends.socket import SocketBackend, socket_config_of

__all__ = ["HardLSHBackend"]


def _hard_collision_scores(scfg: sk.SocketConfig, bits, u_signs):
    """Hard collision counts from the same packed bits.

    bits (B,KVH,N,W); u_signs (B,KVH,G,L,P) ±1.  Returns (B,KVH,G,N).
    """
    l, p = scfg.num_tables, scfg.num_planes
    k_signs = hashing.unpack_signs(bits, l, p)           # (B,KVH,N,L,P)
    agree = jnp.einsum("bknlp,bkglp->bkgnl", k_signs, u_signs)
    return jnp.sum((agree >= p).astype(jnp.float32), axis=-1)


class HardLSHBackend(SocketBackend):
    name = "hard_lsh"
    supports_paged = True

    def fused_paged(self, cfg):
        # inherits SOCKET's cache layout but overrides attend() without a
        # fused dispatch — cfg.socket.use_paged_kernel must not make the
        # gather-footprint accounting claim a fused path that never runs
        return False

    def attend(self, cfg, params, q, view, *, length, scale):
        scfg = socket_config_of(cfg)
        n = view.n_tokens
        budget = self._budget(cfg, length, n)
        u = sk.soft_hash_query(params["hash_w"], q[..., 0, :])
        u_signs = jnp.where(u >= 0, 1.0, -1.0)
        scores = _hard_collision_scores(scfg, view.leaf("bits"), u_signs)
        scores = jnp.sum(scores, axis=2)                 # sum over group
        kq = sk.topk_budget(scfg, n)
        idx, sel_mask = sk.value_aware_topk(
            scfg, scores, view.leaf("vnorm").astype(jnp.float32), k=kq,
            length=length, n_total=n, budget=budget)
        k_sel = view.gather_rows("k", idx)
        v_sel = view.gather_rows("v", idx)
        return base.subset_attention(cfg, q, k_sel, v_sel, sel_mask,
                                     scale=scale)
