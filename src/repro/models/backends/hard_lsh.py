"""Hard-LSH decode backend (tau -> 0 ablation of SOCKET).

Shares SOCKET's cache layout (packed sign bits + value norms) and
value-aware top-k, but scores by *hard* collision counting: a key scores
the number of tables whose every plane sign agrees with the query's.
Paged-capable for the same reason SOCKET is — scoring reads only the bits
leaf, K/V only at the selected rows.

With ``cfg.socket.use_paged_kernel`` (the same gate as SOCKET — the
backends share the cache layout and every other kernel-eligibility
constraint) PagedView decode runs as one fused Pallas pass
(``kernels/paged_attention.paged_hard_lsh_attend``): in-register
bit-unpack + hard collision counting into the VMEM score ring, exact
radix-select of the per-request budget, and an online-softmax rescan of
only the selected rows — zero XLA gathers on the K/V pool.
"""

from __future__ import annotations

import jax.numpy as jnp

from repro.core import hashing
from repro.core import socket as sk
from repro.models.backends import base
from repro.models.backends import probe as bprobe
from repro.models.backends.socket import SocketBackend, socket_config_of

__all__ = ["HardLSHBackend"]


def _hard_collision_scores(scfg: sk.SocketConfig, bits, u_signs):
    """Hard collision counts from the same packed bits.

    bits (B,KVH,N,W); u_signs (B,KVH,G,L,P) ±1.  Returns (B,KVH,G,N).
    """
    l, p = scfg.num_tables, scfg.num_planes
    k_signs = hashing.unpack_signs(bits, l, p)           # (B,KVH,N,L,P)
    agree = jnp.einsum("bknlp,bkglp->bkgnl", k_signs, u_signs)
    return jnp.sum((agree >= p).astype(jnp.float32), axis=-1)


class HardLSHBackend(SocketBackend):
    name = "hard_lsh"
    supports_paged = True

    def _attend_fused(self, cfg, params, q, view, *, length, scale, budget):
        """Fused paged path: one Pallas pass over the block table."""
        scfg = socket_config_of(cfg)
        if scfg.bits_storage != "packed":
            raise NotImplementedError(
                "the fused paged kernel streams packed uint32 hash words; "
                "bits_storage='int8' must use the unfused paged path")
        if view.block_size % 8:
            raise NotImplementedError(
                f"fused paged kernel needs block_size % 8 == 0 (f32 "
                f"sublane tiling), got {view.block_size}")
        u = sk.soft_hash_query(params["hash_w"], q[..., 0, :])
        u_signs = jnp.where(u >= 0, 1.0, -1.0)
        kq = sk.topk_budget(scfg, view.n_tokens)
        if budget is None:
            budget = jnp.full((q.shape[0],), kq, jnp.int32)
        from repro.kernels.paged_attention import ops as pa_ops
        out = pa_ops.paged_hard_lsh_attend(
            q, view.arrays["k"], view.arrays["v"], view.arrays["bits"],
            view.arrays["vnorm"], u_signs, view.block_table, length=length,
            budget=budget, num_tables=scfg.num_tables,
            num_planes=scfg.num_planes, scale=scale,
            sink_tokens=scfg.sink_tokens, window_tokens=scfg.window_tokens,
            k_scale=base.kv_scales_of(view.arrays, "k"),
            v_scale=base.kv_scales_of(view.arrays, "v"))
        base.record_fused("paged_hard_lsh", out.shape)
        return out.astype(q.dtype)

    def attend(self, cfg, params, q, view, *, length, scale):
        scfg = socket_config_of(cfg)
        n = view.n_tokens
        budget = self._budget(cfg, length, n)

        # probe shadow steps keep the unfused route (same reasoning as
        # SocketBackend.attend: the fused selection is pinned to
        # value_aware_topk by the differential harness)
        if cfg.socket.use_paged_kernel and isinstance(view, base.PagedView) \
                and not bprobe.capturing():
            return self._attend_fused(cfg, params, q, view, length=length,
                                      scale=scale, budget=budget)

        u = sk.soft_hash_query(params["hash_w"], q[..., 0, :])
        u_signs = jnp.where(u >= 0, 1.0, -1.0)
        scores = _hard_collision_scores(scfg, view.leaf("bits"), u_signs)
        scores = jnp.sum(scores, axis=2)                 # sum over group
        kq = sk.topk_budget(scfg, n)
        vnorm = view.leaf("vnorm").astype(jnp.float32)
        idx, sel_mask = sk.value_aware_topk(
            scfg, scores, vnorm, k=kq,
            length=length, n_total=n, budget=budget)
        if bprobe.capturing():
            bprobe.emit(bprobe.selection_stats(
                scfg, q, base.dequant_leaf(cfg, view, "k"), vnorm,
                idx, sel_mask, length=length, budget=budget,
                static_k=kq, scale=scale))
        k_sel, v_sel = base.gather_kv_rows(cfg, view, idx)
        return base.subset_attention(cfg, q, k_sel, v_sel, sel_mask,
                                     scale=scale)

    def fused_paged(self, cfg):
        return bool(cfg.socket.use_paged_kernel)
