"""Decode-backend registry.

Every global-attention decode backend is one module implementing the
:class:`~repro.models.backends.base.DecodeBackend` interface and
registered here under its ``cfg.attention_backend`` name.  Adding a
backend = one module + one :func:`register` call; nothing in
``models/attention.py`` or the serving engine branches on backend names.

See ``base.py`` for the contract (cache_spec / prefill_build / append /
attend over a :class:`~repro.models.backends.base.KVView`) and
``src/repro/serving/README.md`` for what paged capability requires.
"""

from __future__ import annotations

from typing import Dict, Tuple

from repro.models.backends.base import (ContiguousView, DecodeBackend,
                                        KVView, LeafSpec, PagedView,
                                        gather_block_leaf, gather_trace,
                                        gather_trace_reset, record_fused)

__all__ = ["DecodeBackend", "KVView", "ContiguousView", "PagedView",
           "LeafSpec", "register", "get_backend", "registered_backends",
           "gather_block_leaf", "gather_trace", "gather_trace_reset",
           "record_fused", "socket_config_of"]

_REGISTRY: Dict[str, DecodeBackend] = {}


def register(cls):
    """Class decorator: instantiate and register a backend by its name."""
    assert cls.name, cls
    _REGISTRY[cls.name] = cls()
    return cls


def get_backend(name: str) -> DecodeBackend:
    try:
        return _REGISTRY[name]
    except KeyError:
        raise ValueError(
            f"unknown attention backend {name!r}; registered: "
            f"{registered_backends()}") from None


def registered_backends() -> Tuple[str, ...]:
    return tuple(sorted(_REGISTRY))


# ---- built-in backends ----------------------------------------------------
from repro.models.backends.dense import DenseBackend
from repro.models.backends.hard_lsh import HardLSHBackend
from repro.models.backends.quest import QuestBackend
from repro.models.backends.socket import SocketBackend, socket_config_of

for _cls in (SocketBackend, HardLSHBackend, QuestBackend, DenseBackend):
    register(_cls)
del _cls
