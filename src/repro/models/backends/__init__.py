"""Decode-backend registry.

Every global-attention decode backend is one module implementing the
:class:`~repro.models.backends.base.DecodeBackend` interface and
registered here under its ``cfg.attention_backend`` name.  Adding a
backend = one module + one :func:`register` call; nothing in
``models/attention.py`` or the serving engine branches on backend names.

See ``base.py`` for the contract (cache_spec / prefill_build / append /
attend over a :class:`~repro.models.backends.base.KVView`) and
``src/repro/serving/README.md`` for what paged capability requires.
"""

from __future__ import annotations

from typing import Dict, Tuple

from repro.models.backends import kvquant
from repro.models.backends.base import (ContiguousView, DecodeBackend,
                                        KVView, LayerCacheHandler,
                                        LayerCacheSpec, LeafSpec,
                                        PagedKVCacheHandler, PagedView,
                                        RingView, dequant_leaf,
                                        gather_block_leaf,
                                        gather_kv_rows, gather_trace,
                                        gather_trace_reset, kv_leaf_specs,
                                        kv_quant_mode, kv_scales_of,
                                        record_fused, ring_write_page,
                                        write_chunk_blocks,
                                        write_chunk_rows, write_token_kv)

__all__ = ["DecodeBackend", "KVView", "ContiguousView", "PagedView",
           "RingView", "LeafSpec", "LayerCacheSpec", "LayerCacheHandler",
           "PagedKVCacheHandler", "RingCacheHandler", "StateCacheHandler",
           "layer_cache_handler", "layer_cache_spec", "kv_leaf_specs",
           "register", "get_backend", "registered_backends",
           "gather_block_leaf", "gather_trace", "gather_trace_reset",
           "record_fused", "ring_write_page", "write_chunk_blocks",
           "write_chunk_rows", "socket_config_of", "kvquant",
           "kv_quant_mode", "kv_scales_of", "write_token_kv",
           "gather_kv_rows", "dequant_leaf"]

_REGISTRY: Dict[str, DecodeBackend] = {}


def register(cls):
    """Class decorator: instantiate and register a backend by its name."""
    assert cls.name, cls
    _REGISTRY[cls.name] = cls()
    return cls


def get_backend(name: str) -> DecodeBackend:
    try:
        return _REGISTRY[name]
    except KeyError:
        raise ValueError(
            f"unknown attention backend {name!r}; registered: "
            f"{registered_backends()}") from None


def registered_backends() -> Tuple[str, ...]:
    return tuple(sorted(_REGISTRY))


# ---- built-in backends ----------------------------------------------------
from repro.models.backends.dense import DenseBackend
from repro.models.backends.hard_lsh import HardLSHBackend
from repro.models.backends.quest import QuestBackend
from repro.models.backends.socket import SocketBackend, socket_config_of

for _cls in (SocketBackend, HardLSHBackend, QuestBackend, DenseBackend):
    register(_cls)
del _cls

# ---- per-layer cache plan resolution --------------------------------------
from repro.models.backends.ring import RingCacheHandler
from repro.models.backends.state import StateCacheHandler


def layer_cache_handler(cfg, spec) -> LayerCacheHandler:
    """Resolve one :class:`~repro.configs.base.LayerSpec` to its pool-side
    cache handler — the device half of the per-layer heterogeneous cache
    plan (``cfg.cache_plan()``): global attention layers get the decode
    backend's paged-KV layout, sliding-window layers a bounded circular
    page ring, Mamba layers fixed per-slot state rows."""
    if spec.kind != "attn":
        return StateCacheHandler()
    if spec.attn_type == "local":
        return RingCacheHandler()
    return PagedKVCacheHandler(get_backend(cfg.attention_backend))


def layer_cache_spec(cfg, spec) -> LayerCacheSpec:
    """Resolved declarative cache layout for one layer."""
    return layer_cache_handler(cfg, spec).spec(cfg)
