"""K/V pool-page quantization helpers (int8 / fp8 storage).

SOCKET's selection never reads the full-precision K/V rows — scoring
runs on packed hash bits + value norms — so the pool's K/V leaves can be
stored quantized and dequantized only at the attend rescan.  This module
is the single home of the quantization scheme every producer/consumer
shares:

* **Resolution** — ``cfg.serving.kv_dtype`` names the storage mode
  (``"auto"`` = the compute dtype, today's behavior; ``"bf16"`` = plain
  bfloat16 cast, no scales; ``"int8"`` / ``"fp8"`` = quantized rows with
  per-row scales).  ``cfg.cache_plan()`` resolves it per layer kind:
  paged and ring K/V quantize, per-slot Mamba state never does.
* **Scheme** — symmetric per-row absmax: one float32 scale per (token
  row, KV head), ``scale = absmax / QMAX`` (127 for int8, 448 for
  fp8 e4m3fn), stored in a ``k_scale``/``v_scale`` leaf alongside K/V
  exactly the way the SOCKET bit/vnorm side-cache rides along.  Per-row
  (not per-page) scales keep every write path local: a mid-page chunk
  commit, a single-token append and a CoW clone all touch only their own
  rows — no cross-row state, no extra HBM round-trip.
* **Round trip** — ``quantize`` is the one producer transform (jitted
  into whatever step calls it); ``dequantize`` the one consumer
  transform.  The fused Pallas kernels inline the same multiply
  in-register (see ``kernels/paged_attention``); the jnp form here
  serves the unfused O(top_k) gather path and the ref oracles, so both
  regimes see bit-identical dequantized values.

Zero rows are exact: ``absmax == 0`` stores ``scale = 0`` and quantized
zeros, so the dequantized row is exactly zero (the pool's init fill
round-trips bit-exactly — the CoW scrub and trash-page invariants don't
care about the storage dtype).
"""

from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

__all__ = ["KV_DTYPES", "QUANTIZED_KV_DTYPES", "is_quantized",
           "storage_dtype", "scale_dtype", "quantize", "dequantize",
           "resolve_kv_dtype"]

# serving.kv_dtype vocabulary (validated config-time in ModelConfig)
KV_DTYPES = ("auto", "bf16", "int8", "fp8")
QUANTIZED_KV_DTYPES = ("int8", "fp8")

# symmetric quantization grid ceilings
_QMAX = {"int8": 127.0, "fp8": 448.0}     # fp8 = float8_e4m3fn max normal

SCALE_DTYPE = jnp.float32


def is_quantized(kv_dtype: str) -> bool:
    """True when ``kv_dtype`` stores scaled integer/fp8 rows (and the
    cache therefore carries ``k_scale``/``v_scale`` leaves)."""
    return kv_dtype in QUANTIZED_KV_DTYPES


def storage_dtype(kv_dtype: str, compute_dtype):
    """The K/V leaf storage dtype for one resolved ``kv_dtype``."""
    if kv_dtype == "auto":
        return jnp.dtype(compute_dtype)
    if kv_dtype == "bf16":
        return jnp.dtype(jnp.bfloat16)
    if kv_dtype == "int8":
        return jnp.dtype(jnp.int8)
    if kv_dtype == "fp8":
        return jnp.dtype(jnp.float8_e4m3fn)
    raise ValueError(
        f"unknown kv_dtype {kv_dtype!r}; expected one of {KV_DTYPES}")


def scale_dtype():
    """Per-row scale leaf dtype (full precision: scales are metadata,
    like the SOCKET vnorm side-cache, never quantized)."""
    return jnp.dtype(SCALE_DTYPE)


def resolve_kv_dtype(kv_dtype: str, kind: str) -> str:
    """Resolve the serving-level knob for one cache-plan layer kind:
    paged and ring K/V follow the knob, per-slot state rows never
    quantize (they are O(1) per request and hold recurrent state whose
    error would compound)."""
    if kv_dtype not in KV_DTYPES:
        raise ValueError(
            f"unknown serving.kv_dtype {kv_dtype!r}; expected one of "
            f"{KV_DTYPES}")
    if kind == "state":
        return "auto"
    return kv_dtype


def quantize(x: jax.Array, kv_dtype: str) -> Tuple[jax.Array, jax.Array]:
    """Quantize ``(..., hd)`` rows symmetrically per row.

    Returns ``(q, scale)`` with ``q`` shaped like ``x`` in the storage
    dtype and ``scale`` ``(...,)`` float32 such that
    ``dequantize(q, scale) ~= x``.  Zero rows round-trip exactly.
    """
    qmax = _QMAX[kv_dtype]
    xf = x.astype(jnp.float32)
    absmax = jnp.max(jnp.abs(xf), axis=-1)
    scale = absmax / qmax
    safe = jnp.where(scale > 0, scale, 1.0)[..., None]
    scaled = xf / safe
    if kv_dtype == "int8":
        q = jnp.clip(jnp.round(scaled), -qmax, qmax).astype(jnp.int8)
    else:
        q = scaled.astype(jnp.float8_e4m3fn)
    return q, scale.astype(SCALE_DTYPE)


def dequantize(q: jax.Array, scale: jax.Array) -> jax.Array:
    """Inverse of :func:`quantize`: ``(..., hd) x (...,) -> (..., hd)``
    float32 rows.  The one dequant expression both the XLA gather path
    and the ref oracles use (the Pallas kernels inline the identical
    multiply in-register)."""
    return q.astype(jnp.float32) * scale[..., None].astype(jnp.float32)
