"""Ring-buffer cache handler for sliding-window ("local") attention layers.

Sliding-window layers only ever attend the last ``window`` tokens, so
their pages need not accumulate with context: the request block table's
first ``ring_blocks = ceil(window / block_size)`` entries are reused as a
**circular page list** (logical token ``t`` -> entry ``(t // block_size)
% ring_blocks``, row ``t % block_size``) and old pages are recycled in
place.  Per-slot block demand is bounded by ``ring_blocks`` regardless of
context length — on gemma3's 5:1 local:global pattern that bounds 52 of
62 layers by the window instead of the context.

Decode-side reads/writes go through :class:`~repro.models.backends.base
.RingView` (``models/attention.py``); this handler owns the pool-side
half: prefill scatter, the bounded contiguous ring views of the dense
fallback path, and the write-back of decode-updated ring rows.  Both
write paths **scrub at page-opening writes** (see
:func:`~repro.models.backends.base.ring_write_page`): recycled pool
blocks carry the previous owner's data and are never zeroed on device.
"""

from __future__ import annotations

import jax.numpy as jnp

from repro.models.backends import base

__all__ = ["RingCacheHandler"]


class RingCacheHandler(base.LayerCacheHandler):
    kind = "ring"

    def spec(self, cfg) -> base.LayerCacheSpec:
        return base.LayerCacheSpec(kind="ring",
                                   leaves=base.kv_leaf_specs(cfg),
                                   ring_blocks=cfg.ring_geometry()[0])

    def write_prefill(self, cfg, pages, cache, bt_row, slot):
        """The prefill ring cache is already in flat ring layout
        (``ring_blocks * block_size`` rows, slot ``s`` = the newest prompt
        position ``p ≡ s (mod capacity)``), so each ring block scatters to
        exactly one physical page — entries past the request's allocated
        blocks are trash-padded and absorb the unreachable writes.  Every
        row of every touched page is written, which scrubs any previous
        owner's data by construction."""
        del slot
        # the ring cache has rb * block_size rows, so the generic block
        # scatter consumes exactly bt_row[:ring_blocks]
        return {name: base.write_block_prefill(p, cache[name], bt_row)
                for name, p in pages.items()}

    def gather(self, cfg, pages, bt):
        """Bounded contiguous ring views ``(B, KVH, ring_blocks *
        block_size, hd)`` — window-sized, never context-sized."""
        rb = cfg.ring_geometry()[0]
        return {name: base.gather_block_leaf(p, bt[:, :rb])
                for name, p in pages.items()}

    def scatter(self, cfg, pages, views, bt, pos):
        bs = cfg.serving.block_size
        rb, rows = cfg.ring_geometry()
        b = bt.shape[0]
        bidx = jnp.arange(b)
        blk = bt[bidx, (pos // bs) % rb]
        out = {}
        for name, p in pages.items():
            val = views[name][bidx, :, pos % rows]     # (B, KVH, *rest)
            out[name] = base.ring_write_page(
                p, blk, pos, val, block_size=bs, ring_blocks=rb,
                window=cfg.sliding_window)
        return out
