"""Quest decode backend (page min/max metadata + page top-k) [43].

The metadata leaves are **page-granular** (``granularity =
cfg.quest.page_size`` rows in the cache spec): in the serving engine's
pool each physical block carries ``block_size / page_size`` min/max rows,
so Quest no longer fakes contiguous stats tensors — its page table IS the
block pool.  ``page_size`` must divide ``ServingSettings.block_size``
(asserted here and at engine construction).

Paged-capable: page scoring reads only the small kmin/kmax leaves; K/V
are gathered only for the selected pages.
"""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.baselines import quest as quest_mod
from repro.models.backends import base
from repro.models.backends import probe as bprobe
from repro.models.backends.base import KVView, LeafSpec

__all__ = ["QuestBackend"]


class QuestBackend(base.DecodeBackend):
    name = "quest"
    supports_paged = True

    @staticmethod
    def quest_config(cfg) -> quest_mod.QuestConfig:
        """Single source of truth for Quest knobs: page geometry from
        ``cfg.quest``, budget/sink/window shared with the SOCKET settings."""
        return quest_mod.QuestConfig(
            page_size=cfg.quest.page_size, sparsity=cfg.socket.sparsity,
            sink_tokens=cfg.socket.sink_tokens,
            window_tokens=cfg.socket.window_tokens,
            min_pages=cfg.quest.min_pages)

    # ---- layout ---------------------------------------------------------
    def cache_spec(self, cfg):
        ps = cfg.quest.page_size
        if cfg.serving.block_size % ps:
            raise ValueError(
                f"quest page_size {ps} must divide serving block_size "
                f"{cfg.serving.block_size} (one block = whole pages)")
        hd = cfg.head_dim
        spec = base.kv_leaf_specs(cfg)
        spec["kmin"] = LeafSpec(suffix=(hd,), granularity=ps, fill=np.inf)
        spec["kmax"] = LeafSpec(suffix=(hd,), granularity=ps, fill=-np.inf)
        return spec

    # ---- ops ------------------------------------------------------------
    def prefill_build(self, cfg, params, cache, kc, vc):
        del params
        cache = base.write_prefill_kv(cfg, cache, kc, vc)
        # page stats from the keys the attend phase will actually read
        # back (the quantization round trip under int8/fp8 storage), so
        # the min/max bounds stay sound — cfg.quest.stats_from_quantized,
        # enforced by ModelConfig.validate()
        keff = base.effective_keys(cfg, kc)
        b, kvh, t, hd = kc.shape
        ps = cfg.quest.page_size
        n_pages_t = -(-t // ps)
        pad = n_pages_t * ps - t
        kpad_min = jnp.pad(keff, ((0, 0), (0, 0), (0, pad), (0, 0)),
                           constant_values=np.inf)
        kpad_max = jnp.pad(keff, ((0, 0), (0, 0), (0, pad), (0, 0)),
                           constant_values=-np.inf)
        kmin = kpad_min.reshape(b, kvh, n_pages_t, ps, hd).min(axis=3)
        kmax = kpad_max.reshape(b, kvh, n_pages_t, ps, hd).max(axis=3)
        cache["kmin"] = cache["kmin"].at[:, :, :n_pages_t].set(
            kmin.astype(cache["kmin"].dtype))
        cache["kmax"] = cache["kmax"].at[:, :, :n_pages_t].set(
            kmax.astype(cache["kmax"].dtype))
        return cache

    def append(self, cfg, params, view: KVView, kc, vc, pos):
        del params
        base.write_token_kv(cfg, view, pos, kc[:, :, 0], vc[:, :, 0])
        # stats merge the key the attend phase will read back (the
        # quantization round trip under int8/fp8 storage)
        knew = base.effective_keys(cfg, kc)[:, :, 0]     # (B, KVH, hd)
        # A token opening a fresh page must *reset* the stats, not merge:
        # in the serving pool a decode-growth block may be a reused page
        # still carrying the previous owner's min/max (BlockPool never
        # scrubs device memory), and merging against stale bounds corrupts
        # page selection.  Page starts always coincide with block starts
        # (page_size | block_size), so resetting at pos % page_size == 0
        # covers every first write into a page.
        first = jnp.asarray(pos, jnp.int32) % cfg.quest.page_size == 0
        if first.ndim:
            first = first[:, None, None]                 # (B,1,1) ragged
        view.rmw_token(
            "kmin", pos, lambda old: jnp.where(
                first, knew.astype(old.dtype),
                jnp.minimum(old, knew.astype(old.dtype))))
        view.rmw_token(
            "kmax", pos, lambda old: jnp.where(
                first, knew.astype(old.dtype),
                jnp.maximum(old, knew.astype(old.dtype))))

    def _attend_fused(self, cfg, params, q, view, *, length, scale):
        """Fused paged path: one Pallas pass over the block table."""
        del params
        qcfg = self.quest_config(cfg)
        if view.block_size % 8:
            raise NotImplementedError(
                f"fused paged kernel needs block_size % 8 == 0 (f32 "
                f"sublane tiling), got {view.block_size}")
        n = view.n_tokens
        kp = quest_mod.page_budget(qcfg, n // qcfg.page_size, n)
        from repro.kernels.paged_attention import ops as pa_ops
        out = pa_ops.paged_quest_attend(
            q, view.arrays["k"], view.arrays["v"], view.arrays["kmin"],
            view.arrays["kmax"], view.block_table, length=length,
            page_budget=kp, page_size=qcfg.page_size, scale=scale,
            sink_tokens=qcfg.sink_tokens, window_tokens=qcfg.window_tokens,
            k_scale=base.kv_scales_of(view.arrays, "k"),
            v_scale=base.kv_scales_of(view.arrays, "v"))
        base.record_fused("paged_quest", out.shape)
        return out.astype(q.dtype)

    def attend(self, cfg, params, q, view: KVView, *, length, scale):
        # probe shadow steps keep the unfused route (the fused page
        # selection is pinned bitwise to select_tokens by the harness)
        if cfg.quest.use_paged_kernel and isinstance(view, base.PagedView) \
                and not bprobe.capturing():
            return self._attend_fused(cfg, params, q, view, length=length,
                                      scale=scale)
        del params
        qcfg = self.quest_config(cfg)
        state = quest_mod.QuestState(kmin=view.leaf("kmin"),
                                     kmax=view.leaf("kmax"))
        idx, sel_mask = quest_mod.select_tokens(
            qcfg, state, q, length=length, n=view.n_tokens)
        if bprobe.capturing():
            # QuestConfig carries the sink/window fields selection_stats
            # reads; budget is page-granular and folded into sel_mask, so
            # the reported budget is the static selection width
            bprobe.emit(bprobe.selection_stats(
                qcfg, q, base.dequant_leaf(cfg, view, "k"), None,
                idx, sel_mask, length=length, budget=None,
                static_k=idx.shape[-1], scale=scale))
        k_sel, v_sel = base.gather_kv_rows(cfg, view, idx)
        return base.subset_attention(cfg, q, k_sel, v_sel, sel_mask,
                                     scale=scale)

    # ---- accounting -----------------------------------------------------
    def selected_rows(self, cfg, n):
        qcfg = self.quest_config(cfg)
        n_pages = -(-n // qcfg.page_size)
        return quest_mod.page_budget(qcfg, n_pages, n) * qcfg.page_size

    def fused_paged(self, cfg):
        return bool(cfg.quest.use_paged_kernel)
