"""DecodeBackend protocol + KVView abstraction for sparse decode attention.

A **decode backend** owns one global-attention layer's cache layout and the
three operations the model/engine needs:

* ``cache_spec(cfg)``      — declarative leaf layout (:class:`LeafSpec`):
                             trailing shape, dtype, sequence **granularity**
                             (tokens per row — Quest metadata is
                             page-granular), and init fill value.
* ``prefill_build(...)``   — write the prompt's K/V rows + backend metadata
                             into a freshly allocated contiguous cache.
* ``append(...)``          — write one new token (K/V + metadata) through a
                             :class:`KVView` at logical position ``pos``.
* ``attend(...)``          — decode attention for one query step against a
                             :class:`KVView`.

``attend``/``append`` never touch array layout directly: they go through a
:class:`KVView`, which has two realizations.  :class:`ContiguousView` wraps
the standard ``(B, KVH, N, ...)`` cache; :class:`PagedView` wraps the
serving engine's page pool ``(num_blocks, KVH, block_size, ...)`` plus a
per-request block table, translating logical token indices through the
table.  A backend whose ``attend`` reads full K/V only through
``gather_rows`` (top-k selection) is **paged-capable**
(``supports_paged``): the engine then never materializes contiguous K/V
views — per step it moves only the small metadata leaves plus
``O(top_k)`` K/V rows.

Paged-view reads are recorded in a trace-time log (:func:`gather_trace`)
so tests and benchmarks can assert exactly which leaves a backend
materializes per decode step.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.backends import kvquant

__all__ = ["LeafSpec", "LayerCacheSpec", "KVView", "ContiguousView",
           "PagedView", "RingView", "DecodeBackend", "LayerCacheHandler",
           "PagedKVCacheHandler", "kv_leaf_specs", "write_prefill_kv",
           "subset_attention", "gather_trace", "gather_trace_reset",
           "record_fused", "gather_block_leaf", "write_block_prefill",
           "write_chunk_blocks", "write_chunk_rows", "ring_write_page",
           "kv_quant_mode", "write_token_kv", "gather_kv_rows",
           "dequant_leaf", "effective_keys", "kv_scales_of"]


def gather_block_leaf(pages: jax.Array, bt: jax.Array) -> jax.Array:
    """Materialize a paged leaf's logical view through a block table:
    ``(NB, KVH, rows_pb, *rest), (B, nb) -> (B, KVH, nb*rows_pb, *rest)``.

    The one implementation of the pool layout's logical flattening —
    shared by :meth:`PagedView.leaf`, the serving engine's dense
    fallback (``serving.paged.gather_views``), and the fused paged
    kernel's test oracle."""
    b, nb = bt.shape
    g = pages[bt]                          # (B, nb, KVH, rows_pb, *rest)
    g = jnp.moveaxis(g, 2, 1)              # (B, KVH, nb, rows_pb, *rest)
    return g.reshape(b, pages.shape[1], nb * pages.shape[2],
                     *pages.shape[3:])


@dataclasses.dataclass(frozen=True)
class LeafSpec:
    """Layout of one cache leaf along ``(batch, KVH, seq_rows, *suffix)``.

    ``granularity`` is tokens per sequence row: 1 for token-granular leaves
    (k, v, bits, vnorm), ``page_size`` for Quest's per-page min/max
    statistics.  A capacity of ``N`` tokens allocates ``ceil(N /
    granularity)`` rows.  ``dtype is None`` means "use the cache compute
    dtype"; ``fill`` is the zero/identity init value (±inf for min/max).
    """

    suffix: Tuple[int, ...] = ()
    dtype: Optional[Any] = None
    granularity: int = 1
    fill: float = 0.0

    def rows(self, capacity: int) -> int:
        return -(-capacity // self.granularity)

    def leaf_dtype(self, cache_dtype) -> Any:
        return cache_dtype if self.dtype is None else jnp.dtype(self.dtype)


def kv_quant_mode(cfg) -> str:
    """The resolved K/V storage mode for attention layers (paged and
    ring alike — ``cfg.cache_plan()`` resolves the same knob; Mamba state
    never quantizes)."""
    return getattr(cfg.serving, "kv_dtype", "auto")


def kv_leaf_specs(cfg) -> Dict[str, LeafSpec]:
    """The K/V leaves every backend stores.

    Under ``serving.kv_dtype`` ``"int8"``/``"fp8"`` the k/v leaves store
    quantized rows and a float32 per-row scale leaf rides along
    (``k_scale``/``v_scale``, empty suffix, granularity 1 — exactly how
    the SOCKET bits/vnorm side-cache rides along), produced/consumed via
    :mod:`repro.models.backends.kvquant`.  ``"bf16"`` is a plain storage
    cast (no scales); ``"auto"`` keeps the compute dtype.
    """
    hd = cfg.head_dim
    kvd = kv_quant_mode(cfg)
    if kvd == "auto":
        return {"k": LeafSpec(suffix=(hd,)), "v": LeafSpec(suffix=(hd,))}
    sdt = kvquant.storage_dtype(kvd, None)
    spec = {"k": LeafSpec(suffix=(hd,), dtype=sdt),
            "v": LeafSpec(suffix=(hd,), dtype=sdt)}
    if kvquant.is_quantized(kvd):
        spec["k_scale"] = LeafSpec(suffix=(), dtype=kvquant.scale_dtype())
        spec["v_scale"] = LeafSpec(suffix=(), dtype=kvquant.scale_dtype())
    return spec


def kv_scales_of(arrays: Dict[str, jax.Array], name: str):
    """The scale leaf paired with K/V leaf ``name`` (None when the cache
    is unquantized)."""
    return arrays.get(name + "_scale")


@dataclasses.dataclass(frozen=True)
class LayerCacheSpec:
    """One layer's resolved cache layout on the serving engine's pool.

    * ``kind == "paged"`` — leaves live in pool pages addressed linearly
      through the request block table (global-attention backends).
    * ``kind == "ring"`` — K/V pages addressed circularly through the
      first ``ring_blocks`` block-table entries (sliding-window layers).
    * ``kind == "state"`` — fixed per-decode-slot leaves (batch axis =
      slots), no block table at all (Mamba conv tail + SSD state);
      ``leaves`` is empty — shapes come from the Mamba cache builder.
    """

    kind: str
    leaves: Dict[str, LeafSpec]
    ring_blocks: int = 0


# --------------------------------------------------------------------- trace

# Trace-time log of paged-view materializations: each PagedView.leaf /
# gather_rows call appends (kind, leaf_name, shape) while the enclosing
# function is being traced.  Tests assert the SOCKET paged path never
# materializes full "k"/"v" leaves; the serving benchmark turns the shapes
# into per-step gathered bytes.
_GATHER_TRACE = []


def gather_trace_reset() -> None:
    _GATHER_TRACE.clear()


def gather_trace():
    return list(_GATHER_TRACE)


def record_fused(name: str, shape) -> None:
    """Log a fused-kernel dispatch (kind ``"fused"``): the attend consumed
    the pool + block table in place — zero leaf materializations, zero
    K/V row gathers.  Lets the zero-gather tests distinguish "the fused
    path ran" from "the paged path was never exercised"."""
    _GATHER_TRACE.append(("fused", name, tuple(shape)))


# --------------------------------------------------------------------- views

class KVView:
    """Uniform read/write interface over one layer's decode cache.

    ``arrays`` maps leaf name -> array; layout depends on the subclass.
    Writes replace entries in ``arrays`` functionally (the dict mutates,
    the arrays never do) — callers read back ``view.arrays`` as the
    updated cache pytree.
    """

    def __init__(self, arrays: Dict[str, jax.Array],
                 spec: Dict[str, LeafSpec]):
        self.arrays = dict(arrays)
        self.spec = spec

    # ---- reads
    @property
    def n_tokens(self) -> int:
        """Logical token capacity of the view."""
        raise NotImplementedError

    def leaf(self, name: str) -> jax.Array:
        """Full logical-layout leaf ``(B, KVH, rows, *suffix)``."""
        raise NotImplementedError

    def gather_rows(self, name: str, idx: jax.Array) -> jax.Array:
        """Rows of a token-granular leaf at logical indices ``idx``
        ``(B, KVH, *sel)`` -> ``(B, KVH, *sel, *suffix)``."""
        raise NotImplementedError

    # ---- writes (one token at logical position pos: scalar or (B,))
    def write_token(self, name: str, pos: jax.Array,
                    value: jax.Array) -> None:
        """Set the row covering token ``pos`` to ``value`` (B, KVH, *suffix)."""
        raise NotImplementedError

    def rmw_token(self, name: str, pos: jax.Array, fn) -> None:
        """Read-modify-write the row covering token ``pos`` (Quest min/max):
        ``row <- fn(row)``."""
        raise NotImplementedError

    # ---- helpers
    def _pos_vec(self, pos: jax.Array, batch: int) -> jax.Array:
        pos = jnp.asarray(pos, jnp.int32)
        return jnp.broadcast_to(pos, (batch,)) if pos.ndim == 0 else pos


class ContiguousView(KVView):
    """Today's layout: each leaf is ``(B, KVH, rows, *suffix)``."""

    @property
    def n_tokens(self) -> int:
        return self.arrays["k"].shape[2] * self.spec["k"].granularity

    def leaf(self, name: str) -> jax.Array:
        return self.arrays[name]

    def gather_rows(self, name: str, idx: jax.Array) -> jax.Array:
        assert self.spec[name].granularity == 1, name
        a = self.arrays[name]
        b, kvh = a.shape[:2]
        bidx = jnp.arange(b).reshape(b, *([1] * (idx.ndim - 1)))
        hidx = jnp.arange(kvh).reshape(1, kvh, *([1] * (idx.ndim - 2)))
        return a[bidx, hidx, idx]

    def _row(self, name: str, pos: jax.Array):
        a = self.arrays[name]
        pos = self._pos_vec(pos, a.shape[0])
        return a, jnp.arange(a.shape[0]), pos // self.spec[name].granularity

    # Scalar pos (lockstep batch) keeps the dynamic-update-slice lowering —
    # a per-row scatter for the whole-batch-one-position case is markedly
    # slower than DUS on TPU; the gather/scatter form is only for ragged
    # (B,) position vectors.
    def write_token(self, name, pos, value) -> None:
        a = self.arrays[name]
        if jnp.ndim(pos) == 0:
            row = jnp.asarray(pos, jnp.int32) // self.spec[name].granularity
            start = (0, 0, row) + (0,) * (a.ndim - 3)
            self.arrays[name] = jax.lax.dynamic_update_slice(
                a, value[:, :, None].astype(a.dtype), start)
            return
        a, bidx, row = self._row(name, pos)
        self.arrays[name] = a.at[bidx, :, row].set(value.astype(a.dtype))

    def rmw_token(self, name, pos, fn) -> None:
        a = self.arrays[name]
        if jnp.ndim(pos) == 0:
            row = jnp.asarray(pos, jnp.int32) // self.spec[name].granularity
            start = (0, 0, row) + (0,) * (a.ndim - 3)
            old = jax.lax.dynamic_slice(
                a, start, (a.shape[0], a.shape[1], 1) + a.shape[3:])
            self.arrays[name] = jax.lax.dynamic_update_slice(
                a, fn(old[:, :, 0])[:, :, None].astype(a.dtype), start)
            return
        a, bidx, row = self._row(name, pos)
        self.arrays[name] = a.at[bidx, :, row].set(
            fn(a[bidx, :, row]).astype(a.dtype))


class PagedView(KVView):
    """Serving-engine layout: each leaf is ``(num_blocks, KVH,
    block_size / granularity, *suffix)`` plus a per-request block table
    ``(B, blocks_per_seq)`` of physical block ids (trash-padded).

    Logical token ``t`` of request ``b`` lives in physical block
    ``block_table[b, t // block_size]`` at row ``(t % block_size) //
    granularity``.  ``leaf()`` materializes the full logical view (cheap
    for metadata leaves, what paged-capable backends avoid for K/V);
    ``gather_rows`` translates selected logical indices through the table
    and touches only those rows.
    """

    def __init__(self, arrays, spec, block_table: jax.Array,
                 block_size: int):
        super().__init__(arrays, spec)
        self.block_table = block_table
        self.block_size = block_size

    @property
    def n_tokens(self) -> int:
        return self.block_table.shape[1] * self.block_size

    def leaf(self, name: str) -> jax.Array:
        out = gather_block_leaf(self.arrays[name], self.block_table)
        _GATHER_TRACE.append(("leaf", name, out.shape))
        return out

    def gather_rows(self, name: str, idx: jax.Array) -> jax.Array:
        assert self.spec[name].granularity == 1, name
        pages = self.arrays[name]
        bt = self.block_table
        b, kvh = bt.shape[0], pages.shape[1]
        bidx = jnp.arange(b).reshape(b, *([1] * (idx.ndim - 1)))
        hidx = jnp.arange(kvh).reshape(1, kvh, *([1] * (idx.ndim - 2)))
        blk = bt[bidx, idx // self.block_size]
        out = pages[blk, hidx, idx % self.block_size]
        _GATHER_TRACE.append(("rows", name, out.shape))
        return out

    def _addr(self, name: str, pos: jax.Array):
        pages = self.arrays[name]
        pos = self._pos_vec(pos, self.block_table.shape[0])
        bidx = jnp.arange(self.block_table.shape[0])
        blk = self.block_table[bidx, pos // self.block_size]
        row = (pos % self.block_size) // self.spec[name].granularity
        return pages, blk, row

    def write_token(self, name, pos, value) -> None:
        pages, blk, row = self._addr(name, pos)
        self.arrays[name] = pages.at[blk, :, row].set(
            value.astype(pages.dtype))

    def rmw_token(self, name, pos, fn) -> None:
        pages, blk, row = self._addr(name, pos)
        self.arrays[name] = pages.at[blk, :, row].set(
            fn(pages[blk, :, row]).astype(pages.dtype))


def ring_write_page(pages: jax.Array, blk: jax.Array, pos: jax.Array,
                    value: jax.Array, *, block_size: int, ring_blocks: int,
                    window: int) -> jax.Array:
    """Write token ``pos``'s ``value`` (B, KVH, *suffix) into its circular
    page ``blk`` (B,) at row ``pos % block_size``, **scrubbing rows that
    cannot hold in-window tokens at page-opening writes** (row 0):

    * first pass over the ring (``pos < ring capacity``): the page is a
      freshly allocated pool block still carrying its previous owner's
      data, and no row past the one written can be valid yet (they map
      to negative positions) — zero it all;
    * later passes: rows ``[1, capacity - window]`` hold positions that
      fell out of the window the moment this page reopened — zero that
      dead band, keep the still-live window rows (``capacity - window <
      block_size`` always, since ``capacity = ceil(window / block_size)
      * block_size``).

    Ring validity masking already excludes every scrubbed row from
    attention; the scrub exists so pool contents are a pure function of
    the live requests — recycled blocks are never zeroed on device
    otherwise.  Active slots hold disjoint blocks; only trash-page
    writes alias (their content is never read unmasked)."""
    b = blk.shape[0]
    cap = ring_blocks * block_size
    row = pos % block_size
    page = pages[blk]                      # (B, KVH, block_size, *suffix)
    r = jnp.arange(block_size)
    scrub = (row == 0)[:, None] & (r[None] >= 1) & (
        (r[None] <= cap - window) | (pos < cap)[:, None])   # (B, bs)
    scrub = scrub.reshape(b, 1, block_size, *([1] * (page.ndim - 3)))
    page = jnp.where(scrub, jnp.zeros((), page.dtype), page)
    page = page.at[jnp.arange(b), :, row].set(value.astype(page.dtype))
    return pages.at[blk].set(page)


class RingView(PagedView):
    """Sliding-window ring over pool pages: the first ``ring_blocks``
    block-table entries form a circular page list — logical token ``t``
    lives at entry ``(t // block_size) % ring_blocks``, row
    ``t % block_size`` (so flat ring slot ``t % (ring_blocks *
    block_size)``).  Old pages are recycled in place; per-slot block
    demand never exceeds ``ring_blocks``.

    ``leaf()`` materializes the *bounded* ring view (``ring_blocks *
    block_size`` rows — window-sized, never context-sized), recorded in
    the gather trace under kind ``"ring"`` so the zero-materialization
    assertions for paged K/V stay meaningful.  ``window`` drives the
    page-opening scrub of :func:`ring_write_page`.
    """

    def __init__(self, arrays, spec, block_table: jax.Array,
                 block_size: int, ring_blocks: int, window: int):
        super().__init__(arrays, spec, block_table, block_size)
        self.ring_blocks = ring_blocks
        self.window = window

    @property
    def n_tokens(self) -> int:
        return self.ring_blocks * self.block_size

    def leaf(self, name: str) -> jax.Array:
        out = gather_block_leaf(self.arrays[name],
                                self.block_table[:, :self.ring_blocks])
        _GATHER_TRACE.append(("ring", name, out.shape))
        return out

    def _addr(self, name: str, pos: jax.Array):
        assert self.spec[name].granularity == 1, name
        pages = self.arrays[name]
        pos = self._pos_vec(pos, self.block_table.shape[0])
        bidx = jnp.arange(self.block_table.shape[0])
        blk = self.block_table[
            bidx, (pos // self.block_size) % self.ring_blocks]
        return pages, blk, pos % self.block_size

    def gather_rows(self, name: str, idx: jax.Array) -> jax.Array:
        pages = self.arrays[name]
        bt = self.block_table
        b, kvh = bt.shape[0], pages.shape[1]
        bidx = jnp.arange(b).reshape(b, *([1] * (idx.ndim - 1)))
        hidx = jnp.arange(kvh).reshape(1, kvh, *([1] * (idx.ndim - 2)))
        blk = bt[bidx, (idx // self.block_size) % self.ring_blocks]
        out = pages[blk, hidx, idx % self.block_size]
        _GATHER_TRACE.append(("ring", name, out.shape))
        return out

    def write_token(self, name, pos, value) -> None:
        assert self.spec[name].granularity == 1, name
        pages = self.arrays[name]
        pos = self._pos_vec(pos, self.block_table.shape[0])
        bidx = jnp.arange(self.block_table.shape[0])
        blk = self.block_table[
            bidx, (pos // self.block_size) % self.ring_blocks]
        self.arrays[name] = ring_write_page(
            pages, blk, pos, value, block_size=self.block_size,
            ring_blocks=self.ring_blocks, window=self.window)


# ------------------------------------------------------------------ backend

def write_prefill_kv(cfg, cache: Dict[str, jax.Array], kc: jax.Array,
                     vc: jax.Array) -> Dict[str, jax.Array]:
    """Write the prompt K/V ``(B, KVH, T, hd)`` into rows [0, T),
    quantizing on write (absmax per row, inside the caller's jit — no
    extra HBM round-trip) when the cache carries scale leaves."""
    t = kc.shape[2]
    kvd = kv_quant_mode(cfg)
    cache = dict(cache)
    if kvquant.is_quantized(kvd):
        kq, ks = kvquant.quantize(kc, kvd)
        vq, vs = kvquant.quantize(vc, kvd)
        cache["k"] = cache["k"].at[:, :, :t].set(kq)
        cache["v"] = cache["v"].at[:, :, :t].set(vq)
        cache["k_scale"] = cache["k_scale"].at[:, :, :t].set(ks)
        cache["v_scale"] = cache["v_scale"].at[:, :, :t].set(vs)
        return cache
    cache["k"] = cache["k"].at[:, :, :t].set(kc.astype(cache["k"].dtype))
    cache["v"] = cache["v"].at[:, :, :t].set(vc.astype(cache["v"].dtype))
    return cache


def write_token_kv(cfg, view: "KVView", pos: jax.Array, kc: jax.Array,
                   vc: jax.Array) -> None:
    """Append-side K/V write of one token ``(B, KVH, hd)`` through a
    view, quantizing on write when the cache carries scale leaves."""
    kvd = kv_quant_mode(cfg)
    if kvquant.is_quantized(kvd):
        kq, ks = kvquant.quantize(kc, kvd)
        vq, vs = kvquant.quantize(vc, kvd)
        view.write_token("k", pos, kq)
        view.write_token("v", pos, vq)
        view.write_token("k_scale", pos, ks)
        view.write_token("v_scale", pos, vs)
        return
    view.write_token("k", pos, kc)
    view.write_token("v", pos, vc)


def gather_kv_rows(cfg, view: "KVView", idx: jax.Array):
    """The unfused paths' K/V read: gather the O(top_k) selected rows and
    dequantize ONLY those (the quantized pool rows never round-trip
    through HBM at full precision).  Returns ``(k_sel, v_sel)`` in
    float32 under quantization, storage dtype otherwise."""
    k_sel = view.gather_rows("k", idx)
    v_sel = view.gather_rows("v", idx)
    if kvquant.is_quantized(kv_quant_mode(cfg)):
        k_sel = kvquant.dequantize(k_sel, view.gather_rows("k_scale", idx))
        v_sel = kvquant.dequantize(v_sel, view.gather_rows("v_scale", idx))
    return k_sel, v_sel


def dequant_leaf(cfg, view: "KVView", name: str) -> jax.Array:
    """Full logical K/V leaf, dequantized when the cache carries scale
    leaves (dense fallback / probe shadow path — the fused kernels never
    take this route)."""
    a = view.leaf(name)
    if name in ("k", "v") and kvquant.is_quantized(kv_quant_mode(cfg)):
        return kvquant.dequantize(a, view.leaf(name + "_scale"))
    return a


def effective_keys(cfg, kc: jax.Array) -> jax.Array:
    """The key values the attend phase will actually read back: the
    quantization round trip of ``kc`` under int8/fp8 storage, ``kc``
    itself otherwise.  Quest's kmin/kmax page stats are computed from
    this (``quest.stats_from_quantized``) so the per-page bounds cover
    the dequantized keys and the upper-bound score stays sound."""
    kvd = kv_quant_mode(cfg)
    if kvquant.is_quantized(kvd) and cfg.quest.stats_from_quantized:
        return kvquant.dequantize(*kvquant.quantize(kc, kvd))
    return kc


def subset_attention(cfg, q: jax.Array, k_sel: jax.Array, v_sel: jax.Array,
                     sel_mask: jax.Array, *, scale: float) -> jax.Array:
    """Exact attention over a gathered subset, routed through the Pallas
    ``flash_decode`` kernel when ``cfg.socket.use_flash_decode`` is set
    (interpret mode off-TPU) and the layout is the shared-KV one."""
    if cfg.socket.use_flash_decode and k_sel.ndim == 4:
        from repro.kernels.flash_decode import ops as fd_ops
        return fd_ops.flash_decode(q, k_sel, v_sel, sel_mask, scale=scale)
    from repro.core import socket as sk
    return sk.sparse_attention_over_subset(q, k_sel, v_sel, sel_mask,
                                           scale=scale)


class DecodeBackend:
    """One decode-attention backend (see module docstring).

    Subclasses set ``name`` (registry key) and ``supports_paged`` (True
    iff ``attend`` reads K/V only via ``gather_rows`` so the serving
    engine can skip contiguous-view materialization entirely).
    """

    name: str = ""
    supports_paged: bool = False

    # ---- layout ---------------------------------------------------------
    def cache_spec(self, cfg) -> Dict[str, LeafSpec]:
        raise NotImplementedError

    def init_cache(self, cfg, batch: int, kv_heads: int, capacity: int,
                   dtype) -> Dict[str, jax.Array]:
        """Allocate one layer's contiguous cache from the spec."""
        out = {}
        for name, s in self.cache_spec(cfg).items():
            out[name] = jnp.full(
                (batch, kv_heads, s.rows(capacity), *s.suffix),
                s.fill, s.leaf_dtype(dtype))
        return out

    def cache_axes(self, cfg, seq_axis: str) -> Dict[str, Tuple]:
        """Logical sharding axes mirroring :meth:`init_cache`."""
        return {name: ("cache_batch", "cache_heads", seq_axis) +
                (None,) * len(s.suffix)
                for name, s in self.cache_spec(cfg).items()}

    # ---- ops ------------------------------------------------------------
    def prefill_build(self, cfg, params, cache: Dict[str, jax.Array],
                      kc: jax.Array, vc: jax.Array) -> Dict[str, jax.Array]:
        """Write prompt K/V ``(B, KVH, T, hd)`` + metadata into ``cache``."""
        raise NotImplementedError

    def append(self, cfg, params, view: KVView, kc: jax.Array,
               vc: jax.Array, pos: jax.Array) -> None:
        """Write one token's K/V ``(B, KVH, 1, hd)`` + metadata at ``pos``
        (scalar or per-request ``(B,)`` vector) through the view."""
        raise NotImplementedError

    def attend(self, cfg, params, q: jax.Array, view: KVView, *,
               length, scale: float) -> jax.Array:
        """Decode attention for ``q`` ``(B, KVH, G, 1, hd)`` against the
        view's first ``length`` tokens (scalar or ragged ``(B,)``).
        Per-request sparsity budgets are derived from ``length`` when it
        is a vector."""
        raise NotImplementedError

    # ---- accounting -----------------------------------------------------
    def selected_rows(self, cfg, n: int) -> int:
        """Static K/V rows gathered per step at capacity ``n`` (for the
        memory-traffic accounting in :func:`repro.serving.paged
        .gather_footprint`)."""
        return n

    def fused_paged(self, cfg) -> bool:
        """True when this backend's PagedView attend runs as one fused
        kernel over the pool — zero XLA gathers, zero materialized
        views, so the gather-footprint accounting reports ≈ 0."""
        return False


# --------------------------------------------------------- cache handlers

def write_block_prefill(pages: jax.Array, leaf: jax.Array,
                        bt_row: jax.Array) -> jax.Array:
    """Scatter a batch=1 prefill cache leaf ``(1, KVH, rows, *rest)`` into
    pool pages addressed by ``bt_row`` (block ids, trash-padded; only the
    first ``rows / rows_per_block`` entries are consumed)."""
    kvh, rows = leaf.shape[1], leaf.shape[2]
    rows_pb = pages.shape[2]
    nb = rows // rows_pb
    blocks = leaf[0].reshape(kvh, nb, rows_pb, *leaf.shape[3:])
    blocks = jnp.moveaxis(blocks, 1, 0)      # (nb, KVH, rows_pb, *rest)
    return pages.at[bt_row[:nb]].set(blocks.astype(pages.dtype))


def write_chunk_blocks(pages: jax.Array, leaf: jax.Array,
                       bt_row: jax.Array, block0) -> jax.Array:
    """Scatter one *prefill chunk's* batch=1 cache leaf ``(1, KVH, rows,
    *rest)`` into pool pages at block-table offset ``block0`` (a traced
    scalar — the chunk's first logical block, ``history // block_size``):
    the chunked analogue of :func:`write_block_prefill`.  ``bt_row`` must
    be padded so ``block0 + rows / rows_per_block`` never exceeds its
    static length (entries past the request's allocation are trash)."""
    kvh, rows = leaf.shape[1], leaf.shape[2]
    rows_pb = pages.shape[2]
    nb = rows // rows_pb
    blocks = leaf[0].reshape(kvh, nb, rows_pb, *leaf.shape[3:])
    blocks = jnp.moveaxis(blocks, 1, 0)      # (nb, KVH, rows_pb, *rest)
    ids = jax.lax.dynamic_slice(bt_row, (jnp.asarray(block0, jnp.int32),),
                                (nb,))
    return pages.at[ids].set(blocks.astype(pages.dtype))


def write_chunk_rows(pages: jax.Array, leaf: jax.Array, bt_row: jax.Array,
                     history, last_index) -> jax.Array:
    """Row-granular variant of :func:`write_chunk_blocks`: chunk token
    ``i`` lands at logical position ``history + i``, i.e. row
    ``(history + i) % rows_per_block`` of block ``bt_row[(history + i) //
    rows_per_block]``.  Needed when the chunk start is **not**
    page-aligned — a prefix-cache hit resumes prefill mid-page after the
    shared tail page is CoW-cloned — and only valid for granularity-1
    leaves (per-token rows; page-granular stats can't be written by the
    row).  Rows past ``last_index`` (final-chunk padding) are routed to
    the trash page instead of committing junk into real blocks."""
    rows = leaf.shape[2]
    rows_pb = pages.shape[2]
    i = jnp.arange(rows, dtype=jnp.int32)
    ti = jnp.asarray(history, jnp.int32) + i
    blk = jnp.where(i <= jnp.asarray(last_index, jnp.int32),
                    bt_row[ti // rows_pb], 0)
    vals = jnp.moveaxis(leaf[0], 1, 0)       # (rows, KVH, *rest)
    return pages.at[blk, :, ti % rows_pb].set(vals.astype(pages.dtype))


class LayerCacheHandler:
    """Pool-side operations for ONE layer of the per-layer cache plan.

    The serving engine's pool helpers (:mod:`repro.serving.paged`) resolve
    each layer to a handler (``layer_cache_handler``) and dispatch through
    this interface; grouped (scan-stacked) layers are lifted over the
    group axis with ``jax.vmap`` by the caller.  All methods operate on
    one layer's leaf dict (name -> array).

    * ``spec``          — declarative :class:`LayerCacheSpec`.
    * ``write_prefill`` — scatter a fresh batch=1 prefill cache into the
                          pool (pages via ``bt_row`` or slot row ``slot``).
    * ``gather``        — materialize the contiguous per-slot views the
                          unmodified (non-paged) decode path consumes.
    * ``scatter``       — write the row(s) a decode step updated in those
                          views back into the pool.
    """

    kind: str = ""

    def spec(self, cfg) -> LayerCacheSpec:
        raise NotImplementedError

    def write_prefill(self, cfg, pages: Dict[str, jax.Array],
                      cache: Dict[str, jax.Array], bt_row: jax.Array,
                      slot: jax.Array) -> Dict[str, jax.Array]:
        raise NotImplementedError

    def gather(self, cfg, pages: Dict[str, jax.Array],
               bt: jax.Array) -> Dict[str, jax.Array]:
        raise NotImplementedError

    def scatter(self, cfg, pages: Dict[str, jax.Array],
                views: Dict[str, jax.Array], bt: jax.Array,
                pos: jax.Array) -> Dict[str, jax.Array]:
        raise NotImplementedError


class PagedKVCacheHandler(LayerCacheHandler):
    """Global-attention layers: the decode backend's ``cache_spec`` leaves
    in pool pages, block table consumed linearly (unchanged layout)."""

    kind = "paged"

    def __init__(self, backend: DecodeBackend):
        self.backend = backend

    def spec(self, cfg) -> LayerCacheSpec:
        return LayerCacheSpec(kind="paged",
                              leaves=self.backend.cache_spec(cfg))

    def write_prefill(self, cfg, pages, cache, bt_row, slot):
        del slot
        return {name: write_block_prefill(pages[name], cache[name], bt_row)
                for name in pages}

    def gather(self, cfg, pages, bt):
        return {name: gather_block_leaf(p, bt) for name, p in pages.items()}

    def scatter(self, cfg, pages, views, bt, pos):
        """Write the row each slot updated at token index ``pos[b]`` (view
        row ``pos // gran``) into physical page ``bt[b, pos //
        block_size]``.  Inactive slots point at the trash block; duplicate
        trash writes are benign."""
        bs = cfg.serving.block_size
        spec = self.backend.cache_spec(cfg)
        b = bt.shape[0]
        bidx = jnp.arange(b)
        blk = bt[bidx, pos // bs]
        out = {}
        for name, p in pages.items():
            gran = spec[name].granularity
            row = views[name][bidx, :, pos // gran]   # (B, KVH, *rest)
            out[name] = p.at[blk, :, (pos % bs) // gran].set(
                row.astype(p.dtype))
        return out
