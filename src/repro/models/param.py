"""Boxed parameters: value + logical sharding axes, in one pytree.

Model ``init_*`` functions build trees of :class:`Boxed` leaves; the
launcher strips them into (values, axes) with :func:`unbox`/:func:`axes_of`
and converts axes to ``NamedSharding`` via ``distributed.sharding``.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["Boxed", "normal", "zeros", "ones", "constant", "unbox",
           "axes_of", "stack_boxed", "tree_paths_matching", "leaf_count"]


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class Boxed:
    value: jax.Array
    axes: Tuple[Optional[str], ...]

    def tree_flatten(self):
        return (self.value,), self.axes

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(children[0], aux)

    @property
    def shape(self):
        return self.value.shape


def _check(shape, axes):
    if len(shape) != len(axes):
        raise ValueError(f"axes {axes} do not match shape {shape}")


def normal(rng: jax.Array, shape, axes, *, stddev: float = 1.0,
           dtype=jnp.float32) -> Boxed:
    _check(shape, axes)
    return Boxed(jax.random.normal(rng, shape, dtype) * jnp.asarray(
        stddev, dtype), tuple(axes))


def zeros(shape, axes, *, dtype=jnp.float32) -> Boxed:
    _check(shape, axes)
    return Boxed(jnp.zeros(shape, dtype), tuple(axes))


def ones(shape, axes, *, dtype=jnp.float32) -> Boxed:
    _check(shape, axes)
    return Boxed(jnp.ones(shape, dtype), tuple(axes))


def constant(value: jax.Array, axes) -> Boxed:
    _check(value.shape, axes)
    return Boxed(value, tuple(axes))


def unbox(tree):
    return jax.tree_util.tree_map(
        lambda b: b.value, tree, is_leaf=lambda x: isinstance(x, Boxed))


def axes_of(tree):
    return jax.tree_util.tree_map(
        lambda b: b.axes, tree, is_leaf=lambda x: isinstance(x, Boxed))


def stack_boxed(boxes):
    """Stack a list of identically-structured Boxed trees along a new
    leading 'groups' axis (for scan-over-groups parameter stacking)."""
    def _stack(*bs):
        return Boxed(jnp.stack([b.value for b in bs]),
                     ("groups",) + bs[0].axes)
    return jax.tree_util.tree_map(
        _stack, *boxes, is_leaf=lambda x: isinstance(x, Boxed))


def tree_paths_matching(tree, predicate: Callable[[str], bool]):
    """Boolean mask pytree: True where the joined key-path satisfies
    ``predicate`` (used for optimizer masks, e.g. freezing hash planes)."""
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    mask = [predicate(jax.tree_util.keystr(path)) for path, _ in flat]
    return jax.tree_util.tree_unflatten(treedef, mask)


def leaf_count(tree) -> int:
    return sum(int(np.prod(x.shape)) for x in jax.tree_util.tree_leaves(tree))
