"""Failure handling + elastic reconfiguration for the training supervisor.

Semantics implemented (and exercised by tests with injected failures):

* **detect**: any exception escaping a step (device loss manifests as
  ``XlaRuntimeError``; we also accept an injectable ``FailureInjector`` for
  deterministic testing) marks the step failed.
* **restore**: roll back to the newest checkpoint; the data loader state is
  restored from the same checkpoint, so no batch is skipped or repeated.
* **elastic rescale**: the supervisor asks ``mesh_factory(devices)`` for a
  new (possibly smaller) mesh built from the devices that are still
  healthy, re-lowers the step function, and reshards the restored state
  onto the new topology (Checkpointer.restore(shardings=...)).  Batch
  size is kept constant by increasing per-device batch (the data-parallel
  dimension of the global batch is resharded, not shrunk).
* **give up** after ``max_failures`` consecutive failures.

On this single-process container "losing a host" is simulated by shrinking
the device list handed to ``mesh_factory``; the full restore→reshard→
resume path is real.
"""

from __future__ import annotations

import dataclasses
import random
from typing import Callable, List, Optional

__all__ = ["FailureInjector", "RetryPolicy"]


class FailureInjector:
    """Deterministic fault injection for tests and chaos drills.

    ``schedule`` maps step -> exception to raise (or "lose_device:<n>" to
    simulate shrinking the fleet by n devices before the raise).
    """

    def __init__(self, schedule: Optional[dict] = None):
        self.schedule = dict(schedule or {})
        self.lost_devices = 0

    def maybe_fail(self, step: int):
        action = self.schedule.pop(step, None)
        if action is None:
            return
        if isinstance(action, str) and action.startswith("lose_device"):
            n = int(action.split(":")[1]) if ":" in action else 1
            self.lost_devices += n
            raise RuntimeError(
                f"injected device loss at step {step} (-{n} devices)")
        if isinstance(action, BaseException):
            raise action
        raise RuntimeError(f"injected failure at step {step}")


@dataclasses.dataclass
class RetryPolicy:
    max_consecutive_failures: int = 3
    backoff_s: float = 0.0      # no real sleep in tests

    def __post_init__(self):
        self._consecutive = 0

    def record_success(self):
        self._consecutive = 0

    def record_failure(self) -> bool:
        """Returns True if training should keep retrying."""
        self._consecutive += 1
        return self._consecutive <= self.max_consecutive_failures
