"""Straggler detection for fleet-scale training.

At thousands of chips, tail-latency hosts (thermal throttling, failing
HBM, network congestion) silently stretch every synchronous step.  The
detector keeps an EWMA + EW-variance of step latencies and flags steps
beyond ``threshold`` sigmas; sustained flags trigger a mitigation callback
(in a real deployment: demote the host, re-slice the ring, or swap in a
hot spare — here: logged + surfaced to the supervisor, which can trigger
an elastic reconfiguration, see fault_tolerance.py).
"""

from __future__ import annotations

import dataclasses
import math
from typing import Callable, List, Optional

__all__ = ["StragglerDetector"]


@dataclasses.dataclass
class StragglerDetector:
    alpha: float = 0.1          # EWMA coefficient
    threshold_sigma: float = 4.0
    patience: int = 3           # consecutive flags before mitigation
    warmup_steps: int = 5       # ignore compile/cache warmup
    on_straggler: Optional[Callable[[int, float], None]] = None

    _mean: float = 0.0
    _var: float = 0.0
    _n: int = 0
    _consecutive: int = 0
    events: List[dict] = dataclasses.field(default_factory=list)

    def observe(self, step: int, latency_s: float) -> bool:
        """Record a step latency; returns True if flagged as straggling."""
        self._n += 1
        if self._n <= self.warmup_steps:
            # prime the statistics without flagging
            if self._n == 1:
                self._mean = latency_s
            else:
                self._mean += self.alpha * (latency_s - self._mean)
                self._var = max(self._var, (latency_s - self._mean) ** 2)
            return False

        sigma = math.sqrt(self._var) if self._var > 0 else self._mean * 0.1
        flagged = latency_s > self._mean + self.threshold_sigma * sigma

        if flagged:
            self._consecutive += 1
            self.events.append({"step": step, "latency_s": latency_s,
                                "mean_s": self._mean, "sigma_s": sigma})
            if (self._consecutive >= self.patience
                    and self.on_straggler is not None):
                self.on_straggler(step, latency_s)
                self._consecutive = 0
        else:
            self._consecutive = 0
            # only track healthy steps in the baseline
            delta = latency_s - self._mean
            self._mean += self.alpha * delta
            self._var = (1 - self.alpha) * (self._var +
                                            self.alpha * delta * delta)
        return flagged

    @property
    def mean_latency(self) -> float:
        return self._mean
