"""Jit-able step functions shared by the dry-run, the train loop and the
serve loop.

``make_train_step`` supports gradient accumulation (microbatching): the
global batch is split into ``accum`` microbatches scanned sequentially with
fp32 gradient accumulation in parameter-sharded buffers.  This is the
standard memory lever for the ≥100B assigned architectures — activation
temps scale with the microbatch, grads/optimizer stay FSDP-sharded — and
it is also where DP comm/compute overlap comes from (XLA overlaps the
reduce-scatter of microbatch i's grads with microbatch i+1's compute).
"""

from __future__ import annotations

import functools
from typing import Callable, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import transformer as tfm
from repro.optim import AdamWConfig, adamw_update

__all__ = ["make_train_step", "make_prefill_step",
           "make_chunk_prefill_step", "make_serve_step"]


def make_train_step(cfg: ModelConfig, ocfg: AdamWConfig,
                    accum: int = 1, grad_shardings=None) -> Callable:
    """(params, opt_state, batch) -> (params, opt_state, metrics).

    ``grad_shardings``: optional pytree of NamedShardings (usually the
    parameter shardings) pinning the fp32 accumulation carry — without it
    XLA is free to pick an arbitrary scan-carry layout and pay full
    replication reshards at the optimizer boundary (measured 4-10x temp
    blowups on the MoE giants).
    """
    def _pin(tree):
        if grad_shardings is None:
            return tree
        return jax.tree_util.tree_map(
            jax.lax.with_sharding_constraint, tree, grad_shardings)

    def loss_fn(params, batch):
        return tfm.loss_and_metrics(cfg, params, batch)

    def train_step(params, opt_state, batch):
        if accum == 1:
            (loss, metrics), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(params, batch)
        else:
            micro = jax.tree_util.tree_map(
                lambda x: x.reshape(accum, x.shape[0] // accum,
                                    *x.shape[1:]), batch)

            def body(carry, mb):
                gacc, lacc = carry
                (l, _), g = jax.value_and_grad(loss_fn, has_aux=True)(
                    params, mb)
                gacc = _pin(jax.tree_util.tree_map(
                    lambda a, b: a + b.astype(a.dtype), gacc, g))
                return (gacc, lacc + l), None

            gzero = _pin(jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params))
            (gsum, lsum), _ = jax.lax.scan(body, (gzero, jnp.float32(0)),
                                           micro)
            grads = _pin(jax.tree_util.tree_map(lambda g: g / accum, gsum))
            loss = lsum / accum
            metrics = {"loss": loss}
        new_p, new_o, om = adamw_update(ocfg, grads, opt_state, params)
        metrics = {**metrics, **om}
        metrics["loss"] = loss
        return new_p, new_o, metrics

    return train_step


def make_prefill_step(cfg: ModelConfig, capacity: int,
                      bucketed: bool = False,
                      paged: bool = False) -> Callable:
    """``bucketed=True`` adds a ``last_index`` argument: the continuous
    engine pads prompts to a static bucket, so the last *real* token's
    position must be passed explicitly (see :func:`tfm.prefill`) — it
    also pins sliding-window rings and Mamba states to the prompt's true
    end.  ``paged=True`` builds caches in pool geometry (page-aligned
    rings)."""
    if bucketed:
        def prefill_bucketed(params, batch, last_index):
            return tfm.prefill(cfg, params, batch, capacity=capacity,
                               last_index=last_index, paged=paged)
        return prefill_bucketed

    def prefill_step(params, batch):
        return tfm.prefill(cfg, params, batch, capacity=capacity)
    return prefill_step


def make_chunk_prefill_step(cfg: ModelConfig) -> Callable:
    """(params, pages, tokens, bt_row, slot, history, last_index) ->
    (last-real-token logits, pages).  One prefix-extension prefill chunk
    straight against the paged pool (see :func:`tfm.prefill_chunk`):
    the chunked engine's half of the token-budget mixed step.  The chunk
    length is static (``tokens.shape[1]``); history/slot/last_index are
    traced, so ONE compile serves every chunk of every request."""
    def chunk_step(params, pages, tokens, bt_row, slot, history,
                   last_index):
        return tfm.prefill_chunk(cfg, params, pages, tokens,
                                 bt_row=bt_row, slot=slot, history=history,
                                 last_index=last_index)
    return chunk_step


def make_serve_step(cfg: ModelConfig) -> Callable:
    """(params, caches, inp, pos[, block_tables]) -> (logits, caches).
    ``pos`` may be a scalar (static batch) or a ``(B,)`` vector (ragged
    continuous batch); ``block_tables`` switches ``caches`` to the paged
    pool (see :func:`tfm.decode_step`)."""
    def serve_step(params, caches, inp, pos, block_tables=None):
        return tfm.decode_step(cfg, params, caches, inp, pos,
                               block_tables=block_tables)
    return serve_step
