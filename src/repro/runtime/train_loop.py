"""Fault-tolerant, elastic, straggler-aware training supervisor.

The Trainer owns: mesh construction (from the *currently healthy* device
list), parameter/optimizer state placement, the jitted train step, the
data loader, async checkpointing, failure recovery and elastic rescaling.

Recovery path (exercised in tests with injected failures):

    step fails -> RetryPolicy -> rebuild mesh from mesh_factory(devices)
    -> re-lower step -> Checkpointer.restore(shardings=new placement)
    -> data loader state restored -> resume at last checkpointed step

The same path serves planned elasticity (scale the fleet up/down between
jobs): the mesh shape is a function of the device count, everything else
reshards automatically.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec

from repro.checkpoint import Checkpointer
from repro.configs.base import ModelConfig
from repro.data import DataConfig, HostDataLoader
from repro.distributed import sharding as shd
from repro.launch import specs as sp
from repro.models import param as pm
from repro.models import transformer as tfm
from repro.optim import AdamWConfig, init_adamw
from repro.runtime.fault_tolerance import FailureInjector, RetryPolicy
from repro.runtime.steps import make_train_step
from repro.runtime.straggler import StragglerDetector

__all__ = ["TrainLoopConfig", "Trainer", "default_mesh_factory"]


@dataclasses.dataclass(frozen=True)
class TrainLoopConfig:
    total_steps: int = 100
    checkpoint_every: int = 50
    log_every: int = 10
    accum: int = 1
    keep_checkpoints: int = 3
    seed: int = 0


def default_mesh_factory(devices: List) -> Optional[Mesh]:
    """Largest (data, model=1) mesh over the healthy devices; None for 1."""
    n = len(devices)
    if n <= 1:
        return None
    return Mesh(np.asarray(devices[:n]).reshape(n, 1), ("data", "model"))


class Trainer:
    def __init__(self, cfg: ModelConfig, ocfg: AdamWConfig,
                 loop: TrainLoopConfig, data_cfg: DataConfig,
                 checkpoint_dir: str,
                 mesh_factory: Callable = default_mesh_factory,
                 injector: Optional[FailureInjector] = None,
                 retry: Optional[RetryPolicy] = None):
        self.cfg, self.ocfg, self.loop = cfg, ocfg, loop
        self.data_cfg = data_cfg
        self.mesh_factory = mesh_factory
        self.injector = injector or FailureInjector()
        self.retry = retry or RetryPolicy()
        self.ckpt = Checkpointer(checkpoint_dir, keep=loop.keep_checkpoints)
        self.straggler = StragglerDetector()
        self.loader = HostDataLoader(data_cfg)
        self.metrics_log: List[Dict] = []
        self.rebuild_count = 0
        self._setup(restore=self.ckpt.latest_step() is not None)

    # ------------------------------------------------------------- setup
    def _devices(self) -> List:
        devs = jax.devices()
        return devs[: max(1, len(devs) - self.injector.lost_devices)]

    def _setup(self, restore: bool):
        self.mesh = self.mesh_factory(self._devices())
        cfg = self.cfg
        rng = jax.random.PRNGKey(self.loop.seed)

        if self.mesh is not None:
            rules: Dict = {}
            with shd.activate_mesh(self.mesh, rules):
                params_sds, params_sh = sp.param_specs(cfg, self.mesh,
                                                       rules, [])
                opt_sds, opt_sh = sp.opt_specs(self.ocfg, params_sds,
                                               params_sh, self.mesh,
                                               rules, [])
            self._params_sh, self._opt_sh = params_sh, opt_sh
            self._rules = rules
        else:
            self._params_sh = self._opt_sh = None
            self._rules = {}

        if restore:
            rec = self.ckpt.restore(shardings=None)
            state = rec["tree"]
            if self.mesh is not None:
                state = {
                    "params": jax.tree_util.tree_map(
                        jax.device_put, state["params"],
                        self._params_sh),
                    "opt": jax.tree_util.tree_map(
                        jax.device_put, state["opt"], self._opt_sh),
                }
            self.params, self.opt_state = state["params"], state["opt"]
            self.step = rec["step"]
            self.loader.load_state_dict(rec["extra"]["loader"])
        else:
            boxed = tfm.init_model(cfg, rng)
            self.params = pm.unbox(boxed)
            self.opt_state = init_adamw(self.ocfg, self.params)
            if self.mesh is not None:
                self.params = jax.tree_util.tree_map(
                    jax.device_put, self.params, self._params_sh)
                self.opt_state = jax.tree_util.tree_map(
                    jax.device_put, self.opt_state, self._opt_sh)
            self.step = 0

        step_fn = make_train_step(cfg, self.ocfg, accum=self.loop.accum,
                                  grad_shardings=self._params_sh)
        if self.mesh is not None:
            self._jit_step = jax.jit(
                step_fn,
                in_shardings=(self._params_sh, self._opt_sh, None),
                out_shardings=(self._params_sh, self._opt_sh, None),
                donate_argnums=(0, 1))
        else:
            self._jit_step = jax.jit(step_fn, donate_argnums=(0, 1))

    # ------------------------------------------------------------- steps
    def _save(self, blocking: bool = False):
        self.ckpt.save(self.step,
                       {"params": self.params, "opt": self.opt_state},
                       extra={"loader": self.loader.state_dict()},
                       blocking=blocking)

    def _one_step(self) -> Dict:
        batch = next(self.loader)
        batch = {k: jnp.asarray(v) for k, v in batch.items()}
        self.injector.maybe_fail(self.step)
        ctx = shd.activate_mesh(self.mesh, self._rules) if self.mesh \
            else _nullcontext()
        with ctx:
            self.params, self.opt_state, metrics = self._jit_step(
                self.params, self.opt_state, batch)
        metrics = {k: float(v) for k, v in metrics.items()
                   if jnp.ndim(v) == 0}
        return metrics

    def run(self) -> List[Dict]:
        while self.step < self.loop.total_steps:
            t0 = time.time()
            try:
                metrics = self._one_step()
            except Exception as e:  # noqa: BLE001 — supervisor boundary
                if not self.retry.record_failure():
                    raise RuntimeError(
                        f"giving up after repeated failures: {e}") from e
                self.rebuild_count += 1
                # elastic recovery: rebuild mesh from surviving devices,
                # restore newest checkpoint, resume
                self.ckpt.wait()
                restore = self.ckpt.latest_step() is not None
                self._setup(restore=restore)
                if not restore:
                    # nothing saved yet: restart from init
                    self.step = 0
                continue
            self.retry.record_success()
            dt = time.time() - t0
            self.straggler.observe(self.step, dt)
            metrics.update(step=self.step, wall_s=dt)
            self.metrics_log.append(metrics)
            self.step += 1
            if self.step % self.loop.checkpoint_every == 0:
                self._save()
        self._save(blocking=True)
        self.loader.close()
        return self.metrics_log


class _nullcontext:
    def __enter__(self):
        return None

    def __exit__(self, *a):
        return False
