"""Runtime: fault-tolerant train loop, serve loop, straggler detection."""
