"""Roofline derivation from compiled HLO (see EXPERIMENTS.md §Roofline)."""

from repro.roofline.analysis import (HW, RooflineTerms,
                                     parse_collective_bytes,
                                     roofline_from_compiled)

__all__ = ["HW", "RooflineTerms", "parse_collective_bytes",
           "roofline_from_compiled"]
