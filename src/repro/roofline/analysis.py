"""Roofline term derivation from compiled XLA artifacts.

Three terms per (arch × shape × mesh), per the assignment:

    compute    = HLO_FLOPs      / (chips × peak_FLOP/s)
    memory     = HLO_bytes      / (chips × HBM_bw)
    collective = collective_B   / (chips × link_bw)

``compiled.cost_analysis()`` supplies FLOPs / bytes-accessed of the
*partitioned per-device* module (verified empirically by
``tests/test_roofline.py::test_cost_analysis_is_per_device``), so the
global figures are per-device × chips — the formulas below keep everything
in per-device terms and divide once.

Collective bytes are NOT in cost_analysis: :func:`parse_collective_bytes`
scans the optimized HLO text and sums the result-shape bytes of every
all-gather / all-reduce / reduce-scatter / all-to-all / collective-permute
(async *-start ops counted once, *-done skipped).

Hardware constants: TPU v5e — 197 bf16 TFLOP/s, 819 GB/s HBM,
~50 GB/s/link ICI.
"""

from __future__ import annotations

import dataclasses
import re
from typing import Dict, Optional

__all__ = ["HW", "parse_collective_bytes", "roofline_from_compiled",
           "RooflineTerms"]

HW = {
    "peak_flops": 197e12,    # bf16 / chip
    "hbm_bw": 819e9,         # B/s / chip
    "ici_bw": 50e9,          # B/s / link
}

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1, "s4": 1, "u4": 1,
}

_COLL_RE = re.compile(
    r"=\s*(\(?[a-z0-9\[\],{}/#\s_]*\)?)\s*"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(-start)?\(", re.IGNORECASE)

_SHAPE_RE = re.compile(r"(pred|[a-z]+[0-9]+[a-z0-9]*)\[([0-9,]*)\]")


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_RE.findall(shape_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def parse_collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Sum result-shape bytes per collective kind over the HLO module."""
    out: Dict[str, int] = {}
    for line in hlo_text.splitlines():
        if "-done" in line:
            continue
        m = _COLL_RE.search(line)
        if not m:
            continue
        shape_str, kind = m.group(1), m.group(2).lower()
        b = _shape_bytes(shape_str)
        out[kind] = out.get(kind, 0) + b
    out["total"] = sum(v for k, v in out.items() if k != "total")
    return out


@dataclasses.dataclass
class RooflineTerms:
    flops_per_device: float
    hbm_bytes_per_device: float
    collective_bytes_per_device: float
    chips: int

    @property
    def compute_s(self) -> float:
        return self.flops_per_device / HW["peak_flops"]

    @property
    def memory_s(self) -> float:
        return self.hbm_bytes_per_device / HW["hbm_bw"]

    @property
    def collective_s(self) -> float:
        return self.collective_bytes_per_device / HW["ici_bw"]

    @property
    def dominant(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def bound_s(self) -> float:
        return max(self.compute_s, self.memory_s, self.collective_s)

    def as_dict(self) -> Dict:
        return {
            "flops_per_device": self.flops_per_device,
            "hbm_bytes_per_device": self.hbm_bytes_per_device,
            "collective_bytes_per_device": self.collective_bytes_per_device,
            "chips": self.chips,
            "compute_s": self.compute_s,
            "memory_s": self.memory_s,
            "collective_s": self.collective_s,
            "dominant": self.dominant,
        }


def roofline_from_compiled(compiled, chips: int,
                           hlo_text: Optional[str] = None) -> RooflineTerms:
    cost = compiled.cost_analysis()
    if isinstance(cost, list):  # older jax returns [dict]
        cost = cost[0]
    flops = float(cost.get("flops", 0.0))
    byt = float(cost.get("bytes accessed", 0.0))
    text = hlo_text if hlo_text is not None else compiled.as_text()
    coll = parse_collective_bytes(text)
    return RooflineTerms(
        flops_per_device=flops,
        hbm_bytes_per_device=byt,
        collective_bytes_per_device=float(coll.get("total", 0)),
        chips=chips,
    )
