"""minitron-8b — width/depth-pruned Nemotron-4.

32L, d_model=4096, 32 heads (GQA kv=8), d_ff=16384, vocab=256000.
[arXiv:2407.14679; hf].
"""

from repro.configs.base import LayerSpec, ModelConfig

CONFIG = ModelConfig(
    name="minitron-8b",
    family="dense",
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    head_dim=128,
    d_ff=16384,
    vocab_size=256000,
    pattern=(LayerSpec(kind="attn", attn_type="global", mlp="dense"),),
    num_groups=32,
    mlp_activation="swiglu",
    source="arXiv:2407.14679; hf",
)
