"""Architecture configs (one module per assigned architecture)."""

from repro.configs.base import LayerSpec, ModelConfig, SocketSettings
from repro.configs.registry import ARCHITECTURES, ASSIGNED, get_config

__all__ = ["ARCHITECTURES", "ASSIGNED", "LayerSpec", "ModelConfig",
           "SocketSettings", "get_config"]
