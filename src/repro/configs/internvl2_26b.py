"""internvl2-26b — VLM: InternViT-6B vision encoder + InternLM2-20B LLM.

Backbone (assignment scope): the InternLM2-20B language decoder —
48L, d_model=6144, 48 heads (GQA kv=8), d_ff=16384, vocab=92553 (padded to
92672 for the 16-way vocab shard).  [arXiv:2404.16821; hf].

The InternViT frontend is a STUB per the assignment: ``input_specs()``
feeds precomputed patch embeddings (``input_mode='embeddings'``), so the
vision tower is represented by its output interface only.
"""

from repro.configs.base import LayerSpec, ModelConfig

CONFIG = ModelConfig(
    name="internvl2-26b",
    family="vlm",
    d_model=6144,
    num_heads=48,
    num_kv_heads=8,
    head_dim=128,
    d_ff=16384,
    vocab_size=92553,
    pattern=(LayerSpec(kind="attn", attn_type="global", mlp="dense"),),
    num_groups=48,
    mlp_activation="swiglu",
    input_mode="embeddings",
    source="arXiv:2404.16821; hf",
)
