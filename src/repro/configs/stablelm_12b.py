"""stablelm-12b — plain dense GQA decoder.

40L, d_model=5120, 32 heads (GQA kv=8), d_ff=13824, vocab=100352.
[hf:stabilityai/stablelm-2-1_6b; hf].
"""

from repro.configs.base import LayerSpec, ModelConfig

CONFIG = ModelConfig(
    name="stablelm-12b",
    family="dense",
    d_model=5120,
    num_heads=32,
    num_kv_heads=8,
    head_dim=160,        # 5120 / 32
    d_ff=13824,
    vocab_size=100352,
    pattern=(LayerSpec(kind="attn", attn_type="global", mlp="dense"),),
    num_groups=40,
    mlp_activation="swiglu",
    qk_norm=True,
    source="hf:stabilityai/stablelm-2-1_6b; hf",
)
