"""jamba-v0.1-52b — hybrid Mamba+attention MoE decoder.

32L, d_model=4096, 32 heads (GQA kv=8), d_ff=14336, vocab=65536,
MoE 16 experts top-2.  [arXiv:2403.19887; hf].

Layout follows the Jamba block: period-8 pattern with attention at index 4
(1:7 attn:mamba ratio) and MoE replacing the dense MLP on every other
layer.  SOCKET applies only to the attention layers (which hold all of
Jamba's KV memory); Mamba layers decode from O(1) state — DESIGN.md §5.
"""

from repro.configs.base import LayerSpec, ModelConfig


def _layer(i: int) -> LayerSpec:
    kind = "attn" if i == 4 else "mamba"
    mlp = "moe" if i % 2 == 1 else "dense"
    return LayerSpec(kind=kind, attn_type="global", mlp=mlp)


CONFIG = ModelConfig(
    name="jamba-v0.1-52b",
    family="hybrid",
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab_size=65536,
    pattern=tuple(_layer(i) for i in range(8)),
    num_groups=4,
    num_experts=16,
    num_experts_per_tok=2,
    moe_parallelism="ep",
    ssm_state=16,
    ssm_expand=2,
    ssm_head_dim=64,
    mlp_activation="swiglu",
    source="arXiv:2403.19887; hf",
)
