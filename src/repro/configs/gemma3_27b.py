"""gemma3-27b — dense decoder with 5:1 local:global attention, 128k context.

62L, d_model=5376, 32 heads (GQA kv=16), d_ff=21504, vocab=262144.
[hf:google/gemma-3-1b-pt; unverified].

Layout: pattern = 5 sliding-window ("local") layers followed by 1 global
layer, repeated 10x (60 layers) + a 2-layer local remainder = 62 layers.
SOCKET applies to the *global* layers' KV caches; local layers are already
sparse by construction (window 1024) — see DESIGN.md §5.
"""

from repro.configs.base import LayerSpec, ModelConfig

_LOCAL = LayerSpec(kind="attn", attn_type="local", mlp="dense")
_GLOBAL = LayerSpec(kind="attn", attn_type="global", mlp="dense")

CONFIG = ModelConfig(
    name="gemma3-27b",
    family="dense",
    d_model=5376,
    num_heads=32,
    num_kv_heads=16,
    head_dim=128,
    d_ff=21504,
    vocab_size=262144,
    pattern=(_LOCAL, _LOCAL, _LOCAL, _LOCAL, _LOCAL, _GLOBAL),
    num_groups=10,
    remainder=(_LOCAL, _LOCAL),
    sliding_window=1024,
    rope_theta=1_000_000.0,
    qk_norm=True,
    mlp_activation="geglu",
    source="hf:google/gemma-3-1b-pt; unverified",
)
