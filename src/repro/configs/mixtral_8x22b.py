"""mixtral-8x22b — sparse MoE decoder (8 experts, top-2) with SWA.

56L, d_model=6144, 48 heads (GQA kv=8), expert d_ff=16384, vocab=32768.
[arXiv:2401.04088; hf].

MoE parallelism: 8 experts do not divide the 16-way model axis, so experts
are replicated and *intra-expert* tensor parallelism shards d_ff
(``moe_parallelism='tp'``) — see DESIGN.md §4.
"""

from repro.configs.base import LayerSpec, ModelConfig

CONFIG = ModelConfig(
    name="mixtral-8x22b",
    family="moe",
    d_model=6144,
    num_heads=48,
    num_kv_heads=8,
    head_dim=128,
    d_ff=16384,
    vocab_size=32768,
    pattern=(LayerSpec(kind="attn", attn_type="local", mlp="moe"),),
    num_groups=56,
    sliding_window=4096,
    num_experts=8,
    num_experts_per_tok=2,
    moe_parallelism="tp",
    mlp_activation="swiglu",
    source="arXiv:2401.04088; hf",
)
