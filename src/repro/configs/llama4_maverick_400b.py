"""llama4-maverick-400b-a17b — interleaved-MoE decoder, 128 experts top-1.

48L, d_model=5120, 40 heads (GQA kv=8), expert d_ff=8192, vocab=202048.
[hf:meta-llama/Llama-4-Scout-17B-16E; unverified].  "Early fusion"
multimodality is out of backbone scope (text path only, per the assignment
note); MoE layers are interleaved with dense layers (period-2 pattern, the
Maverick design), giving ~17B active of ~400B total parameters.

Sharding notes: 40 heads do not divide the 16-way model axis — attention
falls back to replicated heads (optionally zero-padded to 48, see §Perf);
128 experts shard 8-per-chip over "model" (``moe_parallelism='ep'``).
8-bit optimizer states are required to fit training on 256 chips
(EXPERIMENTS.md §Dry-run memory table).
"""

from repro.configs.base import LayerSpec, ModelConfig

CONFIG = ModelConfig(
    name="llama4-maverick-400b-a17b",
    family="moe",
    d_model=5120,
    num_heads=40,
    num_kv_heads=8,
    head_dim=128,
    d_ff=8192,
    vocab_size=202048,
    pattern=(
        LayerSpec(kind="attn", attn_type="global", mlp="dense"),
        LayerSpec(kind="attn", attn_type="global", mlp="moe"),
    ),
    num_groups=24,
    num_experts=128,
    num_experts_per_tok=1,
    moe_parallelism="ep",
    mlp_activation="swiglu",
    rope_theta=500_000.0,
    source="hf:meta-llama/Llama-4-Scout-17B-16E; unverified",
)
