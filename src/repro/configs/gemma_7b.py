"""gemma-7b — dense decoder with GeGLU and wide head_dim=256.

28L, d_model=3072, 16 heads (kv=16 => MHA on 7b; MQA is the 2b variant),
d_ff=24576, vocab=256000.  [arXiv:2403.08295; hf].
"""

from repro.configs.base import LayerSpec, ModelConfig

CONFIG = ModelConfig(
    name="gemma-7b",
    family="dense",
    d_model=3072,
    num_heads=16,
    num_kv_heads=16,
    head_dim=256,
    d_ff=24576,
    vocab_size=256000,
    pattern=(LayerSpec(kind="attn", attn_type="global", mlp="dense"),),
    num_groups=28,
    mlp_activation="geglu",
    source="arXiv:2403.08295; hf",
)
