"""Architecture registry: ``--arch <id>`` resolution for every launcher.

All ten assigned architectures plus the paper's own evaluation scale
(``llama31-8b``-shaped reference config used by the fidelity benchmarks).
"""

from __future__ import annotations

from typing import Dict

from repro.configs.base import LayerSpec, ModelConfig

from repro.configs.musicgen_medium import CONFIG as MUSICGEN_MEDIUM
from repro.configs.gemma3_27b import CONFIG as GEMMA3_27B
from repro.configs.stablelm_12b import CONFIG as STABLELM_12B
from repro.configs.minitron_8b import CONFIG as MINITRON_8B
from repro.configs.gemma_7b import CONFIG as GEMMA_7B
from repro.configs.mixtral_8x22b import CONFIG as MIXTRAL_8X22B
from repro.configs.llama4_maverick_400b import CONFIG as LLAMA4_MAVERICK
from repro.configs.jamba_v01_52b import CONFIG as JAMBA_V01_52B
from repro.configs.mamba2_780m import CONFIG as MAMBA2_780M
from repro.configs.internvl2_26b import CONFIG as INTERNVL2_26B

# The paper evaluates SOCKET on Llama-3.1-8B-Instruct; this reference config
# exists so the fidelity benchmarks exercise the exact (P, L, tau) operating
# point of paper Tables 1/13 on the right head geometry.
LLAMA31_8B = ModelConfig(
    name="llama31-8b",
    family="dense",
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab_size=128256,
    pattern=(LayerSpec(kind="attn", attn_type="global", mlp="dense"),),
    num_groups=32,
    rope_theta=500_000.0,
    mlp_activation="swiglu",
    source="arXiv:2407.21783 (paper's eval model)",
)

ARCHITECTURES: Dict[str, ModelConfig] = {
    "musicgen-medium": MUSICGEN_MEDIUM,
    "gemma3-27b": GEMMA3_27B,
    "stablelm-12b": STABLELM_12B,
    "minitron-8b": MINITRON_8B,
    "gemma-7b": GEMMA_7B,
    "mixtral-8x22b": MIXTRAL_8X22B,
    "llama4-maverick-400b-a17b": LLAMA4_MAVERICK,
    "jamba-v0.1-52b": JAMBA_V01_52B,
    "mamba2-780m": MAMBA2_780M,
    "internvl2-26b": INTERNVL2_26B,
    "llama31-8b": LLAMA31_8B,
}

ASSIGNED = tuple(k for k in ARCHITECTURES if k != "llama31-8b")


def get_config(name: str) -> ModelConfig:
    if name not in ARCHITECTURES:
        raise KeyError(
            f"unknown arch {name!r}; available: {sorted(ARCHITECTURES)}")
    return ARCHITECTURES[name]
