"""Model configuration schema.

A :class:`ModelConfig` fully describes one architecture: the layer layout
(heterogeneous patterns like gemma3's 5:1 local:global or jamba's 1:7
attn:mamba are first-class), attention/MoE/SSM hyper-parameters, the
modality frontend mode, and the SOCKET sparse-attention settings.

Layer layout = ``pattern`` (one scan *group*) repeated ``num_groups`` times
plus an optional ``remainder`` — the training/serving stacks `jax.lax.scan`
over groups with stacked parameters so the HLO stays small for 48-62 layer
models (critical for 1-core CPU compiles and for real-TPU compile times).
"""

from __future__ import annotations

import dataclasses
import math
from typing import Optional, Tuple

__all__ = ["LayerSpec", "LayerCachePlan", "ModelConfig", "SocketSettings",
           "QuestSettings", "ServingSettings"]

# K/V pool-page storage modes (mirrors repro.models.backends.kvquant,
# duplicated here so the config layer stays jax-free)
_KV_DTYPES = ("auto", "bf16", "int8", "fp8")


@dataclasses.dataclass(frozen=True)
class LayerSpec:
    """One layer inside a pattern."""

    kind: str = "attn"          # "attn" | "mamba"
    attn_type: str = "global"   # "global" | "local"  (local = sliding window)
    mlp: str = "dense"          # "dense" | "moe" | "none"


@dataclasses.dataclass(frozen=True)
class LayerCachePlan:
    """How the continuous engine caches ONE layer (derived per LayerSpec).

    ``kind``:

    * ``"paged"`` — global attention: the decode backend's cache leaves
      live in pool pages, the request block table is consumed linearly
      (block demand grows with context).
    * ``"ring"`` — sliding-window attention: K/V pages with the first
      ``ring_blocks`` block-table entries reused as a circular page list,
      so old pages are recycled in place and per-slot block demand is
      bounded by ``ceil(window / block_size)``.
    * ``"state"`` — Mamba/SSD: conv tail + recurrent state held as fixed
      per-decode-slot leaves; consumes no pool blocks at all.

    ``kv_dtype`` is the resolved K/V page storage mode for this layer
    (``"auto"`` = compute dtype, ``"bf16"``, ``"int8"``, ``"fp8"`` —
    see :mod:`repro.models.backends.kvquant`): paged and ring layers
    follow ``ServingSettings.kv_dtype``, state layers always resolve to
    ``"auto"`` (recurrent state is O(1) per slot and never quantized).

    The device-side handlers live in :mod:`repro.models.backends`
    (``layer_cache_handler``); the host-side block accounting in
    :class:`repro.serving.scheduler.Scheduler` derives from the same plan.
    """

    kind: str
    ring_blocks: int = 0
    kv_dtype: str = "auto"


@dataclasses.dataclass(frozen=True)
class SocketSettings:
    """SOCKET knobs carried inside the model config (deployment defaults
    follow paper Table 13: P=10, L=60, tau in [0.3, 0.5])."""

    num_planes: int = 10
    num_tables: int = 60
    tau: float = 0.4
    sparsity: float = 10.0
    sink_tokens: int = 128
    window_tokens: int = 128
    min_k: int = 16
    bits_storage: str = "packed"
    score_chunk: int = 0          # XLA-path scoring chunk (see core.socket)
    score_dtype: str = "float32"  # "bfloat16" halves long-context buffers
    # "kvhead": per-q-head scores summed over the GQA group (paper-faithful)
    # "pooled": score once with the group-mean query (G x less score
    #           compute/memory; §Perf fidelity numbers in EXPERIMENTS.md)
    selection: str = "kvhead"
    # Pallas kernel routing for the decode path (models.backends.socket):
    # score via kernels/socket_score and attend the selected subset via
    # kernels/flash_decode.  Off-TPU both run in interpret mode (bit-exact
    # semantics, interpreter speed) — the XLA fallback is the CPU default.
    use_score_kernel: bool = False
    use_flash_decode: bool = False
    # Route PagedView decode (the serving engine) through the fused
    # kernels/paged_attention pass: score + select + attend in one sweep
    # over the block table, zero XLA gathers on the K/V pool.  Contiguous
    # callers keep the socket_score + flash_decode pair.  Requires packed
    # bits and kvhead/pooled selection (fails fast otherwise).
    use_paged_kernel: bool = False


@dataclasses.dataclass(frozen=True)
class QuestSettings:
    """Quest baseline page geometry (models.backends.quest).

    ``page_size`` is the single source of truth for Quest's metadata
    granularity; it must divide ``ServingSettings.block_size`` so each
    paged-pool block carries whole min/max rows.
    """

    page_size: int = 16
    min_pages: int = 4
    # Route PagedView decode through the fused kernels/paged_attention
    # quest pass: page-bound scoring from the kmin/kmax leaves +
    # page-granular radix select + attend in one sweep over the block
    # table, zero XLA gathers on the K/V pool.
    use_paged_kernel: bool = False
    # Under quantized K/V pages (serving.kv_dtype int8/fp8), compute the
    # kmin/kmax page stats from the DEQUANTIZED quantized keys instead of
    # the original full-precision keys, so the per-page bounds cover the
    # keys the attend phase actually sees and Quest's upper-bound score
    # stays sound.  Required (validate() enforces it) whenever the quest
    # backend runs on quantized pages.
    stats_from_quantized: bool = True


@dataclasses.dataclass(frozen=True)
class ServingSettings:
    """Continuous-batching engine shape knobs (repro.serving).

    The paged pool holds ``num_blocks`` fixed-size pages shared by all
    layers; block 0 is reserved as the trash page that masked slots and
    padded block-table entries write into.  ``max_blocks_per_seq *
    block_size`` is the per-request context ceiling and the static length
    of the gathered ragged-decode view.

    ``prefill_chunk > 0`` (the default) selects the **token-budget mixed
    step**: each engine iteration runs at most one prefill chunk of this
    many tokens alongside the full ragged decode batch in ONE jitted
    call, so a long prompt stalls in-flight decodes by at most one chunk
    and prompts are bounded only by ``max_context`` (two compiles total:
    mixed + decode-only).  ``prefill_chunk = 0`` keeps the legacy
    alternating whole-prompt phases, where ``prefill_buckets`` are the
    static prompt paddings (each a multiple of ``block_size``, one
    prefill compile per bucket) and prompts beyond the largest bucket
    are rejected.
    """

    block_size: int = 16
    num_blocks: int = 512
    max_batch: int = 8
    max_blocks_per_seq: int = 64
    prefill_buckets: Tuple[int, ...] = (128, 256, 512, 1024)
    max_prefill_per_iter: int = 1
    prefill_chunk: int = 256
    # cross-request prefix cache (repro.serving.prefix_cache): radix-index
    # committed prompt pages and admit matching prompts with the shared
    # block-table prefix installed, chunk-prefilling only the tail.
    # Requires the mixed step (prefill_chunk > 0) and an all-paged cache
    # plan — configs with ring/Mamba layers fall back to no-share (the
    # engine simply builds no cache).  Generations are token-exact vs
    # cache-off (copy-on-write keeps shared pages immutable).
    prefix_cache: bool = False
    # K/V pool-page storage mode: "auto" (compute dtype — today's
    # behavior), "bf16" (plain cast, no scales), or "int8"/"fp8"
    # (symmetric per-row absmax quantization with float32 scale leaves
    # beside K/V; see repro.models.backends.kvquant).  Applies to paged
    # AND ring attention layers; Mamba state rows are never quantized.
    # Selection metadata (SOCKET bits/vnorms, Quest kmin/kmax) stays
    # full precision — only the attend rescan reads quantized rows.
    kv_dtype: str = "auto"

    def validate(self) -> None:
        assert self.num_blocks > 1, "need at least one non-trash block"
        for b in self.prefill_buckets:
            assert b % self.block_size == 0, (
                f"prefill bucket {b} not a multiple of block_size "
                f"{self.block_size}")
        assert self.prefill_chunk >= 0, (
            f"prefill_chunk must be >= 0, got {self.prefill_chunk}")
        if self.prefill_chunk:
            assert self.prefill_chunk % self.block_size == 0, (
                f"prefill_chunk {self.prefill_chunk} not a multiple of "
                f"block_size {self.block_size} (chunks write whole pages)")
        else:
            # legacy whole-prompt bucketing: every admissible request
            # (prompt+generated after preemption) must fit some bucket
            assert max(self.prefill_buckets) >= self.max_context, (
                f"largest prefill bucket {max(self.prefill_buckets)} < "
                f"max_context {self.max_context}: an admissible request "
                "(prompt+generated after preemption) could fail prefill "
                "bucketing mid-run")

    @property
    def max_context(self) -> int:
        return self.max_blocks_per_seq * self.block_size

    def replace(self, **kw) -> "ServingSettings":
        return dataclasses.replace(self, **kw)


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                     # dense | moe | hybrid | ssm | audio | vlm
    # --- dimensions -----------------------------------------------------
    d_model: int = 1024
    num_heads: int = 8
    num_kv_heads: int = 8
    head_dim: int = 128
    d_ff: int = 4096
    vocab_size: int = 32000
    # --- layout ---------------------------------------------------------
    pattern: Tuple[LayerSpec, ...] = (LayerSpec(),)
    num_groups: int = 1
    remainder: Tuple[LayerSpec, ...] = ()
    # --- attention ------------------------------------------------------
    rope_theta: float = 10000.0
    sliding_window: int = 1024      # for attn_type == "local"
    qk_norm: bool = False
    attn_logit_softcap: float = 0.0
    # q-chunked attention for the XLA train/prefill path: bounds the live
    # (chunk, S) logits buffer at long sequence lengths (0 = disabled).
    attn_q_chunk: int = 0
    # --- mlp ------------------------------------------------------------
    mlp_activation: str = "swiglu"  # "swiglu" | "geglu"
    # --- moe ------------------------------------------------------------
    num_experts: int = 0
    num_experts_per_tok: int = 0
    moe_parallelism: str = "ep"     # "ep" (shard experts) | "tp" (shard d_ff)
    moe_dispatch: str = "global"    # "global" | "batch" (see models.moe)
    capacity_factor: float = 1.25
    router_z_loss: float = 1e-3
    # --- mamba (SSD) ------------------------------------------------------
    ssm_state: int = 128
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_conv_width: int = 4
    ssm_chunk: int = 128
    # --- io / modality ----------------------------------------------------
    input_mode: str = "tokens"      # "tokens" | "embeddings" (audio/vlm stub)
    tie_embeddings: bool = False
    # --- numerics ---------------------------------------------------------
    param_dtype: str = "float32"
    compute_dtype: str = "float32"
    remat_policy: str = "none"      # "none" | "full" | "dots"
    logical_pad_heads: bool = False # zero-pad heads to mesh divisibility
    # --- sparse attention (the paper's technique) --------------------------
    # decode backend name, resolved via repro.models.backends.get_backend
    attention_backend: str = "socket"
    socket: SocketSettings = SocketSettings()
    quest: QuestSettings = QuestSettings()
    # Route sliding-window (ring) layer decode through the Pallas
    # kernels/paged_attention ring pass: stream the circular page list
    # straight from the pool with the window mask applied in-kernel
    # instead of gathering the ring K/V via XLA.
    use_ring_kernel: bool = False
    # --- continuous-batching serving engine (repro.serving) ----------------
    serving: ServingSettings = ServingSettings()
    # context-parallel SOCKET decode: shard_map local-topk + psum merge over
    # these mesh axes (set by the launcher per shape; () = pjit/XLA path)
    decode_cp_axes: Tuple[str, ...] = ()
    decode_cp_batch_axes: Tuple[str, ...] = ("pod", "data")
    # --- provenance ---------------------------------------------------------
    source: str = ""

    # ----------------------------------------------------------------- utils
    @property
    def num_layers(self) -> int:
        return len(self.pattern) * self.num_groups + len(self.remainder)

    @property
    def layer_specs(self) -> Tuple[LayerSpec, ...]:
        return self.pattern * self.num_groups + self.remainder

    @property
    def gqa_groups(self) -> int:
        return self.num_heads // self.num_kv_heads

    @property
    def d_inner(self) -> int:  # mamba inner width
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    # ----------------------------------------------------------- validation
    def validate(self) -> None:
        """Config-time fused-kernel eligibility: every combination the
        Pallas paged kernels would reject at trace time (deep inside a
        jitted serving step, with a Pallas traceback) is rejected here
        with the offending flag pair named.  Called from
        :meth:`cache_plan`, so any serving-engine construction fails
        before the first step is traced."""
        if self.socket.use_paged_kernel:
            if self.socket.bits_storage != "packed":
                raise ValueError(
                    "socket.use_paged_kernel=True is incompatible with "
                    "socket.bits_storage='int8': the fused paged kernel "
                    "streams packed uint32 hash words — set "
                    "bits_storage='packed' or disable use_paged_kernel")
            if self.socket.selection not in ("kvhead", "pooled"):
                raise ValueError(
                    f"socket.use_paged_kernel=True is incompatible with "
                    f"socket.selection='{self.socket.selection}': the "
                    "fused paged kernel group-sums scores — use "
                    "selection='kvhead'/'pooled' or disable "
                    "use_paged_kernel")
            if self.serving.block_size % 8:
                raise ValueError(
                    f"socket.use_paged_kernel=True needs "
                    f"serving.block_size % 8 == 0 (f32 sublane tiling), "
                    f"got block_size={self.serving.block_size}")
        if self.quest.use_paged_kernel:
            if self.serving.block_size % 8:
                raise ValueError(
                    f"quest.use_paged_kernel=True needs "
                    f"serving.block_size % 8 == 0 (f32 sublane tiling), "
                    f"got block_size={self.serving.block_size}")
            if self.serving.block_size % self.quest.page_size:
                raise ValueError(
                    f"quest.use_paged_kernel=True needs quest.page_size "
                    f"({self.quest.page_size}) to divide "
                    f"serving.block_size ({self.serving.block_size}) so "
                    "each pool block carries whole min/max pages")
        if self.use_ring_kernel and self.serving.block_size % 8:
            raise ValueError(
                f"use_ring_kernel=True needs serving.block_size % 8 == 0 "
                f"(f32 sublane tiling), got "
                f"block_size={self.serving.block_size}")
        # --- quantized K/V page matrix (serving.kv_dtype) ----------------
        kvd = self.serving.kv_dtype
        if kvd not in _KV_DTYPES:
            raise ValueError(
                f"serving.kv_dtype={kvd!r} is not a known K/V page storage "
                f"mode — expected one of {_KV_DTYPES}")
        if kvd == "fp8":
            # fp8 rows are only consumed in-register by the fused Pallas
            # attend phases; the XLA fallback's gathered-subset math on
            # float8 is not a supported path.  Demand the fused consumer
            # for every layer kind this config actually has.
            if self.uses_attention and any(
                    s.kind == "attn" and s.attn_type == "global"
                    for s in self.layer_specs):
                if self.attention_backend in ("socket", "hard_lsh") \
                        and not self.socket.use_paged_kernel:
                    raise ValueError(
                        f"serving.kv_dtype='fp8' with attention_backend="
                        f"'{self.attention_backend}' requires "
                        "socket.use_paged_kernel=True: fp8 rows are only "
                        "dequantized in-register by the fused paged kernel "
                        "— enable use_paged_kernel or use kv_dtype='int8'")
                if self.attention_backend == "quest" \
                        and not self.quest.use_paged_kernel:
                    raise ValueError(
                        "serving.kv_dtype='fp8' with attention_backend="
                        "'quest' requires quest.use_paged_kernel=True: fp8 "
                        "rows are only dequantized in-register by the fused "
                        "paged kernel — enable use_paged_kernel or use "
                        "kv_dtype='int8'")
                if self.attention_backend == "dense":
                    raise ValueError(
                        "serving.kv_dtype='fp8' is incompatible with "
                        "attention_backend='dense': dense decode has no "
                        "fused paged path to dequantize fp8 in-register — "
                        "use kv_dtype='int8' or 'bf16'")
            if any(s.kind == "attn" and s.attn_type == "local"
                   for s in self.layer_specs) and not self.use_ring_kernel:
                raise ValueError(
                    "serving.kv_dtype='fp8' with sliding-window (local) "
                    "layers requires use_ring_kernel=True: fp8 ring pages "
                    "are only dequantized in-register by the fused ring "
                    "kernel — enable use_ring_kernel or use kv_dtype="
                    "'int8'")
        if kvd in ("int8", "fp8") and self.attention_backend == "quest" \
                and not self.quest.stats_from_quantized:
            raise ValueError(
                f"serving.kv_dtype='{kvd}' with attention_backend='quest' "
                "requires quest.stats_from_quantized=True: page kmin/kmax "
                "bounds must be computed from the dequantized quantized "
                "keys the attend phase reads, or Quest's upper bound is "
                "unsound — set stats_from_quantized=True or kv_dtype="
                "'auto'/'bf16'")

    # ------------------------------------------------------ cache planning
    def ring_geometry(self) -> Tuple[int, int]:
        """(blocks, rows) of the paged sliding-window ring: the circular
        page list covers the window (``ceil(window / block_size)`` pool
        blocks, clamped to the per-request block table)."""
        sv = self.serving
        blocks = min(-(-self.sliding_window // sv.block_size),
                     sv.max_blocks_per_seq)
        return blocks, blocks * sv.block_size

    def plan_for(self, spec: LayerSpec) -> LayerCachePlan:
        """Resolve one layer's cache plan (see :class:`LayerCachePlan`)."""
        if spec.kind != "attn":
            return LayerCachePlan(kind="state")   # state rows: never quantized
        if spec.attn_type == "local":
            return LayerCachePlan(kind="ring",
                                  ring_blocks=self.ring_geometry()[0],
                                  kv_dtype=self.serving.kv_dtype)
        return LayerCachePlan(kind="paged", kv_dtype=self.serving.kv_dtype)

    def cache_plan(self) -> Tuple[LayerCachePlan, ...]:
        """Per-layer heterogeneous cache plan (one entry per
        ``layer_specs``) for the paged continuous-batching engine."""
        self.validate()
        return tuple(self.plan_for(s) for s in self.layer_specs)

    @property
    def uses_attention(self) -> bool:
        return any(s.kind == "attn" for s in self.layer_specs)

    @property
    def uses_mamba(self) -> bool:
        return any(s.kind == "mamba" for s in self.layer_specs)

    @property
    def uses_moe(self) -> bool:
        return any(s.mlp == "moe" for s in self.layer_specs)

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    def padded_vocab(self, multiple: int = 128) -> int:
        return ((self.vocab_size + multiple - 1) // multiple) * multiple

    # ------------------------------------------------------------- counting
    def param_count(self) -> int:
        """Exact parameter count of this config (embeddings included)."""
        d, h, kv, hd, ff = (self.d_model, self.num_heads, self.num_kv_heads,
                            self.head_dim, self.d_ff)
        n = 0
        n += self.padded_vocab() * d                       # embed
        if not self.tie_embeddings:
            n += d * self.padded_vocab()                   # lm head
        for spec in self.layer_specs:
            n += d                                          # pre norm
            if spec.kind == "attn":
                n += d * (h + 2 * kv) * hd + h * hd * d
                if self.qk_norm:
                    n += 2 * hd
            else:
                di, st, nh = self.d_inner, self.ssm_state, self.ssm_heads
                conv_dim = di + 2 * st
                n += d * (2 * di + 2 * st + nh)            # in_proj
                n += conv_dim * self.ssm_conv_width + conv_dim
                n += nh * 2 + nh                           # A_log, dt_bias, D
                n += di                                    # gated norm
                n += di * d                                # out_proj
            if spec.mlp == "dense":
                n += d + 3 * d * ff
            elif spec.mlp == "moe":
                n += d + d * self.num_experts              # norm + router
                n += self.num_experts * 3 * d * ff
        n += d                                             # final norm
        return n

    def active_param_count(self) -> int:
        """Params touched per token (MoE: only routed experts)."""
        if not self.uses_moe:
            return self.param_count()
        full_moe = sum(1 for s in self.layer_specs if s.mlp == "moe")
        per_expert = 3 * self.d_model * self.d_ff
        inactive = full_moe * (self.num_experts -
                               self.num_experts_per_tok) * per_expert
        return self.param_count() - inactive

    # ------------------------------------------------------------- reduction
    def smoke(self) -> "ModelConfig":
        """A drastically reduced config of the same family for CPU tests:
        same pattern structure, tiny widths, few groups, tiny vocab."""
        return self.replace(
            d_model=64,
            num_heads=4,
            num_kv_heads=max(1, min(self.num_kv_heads, 2)),
            head_dim=16,
            d_ff=128,
            vocab_size=256,
            num_groups=min(self.num_groups, 2),
            remainder=self.remainder[: min(len(self.remainder), 1)],
            num_experts=min(self.num_experts, 4) if self.num_experts else 0,
            num_experts_per_tok=min(self.num_experts_per_tok, 2)
            if self.num_experts_per_tok else 0,
            ssm_state=16,
            ssm_head_dim=16,
            ssm_chunk=16,
            sliding_window=32,
            socket=dataclasses.replace(
                self.socket, num_planes=6, num_tables=12, sink_tokens=4,
                window_tokens=4, min_k=8, sparsity=4.0),
            quest=dataclasses.replace(self.quest, page_size=8),
            serving=dataclasses.replace(
                self.serving, block_size=8, num_blocks=48, max_batch=4,
                max_blocks_per_seq=8, prefill_buckets=(24, 32, 48, 64),
                # prefill_chunk == smoke ssm_chunk: chunk boundaries land
                # on the SSD grid, so chunked prefill carries Mamba state
                # across chunks bit-exactly vs the whole-bucket path
                prefill_chunk=16),
        )
