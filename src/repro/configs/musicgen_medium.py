"""musicgen-medium — decoder-only LM over EnCodec audio tokens.

48L, d_model=1536, 24 heads (GQA kv=24 => MHA), d_ff=6144, vocab=2048.
[arXiv:2306.05284; hf].  The EnCodec/conditioning frontend is a stub: the
model consumes precomputed frame embeddings (``input_mode='embeddings'``);
the LM head predicts the 2048-entry codebook.

Sharding note (DESIGN.md §7.3): 24 heads do not divide the 16-way model
axis — attention weights fall back to replication over "model" (MLP keeps
tensor parallelism; 6144 % 16 == 0).  ``logical_pad_heads=True`` pads to 32
heads for full TP (exact, zero-initialised pad heads) and is evaluated in
EXPERIMENTS.md §Perf.
"""

from repro.configs.base import LayerSpec, ModelConfig

CONFIG = ModelConfig(
    name="musicgen-medium",
    family="audio",
    d_model=1536,
    num_heads=24,
    num_kv_heads=24,
    head_dim=64,          # 1536 / 24
    d_ff=6144,
    vocab_size=2048,
    pattern=(LayerSpec(kind="attn", attn_type="global", mlp="dense"),),
    num_groups=48,
    mlp_activation="geglu",
    input_mode="embeddings",
    source="arXiv:2306.05284; hf",
)
