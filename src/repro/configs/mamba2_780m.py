"""mamba2-780m — attention-free SSD (state-space duality) decoder.

48L, d_model=1536, d_ff=0 (no separate MLP; the Mamba block is the whole
layer), vocab=50280 (padded to 50304 for the 16-way vocab shard),
ssm_state=128.  [arXiv:2405.21060; unverified].

SOCKET **does not apply**: there are no keys and no KV cache to sparsify
(DESIGN.md §Arch-applicability).  ``long_500k`` decode runs natively —
SSM decode is O(1) in context length, which is the arch's selling point.
"""

from repro.configs.base import LayerSpec, ModelConfig

CONFIG = ModelConfig(
    name="mamba2-780m",
    family="ssm",
    d_model=1536,
    num_heads=0,
    num_kv_heads=0,
    head_dim=0,
    d_ff=0,
    vocab_size=50280,
    pattern=(LayerSpec(kind="mamba", mlp="none"),),
    num_groups=48,
    ssm_state=128,
    ssm_expand=2,
    ssm_head_dim=64,
    attention_backend="dense",   # no attention layers; backend unused
    source="arXiv:2405.21060; unverified",
)
