"""Training launcher.

    PYTHONPATH=src python -m repro.launch.train --arch minitron-8b \
        --smoke --steps 200 --batch 8 --seq 512 --ckpt /tmp/ckpt

``--smoke`` shrinks the architecture to its reduced config (same family /
pattern) so a full train run fits on CPU; without it the full config is
used (real accelerator fleets).  The loop is the fault-tolerant Trainer
(checkpoint/restore, elastic mesh rebuild, straggler detection).
"""

from __future__ import annotations

import argparse
import json

import jax

from repro.configs import get_config
from repro.data import DataConfig
from repro.optim import AdamWConfig
from repro.optim.schedule import ScheduleConfig
from repro.runtime.train_loop import Trainer, TrainLoopConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=512)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--accum", type=int, default=1)
    ap.add_argument("--ckpt", default="/tmp/repro_ckpt")
    ap.add_argument("--checkpoint-every", type=int, default=50)
    ap.add_argument("--state-bits", type=int, default=32, choices=[8, 32])
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = cfg.smoke()
    ocfg = AdamWConfig(
        state_bits=args.state_bits,
        schedule=ScheduleConfig(peak_lr=args.lr, warmup_steps=20,
                                decay_steps=args.steps))
    loop = TrainLoopConfig(total_steps=args.steps,
                           checkpoint_every=args.checkpoint_every,
                           accum=args.accum)
    data = DataConfig(seq_len=args.seq, global_batch=args.batch,
                      vocab_size=cfg.vocab_size)

    trainer = Trainer(cfg, ocfg, loop, data, args.ckpt)
    log = trainer.run()
    first = [m["loss"] for m in log[:10]]
    last = [m["loss"] for m in log[-10:]]
    print(json.dumps({
        "arch": cfg.name, "steps": len(log),
        "loss_first10": sum(first) / max(len(first), 1),
        "loss_last10": sum(last) / max(len(last), 1),
        "mean_step_s": trainer.straggler.mean_latency,
        "straggler_events": len(trainer.straggler.events),
    }, indent=2))


if __name__ == "__main__":
    main()
