"""Serving launcher.

Two engines:

* ``--engine static`` (legacy): prefill one fixed-shape batch, decode in
  lockstep, report throughput.
* ``--engine continuous``: the paged-KV continuous-batching engine
  (repro.serving) fed Poisson-arriving requests of mixed prompt lengths;
  reports throughput, TTFT and p50/p99 per-token latency.

    PYTHONPATH=src python -m repro.launch.serve --arch stablelm-12b \
        --smoke --engine continuous --backend socket

    PYTHONPATH=src python -m repro.launch.serve --arch stablelm-12b \
        --smoke --batch 4 --prompt-len 256 --decode-steps 64 \
        --backend socket
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.models import param as pm
from repro.models import transformer as tfm
from repro.runtime.steps import make_prefill_step, make_serve_step
# strict JSON: NaN/Infinity serialized as null, never the non-strict
# tokens (an empty-series percentile is NaN; json.dumps would happily
# emit `NaN`, which no compliant parser accepts)
from repro.serving.obs.events import strict_dumps

# serving-surface backend names: the real DecodeBackend registry plus the
# *_fused pseudo-backends (backend + its cfg.*.use_paged_kernel gate — the
# fused Pallas paged-attention passes, PagedView/continuous-engine only)
SERVING_BACKENDS = ("socket", "socket_fused", "dense", "quest",
                    "quest_fused", "hard_lsh", "hard_lsh_fused")


def apply_backend_arg(cfg, backend: str):
    """Resolve a serving-surface backend name onto the config.  Shared by
    this CLI and ``benchmarks.bench_serving`` so the pseudo-backend
    mapping lives in exactly one place."""
    import dataclasses
    if backend in ("socket_fused", "hard_lsh_fused"):
        # hard_lsh shares SOCKET's cache layout and kernel gate
        return cfg.replace(
            attention_backend=backend[: -len("_fused")],
            socket=dataclasses.replace(cfg.socket, use_paged_kernel=True))
    if backend == "quest_fused":
        return cfg.replace(
            attention_backend="quest",
            quest=dataclasses.replace(cfg.quest, use_paged_kernel=True))
    return cfg.replace(attention_backend=backend)


# K/V pool page storage modes (ServingSettings.kv_dtype): "auto" stores
# pages at the compute dtype, int8/fp8 quantize on write with per-row
# absmax scales and dequantize in-kernel on the fused paths
KV_DTYPES = ("auto", "bf16", "int8", "fp8")


def apply_kv_dtype(cfg, kv_dtype):
    """Resolve a ``--kv-dtype`` value onto the config's serving plan.
    Shared by this CLI and ``benchmarks.bench_serving`` so the
    quantized-pool knob lives in exactly one place.  ``None`` keeps the
    config's own ``serving.kv_dtype``; the dtype matrix itself (fp8
    needs the fused kernels, quest needs quantized-round-trip stats,
    ...) is enforced by ``cfg.validate()``."""
    if kv_dtype is None:
        return cfg
    if kv_dtype not in KV_DTYPES:
        raise ValueError(f"kv_dtype={kv_dtype!r} not in {KV_DTYPES}")
    return cfg.replace(serving=cfg.serving.replace(kv_dtype=kv_dtype))


def run_serve(cfg, batch: int, prompt_len: int, decode_steps: int,
              seed: int = 0, prompt=None):
    """Prefill + greedy decode; returns (tokens, prefill_s, decode_s).

    ``prompt``: optional (batch, prompt_len) int32 token array — the
    parity tests feed the same prompts to both engines.
    """
    rng = jax.random.PRNGKey(seed)
    params = pm.unbox(tfm.init_model(cfg, rng))
    capacity = prompt_len + decode_steps
    if cfg.input_mode == "tokens":
        if prompt is None:
            prompt = jax.random.randint(rng, (batch, prompt_len), 0,
                                        cfg.vocab_size)
        batch_in = {"tokens": jnp.asarray(prompt, jnp.int32)}
    else:
        batch_in = {"embeds": jax.random.normal(
            rng, (batch, prompt_len, cfg.d_model),
            jnp.dtype(cfg.compute_dtype))}

    prefill = jax.jit(make_prefill_step(cfg, capacity))
    serve = jax.jit(make_serve_step(cfg))

    t0 = time.time()
    logits, caches = prefill(params, batch_in)
    logits.block_until_ready()
    prefill_s = time.time() - t0

    toks = [jnp.argmax(logits[:, -1], axis=-1)[:, None]]
    # warm up compile outside the timed loop
    _, caches_w = serve(params, caches, toks[-1] if cfg.input_mode ==
                        "tokens" else jax.random.normal(
                            rng, (batch, 1, cfg.d_model)),
                        jnp.int32(prompt_len))
    del caches_w

    t0 = time.time()
    for t in range(decode_steps):
        inp = toks[-1] if cfg.input_mode == "tokens" else \
            jax.random.normal(jax.random.fold_in(rng, t),
                              (batch, 1, cfg.d_model))
        logits, caches = serve(params, caches, inp,
                               jnp.int32(prompt_len + t))
        toks.append(jnp.argmax(logits[:, -1], axis=-1)[:, None])
    toks[-1].block_until_ready()
    decode_s = time.time() - t0
    return jnp.concatenate(toks, axis=1), prefill_s, decode_s


def make_poisson_requests(cfg, num_requests: int, rate_rps: float,
                          prompt_lens, max_new_tokens: int, seed: int = 0):
    """Poisson arrival process with prompt lengths drawn from
    ``prompt_lens`` (the multi-tenant mixed-length regime)."""
    from repro.serving import Request
    rng = np.random.default_rng(seed)
    t = 0.0
    reqs = []
    for _ in range(num_requests):
        t += float(rng.exponential(1.0 / rate_rps))
        plen = int(rng.choice(prompt_lens))
        prompt = rng.integers(0, cfg.vocab_size, size=plen,
                              dtype=np.int64).tolist()
        reqs.append(Request(prompt=prompt, max_new_tokens=max_new_tokens,
                            arrival=t))
    return reqs


def serving_ceiling(cfg) -> int:
    """Largest servable prompt+generated context: the block table alone
    under chunked prefill, additionally the largest prefill bucket in
    legacy whole-prompt mode."""
    sv = cfg.serving
    if sv.prefill_chunk:
        return sv.max_context
    return min(max(sv.prefill_buckets), sv.max_context)


def run_continuous(cfg, num_requests: int, rate_rps: float, prompt_lens,
                   max_new_tokens: int, seed: int = 0, realtime=True,
                   warmup=False, temperature: float = 0.0,
                   top_p: float = 1.0, arrivals=None, obs=None,
                   prompts=None):
    """Continuous-batching serve; returns (requests, ServeMetrics,
    engine) — the engine exposes the run's metrics registry
    (``engine.registry``) for snapshot / Prometheus exposition.

    ``warmup=True`` pre-compiles the shapes this workload needs (chunked
    mode: the mixed + decode steps; legacy: only the buckets the prompts
    hit) so the reported TTFT/latency reflect steady-state serving, not
    jit.  ``temperature > 0`` samples inside the jitted decode step
    (temperature + nucleus top-p, per-request seeded PRNG); the default
    is greedy, bit-exact vs the static engine.  ``arrivals``: optional
    explicit per-request arrival times overriding the Poisson draw
    (cycled over ``prompt_lens`` in order).  ``obs``: optional
    :class:`repro.serving.obs.Observability` bundle (event trace /
    selection probe / profiler) threaded into the engine.
    ``prompts``: optional explicit token lists (e.g. from
    :mod:`repro.serving.prefix_cache.workloads`) overriding the random
    draw — the prefix-cache workloads need real shared prefixes, which
    independent random prompts never have; ``prompt_lens`` is ignored.
    """
    from repro.serving.engine import ContinuousBatchingEngine
    engine = ContinuousBatchingEngine(cfg, rng=jax.random.PRNGKey(seed),
                                      temperature=temperature, top_p=top_p,
                                      sample_seed=seed, obs=obs)
    if prompts is not None:
        from repro.serving import Request
        assert len(prompts) == num_requests, (
            f"prompts ({len(prompts)}) must match num_requests "
            f"({num_requests})")
        if arrivals is None:
            rng = np.random.default_rng(seed)
            t, arrivals = 0.0, []
            for _ in range(num_requests):
                t += float(rng.exponential(1.0 / rate_rps))
                arrivals.append(t)
        reqs = [Request(prompt=list(p), max_new_tokens=max_new_tokens,
                        arrival=t) for p, t in zip(prompts, arrivals)]
    elif arrivals is None:
        reqs = make_poisson_requests(cfg, num_requests, rate_rps,
                                     prompt_lens, max_new_tokens, seed=seed)
    else:
        from repro.serving import Request
        assert len(arrivals) == num_requests, (
            f"arrivals ({len(arrivals)}) must match num_requests "
            f"({num_requests})")
        rng = np.random.default_rng(seed)
        reqs = [Request(prompt=rng.integers(
                    0, cfg.vocab_size,
                    size=prompt_lens[i % len(prompt_lens)]).tolist(),
                        max_new_tokens=max_new_tokens, arrival=t)
                for i, t in enumerate(arrivals)]
    if warmup:
        engine.warmup(reqs)
    metrics = engine.run(reqs, realtime=realtime)
    return reqs, metrics, engine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--engine", default="static",
                    choices=["static", "continuous"])
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=256)
    ap.add_argument("--decode-steps", type=int, default=64)
    ap.add_argument("--backend", default="socket",
                    choices=list(SERVING_BACKENDS),
                    help="decode backend; the *_fused names route the "
                         "continuous engine through the corresponding "
                         "fused Pallas paged-attention kernel")
    ap.add_argument("--kv-dtype", default=None, choices=list(KV_DTYPES),
                    help="K/V pool page storage: 'auto' (compute dtype), "
                         "'bf16', or quantized 'int8'/'fp8' pages with "
                         "per-row scales dequantized in-kernel (default: "
                         "the config's serving.kv_dtype)")
    ap.add_argument("--ring-kernel", action="store_true",
                    help="route sliding-window (local) layer decode "
                         "through the Pallas ring kernel (continuous "
                         "engine; no-op for all-global architectures)")
    # continuous-engine knobs
    ap.add_argument("--num-requests", type=int, default=8)
    ap.add_argument("--rate", type=float, default=20.0,
                    help="Poisson arrival rate (requests/s)")
    ap.add_argument("--max-new-tokens", type=int, default=None)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--temperature", type=float, default=0.0,
                    help="sampling temperature; 0 = greedy (default)")
    ap.add_argument("--top-p", type=float, default=1.0,
                    help="nucleus sampling mass (with --temperature > 0)")
    ap.add_argument("--prefill-chunk", type=int, default=None,
                    help="chunked-prefill token budget per engine "
                         "iteration (continuous engine; 0 = legacy "
                         "whole-prompt bucketed prefill; default: the "
                         "config's serving.prefill_chunk)")
    ap.add_argument("--prefix-cache", action="store_true",
                    help="enable the radix-tree prefix cache (continuous "
                         "engine, chunked prefill, all-paged plans; "
                         "hybrid/ring plans fall back to no sharing)")
    ap.add_argument("--workload", default="mixed",
                    choices=["mixed", "chatbot", "rag"],
                    help="request generator: 'mixed' = independent "
                         "random prompts of mixed lengths (default); "
                         "'chatbot' = multi-turn sessions whose prompts "
                         "share growing histories; 'rag' = shared "
                         "template + unique suffix")
    ap.add_argument("--overlap", type=float, default=0.6,
                    help="shared-template fraction of each prompt "
                         "(--workload rag)")
    ap.add_argument("--sessions", type=int, default=2,
                    help="number of concurrent chat sessions "
                         "(--workload chatbot)")
    # observability (continuous engine)
    ap.add_argument("--trace", default=None, metavar="FILE",
                    help="stream a schema-validated JSONL event trace "
                         "of the run to FILE")
    ap.add_argument("--perfetto", default=None, metavar="FILE",
                    help="also export the trace as Chrome trace-event "
                         "JSON (open at https://ui.perfetto.dev); "
                         "requires --trace")
    ap.add_argument("--metrics-json", default=None, metavar="FILE",
                    help="write the run's metrics-registry snapshot as "
                         "strict JSON")
    ap.add_argument("--metrics-prom", default=None, metavar="FILE",
                    help="write the run's metrics registry in "
                         "Prometheus text exposition format")
    ap.add_argument("--probe-every", type=int, default=0,
                    help="sample the SOCKET selection-quality probe "
                         "every N engine iterations (0 = off; socket "
                         "backend, kvhead/pooled selection)")
    ap.add_argument("--profile-dir", default=None,
                    help="capture a jax.profiler trace of the engine "
                         "loop into this directory")
    ap.add_argument("--profile-steps", type=int, default=20,
                    help="profiled window length in engine iterations "
                         "(with --profile-dir)")
    args = ap.parse_args()

    if args.backend.endswith("_fused") and args.engine != "continuous":
        ap.error(f"--backend {args.backend} requires --engine continuous: "
                 "the fused kernels serve the paged decode path only "
                 "(the static engine would silently run the unfused "
                 "backend)")
    if args.ring_kernel and args.engine != "continuous":
        ap.error("--ring-kernel requires --engine continuous: the ring "
                 "kernel streams the paged pool's circular page lists")
    if args.temperature > 0 and args.engine != "continuous":
        ap.error("--temperature requires --engine continuous: sampling "
                 "lives in the continuous engine's jitted decode step "
                 "(the static engine would silently decode greedily)")
    if not 0.0 < args.top_p <= 1.0:
        ap.error(f"--top-p must be in (0, 1], got {args.top_p}")

    if args.prefill_chunk is not None and args.engine != "continuous":
        ap.error("--prefill-chunk requires --engine continuous: chunked "
                 "prefill is the continuous engine's execution model")
    if args.prefix_cache and args.engine != "continuous":
        ap.error("--prefix-cache requires --engine continuous: the "
                 "prefix cache shares pages of the continuous engine's "
                 "paged pool")
    if args.workload != "mixed" and args.engine != "continuous":
        ap.error("--workload chatbot/rag requires --engine continuous")
    if not 0.0 <= args.overlap < 1.0:
        ap.error(f"--overlap must be in [0, 1), got {args.overlap}")
    obs_flags = (args.trace, args.perfetto, args.metrics_json,
                 args.metrics_prom, args.profile_dir)
    if (any(f is not None for f in obs_flags) or args.probe_every) \
            and args.engine != "continuous":
        ap.error("observability flags (--trace/--perfetto/--metrics-*/"
                 "--probe-every/--profile-dir) require --engine "
                 "continuous")
    if args.perfetto and not args.trace:
        ap.error("--perfetto needs --trace (it exports the event trace)")

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = cfg.smoke()
    cfg = apply_backend_arg(cfg, args.backend)
    cfg = apply_kv_dtype(cfg, args.kv_dtype)
    if args.ring_kernel:
        cfg = cfg.replace(use_ring_kernel=True)
    if args.prefill_chunk is not None:
        cfg = cfg.replace(serving=cfg.serving.replace(
            prefill_chunk=args.prefill_chunk))
    if args.prefix_cache:
        cfg = cfg.replace(serving=cfg.serving.replace(prefix_cache=True))

    if args.engine == "continuous":
        sv = cfg.serving
        # mixed prompt lengths, bounded so prompt+generated fits the
        # serving ceiling (block table only when chunked; additionally
        # the largest prefill bucket in legacy whole-prompt mode)
        max_new = args.max_new_tokens or (8 if args.smoke else 64)
        ceiling = serving_ceiling(cfg)
        top = ceiling - max_new
        if top < 1:
            ap.error(f"--max-new-tokens {max_new} leaves no prompt room "
                     f"under the serving context ceiling "
                     f"({ceiling} tokens)")
        lens = sorted({max(1, top // 4), max(1, top // 2),
                       max(1, (3 * top) // 4), top})
        prompts = None
        if args.workload == "chatbot":
            from repro.serving.prefix_cache.workloads import chatbot_prompts
            prompts = chatbot_prompts(args.num_requests,
                                      sessions=args.sessions,
                                      max_prompt_len=top,
                                      vocab_size=cfg.vocab_size,
                                      seed=args.seed)
        elif args.workload == "rag":
            from repro.serving.prefix_cache.workloads import rag_prompts
            prompts = rag_prompts(args.num_requests, prompt_len=top,
                                  overlap=args.overlap,
                                  vocab_size=cfg.vocab_size,
                                  seed=args.seed)
        obs = None
        if any(f is not None for f in obs_flags) or args.probe_every:
            from repro.serving.obs import Observability
            obs = Observability(args.trace, probe_every=args.probe_every,
                                profile_dir=args.profile_dir,
                                profile_steps=args.profile_steps)
        reqs, m, engine = run_continuous(cfg, args.num_requests,
                                         args.rate, lens,
                                         max_new, seed=args.seed,
                                         temperature=args.temperature,
                                         top_p=args.top_p, obs=obs,
                                         prompts=prompts)
        report = {
            "arch": cfg.name, "backend": args.backend,
            "engine": "continuous",
            "kv_dtype": sv.kv_dtype,
            "prefill_chunk": sv.prefill_chunk,
            "workload": args.workload,
            "prompt_lens": lens if prompts is None else sorted(
                {len(p) for p in prompts}),
            "max_new_tokens": max_new,
            "temperature": args.temperature,
            "top_p": args.top_p,
            "finished": sum(r.state == "finished" for r in reqs),
            **m.to_json(),
        }
        if args.prefix_cache:
            reg = engine.registry
            hits = reg.value("prefix_cache_hits_total")
            misses = reg.value("prefix_cache_misses_total")
            report["prefix_cache"] = {
                # engine.prefix_cache is None when the plan can't share
                # (hybrid/ring/legacy prefill) — the flag degrades to a
                # no-op and this block records that honestly
                "active": engine.prefix_cache is not None,
                "hits": hits, "misses": misses,
                "hit_rate": hits / (hits + misses) if hits + misses
                else None,
                "cached_tokens": reg.value(
                    "prefix_cache_cached_tokens_total"),
                "prompt_tokens": reg.value(
                    "prefix_cache_prompt_tokens_total"),
                "cow_copies": reg.value("prefix_cache_cow_total"),
                "evicted_blocks": reg.value(
                    "prefix_cache_evicted_total"),
            }
        if obs is not None:
            obs.close()
            if args.probe_every:
                report["probe"] = obs.probe_summary()
            if args.perfetto:
                from repro.serving.obs import write_chrome_trace
                write_chrome_trace(args.trace, args.perfetto)
            if args.metrics_json:
                with open(args.metrics_json, "w") as f:
                    f.write(strict_dumps(engine.registry.snapshot(),
                                         indent=2, sort_keys=True))
            if args.metrics_prom:
                with open(args.metrics_prom, "w") as f:
                    f.write(engine.registry.prometheus_text())
        print(strict_dumps(report, indent=2))
        return

    toks, prefill_s, decode_s = run_serve(cfg, args.batch, args.prompt_len,
                                          args.decode_steps,
                                          seed=args.seed)
    tput = args.batch * args.decode_steps / decode_s
    print(strict_dumps({
        "arch": cfg.name, "backend": args.backend, "engine": "static",
        "prefill_s": round(prefill_s, 3),
        "decode_s": round(decode_s, 3),
        "decode_tokens_per_s": round(tput, 1),
        "generated_shape": list(toks.shape),
    }, indent=2))


if __name__ == "__main__":
    main()
