"""Serving launcher: prefill a batch of prompts, decode with SOCKET sparse
attention, report throughput.

    PYTHONPATH=src python -m repro.launch.serve --arch stablelm-12b \
        --smoke --batch 4 --prompt-len 256 --decode-steps 64 \
        --backend socket
"""

from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.models import param as pm
from repro.models import transformer as tfm
from repro.runtime.steps import make_prefill_step, make_serve_step


def run_serve(cfg, batch: int, prompt_len: int, decode_steps: int,
              seed: int = 0):
    """Prefill + greedy decode; returns (tokens, prefill_s, decode_s)."""
    rng = jax.random.PRNGKey(seed)
    params = pm.unbox(tfm.init_model(cfg, rng))
    capacity = prompt_len + decode_steps
    if cfg.input_mode == "tokens":
        prompt = jax.random.randint(rng, (batch, prompt_len), 0,
                                    cfg.vocab_size)
        batch_in = {"tokens": prompt}
    else:
        batch_in = {"embeds": jax.random.normal(
            rng, (batch, prompt_len, cfg.d_model),
            jnp.dtype(cfg.compute_dtype))}

    prefill = jax.jit(make_prefill_step(cfg, capacity))
    serve = jax.jit(make_serve_step(cfg))

    t0 = time.time()
    logits, caches = prefill(params, batch_in)
    logits.block_until_ready()
    prefill_s = time.time() - t0

    toks = [jnp.argmax(logits[:, -1], axis=-1)[:, None]]
    # warm up compile outside the timed loop
    _, caches_w = serve(params, caches, toks[-1] if cfg.input_mode ==
                        "tokens" else jax.random.normal(
                            rng, (batch, 1, cfg.d_model)),
                        jnp.int32(prompt_len))
    del caches_w

    t0 = time.time()
    for t in range(decode_steps):
        inp = toks[-1] if cfg.input_mode == "tokens" else \
            jax.random.normal(jax.random.fold_in(rng, t),
                              (batch, 1, cfg.d_model))
        logits, caches = serve(params, caches, inp,
                               jnp.int32(prompt_len + t))
        toks.append(jnp.argmax(logits[:, -1], axis=-1)[:, None])
    toks[-1].block_until_ready()
    decode_s = time.time() - t0
    return jnp.concatenate(toks, axis=1), prefill_s, decode_s


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=256)
    ap.add_argument("--decode-steps", type=int, default=64)
    ap.add_argument("--backend", default="socket",
                    choices=["socket", "dense", "quest", "hard_lsh"])
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = cfg.smoke()
    cfg = cfg.replace(attention_backend=args.backend)

    toks, prefill_s, decode_s = run_serve(cfg, args.batch, args.prompt_len,
                                          args.decode_steps)
    tput = args.batch * args.decode_steps / decode_s
    print(json.dumps({
        "arch": cfg.name, "backend": args.backend,
        "prefill_s": round(prefill_s, 3),
        "decode_s": round(decode_s, 3),
        "decode_tokens_per_s": round(tput, 1),
        "generated_shape": list(toks.shape),
    }, indent=2))


if __name__ == "__main__":
    main()
