"""Input ShapeDtypeStruct specs and sharding assembly for every
(architecture × input-shape × mesh) dry-run cell.

Nothing here allocates device memory: parameters, optimizer states and
caches are built with ``jax.eval_shape`` over the real init functions, so
the dry-run lowers exactly the production pytrees.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec

from repro.configs.base import ModelConfig
from repro.distributed import sharding as shd
from repro.models import param as pm
from repro.models import transformer as tfm
from repro.optim import AdamWConfig, init_adamw

__all__ = ["ShapeSpec", "SHAPES", "dryrun_model_config", "arch_rules",
           "batch_specs", "param_specs", "opt_specs", "cache_specs",
           "scalar_sharding", "input_specs"]


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    kind: str            # "train" | "prefill" | "decode"
    seq_len: int
    global_batch: int
    long_context: bool = False


SHAPES: Dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", "train", 4096, 256),
    "prefill_32k": ShapeSpec("prefill_32k", "prefill", 32768, 32),
    "decode_32k": ShapeSpec("decode_32k", "decode", 32768, 128),
    "long_500k": ShapeSpec("long_500k", "decode", 524288, 1,
                           long_context=True),
}


def dryrun_model_config(cfg: ModelConfig, shape: ShapeSpec) -> ModelConfig:
    """Deployment numerics + memory policies for the production lowering."""
    sock = dataclasses.replace(cfg.socket, score_chunk=16384,
                               score_dtype="bfloat16")
    out = cfg.replace(
        param_dtype="bfloat16",
        compute_dtype="bfloat16",
        remat_policy="full" if shape.kind == "train" else "none",
        attn_q_chunk=1024 if shape.seq_len > 4096 else 0,
        socket=sock,
    )
    return out


def arch_rules(cfg: ModelConfig, shape: ShapeSpec, mesh: Mesh) -> Dict:
    """Per-(arch, shape) overrides of the logical sharding rules."""
    rules: Dict[str, Any] = {}
    model_size = mesh.shape.get("model", 1)
    kv_div = cfg.num_kv_heads and cfg.num_kv_heads % model_size == 0
    if shape.kind in ("decode", "prefill"):
        if shape.long_context:
            # context parallelism: cache sequence over the data axis (plus
            # model when KV heads cannot use it — e.g. kv=8 on 16-way TP)
            rules["cache_seq_cp"] = ("data", "model") if not kv_div \
                else ("pod", "data")
            rules["cache_heads"] = ("model",) if kv_div else None
            # batch=1: activations replicated over data
            rules["batch"] = None
            rules["cache_batch"] = None
        elif not kv_div and cfg.num_kv_heads:
            # kv heads unshardable: spread the cache over sequence instead
            rules["cache_seq"] = ("model",)
            rules["cache_heads"] = None
    # q8 optimizer-state flats
    rules["q8_flat"] = ("pod", "data", "model")
    rules["q8_scale"] = ("data", "model")
    return rules


def _named(mesh: Mesh, axes, shape, rules, log) -> NamedSharding:
    return shd.named_sharding(mesh, axes, shape, rules, log)


def scalar_sharding(mesh: Mesh) -> NamedSharding:
    return NamedSharding(mesh, PartitionSpec())


# --------------------------------------------------------------- parameters

def param_specs(cfg: ModelConfig, mesh: Mesh, rules: Dict,
                log: Optional[List[str]] = None):
    """(values_sds, shardings) for the model parameters via eval_shape."""
    boxed = jax.eval_shape(
        functools.partial(tfm.init_model, cfg), jax.random.PRNGKey(0))
    values = pm.unbox(boxed)
    axes = pm.axes_of(boxed)
    flat_v, treedef = jax.tree_util.tree_flatten(values)
    flat_a = jax.tree_util.tree_leaves(
        axes, is_leaf=lambda x: isinstance(x, tuple))
    shardings = [
        _named(mesh, a, v.shape, rules, log) for v, a in zip(flat_v, flat_a)]
    return values, jax.tree_util.tree_unflatten(treedef, shardings)


# ---------------------------------------------------------- optimizer state

def opt_specs(ocfg: AdamWConfig, params_sds, param_shardings, mesh: Mesh,
              rules: Dict, log: Optional[List[str]] = None):
    """(opt_sds, opt_shardings); moments inherit parameter shardings
    (ZeRO-over-FSDP), int8 states shard their flat axes."""
    opt_sds = jax.eval_shape(
        functools.partial(init_adamw, ocfg), params_sds)

    def is_q8(x):
        return isinstance(x, dict) and set(x) == {"q", "scale"}

    def _fit(spec: PartitionSpec, shape) -> NamedSharding:
        """Reuse a param spec on a congruent-rank tensor, dropping entries
        that no longer divide (e.g. the blocked scale's last dim)."""
        entries = tuple(spec) + (None,) * (len(shape) - len(tuple(spec)))
        out = []
        for dim, e in enumerate(entries):
            if e is None:
                out.append(None)
                continue
            axes = (e,) if isinstance(e, str) else tuple(e)
            size = int(np.prod([mesh.shape[a] for a in axes]))
            out.append(e if shape[dim] % size == 0 else None)
        return NamedSharding(mesh, PartitionSpec(*out))

    def moment_shardings(tree):
        flat_m, tdef = jax.tree_util.tree_flatten(tree, is_leaf=is_q8)
        flat_p = jax.tree_util.tree_leaves(param_shardings)
        out = []
        for m, psh in zip(flat_m, flat_p):
            if is_q8(m):
                # q keeps the parameter's sharding (same rank, last dim
                # padded); scale drops the last-dim entry
                pspec = tuple(psh.spec)
                scale_spec = PartitionSpec(
                    *(pspec[:len(m["scale"].shape) - 1] +
                      ((None,) if len(m["scale"].shape) else ())))
                out.append({
                    "q": _fit(psh.spec, m["q"].shape),
                    "scale": _fit(scale_spec, m["scale"].shape),
                })
            elif getattr(m, "shape", None) == ():
                out.append(scalar_sharding(mesh))
            else:
                out.append(psh)
        return jax.tree_util.tree_unflatten(tdef, out)

    opt_sh = {
        "step": scalar_sharding(mesh),
        "m": moment_shardings(opt_sds["m"]),
        "v": moment_shardings(opt_sds["v"]),
    }
    return opt_sds, opt_sh


# ------------------------------------------------------------------- batch

def batch_specs(cfg: ModelConfig, shape: ShapeSpec, mesh: Mesh, rules: Dict,
                log: Optional[List[str]] = None):
    """(batch_sds, batch_shardings) for train/prefill inputs."""
    b, s = shape.global_batch, shape.seq_len
    sds: Dict[str, jax.ShapeDtypeStruct] = {}
    axes: Dict[str, tuple] = {}
    if cfg.input_mode == "tokens":
        sds["tokens"] = jax.ShapeDtypeStruct((b, s), jnp.int32)
        axes["tokens"] = ("batch", "seq")
    else:
        sds["embeds"] = jax.ShapeDtypeStruct((b, s, cfg.d_model),
                                             jnp.bfloat16)
        axes["embeds"] = ("batch", "seq", "embed")
    if shape.kind == "train":
        sds["labels"] = jax.ShapeDtypeStruct((b, s), jnp.int32)
        axes["labels"] = ("batch", "seq")
    sh = {k: _named(mesh, axes[k], sds[k].shape, rules, log) for k in sds}
    return sds, sh


def decode_input_specs(cfg: ModelConfig, shape: ShapeSpec, mesh: Mesh,
                       rules: Dict, log=None):
    b = shape.global_batch
    if cfg.input_mode == "tokens":
        sds = jax.ShapeDtypeStruct((b, 1), jnp.int32)
        sh = _named(mesh, ("batch", None), sds.shape, rules, log)
    else:
        sds = jax.ShapeDtypeStruct((b, 1, cfg.d_model), jnp.bfloat16)
        sh = _named(mesh, ("batch", None, "embed"), sds.shape, rules, log)
    return sds, sh


# ------------------------------------------------------------------- cache

def cache_specs(cfg: ModelConfig, shape: ShapeSpec, mesh: Mesh, rules: Dict,
                log: Optional[List[str]] = None):
    """(cache_sds, cache_shardings) for the decode caches."""
    sds = jax.eval_shape(functools.partial(
        tfm.init_decode_caches, cfg, shape.global_batch, shape.seq_len,
        shape.long_context))
    axes = tfm.decode_cache_axes(cfg, shape.long_context)
    flat_s, treedef = jax.tree_util.tree_flatten(sds)
    flat_a = jax.tree_util.tree_leaves(
        axes, is_leaf=lambda x: isinstance(x, tuple))
    if len(flat_s) != len(flat_a):
        raise ValueError(
            f"cache sds/axes mismatch: {len(flat_s)} vs {len(flat_a)}")
    sh = [
        _named(mesh, a, v.shape, rules, log) for v, a in zip(flat_s, flat_a)]
    return sds, jax.tree_util.tree_unflatten(treedef, sh)


def input_specs(arch: str, shape_name: str = "train_4k"):
    """ShapeDtypeStruct stand-ins for every model input of one cell —
    weak-type-correct, shardable, no device allocation.

    For a training step: {"tokens"|"embeds": ..., "labels": ...};
    for prefill: the prompt batch; for decode: the full
    (params, caches, inp, pos) keyword set matching
    ``runtime.steps.make_serve_step``.

        lowered = jax.jit(train_step).lower(params, opt, **input_specs(a))
    """
    import jax as _jax
    from repro.configs import get_config

    shape = SHAPES[shape_name]
    # AbstractMesh: the production 16x16 topology without touching device
    # state (usable for divisibility-checked spec construction anywhere).
    # jax >= 0.5 takes (sizes, names); 0.4.x takes ((name, size), ...)
    try:
        mesh = _jax.sharding.AbstractMesh((16, 16), ("data", "model"))
    except TypeError:
        mesh = _jax.sharding.AbstractMesh((("data", 16), ("model", 16)))
    cfg = dryrun_model_config(get_config(arch), shape)
    rules = arch_rules(cfg, shape, mesh)
    if shape.kind in ("train", "prefill"):
        sds, _ = batch_specs(cfg, shape, mesh, rules)
        if shape.kind == "prefill":
            sds.pop("labels", None)
        return {"batch": sds}
    cache_sds, _ = cache_specs(cfg, shape, mesh, rules)
    inp_sds, _ = decode_input_specs(cfg, shape, mesh, rules)
    return {"caches": cache_sds, "inp": inp_sds,
            "pos": _jax.ShapeDtypeStruct((), jnp.int32)}
