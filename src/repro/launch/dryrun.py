import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (architecture × input shape)
cell on the production meshes, record memory / cost / collective analysis.

MUST be executed as a script or module (``python -m repro.launch.dryrun``)
so the XLA_FLAGS line above runs before any jax initialisation.

    python -m repro.launch.dryrun --arch gemma3-27b --shape decode_32k
    python -m repro.launch.dryrun --all --mesh both --out experiments/dryrun

Per cell this builds the *real* production pytrees via eval_shape (no
allocation), pjit-lowers the appropriate step function
(train_step / prefill_step / serve_step), compiles, and saves a JSON record
with memory_analysis, cost_analysis, per-kind collective bytes and the
three roofline terms (§Roofline).
"""

import argparse
import functools
import json
import time
import traceback
from typing import Dict

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec

from repro.configs import ASSIGNED, get_config
from repro.distributed import sharding as shd
from repro.launch import specs as sp
from repro.launch.mesh import make_production_mesh
from repro.models import param as pm
from repro.models import transformer as tfm
from repro.optim import AdamWConfig
from repro.roofline.analysis import parse_collective_bytes, \
    roofline_from_compiled
from repro.runtime.steps import make_train_step


def _metrics_shardings(mesh, tree_sds):
    return jax.tree_util.tree_map(
        lambda _: NamedSharding(mesh, PartitionSpec()), tree_sds)


def build_cell(arch: str, shape_name: str, multi_pod: bool,
               variant: str = "base"):
    """Lower + compile one cell; returns (compiled, record_dict).

    ``variant``: "base" (paper-faithful pjit lowering) or "opt" (the
    §Perf-optimized lowering: context-parallel decode, etc.).
    """
    shape = sp.SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = int(np.prod(list(mesh.shape.values())))
    base_cfg = get_config(arch)
    cfg = sp.dryrun_model_config(base_cfg, shape)
    if variant == "opt" and cfg.uses_attention and cfg.num_heads % \
            mesh.shape.get("model", 1) != 0:
        # §Perf: zero-pad q heads to the TP width (exact at init; avoids
        # replicated attention weights/grads — llama4 40->48, musicgen
        # 24->32)
        cfg = cfg.replace(logical_pad_heads=True)
    if variant == "opt" and cfg.uses_moe and shape.kind in (
            "train", "prefill"):
        # §Perf: shard_map all-to-all expert parallelism (exact; minimal
        # exchange traffic).  Falls back internally when E % model != 0
        # (mixtral's 8 experts -> per-row dispatch instead).
        dispatch = "alltoall" if cfg.num_experts % mesh.shape.get(
            "model", 1) == 0 else "batch"
        cfg = cfg.replace(moe_dispatch=dispatch)
    if variant == "opt" and shape.kind == "decode":
        import dataclasses as _dc
        model_size = mesh.shape.get("model", 1)
        kv_div = cfg.num_kv_heads and cfg.num_kv_heads % model_size == 0
        # §Perf iteration 2: pooled-query selection (G x less scoring)
        cfg = cfg.replace(socket=_dc.replace(cfg.socket,
                                             selection="pooled"))
        if shape.long_context:
            axes = ("data", "model") if not kv_div else ("pod", "data")
            cfg = cfg.replace(decode_cp_axes=axes,
                              decode_cp_batch_axes=())
        elif not kv_div and cfg.num_kv_heads:
            cfg = cfg.replace(decode_cp_axes=("model",))
    # the giants need 8-bit moments to fit (DESIGN.md §4)
    pcount = base_cfg.param_count()
    big = pcount > 60e9
    ocfg = AdamWConfig(state_bits=8 if big else 32)
    # gradient accumulation: bound per-microbatch activation temps
    accum = 8 if big else (4 if pcount > 15e9 else 2)
    rules = sp.arch_rules(cfg, shape, mesh)
    # sequence-parallel residual stream (Megatron-style): shards the saved
    # scan carries over "model" — measured 2.9x temp reduction on mixtral
    if shape.kind in ("train", "prefill"):
        rules["act_seq"] = ("model",)
    log: list = []

    record: Dict = {
        "arch": arch, "shape": shape_name,
        "mesh": dict(mesh.shape), "chips": chips,
        "params_b": pcount / 1e9,
        "opt_state_bits": ocfg.state_bits,
        "grad_accum": accum,
    }

    with shd.activate_mesh(mesh, rules):
        params_sds, params_sh = sp.param_specs(cfg, mesh, rules, log)

        if shape.kind == "train":
            opt_sds, opt_sh = sp.opt_specs(ocfg, params_sds, params_sh,
                                           mesh, rules, log)
            batch_sds, batch_sh = sp.batch_specs(cfg, shape, mesh, rules,
                                                 log)

            train_step = make_train_step(cfg, ocfg, accum=accum,
                                         grad_shardings=params_sh)

            metrics_sds = jax.eval_shape(train_step, params_sds, opt_sds,
                                         batch_sds)[2]
            fn = jax.jit(
                train_step,
                in_shardings=(params_sh, opt_sh, batch_sh),
                out_shardings=(params_sh, opt_sh,
                               _metrics_shardings(mesh, metrics_sds)),
                donate_argnums=(0, 1),
            )
            lowered = fn.lower(params_sds, opt_sds, batch_sds)

        elif shape.kind == "prefill":
            batch_sds, batch_sh = sp.batch_specs(cfg, shape, mesh, rules,
                                                 log)
            cache_sds, cache_sh = sp.cache_specs(cfg, shape, mesh, rules,
                                                 log)
            logits_sh = NamedSharding(mesh, PartitionSpec())

            def prefill_step(params, batch):
                return tfm.prefill(cfg, params, batch,
                                   capacity=shape.seq_len)

            fn = jax.jit(prefill_step,
                         in_shardings=(params_sh, batch_sh),
                         out_shardings=(logits_sh, cache_sh))
            lowered = fn.lower(params_sds, batch_sds)

        else:  # decode
            cache_sds, cache_sh = sp.cache_specs(cfg, shape, mesh, rules,
                                                 log)
            inp_sds, inp_sh = sp.decode_input_specs(cfg, shape, mesh,
                                                    rules, log)
            pos_sds = jax.ShapeDtypeStruct((), jnp.int32)
            logits_sh = NamedSharding(mesh, PartitionSpec())

            def serve_step(params, caches, inp, pos):
                return tfm.decode_step(cfg, params, caches, inp, pos)

            fn = jax.jit(serve_step,
                         in_shardings=(params_sh, cache_sh, inp_sh,
                                       sp.scalar_sharding(mesh)),
                         out_shardings=(logits_sh, cache_sh),
                         donate_argnums=(1,))
            lowered = fn.lower(params_sds, cache_sds, inp_sds, pos_sds)

        t0 = time.time()
        compiled = lowered.compile()
        record["compile_s"] = round(time.time() - t0, 1)

    # ---- analyses (printed per the dry-run contract) --------------------
    try:
        mem = compiled.memory_analysis()
        print(mem)                                # proves it fits
        ca = compiled.cost_analysis()
        ca = ca[0] if isinstance(ca, list) else ca
        print({k: ca.get(k) for k in ("flops", "bytes accessed")
               if k in ca})                        # FLOPs/bytes for §Roofline
        record["memory_analysis"] = {
            k: int(getattr(mem, k))
            for k in ("argument_size_in_bytes", "output_size_in_bytes",
                      "temp_size_in_bytes", "generated_code_size_in_bytes")
            if hasattr(mem, k)}
        args_b = record["memory_analysis"].get("argument_size_in_bytes", 0)
        tmp_b = record["memory_analysis"].get("temp_size_in_bytes", 0)
        record["hbm_per_device_gb"] = round((args_b + tmp_b) / 2**30, 3)
    except Exception as e:  # noqa: BLE001 — record and continue
        record["memory_analysis"] = f"unavailable: {e}"

    try:
        hlo = compiled.as_text()
        record["collective_bytes"] = parse_collective_bytes(hlo)
        rt = roofline_from_compiled(compiled, chips, hlo_text=hlo)
        record["roofline"] = rt.as_dict()
    except Exception as e:  # noqa: BLE001
        record["roofline"] = f"unavailable: {e}"

    record["sharding_fallbacks"] = sorted(set(log))
    return compiled, record


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None,
                    choices=list(sp.SHAPES) + [None])
    ap.add_argument("--mesh", default="single",
                    choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--skip-existing", action="store_true")
    ap.add_argument("--variant", default="base", choices=["base", "opt"])
    args = ap.parse_args()

    os.makedirs(args.out, exist_ok=True)
    archs = list(ASSIGNED) if args.all or not args.arch else [args.arch]
    shapes = list(sp.SHAPES) if args.all or not args.shape else [args.shape]
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]

    failures = []
    for arch in archs:
        for shape_name in shapes:
            for mp in meshes:
                tag = f"{arch}__{shape_name}__{'multi' if mp else 'single'}"
                if args.variant != "base":
                    tag += f"__{args.variant}"
                path = os.path.join(args.out, tag + ".json")
                if args.skip_existing and os.path.exists(path):
                    print(f"[skip] {tag}")
                    continue
                print(f"[cell] {tag} ...", flush=True)
                t0 = time.time()
                try:
                    compiled, rec = build_cell(arch, shape_name, mp,
                                               variant=args.variant)
                    rec["status"] = "ok"
                    del compiled
                except Exception as e:  # noqa: BLE001
                    rec = {"arch": arch, "shape": shape_name,
                           "multi_pod": mp, "status": "fail",
                           "error": f"{type(e).__name__}: {e}",
                           "traceback": traceback.format_exc()[-4000:]}
                    failures.append(tag)
                rec["wall_s"] = round(time.time() - t0, 1)
                with open(path, "w") as f:
                    json.dump(rec, f, indent=2, default=str)
                print(f"[done] {tag}: {rec['status']} "
                      f"({rec['wall_s']}s)", flush=True)

    print(f"\n{len(failures)} failures: {failures}")
    return 1 if failures else 0


if __name__ == "__main__":
    raise SystemExit(main())
