"""Production mesh construction.

Kept as functions (never module-level constants) so importing this module
never touches jax device state — the dry-run sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before any jax
initialisation, and smoke tests must keep seeing 1 device.
"""

from __future__ import annotations

import jax

__all__ = ["make_production_mesh", "make_test_mesh"]


def make_production_mesh(*, multi_pod: bool = False):
    """TPU v5e pod mesh: 16x16 = 256 chips per pod; 2 pods = 512 chips.

    Axes: ("data", "model") single-pod, ("pod", "data", "model") multi-pod.
    The "pod" axis carries cross-pod data parallelism (with optional int8
    error-feedback gradient compression — optim/compression.py) and is the
    slow-link axis: DCI between pods vs ICI within a pod.
    """
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_test_mesh(data: int = 2, model: int = 2):
    """Small mesh for subprocess-based distribution tests (8 host devices)."""
    return jax.make_mesh((data, model), ("data", "model"))
